"""Document retrieval demo: WMD top-k vs centroid-cosine baseline, plus a
convergence study of the "while x changes" loop (paper section III-B1).

    PYTHONPATH=src python examples/doc_retrieval.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core import (ell_from_dense, select_query, sinkhorn_wmd_converged,
                        sinkhorn_wmd_sparse)
from repro.data import make_corpus


def centroid_baseline(query, ell_dense, vecs):
    """Cheap baseline: cosine distance between frequency-weighted centroids."""
    qc = query @ vecs
    dc = ell_dense.T @ vecs                             # (N, w)
    qn = qc / np.linalg.norm(qc)
    dn = dc / np.maximum(np.linalg.norm(dc, axis=1, keepdims=True), 1e-9)
    return 1.0 - dn @ qn


def main():
    data = make_corpus(vocab_size=4096, embed_dim=32, num_docs=256,
                       num_queries=3, seed=1)
    c_dense = data.ell.to_dense()
    cols, vals = jnp.asarray(data.ell.cols), jnp.asarray(data.ell.vals)

    for qi, query in enumerate(data.queries):
        sel, r_sel = select_query(query)
        lamb = 0.5
        wmd = np.asarray(sinkhorn_wmd_sparse(sel, r_sel, cols, vals,
                                             data.vecs, lamb, 200))
        cen = centroid_baseline(query, c_dense, data.vecs)
        top_wmd = np.argsort(wmd)[:10]
        top_cen = np.argsort(cen)[:10]
        overlap = len(set(top_wmd) & set(top_cen))
        print(f"query {qi}: WMD top10 {top_wmd[:5].tolist()}... "
              f"centroid overlap {overlap}/10")

        # convergence: the 'ideal' while-x-changes loop vs the fixed cutoff
        out = sinkhorn_wmd_converged(sel, r_sel, cols, vals, data.vecs,
                                     lamb, 500, tol=1e-4)
        agree = np.argsort(np.asarray(out.wmd))[:10]
        print(f"         converged in {int(out.n_iter)} iters "
              f"(top10 matches 200-iter solve: "
              f"{np.array_equal(agree, top_wmd)})")


if __name__ == "__main__":
    main()
