"""End-to-end serving driver: batched WMD queries against a sharded corpus.

    PYTHONPATH=src python examples/wmd_query_service.py [--devices 8]
    PYTHONPATH=src python examples/wmd_query_service.py \
        --zipf-stream --cache-capacity 1024
    PYTHONPATH=src python examples/wmd_query_service.py \
        --coalesce --clients 8
    PYTHONPATH=src python examples/wmd_query_service.py \
        --top-k 8 --prune --docs 1024
    PYTHONPATH=src python examples/wmd_query_service.py \
        --offline 256 --top-k 8 --prune --cache-dir /tmp/wmd-jax-cache

Loads a corpus once onto the mesh (vocab-striped K + doc-sharded ELL),
then serves a stream of queries (bucketed by padded v_r, one psum per
Sinkhorn iteration). This is deliverable (b)'s "serve a small model with
batched requests" driver for the paper's own workload.

--zipf-stream demos the cross-query K cache on a realistic skewed workload:
batches drawn from `repro.data.zipf_query_stream` repeat word ids across
queries, so after a few batches most precompute rows are already resident
(`core.kcache`) and `query_batch` only computes the misses -- watch the
per-batch hit rate climb and the precompute phase shrink.

--top-k K --prune demos the two-tier pruned retriever: every doc is scored
with the O(nnz) doc-side RWMD lower bound (`core.rwmd`), and the exact
Sinkhorn rerank only runs on docs whose bound cannot rule them out of the
top-k. The demo prints the solves-avoided fraction and *verifies* the
pruned answer bitwise against `top_k_scan_batch`, the exhaustive scan
through the same chunked engine -- the exactness contract in one run.

--coalesce demos the async admission layer: ``--clients`` concurrent
closed-loop clients each submit single queries to a
`serving.coalescer.QueryCoalescer` (via `svc.async_service`) and the
coalescer micro-batches them into full `query_batch` dispatches -- the
batch-size histogram and client-side latency percentiles it prints are the
whole story (fill-triggered batches under load, window flushes at the
tail). Combine with --cache-capacity to watch the cross-query K cache's
hit rate ride along in the same report. Warmup now runs through the AOT
program-shape registry (`serving.warmup.ShapeRegistry`): every pow2 Q
bucket the coalescer can dispatch is precompiled before the first client
arrives, so no request ever pays a first-hit compile.

--offline N demos the bulk-scoring mode (`serving.offline.run_offline`):
N Zipf queries scored at maximum batch occupancy -- no admission windows,
pure throughput, the MLPerf offline scenario. With --top-k it uses union
rerank batching (one (Q, chunk) rerank program per candidate block for
the whole batch) and verifies the answer bitwise against the exhaustive
scan. Add --cache-dir DIR to persist compiled programs across processes:
the second run of the same command starts with zero backend compiles
(the production knob behind `launch/serve.py --warmup --cache-dir`).
"""
import argparse
import os


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, default=0)
    ap.add_argument("--docs", type=int, default=512)
    ap.add_argument("--vocab", type=int, default=4096)
    ap.add_argument("--queries", type=int, default=6)
    ap.add_argument("--batch-queries", action="store_true",
                    help="solve all queries in one batched (Q, v_r, N) "
                         "program and report throughput vs the loop")
    ap.add_argument("--docs-chunk", type=int, default=0,
                    help="cache-block the batched solve over doc chunks "
                         "of this size (0 = unchunked)")
    ap.add_argument("--zipf-stream", action="store_true",
                    help="serve batches from a Zipf query stream through "
                         "the cross-query K cache and print per-batch "
                         "hit rate + phase split")
    ap.add_argument("--cache-capacity", type=int, default=1024,
                    help="resident K/K.M rows for --zipf-stream and "
                         "--coalesce")
    ap.add_argument("--stream-batches", type=int, default=8)
    ap.add_argument("--coalesce", action="store_true",
                    help="fire concurrent single-query clients at the "
                         "async coalescer and print the batch-size "
                         "histogram + latency percentiles")
    ap.add_argument("--clients", type=int, default=8,
                    help="concurrent closed-loop clients for --coalesce")
    ap.add_argument("--requests-per-client", type=int, default=12)
    ap.add_argument("--coalesce-window-ms", type=float, default=5.0)
    ap.add_argument("--top-k", type=int, default=0,
                    help="> 0: run the two-tier pruned top-k demo with "
                         "this k (add --prune to prune; without it the "
                         "demo still verifies but prunes nothing)")
    ap.add_argument("--prune", action="store_true",
                    help="prune the top-k rerank with the RWMD prefilter "
                         "and print solves-avoided (verified bitwise "
                         "against the exact scan)")
    ap.add_argument("--prune-chunk", type=int, default=64,
                    help="doc-block size of the pruned rerank")
    ap.add_argument("--offline", type=int, default=0, metavar="N",
                    help="> 0: bulk-score N Zipf queries at max batch "
                         "occupancy (combine with --top-k/--prune for "
                         "union-rerank retrieval, verified vs the scan)")
    ap.add_argument("--cache-dir", default="",
                    help="persist jax-compiled programs here; a second "
                         "run of the same shapes starts with zero "
                         "backend compiles")
    args = ap.parse_args()
    if args.devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices}")

    import time
    import numpy as np
    import jax
    from repro.configs.sinkhorn_wmd import WMDConfig
    from repro.data import make_corpus
    from repro.launch.mesh import make_mesh
    from repro.serving import WMDService

    if args.cache_dir:
        # must be on before the first compile; programs are persisted
        # eagerly, keyed by (HLO, jaxlib, flags)
        from repro.serving import enable_compilation_cache
        enable_compilation_cache(args.cache_dir)

    n_dev = len(jax.devices())
    model_par = 2 if n_dev % 2 == 0 and n_dev > 1 else 1
    mesh = make_mesh((n_dev // model_par, model_par), ("data", "model"))
    print(f"mesh: data={n_dev // model_par} model={model_par}")

    cfg = WMDConfig(name="svc", vocab_size=args.vocab, embed_dim=64,
                    num_docs=args.docs, nnz_max=64, v_r=32, lamb=1.0,
                    max_iter=15)
    data = make_corpus(vocab_size=cfg.vocab_size, embed_dim=cfg.embed_dim,
                       num_docs=cfg.num_docs, num_queries=args.queries,
                       query_words=19, seed=0)
    t0 = time.perf_counter()
    svc = WMDService(mesh=mesh, cfg=cfg, vecs=data.vecs, ell=data.ell,
                     docs_chunk=args.docs_chunk or None,
                     prune_chunk=args.prune_chunk,
                     cache_capacity=(args.cache_capacity
                                     if args.zipf_stream or args.coalesce
                                     or args.top_k or args.offline else 0))
    print(f"corpus loaded+sharded in {time.perf_counter() - t0:.2f}s "
          f"(nnz={data.nnz})")

    if args.offline:
        # bulk-scoring mode: the whole workload is known up front, so the
        # scheduler is trivial and maximal -- full buckets, 100% occupancy.
        # Warmup first (registry pass), so the timed run never compiles;
        # with --cache-dir a SECOND process run reports 0 compiles here.
        from repro.data import zipf_query_stream
        from repro.serving import ShapeRegistry, run_offline, warm
        stream = zipf_query_stream(vocab_size=cfg.vocab_size,
                                   query_words=13, s=1.3, seed=0)
        qs = [next(stream) for _ in range(args.offline)]
        max_batch = 16
        kinds = ("plain",) if not args.top_k else ("top_k_union",)
        reg = ShapeRegistry.from_service(
            svc, max_batch=max_batch,
            ks=(args.top_k,) if args.top_k else (), kinds=kinds)
        rep = warm(svc, reg)
        print(f"warmup: {len(reg)} shapes, {rep.compiles} compiles "
              f"({rep.compile_s:.2f}s), {rep.persistent_hits} persisted-"
              f"cache hits in {rep.wall_s:.2f}s")
        off = run_offline(svc, qs,
                          k=args.top_k or None, max_batch=max_batch)
        print(f"offline: {off.n} queries in {off.batches} batches, "
              f"{off.throughput_qps:.1f} q/s")
        if args.top_k and args.prune:
            idx_s, d_s = svc.top_k_scan_batch(qs, args.top_k)
            exact = (np.array_equal(off.topk_idx, idx_s)
                     and np.array_equal(off.topk_dist, d_s))
            print(f"  union rerank: {off.rerank_programs} programs, "
                  f"solves avoided {off.solves_avoided:.1%}, "
                  f"bitwise-identical to the exact scan: {exact}")
            assert exact, "offline top-k must equal the exact scan"
        return

    if args.top_k:
        # two-tier retrieval: RWMD prefilter + exact Sinkhorn rerank. The
        # pruned answer is verified BITWISE against the exhaustive scan
        # through the same chunked engine -- fewer solves, same bits.
        from repro.data import zipf_query_stream
        stream = zipf_query_stream(vocab_size=cfg.vocab_size,
                                   query_words=13, s=1.3, seed=0)
        qs = [next(stream) for _ in range(args.queries)]
        svc.top_k_batch(qs, args.top_k, prune=args.prune)  # compile
        t0 = time.perf_counter()
        idx_p, d_p = svc.top_k_batch(qs, args.top_k, prune=args.prune)
        dt = time.perf_counter() - t0
        for i in range(len(qs)):
            print(f"query {i}: top{args.top_k}={idx_p[i].tolist()} "
                  f"d={np.round(d_p[i], 3).tolist()}")
        if args.prune:
            ps = dict(svc.last_prune_stats)
            idx_s, d_s = svc.top_k_scan_batch(qs, args.top_k)
            exact = (np.array_equal(idx_p, idx_s)
                     and np.array_equal(d_p, d_s))
            print(f"pruned top-{args.top_k}: Q={len(qs)} in "
                  f"{dt * 1e3:.1f} ms, solves avoided "
                  f"{ps['solves_avoided']:.1%} "
                  f"({ps['exact_solves']}/{ps['scan_solves']} exact "
                  f"solves, {ps['rerank_programs']} rerank programs, "
                  f"bound {ps['bound_s'] * 1e3:.1f} ms)")
            print(f"bitwise-identical to the exact scan: {exact}")
            assert exact, "pruned top-k must equal the exact scan"
        else:
            print(f"full-scan top-{args.top_k}: Q={len(qs)} in "
                  f"{dt * 1e3:.1f} ms (add --prune to skip provably "
                  f"out-of-top-k solves)")
        return

    if args.coalesce:
        # concurrent clients each submit ONE query at a time; the coalescer
        # turns that stream into full (Q, v_r, N) dispatches -- mean batch
        # size is the amortization the paper's batching wins come from
        import itertools
        from repro.data import zipf_query_stream
        from repro.serving import closed_loop
        stream = zipf_query_stream(vocab_size=cfg.vocab_size,
                                   query_words=13, s=1.3, seed=0)
        qs = list(itertools.islice(
            stream, args.clients * args.requests_per_client))
        max_batch = max(args.clients, 2)
        with svc.async_service(window_ms=args.coalesce_window_ms,
                               max_batch=max_batch,
                               max_queue=4 * max_batch) as co:
            rep = co.warm_registry(queries=qs)   # AOT: every pow2 bucket
            print(f"  warmed {len(rep.shapes)} shapes "
                  f"({rep.compiles} compiles, {rep.compile_s:.2f}s)")
            res = closed_loop(co.submit, qs, concurrency=args.clients)
            st = co.stats()
        print(f"coalesce: {args.clients} clients x "
              f"{args.requests_per_client} requests, "
              f"window={args.coalesce_window_ms:g} ms -> "
              f"{res.throughput_qps:.1f} q/s, "
              f"mean batch {st.mean_batch_size:.1f}")
        print(f"  dispatches={st.dispatches} (fill={st.dispatch_fill} "
              f"window={st.dispatch_window} drain={st.dispatch_drain}) "
              f"batch-size hist={st.batch_size_hist}")
        print(f"  client latency ms: p50={res.percentile_ms(50):.1f} "
              f"p95={res.percentile_ms(95):.1f} "
              f"p99={res.percentile_ms(99):.1f}"
              + (f"  cache hit_rate={st.hit_rate:.2f}"
                 if st.hit_rate is not None else ""))
        return

    if args.zipf_stream:
        # realistic skewed workload in one line: successive batches share
        # most of their vocabulary, so the cross-query K cache converges to
        # serving the precompute almost entirely from resident rows
        from repro.data import zipf_query_stream
        stream = zipf_query_stream(vocab_size=cfg.vocab_size,
                                   query_words=13, s=1.3, seed=0)
        q = max(args.queries, 8)
        for b in range(args.stream_batches):
            batch = [next(stream) for _ in range(q)]
            dists = svc.query_batch(batch)
            st = svc.last_batch_stats
            print(f"batch {b}: Q={q} top1={int(np.argmin(dists[0]))} "
                  f"hit_rate={st['hit_rate']:.2f} "
                  f"precompute={st['precompute_s'] * 1e3:.1f} ms "
                  f"solve={st['solve_s'] * 1e3:.1f} ms")
        cs = svc.cache_stats
        print(f"cache: cumulative hit_rate={cs.hit_rate:.2f} "
              f"evictions={cs.evictions} resident={svc.cache_resident}")
        return

    if args.batch_queries:
        # compile BOTH paths outside timing so the A/B compares solves only
        svc.query_batch(data.queries)
        svc.query_batch_sequential(data.queries)
        t0 = time.perf_counter()
        dists = svc.query_batch(data.queries)
        dt_b = time.perf_counter() - t0
        t0 = time.perf_counter()
        svc.query_batch_sequential(data.queries)
        dt_s = time.perf_counter() - t0
        for i, d in enumerate(dists):
            idx = np.argsort(d)[:3]
            print(f"query {i}: top3={idx.tolist()} "
                  f"d={np.round(d[idx], 3).tolist()}")
        q = len(data.queries)
        print(f"batched Q={q}: {dt_b * 1e3:.1f} ms ({q / dt_b:.1f} q/s) "
              f"vs sequential {dt_s * 1e3:.1f} ms ({q / dt_s:.1f} q/s) "
              f"-> {dt_s / dt_b:.2f}x")
        return

    lat = []
    for i, q in enumerate(data.queries):
        t0 = time.perf_counter()
        idx, dist = svc.top_k(q, k=3)
        dt = time.perf_counter() - t0
        lat.append(dt)
        print(f"query {i}: top3={idx.tolist()} "
              f"d={np.round(dist, 3).tolist()} ({dt * 1e3:.1f} ms)")
    lat = np.array(lat[1:]) * 1e3  # drop compile
    print(f"steady-state latency: p50={np.percentile(lat, 50):.1f} ms "
          f"p95={np.percentile(lat, 95):.1f} ms")


if __name__ == "__main__":
    main()
