"""Train a ~100M MoE LM with the paper's Sinkhorn-Knopp technique as the
router, for a few hundred steps (deliverable b's end-to-end train driver).

    PYTHONPATH=src python examples/train_moe_sinkhorn.py \
        [--steps 300] [--router sinkhorn|topk] [--devices 4]

The router solves a token->expert optimal-transport problem per layer with
the same `repro.core.ot` Sinkhorn core the WMD engine uses (DESIGN.md
section 5) -- balanced expert load by construction. Compare expert-load CV
against --router topk.
"""
import argparse
import os


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--router", choices=["sinkhorn", "topk"],
                    default="sinkhorn")
    ap.add_argument("--devices", type=int, default=0)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_moe_sinkhorn")
    args = ap.parse_args()
    if args.devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices}")

    import jax
    from repro.configs.base import ModelConfig, MoEConfig
    from repro.data import TokenPipeline
    from repro.launch.mesh import make_mesh
    from repro.models import build_model
    from repro.optim import adamw, warmup_cosine
    from repro.train import Trainer

    # ~100M-param MoE: 8 experts top-2, d=512, 8 layers, 16k vocab
    cfg = ModelConfig(
        name=f"moe-100m-{args.router}", family="moe", num_layers=8,
        d_model=512, num_heads=8, num_kv_heads=4, head_dim=64, d_ff=0,
        vocab_size=16_384, attn_kind="full", mlp_kind="silu_glu",
        norm_kind="rmsnorm",
        moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=1024,
                      router=args.router),
    )
    print(f"model: {cfg.name} ~{cfg.param_count() / 1e6:.0f}M params "
          f"({cfg.active_param_count() / 1e6:.0f}M active)")

    n_dev = len(jax.devices())
    mesh = make_mesh((n_dev, 1), ("data", "model"))
    model = build_model(cfg, q_block=64, kv_block=64)
    opt = adamw(warmup_cosine(3e-4, warmup_steps=args.steps // 10,
                              total_steps=args.steps))
    pipe = TokenPipeline(cfg, batch=args.batch, seq_len=args.seq_len)
    trainer = Trainer(model, opt, mesh, pipe,
                      ckpt_dir=f"{args.ckpt_dir}-{args.router}",
                      ckpt_every=100)
    out = trainer.run(jax.random.PRNGKey(0), args.steps)
    hist = out["history"]
    print(f"[{args.router}] loss {hist[0]['loss']:.4f} -> "
          f"{hist[-1]['loss']:.4f} over {len(hist)} steps "
          f"({sum(h['sec'] for h in hist):.1f}s)")


if __name__ == "__main__":
    main()
