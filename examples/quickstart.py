"""Quickstart: compute Word-Movers Distances of one query against a corpus.

    PYTHONPATH=src python examples/quickstart.py

Builds a synthetic dbpedia-statistics corpus, runs the paper-faithful dense
solver and the PASWD sparse-fused solver, checks they agree, and prints the
nearest documents.
"""
import time

import jax.numpy as jnp
import numpy as np

from repro.core import (ell_from_dense, select_query, sinkhorn_wmd_dense,
                        sinkhorn_wmd_sparse)
from repro.data import make_corpus

VOCAB, EMBED, DOCS = 8_000, 300, 256
LAMB, ITERS = 1.0, 15


def main():
    print(f"corpus: V={VOCAB} w={EMBED} N={DOCS}")
    data = make_corpus(vocab_size=VOCAB, embed_dim=EMBED, num_docs=DOCS,
                       num_queries=1, seed=0)
    query = data.queries[0]
    sel, r_sel = select_query(query)
    print(f"query: v_r={len(sel)} words; corpus nnz={data.nnz} "
          f"(density {data.nnz / (VOCAB * DOCS):.4%})")

    # paper Algorithm 1, dense (the faithful baseline)
    c_dense = jnp.asarray(data.ell.to_dense())
    t0 = time.perf_counter()
    wmd_dense = np.asarray(sinkhorn_wmd_dense(sel, r_sel, c_dense,
                                              data.vecs, LAMB, ITERS))
    t_dense = time.perf_counter() - t0

    # PASWD: sparse fused SDDMM-SpMM (the paper's contribution)
    cols, vals = jnp.asarray(data.ell.cols), jnp.asarray(data.ell.vals)
    sinkhorn_wmd_sparse(sel, r_sel, cols, vals, data.vecs, LAMB,
                        ITERS).block_until_ready()  # warm compile
    t0 = time.perf_counter()
    wmd_sparse = np.asarray(sinkhorn_wmd_sparse(sel, r_sel, cols, vals,
                                                data.vecs, LAMB, ITERS))
    t_sparse = time.perf_counter() - t0

    err = np.abs(wmd_dense - wmd_sparse).max() / np.abs(wmd_dense).max()
    print(f"dense  : {t_dense * 1e3:8.1f} ms")
    print(f"sparse : {t_sparse * 1e3:8.1f} ms "
          f"({t_dense / t_sparse:.1f}x)   max rel diff {err:.2e}")
    top = np.argsort(wmd_sparse)[:5]
    print("nearest docs:", top.tolist())
    print("distances   :", np.round(wmd_sparse[top], 4).tolist())


if __name__ == "__main__":
    main()
