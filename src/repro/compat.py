"""Version compatibility shims for the jax API surface this repo uses.

The codebase targets current jax (`jax.shard_map`, `jax.sharding.AxisType`,
``check_vma=``); CI and some containers pin older CPU jax where those names
live elsewhere (`jax.experimental.shard_map.shard_map`, no axis types,
``check_rep=``). Everything version-dependent is funneled through here so the
rest of the code imports one spelling.
"""
from __future__ import annotations

import jax


def shard_map(fn, *, mesh, in_specs, out_specs, check_vma: bool = False):
    """`jax.shard_map` with fallback to `jax.experimental.shard_map`.

    Older jax calls the replication-checking flag ``check_rep``; newer jax
    renamed it ``check_vma``. Semantics at False are equivalent (skip the
    check), which is the only way this repo calls it.
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(fn, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(fn, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, check_rep=check_vma)


def make_mesh(shape, axes) -> jax.sharding.Mesh:
    """`jax.make_mesh` with explicit Auto axis types where supported."""
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            tuple(shape), tuple(axes),
            axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
    return jax.make_mesh(tuple(shape), tuple(axes))


def abstract_mesh(shape, axes) -> "jax.sharding.AbstractMesh":
    """`jax.sharding.AbstractMesh` across the constructor signature change:
    new jax takes (sizes, names, axis_types=...), old jax one shape tuple."""
    if hasattr(jax.sharding, "AxisType"):
        return jax.sharding.AbstractMesh(
            tuple(shape), tuple(axes),
            axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
    return jax.sharding.AbstractMesh(tuple(zip(axes, shape)))
