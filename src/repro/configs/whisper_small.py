"""whisper-small [audio] -- enc-dec, 12L each, d_model=768 12H d_ff=3072
vocab=51865. Conv frontend is a STUB per the assignment: input_specs()
provides precomputed mel-frame embeddings (1500 positions) consumed by the
encoder; decoder has causal self-attention + cross-attention. Learned
positional embeddings, LayerNorm, non-gated GELU.
[arXiv:2212.04356; unverified]

Note: whisper's published decoder context is 448 tokens; the assigned
prefill/decode shapes (32k) exercise the backbone mechanically at the
framework level (position table sized to the shape) -- recorded in
DESIGN.md section 5.
"""
from repro.configs.base import EncoderConfig, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-small",
        family="audio",
        num_layers=12,
        d_model=768,
        num_heads=12,
        num_kv_heads=12,
        head_dim=64,
        d_ff=3072,
        vocab_size=51865,
        attn_kind="full",
        use_rope=False,
        learned_pos=True,
        mlp_kind="gelu",
        norm_kind="layernorm",
        encoder=EncoderConfig(kind="audio_frames", num_positions=1500,
                              num_layers=12, bidirectional=True),
        supports_long_context=False,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="whisper-small-smoke",
        family="audio",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        attn_kind="full",
        use_rope=False,
        learned_pos=True,
        mlp_kind="gelu",
        norm_kind="layernorm",
        encoder=EncoderConfig(kind="audio_frames", num_positions=16,
                              num_layers=2, bidirectional=True),
    )
