"""paligemma-3b [vlm] -- 18L d_model=2048 8H (kv=1) d_ff=16384 vocab=257216,
SigLIP vision tower + gemma-2B text backbone. The SigLIP frontend is a STUB
per the assignment: input_specs() provides precomputed patch embeddings
(256 patches at 224px/14px) which the backbone consumes as a full-attention
prefix (prefix-LM masking). [arXiv:2407.07726; hf]
"""
from repro.configs.base import EncoderConfig, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="paligemma-3b",
        family="vlm",
        num_layers=18,
        d_model=2048,
        num_heads=8,
        num_kv_heads=1,
        head_dim=256,
        d_ff=16384,
        vocab_size=257216,
        attn_kind="full",
        mlp_kind="geglu",
        norm_kind="rmsnorm",
        embed_scale=True,
        tie_embeddings=True,
        encoder=EncoderConfig(kind="image_patches", num_positions=256,
                              num_layers=0, bidirectional=True),
        supports_long_context=False,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="paligemma-3b-smoke",
        family="vlm",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=1,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        attn_kind="full",
        mlp_kind="geglu",
        norm_kind="rmsnorm",
        embed_scale=True,
        tie_embeddings=True,
        encoder=EncoderConfig(kind="image_patches", num_positions=8,
                              num_layers=0, bidirectional=True),
    )
