"""--arch <id> registry: maps architecture ids to their config modules."""
from __future__ import annotations

import importlib

from repro.configs.base import ModelConfig, SHAPES, ShapeConfig

_ARCHS = {
    "mixtral-8x22b": "repro.configs.mixtral_8x22b",
    "deepseek-moe-16b": "repro.configs.deepseek_moe_16b",
    "xlstm-125m": "repro.configs.xlstm_125m",
    "paligemma-3b": "repro.configs.paligemma_3b",
    "recurrentgemma-9b": "repro.configs.recurrentgemma_9b",
    "minicpm3-4b": "repro.configs.minicpm3_4b",
    "olmo-1b": "repro.configs.olmo_1b",
    "gemma-2b": "repro.configs.gemma_2b",
    "starcoder2-3b": "repro.configs.starcoder2_3b",
    "whisper-small": "repro.configs.whisper_small",
}


def arch_ids() -> list[str]:
    return list(_ARCHS)


def _module(arch: str):
    if arch not in _ARCHS:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_ARCHS)}")
    return importlib.import_module(_ARCHS[arch])


def get_config(arch: str) -> ModelConfig:
    return _module(arch).config()


def get_smoke_config(arch: str) -> ModelConfig:
    return _module(arch).smoke_config()


def get_shape(shape: str) -> ShapeConfig:
    return SHAPES[shape]


def cells() -> list[tuple[str, str]]:
    """All 40 (arch x shape) dry-run cells, including the documented skips."""
    return [(a, s) for a in arch_ids() for s in SHAPES]


def cell_supported(arch: str, shape: str) -> tuple[bool, str]:
    """Whether the cell runs, and the reason if skipped (DESIGN.md section 5)."""
    cfg = get_config(arch)
    if shape == "long_500k" and not cfg.supports_long_context:
        return False, ("pure full-attention arch: long_500k requires "
                       "sub-quadratic sequence mixing (skip per assignment)")
    return True, ""
