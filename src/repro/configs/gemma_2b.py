"""gemma-2b [dense] -- 18L d_model=2048 8H (MQA kv=1) d_ff=16384 vocab=256000,
GeGLU MLP, head_dim=256, embeddings scaled by sqrt(d_model), tied softmax.
[arXiv:2403.08295; hf]
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma-2b",
        family="dense",
        num_layers=18,
        d_model=2048,
        num_heads=8,
        num_kv_heads=1,
        head_dim=256,
        d_ff=16384,
        vocab_size=256000,
        attn_kind="full",
        mlp_kind="geglu",
        norm_kind="rmsnorm",
        embed_scale=True,
        tie_embeddings=True,
        supports_long_context=False,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="gemma-2b-smoke",
        family="dense",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=1,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        attn_kind="full",
        mlp_kind="geglu",
        norm_kind="rmsnorm",
        embed_scale=True,
        tie_embeddings=True,
    )
