"""xlstm-125m [ssm] -- 12L d_model=768 4H d_ff=0 vocab=50304, sLSTM + mLSTM
blocks. Pattern chosen as 5 mLSTM : 1 sLSTM per 6-layer unit (the xLSTM
paper's LM configs are mLSTM-dominant, e.g. xLSTM[7:1]); source is tagged
`unverified` in the assignment so the ratio is a documented choice.
[arXiv:2405.04517; unverified]
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="xlstm-125m",
        family="ssm",
        num_layers=12,
        d_model=768,
        num_heads=4,
        num_kv_heads=4,
        head_dim=192,
        d_ff=0,  # xLSTM blocks carry their own up/down projections
        vocab_size=50304,
        block_pattern=("mlstm", "mlstm", "mlstm", "mlstm", "mlstm", "slstm"),
        attn_kind="none",
        use_rope=False,
        norm_kind="layernorm",
        supports_long_context=True,  # recurrent state, O(1) per decode step
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="xlstm-125m-smoke",
        family="ssm",
        num_layers=2,
        d_model=64,
        num_heads=2,
        num_kv_heads=2,
        head_dim=32,
        d_ff=0,
        vocab_size=256,
        block_pattern=("mlstm", "slstm"),
        attn_kind="none",
        use_rope=False,
        norm_kind="layernorm",
        supports_long_context=True,
    )
