"""Config system: every architecture is a frozen dataclass instance.

One file per assigned architecture under `repro.configs`; each exposes
``config()`` returning the exact published dims plus ``smoke_config()``
returning a reduced same-family config for CPU smoke tests. The registry
(`repro.configs.registry`) maps ``--arch <id>`` to these.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int                 # routed experts
    top_k: int
    d_ff_expert: int                 # per-expert hidden dim
    num_shared: int = 0              # always-on shared experts (deepseek)
    capacity_factor: float = 1.25
    router: str = "topk"             # "topk" | "sinkhorn" (paper's technique)
    sinkhorn_iters: int = 8
    sinkhorn_lamb: float = 8.0
    router_aux_loss: float = 0.01    # load-balance aux loss weight (topk)
    first_dense_layers: int = 0      # deepseek: layer 0 is a dense FFN
    d_ff_dense_first: int = 0        # hidden dim of that dense first layer


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """Multi-head Latent Attention (MiniCPM3 / DeepSeek-V2 style)."""
    q_lora_rank: int = 768
    kv_lora_rank: int = 256
    qk_nope_head_dim: int = 64
    qk_rope_head_dim: int = 32
    v_head_dim: int = 64


@dataclasses.dataclass(frozen=True)
class EncoderConfig:
    """Frontend/encoder for [audio]/[vlm] archs. The modality frontend is a
    STUB per the assignment: input_specs() provides precomputed frame/patch
    embeddings; only the transformer backbone is real."""
    kind: str                        # "audio_frames" | "image_patches"
    num_positions: int               # frames (whisper: 1500) / patches (256)
    num_layers: int = 0              # encoder transformer depth (whisper)
    bidirectional: bool = True


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int                        # dense-MLP hidden (0 = no separate MLP)
    vocab_size: int

    # block structure: repeating pattern of layer kinds; len must divide into
    # num_layers with the remainder unrolled. kinds: "attn", "mlstm", "slstm",
    # "rglru".
    block_pattern: Tuple[str, ...] = ("attn",)

    # attention details
    attn_kind: str = "full"          # full | swa | local (window-limited)
    window: int = 0                  # swa/local window size
    rope_theta: float = 10000.0
    use_rope: bool = True
    learned_pos: bool = False        # whisper-style learned positions
    logit_softcap: float = 0.0

    # mlp / norm
    mlp_kind: str = "silu_glu"       # silu_glu | geglu | gelu (non-gated)
    norm_kind: str = "rmsnorm"       # rmsnorm | layernorm | nonparam_ln
    embed_scale: bool = False        # gemma: embeddings * sqrt(d_model)
    tie_embeddings: bool = False

    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    # absorbed MLA decode (W_uk folded into q, W_uv into output): same math,
    # O(S*(r+rope)) per head instead of re-expanding K/V -- §Perf hillclimb
    # for decode_32k x minicpm3. False = paper-naive decode for A/B.
    mla_absorbed: bool = True
    encoder: Optional[EncoderConfig] = None
    rglru_conv_width: int = 4        # recurrentgemma conv1d temporal width

    # numerics
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"

    # long_500k applicability: True only for sub-quadratic sequence mixing
    # (state recurrences or bounded attention windows). DESIGN.md section 5.
    supports_long_context: bool = False

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks), for sanity."""
        d, l = self.d_model, self.num_layers
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        n_attn = sum(1 for k in self._layer_kinds() if k == "attn")
        n_rec = l - n_attn
        # attention
        if self.mla is not None:
            m = self.mla
            qk = m.qk_nope_head_dim + m.qk_rope_head_dim
            per_attn = (d * m.q_lora_rank + m.q_lora_rank * self.num_heads * qk
                        + d * (m.kv_lora_rank + m.qk_rope_head_dim)
                        + m.kv_lora_rank * self.num_heads
                        * (m.qk_nope_head_dim + m.v_head_dim)
                        + self.num_heads * m.v_head_dim * d)
        else:
            per_attn = (d * self.num_heads * self.head_dim * 2
                        + d * self.num_kv_heads * self.head_dim * 2)
        # recurrent blocks, per kind: rglru has 5 full d x d matrices
        # (gate, in, a, x, out); mlstm 6 (up, gate, q, k, v, down);
        # slstm ~6 effective (w = 4 d^2 block + gate/down + block-diag R)
        rec_weights = {"rglru": 5, "mlstm": 6, "slstm": 6}
        per_rec_by_kind = {k: n * d * d for k, n in rec_weights.items()}
        kinds = self._layer_kinds()
        rec_total = sum(per_rec_by_kind.get(k, 0) for k in kinds
                        if k != "attn")
        per_rec = 0  # folded into rec_total below
        # mlp
        if self.moe is not None:
            e = self.moe
            per_mlp = (e.num_experts + e.num_shared) * 3 * d * e.d_ff_expert \
                + d * e.num_experts
        elif self.d_ff > 0:
            gates = 3 if self.mlp_kind in ("silu_glu", "geglu") else 2
            per_mlp = gates * d * self.d_ff
        else:
            per_mlp = 0
        per_layer = per_mlp
        total = emb + n_attn * per_attn + rec_total + l * per_layer
        if self.encoder is not None and self.encoder.num_layers:
            enc_attn = 4 * d * d
            enc_mlp = 2 * d * self.d_ff
            total += self.encoder.num_layers * (enc_attn + enc_mlp)
            total += n_attn * 2 * d * d  # decoder cross-attention (approx)
        return total

    def active_param_count(self) -> int:
        """Params touched per token: MoE counts shared + top_k experts only
        (the 6*N_active*D convention for MoE MFU)."""
        if self.moe is None:
            return self.param_count()
        e = self.moe
        per_expert = 3 * self.d_model * e.d_ff_expert
        inactive = (e.num_experts - e.top_k) * per_expert \
            * (self.num_layers - e.first_dense_layers)
        return self.param_count() - inactive

    def _layer_kinds(self) -> Tuple[str, ...]:
        reps = self.num_layers // len(self.block_pattern)
        tail = self.num_layers % len(self.block_pattern)
        return self.block_pattern * reps + self.block_pattern[:tail]

    def layer_kinds(self) -> Tuple[str, ...]:
        return self._layer_kinds()


# the four assigned input shapes (LM family)
@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}
