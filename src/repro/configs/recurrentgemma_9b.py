"""recurrentgemma-9b [hybrid] -- 38L d_model=4096 16H (kv=1 MQA on the
attention layers) d_ff=12288 vocab=256000, Griffin block pattern: RG-LRU,
RG-LRU, local attention (1:2 attn:recurrent), window 2048.
[arXiv:2402.19427; unverified]
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-9b",
        family="hybrid",
        num_layers=38,
        d_model=4096,
        num_heads=16,
        num_kv_heads=1,
        head_dim=256,
        d_ff=12288,
        vocab_size=256000,
        block_pattern=("rglru", "rglru", "attn"),
        attn_kind="local",
        window=2048,
        mlp_kind="geglu",
        norm_kind="rmsnorm",
        embed_scale=True,
        tie_embeddings=True,
        rglru_conv_width=4,
        supports_long_context=True,  # RG-LRU state + bounded local window
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-9b-smoke",
        family="hybrid",
        num_layers=3,
        d_model=64,
        num_heads=4,
        num_kv_heads=1,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        block_pattern=("rglru", "rglru", "attn"),
        attn_kind="local",
        window=16,
        mlp_kind="geglu",
        norm_kind="rmsnorm",
        embed_scale=True,
        tie_embeddings=True,
        supports_long_context=True,
    )
