"""mixtral-8x22b [moe] -- 56L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=32768, MoE 8 experts top-2, sliding-window attention.
[arXiv:2401.04088; hf]
"""
from repro.configs.base import ModelConfig, MoEConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x22b",
        family="moe",
        num_layers=56,
        d_model=6144,
        num_heads=48,
        num_kv_heads=8,
        head_dim=128,
        d_ff=0,  # every layer is MoE
        vocab_size=32768,
        attn_kind="swa",
        window=4096,
        rope_theta=1_000_000.0,
        mlp_kind="silu_glu",
        norm_kind="rmsnorm",
        moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=16384),
        supports_long_context=True,  # SWA bounds the KV cache at `window`
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x22b-smoke",
        family="moe",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=0,
        vocab_size=256,
        attn_kind="swa",
        window=16,
        mlp_kind="silu_glu",
        norm_kind="rmsnorm",
        moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=128),
        supports_long_context=True,
    )
