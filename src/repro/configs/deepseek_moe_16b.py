"""deepseek-moe-16b [moe] -- 28L d_model=2048 16H (GQA kv=16) d_ff=1408
vocab=102400, MoE: 2 shared + 64 routed top-6, fine-grained experts; first
layer is a dense FFN (d_ff 10944). [arXiv:2401.06066; hf]
"""
from repro.configs.base import ModelConfig, MoEConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-moe-16b",
        family="moe",
        num_layers=28,
        d_model=2048,
        num_heads=16,
        num_kv_heads=16,
        head_dim=128,
        d_ff=0,
        vocab_size=102400,
        attn_kind="full",
        rope_theta=10000.0,
        mlp_kind="silu_glu",
        norm_kind="rmsnorm",
        moe=MoEConfig(
            num_experts=64, top_k=6, d_ff_expert=1408, num_shared=2,
            first_dense_layers=1, d_ff_dense_first=10944,
        ),
        supports_long_context=False,  # pure full attention
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-moe-16b-smoke",
        family="moe",
        num_layers=3,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        head_dim=16,
        d_ff=0,
        vocab_size=256,
        attn_kind="full",
        mlp_kind="silu_glu",
        norm_kind="rmsnorm",
        moe=MoEConfig(num_experts=8, top_k=3, d_ff_expert=32, num_shared=2,
                      first_dense_layers=1, d_ff_dense_first=128),
    )
