"""starcoder2-3b [dense] -- 30L d_model=3072 24H (GQA kv=2) d_ff=12288
vocab=49152, GQA + RoPE, LayerNorm, non-gated GELU MLP.
[arXiv:2402.19173; hf]
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-3b",
        family="dense",
        num_layers=30,
        d_model=3072,
        num_heads=24,
        num_kv_heads=2,
        head_dim=128,
        d_ff=12288,
        vocab_size=49152,
        attn_kind="full",
        rope_theta=100_000.0,
        mlp_kind="gelu",
        norm_kind="layernorm",
        supports_long_context=False,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-3b-smoke",
        family="dense",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        attn_kind="full",
        mlp_kind="gelu",
        norm_kind="layernorm",
    )
