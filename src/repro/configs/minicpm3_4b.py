"""minicpm3-4b [dense] -- 62L d_model=2560 40H d_ff=6400 vocab=73448,
Multi-head Latent Attention (MLA): q_lora 768, kv_lora 256, qk_nope 64,
qk_rope 32, v_head 64. [hf:openbmb/MiniCPM3-4B; hf]
"""
from repro.configs.base import MLAConfig, ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="minicpm3-4b",
        family="dense",
        num_layers=62,
        d_model=2560,
        num_heads=40,
        num_kv_heads=40,
        head_dim=64,  # v head dim; qk dims live in MLAConfig
        d_ff=6400,
        vocab_size=73448,
        attn_kind="full",
        mlp_kind="silu_glu",
        norm_kind="rmsnorm",
        tie_embeddings=True,
        mla=MLAConfig(q_lora_rank=768, kv_lora_rank=256,
                      qk_nope_head_dim=64, qk_rope_head_dim=32,
                      v_head_dim=64),
        supports_long_context=False,  # full attention (MLA compresses the
        # cache but per-step cost is still O(T) over 500k; skipped per spec)
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="minicpm3-4b-smoke",
        family="dense",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        attn_kind="full",
        mlp_kind="silu_glu",
        norm_kind="rmsnorm",
        tie_embeddings=True,
        mla=MLAConfig(q_lora_rank=32, kv_lora_rank=16,
                      qk_nope_head_dim=16, qk_rope_head_dim=8,
                      v_head_dim=16),
    )
