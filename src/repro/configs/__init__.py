"""Architecture configs: one module per assigned arch + the paper's own
Sinkhorn-WMD workload. See `repro.configs.registry` for --arch dispatch."""
from repro.configs.base import (MLAConfig, ModelConfig, MoEConfig,
                                EncoderConfig, SHAPES, ShapeConfig)
from repro.configs.registry import (arch_ids, cell_supported, cells,
                                    get_config, get_shape, get_smoke_config)

__all__ = [
    "MLAConfig", "ModelConfig", "MoEConfig", "EncoderConfig", "SHAPES",
    "ShapeConfig", "arch_ids", "cell_supported", "cells", "get_config",
    "get_shape", "get_smoke_config",
]
