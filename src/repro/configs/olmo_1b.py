"""olmo-1b [dense] -- 16L d_model=2048 16H d_ff=8192 vocab=50304, SwiGLU MLP,
non-parametric LayerNorm (no learnable scale/bias -- OLMo's hallmark).
[arXiv:2402.00838; hf]
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="olmo-1b",
        family="dense",
        num_layers=16,
        d_model=2048,
        num_heads=16,
        num_kv_heads=16,
        head_dim=128,
        d_ff=8192,
        vocab_size=50304,
        attn_kind="full",
        mlp_kind="silu_glu",
        norm_kind="nonparam_ln",
        tie_embeddings=True,
        supports_long_context=False,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="olmo-1b-smoke",
        family="dense",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        attn_kind="full",
        mlp_kind="silu_glu",
        norm_kind="nonparam_ln",
        tie_embeddings=True,
    )
