"""The paper's own workload as a first-class config: Sinkhorn-WMD query
service at production scale. Not one of the 10 assigned LM archs -- this is
the 11th config so the paper's actual kernel is dry-run/roofline'd on the
production mesh alongside them.

Shapes (paper section III-B2 scaled up per its "database of 5M documents"
motivation):
  paper_5k  -- the paper's measured dataset: V=100k, w=300, N=5000,
               nnz ~ 173k (nnz_max 128), v_r bucket 32, 15 iterations.
  prod_5m   -- the paper's motivating scale: N = 5M docs, same vocab.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class WMDConfig:
    name: str
    vocab_size: int
    embed_dim: int
    num_docs: int
    nnz_max: int          # padded ELL slots per doc (pre vocab-bucketing)
    v_r: int              # query bucket size (padded)
    lamb: float
    max_iter: int
    num_queries: int = 1  # simultaneous query batch (vmapped)


def config(shape: str = "paper_5k") -> WMDConfig:
    if shape == "paper_5k":
        return WMDConfig(name="sinkhorn-wmd/paper_5k", vocab_size=100_000,
                         embed_dim=300, num_docs=5_000, nnz_max=128, v_r=32,
                         lamb=1.0, max_iter=15)
    if shape == "prod_5m":
        return WMDConfig(name="sinkhorn-wmd/prod_5m", vocab_size=100_000,
                         embed_dim=300, num_docs=5_242_880, nnz_max=128,
                         v_r=32, lamb=1.0, max_iter=15)
    raise ValueError(f"unknown wmd shape {shape!r}")


def smoke_config() -> WMDConfig:
    return WMDConfig(name="sinkhorn-wmd-smoke", vocab_size=512, embed_dim=32,
                     num_docs=64, nnz_max=16, v_r=8, lamb=1.0, max_iter=5)
