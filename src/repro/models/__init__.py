"""Model stack: layers, decoder-only LM, enc-dec, uniform ModelAPI."""
from repro.models.registry import ModelAPI, build_model, cross_entropy

__all__ = ["ModelAPI", "build_model", "cross_entropy"]
