"""Activation-sharding hints (with_sharding_constraint at key cut points).

GSPMD propagates parameter shardings well through matmuls but needs anchors
on the few giant activations whose sharding is under-determined -- above all
the (B, S, V) logits: left unconstrained they shard only over batch, and the
f32 CE intermediates blow past HBM (measured: olmo train_4k 79 GiB/chip
temp before hints).

Models are mesh-agnostic; the axes come from a contextvar set by
``activation_sharding(mesh)`` around trace time (dry-run, trainer, serving
all wrap their trace/call sites). When the context is unset (unit tests,
single-device runs) every hint is a no-op.
"""
from __future__ import annotations

import contextlib
from contextvars import ContextVar
from typing import Optional

import jax
from jax.sharding import PartitionSpec as P

_AXES: ContextVar[Optional[tuple]] = \
    ContextVar("activation_sharding_axes", default=None)


@contextlib.contextmanager
def activation_sharding(mesh: jax.sharding.Mesh, mode: str = "train"):
    """mode: "train"/"prefill" (token counts amortize FSDP weight gathers)
    or "decode" (single token: gathering multi-GB MoE expert weights per
    step is a loss -- measured 20x on mixtral decode; 3D expert weights
    stay sharded and GSPMD reduces the tiny activations instead)."""
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    model = "model" if "model" in mesh.axis_names else None
    token = _AXES.set((dp, model, mode))
    try:
        yield
    finally:
        _AXES.reset(token)


def _get():
    return _AXES.get()


def hint_logits(x: jax.Array) -> jax.Array:
    """(..., S, V): batch over dp, vocab over model."""
    ctx = _get()
    if ctx is None:
        return x
    dp, model = ctx[0], ctx[1]
    spec = P(dp, *([None] * (x.ndim - 2)), model)
    return jax.lax.with_sharding_constraint(x, spec)


def hint_activations(x: jax.Array) -> jax.Array:
    """(B, S, D): batch over dp, rest replicated."""
    ctx = _get()
    if ctx is None:
        return x
    dp = ctx[0]
    spec = P(dp, *([None] * (x.ndim - 1)))
    return jax.lax.with_sharding_constraint(x, spec)


def fsdp_use(w: jax.Array, name: str, dtype=None) -> jax.Array:
    """FSDP gather point: cast (bf16 first => half the gather bytes) and
    constrain the weight to its TP-only spec -- i.e. explicitly un-shard the
    ``data`` (FSDP) axis at the point of use.

    Without this anchor GSPMD tends to keep the weight sharded on the
    contraction dim and all-reduce the *activation* gradients instead --
    measured at ~12 GB/layer on mixtral train (EXPERIMENTS.md §Perf). With
    it, the forward all-gathers weight shards (bf16, layer-sized) and the
    weight-grad reduction becomes a reduce-scatter back to the FSDP shard --
    the canonical FSDP dataflow.
    """
    out = w.astype(dtype) if dtype is not None else w
    ctx = _get()
    if ctx is None:
        return out
    if len(ctx) > 2 and ctx[2] == "decode" and w.ndim >= 3:
        return out    # MoE expert weights: stay sharded at decode
    from repro.distributed.partitioning import _RULES, _RULES_3D
    base = None
    if w.ndim >= 3 and name in _RULES_3D:
        base = _RULES_3D[name]
    elif name in _RULES:
        base = _RULES[name]
    if base is None or len(base) > w.ndim:
        return out
    entries = [None if e == "data" else e for e in base]
    entries += [None] * (w.ndim - len(entries))
    return jax.lax.with_sharding_constraint(out, P(*entries))


def hint_moe_tokens(x: jax.Array, replicate_at_decode: bool = True
                    ) -> jax.Array:
    """MoE dispatch/output buffers (B, E, C, D): batch over dp only.

    In decode mode, when the buffers are smaller than the expert-weight
    gather (few big experts, e.g. mixtral: 25 MB of tokens vs 200 MB of
    weights per layer), replicating them lets GSPMD keep weights sharded
    and all-reduce activation-sized partials instead of streaming weights
    (measured 3.8x on mixtral decode). Fine-grained MoE (deepseek, 64 small
    experts) inverts the trade-off -- the caller passes the heuristic."""
    ctx = _get()
    if ctx is None:
        return x
    if len(ctx) > 2 and ctx[2] == "decode" and replicate_at_decode:
        return jax.lax.with_sharding_constraint(
            x, P(*([None] * x.ndim)))
    dp = ctx[0]
    return jax.lax.with_sharding_constraint(
        x, P(dp, *([None] * (x.ndim - 1))))


def hint_moe_hidden(x: jax.Array, replicate_at_decode: bool = True
                    ) -> jax.Array:
    """MoE expert hidden (B, E, C, F): batch over dp, F over model (TP)."""
    ctx = _get()
    if ctx is None:
        return x
    if len(ctx) > 2 and ctx[2] == "decode" and replicate_at_decode:
        return jax.lax.with_sharding_constraint(
            x, P(*([None] * (x.ndim - 1)), ctx[1]))
    dp, model = ctx[0], ctx[1]
    return jax.lax.with_sharding_constraint(
        x, P(dp, *([None] * (x.ndim - 2)), model))
