"""Encoder-decoder assembly (whisper-small).

The conv/mel frontend is a STUB per the assignment: the model consumes
precomputed frame embeddings (B, frames, d_model) through a linear adapter.
Encoder: bidirectional self-attention layers (scanned). Decoder: causal
self-attention + cross-attention + MLP (scanned). Decode cache holds the
per-layer self-attention KV ring plus the precomputed cross K/V.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import attention, embedding, mlp, norms

Params = Any
Cache = Any


def _enc_layer_init(key, cfg, dtype):
    k1, k2 = jax.random.split(key)
    return {
        "attn_norm": norms.init(cfg.norm_kind, cfg.d_model, dtype),
        "attn": attention.init(k1, cfg, dtype),
        "mlp_norm": norms.init(cfg.norm_kind, cfg.d_model, dtype),
        "mlp": mlp.init(k2, cfg.mlp_kind, cfg.d_model, cfg.d_ff, dtype),
    }


def _dec_layer_init(key, cfg, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "self_norm": norms.init(cfg.norm_kind, cfg.d_model, dtype),
        "self_attn": attention.init(k1, cfg, dtype),
        "cross_norm": norms.init(cfg.norm_kind, cfg.d_model, dtype),
        "cross_attn": attention.init(k2, cfg, dtype),
        "mlp_norm": norms.init(cfg.norm_kind, cfg.d_model, dtype),
        "mlp": mlp.init(k3, cfg.mlp_kind, cfg.d_model, cfg.d_ff, dtype),
    }


def init_params(key: jax.Array, cfg: ModelConfig, *, max_positions: int,
                dtype=jnp.float32) -> Params:
    enc = cfg.encoder
    ks = jax.random.split(key, 5)
    enc_layers = [_enc_layer_init(jax.random.fold_in(ks[0], i), cfg, dtype)
                  for i in range(enc.num_layers)]
    dec_layers = [_dec_layer_init(jax.random.fold_in(ks[1], i), cfg, dtype)
                  for i in range(cfg.num_layers)]
    return {
        "embedding": embedding.init(ks[2], cfg, max_positions=max_positions,
                                    dtype=dtype),
        "frame_adapter": jax.random.normal(
            ks[3], (cfg.d_model, cfg.d_model), dtype) * cfg.d_model ** -0.5,
        "enc_pos": jax.random.normal(
            ks[4], (enc.num_positions, cfg.d_model), dtype) * 0.02,
        "encoder": jax.tree.map(lambda *xs: jnp.stack(xs), *enc_layers),
        "enc_norm": norms.init(cfg.norm_kind, cfg.d_model, dtype),
        "decoder": jax.tree.map(lambda *xs: jnp.stack(xs), *dec_layers),
        "final_norm": norms.init(cfg.norm_kind, cfg.d_model, dtype),
    }


def encode(cfg: ModelConfig, params: Params, frames: jax.Array, *,
           q_block: int = 512, kv_block: int = 512,
           remat: bool = True) -> jax.Array:
    """frames (B, Tenc, D) stub embeddings -> encoder output (B, Tenc, D)."""
    dtype = jnp.bfloat16 if cfg.compute_dtype == "bfloat16" else jnp.float32
    x = frames.astype(dtype) @ params["frame_adapter"].astype(dtype)
    x = x + params["enc_pos"].astype(dtype)

    def layer(x, p):
        xn = norms.apply(cfg.norm_kind, p["attn_norm"], x)
        x = x + attention.fwd_full(cfg, p["attn"], xn, causal=False,
                                   q_block=q_block, kv_block=kv_block)
        xn = norms.apply(cfg.norm_kind, p["mlp_norm"], x)
        x = x + mlp.apply(cfg.mlp_kind, p["mlp"], xn)
        return x, None

    body = jax.checkpoint(layer) if remat else layer
    x, _ = jax.lax.scan(body, x, params["encoder"])
    return norms.apply(cfg.norm_kind, params["enc_norm"], x)


def decode_full(cfg: ModelConfig, params: Params, tokens: jax.Array,
                enc_out: jax.Array, *, q_block: int = 512,
                kv_block: int = 1024, remat: bool = True) -> jax.Array:
    """Teacher-forced decoder pass -> hidden states (B, T, D)."""
    x = embedding.embed(cfg, params["embedding"], tokens)

    def layer(x, p):
        xn = norms.apply(cfg.norm_kind, p["self_norm"], x)
        x = x + attention.fwd_full(cfg, p["self_attn"], xn, causal=True,
                                   q_block=q_block, kv_block=kv_block)
        xn = norms.apply(cfg.norm_kind, p["cross_norm"], x)
        x = x + attention.fwd_full(cfg, p["cross_attn"], xn,
                                   kv_src=enc_out.astype(x.dtype),
                                   q_block=q_block, kv_block=kv_block)
        xn = norms.apply(cfg.norm_kind, p["mlp_norm"], x)
        x = x + mlp.apply(cfg.mlp_kind, p["mlp"], xn)
        return x, None

    body = jax.checkpoint(layer) if remat else layer
    x, _ = jax.lax.scan(body, x, params["decoder"])
    return norms.apply(cfg.norm_kind, params["final_norm"], x)


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16) -> Cache:
    enc = cfg.encoder
    l = cfg.num_layers
    kv, hd = cfg.num_kv_heads, cfg.head_dim
    self_c = attention.init_cache(cfg, batch, max_len, dtype)
    return {
        "self": jax.tree.map(
            lambda x: jnp.broadcast_to(x, (l, *x.shape)).copy(), self_c),
        "cross_k": jnp.zeros((l, batch, enc.num_positions, kv, hd), dtype),
        "cross_v": jnp.zeros((l, batch, enc.num_positions, kv, hd), dtype),
        "pos": jnp.zeros((), jnp.int32),
    }


def prefill(cfg: ModelConfig, params: Params, frames: jax.Array,
            tokens: jax.Array, *, max_len: int, q_block: int = 512,
            kv_block: int = 1024, cache_dtype=jnp.bfloat16
            ) -> tuple[jax.Array, Cache]:
    """Encode + teacher-forced decoder prefill -> (hidden, cache)."""
    enc_out = encode(cfg, params, frames)
    x = embedding.embed(cfg, params["embedding"], tokens)
    t = tokens.shape[1]
    kv, hd = cfg.num_kv_heads, cfg.head_dim
    b = tokens.shape[0]

    def layer(x, p):
        xn = norms.apply(cfg.norm_kind, p["self_norm"], x)
        h, (k_all, v_all) = attention.fwd_full(
            cfg, p["self_attn"], xn, causal=True, q_block=q_block,
            kv_block=kv_block, return_kv=True)
        x = x + h
        self_c = attention.fill_cache(cfg, k_all, v_all, max_len, cache_dtype)
        xn = norms.apply(cfg.norm_kind, p["cross_norm"], x)
        dtype = x.dtype
        ck = (enc_out.astype(dtype)
              @ p["cross_attn"]["wk"].astype(dtype)).reshape(
                  b, -1, kv, hd)
        cv = (enc_out.astype(dtype)
              @ p["cross_attn"]["wv"].astype(dtype)).reshape(
                  b, -1, kv, hd)
        x = x + attention.fwd_full(cfg, p["cross_attn"], xn,
                                   kv_src=enc_out.astype(dtype),
                                   q_block=q_block, kv_block=kv_block)
        xn = norms.apply(cfg.norm_kind, p["mlp_norm"], x)
        x = x + mlp.apply(cfg.mlp_kind, p["mlp"], xn)
        return x, (self_c, ck.astype(cache_dtype), cv.astype(cache_dtype))

    x, (self_cs, cks, cvs) = jax.lax.scan(layer, x, params["decoder"])
    x = norms.apply(cfg.norm_kind, params["final_norm"], x)
    cache = {"self": self_cs, "cross_k": cks, "cross_v": cvs,
             "pos": jnp.asarray(t, jnp.int32)}
    return x, cache


def decode_step(cfg: ModelConfig, params: Params, cache: Cache,
                x: jax.Array) -> tuple[jax.Array, Cache]:
    """One decoder token step on embedded x (B, 1, D)."""
    def layer(x, inp):
        p, self_c, ck, cv = inp
        xn = norms.apply(cfg.norm_kind, p["self_norm"], x)
        h, self_c = attention.fwd_decode(cfg, p["self_attn"], xn, self_c)
        x = x + h
        xn = norms.apply(cfg.norm_kind, p["cross_norm"], x)
        h, _ = attention.fwd_decode(cfg, p["cross_attn"], xn, self_c,
                                    cross_kv=(ck, cv))
        x = x + h
        xn = norms.apply(cfg.norm_kind, p["mlp_norm"], x)
        x = x + mlp.apply(cfg.mlp_kind, p["mlp"], xn)
        return x, self_c

    x, new_self = jax.lax.scan(
        layer, x, (params["decoder"], cache["self"],
                   cache["cross_k"], cache["cross_v"]))
    x = norms.apply(cfg.norm_kind, params["final_norm"], x)
    new_cache = dict(cache, self=new_self, pos=cache["pos"] + 1)
    return x, new_cache
