"""Decoder-only LM assembly: pattern-scanned blocks, train/prefill/decode.

Layer stacks run under ``jax.lax.scan`` over *pattern units* (the repeating
block-kind tuple from the config, e.g. Griffin's (rglru, rglru, attn)), with
parameters stacked along a leading unit axis. This keeps the HLO O(1) in
depth -- required to compile 56-layer models against 512 SPMD devices on this
container -- and is how production JAX LMs (MaxText et al.) are built anyway.
Non-conforming layers (deepseek's dense-FFN first layer, pattern tails like
recurrentgemma's 38 = 12x3 + 2) are unrolled as ``prefix`` / ``tail`` groups.

Caches are pytrees mirroring the same prefix/units/tail structure, with
scanned-unit caches stacked on the leading axis, so decode also scans.

The VLM (paligemma) path consumes precomputed patch embeddings as a
full-attention prefix (prefix-LM masking); the frontend is a stub per the
assignment.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import attention, embedding, mla, mlp, moe, norms
from repro.models.layers import rglru as rglru_mod
from repro.models.layers import xlstm

Params = Any
Cache = Any


# ---------------------------------------------------------------------------
# per-block init / apply
# ---------------------------------------------------------------------------

def _is_moe_layer(cfg: ModelConfig, layer_idx: int) -> bool:
    return (cfg.moe is not None
            and layer_idx >= cfg.moe.first_dense_layers)


def init_block(key: jax.Array, cfg: ModelConfig, kind: str,
               layer_idx: int, dtype=jnp.float32) -> dict:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p: dict = {}
    if kind == "attn":
        p["mix_norm"] = norms.init(cfg.norm_kind, cfg.d_model, dtype)
        p["mix"] = (mla.init(k1, cfg, dtype) if cfg.mla is not None
                    else attention.init(k1, cfg, dtype))
    elif kind == "rglru":
        p["mix_norm"] = norms.init(cfg.norm_kind, cfg.d_model, dtype)
        p["mix"] = rglru_mod.init(k1, cfg, dtype)
    elif kind == "mlstm":
        p["mix"] = xlstm.init_mlstm(k1, cfg, dtype)        # owns its LN
    elif kind == "slstm":
        p["mix"] = xlstm.init_slstm(k1, cfg, dtype)
    else:
        raise ValueError(f"unknown block kind {kind!r}")

    if _is_moe_layer(cfg, layer_idx):
        p["mlp_norm"] = norms.init(cfg.norm_kind, cfg.d_model, dtype)
        p["mlp"] = moe.init(k2, cfg, dtype)
    elif cfg.moe is not None and layer_idx < cfg.moe.first_dense_layers:
        p["mlp_norm"] = norms.init(cfg.norm_kind, cfg.d_model, dtype)
        p["mlp"] = mlp.init(k3, "silu_glu", cfg.d_model,
                            cfg.moe.d_ff_dense_first, dtype)
    elif cfg.d_ff > 0:
        p["mlp_norm"] = norms.init(cfg.norm_kind, cfg.d_model, dtype)
        p["mlp"] = mlp.init(k4, cfg.mlp_kind, cfg.d_model, cfg.d_ff, dtype)
    return p


def apply_block_full(cfg: ModelConfig, kind: str, params: dict, x: jax.Array,
                     *, layer_idx: int, prefix_len: int = 0,
                     q_block: int, kv_block: int) -> tuple[jax.Array, jax.Array]:
    """Full-sequence block. Returns (x, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    if kind == "attn":
        xn = norms.apply(cfg.norm_kind, params["mix_norm"], x)
        if cfg.mla is not None:
            h = mla.fwd_full(cfg, params["mix"], xn,
                             q_block=q_block, kv_block=kv_block)
        else:
            h = attention.fwd_full(cfg, params["mix"], xn,
                                   prefix_len=prefix_len,
                                   q_block=q_block, kv_block=kv_block)
        x = x + h
    elif kind == "rglru":
        xn = norms.apply(cfg.norm_kind, params["mix_norm"], x)
        x = x + rglru_mod.fwd_full(cfg, params["mix"], xn)
    elif kind == "mlstm":
        x = x + xlstm.mlstm_block(cfg, params["mix"], x)
    elif kind == "slstm":
        x = x + xlstm.slstm_block(cfg, params["mix"], x)

    if "mlp" in params:
        xn = norms.apply(cfg.norm_kind, params["mlp_norm"], x)
        if _is_moe_layer(cfg, layer_idx):
            h, aux = moe.apply(cfg, params["mlp"], xn)
        elif cfg.moe is not None:
            h = mlp.apply("silu_glu", params["mlp"], xn)
        else:
            h = mlp.apply(cfg.mlp_kind, params["mlp"], xn)
        x = x + h
    return x, aux


def init_block_cache(cfg: ModelConfig, kind: str, batch: int, max_len: int,
                     dtype=jnp.bfloat16):
    if kind == "attn":
        if cfg.mla is not None:
            return mla.init_cache(cfg, batch, max_len, dtype)
        return attention.init_cache(cfg, batch, max_len, dtype)
    if kind == "rglru":
        return rglru_mod.init_state(cfg, batch)
    if kind == "mlstm":
        return xlstm.init_mlstm_state(cfg, batch)
    if kind == "slstm":
        return xlstm.init_slstm_state(cfg, batch)
    raise ValueError(kind)


def apply_block_decode(cfg: ModelConfig, kind: str, params: dict,
                       x: jax.Array, cache, *, layer_idx: int):
    if kind == "attn":
        if cfg.mla is not None:
            decode_fn = mla.fwd_decode_absorbed if cfg.mla_absorbed \
                else mla.fwd_decode
            h, cache = decode_fn(
                cfg, params["mix"],
                norms.apply(cfg.norm_kind, params["mix_norm"], x), cache)
        else:
            h, cache = attention.fwd_decode(
                cfg, params["mix"],
                norms.apply(cfg.norm_kind, params["mix_norm"], x), cache)
        x = x + h
    elif kind == "rglru":
        h, cache = rglru_mod.fwd_decode(
            cfg, params["mix"],
            norms.apply(cfg.norm_kind, params["mix_norm"], x), cache)
        x = x + h
    elif kind == "mlstm":
        h, cache = xlstm.mlstm_block_decode(cfg, params["mix"], x, cache)
        x = x + h
    elif kind == "slstm":
        h, cache = xlstm.slstm_block_decode(cfg, params["mix"], x, cache)
        x = x + h

    if "mlp" in params:
        xn = norms.apply(cfg.norm_kind, params["mlp_norm"], x)
        if _is_moe_layer(cfg, layer_idx):
            h, _ = moe.apply(cfg, params["mlp"], xn)
        elif cfg.moe is not None:
            h = mlp.apply("silu_glu", params["mlp"], xn)
        else:
            h = mlp.apply(cfg.mlp_kind, params["mlp"], xn)
        x = x + h
    return x, cache


# ---------------------------------------------------------------------------
# stack structure: prefix (unrolled) + units (scanned) + tail (unrolled)
# ---------------------------------------------------------------------------

class StackPlan(NamedTuple):
    prefix: tuple[str, ...]          # unrolled leading layer kinds
    unit: tuple[str, ...]            # repeating pattern
    n_units: int
    tail: tuple[str, ...]            # unrolled trailing kinds


def stack_plan(cfg: ModelConfig) -> StackPlan:
    kinds = cfg.layer_kinds()
    n_prefix = cfg.moe.first_dense_layers if cfg.moe is not None else 0
    body = kinds[n_prefix:]
    unit = cfg.block_pattern
    n_units = len(body) // len(unit)
    tail = body[n_units * len(unit):]
    return StackPlan(prefix=kinds[:n_prefix], unit=unit,
                     n_units=n_units, tail=tail)


def init_params(key: jax.Array, cfg: ModelConfig, *, max_positions: int = 0,
                dtype=jnp.float32) -> Params:
    plan = stack_plan(cfg)
    n_prefix = len(plan.prefix)
    keys = jax.random.split(key, 4)
    params: dict = {
        "embedding": embedding.init(keys[0], cfg, max_positions=max_positions,
                                    dtype=dtype),
        "final_norm": norms.init(cfg.norm_kind, cfg.d_model, dtype),
    }
    params["prefix"] = [
        init_block(jax.random.fold_in(keys[1], i), cfg, kind, i, dtype)
        for i, kind in enumerate(plan.prefix)]
    # scanned units: stack identical-structure params on a leading axis
    def unit_params(u: int):
        return [init_block(jax.random.fold_in(keys[2], u * 131 + p), cfg,
                           kind, n_prefix + u * len(plan.unit) + p, dtype)
                for p, kind in enumerate(plan.unit)]
    if plan.n_units > 0:
        units = [unit_params(u) for u in range(plan.n_units)]
        params["units"] = jax.tree.map(lambda *xs: jnp.stack(xs), *units)
    else:
        params["units"] = []
    base_tail = n_prefix + plan.n_units * len(plan.unit)
    params["tail"] = [
        init_block(jax.random.fold_in(keys[3], i), cfg, kind,
                   base_tail + i, dtype)
        for i, kind in enumerate(plan.tail)]
    return params


def forward(cfg: ModelConfig, params: Params, x: jax.Array, *,
            prefix_len: int = 0, q_block: int = 512, kv_block: int = 1024,
            remat: bool = True) -> tuple[jax.Array, jax.Array]:
    """Run the block stack on embedded activations x (B, T, D).
    Returns (hidden (B,T,D), total aux loss)."""
    plan = stack_plan(cfg)
    n_prefix = len(plan.prefix)
    aux_total = jnp.zeros((), jnp.float32)

    for i, kind in enumerate(plan.prefix):
        x, aux = apply_block_full(cfg, kind, params["prefix"][i], x,
                                  layer_idx=i, prefix_len=prefix_len,
                                  q_block=q_block, kv_block=kv_block)
        aux_total += aux

    if plan.n_units > 0:
        def unit_fn(x, unit_params):
            aux_u = jnp.zeros((), jnp.float32)
            for p, kind in enumerate(plan.unit):
                # layer_idx only matters for the moe-vs-dense split, which is
                # uniform inside scanned units
                x, aux = apply_block_full(
                    cfg, kind, unit_params[p], x,
                    layer_idx=n_prefix + p, prefix_len=prefix_len,
                    q_block=q_block, kv_block=kv_block)
                aux_u += aux
            return x, aux_u

        scanned = jax.checkpoint(unit_fn) if remat else unit_fn

        def scan_body(x, unit_params):
            return scanned(x, unit_params)

        x, aux_units = jax.lax.scan(scan_body, x, params["units"])
        aux_total += jnp.sum(aux_units)

    base_tail = n_prefix + plan.n_units * len(plan.unit)
    for i, kind in enumerate(plan.tail):
        x, aux = apply_block_full(cfg, kind, params["tail"][i], x,
                                  layer_idx=base_tail + i,
                                  prefix_len=prefix_len,
                                  q_block=q_block, kv_block=kv_block)
        aux_total += aux

    x = norms.apply(cfg.norm_kind, params["final_norm"], x)
    return x, aux_total


# ---------------------------------------------------------------------------
# caches: same prefix/units/tail structure
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16) -> Cache:
    plan = stack_plan(cfg)
    cache = {
        "prefix": [init_block_cache(cfg, k, batch, max_len, dtype)
                   for k in plan.prefix],
        "tail": [init_block_cache(cfg, k, batch, max_len, dtype)
                 for k in plan.tail],
        "pos": jnp.zeros((), jnp.int32),
    }
    if plan.n_units > 0:
        unit_cache = [init_block_cache(cfg, k, batch, max_len, dtype)
                      for k in plan.unit]
        cache["units"] = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (plan.n_units, *x.shape)).copy(),
            unit_cache)
    else:
        cache["units"] = []
    return cache


def apply_block_prefill(cfg: ModelConfig, kind: str, params: dict,
                        x: jax.Array, *, layer_idx: int, max_len: int,
                        prefix_len: int = 0, q_block: int, kv_block: int,
                        cache_dtype=jnp.bfloat16):
    """Full-sequence block that also emits its decode-cache entry."""
    from repro.models.layers import attention as attn_mod
    aux = jnp.zeros((), jnp.float32)
    if kind == "attn":
        xn = norms.apply(cfg.norm_kind, params["mix_norm"], x)
        if cfg.mla is not None:
            h, (c_kv, k_rope) = mla.fwd_full(cfg, params["mix"], xn,
                                             q_block=q_block,
                                             kv_block=kv_block,
                                             return_latent=True)
            cache = mla.fill_cache(cfg, c_kv, k_rope, max_len, cache_dtype)
        else:
            h, (k_all, v_all) = attention.fwd_full(cfg, params["mix"], xn,
                                                   prefix_len=prefix_len,
                                                   q_block=q_block,
                                                   kv_block=kv_block,
                                                   return_kv=True)
            cache = attn_mod.fill_cache(cfg, k_all, v_all, max_len,
                                        cache_dtype)
        x = x + h
    elif kind == "rglru":
        xn = norms.apply(cfg.norm_kind, params["mix_norm"], x)
        h, cache = rglru_mod.fwd_full(cfg, params["mix"], xn,
                                      return_state=True)
        x = x + h
    elif kind == "mlstm":
        h, cache = xlstm.mlstm_block(cfg, params["mix"], x, return_state=True)
        x = x + h
    elif kind == "slstm":
        h, cache = xlstm.slstm_block(cfg, params["mix"], x, return_state=True)
        x = x + h
    else:
        raise ValueError(kind)

    if "mlp" in params:
        xn = norms.apply(cfg.norm_kind, params["mlp_norm"], x)
        if _is_moe_layer(cfg, layer_idx):
            h, aux = moe.apply(cfg, params["mlp"], xn)
        elif cfg.moe is not None:
            h = mlp.apply("silu_glu", params["mlp"], xn)
        else:
            h = mlp.apply(cfg.mlp_kind, params["mlp"], xn)
        x = x + h
    return x, aux, cache


def prefill(cfg: ModelConfig, params: Params, x: jax.Array, *, max_len: int,
            prefix_len: int = 0, q_block: int = 512, kv_block: int = 1024,
            cache_dtype=jnp.bfloat16) -> tuple[jax.Array, Cache]:
    """Prefill on embedded activations x (B, T, D). Returns (hidden, cache)."""
    plan = stack_plan(cfg)
    n_prefix = len(plan.prefix)
    t = x.shape[1]
    kw = dict(max_len=max_len, prefix_len=prefix_len, q_block=q_block,
              kv_block=kv_block, cache_dtype=cache_dtype)

    new_prefix = []
    for i, kind in enumerate(plan.prefix):
        x, _, c = apply_block_prefill(cfg, kind, params["prefix"][i], x,
                                      layer_idx=i, **kw)
        new_prefix.append(c)

    new_units = []
    if plan.n_units > 0:
        def scan_body(x, unit_params):
            caches = []
            for p, kind in enumerate(plan.unit):
                x, _, c = apply_block_prefill(cfg, kind, unit_params[p], x,
                                              layer_idx=n_prefix + p, **kw)
                caches.append(c)
            return x, caches

        x, new_units = jax.lax.scan(scan_body, x, params["units"])

    base_tail = n_prefix + plan.n_units * len(plan.unit)
    new_tail = []
    for i, kind in enumerate(plan.tail):
        x, _, c = apply_block_prefill(cfg, kind, params["tail"][i], x,
                                      layer_idx=base_tail + i, **kw)
        new_tail.append(c)

    x = norms.apply(cfg.norm_kind, params["final_norm"], x)
    cache = {"prefix": new_prefix, "units": new_units, "tail": new_tail,
             "pos": jnp.asarray(t, jnp.int32)}
    return x, cache


def decode_step(cfg: ModelConfig, params: Params, cache: Cache,
                x: jax.Array) -> tuple[jax.Array, Cache]:
    """One token step on embedded activations x (B, 1, D)."""
    plan = stack_plan(cfg)
    n_prefix = len(plan.prefix)
    new_prefix = []
    for i, kind in enumerate(plan.prefix):
        x, c = apply_block_decode(cfg, kind, params["prefix"][i], x,
                                  cache["prefix"][i], layer_idx=i)
        new_prefix.append(c)

    new_units = cache["units"]
    if plan.n_units > 0:
        def scan_body(x, unit):
            unit_params, unit_cache = unit
            new_caches = []
            for p, kind in enumerate(plan.unit):
                x, c = apply_block_decode(cfg, kind, unit_params[p], x,
                                          unit_cache[p],
                                          layer_idx=n_prefix + p)
                new_caches.append(c)
            return x, new_caches

        x, new_units = jax.lax.scan(
            scan_body, x, (params["units"], cache["units"]))

    base_tail = n_prefix + plan.n_units * len(plan.unit)
    new_tail = []
    for i, kind in enumerate(plan.tail):
        x, c = apply_block_decode(cfg, kind, params["tail"][i], x,
                                  cache["tail"][i], layer_idx=base_tail + i)
        new_tail.append(c)

    x = norms.apply(cfg.norm_kind, params["final_norm"], x)
    new_cache = {"prefix": new_prefix, "units": new_units, "tail": new_tail,
                 "pos": cache["pos"] + 1}
    return x, new_cache
