"""xLSTM blocks: mLSTM (matrix memory) and sLSTM (scalar memory).

mLSTM is a gated linear-attention recurrence:
    m_t = max(log_f_t + m_{t-1}, log_i_t)                  (stabilizer)
    f'  = exp(log_f_t + m_{t-1} - m_t);  i' = exp(log_i_t - m_t)
    C_t = f' C_{t-1} + i' k_t v_t^T;     n_t = f' n_{t-1} + i' k_t
    h_t = (q_t C_t) / max(|q_t . n_t|, exp(-m_t))          (q pre-scaled)

Execution paths:
  * ``mlstm_chunkwise`` -- the TPU-native form (DESIGN.md section 2 spirit):
    sequence is split into chunks; within a chunk the recurrence is evaluated
    as a masked (L x L) matmul against the MXU, between chunks a (hd x hd)
    state is carried by a lax.scan. O(T*L) memory instead of O(T^2); this is
    what makes prefill_32k feasible (a full 32k x 32k decay matrix would be
    the same petabyte blow-up as naive attention).
  * ``mlstm_recurrent`` -- step-by-step oracle (tests + decode).

sLSTM has a *non-linear* recurrent dependency (block-diagonal R h_{t-1}
inside the gates) so it is inherently sequential: lax.scan over time for
train/prefill, O(1) step for decode. This is the xLSTM paper's own stated
trade-off, not an implementation shortcut.

Block wiring (both kinds): pre-LN -> up-projection x2 -> cell with causal
conv4 + silu on the q/k path -> per-head GroupNorm -> gated by silu branch
-> down-projection. d_ff = 0 in the config: blocks own their projections.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import norms
from repro.models.sharding_hints import fsdp_use

EPS = 1e-6


# ---------------------------------------------------------------------------
# mLSTM cell
# ---------------------------------------------------------------------------

class MLSTMState(NamedTuple):
    c: jax.Array     # (B, H, hd, hd)
    n: jax.Array     # (B, H, hd)
    m: jax.Array     # (B, H)
    conv: jax.Array  # (B, W-1, D) conv history
    pos: jax.Array


def mlstm_recurrent(q, k, v, log_i, log_f, state=None):
    """Oracle: q,k,v (B,H,T,hd) (q pre-scaled by hd^-0.5), gates (B,H,T).
    Returns h (B,H,T,hd) and final (C, n, m)."""
    b, h, t, hd = q.shape
    if state is None:
        c0 = jnp.zeros((b, h, hd, hd), jnp.float32)
        n0 = jnp.zeros((b, h, hd), jnp.float32)
        m0 = jnp.full((b, h), -jnp.inf, jnp.float32)
    else:
        c0, n0, m0 = state

    def step(carry, inp):
        c, n, m = carry
        qt, kt, vt, li, lf = inp
        m_new = jnp.maximum(lf + m, li)
        fp = jnp.exp(lf + m - m_new)
        ip = jnp.exp(li - m_new)
        c = fp[..., None, None] * c \
            + ip[..., None, None] * (kt[..., :, None] * vt[..., None, :])
        n = fp[..., None] * n + ip[..., None] * kt
        num = jnp.einsum("bhd,bhde->bhe", qt, c)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", qt, n)),
                          jnp.exp(-m_new))
        return (c, n, m_new), num / den[..., None]

    xs = (q.transpose(2, 0, 1, 3), k.transpose(2, 0, 1, 3),
          v.transpose(2, 0, 1, 3), log_i.transpose(2, 0, 1),
          log_f.transpose(2, 0, 1))
    (c, n, m), hs = jax.lax.scan(step, (c0, n0, m0), xs)
    return hs.transpose(1, 2, 0, 3), (c, n, m)


def mlstm_chunkwise(q, k, v, log_i, log_f, *, chunk: int = 256, state=None):
    """Chunk-parallel mLSTM. Same contract as mlstm_recurrent."""
    b, h, t, hd = q.shape
    chunk = min(chunk, t)
    if t % chunk:
        raise ValueError(f"T={t} not divisible by chunk={chunk}")
    nc = t // chunk
    if state is None:
        c0 = jnp.zeros((b, h, hd, hd), jnp.float32)
        n0 = jnp.zeros((b, h, hd), jnp.float32)
        m0 = jnp.full((b, h), -jnp.inf, jnp.float32)
    else:
        c0, n0, m0 = state

    rs = lambda x: x.reshape(b, h, nc, chunk, *x.shape[3:]).swapaxes(0, 2) \
        .swapaxes(1, 2)  # (nc, B, H, L, ...)
    qs, ks_, vs = rs(q), rs(k), rs(v)
    lis, lfs = rs(log_i), rs(log_f)

    def chunk_step(carry, inp):
        c_prev, n_prev, m_prev = carry
        qc, kc, vc, li, lf = inp                         # (B,H,L,...)
        bcum = jnp.cumsum(lf, axis=-1)                   # (B,H,L)
        # log intra scores: li[s] + b[l] - b[s], s <= l
        logw = li[..., None, :] + bcum[..., :, None] - bcum[..., None, :]
        l_idx = jnp.arange(chunk)
        tri = l_idx[:, None] >= l_idx[None, :]           # s <= l
        logw = jnp.where(tri, logw, -jnp.inf)
        m_intra = jnp.max(logw, axis=-1)                 # (B,H,L)
        m_state = m_prev[..., None] + bcum
        m_new = jnp.maximum(m_state, m_intra)
        d = jnp.exp(logw - m_new[..., None])             # (B,H,L,L) masked
        inter = jnp.exp(m_state - m_new)                 # (B,H,L)
        s_intra = jnp.einsum("bhld,bhsd->bhls", qc, kc) * d
        num = jnp.einsum("bhls,bhse->bhle", s_intra, vc) \
            + inter[..., None] * jnp.einsum("bhld,bhde->bhle", qc, c_prev)
        nvec = jnp.einsum("bhls,bhsd->bhld", d, kc) \
            + inter[..., None] * n_prev[..., None, :]
        den = jnp.maximum(jnp.abs(jnp.einsum("bhld,bhld->bhl", qc, nvec)),
                          jnp.exp(-m_new))
        hout = num / den[..., None]
        # carry to next chunk (state at the last step of this chunk)
        m_out = m_new[..., -1]                           # (B,H)
        w_end = jnp.exp(li + bcum[..., -1:] - bcum - m_out[..., None])
        c_new = jnp.exp(m_prev + bcum[..., -1] - m_out)[..., None, None] \
            * c_prev + jnp.einsum("bhs,bhsd,bhse->bhde", w_end, kc, vc)
        n_new = jnp.exp(m_prev + bcum[..., -1] - m_out)[..., None] * n_prev \
            + jnp.einsum("bhs,bhsd->bhd", w_end, kc)
        return (c_new, n_new, m_out), hout

    (c, n, m), hs = jax.lax.scan(chunk_step, (c0, n0, m0),
                                 (qs, ks_, vs, lis, lfs))
    # hs: (nc, B, H, L, hd) -> (B, H, T, hd)
    h_out = hs.transpose(1, 2, 0, 3, 4).reshape(b, h, t, hd)
    return h_out, (c, n, m)


# ---------------------------------------------------------------------------
# mLSTM block
# ---------------------------------------------------------------------------

def init_mlstm(key: jax.Array, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    d = cfg.d_model
    h, hd = cfg.num_heads, cfg.head_dim
    ks = jax.random.split(key, 8)
    s = d ** -0.5
    return {
        "ln": norms.init("layernorm", d, dtype),
        "w_up": jax.random.normal(ks[0], (d, d), dtype) * s,
        "w_gate": jax.random.normal(ks[1], (d, d), dtype) * s,
        "conv_w": jax.random.normal(ks[2], (4, d), dtype) * 0.5,
        "conv_b": jnp.zeros((d,), dtype),
        "wq": jax.random.normal(ks[3], (d, h * hd), dtype) * s,
        "wk": jax.random.normal(ks[4], (d, h * hd), dtype) * s,
        "wv": jax.random.normal(ks[5], (d, h * hd), dtype) * s,
        "w_if": jax.random.normal(ks[6], (d, 2 * h), dtype) * s,
        "b_if": jnp.concatenate([jnp.zeros((h,), dtype),
                                 jnp.full((h,), 3.0, dtype)]),  # f-bias high
        "gn": {"scale": jnp.ones((h * hd,), dtype)},
        "w_down": jax.random.normal(ks[7], (d, d), dtype) * s,
    }


def _conv_silu(params, x, history=None):
    w = params["conv_w"].shape[0]
    b, t, d = x.shape
    if history is None:
        history = jnp.zeros((b, w - 1, d), x.dtype)
    xx = jnp.concatenate([history, x], axis=1)
    out = jnp.zeros((b, t, d), x.dtype)
    for tap in range(w):
        out = out + xx[:, tap: tap + t] * params["conv_w"][tap].astype(x.dtype)
    return jax.nn.silu(out + params["conv_b"].astype(x.dtype)), xx[:, t:]


def _mlstm_qkvg(cfg, params, xn, conv_hist=None):
    b, t, d = xn.shape
    h, hd = cfg.num_heads, cfg.head_dim
    dtype = xn.dtype
    up = xn @ fsdp_use(params["w_up"], "w_up", dtype)
    gate = xn @ fsdp_use(params["w_gate"], "w_gate", dtype)
    cx, new_hist = _conv_silu(params, up, conv_hist)
    q = (cx @ fsdp_use(params["wq"], "wq", dtype)).reshape(b, t, h, hd)
    k = (cx @ fsdp_use(params["wk"], "wk", dtype)).reshape(b, t, h, hd)
    v = (up @ fsdp_use(params["wv"], "wv", dtype)).reshape(b, t, h, hd)
    gif = (cx @ params["w_if"].astype(dtype)
           + params["b_if"].astype(dtype)).astype(jnp.float32)
    log_i = gif[..., :h]
    log_f = jax.nn.log_sigmoid(gif[..., h:])
    tb = lambda x: x.transpose(0, 2, 1, 3).astype(jnp.float32)  # (B,H,T,hd)
    return (tb(q) * hd ** -0.5, tb(k), tb(v),
            log_i.transpose(0, 2, 1), log_f.transpose(0, 2, 1),
            gate, new_hist)


def mlstm_block(cfg: ModelConfig, params: dict, x: jax.Array, *,
                chunk: int = 256, return_state: bool = False):
    """Full-sequence mLSTM block (train/prefill). Residual added by caller."""
    b, t, d = x.shape
    h, hd = cfg.num_heads, cfg.head_dim
    dtype = x.dtype
    xn = norms.apply("layernorm", params["ln"], x)
    q, k, v, li, lf, gate, hist = _mlstm_qkvg(cfg, params, xn)
    hs, (c, n, m) = mlstm_chunkwise(q, k, v, li, lf, chunk=min(chunk, t))
    hs = hs.transpose(0, 2, 1, 3).reshape(b, t, h * hd).astype(dtype)
    hs = norms.apply("rmsnorm", params["gn"], hs)          # per-channel GN
    out = (hs * jax.nn.silu(gate)) @ fsdp_use(params["w_down"], "w_down", dtype)
    if return_state:
        state = MLSTMState(c=c, n=n, m=m, conv=hist.astype(jnp.float32),
                           pos=jnp.asarray(t, jnp.int32))
        return out, state
    return out


def init_mlstm_state(cfg: ModelConfig, batch: int) -> MLSTMState:
    h, hd, d = cfg.num_heads, cfg.head_dim, cfg.d_model
    return MLSTMState(
        c=jnp.zeros((batch, h, hd, hd), jnp.float32),
        n=jnp.zeros((batch, h, hd), jnp.float32),
        m=jnp.full((batch, h), -1e30, jnp.float32),
        conv=jnp.zeros((batch, 3, d), jnp.float32),
        pos=jnp.zeros((), jnp.int32),
    )


def mlstm_block_decode(cfg: ModelConfig, params: dict, x: jax.Array,
                       state: MLSTMState) -> tuple[jax.Array, MLSTMState]:
    b, _, d = x.shape
    h, hd = cfg.num_heads, cfg.head_dim
    dtype = x.dtype
    xn = norms.apply("layernorm", params["ln"], x)
    q, k, v, li, lf, gate, hist = _mlstm_qkvg(
        cfg, params, xn, state.conv.astype(dtype))
    hs, (c, n, m) = mlstm_recurrent(q, k, v, li, lf,
                                    state=(state.c, state.n, state.m))
    hs = hs.transpose(0, 2, 1, 3).reshape(b, 1, h * hd).astype(dtype)
    hs = norms.apply("rmsnorm", params["gn"], hs)
    out = (hs * jax.nn.silu(gate)) @ fsdp_use(params["w_down"], "w_down", dtype)
    return out, MLSTMState(c=c, n=n, m=m, conv=hist.astype(state.conv.dtype),
                           pos=state.pos + 1)


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

class SLSTMState(NamedTuple):
    h: jax.Array   # (B, D)
    c: jax.Array   # (B, D)
    n: jax.Array   # (B, D)
    m: jax.Array   # (B, D)
    pos: jax.Array


def init_slstm(key: jax.Array, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    d = cfg.d_model
    h = cfg.num_heads
    hd = d // h
    ks = jax.random.split(key, 4)
    s = d ** -0.5
    return {
        "ln": norms.init("layernorm", d, dtype),
        # input weights for 4 gates (i, f, z, o)
        "w": jax.random.normal(ks[0], (d, 4 * d), dtype) * s,
        # block-diagonal recurrent weights: (H, hd, 4*hd) per head
        "r": jax.random.normal(ks[1], (h, hd, 4 * hd), dtype) * hd ** -0.5,
        "b": jnp.concatenate([jnp.zeros((d,), dtype),
                              jnp.full((d,), 3.0, dtype),     # f bias high
                              jnp.zeros((2 * d,), dtype)]),
        "gn": {"scale": jnp.ones((d,), dtype)},
        "w_down": jax.random.normal(ks[2], (d, d), dtype) * s,
        "w_gate": jax.random.normal(ks[3], (d, d), dtype) * s,
    }


def _slstm_step(cfg, params, xt, state):
    """One sLSTM step. xt: (B, 4D) pre-projected input contribution."""
    b = xt.shape[0]
    d = cfg.d_model
    h = cfg.num_heads
    hd = d // h
    hh = state.h.astype(jnp.float32).reshape(b, h, hd)
    rec = jnp.einsum("bhd,hde->bhe", hh,
                     params["r"].astype(jnp.float32)).reshape(b, 4 * d)
    g = xt.astype(jnp.float32) + rec + params["b"].astype(jnp.float32)
    gi, gf, gz, go = jnp.split(g, 4, axis=-1)
    log_i = gi
    log_f = jax.nn.log_sigmoid(gf)
    m_new = jnp.maximum(log_f + state.m, log_i)
    ip = jnp.exp(log_i - m_new)
    fp = jnp.exp(log_f + state.m - m_new)
    z = jnp.tanh(gz)
    o = jax.nn.sigmoid(go)
    c = fp * state.c + ip * z
    n = fp * state.n + ip
    h_new = o * c / jnp.maximum(n, EPS)
    return SLSTMState(h=h_new, c=c, n=n, m=m_new, pos=state.pos + 1), h_new


def init_slstm_state(cfg: ModelConfig, batch: int) -> SLSTMState:
    d = cfg.d_model
    z = jnp.zeros((batch, d), jnp.float32)
    return SLSTMState(h=z, c=z, n=z, m=jnp.full((batch, d), -1e30),
                      pos=jnp.zeros((), jnp.int32))


def slstm_block(cfg: ModelConfig, params: dict, x: jax.Array, *,
                return_state: bool = False):
    """Sequential sLSTM block over (B, T, D)."""
    b, t, d = x.shape
    dtype = x.dtype
    xn = norms.apply("layernorm", params["ln"], x)
    gate = xn @ fsdp_use(params["w_gate"], "w_gate", dtype)
    xg = xn @ fsdp_use(params["w"], "w", dtype)                    # (B, T, 4D)
    state0 = init_slstm_state(cfg, b)

    def step(st, xt):
        st, h = _slstm_step(cfg, params, xt, st)
        return st, h

    state, hs = jax.lax.scan(step, state0, xg.transpose(1, 0, 2))
    hs = hs.transpose(1, 0, 2).astype(dtype)               # (B, T, D)
    hs = norms.apply("rmsnorm", params["gn"], hs)
    out = (hs * jax.nn.silu(gate)) @ fsdp_use(params["w_down"], "w_down", dtype)
    if return_state:
        return out, state
    return out


def slstm_block_decode(cfg: ModelConfig, params: dict, x: jax.Array,
                       state: SLSTMState) -> tuple[jax.Array, SLSTMState]:
    dtype = x.dtype
    xn = norms.apply("layernorm", params["ln"], x)
    gate = xn[:, 0] @ params["w_gate"].astype(dtype)
    xg = xn[:, 0] @ params["w"].astype(dtype)
    state, h = _slstm_step(cfg, params, xg, state)
    h = norms.apply("rmsnorm", params["gn"], h.astype(dtype))
    out = (h * jax.nn.silu(gate)) @ fsdp_use(params["w_down"], "w_down", dtype)
    return out[:, None], state
