"""Multi-head Latent Attention (MiniCPM3 / DeepSeek-V2 style).

Queries go through a low-rank bottleneck (q_lora); keys/values are generated
from a shared compressed latent c_kv (kv_lora) plus one rope-carrying key
channel shared across heads. The decode cache stores ONLY (c_kv, k_rope) --
the latent compression that is MLA's point: cache bytes per token are
(kv_lora + rope_dim) instead of 2*H*hd.

Two decode variants (the absorbed one is the §Perf hillclimb for the
decode_32k x minicpm3 cell):
  * ``fwd_decode``           -- naive: re-expands K/V from the latent for all
                                cached positions each step
                                (O(S * kv_lora * H * (nope+v)) FLOPs/step).
  * ``fwd_decode_absorbed``  -- folds W_uk into the query and W_uv into the
                                output projection, attending directly in
                                latent space (O(S * (kv_lora+rope)) per head).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers.rope import apply_rope
from repro.models.layers.attention import blockwise_attention
from repro.models.layers import norms
from repro.models.sharding_hints import fsdp_use

NEG_INF = -1e30


class MLACache(NamedTuple):
    c_kv: jax.Array    # (B, S, kv_lora)        compressed latent
    k_rope: jax.Array  # (B, S, rope_dim)       shared rope key channel
    pos: jax.Array


def init(key: jax.Array, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    m = cfg.mla
    d, h = cfg.d_model, cfg.num_heads
    qk = m.qk_nope_head_dim + m.qk_rope_head_dim
    ks = jax.random.split(key, 6)
    s = d ** -0.5
    return {
        "wq_down": jax.random.normal(ks[0], (d, m.q_lora_rank), dtype) * s,
        "q_norm": {"scale": jnp.ones((m.q_lora_rank,), dtype)},
        "wq_up": jax.random.normal(
            ks[1], (m.q_lora_rank, h * qk), dtype) * m.q_lora_rank ** -0.5,
        "wkv_down": jax.random.normal(
            ks[2], (d, m.kv_lora_rank + m.qk_rope_head_dim), dtype) * s,
        "kv_norm": {"scale": jnp.ones((m.kv_lora_rank,), dtype)},
        "wkv_up": jax.random.normal(
            ks[3], (m.kv_lora_rank, h * (m.qk_nope_head_dim + m.v_head_dim)),
            dtype) * m.kv_lora_rank ** -0.5,
        "wo": jax.random.normal(
            ks[4], (h * m.v_head_dim, d), dtype) * (h * m.v_head_dim) ** -0.5,
    }


def _project_q(cfg: ModelConfig, params: dict, x: jax.Array,
               positions: jax.Array):
    """-> q_nope (B,T,H,nope), q_rope (B,T,H,rope) with rope applied."""
    m = cfg.mla
    h = cfg.num_heads
    b, t, _ = x.shape
    dtype = x.dtype
    ql = x @ fsdp_use(params["wq_down"], "wq_down", dtype)
    ql = norms.apply("rmsnorm", params["q_norm"], ql)
    q = (ql @ fsdp_use(params["wq_up"], "wq_up", dtype)).reshape(
        b, t, h, m.qk_nope_head_dim + m.qk_rope_head_dim)
    q_nope = q[..., :m.qk_nope_head_dim]
    q_rope = apply_rope(q[..., m.qk_nope_head_dim:], positions,
                        theta=cfg.rope_theta)
    return q_nope, q_rope


def _project_kv_latent(cfg: ModelConfig, params: dict, x: jax.Array,
                       positions: jax.Array):
    """-> c_kv (B,T,kv_lora) normalized, k_rope (B,T,rope) with rope."""
    m = cfg.mla
    dtype = x.dtype
    kvd = x @ fsdp_use(params["wkv_down"], "wkv_down", dtype)
    c_kv = norms.apply("rmsnorm", params["kv_norm"],
                       kvd[..., :m.kv_lora_rank])
    k_rope = apply_rope(kvd[..., m.kv_lora_rank:][:, :, None, :],
                        positions, theta=cfg.rope_theta)[:, :, 0]
    return c_kv, k_rope


def _expand_kv(cfg: ModelConfig, params: dict, c_kv: jax.Array):
    """latent -> k_nope (B,S,H,nope), v (B,S,H,v)."""
    m = cfg.mla
    h = cfg.num_heads
    b, s, _ = c_kv.shape
    kv = (c_kv @ fsdp_use(params["wkv_up"], "wkv_up", c_kv.dtype)).reshape(
        b, s, h, m.qk_nope_head_dim + m.v_head_dim)
    return kv[..., :m.qk_nope_head_dim], kv[..., m.qk_nope_head_dim:]


def fwd_full(cfg: ModelConfig, params: dict, x: jax.Array, *,
             positions=None, q_block: int = 512,
             kv_block: int = 1024, return_latent: bool = False):
    """Train / prefill MLA, blockwise. Returns (B, T, D) (+ latents)."""
    m = cfg.mla
    b, t, _ = x.shape
    h = cfg.num_heads
    dtype = x.dtype
    pos = positions if positions is not None else jnp.arange(t)
    q_nope, q_rope = _project_q(cfg, params, x, pos)
    c_kv, k_rope = _project_kv_latent(cfg, params, x, pos)
    k_nope, v = _expand_kv(cfg, params, c_kv)
    # assemble full-rank q/k with the shared rope channel appended
    q = jnp.concatenate([q_nope, q_rope], axis=-1)         # (B,T,H,qk)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                  (*k_rope.shape[:2], h, m.qk_rope_head_dim))],
        axis=-1)
    # v padded to qk width so the shared blockwise kernel applies; sliced back
    qk = m.qk_nope_head_dim + m.qk_rope_head_dim
    v_pad = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, qk - m.v_head_dim)))
    out = blockwise_attention(q[:, :, :, None, :], k, v_pad,
                              causal=True, q_block=q_block,
                              kv_block=kv_block)
    out = out[:, :, :, 0, : m.v_head_dim].reshape(b, t, h * m.v_head_dim)
    out = out @ fsdp_use(params["wo"], "wo", dtype)
    if return_latent:
        return out, (c_kv, k_rope)
    return out


def fill_cache(cfg: ModelConfig, c_kv: jax.Array, k_rope: jax.Array,
               max_len: int, dtype=jnp.bfloat16) -> MLACache:
    b, t, _ = c_kv.shape
    cache = init_cache(cfg, b, max_len, dtype)
    return MLACache(
        c_kv=cache.c_kv.at[:, :t].set(c_kv.astype(dtype)),
        k_rope=cache.k_rope.at[:, :t].set(k_rope.astype(dtype)),
        pos=jnp.asarray(t, jnp.int32))


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16) -> MLACache:
    m = cfg.mla
    return MLACache(
        c_kv=jnp.zeros((batch, max_len, m.kv_lora_rank), dtype),
        k_rope=jnp.zeros((batch, max_len, m.qk_rope_head_dim), dtype),
        pos=jnp.zeros((), jnp.int32),
    )


def _decode_common(cfg, params, x, cache):
    b, _, _ = x.shape
    pos = cache.pos
    p_now = jnp.full((1,), pos, jnp.int32)
    q_nope, q_rope = _project_q(cfg, params, x, p_now)
    c_new, kr_new = _project_kv_latent(cfg, params, x, p_now)
    c_kv = jax.lax.dynamic_update_slice_in_dim(
        cache.c_kv, c_new.astype(cache.c_kv.dtype), pos, axis=1)
    k_rope = jax.lax.dynamic_update_slice_in_dim(
        cache.k_rope, kr_new.astype(cache.k_rope.dtype), pos, axis=1)
    new_cache = MLACache(c_kv=c_kv, k_rope=k_rope, pos=pos + 1)
    s_mask = jnp.arange(c_kv.shape[1]) <= pos
    return q_nope[:, 0], q_rope[:, 0], new_cache, s_mask


def fwd_decode(cfg: ModelConfig, params: dict, x: jax.Array,
               cache: MLACache) -> tuple[jax.Array, MLACache]:
    """Naive decode: expand K/V from latent for every cached position."""
    m = cfg.mla
    h = cfg.num_heads
    b = x.shape[0]
    dtype = x.dtype
    qn, qr, cache, s_mask = _decode_common(cfg, params, x, cache)
    k_nope, v = _expand_kv(cfg, params, cache.c_kv.astype(dtype))
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    s = (jnp.einsum("bhe,bshe->bhs", qn.astype(jnp.float32),
                    k_nope.astype(jnp.float32))
         + jnp.einsum("bhr,bsr->bhs", qr.astype(jnp.float32),
                      cache.k_rope.astype(jnp.float32))) * scale
    s = jnp.where(s_mask[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhs,bshv->bhv", p, v.astype(jnp.float32))
    out = o.reshape(b, 1, h * m.v_head_dim).astype(dtype)
    return out @ params["wo"].astype(dtype), cache


def fwd_decode_absorbed(cfg: ModelConfig, params: dict, x: jax.Array,
                        cache: MLACache) -> tuple[jax.Array, MLACache]:
    """Absorbed decode: attend in latent space; W_uk folds into q, W_uv into
    the output head. FLOPs per step drop from O(S*r*H*(nope+v)) to
    O(S*H*(r+rope))."""
    m = cfg.mla
    h = cfg.num_heads
    b = x.shape[0]
    dtype = x.dtype
    qn, qr, cache, s_mask = _decode_common(cfg, params, x, cache)
    wkv_up = params["wkv_up"].astype(jnp.float32).reshape(
        m.kv_lora_rank, h, m.qk_nope_head_dim + m.v_head_dim)
    w_uk = wkv_up[..., :m.qk_nope_head_dim]                # (r, H, nope)
    w_uv = wkv_up[..., m.qk_nope_head_dim:]                # (r, H, v)
    # fold: q_lat[b,h,r] = sum_e q_nope[b,h,e] * w_uk[r,h,e]
    q_lat = jnp.einsum("bhe,rhe->bhr", qn.astype(jnp.float32), w_uk)
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    c = cache.c_kv.astype(jnp.float32)
    s = (jnp.einsum("bhr,bsr->bhs", q_lat, c)
         + jnp.einsum("bhr,bsr->bhs", qr.astype(jnp.float32),
                      cache.k_rope.astype(jnp.float32))) * scale
    s = jnp.where(s_mask[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o_lat = jnp.einsum("bhs,bsr->bhr", p, c)               # latent output
    o = jnp.einsum("bhr,rhv->bhv", o_lat, w_uv)            # absorbed W_uv
    out = o.reshape(b, 1, h * m.v_head_dim).astype(dtype)
    return out @ params["wo"].astype(dtype), cache
