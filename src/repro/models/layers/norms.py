"""Normalization layers: RMSNorm, LayerNorm, and OLMo's non-parametric LN.

All norms compute in f32 regardless of activation dtype (standard practice)
and cast back to the input dtype.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def init(kind: str, d: int, dtype=jnp.float32) -> dict:
    if kind == "rmsnorm":
        return {"scale": jnp.ones((d,), dtype)}
    if kind == "layernorm":
        return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}
    if kind == "nonparam_ln":
        return {}  # OLMo: no learnable parameters
    raise ValueError(f"unknown norm kind {kind!r}")


def apply(kind: str, params: dict, x: jax.Array, *, eps: float = 1e-6
          ) -> jax.Array:
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
        y = y * params["scale"].astype(jnp.float32)
    elif kind in ("layernorm", "nonparam_ln"):
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        if kind == "layernorm":
            y = y * params["scale"].astype(jnp.float32) \
                + params["bias"].astype(jnp.float32)
    else:
        raise ValueError(f"unknown norm kind {kind!r}")
    return y.astype(dtype)
