"""Token embeddings / logits head (vocab-shardable), learned positions."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.sharding_hints import (fsdp_use, hint_activations,
                                         hint_logits)


def init(key: jax.Array, cfg: ModelConfig, *, max_positions: int = 0,
         dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 3)
    p = {"embed": jax.random.normal(
        ks[0], (cfg.vocab_size, cfg.d_model), dtype) * cfg.d_model ** -0.5}
    if not cfg.tie_embeddings:
        p["unembed"] = jax.random.normal(
            ks[1], (cfg.d_model, cfg.vocab_size), dtype) * cfg.d_model ** -0.5
    if cfg.learned_pos and max_positions:
        p["pos"] = jax.random.normal(
            ks[2], (max_positions, cfg.d_model), dtype) * 0.02
    return p


def embed(cfg: ModelConfig, params: dict, tokens: jax.Array,
          *, positions: jax.Array | None = None,
          dtype=jnp.bfloat16) -> jax.Array:
    x = hint_activations(params["embed"][tokens].astype(dtype))
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, dtype)
    if cfg.learned_pos and "pos" in params:
        pos = positions if positions is not None \
            else jnp.arange(tokens.shape[-1])
        x = x + params["pos"][pos].astype(dtype)
    return x


def logits(cfg: ModelConfig, params: dict, x: jax.Array) -> jax.Array:
    if cfg.tie_embeddings:
        out = x @ fsdp_use(params["embed"], "embed", x.dtype).T
    else:
        out = x @ fsdp_use(params["unembed"], "unembed", x.dtype)
    out = hint_logits(out)
    if cfg.logit_softcap > 0:
        cap = cfg.logit_softcap
        out = cap * jnp.tanh(out.astype(jnp.float32) / cap)
    return out
