"""Griffin recurrent block: conv1d + RG-LRU (recurrentgemma).

RG-LRU recurrence (per channel):
    r_t = sigmoid(W_a x_t + b_a)            recurrence gate
    i_t = sigmoid(W_x x_t + b_x)            input gate
    a_t = exp(-c * softplus(L) * r_t)       (L learnable; c = 8)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Train/prefill uses ``jax.lax.associative_scan`` over the (a, b) linear
recurrence -- O(log T) depth, TPU-friendly. Decode carries (h, conv_state)
with O(1) work per token, which is what makes long_500k run for this family.

Block structure (Griffin): two branches from x --
  gate branch: gelu(W_gate x); rnn branch: W_in x -> causal depthwise conv1d
  (width 4) -> RG-LRU -> multiply by gate -> W_out.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.sharding_hints import fsdp_use

_C = 8.0


class RGLRUState(NamedTuple):
    h: jax.Array         # (B, D) recurrent state
    conv: jax.Array      # (B, W-1, D) trailing inputs for the causal conv
    pos: jax.Array


def init(key: jax.Array, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    d = cfg.d_model
    w = cfg.rglru_conv_width
    ks = jax.random.split(key, 6)
    s = d ** -0.5
    return {
        "w_gate": jax.random.normal(ks[0], (d, d), dtype) * s,
        "w_in": jax.random.normal(ks[1], (d, d), dtype) * s,
        "conv_w": jax.random.normal(ks[2], (w, d), dtype) * w ** -0.5,
        "conv_b": jnp.zeros((d,), dtype),
        "w_a": jax.random.normal(ks[3], (d, d), dtype) * s,
        "b_a": jnp.zeros((d,), dtype),
        "w_x": jax.random.normal(ks[4], (d, d), dtype) * s,
        "b_x": jnp.zeros((d,), dtype),
        # softplus(lambda) init so a ~ 0.9..0.999 (Griffin's init range)
        "lam": jnp.full((d,), 0.7, dtype),
        "w_out": jax.random.normal(ks[5], (d, d), dtype) * s,
    }


def _rglru_coeffs(params: dict, u: jax.Array):
    """u: (..., D) conv output -> (a, b) of h_t = a*h_{t-1} + b. f32."""
    uf = u.astype(jnp.float32)
    r = jax.nn.sigmoid(uf @ fsdp_use(params["w_a"], "w_a", jnp.float32)
                       + params["b_a"].astype(jnp.float32))
    i = jax.nn.sigmoid(uf @ fsdp_use(params["w_x"], "w_x", jnp.float32)
                       + params["b_x"].astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(params["lam"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i * uf)
    return a, b


def _causal_conv(params: dict, x: jax.Array, history: jax.Array | None = None):
    """Depthwise causal conv, width W. x (B,T,D); history (B,W-1,D) or zeros."""
    w = params["conv_w"].shape[0]
    b, t, d = x.shape
    if history is None:
        history = jnp.zeros((b, w - 1, d), x.dtype)
    xx = jnp.concatenate([history, x], axis=1)              # (B, T+W-1, D)
    out = jnp.zeros((b, t, d), x.dtype)
    for tap in range(w):                                    # width is tiny (4)
        out = out + xx[:, tap: tap + t] * params["conv_w"][tap].astype(x.dtype)
    return out + params["conv_b"].astype(x.dtype)


def fwd_full(cfg: ModelConfig, params: dict, x: jax.Array,
             h0: jax.Array | None = None, *, return_state: bool = False):
    """Train/prefill. x (B,T,D) -> (B,T,D) via associative scan."""
    b, t, d = x.shape
    dtype = x.dtype
    gate = jax.nn.gelu(x @ fsdp_use(params["w_gate"], "w_gate", dtype),
                       approximate=True)
    xin = x @ fsdp_use(params["w_in"], "w_in", dtype)
    u = _causal_conv(params, xin)
    a, bb = _rglru_coeffs(params, u)                        # (B,T,D) f32
    if h0 is not None:
        bb = bb.at[:, 0].add(a[:, 0] * h0.astype(jnp.float32))

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, bb), axis=1)
    y = (h.astype(dtype) * gate) @ fsdp_use(params["w_out"], "w_out", dtype)
    if return_state:
        w = params["conv_w"].shape[0]
        state = RGLRUState(h=h[:, -1], conv=xin[:, t - (w - 1):]
                           .astype(jnp.float32),
                           pos=jnp.asarray(t, jnp.int32))
        return y, state
    return y


def init_state(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> RGLRUState:
    d = cfg.d_model
    w = cfg.rglru_conv_width
    return RGLRUState(h=jnp.zeros((batch, d), dtype),
                      conv=jnp.zeros((batch, w - 1, d), dtype),
                      pos=jnp.zeros((), jnp.int32))


def fwd_decode(cfg: ModelConfig, params: dict, x: jax.Array,
               state: RGLRUState) -> tuple[jax.Array, RGLRUState]:
    """One step. x (B,1,D). O(1) per token."""
    b, _, d = x.shape
    dtype = x.dtype
    gate = jax.nn.gelu(x[:, 0] @ params["w_gate"].astype(dtype),
                       approximate=True)
    xin = x[:, 0] @ params["w_in"].astype(dtype)            # (B, D)
    # conv over (history ++ xin)
    w = params["conv_w"].shape[0]
    xx = jnp.concatenate([state.conv, xin[:, None]], axis=1)  # (B, W, D)
    u = jnp.einsum("bwd,wd->bd", xx.astype(jnp.float32),
                   params["conv_w"].astype(jnp.float32)) \
        + params["conv_b"].astype(jnp.float32)
    a, bb = _rglru_coeffs(params, u[:, None])
    h = a[:, 0] * state.h.astype(jnp.float32) + bb[:, 0]
    y = (h.astype(dtype) * gate) @ params["w_out"].astype(dtype)
    new_state = RGLRUState(h=h.astype(state.h.dtype),
                           conv=xx[:, 1:].astype(state.conv.dtype),
                           pos=state.pos + 1)
    return y[:, None], new_state
