"""Rotary position embeddings (RoPE), decode-aware (absolute positions)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _freqs(head_dim: int, theta: float, dtype=jnp.float32) -> jax.Array:
    exponent = jnp.arange(0, head_dim, 2, dtype=dtype) / head_dim
    return 1.0 / (theta ** exponent)                    # (head_dim/2,)


def apply_rope(x: jax.Array, positions: jax.Array, *,
               theta: float = 10000.0) -> jax.Array:
    """x: (..., seq, heads, head_dim); positions: broadcastable to (..., seq).

    Rotates pairs (x[2i], x[2i+1]) by positions * freq_i. Computed in f32.
    """
    dtype = x.dtype
    head_dim = x.shape[-1]
    freqs = _freqs(head_dim, theta)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(angles)[..., None, :]                 # broadcast over heads
    sin = jnp.sin(angles)[..., None, :]
    x1 = x[..., 0::2].astype(jnp.float32)
    x2 = x[..., 1::2].astype(jnp.float32)
    r1 = x1 * cos - x2 * sin
    r2 = x2 * cos + x1 * sin
    out = jnp.stack([r1, r2], axis=-1).reshape(x.shape)
    return out.astype(dtype)
