"""Attention: GQA/MQA, sliding-window / local, prefix-LM, cross-attention.

Two execution paths:

* ``fwd_full`` (train / prefill): **blockwise online-softmax attention**
  (flash-style, pure JAX). Scores never materialize beyond one
  (q_block x kv_block) tile -- mandatory for the 32k-prefill shapes, where a
  full (B, H, T, T) score tensor would be petabytes. The inner loop is a
  *banded* scan: for query block i, only kv blocks in the causal band
  [i - band + 1, i] are visited, so windowed attention (mixtral SWA 4096,
  recurrentgemma local 2048) does near-minimal work with static trip counts.
  For full causal attention the band covers the whole prefix (the rectangular
  iteration space costs ~2x the triangle -- a known, measured inefficiency;
  see EXPERIMENTS.md §Perf for the hillclimb).

* ``fwd_decode`` (serving): one query token against a KV cache.
  Windowed layers use a **ring-buffer cache** of exactly ``window`` slots --
  this is what makes long_500k feasible for mixtral (4096-slot cache instead
  of 500k). RoPE is applied at absolute positions before caching, so the ring
  wraparound is transparent.

GQA folds the group axis into queries: q (B,T,KV,G,hd) against k (B,S,KV,hd).
Softmax is computed in f32.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers.rope import apply_rope
from repro.models.sharding_hints import fsdp_use

NEG_INF = -1e30


class KVCache(NamedTuple):
    k: jax.Array    # (B, buf_len, KV, hd) -- buf_len = window (ring) or max
    v: jax.Array
    pos: jax.Array  # scalar int32: number of tokens already written


def init(key: jax.Array, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    d = cfg.d_model
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s = d ** -0.5
    return {
        "wq": jax.random.normal(k1, (d, h * hd), dtype) * s,
        "wk": jax.random.normal(k2, (d, kv * hd), dtype) * s,
        "wv": jax.random.normal(k3, (d, kv * hd), dtype) * s,
        "wo": jax.random.normal(k4, (h * hd, d), dtype) * (h * hd) ** -0.5,
    }


# ---------------------------------------------------------------------------
# Blockwise (flash-style) attention for train / prefill
# ---------------------------------------------------------------------------

def _fit_block(t: int, want: int) -> int:
    """Largest divisor of t that is <= want (handles e.g. whisper's 1500
    encoder frames against the default 512 block)."""
    b = min(want, t)
    while t % b:
        b -= 1
    return b


def _block_mask(q_idx: jax.Array, k_idx: jax.Array, *, causal: bool,
                window: int, prefix_len: int) -> jax.Array:
    """Elementwise visibility for absolute indices q_idx (Tq,1), k_idx (1,Tk)."""
    if not causal:
        return jnp.ones((q_idx.shape[0], k_idx.shape[1]), bool)
    m = k_idx <= q_idx
    if window > 0:
        m &= k_idx > (q_idx - window)
    if prefix_len > 0:
        # prefix-LM: inside the prefix everything sees everything
        m |= (k_idx < prefix_len) & (q_idx < prefix_len)
    return m


def blockwise_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool, window: int = 0, prefix_len: int = 0,
                        q_block: int = 512, kv_block: int = 1024,
                        q_offset: int = 0) -> jax.Array:
    """q (B,Tq,KV,G,hd), k/v (B,Tk,KV,hd) -> (B,Tq,KV,G,hd). f32 softmax.

    ``q_offset``: absolute position of q[,0] (prefill continuation support).
    """
    b, tq, kvh, g, hd = q.shape
    tk = k.shape[1]
    q_block = _fit_block(tq, q_block)
    kv_block = _fit_block(tk, kv_block)
    if prefix_len > kv_block:
        raise ValueError("prefix_len must fit within one kv block")
    n_q, n_k = tq // q_block, tk // kv_block
    scale = hd ** -0.5

    if causal:
        # banded kv visit: blocks [i_k - band + 1, i_k] in kv-block units,
        # where i_k is the kv block containing this q block's diagonal.
        if window > 0:
            # worst-case kv-block span of [q_lo - window + 1, q_hi]: the key
            # span has length q_block + window - 1 and may straddle an extra
            # block boundary on each side
            band = (window + q_block) // kv_block + 2
        else:
            band = n_k
        band = min(band, n_k)
    else:
        band = n_k

    qf = (q.astype(jnp.float32) * scale).reshape(b, n_q, q_block, kvh, g, hd)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)

    def q_step(_, qi):
        q_blk = qf[:, qi]                                   # (B,qb,KV,G,hd)
        q_abs = q_offset + qi * q_block + jnp.arange(q_block)
        diag_k = (q_offset + (qi + 1) * q_block - 1) // kv_block

        def kv_step(carry, o):
            m_run, l_run, acc = carry
            if causal:
                kj = jnp.maximum(diag_k - band + 1 + o, 0)  # clamped band
                in_band = (diag_k - band + 1 + o) >= 0
            else:
                kj = o                                      # visit every block
                in_band = jnp.bool_(True)
            k_blk = jax.lax.dynamic_slice_in_dim(kf, kj * kv_block,
                                                 kv_block, axis=1)
            v_blk = jax.lax.dynamic_slice_in_dim(vf, kj * kv_block,
                                                 kv_block, axis=1)
            k_abs = kj * kv_block + jnp.arange(kv_block)
            mask = _block_mask(q_abs[:, None], k_abs[None, :],
                               causal=causal, window=window,
                               prefix_len=prefix_len)
            mask &= in_band
            s = jnp.einsum("bqkgh,bskh->bkgqs", q_blk, k_blk)
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m_run, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m_run - m_new)
            l_new = l_run * corr + jnp.sum(p, axis=-1)
            acc = acc * corr[..., None] \
                + jnp.einsum("bkgqs,bskh->bkgqh", p, v_blk)
            return (m_new, l_new, acc), None

        m0 = jnp.full((b, kvh, g, q_block), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, kvh, g, q_block), jnp.float32)
        a0 = jnp.zeros((b, kvh, g, q_block, hd), jnp.float32)
        (m_f, l_f, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0), jnp.arange(band))
        out = acc / jnp.maximum(l_f, 1e-30)[..., None]      # (B,KV,G,qb,hd)
        return _, out.transpose(0, 3, 1, 2, 4)              # (B,qb,KV,G,hd)

    _, blocks = jax.lax.scan(q_step, None, jnp.arange(n_q))
    # blocks: (n_q, B, qb, KV, G, hd)
    out = blocks.transpose(1, 0, 2, 3, 4, 5).reshape(b, tq, kvh, g, hd)
    return out.astype(q.dtype)


def fwd_full(cfg: ModelConfig, params: dict, x: jax.Array, *,
             causal: bool = True, prefix_len: int = 0,
             kv_src: Optional[jax.Array] = None,
             positions: Optional[jax.Array] = None,
             q_block: int = 512, kv_block: int = 1024,
             return_kv: bool = False):
    """Full-sequence attention (train / prefill). kv_src enables cross-attn.
    With return_kv, also returns the post-rope (k, v) for cache filling."""
    b, t, d = x.shape
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    g = h // kv
    dtype = x.dtype
    src = x if kv_src is None else kv_src
    tk = src.shape[1]
    q = (x @ fsdp_use(params["wq"], "wq", dtype)).reshape(b, t, h, hd)
    k = (src @ fsdp_use(params["wk"], "wk", dtype)).reshape(b, tk, kv, hd)
    v = (src @ fsdp_use(params["wv"], "wv", dtype)).reshape(b, tk, kv, hd)
    if cfg.use_rope and kv_src is None:
        pos = positions if positions is not None else jnp.arange(t)
        q = apply_rope(q, pos, theta=cfg.rope_theta)
        k = apply_rope(k, pos, theta=cfg.rope_theta)
    q = q.reshape(b, t, kv, g, hd)
    window = cfg.window if cfg.attn_kind in ("swa", "local") else 0
    out = blockwise_attention(q, k, v, causal=causal and kv_src is None,
                              window=window, prefix_len=prefix_len,
                              q_block=q_block, kv_block=kv_block)
    out = out.reshape(b, t, h * hd)
    out = out @ fsdp_use(params["wo"], "wo", dtype)
    if return_kv:
        return out, (k, v)
    return out


def fill_cache(cfg: ModelConfig, k_all: jax.Array, v_all: jax.Array,
               max_len: int, dtype=jnp.bfloat16) -> KVCache:
    """Build a decode cache from prefill K/V (ring layout for windowed)."""
    b, t, kv, hd = k_all.shape
    buf = cache_len(cfg, max_len)
    lastn = min(buf, t)
    slots = jnp.arange(t - lastn, t) % buf
    k_buf = jnp.zeros((b, buf, kv, hd), dtype).at[:, slots].set(
        k_all[:, t - lastn:].astype(dtype))
    v_buf = jnp.zeros((b, buf, kv, hd), dtype).at[:, slots].set(
        v_all[:, t - lastn:].astype(dtype))
    return KVCache(k=k_buf, v=v_buf, pos=jnp.asarray(t, jnp.int32))


# ---------------------------------------------------------------------------
# Decode path (single token, KV cache; ring buffer for windowed layers)
# ---------------------------------------------------------------------------

def cache_len(cfg: ModelConfig, max_len: int) -> int:
    if cfg.attn_kind in ("swa", "local") and cfg.window > 0:
        return min(cfg.window, max_len)
    return max_len


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16) -> KVCache:
    buf = cache_len(cfg, max_len)
    kv, hd = cfg.num_kv_heads, cfg.head_dim
    return KVCache(
        k=jnp.zeros((batch, buf, kv, hd), dtype),
        v=jnp.zeros((batch, buf, kv, hd), dtype),
        pos=jnp.zeros((), jnp.int32),
    )


def fwd_decode(cfg: ModelConfig, params: dict, x: jax.Array,
               cache: KVCache, *,
               cross_kv: Optional[tuple[jax.Array, jax.Array]] = None
               ) -> tuple[jax.Array, KVCache]:
    """One decode step. x: (B, 1, D). Returns (out (B,1,D), new cache).

    cross_kv: precomputed (k, v) from the encoder (whisper decode) -- no
    cache update, bidirectional over the encoder length.
    """
    b, _, d = x.shape
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    g = h // kv
    dtype = x.dtype
    q = (x @ params["wq"].astype(dtype)).reshape(b, 1, h, hd)

    if cross_kv is not None:
        k_all, v_all = cross_kv
        qg = q.reshape(b, kv, g, hd).astype(jnp.float32) * hd ** -0.5
        s = jnp.einsum("bkgh,bskh->bkgs", qg, k_all.astype(jnp.float32))
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bkgs,bskh->bkgh", p, v_all.astype(jnp.float32))
        out = o.reshape(b, 1, h * hd).astype(dtype)
        return out @ params["wo"].astype(dtype), cache

    pos = cache.pos                                        # tokens so far
    k_new = (x @ params["wk"].astype(dtype)).reshape(b, 1, kv, hd)
    v_new = (x @ params["wv"].astype(dtype)).reshape(b, 1, kv, hd)
    if cfg.use_rope:
        p_now = jnp.full((1,), pos, jnp.int32)
        q = apply_rope(q, p_now, theta=cfg.rope_theta)
        k_new = apply_rope(k_new, p_now, theta=cfg.rope_theta)

    buf = cache.k.shape[1]
    slot = jnp.mod(pos, buf)                               # ring slot
    k_buf = jax.lax.dynamic_update_slice_in_dim(
        cache.k, k_new.astype(cache.k.dtype), slot, axis=1)
    v_buf = jax.lax.dynamic_update_slice_in_dim(
        cache.v, v_new.astype(cache.v.dtype), slot, axis=1)

    # absolute position held by each slot after this write
    s_idx = jnp.arange(buf)
    abs_pos = pos - jnp.mod(pos - s_idx, buf)              # <= pos
    valid = abs_pos >= 0

    qg = q.reshape(b, kv, g, hd).astype(jnp.float32) * hd ** -0.5
    s = jnp.einsum("bkgh,bskh->bkgs", qg, k_buf.astype(jnp.float32))
    s = jnp.where(valid[None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bskh->bkgh", p, v_buf.astype(jnp.float32))
    out = o.reshape(b, 1, h * hd).astype(dtype)
    out = out @ params["wo"].astype(dtype)
    return out, KVCache(k=k_buf, v=v_buf, pos=pos + 1)
