"""Feed-forward blocks: SiLU-GLU (llama/olmo/deepseek), GeGLU (gemma),
non-gated GELU (starcoder2/whisper)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from repro.models.sharding_hints import fsdp_use


def init(key: jax.Array, kind: str, d: int, d_ff: int,
         dtype=jnp.float32) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    scale_in = d ** -0.5
    scale_out = d_ff ** -0.5
    if kind in ("silu_glu", "geglu"):
        return {
            "wi_gate": jax.random.normal(k1, (d, d_ff), dtype) * scale_in,
            "wi_up": jax.random.normal(k2, (d, d_ff), dtype) * scale_in,
            "wo": jax.random.normal(k3, (d_ff, d), dtype) * scale_out,
        }
    if kind == "gelu":
        return {
            "wi": jax.random.normal(k1, (d, d_ff), dtype) * scale_in,
            "bi": jnp.zeros((d_ff,), dtype),
            "wo": jax.random.normal(k2, (d_ff, d), dtype) * scale_out,
            "bo": jnp.zeros((d,), dtype),
        }
    raise ValueError(f"unknown mlp kind {kind!r}")


def apply(kind: str, params: dict, x: jax.Array) -> jax.Array:
    dtype = x.dtype
    if kind in ("silu_glu", "geglu"):
        gate = x @ fsdp_use(params["wi_gate"], "wi_gate", dtype)
        up = x @ fsdp_use(params["wi_up"], "wi_up", dtype)
        act = jax.nn.silu(gate) if kind == "silu_glu" \
            else jax.nn.gelu(gate, approximate=True)
        return (act * up) @ fsdp_use(params["wo"], "wo", dtype)
    if kind == "gelu":
        h = jax.nn.gelu(x @ fsdp_use(params["wi"], "wi", dtype)
                        + params["bi"].astype(dtype), approximate=True)
        return h @ fsdp_use(params["wo"], "wo", dtype) \
            + params["bo"].astype(dtype)
    raise ValueError(f"unknown mlp kind {kind!r}")
