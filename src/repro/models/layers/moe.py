"""Mixture-of-Experts with sort-based dispatch + optional Sinkhorn router.

Dispatch is **sort-based** (argsort tokens by expert, gather into (E, C, D)
groups, batched expert matmul, scatter-add back) rather than the GShard
one-hot-einsum form: the einsum dispatch costs O(T^2 * k * cf * D) FLOPs of
pure bookkeeping, which would swamp the useful-FLOPs ratio in the roofline
tables; gathers/scatters cost bytes, not FLOPs. Tokens beyond per-expert
capacity C = ceil(T * top_k * cf / E) are dropped (standard).

Routers:
  * ``topk``     -- softmax gate, faithful to mixtral/deepseek.
  * ``sinkhorn`` -- the paper's technique as a first-class framework feature:
    token->expert assignment is an entropy-regularized OT problem (uniform
    expert marginal = perfect balance), solved with the same Sinkhorn-Knopp
    core (`repro.core.ot`) the WMD engine uses. The transport plan replaces
    the softmax probabilities before top-k. See DESIGN.md section 5.

The load-balance auxiliary loss (switch-style) is returned for the topk
router; the sinkhorn router is balanced by construction (marginal constraint)
so its aux loss is ~0 by design -- asserted in tests.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, MoEConfig
from repro.core.ot import sinkhorn_plan
from repro.models.layers import mlp
from repro.models.sharding_hints import fsdp_use


def init(key: jax.Array, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    e = cfg.moe
    d = cfg.d_model
    k_r, k_e, k_s = jax.random.split(key, 3)
    s_in, s_out = d ** -0.5, e.d_ff_expert ** -0.5
    ks = jax.random.split(k_e, 3)
    params = {
        "router": jax.random.normal(k_r, (d, e.num_experts), dtype) * s_in,
        "wi_gate": jax.random.normal(
            ks[0], (e.num_experts, d, e.d_ff_expert), dtype) * s_in,
        "wi_up": jax.random.normal(
            ks[1], (e.num_experts, d, e.d_ff_expert), dtype) * s_in,
        "wo": jax.random.normal(
            ks[2], (e.num_experts, e.d_ff_expert, d), dtype) * s_out,
    }
    if e.num_shared > 0:
        params["shared"] = mlp.init(
            k_s, "silu_glu", d, e.num_shared * e.d_ff_expert, dtype)
    return params


def _gates(e: MoEConfig, logits: jax.Array):
    """(T, E) routing logits -> (T, k) expert ids + normalized weights + aux."""
    t = logits.shape[0]
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    if e.router == "sinkhorn":
        # OT: uniform token mass -> uniform expert marginal (balanced).
        a = jnp.full((t,), 1.0 / t, jnp.float32)
        b = jnp.full((e.num_experts,), 1.0 / e.num_experts, jnp.float32)
        cost = -jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        plan = sinkhorn_plan(cost, a, b, lamb=e.sinkhorn_lamb,
                             max_iter=e.sinkhorn_iters).plan
        scores = plan * t                    # rows ~ sum to 1
    elif e.router == "topk":
        scores = probs
    else:
        raise ValueError(f"unknown router {e.router!r}")
    weights, ids = jax.lax.top_k(scores, e.top_k)           # (T, k)
    weights = weights / jnp.maximum(
        jnp.sum(weights, axis=-1, keepdims=True), 1e-9)
    # switch-style load-balance aux: E * sum_e f_e * p_e
    assign = jax.nn.one_hot(ids[:, 0], e.num_experts, dtype=jnp.float32)
    f_e = jnp.mean(assign, axis=0)
    p_e = jnp.mean(probs, axis=0)
    aux = e.num_experts * jnp.sum(f_e * p_e)
    return ids, weights, aux


def _dispatch_group(e: MoEConfig, xg: jax.Array, ids: jax.Array,
                    weights: jax.Array, cap: int):
    """Group-local sort-based dispatch. xg (Tg, D); ids/weights (Tg, k).
    Returns grouped (E, C, D), combine metadata. All index ops are local to
    the group, so under vmap the group axis is a clean batch dim for GSPMD
    (no cross-group scatter; see ``apply``)."""
    tg, d = xg.shape
    k = e.top_k
    flat_exp = ids.reshape(tg * k)
    flat_tok = jnp.repeat(jnp.arange(tg), k)
    flat_w = weights.reshape(tg * k)
    order = jnp.argsort(flat_exp, stable=True)
    sorted_exp = flat_exp[order]
    sorted_tok = flat_tok[order]
    sorted_w = flat_w[order]
    counts = jnp.bincount(sorted_exp, length=e.num_experts)
    starts = jnp.cumsum(counts) - counts
    pos_in_exp = jnp.arange(tg * k) - starts[sorted_exp]
    keep = pos_in_exp < cap
    slot = jnp.where(keep, sorted_exp * cap + pos_in_exp,
                     e.num_experts * cap)
    buf = jnp.zeros((e.num_experts * cap + 1, d), xg.dtype)
    buf = buf.at[slot].set(xg[sorted_tok])
    grouped = buf[:-1].reshape(e.num_experts, cap, d)
    return grouped, (keep, slot, sorted_tok, sorted_w)


def _combine_group(meta, y: jax.Array, tg: int, d: int):
    keep, slot, sorted_tok, sorted_w = meta
    yf = y.reshape(-1, d)                                   # (E*C, D)
    contrib = jnp.where(keep[:, None],
                        yf[jnp.minimum(slot, yf.shape[0] - 1)]
                        * sorted_w[:, None].astype(y.dtype), 0.0)
    return jnp.zeros((tg, d), y.dtype).at[sorted_tok].add(contrib)


def apply(cfg: ModelConfig, params: dict, x: jax.Array
          ) -> tuple[jax.Array, jax.Array]:
    """x: (B, S, D) -> (out (B,S,D), aux_loss scalar).

    Grouped sort-based dispatch: each batch row is a dispatch group
    (GShard's group-local capacity), and the whole gather/sort/scatter
    pipeline is vmapped over the batch axis. This keeps dispatch FLOP-free
    (no one-hot einsum) while staying GSPMD-tileable: every index op
    carries the sharded batch dim, so dispatch is device-local. The first
    (ungrouped) version forced GSPMD to replicate the (E*C, D) buffers and
    all-reduce ~24 GB per layer -- see EXPERIMENTS.md §Perf iteration log.

    Capacity C = ceil(S * top_k * cf / E) per group; overflow drops are
    group-local (standard GShard semantics).
    """
    e = cfg.moe
    b, s, d = x.shape
    dtype = x.dtype
    t = b * s

    logits = x.reshape(t, d) @ params["router"].astype(dtype)  # (T, E)
    ids, weights, aux = _gates(e, logits)                      # (T, k)
    cap = max(int(s * e.top_k * e.capacity_factor / e.num_experts + 1),
              e.top_k)

    ids_g = ids.reshape(b, s, e.top_k)
    w_g = weights.reshape(b, s, e.top_k)

    grouped, meta = jax.vmap(
        lambda xg, i, w: _dispatch_group(e, xg, i, w, cap))(x, ids_g, w_g)
    # pin the intended layout: batch over dp, expert-hidden over model.
    # Without these anchors GSPMD chose a d-sharded contraction and emitted
    # ~21 GB all-reduces per layer (EXPERIMENTS.md §Perf).
    from repro.models.sharding_hints import hint_moe_tokens, hint_moe_hidden
    # decode trade-off: replicate token buffers (move activations) only when
    # they are smaller than the per-chip weight gather they would avoid
    rep_dec = (b * cap) < (3 * e.d_ff_expert) // 8
    grouped = hint_moe_tokens(grouped, rep_dec)  # (B,E,C,D) -> P(dp,N,N,N)
    gate = jnp.einsum("becd,edf->becf", grouped,
                      fsdp_use(params["wi_gate"], "wi_gate", dtype))
    up = jnp.einsum("becd,edf->becf", grouped,
                    fsdp_use(params["wi_up"], "wi_up", dtype))
    h = hint_moe_hidden(jax.nn.silu(gate) * up, rep_dec)  # P(dp,N,N,model)
    y = jnp.einsum("becf,efd->becd", h, fsdp_use(params["wo"], "wo", dtype))
    y = hint_moe_tokens(y, rep_dec)

    out = jax.vmap(lambda m, yg: _combine_group(m, yg, s, d))(meta, y)

    if e.num_shared > 0:
        out = out + mlp.apply("silu_glu", params["shared"],
                              x.reshape(t, d)).reshape(b, s, d)
    return out.reshape(b, s, d), aux.astype(jnp.float32)
