"""Uniform model API over the three assembly families (lm / vlm / enc-dec).

``build_model(cfg)`` returns a ModelAPI whose five functions are everything
the training loop, serving loop, and dry-run need:

    init(key)                 -> params
    loss(params, batch)       -> (scalar loss, metrics dict)
    prefill(params, batch)    -> (last-position logits, cache)
    decode(params, cache, tok)-> (logits, new cache)
    init_cache(batch, max_len)-> cache pytree

Batches (all int32 tokens; stub modalities per the assignment):
    lm:    {tokens (B,S), labels (B,S)}
    vlm:   {patches (B,P,D) f32, tokens (B,S-P), labels (B,S-P)}
    audio: {frames (B,F,D) f32, tokens (B,S), labels (B,S)}
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import encdec, lm
from repro.models.layers import embedding

# decode tables for whisper's learned positions are sized to the largest
# assigned decode shape
_MAX_LEARNED_POS = 32768


class ModelAPI(NamedTuple):
    cfg: ModelConfig
    init: Callable[..., Any]
    loss: Callable[..., Any]
    prefill: Callable[..., Any]
    decode: Callable[..., Any]
    init_cache: Callable[..., Any]


def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean next-token CE, f32 softmax, ignoring labels < 0."""
    from repro.models.sharding_hints import hint_logits
    logits = hint_logits(logits.astype(jnp.float32))
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(
        logits, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
    nll = logz - gold
    mask = (labels >= 0).astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def _compute_dtype(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.compute_dtype == "bfloat16" else jnp.float32


def build_model(cfg: ModelConfig, *, q_block: int = 512,
                kv_block: int = 1024, remat: bool = True) -> ModelAPI:
    if cfg.family == "audio":
        return _build_encdec(cfg, q_block, kv_block, remat)
    return _build_lm(cfg, q_block, kv_block, remat)


# ---------------------------------------------------------------------------
# decoder-only (lm / moe / ssm / hybrid / vlm)
# ---------------------------------------------------------------------------

def _build_lm(cfg: ModelConfig, q_block: int, kv_block: int,
              remat: bool) -> ModelAPI:
    is_vlm = cfg.family == "vlm"
    dtype = _compute_dtype(cfg)

    def init(key):
        return lm.init_params(key, cfg, max_positions=_MAX_LEARNED_POS
                              if cfg.learned_pos else 0)

    def _embed_inputs(params, batch):
        x = embedding.embed(cfg, params["embedding"], batch["tokens"],
                            dtype=dtype)
        prefix_len = 0
        if is_vlm:
            patches = batch["patches"].astype(dtype)
            x = jnp.concatenate([patches, x], axis=1)
            prefix_len = patches.shape[1]
        return x, prefix_len

    def loss(params, batch):
        x, prefix_len = _embed_inputs(params, batch)
        h, aux = lm.forward(cfg, params, x, prefix_len=prefix_len,
                            q_block=q_block, kv_block=kv_block, remat=remat)
        if is_vlm:
            h = h[:, prefix_len:]
        logits = embedding.logits(cfg, params["embedding"], h)
        ce = cross_entropy(logits, batch["labels"])
        aux_w = cfg.moe.router_aux_loss if cfg.moe is not None else 0.0
        total = ce + aux_w * aux
        return total, {"ce": ce, "aux": aux}

    def prefill_fn(params, batch, *, max_len: int):
        x, prefix_len = _embed_inputs(params, batch)
        h, cache = lm.prefill(cfg, params, x, max_len=max_len,
                              prefix_len=prefix_len, q_block=q_block,
                              kv_block=kv_block)
        logits = embedding.logits(cfg, params["embedding"], h[:, -1:])
        return logits, cache

    def decode(params, cache, tokens):
        pos = cache["pos"]
        x = embedding.embed(cfg, params["embedding"], tokens,
                            positions=pos[None], dtype=dtype)
        h, cache = lm.decode_step(cfg, params, cache, x)
        logits = embedding.logits(cfg, params["embedding"], h)
        return logits, cache

    def init_cache(batch, max_len):
        return lm.init_cache(cfg, batch, max_len)

    return ModelAPI(cfg=cfg, init=init, loss=loss, prefill=prefill_fn,
                    decode=decode, init_cache=init_cache)


# ---------------------------------------------------------------------------
# enc-dec (whisper)
# ---------------------------------------------------------------------------

def _build_encdec(cfg: ModelConfig, q_block: int, kv_block: int,
                  remat: bool) -> ModelAPI:
    dtype = _compute_dtype(cfg)

    def init(key):
        return encdec.init_params(key, cfg, max_positions=_MAX_LEARNED_POS)

    def loss(params, batch):
        enc_out = encdec.encode(cfg, params, batch["frames"], remat=remat)
        h = encdec.decode_full(cfg, params, batch["tokens"], enc_out,
                               q_block=q_block, kv_block=kv_block,
                               remat=remat)
        logits = embedding.logits(cfg, params["embedding"], h)
        ce = cross_entropy(logits, batch["labels"])
        return ce, {"ce": ce, "aux": jnp.zeros((), jnp.float32)}

    def prefill_fn(params, batch, *, max_len: int):
        h, cache = encdec.prefill(cfg, params, batch["frames"],
                                  batch["tokens"], max_len=max_len,
                                  q_block=q_block, kv_block=kv_block)
        logits = embedding.logits(cfg, params["embedding"], h[:, -1:])
        return logits, cache

    def decode(params, cache, tokens):
        pos = cache["pos"]
        x = embedding.embed(cfg, params["embedding"], tokens,
                            positions=pos[None], dtype=dtype)
        h, cache = encdec.decode_step(cfg, params, cache, x)
        logits = embedding.logits(cfg, params["embedding"], h)
        return logits, cache

    def init_cache(batch, max_len):
        return encdec.init_cache(cfg, batch, max_len)

    return ModelAPI(cfg=cfg, init=init, loss=loss, prefill=prefill_fn,
                    decode=decode, init_cache=init_cache)
