"""repro.obs: dependency-free observability for the serving stack.

Three parts (see ``docs/observability.md`` for the naming scheme and
operator quickstart):

- :mod:`repro.obs.metrics` -- thread-safe counter/gauge/histogram
  registry; the single backing store ``ServingStats`` and the K-cache
  stats are views over.
- :mod:`repro.obs.trace` -- per-request span trees + structured event
  log, exportable as Chrome trace-event JSON (Perfetto) and JSONL.
- :mod:`repro.obs.export` -- Prometheus text exposition, a stdlib HTTP
  scrape endpoint, and a periodic JSONL event flusher.

The whole package is stdlib-only and bitwise-neutral: recorders never
touch arrays, and observability-off is the shared :data:`NULL_TRACER`
no-op with zero hot-path cost.
"""
from .export import JsonlExporter, MetricsServer, render_prometheus
from .metrics import (DEFAULT_SIZE_BUCKETS, DEFAULT_TIME_BUCKETS, Counter,
                      Gauge, Histogram, MetricsRegistry)
from .trace import NULL_TRACER, NullTracer, Tracer

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "DEFAULT_TIME_BUCKETS",
    "DEFAULT_SIZE_BUCKETS",
    "Tracer",
    "NullTracer",
    "NULL_TRACER",
    "render_prometheus",
    "MetricsServer",
    "JsonlExporter",
]
