"""Thread-safe counter/gauge/histogram registry: the single backing store
for serving telemetry.

Today's scattered stats (``ServingStats``, ``KCacheStats``,
``last_batch_stats``) become *views* over one ``MetricsRegistry`` so a
live process can be scraped (Prometheus text format, ``obs.export``)
instead of killed to see its counters.

Design constraints, in order:

- **Dependency-free.** stdlib only; importable from `core/` without
  dragging jax or anything else in.
- **Thread-safe by contract.** Counters are incremented from client
  threads (submit), the dispatcher thread, and writer lanes
  concurrently; every mutation takes the metric's own lock.  A
  ``Counter.inc`` is one uncontended lock acquire + int add -- cheap
  enough to sit inside the coalescer's hot path (measured: the serving
  bench gates total observability overhead at <= 5%).
- **Prometheus-shaped.** Metric names follow the exposition conventions
  (``*_total`` counters, ``*_seconds`` units, optional labels); the
  registry renders directly via :func:`repro.obs.export.render_prometheus`.

Metrics never hold arrays and never touch engine inputs/outputs --
attaching a registry is bitwise-neutral on every route (pinned by
``tests/test_obs.py`` against the golden table).
"""
from __future__ import annotations

import threading
from bisect import bisect_left
from typing import Iterable

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "DEFAULT_TIME_BUCKETS",
    "DEFAULT_SIZE_BUCKETS",
]

# latency-ish seconds buckets (sub-ms batches up to multi-second stalls)
DEFAULT_TIME_BUCKETS: tuple[float, ...] = (
    0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025, 0.05,
    0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0,
)
# pow2 size buckets (batch sizes, row counts)
DEFAULT_SIZE_BUCKETS: tuple[float, ...] = (
    1, 2, 4, 8, 16, 32, 64, 128, 256,
)


def _label_key(labels: dict[str, str] | None) -> tuple[tuple[str, str], ...]:
    if not labels:
        return ()
    return tuple(sorted(labels.items()))


class _Metric:
    """Shared bits: name, help text, frozen label set, own lock."""

    kind = "untyped"

    def __init__(self, name: str, help_: str = "",
                 labels: dict[str, str] | None = None):
        self.name = name
        self.help = help_
        self.labels: dict[str, str] = dict(labels or {})
        self._lock = threading.Lock()


class Counter(_Metric):
    """Monotonic counter. ``inc`` only; never goes down."""

    kind = "counter"

    def __init__(self, name: str, help_: str = "",
                 labels: dict[str, str] | None = None):
        super().__init__(name, help_, labels)
        self._value = 0.0

    def inc(self, n: float = 1.0) -> None:
        if n < 0:
            raise ValueError("counters only go up; use a Gauge")
        with self._lock:
            self._value += n

    @property
    def value(self) -> float:
        with self._lock:
            return self._value


class Gauge(_Metric):
    """Point-in-time value; settable and incrementable either way."""

    kind = "gauge"

    def __init__(self, name: str, help_: str = "",
                 labels: dict[str, str] | None = None):
        super().__init__(name, help_, labels)
        self._value = 0.0

    def set(self, v: float) -> None:
        with self._lock:
            self._value = float(v)

    def inc(self, n: float = 1.0) -> None:
        with self._lock:
            self._value += n

    def dec(self, n: float = 1.0) -> None:
        with self._lock:
            self._value -= n

    @property
    def value(self) -> float:
        with self._lock:
            return self._value


class Histogram(_Metric):
    """Fixed-bucket histogram with cumulative Prometheus semantics.

    ``observe(v)`` adds to every bucket whose upper bound ``le >= v``
    at render time; internally we store per-bucket (non-cumulative)
    counts and cumulate when snapshotting, so observe is O(log buckets).
    """

    kind = "histogram"

    def __init__(self, name: str, help_: str = "",
                 buckets: Iterable[float] = DEFAULT_TIME_BUCKETS,
                 labels: dict[str, str] | None = None):
        super().__init__(name, help_, labels)
        bs = sorted(float(b) for b in buckets)
        if not bs:
            raise ValueError("histogram needs at least one bucket bound")
        self.bounds: tuple[float, ...] = tuple(bs)
        # one extra slot for the +Inf overflow bucket
        self._counts = [0] * (len(bs) + 1)
        self._sum = 0.0
        self._count = 0

    def observe(self, v: float) -> None:
        v = float(v)
        i = bisect_left(self.bounds, v)
        with self._lock:
            self._counts[i] += 1
            self._sum += v
            self._count += 1

    @property
    def count(self) -> int:
        with self._lock:
            return self._count

    @property
    def sum(self) -> float:
        with self._lock:
            return self._sum

    def cumulative(self) -> list[tuple[float, int]]:
        """``[(le, cumulative_count), ...]`` ending with ``(inf, count)``."""
        with self._lock:
            counts = list(self._counts)
        out, run = [], 0
        for le, c in zip(self.bounds, counts):
            run += c
            out.append((le, run))
        out.append((float("inf"), run + counts[-1]))
        return out


class MetricsRegistry:
    """Get-or-create registry keyed by (name, labels).

    Re-registering an existing (name, labels) pair returns the same
    object; re-registering under a different metric kind raises -- a
    name means one thing for the process's lifetime.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: dict[tuple, _Metric] = {}

    def _get_or_create(self, cls, name: str, help_: str,
                       labels: dict[str, str] | None, **kw) -> _Metric:
        key = (name, _label_key(labels))
        with self._lock:
            m = self._metrics.get(key)
            if m is not None:
                if not isinstance(m, cls):
                    raise TypeError(
                        f"metric {name!r} already registered as {m.kind}, "
                        f"not {cls.kind}")
                return m
            m = cls(name, help_, labels=labels, **kw)
            self._metrics[key] = m
            return m

    def counter(self, name: str, help_: str = "",
                labels: dict[str, str] | None = None) -> Counter:
        return self._get_or_create(Counter, name, help_, labels)

    def gauge(self, name: str, help_: str = "",
              labels: dict[str, str] | None = None) -> Gauge:
        return self._get_or_create(Gauge, name, help_, labels)

    def histogram(self, name: str, help_: str = "",
                  buckets: Iterable[float] = DEFAULT_TIME_BUCKETS,
                  labels: dict[str, str] | None = None) -> Histogram:
        return self._get_or_create(Histogram, name, help_, labels,
                                   buckets=buckets)

    def collect(self) -> list[_Metric]:
        """All metrics, grouped by name (stable order within a name)."""
        with self._lock:
            ms = list(self._metrics.values())
        ms.sort(key=lambda m: (m.name, _label_key(m.labels)))
        return ms

    def snapshot(self) -> dict[str, object]:
        """Plain-data dump (JSON-able) of every metric's current value."""
        out: dict[str, object] = {}
        for m in self.collect():
            key = m.name
            if m.labels:
                lbl = ",".join(f"{k}={v}" for k, v in sorted(m.labels.items()))
                key = f"{m.name}{{{lbl}}}"
            if isinstance(m, Histogram):
                out[key] = {
                    "count": m.count,
                    "sum": m.sum,
                    # stringify the +Inf bound: strict-JSON consumers choke
                    # on bare Infinity literals
                    "buckets": [["+Inf" if le == float("inf") else le, c]
                                for le, c in m.cumulative()],
                }
            else:
                out[key] = m.value  # type: ignore[union-attr]
        return out
