"""Per-request span tracing + structured event log for the serving stack.

A :class:`Tracer` collects, per submitted request, a small span tree --
root ``request`` span (submit -> fan-out) with children for queue wait,
the dispatch itself, and the engine phases the service can attribute
(K-cache precompute, solve, RWMD bound, rerank).  Completed trees land
in a bounded ring buffer; alongside them a structured event log records
the one-shot facts an operator reasons about in the resilience runbook:
breaker transitions, brownout enter/exit, watchdog strikes, quarantines,
``DegradedResult`` reasons, WAL append / compaction boundaries.

Exports:

- :meth:`Tracer.chrome_trace` / :meth:`Tracer.export_chrome` -- Chrome
  trace-event JSON (``ph: "X"`` complete events, ``ph: "i"`` instants),
  loadable directly in Perfetto / ``chrome://tracing``.
- :meth:`Tracer.export_events_jsonl` / :meth:`Tracer.drain_events` --
  the event log as JSON-lines (one dict per line), for live tailing.

Contract (the whole point of the design):

- **Off = free.**  The shared :data:`NULL_TRACER` is the default
  everywhere; its methods are no-ops and ``enabled`` is ``False`` so
  hot paths can skip even building the attrs dict.
- **Never touches arrays.**  Spans carry only scalars pulled from stats
  dicts; attaching a tracer is bitwise-neutral on every engine route
  (pinned against the golden table in ``tests/test_obs.py``).
- **Every request closes exactly once.**  Quarantined, cancelled,
  failed and degraded requests all end as closed trees with a status --
  the chaos suite asserts submitted == closed with no leaks.

stdlib-only; safe to import from any layer.
"""
from __future__ import annotations

import json
import threading
import time
from collections import deque
from typing import Callable

__all__ = ["Tracer", "NullTracer", "NULL_TRACER"]


class NullTracer:
    """Shared no-op recorder: observability off, zero hot-path cost."""

    enabled = False

    def begin_request(self, seq, **attrs):
        pass

    def add_span(self, seq, name, t0, t1, **attrs):
        pass

    def end_request(self, seq, t1=None, status="ok", **attrs):
        pass

    def closed_request(self, *, status, t0=None, t1=None, **attrs):
        pass

    def event(self, name, **fields):
        pass


NULL_TRACER = NullTracer()


class Tracer(NullTracer):
    """Span/event recorder with bounded memory.

    ``ring``/``max_events`` bound the two deques; one request tree is a
    handful of small dicts, so the defaults hold thousands of requests
    in a few MB.  All methods are thread-safe (client threads submit,
    the dispatcher thread closes) and never raise into the caller.
    """

    enabled = True

    def __init__(self, *, ring: int = 4096, max_events: int = 65536,
                 clock: Callable[[], float] = time.monotonic):
        self._clock = clock
        self._lock = threading.Lock()
        self._open: dict[object, dict] = {}
        self.completed: deque[dict] = deque(maxlen=ring)
        self.events: deque[dict] = deque(maxlen=max_events)
        self._anon = 0          # ids for trees closed without a seq
        self._dropped = 0       # trees evicted from the ring

    def now(self) -> float:
        return self._clock()

    # ---------------------------------------------------------- spans

    def begin_request(self, seq, **attrs):
        t0 = attrs.pop("t0", None)
        tree = {"seq": seq, "t0": self._clock() if t0 is None else t0,
                "t1": None, "status": None, "attrs": attrs, "spans": []}
        with self._lock:
            # a seq reused before closure would leak its first tree;
            # close it defensively rather than lose it
            prev = self._open.pop(seq, None)
            if prev is not None:
                prev["t1"] = tree["t0"]
                prev["status"] = "orphaned"
                self._finish_locked(prev)
            self._open[seq] = tree

    def add_span(self, seq, name, t0, t1, **attrs):
        with self._lock:
            tree = self._open.get(seq)
            if tree is None:
                return
            tree["spans"].append(
                {"name": name, "t0": t0, "t1": t1, "attrs": attrs})

    def end_request(self, seq, t1=None, status="ok", **attrs):
        t1 = self._clock() if t1 is None else t1
        with self._lock:
            tree = self._open.pop(seq, None)
            if tree is None:
                return
            tree["t1"] = t1
            tree["status"] = status
            if attrs:
                tree["attrs"].update(attrs)
            self._finish_locked(tree)

    def closed_request(self, *, status, t0=None, t1=None, **attrs):
        """Record an already-finished request as a closed single-node
        tree (e.g. quarantined at submit: never enqueued, never open)."""
        t = self._clock()
        tree = {"seq": None, "t0": t if t0 is None else t0,
                "t1": t if t1 is None else t1, "status": status,
                "attrs": attrs, "spans": []}
        with self._lock:
            self._anon += 1
            tree["seq"] = f"anon-{self._anon}"
            self._finish_locked(tree)

    def _finish_locked(self, tree: dict) -> None:
        if len(self.completed) == self.completed.maxlen:
            self._dropped += 1
        self.completed.append(tree)

    # ---------------------------------------------------------- events

    def event(self, name, **fields):
        ev = {"t": self._clock(), "event": name}
        ev.update(fields)
        with self._lock:
            self.events.append(ev)

    def drain_events(self) -> list[dict]:
        """Return and clear the buffered events (for periodic flush)."""
        with self._lock:
            out = list(self.events)
            self.events.clear()
        return out

    # ---------------------------------------------------------- state

    @property
    def open_count(self) -> int:
        with self._lock:
            return len(self._open)

    @property
    def dropped(self) -> int:
        with self._lock:
            return self._dropped

    def snapshot(self) -> tuple[list[dict], list[dict]]:
        """(completed trees, events) as lists -- no clearing."""
        with self._lock:
            return list(self.completed), list(self.events)

    # ---------------------------------------------------------- export

    def chrome_trace(self) -> dict:
        """Chrome trace-event JSON object (Perfetto-loadable).

        Layout: each request tree gets its own ``tid`` (its row in the
        viewer) under ``pid`` 1, with the root span and its phase
        children as ``"X"`` complete events; log events appear as
        ``"i"`` instants on tid 0.  Timestamps are microseconds from
        the tracer's clock origin.
        """
        trees, events = self.snapshot()
        tids = {t["seq"]: i + 1 for i, t in enumerate(trees)}
        tev: list[dict] = []

        def us(t: float) -> float:
            return t * 1e6

        def x(name, t0, t1, tid, args):
            tev.append({
                "name": name, "ph": "X", "pid": 1, "tid": tid,
                "ts": us(t0), "dur": max(us(t1) - us(t0), 0.0),
                "cat": "wmd", "args": args,
            })

        for tree in trees:
            tid = tids[tree["seq"]]
            args = {"seq": str(tree["seq"]), "status": tree["status"]}
            args.update(_jsonable(tree["attrs"]))
            x(f"request[{tree['status']}]", tree["t0"],
              tree["t1"] if tree["t1"] is not None else tree["t0"],
              tid, args)
            for sp in tree["spans"]:
                x(sp["name"], sp["t0"], sp["t1"], tid,
                  _jsonable(sp["attrs"]))
        for ev in events:
            args = {k: v for k, v in ev.items() if k not in ("t", "event")}
            tev.append({
                "name": ev["event"], "ph": "i", "pid": 1, "tid": 0,
                "ts": us(ev["t"]), "s": "g", "cat": "wmd-event",
                "args": _jsonable(args),
            })
        return {"traceEvents": tev, "displayTimeUnit": "ms"}

    def export_chrome(self, path: str) -> int:
        """Write the Chrome trace JSON; returns the event count."""
        obj = self.chrome_trace()
        with open(path, "w") as f:
            json.dump(obj, f)
        return len(obj["traceEvents"])

    def export_events_jsonl(self, path: str, *, append: bool = False) -> int:
        """Write the event log as JSON-lines; returns the line count."""
        _, events = self.snapshot()
        with open(path, "a" if append else "w") as f:
            for ev in events:
                f.write(json.dumps(_jsonable(ev)) + "\n")
        return len(events)


def _jsonable(obj):
    """Best-effort plain-data coercion (numpy scalars -> python floats,
    everything unknown -> repr) so export never raises."""
    if isinstance(obj, dict):
        return {str(k): _jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_jsonable(v) for v in obj]
    if isinstance(obj, (str, int, float, bool)) or obj is None:
        return obj
    item = getattr(obj, "item", None)   # numpy scalar
    if callable(item):
        try:
            return _jsonable(item())
        except Exception:
            pass
    return repr(obj)
