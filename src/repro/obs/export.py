"""Export surfaces for the observability layer.

- :func:`render_prometheus` -- a :class:`~repro.obs.metrics.MetricsRegistry`
  to Prometheus text exposition format (version 0.0.4): ``# HELP`` /
  ``# TYPE`` per metric name, cumulative ``_bucket{le=...}`` series plus
  ``_sum``/``_count`` for histograms.
- :class:`MetricsServer` -- a stdlib ``http.server`` daemon thread
  serving ``GET /metrics`` so a running serve loop can be scraped live
  (``launch.serve --metrics-port``).
- :class:`JsonlExporter` -- periodic flush of a tracer's event log to a
  JSON-lines file (append-only; survives the process dying between
  flushes up to one period of loss).

stdlib-only, same as the rest of ``repro.obs``.
"""
from __future__ import annotations

import json
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer

from .metrics import Counter, Gauge, Histogram, MetricsRegistry
from .trace import Tracer

__all__ = ["render_prometheus", "MetricsServer", "JsonlExporter"]

_ESC = str.maketrans({"\\": r"\\", "\n": r"\n", '"': r'\"'})


def _fmt_labels(labels: dict[str, str], extra: dict[str, str] | None = None
                ) -> str:
    items = dict(labels)
    if extra:
        items.update(extra)
    if not items:
        return ""
    inner = ",".join(f'{k}="{str(v).translate(_ESC)}"'
                     for k, v in sorted(items.items()))
    return "{" + inner + "}"


def _fmt_value(v: float) -> str:
    if v == float("inf"):
        return "+Inf"
    if v == float("-inf"):
        return "-Inf"
    f = float(v)
    return repr(int(f)) if f.is_integer() else repr(f)


def render_prometheus(registry: MetricsRegistry) -> str:
    """Render every metric in the registry as text exposition format."""
    lines: list[str] = []
    seen_header: set[str] = set()
    for m in registry.collect():
        if m.name not in seen_header:
            seen_header.add(m.name)
            if m.help:
                lines.append(f"# HELP {m.name} {m.help.translate(_ESC)}")
            lines.append(f"# TYPE {m.name} {m.kind}")
        if isinstance(m, (Counter, Gauge)):
            lines.append(
                f"{m.name}{_fmt_labels(m.labels)} {_fmt_value(m.value)}")
        elif isinstance(m, Histogram):
            for le, c in m.cumulative():
                lab = _fmt_labels(m.labels, {"le": _fmt_value(le)})
                lines.append(f"{m.name}_bucket{lab} {c}")
            lines.append(
                f"{m.name}_sum{_fmt_labels(m.labels)} {_fmt_value(m.sum)}")
            lines.append(
                f"{m.name}_count{_fmt_labels(m.labels)} {m.count}")
    return "\n".join(lines) + "\n"


class MetricsServer:
    """Prometheus scrape endpoint on a daemon thread.

    ``GET /metrics`` renders the registry; ``GET /healthz`` answers
    ``ok`` (a liveness probe that costs nothing).  ``port=0`` binds an
    ephemeral port -- read it back from ``.port`` (tests do).
    """

    def __init__(self, registry: MetricsRegistry, port: int = 0,
                 host: str = "0.0.0.0"):
        self.registry = registry
        srv = self

        class Handler(BaseHTTPRequestHandler):
            def do_GET(self):                          # noqa: N802
                if self.path.split("?")[0] == "/metrics":
                    body = render_prometheus(srv.registry).encode()
                    ctype = "text/plain; version=0.0.4; charset=utf-8"
                elif self.path.split("?")[0] == "/healthz":
                    body, ctype = b"ok\n", "text/plain"
                else:
                    self.send_error(404)
                    return
                self.send_response(200)
                self.send_header("Content-Type", ctype)
                self.send_header("Content-Length", str(len(body)))
                self.end_headers()
                self.wfile.write(body)

            def log_message(self, *a):                 # scrapes are chatty
                pass

        self._httpd = ThreadingHTTPServer((host, port), Handler)
        self.port = self._httpd.server_address[1]
        self._thread = threading.Thread(
            target=self._httpd.serve_forever, name="wmd-metrics",
            daemon=True)
        self._thread.start()

    def close(self) -> None:
        self._httpd.shutdown()
        self._httpd.server_close()
        self._thread.join(timeout=5.0)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


class JsonlExporter:
    """Flush a tracer's event log to a JSONL file every ``interval_s``.

    Events are *drained* (removed from the tracer's ring) on each flush,
    so long runs never lose old events to ring eviction; ``close()``
    performs a final flush.  The file is append-mode: one process run ==
    one growing log.
    """

    def __init__(self, tracer: Tracer, path: str, interval_s: float = 1.0):
        self.tracer = tracer
        self.path = path
        self.interval_s = interval_s
        self.written = 0
        open(path, "w").close()                        # truncate at start
        self._stop = threading.Event()
        self._thread = threading.Thread(
            target=self._run, name="wmd-trace-flush", daemon=True)
        self._thread.start()

    def _flush(self) -> None:
        events = self.tracer.drain_events()
        if not events:
            return
        from .trace import _jsonable
        with open(self.path, "a") as f:
            for ev in events:
                f.write(json.dumps(_jsonable(ev)) + "\n")
        self.written += len(events)

    def _run(self) -> None:
        while not self._stop.wait(self.interval_s):
            try:
                self._flush()
            except Exception:
                pass            # exporter must never kill the process

    def close(self) -> None:
        self._stop.set()
        self._thread.join(timeout=5.0)
        self._flush()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
