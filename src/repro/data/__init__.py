"""Data pipelines: synthetic WMD corpus + LM token batches."""
from repro.data.corpus import WMDData, make_corpus, zipf_query_stream
from repro.data.live_corpus import LiveCorpus
from repro.data.tokens import TokenPipeline, batch_struct
from repro.data.wal import WalWriter, replay

__all__ = ["WMDData", "make_corpus", "zipf_query_stream", "TokenPipeline",
           "batch_struct", "LiveCorpus", "WalWriter", "replay"]
