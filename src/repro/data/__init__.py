"""Data pipelines: synthetic WMD corpus + LM token batches."""
from repro.data.corpus import WMDData, make_corpus
from repro.data.tokens import TokenPipeline, batch_struct

__all__ = ["WMDData", "make_corpus", "TokenPipeline", "batch_struct"]
