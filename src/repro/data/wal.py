"""Checksummed append-only write-ahead log for the live corpus.

Every corpus mutation (`data.live_corpus.LiveCorpus.add_docs` /
``remove_docs``) is made durable here BEFORE it is applied in memory or
acknowledged to the caller, so a crash at any instant loses at most the
operations that were never acknowledged -- the one-directional durability
contract: **acked means recoverable** (un-acked operations may or may not
survive, and either outcome is legal).

Record framing (little-endian, self-delimiting)::

    [u32 payload length][u32 crc32(payload)][payload = msgpack record]

Replay semantics are *truncate at first bad record*: a record whose header
is incomplete, whose payload is short, whose CRC mismatches, or whose
msgpack fails to decode marks the torn tail a crashed writer leaves
behind. Everything before it is intact (each record's CRC covers its whole
payload); everything from it on is discarded and the file is truncated to
the last good boundary, so the next append continues a clean log. This is
the standard WAL recovery rule (ARIES-style logs, LevelDB/RocksDB journal
files) and is exactly what the fsync-before-ack ordering needs: the
acknowledged prefix always verifies.

Durability: `WalWriter.append` flushes AND fsyncs before returning, so an
append that returned is on disk. The ``hook`` callback fires at the three
write boundaries (``wal.append.pre`` / ``wal.append.torn`` /
``wal.append.synced``) -- the crash-point injector's substrate
(`serving.faultinject.CrashInjector`): a crash raised at ``torn`` leaves a
half-written record on disk (a real kill -9 between two write() calls),
which replay must truncate; one at ``synced`` leaves a durable but
un-acked record, which replay may legally surface. Production code passes
no hook; the boundaries cost one no-op call each.

Journal rotation belongs to the caller: `LiveCorpus` keeps one log per
snapshot generation (``wal_<gen>.log`` beside ``snapshot_<gen>``) and
starts a fresh log after each atomic snapshot rename, so replay is always
"latest complete snapshot + its own log" and old generations can be
garbage-collected wholesale.
"""
from __future__ import annotations

import os
import struct
import zlib
from typing import Callable

import msgpack

_HDR = struct.Struct("<II")   # (payload length, crc32(payload))


def _no_hook(name: str) -> None:
    pass


class WalWriter:
    """Append-only writer over one log file (created if missing, opened for
    append otherwise -- recovery truncates torn tails *before* reopening,
    see `replay`). Not thread-safe; the live corpus serializes writers
    under its own lock."""

    def __init__(self, path: str, *,
                 hook: Callable[[str], None] | None = None,
                 tracer=None):
        self.path = path
        self._hook = hook or _no_hook
        # optional repro.obs tracer: WAL boundaries land in the structured
        # event log. Fired BEFORE the crash hook at each boundary, so an
        # injected (or real) crash still leaves its boundary on record.
        if tracer is None:
            from repro.obs.trace import NULL_TRACER
            tracer = NULL_TRACER
        self.tracer = tracer
        self._f = open(path, "ab")

    def _boundary(self, name: str, **fields) -> None:
        if self.tracer.enabled:
            self.tracer.event(name, path=self.path, **fields)
        self._hook(name)

    def append(self, record) -> int:
        """Durably append one msgpack-able record; returns the end offset.

        Write order is header, half the payload, the rest -- with crash
        boundaries between -- then flush + fsync. Only after the fsync
        (the ``synced`` boundary) may the caller acknowledge the
        operation; a crash anywhere earlier leaves a torn record that
        replay truncates away."""
        payload = msgpack.packb(record, use_bin_type=True)
        crc = zlib.crc32(payload) & 0xFFFFFFFF
        self._boundary("wal.append.pre", bytes=len(payload))
        half = len(payload) // 2
        self._f.write(_HDR.pack(len(payload), crc))
        self._f.write(payload[:half])
        self._f.flush()
        self._boundary("wal.append.torn")
        self._f.write(payload[half:])
        self._f.flush()
        os.fsync(self._f.fileno())
        self._boundary("wal.append.synced")
        return self._f.tell()

    def sync(self) -> None:
        self._f.flush()
        os.fsync(self._f.fileno())

    def close(self) -> None:
        if not self._f.closed:
            self._f.flush()
            self._f.close()

    def __enter__(self) -> "WalWriter":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def replay(path: str, *, truncate: bool = True) -> list:
    """Read every intact record from a log; truncate the torn tail.

    Returns the decoded records in append order. Decoding stops at the
    first record that fails any check (short header, short payload, CRC
    mismatch, undecodable msgpack); with ``truncate`` (the recovery
    default) the file is cut back to the last good record boundary so
    subsequent appends extend a verified log. A missing file is an empty
    log (the fresh-directory case)."""
    if not os.path.exists(path):
        return []
    with open(path, "rb") as f:
        buf = f.read()
    records: list = []
    off = 0
    while off + _HDR.size <= len(buf):
        length, crc = _HDR.unpack_from(buf, off)
        start = off + _HDR.size
        end = start + length
        if end > len(buf):
            break                                   # short payload (torn)
        payload = buf[start:end]
        if zlib.crc32(payload) & 0xFFFFFFFF != crc:
            break                                   # corrupt (torn write)
        try:
            rec = msgpack.unpackb(payload, raw=False)
        except Exception:                           # noqa: BLE001
            break                   # CRC passed but payload undecodable --
        records.append(rec)         # treat as bad, same truncation rule
        off = end
    if truncate and off < len(buf):
        with open(path, "r+b") as f:
            f.truncate(off)
    return records
