"""Synthetic LM token pipeline: deterministic, shardable, restart-safe.

A real deployment would stream tokenized shards; the interface below matches
that contract (stateless ``batch_at(step)`` indexed by global step, so a
restarted trainer resumes mid-epoch deterministically -- the property that
matters for fault tolerance) while the payload is synthetic Zipf tokens.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig


@dataclasses.dataclass(frozen=True)
class TokenPipeline:
    cfg: ModelConfig
    batch: int
    seq_len: int
    seed: int = 0

    def batch_at(self, step: int) -> dict:
        """Deterministic batch for a global step (restart-safe)."""
        rng = np.random.default_rng((self.seed, step))
        v = self.cfg.vocab_size
        toks = np.minimum(rng.zipf(1.2, size=(self.batch, self.seq_len + 1)),
                          v) - 1
        toks = toks.astype(np.int32)
        out = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        if self.cfg.family == "vlm":
            p = self.cfg.encoder.num_positions
            out["patches"] = rng.normal(
                size=(self.batch, p, self.cfg.d_model)).astype(np.float32)
            out["tokens"] = out["tokens"][:, : self.seq_len - p]
            out["labels"] = out["labels"][:, : self.seq_len - p]
        if self.cfg.family == "audio":
            f = self.cfg.encoder.num_positions
            out["frames"] = rng.normal(
                size=(self.batch, f, self.cfg.d_model)).astype(np.float32)
        return out


def batch_struct(cfg: ModelConfig, shape: ShapeConfig):
    """ShapeDtypeStructs of one training batch (for dry-run input_specs)."""
    import jax
    import jax.numpy as jnp
    b, s = shape.global_batch, shape.seq_len
    out = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
           "labels": jax.ShapeDtypeStruct((b, s), jnp.int32)}
    if cfg.family == "vlm":
        p = cfg.encoder.num_positions
        out["patches"] = jax.ShapeDtypeStruct((b, p, cfg.d_model),
                                              jnp.float32)
        out["tokens"] = jax.ShapeDtypeStruct((b, s - p), jnp.int32)
        out["labels"] = jax.ShapeDtypeStruct((b, s - p), jnp.int32)
    if cfg.family == "audio":
        f = cfg.encoder.num_positions
        out["frames"] = jax.ShapeDtypeStruct((b, f, cfg.d_model),
                                             jnp.float32)
    return out
