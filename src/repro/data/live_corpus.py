"""Crash-consistent mutable corpus: WAL-backed upserts, tombstones, and
interruptible compaction over the frozen ELL machinery of `core.formats`.

Every serving scenario before this module assumed a corpus built once at
startup. `LiveCorpus` makes the *data* path mutable without giving up one
bit of the engine's determinism, by an LSM-style two-segment layout:

  * **base segment** -- an immutable capacity-padded `EllDocs` built at the
    last compaction (or recovery), exactly the ELL a one-shot build
    produces for the same docs in the same (ascending-id) order;
  * **delta segment** -- an append-only capacity-padded ELL absorbing
    recent `add_docs`; rows are written in place into pow2-grown arrays
    (`core.formats.write_doc_row` / `ell_with_capacity`), so the device
    program shapes stay stable between growth events;
  * **tombstones** -- `remove_docs` (and the old copy an upsert shadows)
    never rewrites a segment: the doc's id simply leaves the location map,
    and its delta row (if any) is cleared to ELL padding. Pad slots gather
    the engine's appended all-zero K column and contribute exactly 0 --
    the same pad-slot inertness the frozen engine already relies on -- so
    a dead row costs flops but can never change a live doc's bits.

The **incremental == batch contract**: per-doc Sinkhorn distances are
bitwise independent of ELL layout (row order, row count, nnz_max slack,
dead neighbors -- each (query, doc) cell reduces over its own slots only,
verified empirically across radically different layouts). Therefore a
corpus assembled by any interleaving of adds/removes/upserts answers
queries bit-for-bit like the same logical doc set built in one shot --
`serving.wmd_service.WMDService` gathers per-segment results into
ascending-doc-id order, and the golden table + ingest chaos suite pin it.

Durability (`data.wal`): every mutation is appended to a checksummed WAL
and fsynced BEFORE it is applied in memory or acknowledged, so **acked
means recoverable** after a kill -9 at any instant. Recovery loads the
newest complete snapshot generation and replays its WAL with
truncate-at-first-bad-record semantics. Raw (word_id, count) docs -- not
derived ELL arrays -- are what's logged and snapshotted, so every rebuild
runs the identical `ell_from_doc_lists` arithmetic and bits never drift.

Compaction is an *interruptible* job with an atomic segment swap, the
checkpointer's tmp-dir/rename pattern (`checkpoint.checkpointer._write`):
build the new base from the live docs, write ``snapshot_<gen+1>.tmp``,
fsync, rename, THEN swap segments in memory, rotate to ``wal_<gen+1>``
and garbage-collect old generations. A crash anywhere before the rename
leaves the old generation fully live (retry is idempotent); a crash after
it recovers to the new generation with an empty delta -- either way the
logical corpus is exactly the pre-crash one.

Crash boundaries (`crash_hook` -- `serving.faultinject.CrashInjector`):
``wal.append.pre`` / ``wal.append.torn`` / ``wal.append.synced`` inside
every append, and ``compact.begin`` / ``compact.built`` /
``compact.snapshot.tmp`` / ``compact.renamed`` / ``compact.done`` across
compaction. The chaos suite dry-runs an op sequence to enumerate its
boundaries, then sweeps a kill over every single one and asserts bitwise
recovery. Production passes no hook.

Disk layout (all inside one directory)::

    snapshot_<gen>/docs.msgpack   raw docs, ascending id (sha256 in meta)
    snapshot_<gen>/meta.json      gen, num_vocab, num_docs, checksum
    snapshot_<gen>.tmp/           crashed-writer leftovers (ignored)
    wal_<gen>.log                 mutations since snapshot <gen>
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
import time
from typing import Callable, Sequence

import msgpack
import numpy as np

from repro.core import formats
from repro.data import wal as wal_mod

_BASE, _DELTA = 0, 1


def _no_hook(name: str) -> None:
    pass


Doc = list  # [(word_id, count), ...] -- raw counts, normalized at ELL time


class LiveCorpus:
    """WAL-backed mutable corpus over a base + delta ELL segment pair.

    Opening is recovery: a fresh directory starts empty at generation 0;
    an existing one loads its newest complete snapshot and replays that
    generation's WAL (truncating any torn tail a crashed writer left).

    Args:
      path:        corpus directory (created if missing).
      num_vocab:   V; word ids are validated against it at the API edge.
      nnz_align:   ELL row-width rounding, as in `core.formats`.
      min_capacity: smallest segment row capacity (pow2-grown above it);
                   also keeps even an empty segment shard-divisible.
      normalize:   normalize doc weights at ELL-build time (pass False
                   when feeding already-normalized weights).
      crash_hook:  test-only boundary callback (see module docstring).
      tracer:      optional `repro.obs` tracer; WAL/compaction boundaries
                   are recorded as structured events (also settable after
                   construction via the ``tracer`` property).
    """

    def __init__(self, path: str, num_vocab: int, *, nnz_align: int = 8,
                 min_capacity: int = 8, normalize: bool = True,
                 crash_hook: Callable[[str], None] | None = None,
                 tracer=None):
        self.path = path
        self.num_vocab = int(num_vocab)
        self.nnz_align = int(nnz_align)
        self.min_capacity = max(int(min_capacity), 1)
        self.normalize = bool(normalize)
        self._hook = crash_hook or _no_hook
        if tracer is None:
            from repro.obs.trace import NULL_TRACER
            tracer = NULL_TRACER
        self._tracer = tracer
        self._lock = threading.RLock()
        # compactions serialize among themselves on a separate lock so the
        # corpus lock is held only for the begin capture and the final swap
        # -- never across the O(docs) rebuild or the snapshot fsyncs
        self._compact_lock = threading.Lock()
        self._compacting = False
        self._pending: list[dict] = []
        self._metrics = None
        self._lock_hold = None
        self.version = 0
        self.base_version = 0

        os.makedirs(path, exist_ok=True)
        gens = [int(d.split("_")[1]) for d in os.listdir(path)
                if d.startswith("snapshot_") and not d.endswith(".tmp")]
        self.gen = max(gens) if gens else 0
        snap_docs: list = []
        if gens:
            snap_docs = self._read_snapshot(self.gen)
        self._docs: dict[int, Doc] = {
            int(i): [(int(w), float(c)) for w, c in d] for i, d in snap_docs}
        self._install_base()
        # replay EVERY surviving WAL generation ascending, not only the
        # snapshot's own (missing file = empty log; a torn tail is
        # truncated so the reopened writer extends a verified log). A
        # compaction that crashed between the snapshot rename and the
        # pending re-log leaves records acked during its build phase only
        # in the PREVIOUS generation's log; replay is idempotent -- a
        # doc's final state is its last op, so re-applying records the
        # snapshot already folded in changes nothing.
        wal_gens = sorted(
            int(n.split("_")[1].split(".")[0]) for n in os.listdir(path)
            if n.startswith("wal_"))
        for g in wal_gens:
            for rec in wal_mod.replay(self._wal_path(g)):
                if rec["op"] == "add":
                    self._apply_add(rec["ids"], rec["docs"])
                elif rec["op"] == "remove":
                    self._apply_remove(rec["ids"])
        self._wal = wal_mod.WalWriter(self._wal_path(self.gen),
                                      hook=self._hook, tracer=self._tracer)

    # -- observability -----------------------------------------------------
    # compaction/WAL boundaries are emitted to an optional repro.obs tracer
    # alongside (and strictly BEFORE) the test-only crash hook, so even an
    # injected-crash run leaves the boundary it died at in the event log.
    # The tracer is late-bindable: `lc.tracer = t` after construction also
    # rebinds the open WAL writer.

    @property
    def tracer(self):
        return self._tracer

    @tracer.setter
    def tracer(self, t) -> None:
        self._tracer = t
        wal = getattr(self, "_wal", None)
        if wal is not None:
            wal.tracer = t

    @property
    def metrics(self):
        return self._metrics

    @metrics.setter
    def metrics(self, registry) -> None:
        """Late-bindable `repro.obs` MetricsRegistry; wiring one arms the
        ``wmd_compact_lock_hold_seconds`` histogram, the observable proof
        that compaction's corpus-lock holds stay O(swap), not O(rebuild)."""
        self._metrics = registry
        self._lock_hold = None if registry is None else registry.histogram(
            "wmd_compact_lock_hold_seconds",
            "corpus-lock hold time of each compaction locked phase")

    def _observe_hold(self, t0: float) -> None:
        if self._lock_hold is not None:
            self._lock_hold.observe(time.perf_counter() - t0)

    def _boundary(self, name: str, **fields) -> None:
        if self._tracer.enabled:
            self._tracer.event(name, gen=self.gen, **fields)
        self._hook(name)

    # -- paths / snapshot io ----------------------------------------------

    def _wal_path(self, gen: int) -> str:
        return os.path.join(self.path, f"wal_{gen:08d}.log")

    def _snap_dir(self, gen: int) -> str:
        return os.path.join(self.path, f"snapshot_{gen:08d}")

    def _read_snapshot(self, gen: int) -> list:
        snap = self._snap_dir(gen)
        with open(os.path.join(snap, "meta.json")) as f:
            meta = json.load(f)
        if meta["num_vocab"] != self.num_vocab:
            raise ValueError(f"snapshot vocab {meta['num_vocab']} != "
                             f"corpus vocab {self.num_vocab}")
        with open(os.path.join(snap, "docs.msgpack"), "rb") as f:
            blob = f.read()
        digest = hashlib.sha256(blob).hexdigest()
        if digest != meta["sha256"]:
            raise RuntimeError(
                f"snapshot generation {gen} failed its checksum "
                f"({digest[:12]} != {meta['sha256'][:12]}) -- the rename "
                "was atomic, so this is disk corruption, not a crash")
        return msgpack.unpackb(blob, raw=False)

    def _write_snapshot(self, gen: int, ids: list[int],
                        docs: list[Doc]) -> None:
        """Atomic snapshot write: tmp dir -> fsync files -> rename -> fsync
        parent (the checkpointer's pattern, plus directory durability)."""
        final = self._snap_dir(gen)
        tmp = final + ".tmp"
        if os.path.exists(tmp):      # a previously killed compaction's
            shutil.rmtree(tmp)       # leftovers must not leak into this one
        os.makedirs(tmp)
        blob = msgpack.packb(
            [[i, [[w, c] for w, c in d] or []] for i, d in zip(ids, docs)],
            use_bin_type=True)
        with open(os.path.join(tmp, "docs.msgpack"), "wb") as f:
            f.write(blob)
            f.flush()
            os.fsync(f.fileno())
        meta = {"gen": gen, "num_vocab": self.num_vocab,
                "num_docs": len(ids), "normalize": self.normalize,
                "nnz_align": self.nnz_align,
                "sha256": hashlib.sha256(blob).hexdigest()}
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump(meta, f)
            f.flush()
            os.fsync(f.fileno())
        self._boundary("compact.snapshot.tmp")
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        dirfd = os.open(self.path, os.O_RDONLY)
        try:
            os.fsync(dirfd)          # make the rename itself durable
        finally:
            os.close(dirfd)

    # -- segment construction ---------------------------------------------

    def _segment_ell(self, docs: Sequence[Doc]) -> formats.EllDocs:
        """Capacity-padded ELL of ``docs`` -- the EXACT `ell_from_doc_lists`
        arithmetic a one-shot build runs, then pow2 row slack."""
        ell = formats.ell_from_doc_lists(docs, self.num_vocab,
                                         nnz_align=self.nnz_align,
                                         normalize=self.normalize)
        cap = formats.next_pow2(max(ell.num_docs, self.min_capacity))
        return formats.ell_with_capacity(ell, cap)

    def _install_base(self) -> None:
        """(Re)build the base segment from the current live docs (ascending
        id) and reset the delta to empty minimum capacity."""
        ids = sorted(self._docs)
        self._base_ell = self._segment_ell([self._docs[i] for i in ids])
        self._where: dict[int, tuple[int, int]] = {
            i: (_BASE, row) for row, i in enumerate(ids)}
        nnz = formats._round_up(1, self.nnz_align)
        self._dcols = np.full((self.min_capacity, nnz), self.num_vocab,
                              np.int32)
        self._dvals = np.zeros((self.min_capacity, nnz), np.float32)
        self._dlen = 0
        self.base_version += 1
        self.version += 1

    def _grow_delta(self, need_nnz: int) -> None:
        rows, nnz = self._dcols.shape
        new_rows = rows if self._dlen < rows else \
            formats.next_pow2(max(rows * 2, self.min_capacity))
        new_nnz = nnz if need_nnz <= nnz else \
            formats._round_up(need_nnz, self.nnz_align)
        cols = np.full((new_rows, new_nnz), self.num_vocab, np.int32)
        vals = np.zeros((new_rows, new_nnz), np.float32)
        cols[:rows, :nnz] = self._dcols
        vals[:rows, :nnz] = self._dvals
        self._dcols, self._dvals = cols, vals

    def _tombstone(self, doc_id: int) -> bool:
        loc = self._where.pop(doc_id, None)
        if loc is None:
            return False
        seg, row = loc
        if seg == _DELTA:
            # clear the dead delta row to padding: pad-slot inertness makes
            # it contribute exactly 0 until compaction reclaims it (base
            # rows are left stale -- the result gather never reads them)
            self._dcols[row, :] = self.num_vocab
            self._dvals[row, :] = 0.0
        self._docs.pop(doc_id, None)
        return True

    # -- mutation application (shared by live ops and WAL replay) ---------

    def _apply_add(self, ids, docs) -> None:
        for i, doc in zip(ids, docs):
            i = int(i)
            doc = [(int(w), float(c)) for w, c in doc]
            self._tombstone(i)                        # upsert semantics
            if len(doc) > self._dcols.shape[1] \
                    or self._dlen >= self._dcols.shape[0]:
                self._grow_delta(len(doc))
            row = self._dlen
            self._dlen += 1
            formats.write_doc_row(self._dcols, self._dvals, row, doc,
                                  self.num_vocab, normalize=self.normalize)
            self._where[i] = (_DELTA, row)
            self._docs[i] = doc
        self.version += 1

    def _apply_remove(self, ids) -> int:
        removed = sum(self._tombstone(int(i)) for i in ids)
        self.version += 1
        return removed

    # -- public mutation API ----------------------------------------------

    def add_docs(self, ids: Sequence[int],
                 docs: Sequence[Sequence[tuple[int, float]]]) -> int:
        """Durable upsert: WAL-append + fsync, THEN apply. Returns the
        number of docs acked (all of them -- a raised exception acks
        nothing the WAL didn't already make recoverable).

        Upsert semantics: an id already live is replaced (its old copy is
        tombstoned); duplicate ids within one call resolve last-wins.
        Empty docs are legal (they solve to distance 0, exactly as in a
        one-shot build). Validation happens BEFORE the WAL append so a
        rejected call leaves neither log nor state behind."""
        if len(ids) != len(docs):
            raise ValueError(f"{len(ids)} ids but {len(docs)} docs")
        ids_c = [int(i) for i in ids]
        docs_c = []
        for d in docs:
            doc = [(int(w), float(c)) for w, c in d]
            for w, c in doc:
                if not 0 <= w < self.num_vocab:
                    raise ValueError(f"word id {w} outside vocab "
                                     f"[0, {self.num_vocab})")
                if not np.isfinite(c) or c < 0:
                    raise ValueError(f"bad count {c} for word {w}")
            docs_c.append(doc)
        rec = {"op": "add", "ids": ids_c,
               "docs": [[[w, c] for w, c in d] for d in docs_c]}
        with self._lock:
            self._wal.append(rec)
            # the append returned => fsynced => acked-and-recoverable
            self._apply_add(ids_c, docs_c)
            if self._compacting:     # re-logged into the next generation's
                self._pending.append(rec)    # WAL at swap (see compact())
            return len(ids_c)

    def remove_docs(self, ids: Sequence[int]) -> int:
        """Durable remove; returns how many ids were actually live.
        Removing a never-added id is a durable no-op (logged, replayed,
        still a no-op) -- idempotence keeps WAL replay trivially safe."""
        ids_c = [int(i) for i in ids]
        rec = {"op": "remove", "ids": ids_c}
        with self._lock:
            self._wal.append(rec)
            removed = self._apply_remove(ids_c)
            if self._compacting:
                self._pending.append(rec)
            return removed

    def compact(self) -> None:
        """Merge the delta into a fresh rebuilt base: an interruptible job
        with an atomic segment swap (see the module docstring). Safe to
        call from a background thread; killed anywhere, the old segments
        stay live and a retry is idempotent.

        The corpus lock is held only for two short windows -- capturing
        the doc set at ``compact.begin`` and the WAL-rotation + in-memory
        swap at the end -- NOT across the O(docs) segment rebuild or the
        snapshot write/fsync between them. Readers and writers proceed
        against the old segments throughout the build; writes landing
        then are applied normally (and WAL-acked in the old generation)
        and additionally buffered, then at swap time re-logged fsynced
        into the new generation's WAL *before* the generation bump and
        re-applied onto the rebuilt base -- exactly the state recovery
        would produce from snapshot + logs. Until a buffered record lands
        in the new log it remains covered by the old one (recovery
        replays every surviving WAL generation ascending), so no
        acknowledged write is ever orphaned by a crash mid-swap.
        Compactions serialize among themselves on ``_compact_lock``."""
        with self._compact_lock:
            with self._lock:
                t0 = time.perf_counter()
                self._boundary("compact.begin", docs=len(self._docs))
                ids = sorted(self._docs)
                docs = [list(self._docs[i]) for i in ids]
                self._compacting = True
                self._pending = []
            self._observe_hold(t0)
            try:
                self._boundary("compact.built")
                new_gen = self.gen + 1
                self._write_snapshot(new_gen, ids, docs)
                # the rename landed: generation new_gen is durable.
                # Everything below is WAL rotation + in-memory swap; a
                # crash anywhere here recovers to new_gen plus every
                # surviving log -- the same logical corpus.
                with self._lock:
                    t0 = time.perf_counter()
                    self._boundary("compact.renamed")
                    pending = self._pending
                    old_wal = self._wal
                    self._wal = wal_mod.WalWriter(self._wal_path(new_gen),
                                                  hook=self._hook,
                                                  tracer=self._tracer)
                    for rec in pending:      # re-log build-window writes
                        self._wal.append(rec)
                    old_wal.close()
                    self.gen = new_gen
                    # rebuild exactly what recovery would produce: base =
                    # the snapshot's docs, delta = the re-applied pending
                    self._docs = {int(i): list(d)
                                  for i, d in zip(ids, docs)}
                    self._install_base()
                    for rec in pending:
                        if rec["op"] == "add":
                            self._apply_add(rec["ids"], rec["docs"])
                        else:
                            self._apply_remove(rec["ids"])
                    self._boundary("compact.done")
                self._observe_hold(t0)
            finally:
                with self._lock:
                    self._compacting = False
                    self._pending = []
            self._gc(keep_gen=self.gen)

    def _gc(self, keep_gen: int) -> None:
        for name in os.listdir(self.path):
            full = os.path.join(self.path, name)
            try:
                if name.endswith(".tmp"):
                    shutil.rmtree(full, ignore_errors=True)
                elif name.startswith("snapshot_"):
                    if int(name.split("_")[1]) < keep_gen:
                        shutil.rmtree(full, ignore_errors=True)
                elif name.startswith("wal_"):
                    if int(name.split("_")[1].split(".")[0]) < keep_gen:
                        os.remove(full)
            except (ValueError, OSError):
                continue             # foreign / already-gone files: skip

    def close(self) -> None:
        with self._lock:
            self._wal.close()

    def __enter__(self) -> "LiveCorpus":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- read API (what the service's refresh consumes) -------------------

    @property
    def num_live(self) -> int:
        return len(self._docs)

    @property
    def base_ell(self) -> formats.EllDocs:
        return self._base_ell

    @property
    def delta_ell(self) -> formats.EllDocs:
        """Copy of the delta segment as an EllDocs (copied so the device
        refresh can never alias a row a concurrent writer rewrites)."""
        with self._lock:
            return formats.EllDocs(cols=self._dcols.copy(),
                                   vals=self._dvals.copy(),
                                   num_vocab=self.num_vocab)

    def live_ids(self) -> np.ndarray:
        with self._lock:
            return np.array(sorted(self._docs), np.int64)

    def locations(self) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(ids, segment, row) aligned arrays in ascending doc-id order --
        the result-gather map: live column j of a query answer is
        ``d_segment[segment[j]][:, row[j]]``."""
        with self._lock:
            ids = sorted(self._docs)
            seg = np.array([self._where[i][0] for i in ids], np.int8)
            row = np.array([self._where[i][1] for i in ids], np.int64)
            return np.array(ids, np.int64), seg, row

    def live_empty_mask(self) -> np.ndarray:
        """Per live doc (ascending id): is it legitimately massless (empty
        or all-zero counts)? Such docs solve to exact distance 0, which the
        numeric guards must not mistake for lambda underflow."""
        with self._lock:
            return np.array([sum(c for _, c in self._docs[i]) == 0
                             for i in sorted(self._docs)], bool)

    def live_docs(self) -> list[tuple[int, Doc]]:
        """(id, raw doc) pairs ascending -- what a one-shot rebuild (and
        the incremental == batch tests) consume."""
        with self._lock:
            return [(i, list(self._docs[i])) for i in sorted(self._docs)]

    def stats(self) -> dict:
        with self._lock:
            wal_path = self._wal_path(self.gen)
            return {"gen": self.gen, "num_live": self.num_live,
                    "base_rows": self._base_ell.num_docs,
                    "delta_rows": self._dlen,
                    "delta_capacity": int(self._dcols.shape[0]),
                    "delta_nnz_max": int(self._dcols.shape[1]),
                    "version": self.version,
                    "base_version": self.base_version,
                    "compacting": self._compacting,
                    "wal_bytes": (os.path.getsize(wal_path)
                                  if os.path.exists(wal_path) else 0)}
