"""Synthetic dbpedia-like corpus generator for the Sinkhorn-WMD workload.

The paper's dataset is private-ish (kaggle mirrors of crawl-300d-2M +
dbpedia.train); this generator reproduces its *statistics* deterministically:
  * vocab V = 100k, embedding width w = 300 (f32),
  * doc lengths ~ lognormal matched to nnz/doc ~ 35 median (so that 5000 docs
    give nnz ~ 173k, density ~0.0035% -- the paper's numbers),
  * word ids ~ Zipf (s ~ 1.07), frequencies normalized per doc,
  * query docs with v_r ~ 19 words (the paper's running example).

Embeddings are unit-ish gaussian scaled so pairwise distances land in the
1-10 range of real word2vec clouds (keeps exp(-lambda*M) in f32 range at the
paper's lambda).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.formats import EllDocs, ell_from_doc_lists


@dataclasses.dataclass(frozen=True)
class WMDData:
    vecs: np.ndarray          # (V, w) f32
    ell: EllDocs              # target docs
    queries: list[np.ndarray]  # list of (V,) sparse frequency vectors
    nnz: int


def zipf_ids(rng: np.random.Generator, n: int, vocab: int,
             s: float = 1.07) -> np.ndarray:
    """Zipf-distributed distinct word ids."""
    # rejection-free: sample with replacement then dedup, top up as needed
    ids: set[int] = set()
    while len(ids) < n:
        draw = rng.zipf(s, size=2 * n)
        ids.update(int(x) - 1 for x in draw if x <= vocab)
    return np.fromiter(list(ids)[:n], dtype=np.int64)


def zipf_query_stream(*, vocab_size: int, query_words: int = 19,
                      s: float = 1.07, seed: int = 0):
    """Infinite seeded generator of Zipf-skewed (V,) query histograms.

    The realistic serving workload in one line: successive queries draw
    their word ids from the same Zipf(s) head, so most ids repeat across
    queries -- exactly the redundancy the cross-query K cache
    (`core.kcache`) exploits. Shared by `benchmarks/bench_query_batch.py
    --zipf` and the cache tests; take Q-sized batches with
    ``[next(stream) for _ in range(q)]`` (or itertools.islice).

    Args:
      vocab_size:  V (ids above it are rejected, as in `zipf_ids`).
      query_words: distinct nonzero words per query (the paper's v_r ~ 19).
      s:           Zipf exponent; larger = heavier head = higher hit rates.
      seed:        stream is fully determined by (seed, s, query_words, V).
    """
    rng = np.random.default_rng(seed)
    while True:
        r = np.zeros(vocab_size, np.float32)
        ids = zipf_ids(rng, query_words, vocab_size, s=s)
        freq = rng.integers(1, 4, size=query_words).astype(np.float32)
        r[ids] = freq / freq.sum()
        yield r


def make_corpus(*, vocab_size: int = 100_000, embed_dim: int = 300,
                num_docs: int = 5_000, num_queries: int = 10,
                mean_words: float = 35.0, query_words: int = 19,
                nnz_align: int = 8, seed: int = 0) -> WMDData:
    rng = np.random.default_rng(seed)
    vecs = rng.normal(scale=1.3, size=(vocab_size, embed_dim)) \
        .astype(np.float32)

    docs = []
    total_nnz = 0
    sigma = 0.55
    mu = np.log(mean_words) - sigma ** 2 / 2
    for _ in range(num_docs):
        n_words = int(np.clip(rng.lognormal(mu, sigma), 3, 4 * mean_words))
        ids = zipf_ids(rng, n_words, vocab_size)
        counts = rng.integers(1, 4, size=n_words).astype(np.float64)
        docs.append(list(zip(ids.tolist(), counts.tolist())))
        total_nnz += n_words
    ell = ell_from_doc_lists(docs, vocab_size, nnz_align=nnz_align)

    queries = []
    for _ in range(num_queries):
        r = np.zeros(vocab_size, np.float32)
        ids = zipf_ids(rng, query_words, vocab_size)
        freq = rng.integers(1, 4, size=query_words).astype(np.float32)
        r[ids] = freq / freq.sum()
        queries.append(r)
    return WMDData(vecs=vecs, ell=ell, queries=queries, nnz=total_nnz)
