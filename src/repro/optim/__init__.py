"""Optimizers and distributed-optimization helpers."""
from repro.optim.adamw import AdamW, AdamWState, adamw, global_norm
from repro.optim.schedules import constant, warmup_cosine
from repro.optim.compression import (CompressionState, compress_grads,
                                     init_state as init_compression_state)

__all__ = ["AdamW", "AdamWState", "adamw", "global_norm", "constant",
           "warmup_cosine", "CompressionState", "compress_grads",
           "init_compression_state"]
