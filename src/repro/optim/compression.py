"""Gradient compression for the cross-pod all-reduce (int8 + error feedback).

At multi-pod scale the pod axis is pure DP (partitioning.py), so the slowest
collective is the cross-pod gradient all-reduce over the optical links. This
module provides stochastic-free int8 block quantization with **error
feedback** (the residual is carried to the next step, which keeps SGD/Adam
convergence -- Karimireddy et al. 2019): the jit path wraps gradient leaves
as quantize -> (all-reduce happens on the int8 view under GSPMD when the
custom collective is wired) -> dequantize + residual.

On this CPU container the collective itself is GSPMD-inserted and the
quantize/dequantize pair simulates the numerics end-to-end; the bytes saving
(4x vs f32) is accounted in the roofline's collective term when
``--grad-compression`` is set on the launcher.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

BLOCK = 256


class CompressionState(NamedTuple):
    residual: Any   # error-feedback residuals, same pytree as grads


def init_state(grads_like: Any) -> CompressionState:
    return CompressionState(residual=jax.tree.map(
        lambda g: jnp.zeros_like(g, dtype=jnp.float32), grads_like))


def _quantize_leaf(g: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Blockwise symmetric int8: returns (q int8, scale f32 per block)."""
    flat = g.astype(jnp.float32).reshape(-1)
    pad = (-flat.size) % BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    q = jnp.clip(jnp.round(blocks / jnp.maximum(scale, 1e-12)),
                 -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize_leaf(q: jax.Array, scale: jax.Array, shape,
                     size: int) -> jax.Array:
    flat = (q.astype(jnp.float32) * scale).reshape(-1)[:size]
    return flat.reshape(shape)


def compress_grads(grads: Any, state: CompressionState
                   ) -> tuple[Any, CompressionState]:
    """int8 round-trip with error feedback. Returns (grads', new state)."""
    def leaf(g, r):
        gf = g.astype(jnp.float32) + r
        q, s = _quantize_leaf(gf)
        deq = _dequantize_leaf(q, s, gf.shape, gf.size)
        return deq.astype(g.dtype), gf - deq

    out = jax.tree.map(leaf, grads, state.residual)
    is_pair = lambda t: isinstance(t, tuple)
    new_grads = jax.tree.map(lambda t: t[0], out, is_leaf=is_pair)
    new_resid = jax.tree.map(lambda t: t[1], out, is_leaf=is_pair)
    return new_grads, CompressionState(residual=new_resid)
