"""AdamW with decoupled weight decay + global-norm clipping (pure pytree).

No optax dependency (not installed offline); the state is a plain pytree so
the FSDP sharding rules (`distributed.partitioning`) apply verbatim to the
moments (same shapes as params).
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    mu: Any       # first moment, same pytree as params
    nu: Any       # second moment


class AdamW(NamedTuple):
    init: Callable[[Any], AdamWState]
    update: Callable[..., tuple[Any, AdamWState]]


def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def adamw(lr: float | Callable[[jax.Array], jax.Array], *,
          b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.1, clip_norm: float = 1.0) -> AdamW:
    lr_fn = lr if callable(lr) else (lambda _: jnp.asarray(lr, jnp.float32))

    def init(params: Any) -> AdamWState:
        zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
        return AdamWState(step=jnp.zeros((), jnp.int32),
                          mu=jax.tree.map(zeros, params),
                          nu=jax.tree.map(zeros, params))

    def update(grads: Any, state: AdamWState, params: Any
               ) -> tuple[Any, AdamWState]:
        step = state.step + 1
        gnorm = global_norm(grads)
        scale = jnp.minimum(1.0, clip_norm / jnp.maximum(gnorm, 1e-9))
        lr_t = lr_fn(step)
        c1 = 1.0 - b1 ** step.astype(jnp.float32)
        c2 = 1.0 - b2 ** step.astype(jnp.float32)

        def upd(g, m, v, p):
            g = g.astype(jnp.float32) * scale
            m = b1 * m + (1.0 - b1) * g
            v = b2 * v + (1.0 - b2) * g * g
            mh = m / c1
            vh = v / c2
            step_val = mh / (jnp.sqrt(vh) + eps) \
                + weight_decay * p.astype(jnp.float32)
            return (p - lr_t * step_val.astype(p.dtype)).astype(p.dtype), m, v

        out = jax.tree.map(upd, grads, state.mu, state.nu, params)
        new_params = jax.tree.map(lambda t: t[0], out,
                                  is_leaf=lambda t: isinstance(t, tuple))
        new_mu = jax.tree.map(lambda t: t[1], out,
                              is_leaf=lambda t: isinstance(t, tuple))
        new_nu = jax.tree.map(lambda t: t[2], out,
                              is_leaf=lambda t: isinstance(t, tuple))
        return new_params, AdamWState(step=step, mu=new_mu, nu=new_nu)

    return AdamW(init=init, update=update)
