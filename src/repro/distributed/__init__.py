"""Distribution substrate: partitioning rules, fault tolerance, elasticity."""
from repro.distributed import elastic, fault_tolerance, partitioning

__all__ = ["elastic", "fault_tolerance", "partitioning"]
