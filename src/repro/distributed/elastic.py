"""Elastic mesh derivation: pick a (pod, data, model) factoring for whatever
device count survives. Configs use named axes only, so any factoring works;
checkpoint restore re-shards (checkpointer.restore with new shardings)."""
from __future__ import annotations

import jax


def remesh(num_devices: int, *, model_parallelism: int = 16,
           pod_size: int = 256):
    """Largest usable mesh for ``num_devices``:
    pods = devices // pod_size (multi-pod if >= 2), model = requested TP
    (reduced to the largest divisor that fits), data = the rest. Drops
    remainder devices (they become hot spares)."""
    model = model_parallelism
    while model > 1 and num_devices % model:
        model //= 2
    usable = num_devices - (num_devices % model)
    chips = usable
    pods = max(chips // pod_size, 1) if chips >= 2 * pod_size else 1
    while pods > 1 and (chips % pods or (chips // pods) % model):
        pods -= 1
    data = chips // (pods * model)
    shape = (pods, data, model) if pods > 1 else (data, model)
    names = ("pod", "data", "model") if pods > 1 else ("data", "model")
    devices = jax.devices()[:pods * data * model]
    import numpy as np
    arr = np.array(devices).reshape(shape)
    return jax.sharding.Mesh(arr, names)
