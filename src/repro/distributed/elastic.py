"""Elastic mesh derivation: pick a (pod, data, model) factoring for whatever
device count survives. Configs use named axes only, so any factoring works;
checkpoint restore re-shards (checkpointer.restore with new shardings).

`mesh_shape` is the pure factoring rule (unit-testable without devices,
tests/test_elastic.py); `remesh` materializes it over `jax.devices()`.
"""
from __future__ import annotations

import jax
import numpy as np


def mesh_shape(num_devices: int, *, model_parallelism: int = 16,
               pod_size: int = 256) -> tuple[tuple[int, ...],
                                             tuple[str, ...]]:
    """The factoring rule of `remesh`, device-free: (shape, axis names).

    pods = devices // pod_size (multi-pod if >= 2), model = requested TP
    halved until it divides the device count, data = the rest. Remainder
    devices are dropped (hot spares). ``num_devices`` must
    be >= 1; a non-positive ``model_parallelism`` is clamped to 1 (no
    tensor parallelism) instead of dividing by zero."""
    if num_devices < 1:
        raise ValueError(
            f"cannot mesh {num_devices} devices (need at least 1)")
    model = max(int(model_parallelism), 1)
    while model > 1 and num_devices % model:
        model //= 2
    usable = num_devices - (num_devices % model)
    chips = usable
    pods = max(chips // pod_size, 1) if chips >= 2 * pod_size else 1
    while pods > 1 and (chips % pods or (chips // pods) % model):
        pods -= 1
    data = chips // (pods * model)
    if pods > 1:
        return (pods, data, model), ("pod", "data", "model")
    return (data, model), ("data", "model")


def remesh(num_devices: int, *, model_parallelism: int = 16,
           pod_size: int = 256):
    """Largest usable mesh for ``num_devices`` (see `mesh_shape` for the
    factoring rule) over the process's actual devices."""
    shape, names = mesh_shape(num_devices,
                              model_parallelism=model_parallelism,
                              pod_size=pod_size)
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()[:n]
    arr = np.array(devices).reshape(shape)
    return jax.sharding.Mesh(arr, names)
