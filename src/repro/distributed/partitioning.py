"""Parameter / activation sharding rules (GSPMD, named-axis only).

Scheme (DESIGN.md section 4.2):
  * ``model`` axis: tensor parallelism -- attention heads, FFN hidden, MoE
    expert hidden, vocab dim of the embedding table.
  * ``data`` axis: FSDP -- the non-TP axis of every large matrix is sharded
    over data too (params + AdamW moments), which is what fits mixtral-8x22b
    (141B x 12B/param of train state) on a 256-chip pod.
  * ``pod`` axis: pure DP across pods -- params are NOT sharded over pod, so
    the only cross-pod traffic is the gradient all-reduce (hierarchical
    FSDP-in-pod / DP-across-pod, the standard multi-pod layout; int8
    compression hooks in optim.compression).

Rules are by leaf *name* and rank; scanned-unit stacking (extra leading axes)
is handled by left-padding the spec with None. Everything is expressed with
named axes only, so any (pod, data, model) mesh factoring works (elastic
re-shard on restore).
"""
from __future__ import annotations

from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# leaf-name -> base spec (by decreasing specificity)
_RULES: dict[str, P] = {
    # embeddings
    "embed": P("model", "data"),          # (V, D): vocab TP + d FSDP
    "unembed": P("data", "model"),        # (D, V)
    "pos": P(None, "data"),
    "enc_pos": P(None, "data"),
    "frame_adapter": P("data", "model"),
    # attention
    "wq": P("data", "model"),
    "wk": P("data", "model"),
    "wv": P("data", "model"),
    "wo": P("model", "data"),
    # mla
    "wq_down": P("data", None),
    "wq_up": P(None, "model"),
    "wkv_down": P("data", None),
    "wkv_up": P(None, "model"),
    # mlp
    "wi_gate": P("data", "model"),
    "wi_up": P("data", "model"),
    "wi": P("data", "model"),
    "bi": P("model"),
    "bo": P("data"),
    # moe (3D expert weights get the extra expert axis unsharded)
    "router": P("data", None),
    # rglru / xlstm
    "w_gate": P("data", "model"),
    "w_in": P("data", "model"),
    "w_up": P("data", "model"),
    "w_a": P("model", "data"),
    "w_x": P("model", "data"),
    "w_out": P("model", "data"),
    "w_down": P("model", "data"),
    "w_if": P("data", None),
    "w": P("data", "model"),
    "conv_w": P(None, "model"),
}

# MoE expert tensors are 3D -- matched by name with explicit 3D specs
_RULES_3D: dict[str, P] = {
    "wi_gate": P(None, "data", "model"),
    "wi_up": P(None, "data", "model"),
    "wo": P(None, "model", "data"),
}


def _leaf_name(path) -> str:
    for entry in reversed(path):
        if isinstance(entry, jax.tree_util.DictKey):
            return str(entry.key)
        if isinstance(entry, jax.tree_util.GetAttrKey):
            return str(entry.name)
    return ""


def spec_for(path, leaf) -> P:
    name = _leaf_name(path)
    ndim = getattr(leaf, "ndim", 0)
    base = None
    if ndim >= 3 and name in _RULES_3D:
        base = _RULES_3D[name]
    elif name in _RULES:
        base = _RULES[name]
    if base is None:
        return P(*([None] * ndim))
    pad = ndim - len(base)
    if pad < 0:  # rank-reduced leaf (e.g. biases sharing a rule name)
        return P(*([None] * ndim))
    return P(*([None] * pad), *base)


def sanitize_spec(mesh: Mesh, spec: P, shape) -> P:
    """Drop axis assignments whose dimension is not evenly divisible.

    Explicit jit in/out shardings require even divisibility (unlike
    with_sharding_constraint); any dim that does not divide by its mesh-axis
    product falls back to replication on that dim -- e.g. minicpm3's vocab
    73448 over model=16, mixtral's 8 kv heads over 16 chips, or long_500k's
    batch=1 over (pod, data).
    """
    out = []
    for i, entry in enumerate(spec):
        if entry is None or i >= len(shape):
            out.append(None if i >= len(shape) else entry)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        factor = 1
        for a in axes:
            factor *= mesh.shape[a]
        out.append(entry if shape[i] % factor == 0 else None)
    # pad missing trailing dims
    out += [None] * (len(shape) - len(out))
    return P(*out)


def param_specs(params: Any) -> Any:
    """Pytree of PartitionSpecs mirroring ``params``."""
    return jax.tree_util.tree_map_with_path(spec_for, params)


def param_shardings(mesh: Mesh, params: Any) -> Any:
    return jax.tree.map(
        lambda s, leaf: NamedSharding(
            mesh, sanitize_spec(mesh, s, getattr(leaf, "shape", ()))),
        param_specs(params), params)


def batch_axes(mesh: Mesh) -> tuple[str, ...]:
    """Mesh axes that shard the global batch (pod first if present)."""
    names = mesh.axis_names
    return tuple(a for a in ("pod", "data") if a in names)


def batch_spec(mesh: Mesh, ndim: int) -> P:
    """Batch tensors: leading axis over (pod, data), rest replicated."""
    return P(batch_axes(mesh), *([None] * (ndim - 1)))


def batch_shardings(mesh: Mesh, batch: Any) -> Any:
    return jax.tree.map(
        lambda x: NamedSharding(
            mesh, sanitize_spec(mesh, batch_spec(mesh, x.ndim), x.shape)),
        batch)


def cache_shardings(mesh: Mesh, cache: Any) -> Any:
    """Decode-cache shardings, type-aware.

    * attention KVCache k/v (B, S, KV, hd): batch over (pod, data), KV heads
      over ``model`` (GSPMD ceil-shards when KV < model size -- e.g.
      starcoder2's 2 kv heads over 16 chips leaves 14 chips padding that
      head axis, which is still 16x less memory than replication). MQA
      (KV == 1) caches replicate over model (nothing to shard).
    * MLA latent caches (shared across heads): batch only -- the latent is
      MLA's point and cannot shard by head. (Sequence-sharded attention for
      these is the decode hillclimb; see EXPERIMENTS.md §Perf.)
    * recurrent states (RG-LRU / xLSTM): batch over (pod, data); mLSTM's
      (B, H, hd, hd) matrix state also shards heads over ``model``.
    * scalars (pos, m) and tiny leaves: replicated.

    Works on pytrees of ShapeDtypeStructs (eval_shape output) because the
    NamedTuple containers are preserved -- dispatch is isinstance-based.
    """
    from repro.models.layers.attention import KVCache
    from repro.models.layers.mla import MLACache
    from repro.models.layers.rglru import RGLRUState
    from repro.models.layers.xlstm import MLSTMState, SLSTMState

    axes = batch_axes(mesh)
    model_size = mesh.shape.get("model", 1)

    def pad(spec_tail, leaf, base_ndim):
        """Left-pad with None for stacked (scanned-unit) leading axes, then
        sanitize against the leaf's actual shape."""
        extra = getattr(leaf, "ndim", 0) - base_ndim
        spec = P(*([None] * extra), *spec_tail)
        return NamedSharding(
            mesh, sanitize_spec(mesh, spec, getattr(leaf, "shape", ())))

    ns = pad  # alias for readability below

    def walk(node):
        if isinstance(node, KVCache):
            kv_heads = node.k.shape[-2]
            buf = node.k.shape[-3]
            if kv_heads % model_size == 0:
                # TP over kv heads (olmo, deepseek)
                kv_spec = (axes, None, "model", None)
            elif buf % model_size == 0:
                # sequence-sharded cache (mixtral kv=8, starcoder2 kv=2,
                # MQA): decode attention becomes flash-decode style, GSPMD
                # inserts the partial-softmax collectives
                kv_spec = (axes, "model", None, None)
            else:
                kv_spec = (axes, None, None, None)
            return KVCache(k=pad(kv_spec, node.k, 4),
                           v=pad(kv_spec, node.v, 4),
                           pos=pad((), node.pos, 0))
        if isinstance(node, MLACache):
            # the latent is shared across heads (cannot head-shard); shard
            # the sequence dim over model when divisible
            seq = node.c_kv.shape[-2]
            sspec = "model" if seq % model_size == 0 else None
            return MLACache(c_kv=pad((axes, sspec, None), node.c_kv, 3),
                            k_rope=pad((axes, sspec, None), node.k_rope, 3),
                            pos=pad((), node.pos, 0))
        if isinstance(node, RGLRUState):
            return RGLRUState(h=pad((axes, "model"), node.h, 2),
                              conv=pad((axes, None, "model"), node.conv, 3),
                              pos=pad((), node.pos, 0))
        if isinstance(node, MLSTMState):
            # heads rarely divide the model axis; shard the head_dim rows of
            # the matrix state instead (sanitizer drops whatever won't fit)
            h = node.c.shape[-3]
            hspec = "model" if h % model_size == 0 else None
            dspec = "model" if hspec is None else None
            return MLSTMState(c=pad((axes, hspec, dspec, None), node.c, 4),
                              n=pad((axes, hspec, dspec), node.n, 3),
                              m=pad((axes, None), node.m, 2),
                              conv=pad((axes, None, None), node.conv, 3),
                              pos=pad((), node.pos, 0))
        if isinstance(node, SLSTMState):
            return SLSTMState(h=pad((axes, "model"), node.h, 2),
                              c=pad((axes, "model"), node.c, 2),
                              n=pad((axes, "model"), node.n, 2),
                              m=pad((axes, "model"), node.m, 2),
                              pos=pad((), node.pos, 0))
        if isinstance(node, dict):
            out = {}
            for k, v in node.items():
                if k in ("cross_k", "cross_v"):   # (L, B, Tenc, KV, hd)
                    out[k] = pad((axes, None, "model", None), v, 4)
                elif k == "pos":
                    out[k] = pad((), v, 0)
                else:
                    out[k] = walk(v)
            return out
        if isinstance(node, (list, tuple)):
            return type(node)(walk(v) for v in node)
        # bare leaf fallback: batch-shard axis 0 if it looks batch-like
        ndim = getattr(node, "ndim", 0)
        return ns(*([None] * ndim))

    return walk(cache)
