"""Launcher-level fault tolerance: heartbeats, failure detection, respawn.

On a real multi-pod deployment each host runs a `HeartbeatMonitor`; the
coordinator applies the policy below. This container is single-host, so the
protocol is exercised by unit tests with simulated clocks/failures -- the
*code path* (detection thresholds, respawn decisions, elastic re-mesh) is
what the tests pin down.

Protocol (DESIGN.md section 7):
  1. every host POSTs a heartbeat (step, timestamp) each train step;
  2. a host silent for ``timeout_s`` is declared dead; the coordinator
     decides: respawn-in-place (transient) vs shrink (hardware loss);
  3. on shrink, `elastic.remesh` picks the largest valid (pod, data, model)
     factoring of the surviving device count, and training resumes from the
     latest checkpoint (checkpointer restores onto the new mesh);
  4. stragglers (> factor x median step time) are respawn candidates after
    ``straggler_strikes`` consecutive slow steps.

`ServingWatchdog` applies the same protocol to the serving loop
(launch/serve.py): each dispatch *kind* ("plain", "top_k") is a virtual
host beating once per dispatch, so dispatcher silence surfaces as a dead
host and per-kind service-time straggler strikes (vs a rolling median of
that kind's own history) fire an ``on_strike`` callback -- wired to
`serving.resilience.EngineGuard.trip`, which force-opens the active
rung's breaker and demotes the engine.
"""
from __future__ import annotations

import collections
import dataclasses
import statistics
import threading
import time
from typing import Callable, Optional


@dataclasses.dataclass
class HostStatus:
    host_id: int
    last_step: int = -1
    last_seen: float = 0.0
    slow_strikes: int = 0
    alive: bool = True


@dataclasses.dataclass
class FaultPolicy:
    timeout_s: float = 60.0
    straggler_factor: float = 2.0
    straggler_strikes: int = 3


class HeartbeatMonitor:
    """Coordinator-side view of the fleet."""

    def __init__(self, num_hosts: int, policy: FaultPolicy = FaultPolicy(),
                 clock: Callable[[], float] = time.monotonic):
        self.policy = policy
        self.clock = clock
        self.hosts = {h: HostStatus(host_id=h, last_seen=clock())
                      for h in range(num_hosts)}
        self.median_step_s: Optional[float] = None

    def heartbeat(self, host_id: int, step: int,
                  step_seconds: Optional[float] = None) -> None:
        st = self.hosts[host_id]
        st.last_step = step
        st.last_seen = self.clock()
        st.alive = True
        if step_seconds is not None and self.median_step_s:
            if step_seconds > self.policy.straggler_factor \
                    * self.median_step_s:
                st.slow_strikes += 1
            else:
                st.slow_strikes = 0

    def set_median_step(self, seconds: float) -> None:
        self.median_step_s = seconds

    def dead_hosts(self) -> list[int]:
        now = self.clock()
        out = []
        for st in self.hosts.values():
            if st.alive and now - st.last_seen > self.policy.timeout_s:
                st.alive = False
                out.append(st.host_id)
        return out

    def respawn_candidates(self) -> list[int]:
        return [st.host_id for st in self.hosts.values()
                if st.alive
                and st.slow_strikes >= self.policy.straggler_strikes]

    def surviving(self) -> int:
        self.dead_hosts()
        return sum(st.alive for st in self.hosts.values())


@dataclasses.dataclass
class _KindTrack:
    """Per-dispatch-kind watchdog state."""
    last_seen: float = 0.0
    dispatches: int = 0
    failures: int = 0
    strikes: int = 0          # consecutive straggler dispatches
    tripped: int = 0          # on_strike firings
    history: collections.deque = dataclasses.field(
        default_factory=lambda: collections.deque(maxlen=64))


class ServingWatchdog:
    """Serving-loop watchdog: dispatcher liveness + straggler strikes.

    Wire ``beat`` as the coalescer's ``heartbeat=`` callback; every
    dispatch reports (kind, wall seconds, ok). A dispatch slower than
    ``policy.straggler_factor`` x the rolling median of its OWN kind's
    recent wall times counts one strike (failed dispatches also strike --
    a rung burning its retry budget is straggling by definition);
    ``policy.straggler_strikes`` consecutive strikes fire ``on_strike``
    (-> `EngineGuard.trip`: force-open the active rung, demote) and reset
    the streak. The median needs ``min_samples`` clean dispatches first,
    so warmup compiles never strike.

    ``check()`` is the liveness poll for the serving loop: kinds silent
    longer than ``policy.timeout_s`` while work is pending (``pending_fn``,
    e.g. ``lambda: co.stats().queue_depth``) are returned as stalled --
    silence with an empty queue is just an idle server.

    Thread-safe; ``clock`` injectable for deterministic tests."""

    def __init__(self, policy: FaultPolicy | None = None, *,
                 on_strike: Optional[Callable[[str], None]] = None,
                 pending_fn: Optional[Callable[[], int]] = None,
                 min_samples: int = 5,
                 clock: Callable[[], float] = time.monotonic,
                 tracer=None):
        self.policy = policy or FaultPolicy()
        self.on_strike = on_strike
        self.pending_fn = pending_fn
        self.min_samples = max(1, min_samples)
        self.clock = clock
        # optional repro.obs tracer (late-bindable attribute): strikes and
        # stall detections land in the structured event log
        if tracer is None:
            from repro.obs.trace import NULL_TRACER
            tracer = NULL_TRACER
        self.tracer = tracer
        self._lock = threading.Lock()
        self._kinds: dict[str, _KindTrack] = {}
        self._last_beat = clock()       # any-kind liveness

    def beat(self, kind: str, wall_s: float, ok: bool) -> None:
        """One dispatch completed (the coalescer heartbeat callback)."""
        strike_cb, struck = None, False
        with self._lock:
            now = self.clock()
            self._last_beat = now
            tr = self._kinds.setdefault(kind, _KindTrack())
            tr.last_seen = now
            tr.dispatches += 1
            if not ok:
                tr.failures += 1
            slow = not ok
            if ok and len(tr.history) >= self.min_samples:
                med = statistics.median(tr.history)
                slow = wall_s > self.policy.straggler_factor * med
            if ok:
                tr.history.append(wall_s)
            if slow:
                tr.strikes += 1
                if tr.strikes >= self.policy.straggler_strikes:
                    tr.strikes = 0
                    tr.tripped += 1
                    struck = True
                    strike_cb = self.on_strike
            else:
                tr.strikes = 0
        if struck:
            self.tracer.event("watchdog.strike", kind=kind,
                              wall_s=round(float(wall_s), 6))
        if strike_cb is not None:
            try:
                strike_cb(kind)
            except Exception:           # noqa: BLE001 -- monitoring must
                pass                    # never kill the dispatcher

    def check(self) -> list[str]:
        """Kinds whose dispatcher looks stalled: silent > ``timeout_s``
        with work pending. Poll from the serving loop."""
        pending = self.pending_fn() if self.pending_fn is not None else 1
        if not pending:
            return []
        now = self.clock()
        with self._lock:
            stalled = [kind for kind, tr in self._kinds.items()
                       if now - tr.last_seen > self.policy.timeout_s]
        for kind in stalled:
            self.tracer.event("watchdog.stalled", kind=kind)
        return stalled

    def report(self) -> dict[str, dict]:
        """Per-kind counters for the serving loop's final stats dump."""
        with self._lock:
            return {kind: {"dispatches": tr.dispatches,
                           "failures": tr.failures,
                           "tripped": tr.tripped,
                           "median_wall_s": (statistics.median(tr.history)
                                             if tr.history else 0.0)}
                    for kind, tr in self._kinds.items()}
