"""Launcher-level fault tolerance: heartbeats, failure detection, respawn.

On a real multi-pod deployment each host runs a `HeartbeatMonitor`; the
coordinator applies the policy below. This container is single-host, so the
protocol is exercised by unit tests with simulated clocks/failures -- the
*code path* (detection thresholds, respawn decisions, elastic re-mesh) is
what the tests pin down.

Protocol (DESIGN.md section 7):
  1. every host POSTs a heartbeat (step, timestamp) each train step;
  2. a host silent for ``timeout_s`` is declared dead; the coordinator
     decides: respawn-in-place (transient) vs shrink (hardware loss);
  3. on shrink, `elastic.remesh` picks the largest valid (pod, data, model)
     factoring of the surviving device count, and training resumes from the
     latest checkpoint (checkpointer restores onto the new mesh);
  4. stragglers (> factor x median step time) are respawn candidates after
    ``straggler_strikes`` consecutive slow steps.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional


@dataclasses.dataclass
class HostStatus:
    host_id: int
    last_step: int = -1
    last_seen: float = 0.0
    slow_strikes: int = 0
    alive: bool = True


@dataclasses.dataclass
class FaultPolicy:
    timeout_s: float = 60.0
    straggler_factor: float = 2.0
    straggler_strikes: int = 3


class HeartbeatMonitor:
    """Coordinator-side view of the fleet."""

    def __init__(self, num_hosts: int, policy: FaultPolicy = FaultPolicy(),
                 clock: Callable[[], float] = time.monotonic):
        self.policy = policy
        self.clock = clock
        self.hosts = {h: HostStatus(host_id=h, last_seen=clock())
                      for h in range(num_hosts)}
        self.median_step_s: Optional[float] = None

    def heartbeat(self, host_id: int, step: int,
                  step_seconds: Optional[float] = None) -> None:
        st = self.hosts[host_id]
        st.last_step = step
        st.last_seen = self.clock()
        st.alive = True
        if step_seconds is not None and self.median_step_s:
            if step_seconds > self.policy.straggler_factor \
                    * self.median_step_s:
                st.slow_strikes += 1
            else:
                st.slow_strikes = 0

    def set_median_step(self, seconds: float) -> None:
        self.median_step_s = seconds

    def dead_hosts(self) -> list[int]:
        now = self.clock()
        out = []
        for st in self.hosts.values():
            if st.alive and now - st.last_seen > self.policy.timeout_s:
                st.alive = False
                out.append(st.host_id)
        return out

    def respawn_candidates(self) -> list[int]:
        return [st.host_id for st in self.hosts.values()
                if st.alive
                and st.slow_strikes >= self.policy.straggler_strikes]

    def surviving(self) -> int:
        self.dead_hosts()
        return sum(st.alive for st in self.hosts.values())
