"""Serving launcher: LM decode loop or the Sinkhorn-WMD query service.

``python -m repro.launch.serve --arch sinkhorn-wmd`` serves WMD queries
(the paper's workload); any other --arch runs prefill + a short batched
decode loop on the smoke config (real configs need real hardware).
"""
import argparse
import os


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prefill-len", type=int, default=64)
    ap.add_argument("--decode-steps", type=int, default=32)
    ap.add_argument("--num-queries", type=int, default=4)
    ap.add_argument("--batch-queries", action="store_true",
                    help="sinkhorn-wmd: serve all queries in one batched "
                         "(Q, v_r, N) solve instead of a per-query loop")
    ap.add_argument("--impl", default="fused",
                    choices=("fused", "unfused", "kernel"),
                    help="sinkhorn-wmd: contraction path for the batched "
                         "engine (kernel = Pallas, interpret on CPU)")
    ap.add_argument("--docs-chunk", type=int, default=0,
                    help="sinkhorn-wmd: cache-block the batched iteration "
                         "over doc chunks of this size (0 = unchunked)")
    ap.add_argument("--tol", type=float, default=0.0,
                    help="sinkhorn-wmd: early-exit tolerance for the "
                         "batched solve (0 = fixed max_iter)")
    ap.add_argument("--devices", type=int, default=0)
    ap.add_argument("--mesh", default="")
    args = ap.parse_args()

    if args.devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices}")

    import time
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.launch.mesh import make_mesh

    n_dev = len(jax.devices())
    if args.mesh:
        shape = tuple(int(x) for x in args.mesh.split("x"))
        axes = ("data", "model") if len(shape) == 2 \
            else ("pod", "data", "model")
    else:
        shape, axes = (n_dev, 1), ("data", "model")
    mesh = make_mesh(shape, axes)

    if args.arch == "sinkhorn-wmd":
        from repro.configs import sinkhorn_wmd as wmd_cfg
        from repro.data import make_corpus
        from repro.serving import WMDService
        cfg = wmd_cfg.smoke_config() if args.smoke else wmd_cfg.config()
        data = make_corpus(vocab_size=cfg.vocab_size,
                           embed_dim=cfg.embed_dim, num_docs=cfg.num_docs,
                           num_queries=args.num_queries,
                           query_words=min(cfg.v_r - 1, 19))
        svc = WMDService(mesh=mesh, cfg=cfg, vecs=data.vecs, ell=data.ell,
                         impl=args.impl,
                         docs_chunk=args.docs_chunk or None,
                         tol=args.tol)
        if args.batch_queries:
            svc.query_batch(data.queries)          # compile outside timing
            t0 = time.perf_counter()
            dists = svc.query_batch(data.queries)
            dt = time.perf_counter() - t0
            for i, d in enumerate(dists):
                idx = np.argsort(d)[:5]
                print(f"[serve-wmd] query {i}: top5 docs {idx.tolist()} "
                      f"d={np.round(d[idx], 3).tolist()}")
            print(f"[serve-wmd] batched Q={len(dists)}: {dt * 1e3:.1f} ms "
                  f"({len(dists) / dt:.1f} queries/s)")
            return
        for i, q in enumerate(data.queries):
            t0 = time.perf_counter()
            idx, dist = svc.top_k(q, k=5)
            dt = time.perf_counter() - t0
            print(f"[serve-wmd] query {i}: top5 docs {idx.tolist()} "
                  f"d={np.round(dist, 3).tolist()} ({dt * 1e3:.1f} ms)")
        return

    from repro.configs import get_config, get_smoke_config
    from repro.models import build_model
    from repro.models.sharding_hints import activation_sharding
    from repro.serving import build_serve_fns
    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = build_model(cfg, q_block=16, kv_block=16)
    max_len = args.prefill_len + args.decode_steps
    jit_prefill, jit_decode = build_serve_fns(model, mesh, max_len=max_len)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(
        0, cfg.vocab_size, (args.batch, args.prefill_len)), jnp.int32)}
    if cfg.family == "vlm":
        p = cfg.encoder.num_positions
        batch["patches"] = jnp.asarray(
            rng.normal(size=(args.batch, p, cfg.d_model)), jnp.float32)
    if cfg.family == "audio":
        f = cfg.encoder.num_positions
        batch["frames"] = jnp.asarray(
            rng.normal(size=(args.batch, f, cfg.d_model)), jnp.float32)
    with mesh, activation_sharding(mesh):
        t0 = time.perf_counter()
        logits, cache = jit_prefill(args.batch)(params, batch)
        print(f"[serve] prefill {args.prefill_len} tokens: "
              f"{(time.perf_counter() - t0) * 1e3:.1f} ms")
        dec = jit_decode(args.batch)
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        t0 = time.perf_counter()
        for _ in range(args.decode_steps):
            logits, cache = dec(params, cache, tok)
            tok = jnp.argmax(logits[:, -1], axis=-1)[:, None] \
                .astype(jnp.int32)
        tok.block_until_ready()
        dt = time.perf_counter() - t0
    print(f"[serve] {args.decode_steps} decode steps: {dt * 1e3:.1f} ms "
          f"({dt / args.decode_steps * 1e3:.2f} ms/tok)")


if __name__ == "__main__":
    main()
