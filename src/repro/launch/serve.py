"""Serving launcher: LM decode loop or the Sinkhorn-WMD query service.

``python -m repro.launch.serve --arch sinkhorn-wmd`` serves WMD queries
(the paper's workload); any other --arch runs prefill + a short batched
decode loop on the smoke config (real configs need real hardware).

``--coalesce-window-ms W`` (W > 0) turns the one-shot WMD batch path into a
real serving loop: a `serving.coalescer.QueryCoalescer` in front of the
service micro-batches an asynchronous stream of Zipf queries (open-loop
Poisson arrivals at ``--rate-qps``, or back-to-back submits when 0), with
``--max-queue`` backpressure and optional per-request ``--deadline-ms``
budgets. Ctrl-C is safe: the loop drains the queue and in-flight batch
before exiting, the `ServingStats` report (batch-size histogram, dispatch
triggers, latency percentiles) always prints on the way out, and with
``--cache-dir`` the persisted compilation cache's state is reported too.

Startup / batch extensions (ROADMAP item 3):

* ``--cache-dir DIR`` points jax's persistent compilation cache at DIR, so
  a restarted server (or a CI job restoring DIR) skips every XLA backend
  compile it has seen before -- pair with ``--warmup``.
* ``--warmup`` precompiles the full serving envelope before any traffic:
  every pow2 Q bucket x request kind the flags imply, via the
  `serving.warmup` shape registry (the serving loop always warms; the flag
  makes the one-shot and offline paths warm too, and prints the per-shape
  compile report).
* ``--offline QUERIES.npz [--offline-out OUT.npz]`` runs the offline
  bulk-scoring mode instead of serving: the query file streams through the
  engine at maximum batch occupancy (no windows/deadlines), top-k reranks
  batched across the batch (union rerank), output bitwise identical to the
  online path on the same queries.
"""
import argparse
import os


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prefill-len", type=int, default=64)
    ap.add_argument("--decode-steps", type=int, default=32)
    ap.add_argument("--num-queries", type=int, default=4)
    ap.add_argument("--batch-queries", action="store_true",
                    help="sinkhorn-wmd: serve all queries in one batched "
                         "(Q, v_r, N) solve instead of a per-query loop")
    ap.add_argument("--impl", default="fused",
                    choices=("fused", "unfused", "kernel"),
                    help="sinkhorn-wmd: contraction path for the batched "
                         "engine (kernel = Pallas, interpret on CPU)")
    ap.add_argument("--docs-chunk", type=int, default=0,
                    help="sinkhorn-wmd: cache-block the batched iteration "
                         "over doc chunks of this size (0 = unchunked)")
    ap.add_argument("--tol", type=float, default=0.0,
                    help="sinkhorn-wmd: early-exit tolerance for the "
                         "batched solve (0 = fixed max_iter)")
    ap.add_argument("--top-k", type=int, default=0,
                    help="sinkhorn-wmd: serve top-k retrieval instead of "
                         "full distance rows (> 0 sets k; with "
                         "--coalesce-window-ms the stream submits via "
                         "submit_top_k and coalesces homogeneously)")
    ap.add_argument("--prune", action="store_true",
                    help="sinkhorn-wmd: route --top-k through the two-tier "
                         "pruned engine (RWMD prefilter + exact Sinkhorn "
                         "rerank; bitwise-identical to the full scan) and "
                         "print solves-avoided")
    ap.add_argument("--coalesce-window-ms", type=float, default=0.0,
                    help="sinkhorn-wmd: > 0 runs the async serving loop -- "
                         "a QueryCoalescer micro-batches a query stream "
                         "with this coalescing window (ms)")
    ap.add_argument("--max-batch", type=int, default=8,
                    help="serving loop: Q bucket that cuts a batch on fill "
                         "(rounded up to a power of two)")
    ap.add_argument("--max-queue", type=int, default=256,
                    help="serving loop: admission-queue bound (blocking "
                         "backpressure when full; 0 = unbounded)")
    ap.add_argument("--deadline-ms", type=float, default=0.0,
                    help="serving loop: per-request deadline budget "
                         "(0 = none); deadlines pull dispatch earlier")
    ap.add_argument("--rate-qps", type=float, default=0.0,
                    help="serving loop: open-loop Poisson arrival rate "
                         "(0 = submit back-to-back, saturating)")
    ap.add_argument("--requests", type=int, default=64,
                    help="serving loop: total queries to serve")
    ap.add_argument("--resilience", action="store_true",
                    help="serving loop: route dispatches through the "
                         "resilience layer (circuit-breaker impl ladder, "
                         "bounded retry, degraded bound-only fallback) and "
                         "run the serving watchdog (dispatcher liveness + "
                         "straggler strikes -> breaker trips)")
    ap.add_argument("--brownout-queue", type=int, default=0,
                    help="serving loop: queue depth that enters brownout "
                         "(degraded bound-only responses until the queue "
                         "clears; 0 = brownout disabled). Implies "
                         "--resilience")
    ap.add_argument("--warmup", action="store_true",
                    help="sinkhorn-wmd: precompile the full serving "
                         "envelope (pow2 Q buckets x request kinds) via "
                         "the shape registry before any query runs, and "
                         "print the per-shape compile report")
    ap.add_argument("--cache-dir", default="",
                    help="sinkhorn-wmd: persist jax's compilation cache "
                         "here -- a restart (or a CI job restoring the "
                         "directory) skips every XLA compile it has seen")
    ap.add_argument("--offline", default="", metavar="QUERIES",
                    help="sinkhorn-wmd: offline bulk-scoring mode -- "
                         "stream this query file (.npz/.npy, (n, V)) at "
                         "maximum batch occupancy instead of serving; "
                         "with --top-k, reranks use union batching")
    ap.add_argument("--offline-out", default="", metavar="OUT",
                    help="offline mode: write the scored outputs (npz) "
                         "here")
    ap.add_argument("--rerank", default="union",
                    choices=("union", "per_query"),
                    help="offline mode: rerank batching strategy (both "
                         "are bitwise-identical; union runs (Q, chunk) "
                         "programs instead of Q x (1, chunk))")
    ap.add_argument("--ingest-stream", type=int, default=0, metavar="N",
                    help="sinkhorn-wmd serving loop: build the service "
                         "over a live WAL-backed corpus and interleave N "
                         "seeded add/remove ops through the coalescer's "
                         "writer lane (requires --coalesce-window-ms)")
    ap.add_argument("--live-dir", default="",
                    help="live-corpus directory (snapshots + WAL); an "
                         "existing directory is *recovered*, so a killed "
                         "run resumes with every acked write. Default: a "
                         "fresh temp dir")
    ap.add_argument("--compact-every", type=int, default=0, metavar="OPS",
                    help="ingest mode: run an (interruptible, atomically "
                         "swapped) corpus compaction every OPS ingest ops "
                         "(0 = never)")
    ap.add_argument("--metrics-port", type=int, default=-1, metavar="PORT",
                    help="serving loop: serve the live metrics registry as "
                         "Prometheus text exposition on this port (0 = an "
                         "ephemeral port, printed at startup; -1 = off)")
    ap.add_argument("--trace-out", default="", metavar="TRACE.json",
                    help="serving loop: record per-request span trees and "
                         "write a Perfetto-loadable Chrome trace here on "
                         "exit (structured events stream to "
                         "TRACE.json.events.jsonl while serving)")
    ap.add_argument("--stats-out", default="", metavar="STATS.json",
                    help="serving loop: persist the final ServingStats + "
                         "warmup/resilience/watchdog reports as JSON on "
                         "clean exit AND on SIGINT")
    ap.add_argument("--devices", type=int, default=0)
    ap.add_argument("--mesh", default="")
    args = ap.parse_args()

    if args.devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices}")

    import time
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.launch.mesh import make_mesh

    n_dev = len(jax.devices())
    if args.mesh:
        shape = tuple(int(x) for x in args.mesh.split("x"))
        axes = ("data", "model") if len(shape) == 2 \
            else ("pod", "data", "model")
    else:
        shape, axes = (n_dev, 1), ("data", "model")
    mesh = make_mesh(shape, axes)

    if args.arch == "sinkhorn-wmd":
        from repro.configs import sinkhorn_wmd as wmd_cfg
        from repro.data import make_corpus
        from repro.serving import WMDService, enable_compilation_cache
        if args.cache_dir:
            # before the service exists: every compile from here on is
            # persisted / looked up in the cache directory
            enable_compilation_cache(args.cache_dir)
        if args.ingest_stream and args.coalesce_window_ms <= 0:
            ap.error("--ingest-stream requires --coalesce-window-ms > 0 "
                     "(writes go through the coalescer's writer lane)")
        cfg = wmd_cfg.smoke_config() if args.smoke else wmd_cfg.config()
        data = make_corpus(vocab_size=cfg.vocab_size,
                           embed_dim=cfg.embed_dim, num_docs=cfg.num_docs,
                           num_queries=args.num_queries,
                           query_words=min(cfg.v_r - 1, 19))
        if args.ingest_stream:
            import tempfile
            from repro.core.formats import doc_lists_from_ell
            from repro.data import LiveCorpus
            live_dir = args.live_dir or tempfile.mkdtemp(prefix="wmd-live-")
            # the corpus stores already-normalized weights (make_corpus
            # emits a normalized ELL), so segment rebuilds must not
            # re-normalize
            live = LiveCorpus(live_dir, cfg.vocab_size, normalize=False)
            if live.num_live == 0:
                seed_docs = doc_lists_from_ell(data.ell)
                live.add_docs(list(range(len(seed_docs))), seed_docs)
                print(f"[serve-wmd] live corpus seeded: "
                      f"{live.num_live} docs at {live_dir}")
            else:
                print(f"[serve-wmd] live corpus recovered: "
                      f"{live.num_live} docs, gen {live.gen} at {live_dir}")
            svc = WMDService.from_live(mesh, cfg, vecs=data.vecs, live=live,
                                       impl=args.impl, tol=args.tol)
        else:
            svc = WMDService(mesh=mesh, cfg=cfg, vecs=data.vecs,
                             ell=data.ell, impl=args.impl,
                             docs_chunk=args.docs_chunk or None,
                             tol=args.tol)
        if args.offline:
            _serve_wmd_offline(svc, args)
            return
        if args.warmup and args.coalesce_window_ms <= 0:
            _warmup_wmd(svc, args)     # the serving loop warms on its own
        if args.coalesce_window_ms > 0:
            _serve_wmd_loop(svc, cfg, args)
            return
        if args.top_k and (args.batch_queries or args.prune):
            # top-k retrieval over the whole query set in one call: pruned
            # (two-tier) or full scan, same (bitwise-identical) answer
            svc.top_k_batch(data.queries, args.top_k, prune=args.prune)
            t0 = time.perf_counter()
            idx_b, dist_b = svc.top_k_batch(data.queries, args.top_k,
                                            prune=args.prune)
            dt = time.perf_counter() - t0
            for i in range(len(data.queries)):
                print(f"[serve-wmd] query {i}: top{args.top_k} docs "
                      f"{idx_b[i].tolist()} "
                      f"d={np.round(dist_b[i], 3).tolist()}")
            route = "pruned" if args.prune else "full-scan"
            msg = (f"[serve-wmd] top-k {route} Q={len(idx_b)}: "
                   f"{dt * 1e3:.1f} ms")
            if args.prune:
                ps = svc.last_prune_stats
                msg += (f", solves avoided "
                        f"{ps['solves_avoided']:.1%} "
                        f"({ps['exact_solves']}/{ps['scan_solves']})")
            print(msg)
            return
        if args.batch_queries:
            svc.query_batch(data.queries)          # compile outside timing
            t0 = time.perf_counter()
            dists = svc.query_batch(data.queries)
            dt = time.perf_counter() - t0
            for i, d in enumerate(dists):
                idx = np.argsort(d)[:5]
                print(f"[serve-wmd] query {i}: top5 docs {idx.tolist()} "
                      f"d={np.round(d[idx], 3).tolist()}")
            print(f"[serve-wmd] batched Q={len(dists)}: {dt * 1e3:.1f} ms "
                  f"({len(dists) / dt:.1f} queries/s)")
            return
        for i, q in enumerate(data.queries):
            t0 = time.perf_counter()
            idx, dist = svc.top_k(q, k=args.top_k or 5)
            dt = time.perf_counter() - t0
            print(f"[serve-wmd] query {i}: top{args.top_k or 5} docs "
                  f"{idx.tolist()} "
                  f"d={np.round(dist, 3).tolist()} ({dt * 1e3:.1f} ms)")
        return

    from repro.configs import get_config, get_smoke_config
    from repro.models import build_model
    from repro.models.sharding_hints import activation_sharding
    from repro.serving import build_serve_fns
    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = build_model(cfg, q_block=16, kv_block=16)
    max_len = args.prefill_len + args.decode_steps
    jit_prefill, jit_decode = build_serve_fns(model, mesh, max_len=max_len)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(
        0, cfg.vocab_size, (args.batch, args.prefill_len)), jnp.int32)}
    if cfg.family == "vlm":
        p = cfg.encoder.num_positions
        batch["patches"] = jnp.asarray(
            rng.normal(size=(args.batch, p, cfg.d_model)), jnp.float32)
    if cfg.family == "audio":
        f = cfg.encoder.num_positions
        batch["frames"] = jnp.asarray(
            rng.normal(size=(args.batch, f, cfg.d_model)), jnp.float32)
    with mesh, activation_sharding(mesh):
        t0 = time.perf_counter()
        logits, cache = jit_prefill(args.batch)(params, batch)
        print(f"[serve] prefill {args.prefill_len} tokens: "
              f"{(time.perf_counter() - t0) * 1e3:.1f} ms")
        dec = jit_decode(args.batch)
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        t0 = time.perf_counter()
        for _ in range(args.decode_steps):
            logits, cache = dec(params, cache, tok)
            tok = jnp.argmax(logits[:, -1], axis=-1)[:, None] \
                .astype(jnp.int32)
        tok.block_until_ready()
        dt = time.perf_counter() - t0
    print(f"[serve] {args.decode_steps} decode steps: {dt * 1e3:.1f} ms "
          f"({dt / args.decode_steps * 1e3:.2f} ms/tok)")


def _warmup_wmd(svc, args, *, max_batch: int | None = None):
    """Registry warmup for the one-shot / offline paths; prints the report
    (the serving loop records the same data into ServingStats instead)."""
    ks = (args.top_k,) if args.top_k else ()
    kinds = None
    if args.offline and args.top_k:
        # the offline driver dispatches union-rerank programs, a shape the
        # online coalescer never cuts -- warm it explicitly
        kinds = ("plain", "top_k", "top_k_union")
    report = svc.warmup(max_batch=max_batch or args.max_batch, ks=ks,
                        kinds=kinds)
    print(f"[serve-wmd] warmup: {len(report.registry)} shapes in "
          f"{report.wall_s:.2f}s, {report.compiles} compiles "
          f"({report.compile_s:.2f}s), {report.persistent_hits} from the "
          f"persisted cache ({report.retrieval_s:.2f}s)")
    return report


def _report_cache_flush():
    """Print the persisted compilation cache's on-disk state (exit paths:
    normal return and SIGINT both land here), so an interrupted run still
    reports the warm cache it leaves for the next start."""
    from repro.serving import flush_compilation_cache
    info = flush_compilation_cache()
    if info:
        print(f"[serve-wmd] compilation cache: {info['entries']} entries "
              f"({info['bytes'] / 1e3:.0f} kB) persisted at {info['dir']}")


def _serve_wmd_offline(svc, args):
    """Offline bulk-scoring: query file -> full-occupancy batches -> npz."""
    from repro.serving import load_query_file, run_offline
    qs = load_query_file(args.offline)
    if args.warmup:
        _warmup_wmd(svc, args)
    try:
        res = run_offline(svc, qs, k=args.top_k or None,
                          max_batch=args.max_batch, rerank=args.rerank,
                          impl=args.impl)
        msg = (f"[serve-wmd] offline {res.mode}: {res.n} queries in "
               f"{res.batches} batches of <= {res.max_batch}, "
               f"{res.wall_s:.2f}s ({res.throughput_qps:.1f} q/s)")
        if res.mode == "top_k":
            msg += f", rerank={res.rerank}"
            if res.solves_avoided is not None:
                msg += f", solves avoided {res.solves_avoided:.1%}"
            msg += f", {res.rerank_programs} rerank programs"
        print(msg)
        if args.offline_out:
            print(f"[serve-wmd] wrote {res.save(args.offline_out)}")
    finally:
        _report_cache_flush()


def _dump_serving_stats(path, st, warmup_report, guard, watchdog, svc,
                        wall_s):
    """Persist the final serving report as one JSON document.

    Called from the serving loop's ``finally`` block, so clean exit and
    SIGINT both leave the same artifact; everything in it is plain
    scalars (ServingStats asdict + the warmup / resilience / watchdog /
    live-corpus report dicts)."""
    import dataclasses
    import json
    payload = {
        "wall_s": wall_s,
        "serving": dataclasses.asdict(st),
        "warmup": warmup_report.summary() if warmup_report else None,
        "resilience": (dataclasses.asdict(guard.stats())
                       if guard is not None else None),
        "watchdog": watchdog.report() if watchdog is not None else None,
        "live_corpus": (svc.live.stats()
                        if getattr(svc, "live", None) is not None else None),
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, default=str)
    print(f"[serve-wmd] stats persisted at {path}")
    return payload


def _serve_wmd_loop(svc, cfg, args):
    """Async serving loop: Zipf stream -> QueryCoalescer -> query_batch.

    SIGINT-safe by construction: KeyboardInterrupt only breaks the submit
    loop; the ``finally`` block still drains the queue + in-flight batch
    (shutdown-with-drain) and prints the ServingStats report, so every
    accepted request is answered before the process exits.
    """
    import time
    import numpy as np
    from repro.data import zipf_query_stream
    from repro.serving import open_loop

    stream = zipf_query_stream(vocab_size=cfg.vocab_size,
                               query_words=min(cfg.v_r - 1, 13), seed=0)
    qs = [next(stream) for _ in range(args.requests)]
    # observability: one registry for the whole stack (service K-cache
    # counters already mirror into svc.metrics), one optional tracer
    tracer = metrics_srv = exporter = None
    if args.trace_out:
        from repro.obs import JsonlExporter, Tracer
        tracer = Tracer()
        exporter = JsonlExporter(tracer, args.trace_out + ".events.jsonl")
        if getattr(svc, "live", None) is not None:
            svc.live.tracer = tracer      # WAL + compaction boundaries
    if args.metrics_port >= 0:
        from repro.obs import MetricsServer
        metrics_srv = MetricsServer(svc.metrics, port=args.metrics_port)
        print(f"[serve-wmd] metrics: http://localhost:{metrics_srv.port}"
              f"/metrics")
    guard = watchdog = None
    if args.resilience or args.brownout_queue:
        from repro.distributed.fault_tolerance import (FaultPolicy,
                                                       ServingWatchdog)
        from repro.serving import EngineGuard, ResiliencePolicy
        policy = ResiliencePolicy(
            brownout_queue_hi=args.brownout_queue or None,
            brownout_queue_lo=max((args.brownout_queue or 0) // 4, 0))
        guard = EngineGuard(svc, policy, tracer=tracer,
                            metrics=svc.metrics)
        # dispatch-kind heartbeats: straggler strikes force-open the
        # active rung's breaker (demote); liveness is polled in `finally`
        watchdog = ServingWatchdog(
            FaultPolicy(timeout_s=30.0),
            on_strike=lambda kind: guard.trip(kind),
            tracer=tracer)
    co = svc.async_service(window_ms=args.coalesce_window_ms,
                           max_batch=args.max_batch,
                           max_queue=args.max_queue,
                           default_deadline_ms=args.deadline_ms or None,
                           resilience=guard,
                           heartbeat=watchdog.beat if watchdog else None,
                           metrics=svc.metrics,
                           tracer=tracer)
    if watchdog is not None:
        # stalled-dispatcher detection only counts silence as a stall
        # while work is actually pending
        watchdog.pending_fn = lambda: co.stats().queue_depth
    # registry warmup: one pass compiles every shape this coalescer can
    # dispatch (pow2 buckets x kinds), so no live dispatch pays compile
    # time; per-shape compile seconds land in ServingStats
    warm_rep = co.warm_registry(ks=(args.top_k,) if args.top_k else (),
                                queries=qs)
    print(f"[serve-wmd] warmup: {len(warm_rep.registry)} shapes, "
          f"{warm_rep.compiles} compiles ({warm_rep.compile_s:.2f}s), "
          f"{warm_rep.persistent_hits} persisted-cache hits")
    if args.top_k:
        submit = lambda r: co.submit_top_k(r, args.top_k)   # noqa: E731
    else:
        submit = co.submit
    wfuts: list = []
    if args.ingest_stream:
        # seeded writer stream: mostly upserts of fresh doc ids, some
        # removes of existing ones, paced to spread over the query stream;
        # every op goes through the coalescer's writer lane so write
        # batches interleave with (and order against) query batches
        wrng = np.random.default_rng(1)
        next_id = [svc.live.num_live]
        done = [0]
        every = max(1, args.requests // max(args.ingest_stream, 1))

        def maybe_ingest(i: int) -> None:
            if done[0] >= args.ingest_stream or i % every:
                return
            done[0] += 1
            if wrng.random() < 0.25 and next_id[0] > 0:
                victim = int(wrng.integers(0, next_id[0]))
                wfuts.append(co.submit_remove_docs([victim]))
            else:
                nw = int(wrng.integers(2, min(8, cfg.v_r)))
                wids = wrng.choice(cfg.vocab_size, size=nw, replace=False)
                cnts = wrng.integers(1, 5, size=nw).astype(np.float64)
                cnts /= cnts.sum()          # corpus stores normalized docs
                doc = [(int(w), float(c)) for w, c in zip(wids, cnts)]
                wfuts.append(co.submit_add_docs([next_id[0]], [doc]))
                next_id[0] += 1
            if args.compact_every and done[0] % args.compact_every == 0:
                svc.compact()       # interruptible; serialized vs dispatch

        base_submit = submit
        counter = [0]

        def submit(r):              # noqa: F811 -- deliberate wrap
            maybe_ingest(counter[0])
            counter[0] += 1
            return base_submit(r)
    print(f"[serve-wmd] serving loop: {args.requests} zipf queries"
          + (f" (top-{args.top_k} pruned)" if args.top_k else "") + ", "
          f"window={args.coalesce_window_ms:g} ms "
          f"max_batch={co.max_batch} max_queue={args.max_queue} "
          f"rate={'saturating' if args.rate_qps <= 0 else args.rate_qps} "
          f"(Ctrl-C drains and reports)")
    futs = []
    t0 = time.perf_counter()
    try:
        if args.rate_qps > 0:
            # loadgen's open loop: absolute seeded Poisson schedule, so slow
            # submits (e.g. blocking backpressure) make the driver catch up
            # instead of silently lowering the offered rate
            open_loop(submit, qs, rate_qps=args.rate_qps, seed=0)
        else:
            futs = [submit(r) for r in qs]         # saturating back-to-back
        co.drain()
    except KeyboardInterrupt:
        print("\n[serve-wmd] SIGINT: draining queued + in-flight requests")
    finally:
        co.shutdown(drain=True)
        dt = time.perf_counter() - t0
        st = co.stats()
        if futs and futs[0].exception() is None:
            res = futs[0].result()
            if args.top_k:
                idx, d = res
            else:
                idx = np.argsort(res)[:5]
                d = res[idx]
            print(f"[serve-wmd] sample query 0: top docs {idx.tolist()} "
                  f"d={np.round(d, 3).tolist()}")
        print(f"[serve-wmd] served {st.completed}/{st.submitted} in "
              f"{dt:.2f}s ({st.completed / max(dt, 1e-9):.1f} q/s), "
              f"mean batch {st.mean_batch_size:.1f}")
        print(f"[serve-wmd] dispatches={st.dispatches} "
              f"(fill={st.dispatch_fill} window={st.dispatch_window} "
              f"deadline={st.dispatch_deadline} drain={st.dispatch_drain}) "
              f"hist={st.batch_size_hist}")
        print(f"[serve-wmd] latency ms: mean={st.latency_ms_mean:.1f} "
              f"p50={st.latency_ms_p50:.1f} p95={st.latency_ms_p95:.1f} "
              f"p99={st.latency_ms_p99:.1f} "
              f"deadline_misses={st.deadline_misses}"
              + (f" hit_rate={st.hit_rate:.2f}"
                 if st.hit_rate is not None else ""))
        if args.ingest_stream:
            acked = sum(1 for f in wfuts
                        if f.done() and f.exception() is None)
            ls = svc.live.stats()
            print(f"[serve-wmd] ingest: {acked}/{len(wfuts)} write ops "
                  f"acked over {st.write_dispatches} dispatches "
                  f"(+{st.docs_added}/-{st.docs_removed} docs), "
                  f"gen={ls['gen']} live={ls['num_live']} "
                  f"delta={ls['delta_rows']} wal={ls['wal_bytes']}B")
        if guard is not None:
            gs = guard.stats()
            stalled = watchdog.check()
            print(f"[serve-wmd] resilience: retries={gs.retries} "
                  f"demoted={gs.demoted} degraded={st.degraded} "
                  f"({st.degraded_fraction:.1%} of completed) "
                  f"quarantined={st.quarantined} "
                  f"breaker_transitions={gs.breaker_transitions} "
                  f"open_rungs={gs.breaker_open} "
                  f"brownout_entries={gs.brownout_entries}"
                  + (f" STALLED={stalled}" if stalled else ""))
            for kind, rep in watchdog.report().items():
                print(f"[serve-wmd] watchdog[{kind}]: "
                      f"{rep['dispatches']} beats, "
                      f"{rep['failures']} failures, "
                      f"{rep['tripped']} strikes tripped, "
                      f"median {rep['median_wall_s'] * 1e3:.1f} ms")
        # SIGINT lands here too: leave the persisted cache state on record
        if args.stats_out:
            _dump_serving_stats(args.stats_out, st, warm_rep, guard,
                                watchdog, svc, dt)
        if tracer is not None:
            if exporter is not None:
                exporter.close()
            tracer.export_chrome(args.trace_out)
            print(f"[serve-wmd] trace: {args.trace_out} "
                  f"({len(tracer.completed)} request trees, "
                  f"{tracer.open_count} left open) + event log at "
                  f"{args.trace_out}.events.jsonl")
        if metrics_srv is not None:
            metrics_srv.close()
        _report_cache_flush()


if __name__ == "__main__":
    main()
