"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

Runs the fault-tolerant trainer on whatever devices exist (the production
mesh needs real hardware; locally use --devices/--mesh to emulate). The
--arch accepts any assigned architecture; --smoke uses the reduced config.
"""
import argparse
import os


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--grad-compression", action="store_true")
    ap.add_argument("--router", choices=["topk", "sinkhorn"], default=None,
                    help="MoE router override (sinkhorn = paper technique)")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--devices", type=int, default=0,
                    help="emulate N host devices (sets XLA_FLAGS; must be "
                         "first jax use in the process)")
    ap.add_argument("--mesh", default="",
                    help="e.g. 4x2 -> (data=4, model=2)")
    args = ap.parse_args()

    if args.devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices}")

    import dataclasses
    import jax
    from repro.configs import get_config, get_smoke_config
    from repro.data import TokenPipeline
    from repro.launch.mesh import make_mesh
    from repro.models import build_model
    from repro.optim import adamw, warmup_cosine
    from repro.train import Trainer

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if args.router and cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, router=args.router))

    n_dev = len(jax.devices())
    if args.mesh:
        shape = tuple(int(x) for x in args.mesh.split("x"))
        axes = ("data", "model") if len(shape) == 2 \
            else ("pod", "data", "model")
    else:
        shape, axes = (n_dev, 1), ("data", "model")
    mesh = make_mesh(shape, axes)
    print(f"[train] arch={cfg.name} devices={n_dev} mesh={dict(zip(axes, shape))}")

    model = build_model(cfg)
    opt = adamw(warmup_cosine(args.lr, warmup_steps=max(args.steps // 10, 1),
                              total_steps=args.steps))
    pipe = TokenPipeline(cfg, batch=args.batch, seq_len=args.seq_len)
    trainer = Trainer(model, opt, mesh, pipe, ckpt_dir=args.ckpt_dir,
                      microbatches=args.microbatches,
                      grad_compression=args.grad_compression,
                      ckpt_every=args.ckpt_every)
    out = trainer.run(jax.random.PRNGKey(0), args.steps)
    hist = out["history"]
    if hist:
        print(f"[train] done: step {hist[-1]['step']} "
              f"loss {hist[0]['loss']:.4f} -> {hist[-1]['loss']:.4f} "
              f"stragglers={out['stragglers']}")


if __name__ == "__main__":
    main()
