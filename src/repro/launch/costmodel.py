"""Cost model for the roofline: exact-trip-count FLOPs/bytes + HLO collectives.

Why not just ``compiled.cost_analysis()``: XLA's HLO cost analysis counts a
while-loop body ONCE regardless of trip count (verified empirically on this
container -- a 10-iteration scan of a matmul reports 1 matmul of FLOPs).
Every model here runs its layer stack under lax.scan, so raw cost_analysis
would under-report FLOPs by ~num_layers. Two complementary fixes:

1. **jaxpr walker** (`jaxpr_cost`): traverses the *traced* jaxpr where scan
   lengths are static. FLOPs: dot_general/conv counted exactly (2*M*N*K),
   elementwise ops ~1 flop/element. HBM bytes: operands+outputs of
   data-motion-dominant ops (dot, conv, gather, scatter, reduce, rng),
   elementwise ops assumed fused (skipped). This is a fusion-optimistic
   HBM model -- documented in EXPERIMENTS.md §Roofline methodology. These
   are LOGICAL (global) numbers; per-chip = /chips under even sharding.

2. **HLO collective parser** (`collective_bytes`): walks
   ``compiled.as_text()``, builds the computation call graph with while
   ``known_trip_count`` multipliers (scan bodies carry them), and sums
   wire bytes of all-gather / all-reduce / reduce-scatter / all-to-all /
   collective-permute with ring-transfer factors ((g-1)/g, 2(g-1)/g for AR).
   SPMD HLO shapes are PER-DEVICE, so the result is per-device wire bytes --
   the collective roofline term divides by link bandwidth only (the chips
   factor in the assignment formula cancels; shown in EXPERIMENTS.md).

Raw cost_analysis numbers are reported alongside for transparency.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any

import numpy as np

# ---------------------------------------------------------------------------
# jaxpr walker
# ---------------------------------------------------------------------------

_ELEMENTWISE = {
    "add", "sub", "mul", "div", "max", "min", "exp", "log", "tanh", "logistic",
    "rsqrt", "sqrt", "pow", "neg", "abs", "floor", "ceil", "round", "sign",
    "erf", "cos", "sin", "integer_pow", "select_n", "clamp", "nextafter",
    "rem", "atan2", "expm1", "log1p", "cbrt", "square",
}
_BYTES_OPS = {
    "dot_general", "conv_general_dilated", "gather", "scatter", "scatter-add",
    "scatter_add", "dynamic_slice", "dynamic_update_slice", "reduce_sum",
    "reduce_max", "reduce_min", "reduce_prod", "argmax", "argmin", "sort",
    "cumsum", "cumlogsumexp", "cummax", "top_k", "iota", "broadcast_in_dim",
}
# shard_map collectives visible at jaxpr level
_JAXPR_COLLECTIVES = {"psum", "all_gather", "all_to_all", "ppermute",
                      "psum_scatter", "pmax", "pmin"}


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: float = 0.0   # jaxpr-level (shard_map) only
    unknown_loops: int = 0

    def __add__(self, o):
        return Cost(self.flops + o.flops, self.bytes + o.bytes,
                    self.collective_bytes + o.collective_bytes,
                    self.unknown_loops + o.unknown_loops)

    def __mul__(self, k: float):
        return Cost(self.flops * k, self.bytes * k,
                    self.collective_bytes * k, self.unknown_loops)


def _aval_bytes(aval) -> float:
    try:
        return float(np.prod(aval.shape) * aval.dtype.itemsize)
    except Exception:
        return 0.0


def _aval_elems(aval) -> float:
    try:
        return float(np.prod(aval.shape))
    except Exception:
        return 0.0


def _dot_flops(eqn) -> float:
    lhs, rhs = eqn.invars[0].aval, eqn.invars[1].aval
    dnums = eqn.params["dimension_numbers"]
    (lc, rc), (lb, rb) = dnums
    batch = np.prod([lhs.shape[i] for i in lb]) if lb else 1.0
    contract = np.prod([lhs.shape[i] for i in lc]) if lc else 1.0
    m = np.prod([lhs.shape[i] for i in range(lhs.ndim)
                 if i not in lc and i not in lb]) or 1.0
    n = np.prod([rhs.shape[i] for i in range(rhs.ndim)
                 if i not in rc and i not in rb]) or 1.0
    return 2.0 * float(batch) * float(m) * float(n) * float(contract)


def _conv_flops(eqn) -> float:
    out = eqn.outvars[0].aval
    rhs = eqn.invars[1].aval                 # kernel
    groups = eqn.params.get("feature_group_count", 1)
    k_elems = np.prod(rhs.shape)             # O*I/g*spatial
    out_spatial_batch = np.prod(out.shape) / out.shape[
        eqn.params["dimension_numbers"].out_spec[1]] \
        if hasattr(eqn.params["dimension_numbers"], "out_spec") else \
        np.prod(out.shape)
    # conservative: 2 * out_elems * (kernel_elems / out_features)
    return 2.0 * float(np.prod(out.shape)) * float(k_elems) \
        / max(float(rhs.shape[0]), 1.0) / groups


def jaxpr_cost(jaxpr) -> Cost:
    """Walk a (closed) jaxpr; multiply scan bodies by their length."""
    if hasattr(jaxpr, "jaxpr"):
        jaxpr = jaxpr.jaxpr
    total = Cost()
    for eqn in jaxpr.eqns:
        prim = eqn.primitive.name
        if prim == "dot_general":
            total += Cost(flops=_dot_flops(eqn),
                          bytes=sum(_aval_bytes(v.aval) for v in eqn.invars)
                          + sum(_aval_bytes(v.aval) for v in eqn.outvars))
        elif prim == "conv_general_dilated":
            total += Cost(flops=_conv_flops(eqn),
                          bytes=sum(_aval_bytes(v.aval) for v in eqn.invars)
                          + sum(_aval_bytes(v.aval) for v in eqn.outvars))
        elif prim == "scan":
            body = jaxpr_cost(eqn.params["jaxpr"])
            total += body * float(eqn.params["length"])
        elif prim == "while":
            body = jaxpr_cost(eqn.params["body_jaxpr"])
            cond = jaxpr_cost(eqn.params["cond_jaxpr"])
            got = body + cond
            got.unknown_loops += 1
            total += got
        elif prim in ("cond", "switch"):
            branches = [jaxpr_cost(b) for b in eqn.params["branches"]]
            total += max(branches, key=lambda c: c.flops) if branches \
                else Cost()
        elif prim in _JAXPR_COLLECTIVES:
            nbytes = sum(_aval_bytes(v.aval) for v in eqn.invars)
            total += Cost(bytes=nbytes, collective_bytes=nbytes)
        elif prim in _ELEMENTWISE:
            total += Cost(flops=_aval_elems(eqn.outvars[0].aval))
        elif prim in _BYTES_OPS:
            total += Cost(bytes=sum(_aval_bytes(v.aval) for v in eqn.invars)
                          + sum(_aval_bytes(v.aval) for v in eqn.outvars))
        else:
            # generic recursion: any primitive carrying sub-jaxprs (pjit,
            # remat2, custom_vjp_call, shard_map, ...) is walked x1.
            for v in eqn.params.values():
                if hasattr(v, "jaxpr") or hasattr(v, "eqns"):
                    total += jaxpr_cost(v)
                elif isinstance(v, (list, tuple)):
                    for b in v:
                        if hasattr(b, "jaxpr") or hasattr(b, "eqns"):
                            total += jaxpr_cost(b)
        # remaining ops (reshape/transpose/convert): assumed fused / free
    return total


# ---------------------------------------------------------------------------
# HLO collective parser (per-device wire bytes, trip-count aware)
# ---------------------------------------------------------------------------

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")
_CALLED_RE = re.compile(
    r"(?:calls=|body=|condition=|to_apply=)%([\w.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count"\s*:\s*\{\s*"n"\s*:\s*"?(\d+)"?')
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")

_COLL_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_bytes(text: str) -> float:
    """Total bytes of the first (possibly tuple) shape in ``text``."""
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        elems = 1.0
        if dims:
            for d in dims.split(","):
                if d:
                    elems *= int(d)
        total += elems * _DTYPE_BYTES[dt]
    return total


def _split_computations(hlo: str) -> dict[str, list[str]]:
    comps: dict[str, list[str]] = {}
    current = None
    for line in hlo.splitlines():
        m = re.match(r"^(?:ENTRY\s+)?%([\w.\-]+)\s*\(", line)
        if m and ("->" in line) and line.rstrip().endswith("{"):
            current = m.group(1)
            comps[current] = []
            continue
        if line.strip() == "}":
            current = None
            continue
        if current is not None:
            comps[current].append(line)
    return comps


def collective_bytes(hlo: str) -> dict[str, Any]:
    """Per-device wire bytes of every collective, trip-count multiplied.

    Returns {"total": float, "by_kind": {...}, "unknown_trip_whiles": int}.
    """
    comps = _split_computations(hlo)

    # find entry: computation not called by any other
    called = set()
    calls: dict[str, list[tuple[str, float]]] = {c: [] for c in comps}
    unknown_whiles = 0
    for cname, lines in comps.items():
        for line in lines:
            body = None
            mbody = re.search(r"body=%([\w.\-]+)", line)
            mcond = re.search(r"condition=%([\w.\-]+)", line)
            if " while(" in line:
                mt = _TRIP_RE.search(line)
                trip = float(mt.group(1)) if mt else 1.0
                if not mt:
                    unknown_whiles += 1
                if mbody:
                    calls[cname].append((mbody.group(1), trip))
                    called.add(mbody.group(1))
                if mcond:
                    calls[cname].append((mcond.group(1), trip + 1))
                    called.add(mcond.group(1))
            else:
                for target in _CALLED_RE.findall(line):
                    if target in comps:
                        calls[cname].append((target, 1.0))
                        called.add(target)
    entries = [c for c in comps if c not in called]

    # propagate multipliers (call graph is a DAG)
    mult: dict[str, float] = {}

    def visit(c: str, m: float):
        mult[c] = mult.get(c, 0.0) + m
        for tgt, k in calls.get(c, []):
            visit(tgt, m * k)

    for e in entries:
        visit(e, 1.0)

    by_kind = {k: 0.0 for k in _COLL_KINDS}
    count = {k: 0 for k in _COLL_KINDS}
    for cname, lines in comps.items():
        m = mult.get(cname, 1.0)
        # shape table for operand lookup
        shapes: dict[str, float] = {}
        for line in lines:
            d = _DEF_RE.match(line)
            if d:
                shapes[d.group(1)] = _shape_bytes(d.group(2))
        for line in lines:
            for kind in _COLL_KINDS:
                if f" {kind}(" in line or f"{kind}-start(" in line:
                    d = _DEF_RE.match(line)
                    out_bytes = _shape_bytes(d.group(2)) if d else 0.0
                    g = 1
                    mg = _GROUPS_RE.search(line)
                    if mg:
                        g = int(mg.group(2))
                    else:
                        mb = _GROUPS_BRACE_RE.search(line)
                        if mb:
                            g = len(mb.group(1).split(","))
                    if g <= 1:
                        continue
                    ring = (g - 1) / g
                    if kind == "all-reduce":
                        wire = out_bytes * 2 * ring
                    elif kind == "collective-permute":
                        wire = out_bytes
                    else:
                        wire = out_bytes * ring
                    by_kind[kind] += wire * m
                    count[kind] += 1
    return {"total": sum(by_kind.values()), "by_kind": by_kind,
            "count": count, "unknown_trip_whiles": unknown_whiles}
