"""Roofline report: three terms per (arch x shape x mesh) from dry-run JSON.

Terms (TPU v5e constants per the assignment):
  compute    = FLOPs / (chips * 197e12)            [bf16 peak]
  memory     = HBM bytes / (chips * 819e9)
  collective = per-device wire bytes / 50e9        [ICI link]

Scoping (see costmodel.py): jaxpr FLOPs/bytes are GLOBAL-logical for pjit
cells (divided by chips here) but PER-DEVICE for shard_map cells
(sinkhorn-wmd -- not divided). HLO collective bytes are always per-device
(SPMD), so the assignment's /chips cancels against the per-chip scope --
the collective term divides by link bandwidth only.

MODEL_FLOPS = 6*N*D for train (N = active params for MoE), 2*N*D for
prefill, 2*N*B for decode (one token). The "useful fraction" is
MODEL_FLOPS / measured FLOPs; the roofline fraction (the §Perf score) is
model-flops-time / dominant term.

Usage: PYTHONPATH=src python -m repro.launch.roofline [--mesh pod16x16]
Writes experiments/roofline_<mesh>.md and prints the table.
"""
from __future__ import annotations

import argparse
import glob
import json
import os

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
LINK_BW = 50e9               # bytes/s / link

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments")


def model_flops(arch: str, shape: str) -> float:
    from repro.configs import get_config, get_shape
    if arch == "sinkhorn-wmd":
        from repro.configs import sinkhorn_wmd as wmd_cfg
        cfg = wmd_cfg.config(shape[:-4] if shape.endswith("_opt")
                             else shape)
        # cdist (2*v_r*V*w) + t iterations of 2 fused contractions over nnz
        nnz = cfg.num_docs * 35                   # corpus mean words/doc
        return (2.0 * cfg.v_r * cfg.vocab_size * cfg.embed_dim
                + cfg.max_iter * 2 * 2 * nnz * cfg.v_r)
    cfg = get_config(arch)
    sh = get_shape(shape)
    n = cfg.active_param_count()
    if sh.kind == "train":
        return 6.0 * n * sh.global_batch * sh.seq_len
    if sh.kind == "prefill":
        return 2.0 * n * sh.global_batch * sh.seq_len
    return 2.0 * n * sh.global_batch              # decode: one token


def chips(mesh_name: str) -> int:
    return 512 if "2x16x16" in mesh_name else 256


def analyze_cell(rec: dict) -> dict | None:
    if rec.get("status") != "ok":
        return None
    mesh_name = rec["mesh"]
    n_chips = chips(mesh_name)
    jc = rec.get("jaxpr_cost") or {}
    flops = jc.get("flops", 0.0)
    bytes_ = jc.get("bytes", 0.0)
    per_device_scope = rec["arch"] == "sinkhorn-wmd"   # shard_map program
    div = 1.0 if per_device_scope else float(n_chips)
    t_compute = flops / div / PEAK_FLOPS
    t_memory = bytes_ / div / HBM_BW
    coll = rec.get("collectives") or {}
    t_coll = float(coll.get("total", 0.0)) / LINK_BW
    terms = {"compute": t_compute, "memory": t_memory,
             "collective": t_coll}
    bottleneck = max(terms, key=terms.get)
    mf = model_flops(rec["arch"], rec["shape"])
    t_model = mf / n_chips / PEAK_FLOPS if not per_device_scope \
        else mf / n_chips / PEAK_FLOPS
    useful = mf / flops / (1.0 if per_device_scope else 1.0) \
        if flops else 0.0
    if per_device_scope:
        useful = (mf / n_chips) / flops if flops else 0.0
    dominant = max(terms.values())
    frac = t_model / dominant if dominant > 0 else 0.0
    mem_gib = ((rec.get("memory_analysis") or {})
               .get("temp_size_in_bytes") or 0) / 2 ** 30
    return {"arch": rec["arch"], "shape": rec["shape"], "mesh": mesh_name,
            **{f"t_{k}": v for k, v in terms.items()},
            "bottleneck": bottleneck, "useful_flops_frac": useful,
            "roofline_frac": frac, "temp_gib_per_chip": mem_gib,
            "unknown_loops": jc.get("unknown_loops", 0)}


def fmt_s(x: float) -> str:
    if x == 0:
        return "0"
    if x < 1e-3:
        return f"{x * 1e6:.1f}us"
    if x < 1:
        return f"{x * 1e3:.2f}ms"
    return f"{x:.2f}s"


def report(mesh_name: str, dryrun_dir: str | None = None) -> str:
    dryrun_dir = dryrun_dir or os.path.join(OUT_DIR, "dryrun", mesh_name)
    rows, skips = [], []
    for f in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        rec = json.load(open(f))
        if rec.get("status") == "skipped":
            skips.append((rec["arch"], rec["shape"], rec.get("reason", "")))
            continue
        row = analyze_cell(rec)
        if row:
            rows.append(row)
    lines = [
        f"### Roofline -- {mesh_name} ({chips(mesh_name)} chips, "
        "v5e: 197 TF/s bf16, 819 GB/s HBM, 50 GB/s link)",
        "",
        "| arch | shape | compute | memory | collective | bottleneck | "
        "useful FLOPs | roofline frac | temp GiB/chip |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        lines.append(
            f"| {r['arch']} | {r['shape']} | {fmt_s(r['t_compute'])} | "
            f"{fmt_s(r['t_memory'])} | {fmt_s(r['t_collective'])} | "
            f"**{r['bottleneck']}** | {r['useful_flops_frac']:.2f} | "
            f"{r['roofline_frac']:.2f} | {r['temp_gib_per_chip']:.2f} |")
    if skips:
        lines += ["", "Skipped cells (documented, DESIGN.md section 5):", ""]
        for a, s, why in skips:
            lines.append(f"* {a} x {s}: {why}")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="pod16x16",
                    choices=["pod16x16", "pod2x16x16"])
    args = ap.parse_args()
    txt = report(args.mesh)
    out = os.path.join(OUT_DIR, f"roofline_{args.mesh}.md")
    with open(out, "w") as f:
        f.write(txt + "\n")
    print(txt)
    print(f"\nwritten: {out}")


if __name__ == "__main__":
    main()
