"""Production mesh construction (required API per the assignment).

A function, not a module-level constant, so importing this module never
touches jax device state. Single-pod: (data=16, model=16) = 256 chips.
Multi-pod: (pod=2, data=16, model=16) = 512 chips.
"""
from __future__ import annotations

import jax

from repro import compat


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def make_mesh(shape, axes) -> jax.sharding.Mesh:
    """General helper with explicit Auto axis types (elastic/test meshes)."""
    return compat.make_mesh(shape, axes)
