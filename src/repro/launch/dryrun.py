"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

MUST be the very first two lines -- before any other import, including
`from repro...`, since jax locks the device count on first init:
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse      # noqa: E402
import json          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import (cell_supported, cells, get_config,  # noqa: E402
                           get_shape)
from repro.configs import sinkhorn_wmd as wmd_cfg  # noqa: E402
from repro.data.tokens import batch_struct  # noqa: E402
from repro.distributed import partitioning  # noqa: E402
from repro.launch import costmodel  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models import build_model  # noqa: E402
from repro.models.sharding_hints import activation_sharding  # noqa: E402
from repro.optim import adamw, warmup_cosine  # noqa: E402
from repro.serving.serve_step import build_serve_fns  # noqa: E402
from repro.train import step as train_step_mod  # noqa: E402

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


def _with_shardings(mesh, struct, shardings):
    """Attach NamedShardings to a pytree of ShapeDtypeStructs."""
    return jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        struct, shardings)


def input_specs(arch: str, shape: str, mesh):
    """ShapeDtypeStruct stand-ins for every model input of this cell --
    weak-type-correct, shardable, no device allocation."""
    cfg = get_config(arch)
    sh = get_shape(shape)
    remat = os.environ.get("REPRO_REMAT", "1") == "1"
    model = build_model(cfg, remat=remat)
    bstruct = batch_struct(cfg, sh)
    bshard = partitioning.batch_shardings(mesh, bstruct)
    bstruct = _with_shardings(mesh, bstruct, bshard)

    pstruct = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    pshard = partitioning.param_shardings(mesh, pstruct)
    pstruct_s = _with_shardings(mesh, pstruct, pshard)

    if sh.kind == "train":
        # per-arch gradient-accumulation defaults chosen so the train cell
        # fits v5e HBM (16 GiB) -- the §Perf memory iteration; override with
        # REPRO_MICROBATCHES.
        default_mb = {
            "mixtral-8x22b": 16, "deepseek-moe-16b": 16, "paligemma-3b": 8,
            "minicpm3-4b": 8, "whisper-small": 4, "recurrentgemma-9b": 4,
            "starcoder2-3b": 4, "gemma-2b": 2, "olmo-1b": 2, "xlstm-125m": 1,
        }.get(arch, 1)
        microbatches = int(os.environ.get("REPRO_MICROBATCHES",
                                          str(default_mb)))
        opt = adamw(warmup_cosine(1e-4, warmup_steps=100, total_steps=1000))
        sstruct = jax.eval_shape(
            lambda k: train_step_mod.init_state(model, opt, k),
            jax.random.PRNGKey(0))
        sshard = train_step_mod.state_shardings(mesh, sstruct)
        sstruct = jax.tree.map(
            lambda s, sh_: jax.ShapeDtypeStruct(s.shape, s.dtype,
                                                sharding=sh_),
            sstruct, sshard)
        return {"kind": "train", "model": model, "opt": opt,
                "microbatches": microbatches, "args": (sstruct, bstruct)}
    if sh.kind == "prefill":
        return {"kind": "prefill", "model": model, "max_len": sh.seq_len,
                "batch_size": sh.global_batch, "args": (pstruct_s, bstruct)}
    # decode: one new token with a KV cache of seq_len
    cstruct = jax.eval_shape(
        lambda: model.init_cache(sh.global_batch, sh.seq_len))
    # pos indicates a full cache
    cshard = partitioning.cache_shardings(mesh, cstruct)
    cstruct = _with_shardings(mesh, cstruct, cshard)
    tok = jax.ShapeDtypeStruct(
        (sh.global_batch, 1), jnp.int32,
        sharding=NamedSharding(mesh, partitioning.sanitize_spec(
            mesh, partitioning.batch_spec(mesh, 2), (sh.global_batch, 1))))
    return {"kind": "decode", "model": model, "max_len": sh.seq_len,
            "batch_size": sh.global_batch, "args": (pstruct_s, cstruct, tok)}


def lower_cell(arch: str, shape: str, mesh):
    spec = input_specs(arch, shape, mesh)
    model = spec["model"]
    mode = "decode" if spec["kind"] == "decode" else "train"
    with mesh, activation_sharding(mesh, mode=mode):
        if spec["kind"] == "train":
            fn = train_step_mod.build_train_step(
                model, spec["opt"], mesh, donate=True,
                microbatches=spec.get("microbatches", 1))
            traced = fn.trace(*spec["args"])
        elif spec["kind"] == "prefill":
            jit_prefill, _ = build_serve_fns(model, mesh,
                                             max_len=spec["max_len"])
            traced = jit_prefill(spec["batch_size"]).trace(*spec["args"])
        else:
            _, jit_decode = build_serve_fns(model, mesh,
                                            max_len=spec["max_len"])
            traced = jit_decode(spec["batch_size"],
                                donate_cache=True).trace(*spec["args"])
    return traced


def lower_wmd(shape: str, mesh):
    """The paper's own workload as a dry-run cell (11th config).

    ``*_opt`` shapes lower the §Perf-optimized engine: doc-sharded /
    K-replicated layout (zero in-loop collectives) + length-bucketed ELL
    (nnz_max 48 instead of 128+rebucket padding).
    """
    from repro.core.distributed import build_wmd_fn, build_wmd_fn_docsharded
    if shape.endswith("_opt"):
        cfg = wmd_cfg.config(shape[:-4])
        doc_par = 1
        for a in mesh.axis_names:
            doc_par *= mesh.shape[a]
        num_docs = -(-cfg.num_docs // doc_par) * doc_par
        nnz = 48  # bucketed mean (bench_padding: 1.38 slots/nnz at mean 35)
        fn = build_wmd_fn_docsharded(mesh, lamb=cfg.lamb,
                                     max_iter=cfg.max_iter)
        sd = jax.ShapeDtypeStruct
        ns = lambda spec: NamedSharding(mesh, spec)
        all_axes = tuple(mesh.axis_names)
        args = (
            sd((cfg.v_r, cfg.embed_dim), jnp.float32, sharding=ns(P())),
            sd((cfg.v_r,), jnp.float32, sharding=ns(P())),
            sd((cfg.v_r,), jnp.float32, sharding=ns(P())),
            sd((cfg.vocab_size, cfg.embed_dim), jnp.float32,
               sharding=ns(P())),
            sd((num_docs, nnz), jnp.int32, sharding=ns(P(all_axes, None))),
            sd((num_docs, nnz), jnp.float32,
               sharding=ns(P(all_axes, None))),
        )
        with mesh, activation_sharding(mesh):
            return fn.trace(*args)
    cfg = wmd_cfg.config(shape)
    model_par = mesh.shape["model"]
    doc_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    doc_par = 1
    for a in doc_axes:
        doc_par *= mesh.shape[a]
    # pad the doc axis to the doc-sharding factor (formats.pad_docs at load
    # time does the same for real data)
    num_docs = -(-cfg.num_docs // doc_par) * doc_par
    nnz_loc = max(cfg.nnz_max // model_par * 2, 16)  # rebucket headroom
    fn = build_wmd_fn(mesh, lamb=cfg.lamb, max_iter=cfg.max_iter,
                      doc_axes=doc_axes)
    sd = jax.ShapeDtypeStruct
    ns = lambda spec: NamedSharding(mesh, spec)
    args = (
        sd((cfg.v_r, cfg.embed_dim), jnp.float32, sharding=ns(P())),
        sd((cfg.v_r,), jnp.float32, sharding=ns(P())),
        sd((cfg.v_r,), jnp.float32, sharding=ns(P())),
        sd((cfg.vocab_size, cfg.embed_dim), jnp.float32,
           sharding=ns(P("model", None))),
        sd((model_par, num_docs, nnz_loc), jnp.int32,
           sharding=ns(P("model", doc_axes, None))),
        sd((model_par, num_docs, nnz_loc), jnp.float32,
           sharding=ns(P("model", doc_axes, None))),
    )
    with mesh, activation_sharding(mesh):
        return fn.trace(*args)


def analyze(traced, *, hlo_collectives: bool = True) -> dict:
    t0 = time.perf_counter()
    lowered = traced.lower()
    compiled = lowered.compile()
    compile_s = time.perf_counter() - t0
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    out = {
        "compile_seconds": compile_s,
        "memory_analysis": {
            k: getattr(mem, k, None) for k in (
                "argument_size_in_bytes", "output_size_in_bytes",
                "temp_size_in_bytes", "generated_code_size_in_bytes",
                "alias_size_in_bytes")
        } if mem is not None else None,
        "cost_analysis_raw": {k: cost.get(k) for k in ("flops",
                                                       "bytes accessed")}
        if cost else None,
    }
    # exact-trip-count logical cost from the traced jaxpr
    try:
        jc = costmodel.jaxpr_cost(traced.jaxpr)
    except Exception:
        jc = None
    if jc is not None:
        out["jaxpr_cost"] = {"flops": jc.flops, "bytes": jc.bytes,
                             "unknown_loops": jc.unknown_loops}
    if hlo_collectives:
        try:
            out["collectives"] = costmodel.collective_bytes(
                compiled.as_text())
        except Exception as e:  # parser must never fail a cell
            out["collectives"] = {"error": str(e)}
    return out


def run_cell(arch: str, shape: str, *, multi_pod: bool,
             out_dir: str = OUT_DIR) -> dict:
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    os.makedirs(os.path.join(out_dir, mesh_name), exist_ok=True)
    out_path = os.path.join(out_dir, mesh_name, f"{arch}__{shape}.json")
    rec = {"arch": arch, "shape": shape, "mesh": mesh_name}
    try:
        if arch == "sinkhorn-wmd":
            mesh = make_production_mesh(multi_pod=multi_pod)
            traced = lower_wmd(shape, mesh)
        else:
            ok, why = cell_supported(arch, shape)
            if not ok:
                rec.update({"status": "skipped", "reason": why})
                with open(out_path, "w") as f:
                    json.dump(rec, f, indent=1)
                return rec
            mesh = make_production_mesh(multi_pod=multi_pod)
            traced = lower_cell(arch, shape, mesh)
        rec.update(analyze(traced))
        rec["status"] = "ok"
    except Exception as e:
        rec.update({"status": "error", "error": f"{type(e).__name__}: {e}",
                    "traceback": traceback.format_exc()[-4000:]})
    with open(out_path, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="every (arch x shape) incl. sinkhorn-wmd cells")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--out-dir", default=OUT_DIR)
    args = ap.parse_args()

    todo = []
    if args.all:
        todo = cells() + [("sinkhorn-wmd", "paper_5k"),
                          ("sinkhorn-wmd", "prod_5m"),
                          ("sinkhorn-wmd", "prod_5m_opt")]
    elif args.arch and args.shape:
        todo = [(args.arch, args.shape)]
    else:
        ap.error("--arch/--shape or --all required")

    mesh_name = "pod2x16x16" if args.multi_pod else "pod16x16"
    for arch, shape in todo:
        out_path = os.path.join(args.out_dir, mesh_name,
                                f"{arch}__{shape}.json")
        if args.skip_existing and os.path.exists(out_path):
            with open(out_path) as f:
                if json.load(f).get("status") in ("ok", "skipped"):
                    print(f"[dryrun] {arch} x {shape}: exists, skipping")
                    continue
        t0 = time.perf_counter()
        rec = run_cell(arch, shape, multi_pod=args.multi_pod,
                       out_dir=args.out_dir)
        dt = time.perf_counter() - t0
        status = rec.get("status")
        extra = ""
        if status == "ok":
            ma = rec.get("memory_analysis") or {}
            extra = (f" temp={ma.get('temp_size_in_bytes', 0) / 2**30:.2f}GiB"
                     f" flops={rec.get('jaxpr_cost', {}).get('flops', 0):.3e}"
                     f" coll={rec.get('collectives', {}).get('total', 0):.3e}B")
        elif status == "error":
            extra = " " + rec.get("error", "")[:160]
        print(f"[dryrun] {arch} x {shape} ({mesh_name}): {status}"
              f" ({dt:.1f}s){extra}", flush=True)


if __name__ == "__main__":
    main()
