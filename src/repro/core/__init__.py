"""PASWD core: the paper's Sinkhorn-WMD contribution as composable JAX modules.

Layers (bottom-up):
  cost_matrix     -- euclidean transportation-cost matrix (MXU matmul form)
  formats         -- CSR/ELL sparse layouts + vocab-shard re-bucketing
  sinkhorn        -- paper Algorithm 1, dense (faithful baseline + oracle)
  sparse_sinkhorn -- PASWD: fused SDDMM-SpMM sparse solver (the contribution)
  ot              -- generic Sinkhorn OT (shared with the MoE router)
  convergence     -- while-x-changes early-exit solver
  distributed     -- shard_map multi-chip / multi-pod engine
  kcache          -- cross-query word-id-keyed K/KM + M row caches
  rwmd            -- doc-side RWMD lower bounds (top-k prune prefilter)
  cascade         -- tier-0 centroid screen + LC-RWMD (cascade front tiers)
  guards          -- typed numeric guards (underflow pre-check, non-finite
                     and silent-zero detection, admission validation)
"""
from repro.core.cost_matrix import cdist, cdist_direct, cdist_matmul
from repro.core.formats import (BucketedEll, EllDocs, bucket_by_length,
                                ell_from_dense, ell_from_csc,
                                ell_from_doc_lists, pad_docs,
                                rebucket_for_vocab_shards)
from repro.core.sinkhorn import (SinkhornPrecompute, assemble_precompute,
                                 m_rows, precompute, precompute_rows,
                                 select_query, sinkhorn_wmd_dense)
from repro.core.guards import (GuardError, InvalidQueryError, NumericalError,
                               check_distances, check_finite, check_km_rows,
                               underflow_possible, validate_query)
from repro.core.kcache import KCache, KCacheStats, MCache
from repro.core.rwmd import (assemble_m_stripes, rwmd_bound_batch,
                             rwmd_lower_bound, rwmd_query_side_bound)
from repro.core.cascade import (centroid_bound_batch, doc_centroids,
                                lc_rwmd_bound_batch, min_cost_vectors)
from repro.core.sparse_sinkhorn import (BatchedSinkhornPrecompute,
                                        batched_sinkhorn_loop, pad_k,
                                        precompute_batch, sddmm, spmm,
                                        sddmm_batch, spmm_batch,
                                        sddmm_spmm_type1, sddmm_spmm_type2,
                                        sddmm_spmm_type1_batch,
                                        sddmm_spmm_type2_batch,
                                        sinkhorn_wmd_sparse,
                                        sinkhorn_wmd_sparse_batch,
                                        sinkhorn_wmd_sparse_batch_stripes)
from repro.core.ot import SinkhornResult, sinkhorn_divergence, sinkhorn_plan
from repro.core.convergence import (BatchConvergedWMD, ConvergedWMD,
                                    sinkhorn_wmd_converged,
                                    sinkhorn_wmd_converged_batch)

__all__ = [
    "cdist", "cdist_direct", "cdist_matmul",
    "BucketedEll", "EllDocs", "bucket_by_length",
    "ell_from_dense", "ell_from_csc", "ell_from_doc_lists",
    "pad_docs", "rebucket_for_vocab_shards",
    "SinkhornPrecompute", "assemble_precompute", "m_rows", "precompute",
    "precompute_rows", "select_query", "sinkhorn_wmd_dense",
    "GuardError", "InvalidQueryError", "NumericalError",
    "check_distances", "check_finite", "check_km_rows",
    "underflow_possible", "validate_query",
    "KCache", "KCacheStats", "MCache",
    "assemble_m_stripes", "rwmd_bound_batch", "rwmd_lower_bound",
    "rwmd_query_side_bound",
    "centroid_bound_batch", "doc_centroids", "lc_rwmd_bound_batch",
    "min_cost_vectors",
    "pad_k", "sddmm", "spmm", "sddmm_spmm_type1", "sddmm_spmm_type2",
    "sinkhorn_wmd_sparse",
    "BatchedSinkhornPrecompute", "precompute_batch",
    "batched_sinkhorn_loop", "sddmm_batch", "spmm_batch",
    "sddmm_spmm_type1_batch", "sddmm_spmm_type2_batch",
    "sinkhorn_wmd_sparse_batch", "sinkhorn_wmd_sparse_batch_stripes",
    "SinkhornResult", "sinkhorn_divergence", "sinkhorn_plan",
    "ConvergedWMD", "sinkhorn_wmd_converged",
    "BatchConvergedWMD", "sinkhorn_wmd_converged_batch",
]
