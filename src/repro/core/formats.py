"""Sparse matrix formats for the Sinkhorn-WMD document-frequency matrix ``c``.

The paper stores ``c`` (vocab_size x num_docs, density ~3.5e-5) as CSR and
partitions its *nonzeros* equally across threads with a binary search into the
row pointer (their "2-D partitioning"). A TPU has no efficient scalar CSR
traversal; the adaptation (DESIGN.md section 3) is a **doc-major padded ELL**:

    cols : (num_docs, nnz_max) int32  word-ids, padded with ``pad_id == V``
    vals : (num_docs, nnz_max) f32    normalized counts, padded with 0.0

Fixed-shape doc tiles give equal work per tile *by construction* -- the moral
equivalent of equal-nnz partitioning -- and the pad id points at an appended
all-zero column of K so padding lanes contribute exactly 0 without branches.

``rebucket_for_vocab_shards`` produces the per-shard ELL used by the
distributed engine: shard ``s`` keeps only the nonzeros whose word-id falls in
its vocab stripe, with ids localized; this is how "a word's K column lives
with its nonzero" (DESIGN.md section 4.1) is realized.

Host-side construction uses numpy (data prep); the arrays feed jit'd code.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class EllDocs:
    """Doc-major padded ELL view of the (V x N) document-frequency matrix."""

    cols: np.ndarray  # (N, nnz_max) int32, pad = num_vocab
    vals: np.ndarray  # (N, nnz_max) f32, pad = 0.0
    num_vocab: int    # V (pad id == num_vocab)

    @property
    def num_docs(self) -> int:
        return self.cols.shape[0]

    @property
    def nnz_max(self) -> int:
        return self.cols.shape[1]

    @property
    def nnz(self) -> int:
        return int((self.vals != 0.0).sum())

    @property
    def pad_waste(self) -> float:
        """Fraction of slots that are padding (the ELL regularity tax)."""
        total = self.cols.size
        return 1.0 - self.nnz / total if total else 0.0

    def to_dense(self) -> np.ndarray:
        """(V, N) dense reconstruction -- test/oracle use only."""
        dense = np.zeros((self.num_vocab, self.num_docs), dtype=self.vals.dtype)
        for j in range(self.num_docs):
            live = self.vals[j] != 0.0
            np.add.at(dense[:, j], self.cols[j][live], self.vals[j][live])
        return dense


def _round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


def ell_from_dense(c: np.ndarray, *, nnz_align: int = 8) -> EllDocs:
    """Build ELL from a dense (V, N) matrix. nnz_max rounds up for VREG lanes."""
    v, n = c.shape
    per_doc = (c != 0.0).sum(axis=0)
    nnz_max = max(int(per_doc.max(initial=0)), 1)
    nnz_max = _round_up(nnz_max, nnz_align)
    cols = np.full((n, nnz_max), v, dtype=np.int32)
    vals = np.zeros((n, nnz_max), dtype=np.float32)
    for j in range(n):
        (idx,) = np.nonzero(c[:, j])
        cols[j, : idx.size] = idx
        vals[j, : idx.size] = c[idx, j]
    return EllDocs(cols=cols, vals=vals, num_vocab=v)


def ell_from_csc(indptr: np.ndarray, indices: np.ndarray, values: np.ndarray,
                 num_vocab: int, *, nnz_align: int = 8) -> EllDocs:
    """Build ELL from CSC of the (V, N) matrix (per-doc column slices).

    This is the ingest path from the paper's dataset: documents arrive as
    (word-id, count) lists, i.e. exactly CSC columns of ``c``.
    """
    n = indptr.size - 1
    per_doc = np.diff(indptr)
    nnz_max = max(int(per_doc.max(initial=0)), 1)
    nnz_max = _round_up(nnz_max, nnz_align)
    cols = np.full((n, nnz_max), num_vocab, dtype=np.int32)
    vals = np.zeros((n, nnz_max), dtype=np.float32)
    for j in range(n):
        lo, hi = int(indptr[j]), int(indptr[j + 1])
        cols[j, : hi - lo] = indices[lo:hi]
        vals[j, : hi - lo] = values[lo:hi]
    return EllDocs(cols=cols, vals=vals, num_vocab=num_vocab)


def ell_from_doc_lists(docs: Sequence[Sequence[tuple[int, float]]],
                       num_vocab: int, *, nnz_align: int = 8,
                       normalize: bool = True) -> EllDocs:
    """Build ELL straight from bag-of-words (word_id, count) documents."""
    n = len(docs)
    nnz_max = max(max((len(d) for d in docs), default=1), 1)
    nnz_max = _round_up(nnz_max, nnz_align)
    cols = np.full((n, nnz_max), num_vocab, dtype=np.int32)
    vals = np.zeros((n, nnz_max), dtype=np.float32)
    for j, doc in enumerate(docs):
        tot = sum(cnt for _, cnt in doc) if normalize else 1.0
        for k, (wid, cnt) in enumerate(doc):
            cols[j, k] = wid
            vals[j, k] = cnt / tot if normalize else cnt
    return EllDocs(cols=cols, vals=vals, num_vocab=num_vocab)


def next_pow2(n: int) -> int:
    """Smallest power of two >= n (>= 1). The segment-capacity rule of the
    live corpus: padded row capacity grows in pow2 steps so the device
    program shapes stay stable between growth events."""
    return 1 << max(n - 1, 0).bit_length()


def write_doc_row(cols: np.ndarray, vals: np.ndarray, row: int,
                  doc: Sequence[tuple[int, float]], num_vocab: int, *,
                  normalize: bool = True) -> None:
    """Write one bag-of-words doc into row ``row`` of preallocated ELL
    arrays, in place, clearing the remainder of the row to padding.

    The normalization arithmetic is *identical* to `ell_from_doc_lists`
    (python-float total, same accumulation order, same f32 cast), so a doc
    written incrementally lands bit-for-bit equal to the same doc in a
    one-shot build -- the foundation of the live corpus's incremental ==
    batch contract. Duplicate word-ids within one doc occupy separate
    slots, exactly as the one-shot builders store them (the engine sums
    slot contributions, so duplicates are equivalent to a merged count,
    though not bitwise so -- which is why both paths store them unmerged).
    """
    if len(doc) > cols.shape[1]:
        raise ValueError(f"doc nnz {len(doc)} exceeds row width "
                         f"{cols.shape[1]}")
    cols[row, :] = num_vocab
    vals[row, :] = 0.0
    tot = sum(cnt for _, cnt in doc) if normalize else 1.0
    for k, (wid, cnt) in enumerate(doc):
        cols[row, k] = wid
        vals[row, k] = cnt / tot if normalize else cnt


def ell_with_capacity(ell: EllDocs, capacity: int, *,
                      nnz_max: int | None = None) -> EllDocs:
    """Grow an ELL to ``capacity`` rows (and optionally a wider nnz_max),
    the new slots all padding. The live corpus's segment-growth primitive:
    unlike `pad_docs` this may also widen the nnz axis, so a delta segment
    can absorb a doc longer than anything it has seen."""
    nz = ell.nnz_max if nnz_max is None else nnz_max
    if capacity < ell.num_docs:
        raise ValueError(f"cannot shrink: {capacity} < {ell.num_docs}")
    if nz < ell.nnz_max:
        raise ValueError(f"cannot narrow: {nz} < {ell.nnz_max}")
    if capacity == ell.num_docs and nz == ell.nnz_max:
        return ell
    cols = np.full((capacity, nz), ell.num_vocab, np.int32)
    vals = np.zeros((capacity, nz), np.float32)
    cols[:ell.num_docs, :ell.nnz_max] = ell.cols
    vals[:ell.num_docs, :ell.nnz_max] = ell.vals
    return EllDocs(cols=cols, vals=vals, num_vocab=ell.num_vocab)


def doc_lists_from_ell(ell: EllDocs) -> list[list[tuple[int, float]]]:
    """Recover bag-of-words (word_id, weight) docs from an ELL (pad slots
    dropped; empty/pad rows come back as empty docs). The ingest bridge:
    a frozen corpus built by `make_corpus` feeds a live corpus through
    this (with normalize=False -- the weights are already normalized)."""
    docs = []
    for j in range(ell.num_docs):
        live = ell.vals[j] != 0.0
        docs.append(list(zip(ell.cols[j][live].tolist(),
                             ell.vals[j][live].tolist())))
    return docs


def pad_docs(ell: EllDocs, num_docs: int) -> EllDocs:
    """Pad the doc axis to ``num_docs`` with empty documents (for even shards)."""
    if num_docs < ell.num_docs:
        raise ValueError(f"cannot shrink: {num_docs} < {ell.num_docs}")
    if num_docs == ell.num_docs:
        return ell
    extra = num_docs - ell.num_docs
    cols = np.concatenate(
        [ell.cols, np.full((extra, ell.nnz_max), ell.num_vocab, np.int32)])
    vals = np.concatenate(
        [ell.vals, np.zeros((extra, ell.nnz_max), np.float32)])
    return EllDocs(cols=cols, vals=vals, num_vocab=ell.num_vocab)


@dataclasses.dataclass(frozen=True)
class BucketedEll:
    """Doc-length-bucketed ELL (beyond-paper optimization, EXPERIMENTS.md
    §Perf): one EllDocs per power-of-two length class, so nnz_max tracks the
    bucket's own maximum instead of the global tail.

    The lognormal doc-length distribution of the paper's corpus makes a
    single global nnz_max ~4x larger than the median doc (measured 4.15
    slots/nnz); bucketing cuts padded-slot work to ~1.3 slots/nnz. The
    solver runs per bucket (equal-shape tiles inside each bucket keep the
    equal-work property); ``doc_ids`` maps bucket-local rows back to corpus
    order.
    """

    buckets: tuple[EllDocs, ...]
    doc_ids: tuple[np.ndarray, ...]   # original doc index per bucket row
    num_vocab: int

    @property
    def nnz(self) -> int:
        return sum(b.nnz for b in self.buckets)

    @property
    def total_slots(self) -> int:
        return sum(b.cols.size for b in self.buckets)

    def scatter(self, per_bucket: Sequence[np.ndarray],
                num_docs: int) -> np.ndarray:
        """Reassemble per-bucket (N_b,) results into corpus order."""
        out = np.zeros(num_docs, dtype=per_bucket[0].dtype)
        for ids, vals in zip(self.doc_ids, per_bucket):
            out[ids] = vals[: len(ids)]
        return out


def bucket_by_length(ell: EllDocs, *, nnz_align: int = 8,
                     min_bucket: int = 8) -> BucketedEll:
    """Split docs into power-of-two length classes with per-class nnz_max."""
    lengths = (ell.vals != 0.0).sum(axis=1)
    edges: list[int] = []
    b = max(min_bucket, nnz_align)
    while b < ell.nnz_max:
        edges.append(b)
        b *= 2
    edges.append(max(int(lengths.max(initial=1)), 1))
    buckets, ids = [], []
    lo = 0
    for hi in edges:
        (sel,) = np.nonzero((lengths > lo) & (lengths <= hi))
        lo = hi
        if sel.size == 0:
            continue
        nnz_b = _round_up(hi, nnz_align)
        cols = ell.cols[sel][:, :nnz_b].copy()
        vals = ell.vals[sel][:, :nnz_b].copy()
        # slots beyond nnz_b are guaranteed padding for this bucket
        buckets.append(EllDocs(cols=cols, vals=vals,
                               num_vocab=ell.num_vocab))
        ids.append(sel)
    return BucketedEll(buckets=tuple(buckets), doc_ids=tuple(ids),
                       num_vocab=ell.num_vocab)


def rebucket_for_vocab_shards(ell: EllDocs, num_shards: int,
                              *, nnz_align: int = 8) -> EllDocs:
    """Re-bucket per vocab stripe for `model`-axis sharding.

    Returns an EllDocs whose arrays carry a leading shard axis folded into
    shape (num_shards, N, nnz_max_shard): shard ``s`` holds only nonzeros with
    word-id in [s*Vs, (s+1)*Vs), ids localized to the stripe, pad id == Vs.
    The result is fed to shard_map with the leading axis mapped to `model`.
    """
    if ell.num_vocab % num_shards:
        raise ValueError(
            f"vocab {ell.num_vocab} not divisible by shards {num_shards}")
    vs = ell.num_vocab // num_shards
    n = ell.num_docs
    shard_of = ell.cols // vs  # pads map to shard num_shards (out of range)
    # worst-case nnz per (shard, doc)
    nnz_shard = 1
    for s in range(num_shards):
        per_doc = ((shard_of == s) & (ell.vals != 0.0)).sum(axis=1)
        nnz_shard = max(nnz_shard, int(per_doc.max(initial=0)))
    nnz_shard = _round_up(nnz_shard, nnz_align)
    cols = np.full((num_shards, n, nnz_shard), vs, dtype=np.int32)
    vals = np.zeros((num_shards, n, nnz_shard), dtype=np.float32)
    for s in range(num_shards):
        for j in range(n):
            live = (shard_of[j] == s) & (ell.vals[j] != 0.0)
            k = int(live.sum())
            cols[s, j, :k] = ell.cols[j][live] - s * vs
            vals[s, j, :k] = ell.vals[j][live]
    return EllDocs(cols=cols, vals=vals, num_vocab=vs)
