"""Transportation-cost matrix: pairwise euclidean distance between embeddings.

The paper's hotspot #2 (Table I / Fig. 7): ``M = cdist(vecs[sel], vecs)``.
On Xeon this vectorizes to AVX-512 FMA; on PIUMA it dominates (scalar cores).
On TPU the natural form is the matmul expansion
``|a - b|^2 = |a|^2 + |b|^2 - 2 a.b`` which routes the O(v_r * V * w) work
through the MXU instead of the VPU -- that is the hardware adaptation.
`repro.kernels.cdist` provides the Pallas-tiled version; this module is the
jnp implementation used as both the production fallback and the oracle's base.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def cdist_direct(a: jax.Array, b: jax.Array, *, squared: bool = False) -> jax.Array:
    """O(n*m*w) elementwise form: sqrt(sum((a_i - b_j)^2)). VPU-bound; oracle."""
    d2 = jnp.sum((a[:, None, :] - b[None, :, :]) ** 2, axis=-1)
    return d2 if squared else jnp.sqrt(d2)


def cdist_matmul(a: jax.Array, b: jax.Array, *, squared: bool = False) -> jax.Array:
    """MXU form: |a|^2 + |b|^2 - 2ab, clamped at 0 for fp round-off."""
    a2 = jnp.sum(a * a, axis=-1)[:, None]
    b2 = jnp.sum(b * b, axis=-1)[None, :]
    d2 = jnp.maximum(a2 + b2 - 2.0 * (a @ b.T), 0.0)
    return d2 if squared else jnp.sqrt(d2)


def cdist(a: jax.Array, b: jax.Array, *, squared: bool = False,
          method: str = "matmul") -> jax.Array:
    """Pairwise euclidean distance. a: (n, w), b: (m, w) -> (n, m)."""
    if method == "matmul":
        return cdist_matmul(a, b, squared=squared)
    if method == "direct":
        return cdist_direct(a, b, squared=squared)
    raise ValueError(f"unknown cdist method: {method!r}")
