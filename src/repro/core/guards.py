"""Numeric guards for the Sinkhorn-WMD engine: typed errors instead of
silently-wrong distances.

The paper's O(V^2) entropic formulation has one classic numerical failure
mode: K = exp(-lambda * M) underflows. In fp32 with flush-to-zero the
smallest positive value is 2^-149, so a K entry is representable only while
``lambda * M[i, j] < 149 * ln 2 ~ 103.28``. With euclidean costs
``M[i, j] <= 2 * max_i ||vec_i||``, which gives the *a-priori* risk gate
`underflow_possible`. Past that point whole K rows (excluding the always-1
self column) flush to zero, the solver's safe-reciprocal clamps keep every
iterate finite, and the distances come out as EXACT ZEROS -- not NaN -- so a
finite-only check cannot catch it. Measured on the bench corpus: at
lambda = 30 11/18 real query rows have an identically-zero K*M stripe and
6/18 (query, doc) distances collapse to 0.0; at the shipped lambda = 1.0
none do and the gate is off.

Two layers of defense, both read-only (guards never perturb computed bits):

  pre-check   `check_km_rows` -- a real query row whose K*M stripe is
              identically zero has lost ALL cost signal; the solve is
              guaranteed garbage, so fail fast before paying for it.
  post-check  `check_distances` -- non-finite distances always raise;
              exact-zero (query, doc) cells raise only under the risk gate
              (a zero distance to a non-empty doc is otherwise legitimate
              for a doc identical to the query... except entropic WMD with
              lambda < inf never returns exactly 0.0 for a real transport
              problem -- but duplicate-free corpora are not a contract we
              own, so the gate keeps the check conservative), with
              empty/pad docs masked out (they legitimately solve to 0).

`validate_query` is the admission-boundary guard (`InvalidQueryError`):
malformed query histograms are rejected before they can poison a whole
coalesced batch.

All guards raise subclasses of `GuardError` so callers can catch the
family; `serving.resilience` maps them to non-retryable failures (retrying
a deterministic numerical error is wasted work).
"""
from __future__ import annotations

import math

import numpy as np

# fp32 smallest positive subnormal is 2^-149; exp(-x) flushes to +0.0 once
# x > 149 * ln 2. This is the hard floor -- with subnormals disabled (FTZ)
# the effective floor is the smallest *normal* (2^-126), so the gate below
# uses the conservative (larger-coverage) subnormal limit.
_FP32_EXP_UNDERFLOW = 149.0 * math.log(2.0)     # ~103.2789


class GuardError(RuntimeError):
    """Base class of every typed guard failure."""


class NumericalError(GuardError):
    """Sinkhorn output or precompute failed a numeric invariant.

    Carries structured ``context`` (which check fired, lambda, offending
    row/cell counts) for ops triage; deterministic for a given input, so
    NOT retryable."""

    def __init__(self, message: str, **context):
        super().__init__(message)
        self.context = context


class InvalidQueryError(GuardError):
    """A query histogram failed admission validation (wrong shape,
    non-finite, negative, or all-zero mass). Raised before dispatch; the
    serving layer quarantines and counts these, never batching them."""

    def __init__(self, message: str, **context):
        super().__init__(message)
        self.context = context


def validate_query(r, vocab_size: int | None = None) -> np.ndarray:
    """Admission-boundary validation of one query histogram.

    Returns ``r`` as an ndarray when valid; raises `InvalidQueryError` on
    non-array input, wrong rank/length (when ``vocab_size`` is given),
    non-finite entries, negative mass, or an all-zero row (no words ->
    no transport problem)."""
    try:
        arr = np.asarray(r)
    except Exception as e:                                  # ragged/object
        raise InvalidQueryError(f"query is not array-like: {e!r}") from e
    if arr.ndim != 1:
        raise InvalidQueryError(
            f"query must be 1-D, got shape {arr.shape}", shape=arr.shape)
    if not np.issubdtype(arr.dtype, np.number) or \
            np.issubdtype(arr.dtype, np.complexfloating):
        raise InvalidQueryError(
            f"query dtype must be real-numeric, got {arr.dtype}",
            dtype=str(arr.dtype))
    if vocab_size is not None and arr.shape[0] != vocab_size:
        raise InvalidQueryError(
            f"query length {arr.shape[0]} != vocab size {vocab_size}",
            length=int(arr.shape[0]), vocab_size=int(vocab_size))
    if not np.isfinite(arr).all():
        bad = int(np.size(arr) - np.isfinite(arr).sum())
        raise InvalidQueryError(
            f"query has {bad} non-finite entries", nonfinite=bad)
    if np.any(arr < 0):
        raise InvalidQueryError(
            f"query has {int((arr < 0).sum())} negative entries",
            negative=int((arr < 0).sum()))
    if not np.any(arr > 0):
        raise InvalidQueryError("query has zero total mass (all-zero row)")
    return arr


def underflow_possible(lamb: float, max_vec_norm: float) -> bool:
    """A-priori risk gate: can K = exp(-lambda * M) underflow to zero for
    this (lambda, embedding) pair?  Euclidean costs are bounded by
    ``2 * max ||vec||``, so underflow is impossible while
    ``lambda * 2 * max_norm`` stays below the fp32 exp underflow limit.
    False at every shipped config (lambda = 1.0); the expensive zero-cell
    post-check only arms when this is True."""
    return float(lamb) * 2.0 * float(max_vec_norm) >= _FP32_EXP_UNDERFLOW


def check_finite(x, what: str = "array", **context) -> None:
    """Raise `NumericalError` if ``x`` has any NaN/Inf entry. Works on
    numpy and jax arrays (pulls to host)."""
    arr = np.asarray(x)
    if np.isfinite(arr).all():
        return
    nonfinite = int(np.size(arr) - np.isfinite(arr).sum())
    raise NumericalError(
        f"{what} has {nonfinite}/{arr.size} non-finite entries",
        check="finite", what=what, nonfinite=nonfinite, **context)


def check_km_rows(km_stripes, row_mask, *, lamb: float | None = None) -> None:
    """Lambda-underflow pre-check on assembled K*M stripes.

    ``km_stripes``: (S, Q, v_r, Vloc+1) K*M rows from the cache assembly,
    an unsharded (Q, v_r, V) stripe, or an already-reduced (Q, v_r) row-max
    (so callers can do the big reduction on device and ship only Q x v_r
    scalars to host); ``row_mask``: (Q, v_r) with 0 marking pad/filler
    rows. A REAL row whose K*M stripe is identically zero across all
    shards has underflowed (K's self-column is exactly 1 but M's self-cost
    is 0, so K*M keeps no signal to hide behind) -- the solve would return
    silent zeros, so fail fast before paying for it."""
    km = np.asarray(km_stripes)
    mask = np.asarray(row_mask) > 0
    if not mask.any():
        return
    # max |K*M| per (Q, v_r) row, reduced over shard and vocab columns
    rowmax = np.abs(km)
    if rowmax.ndim >= 3:
        rowmax = rowmax.max(axis=-1)              # drop vocab columns
    if rowmax.ndim == 3:
        rowmax = rowmax.max(axis=0)               # drop the shard axis
    dead = mask & (rowmax == 0.0)
    if not dead.any():
        return
    n_dead = int(dead.sum())
    n_real = int(mask.sum())
    q_hit = np.nonzero(dead.any(axis=-1))[0].tolist()
    raise NumericalError(
        f"K*M rows underflowed to zero for {n_dead}/{n_real} real query "
        f"rows (queries {q_hit}): lambda"
        f"{f'={lamb:g} ' if lamb is not None else ' '}is too large for "
        f"fp32 -- exp(-lambda*M) flushed to zero and the Sinkhorn solve "
        f"would silently return zero distances",
        check="km_underflow", dead_rows=n_dead, real_rows=n_real,
        queries=q_hit, lamb=lamb)


def check_distances(d, *, lamb: float | None = None,
                    risk: bool = False,
                    empty_doc_mask: np.ndarray | None = None,
                    what: str = "distances") -> None:
    """Post-check on final (..., N) WMD distances.

    Non-finite entries always raise. Exact-zero (query, doc) cells raise
    only when ``risk`` is set (see `underflow_possible`) -- entropic
    distances of real transport problems are strictly positive, so under
    an armed gate a 0.0 cell is underflow, not similarity. ``empty_doc_mask``
    (N,) marks docs with zero total mass, which legitimately solve to 0 and
    are exempt."""
    arr = np.asarray(d)
    check_finite(arr, what, lamb=lamb)
    if not risk or arr.size == 0:
        return
    zero = arr == 0.0
    if empty_doc_mask is not None and zero.any():
        zero = zero & ~np.asarray(empty_doc_mask, bool)
    if not zero.any():
        return
    n_zero = int(zero.sum())
    raise NumericalError(
        f"{what}: {n_zero}/{arr.size} (query, doc) cells are exactly zero "
        f"under an armed underflow gate (lambda"
        f"{f'={lamb:g}' if lamb is not None else ''} too large for fp32): "
        f"K = exp(-lambda*M) flushed to zero and the solver's "
        f"safe-reciprocal clamps turned the result into silent zeros",
        check="zero_distance", zeros=n_zero, total=int(arr.size), lamb=lamb)
