"""PASWD: the paper's sparse-heavy Sinkhorn-WMD with fused SDDMM-SpMM.

This is the paper's contribution, re-architected for TPU (DESIGN.md sections
2-3). The document-frequency matrix is doc-major padded ELL (`core.formats`);
the SDDMM samples only the nnz dot products, and the fusion reuses the
*single* VMEM gather of K columns for both the SDDMM contraction and the SpMM
contraction (K_over_r differs from K only by the per-row 1/r scale):

    SDDMM : w[j,k] = sum_i K[i, cols[j,k]] * u[i,j]
            v[j,k] = vals[j,k] / w[j,k]
    SpMM  : x[i,j] = (1/r[i]) * sum_k K[i, cols[j,k]] * v[j,k]

type2 (final distance) swaps the SpMM operand to K.*M and reduces in-kernel:

    WMD[j] = sum_i u[i,j] * sum_k (K.*M)[i, cols[j,k]] * v[j,k]

Three execution paths, selected by ``impl`` (one table, shared by the
single-query and the batched solver -- see `_resolve_impl`):
  * "fused"    -- single gather per iteration (jnp). Production jnp path and
                  oracle for the Pallas kernel.
  * "unfused"  -- separate SDDMM / SpMM with independent gathers, mirroring
                  the paper's pre-fusion baseline (Fig. 9 numerator).
  * "kernel"   -- `repro.kernels.ops` Pallas kernels (interpret=True on CPU).

All paths consume K padded with one trailing zero column so ELL pad slots
(col == V) contribute exactly zero.

Batched engine & cache blocking
-------------------------------
The batched iteration's nominal working set is the gathered tensor
``(Q, N, nnz_max, v_r) * 4B`` -- at a bulk shape (Q=16, N=1024, nnz=64,
v_r=16) that is 64 MB, far past CPU LLC (and any VMEM budget), which is
where `bench_query_batch.py` showed batched throughput collapsing to
sequential parity. ``docs_chunk`` cache-blocks the engine at two levels:

  * per-op (``sddmm_spmm_type{1,2}_batch(docs_chunk=...)``): the SAME fused
    math over static N-chunks, live gather ``(Q, docs_chunk, nnz, v_r)``.
    Bitwise exact -- every output element's FP op sequence is unchanged
    because both contractions reduce within a single doc (over v_r resp.
    nnz), never across docs. Used inside iteration-major loops that must
    keep ONE collective per iteration (`core.distributed`) or global
    per-query convergence state (`core.convergence`).
  * per-solve (`sinkhorn_wmd_sparse_batch(docs_chunk=...)`): docs are
    *independent* OT problems, so the chunk loop hoists OUTSIDE the whole
    Sinkhorn loop -- each chunk runs all of its iterations while its
    ``(Q, v_r, docs_chunk)`` iterate (and the chunk's ELL slice) stays
    cache-resident across iterations, instead of sweeping the full
    ``(Q, v_r, N)`` state every iteration. Measured 1.5-3.3x over the
    iteration-major unchunked loop at bulk shapes (N >= 1024, Q = 16) on a
    2-core CPU; identical results.

Non-dividing N is handled by padding docs with ELL pad slots (col = V ->
the zero K column, val = 0), whose outputs are sliced off. The chunk loop
is unrolled in-trace (preserving XLA's gather-into-contraction fusion; a
lax.scan fallback bounds HLO size past MAX_UNROLLED_CHUNKS). The Pallas
analogue is the ``docs_blk`` / ``q_blk`` grid tiling in
`kernels.sddmm_spmm` ("Batched kernel & cache blocking" there).

Early exit: `batched_sinkhorn_loop` is the shared while-loop core -- per
query, iteration stops contributing writes once its relative iterate delta
drops below ``tol`` (freeze masks), and the loop exits when all queries
converge or ``max_iter`` hits. With ``tol = 0.0`` no query ever freezes
(``delta >= 0`` always holds), so results equal the fixed-``max_iter``
loop exactly; the solvers skip the loop's bookkeeping entirely in that
case and run a plain fori_loop.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.cost_matrix import cdist
from repro.core.sinkhorn import SinkhornPrecompute, precompute

_IMPLS = ("fused", "unfused", "kernel")

# Reciprocal guard: K = exp(-lamb*M) underflows f32 for far word pairs, and
# the u = 1/x nonlinearity amplifies it to inf*0 = nan. Clamping the
# denominator at TINY is exact for healthy values and replaces inf by a huge
# finite number otherwise (the paper sidesteps this with f64 inputs).
TINY = 1e-30


def safe_recip(x: jax.Array) -> jax.Array:
    return 1.0 / jnp.maximum(x, TINY)


def pad_k(k: jax.Array) -> jax.Array:
    """Append a zero column: gathers of the ELL pad id (== V) read zeros.

    Works on both (v_r, V) single-query and (Q, v_r, V) batched stripes --
    the pad column is always appended on the vocab (last) axis.
    """
    widths = [(0, 0)] * (k.ndim - 1) + [(0, 1)]
    return jnp.pad(k, widths)


# ---------------------------------------------------------------------------
# jnp building blocks (also serve as kernel oracles via kernels/ref.py)
# ---------------------------------------------------------------------------

def gather_k(k_pad: jax.Array, cols: jax.Array) -> jax.Array:
    """Gather K columns per ELL slot: (v_r, V+1), (N, nnz) -> (N, nnz, v_r)."""
    return k_pad.T[cols]


def sddmm(k_pad: jax.Array, u: jax.Array, cols: jax.Array,
          vals: jax.Array) -> jax.Array:
    """Sampled dense-dense matmul: v[j,k] = vals[j,k] / (K^T u)[cols[j,k], j]."""
    kg = gather_k(k_pad, cols)                       # gather #1
    w = jnp.einsum("nki,in->nk", kg, u)
    return jnp.where(vals != 0.0, vals * safe_recip(w), 0.0)


def spmm(kor_pad: jax.Array, v: jax.Array, cols: jax.Array) -> jax.Array:
    """x[i,j] = sum_k K_over_r[i, cols[j,k]] * v[j,k] -- re-gathers K."""
    kg = gather_k(kor_pad, cols)                     # gather #2 (unfused cost)
    return jnp.einsum("nki,nk->in", kg, v)


def sddmm_spmm_type1(k_pad: jax.Array, r_sel: jax.Array, u: jax.Array,
                     cols: jax.Array, vals: jax.Array) -> jax.Array:
    """Fused iteration body: one gather feeds both contractions."""
    kg = gather_k(k_pad, cols)                       # the ONLY gather
    w = jnp.einsum("nki,in->nk", kg, u)
    v = jnp.where(vals != 0.0, vals * safe_recip(w), 0.0)
    x = jnp.einsum("nki,nk->in", kg, v)
    return x / r_sel[:, None]


def sddmm_spmm_type2(k_pad: jax.Array, km_pad: jax.Array, u: jax.Array,
                     cols: jax.Array, vals: jax.Array) -> jax.Array:
    """Fused final distance: 3 dense (K, K.*M, u) + 2 sparse (cols, vals)."""
    kg = gather_k(k_pad, cols)
    kmg = gather_k(km_pad, cols)
    w = jnp.einsum("nki,in->nk", kg, u)
    v = jnp.where(vals != 0.0, vals * safe_recip(w), 0.0)
    xm = jnp.einsum("nki,nk->in", kmg, v)
    return jnp.sum(u * xm, axis=0)                   # (N,)


def _type1_unfused(k_pad: jax.Array, r_sel: jax.Array, u: jax.Array,
                   cols: jax.Array, vals: jax.Array) -> jax.Array:
    # independent gathers, with a barrier so XLA cannot CSE them back
    # into the fused form (keeps the Fig. 9 baseline honest).
    v = sddmm(k_pad, u, cols, vals)
    v = jax.lax.optimization_barrier(v)
    return spmm(k_pad / r_sel[:, None], v, cols)


def _type1_unfused_batch(k_pad: jax.Array, r_sel: jax.Array, u: jax.Array,
                         cols: jax.Array, vals: jax.Array) -> jax.Array:
    v = sddmm_batch(k_pad, u, cols, vals)
    v = jax.lax.optimization_barrier(v)
    return spmm_batch(k_pad / r_sel[..., None], v, cols)


def _resolve_impl(kind: str, impl: str, batched: bool):
    """The ONE impl dispatch table, shared by the single-query and batched
    solvers (and `core.distributed`). kind: "type1" (iteration contraction,
    signature (k_pad, r_sel, u, cols, vals)) or "type2" (final distance,
    signature (k_pad, km_pad, u, cols, vals)). Batched "type1"/"type2"
    additionally accept ``docs_chunk=``."""
    if impl not in _IMPLS:
        raise ValueError(f"impl must be one of {_IMPLS}, got {impl!r}")
    if impl == "kernel":
        from repro.kernels import ops
        table = {("type1", False): ops.sddmm_spmm_type1,
                 ("type2", False): ops.sddmm_spmm_type2,
                 ("type1", True): _kernel_type1_batch,
                 ("type2", True): _kernel_type2_batch}
    else:
        # the unfused baseline shares the fused final distance (the paper's
        # Fig. 9 baseline differs only in the iteration body).
        t1 = _type1_unfused if impl == "unfused" else sddmm_spmm_type1
        t1b = (_unfused_batch_ignoring_chunk if impl == "unfused"
               else sddmm_spmm_type1_batch)
        t2b = (_unfused_final_batch_ignoring_chunk if impl == "unfused"
               else sddmm_spmm_type2_batch)
        table = {("type1", False): t1,
                 ("type2", False): sddmm_spmm_type2,
                 ("type1", True): t1b,
                 ("type2", True): t2b}
    return table[(kind, batched)]


def _unfused_batch_ignoring_chunk(k_pad, r_sel, u, cols, vals, *,
                                  docs_chunk=None):
    del docs_chunk  # the baseline stays deliberately unblocked
    return _type1_unfused_batch(k_pad, r_sel, u, cols, vals)


def _unfused_final_batch_ignoring_chunk(k_pad, km_pad, u, cols, vals, *,
                                        docs_chunk=None):
    # same rule for the final distance: the unfused baseline must stay
    # unblocked END TO END or fused-vs-unfused perf comparisons mix modes.
    del docs_chunk
    return sddmm_spmm_type2_batch(k_pad, km_pad, u, cols, vals)


def _kernel_type1_batch(k_pad, r_sel, u, cols, vals, *, docs_chunk=None):
    # the kernel's native cache blocking IS its doc-tile grid: docs_chunk
    # maps onto docs_blk instead of an outer scan (None/0 = default tile).
    from repro.kernels import ops
    kw = {} if not docs_chunk else {"docs_blk": docs_chunk}
    return ops.sddmm_spmm_type1_batch(k_pad, r_sel, u, cols, vals, **kw)


def _kernel_type2_batch(k_pad, km_pad, u, cols, vals, *, docs_chunk=None):
    from repro.kernels import ops
    kw = {} if not docs_chunk else {"docs_blk": docs_chunk}
    return ops.sddmm_spmm_type2_batch(k_pad, km_pad, u, cols, vals, **kw)


def _iteration(impl: str, pre_kpad: jax.Array, r_sel: jax.Array,
               x: jax.Array, cols: jax.Array, vals: jax.Array) -> jax.Array:
    return _resolve_impl("type1", impl, False)(
        pre_kpad, r_sel, safe_recip(x), cols, vals)


def _final(impl: str, k_pad: jax.Array, km_pad: jax.Array, u: jax.Array,
           cols: jax.Array, vals: jax.Array) -> jax.Array:
    return _resolve_impl("type2", impl, False)(k_pad, km_pad, u, cols, vals)


# ---------------------------------------------------------------------------
# Full solver
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("max_iter", "impl"))
def sinkhorn_wmd_sparse(sel_idx: jax.Array, r_sel: jax.Array,
                        cols: jax.Array, vals: jax.Array, vecs: jax.Array,
                        lamb: float, max_iter: int,
                        impl: str = "fused") -> jax.Array:
    """Sparse PASWD Sinkhorn-WMD. Returns (N,) distances.

    Args:
      sel_idx: (v_r,) nonzero-word indices of the query (host-selected).
      r_sel:   (v_r,) normalized query frequencies.
      cols:    (N, nnz_max) ELL word ids (pad == V).
      vals:    (N, nnz_max) ELL normalized counts (pad == 0).
      vecs:    (V, w) embeddings.
      impl:    "fused" | "unfused" | "kernel".
    """
    pre = precompute(sel_idx, r_sel, vecs, lamb)
    return sinkhorn_wmd_sparse_pre(pre, cols, vals, max_iter, impl)


def sinkhorn_wmd_sparse_pre(pre: SinkhornPrecompute, cols: jax.Array,
                            vals: jax.Array, max_iter: int,
                            impl: str = "fused") -> jax.Array:
    """Solver core on precomputed matrices (shared with the distributed path)."""
    k_pad = pad_k(pre.K)
    km_pad = pad_k(pre.KM)
    v_r = pre.r.shape[0]
    n = cols.shape[0]
    x0 = jnp.full((v_r, n), 1.0 / v_r, dtype=pre.K.dtype)

    def body(_, x):
        return _iteration(impl, k_pad, pre.r, x, cols, vals)

    x = jax.lax.fori_loop(0, max_iter, body, x0)
    u = safe_recip(x)
    return _final(impl, k_pad, km_pad, u, cols, vals)


# ---------------------------------------------------------------------------
# Multi-query batched engine: (Q, v_r, N) with ONE shared ELL gather
# ---------------------------------------------------------------------------
#
# The paper batches one query against N docs; the production axis on top of
# that is Q concurrent queries. The ELL structure (cols, vals) is a property
# of the *corpus*, identical for every query, so the irregular part of the
# iteration -- the gather of K columns at the nonzero word-ids -- becomes ONE
# batched gather op serving all Q queries (same index set, Q stripes), laid
# out (Q, N, nnz, v_r) so both downstream contractions consume it without
# transposing (see gather_k_batch). Everything downstream is dense einsum
# with a leading Q batch axis.
#
# Mixed-size queries ride the exact mask-based padding of core.distributed:
# pad rows carry r = 1 and a zeroed K row, so they contribute exactly zero
# to every w, x and WMD (no epsilon approximations).


class BatchedSinkhornPrecompute(NamedTuple):
    """Per-query iteration-invariant stripes, stacked on a leading Q axis."""

    K: jax.Array   # (Q, v_r, V) exp(-lambda * M), pad rows zeroed
    KM: jax.Array  # (Q, v_r, V) K .* M
    r: jax.Array   # (Q, v_r) pad rows carry 1.0


def precompute_batch(sel_idx: jax.Array, r_sel: jax.Array, vecs: jax.Array,
                     lamb: float, row_mask: jax.Array | None = None
                     ) -> BatchedSinkhornPrecompute:
    """Batched K / K.*M stripes for Q queries bucketed to a common v_r.

    Args:
      sel_idx:  (Q, v_r) word ids per query (pad slots point at word 0).
      r_sel:    (Q, v_r) frequencies (pad rows = 1.0, see pad_query).
      vecs:     (V, w) embeddings.
      row_mask: (Q, v_r) 1.0 for real rows, 0.0 for pad rows; None = all real.
    """
    m = jax.vmap(lambda a: cdist(a, vecs))(vecs[sel_idx])    # (Q, v_r, V)
    k = jnp.exp(-lamb * m)
    if row_mask is not None:
        k = k * row_mask[..., None]
    return BatchedSinkhornPrecompute(K=k, KM=k * m, r=r_sel)


def gather_k_batch(k_pad: jax.Array, cols: jax.Array) -> jax.Array:
    """One batched gather serving all Q queries.

    (Q, v_r, V+1), (N, nnz) -> (Q, N, nnz, v_r): one gather op whose batch
    dims (q, n) lead, so both downstream contractions consume it with NO
    transposition of the large tensor (the (N, nnz, Q, v_r) alternative
    forces XLA to re-lay it out before every dot -- measured ~2.3x slower
    on CPU).
    """
    return jnp.transpose(k_pad, (0, 2, 1))[:, cols]


# Above this many chunks the doc loop rolls up into a lax.scan: the HLO
# stays O(1) in S at the cost of defeating XLA's cross-op gather fusion
# inside the loop body (measured up to ~4x slower on CPU) -- callers wanting
# peak throughput should pick docs_chunk so S stays under this.
MAX_UNROLLED_CHUNKS = 64


def _chunk_over_docs(f, u: jax.Array, cols: jax.Array, vals: jax.Array,
                     docs_chunk: int | None, pad_col: int) -> jax.Array:
    """Apply ``f(u_c, cols_c, vals_c)`` over static N-chunks (cache blocking).

    ``f`` maps a doc slice to an output whose LAST axis is the doc axis.
    Chunking is bitwise exact (see module docstring); a non-dividing N is
    padded with ELL pad slots (col = pad_col -> zero K column, val = 0) and
    the pad docs are sliced off the output.

    The chunk loop is UNROLLED into the trace (independent per-chunk chains
    concatenated on the doc axis): each chain keeps XLA's gather-into-
    contraction fusion, so the gathered (Q, docs_chunk, nnz, v_r) block is
    never materialized whole. A `lax.scan` spelling is kept as fallback for
    very large chunk counts (> MAX_UNROLLED_CHUNKS) where HLO size matters
    more than the fusion loss.
    """
    n = cols.shape[0]
    if not docs_chunk or docs_chunk >= n:   # None and 0 both mean unchunked
        return f(u, cols, vals)
    pad = (-n) % docs_chunk
    if pad:
        cols = jnp.pad(cols, ((0, pad), (0, 0)), constant_values=pad_col)
        vals = jnp.pad(vals, ((0, pad), (0, 0)))
        u = jnp.pad(u, ((0, 0), (0, 0), (0, pad)))
    s = (n + pad) // docs_chunk
    if s <= MAX_UNROLLED_CHUNKS:
        outs = [f(u[:, :, c * docs_chunk:(c + 1) * docs_chunk],
                  cols[c * docs_chunk:(c + 1) * docs_chunk],
                  vals[c * docs_chunk:(c + 1) * docs_chunk])
                for c in range(s)]
        return jnp.concatenate(outs, axis=-1)[..., :n]
    q, v_r = u.shape[0], u.shape[1]
    nnz = cols.shape[1]
    operand = (jnp.moveaxis(u.reshape(q, v_r, s, docs_chunk), 2, 0),
               cols.reshape(s, docs_chunk, nnz),
               vals.reshape(s, docs_chunk, nnz))

    def step(_, op):
        u_c, cols_c, vals_c = op
        return None, f(u_c, cols_c, vals_c)

    _, out = jax.lax.scan(step, None, operand)       # (S, ..., docs_chunk)
    out = jnp.moveaxis(out, 0, -2)
    return out.reshape(*out.shape[:-2], s * docs_chunk)[..., :n]


def sddmm_batch(k_pad: jax.Array, u: jax.Array, cols: jax.Array,
                vals: jax.Array) -> jax.Array:
    """Batched sampled dense-dense matmul with its own gather (unfused)."""
    kg = gather_k_batch(k_pad, cols)                 # gather #1
    w = jnp.einsum("qnki,qin->qnk", kg, u)
    return jnp.where(vals[None] != 0.0, vals[None] * safe_recip(w), 0.0)


def spmm_batch(kor_pad: jax.Array, v: jax.Array, cols: jax.Array
               ) -> jax.Array:
    """Batched SpMM -- re-gathers K (the unfused baseline's second gather)."""
    kg = gather_k_batch(kor_pad, cols)               # gather #2 (unfused cost)
    return jnp.einsum("qnki,qnk->qin", kg, v)


def sddmm_spmm_type1_batch(k_pad: jax.Array, r_sel: jax.Array, u: jax.Array,
                           cols: jax.Array, vals: jax.Array, *,
                           docs_chunk: int | None = None) -> jax.Array:
    """Batched fused iteration body: (Q, v_r, N) <- one gather, two einsums.

    Same math per query as `sddmm_spmm_type1`; the explicit q-leading einsum
    spelling compiles to dot_generals whose batch dims (q, n) are already
    the gathered tensor's leading dims (measured ~2x faster than the
    vmap-of-single lowering on CPU, ~4x faster than a (N, nnz, Q, v_r)
    gather layout).

    ``docs_chunk`` scans the same math over N-chunks so the live gathered
    working set is (Q, docs_chunk, nnz, v_r) -- bitwise identical, see
    "Batched engine & cache blocking" in the module docstring.

    k_pad (Q, v_r, V+1), r_sel (Q, v_r), u (Q, v_r, N), cols/vals (N, nnz).
    """
    def chunk(u_c, cols_c, vals_c):
        kg = gather_k_batch(k_pad, cols_c)           # the ONLY gather
        w = jnp.einsum("qnki,qin->qnk", kg, u_c)
        v = jnp.where(vals_c[None] != 0.0,
                      vals_c[None] * safe_recip(w), 0.0)
        x = jnp.einsum("qnki,qnk->qin", kg, v)
        return x / r_sel[:, :, None]

    return _chunk_over_docs(chunk, u, cols, vals, docs_chunk,
                            pad_col=k_pad.shape[-1] - 1)


def sddmm_spmm_type2_batch(k_pad: jax.Array, km_pad: jax.Array, u: jax.Array,
                           cols: jax.Array, vals: jax.Array, *,
                           docs_chunk: int | None = None) -> jax.Array:
    """Batched fused final distance: (Q, N) WMD for all queries at once.

    The per-doc reduction is spelled sum_k v * <(K.*M) col, u> -- i.e. the
    u contraction happens inside the dot_general and the outer reduce runs
    over the nnz (last) axis, whose extent is chunk-independent. That keeps
    ``docs_chunk`` bitwise exact: a reduce over the v_r (middle) axis would
    let XLA's CPU emitter reassociate differently per doc-chunk shape.
    """
    def chunk(u_c, cols_c, vals_c):
        kg = gather_k_batch(k_pad, cols_c)
        kmg = gather_k_batch(km_pad, cols_c)
        w = jnp.einsum("qnki,qin->qnk", kg, u_c)
        v = jnp.where(vals_c[None] != 0.0,
                      vals_c[None] * safe_recip(w), 0.0)
        wm = jnp.einsum("qnki,qin->qnk", kmg, u_c)
        return jnp.sum(wm * v, axis=-1)              # (Q, docs)

    return _chunk_over_docs(chunk, u, cols, vals, docs_chunk,
                            pad_col=k_pad.shape[-1] - 1)


def _iteration_batch(impl: str, k_pad: jax.Array, r_sel: jax.Array,
                     x: jax.Array, cols: jax.Array, vals: jax.Array,
                     docs_chunk: int | None = None) -> jax.Array:
    return _resolve_impl("type1", impl, True)(
        k_pad, r_sel, safe_recip(x), cols, vals, docs_chunk=docs_chunk)


def _final_batch(impl: str, k_pad: jax.Array, km_pad: jax.Array,
                 u: jax.Array, cols: jax.Array, vals: jax.Array,
                 docs_chunk: int | None = None) -> jax.Array:
    return _resolve_impl("type2", impl, True)(
        k_pad, km_pad, u, cols, vals, docs_chunk=docs_chunk)


def batched_sinkhorn_loop(iteration, x0: jax.Array, *, max_iter: int,
                          tol: float | jax.Array = 0.0,
                          delta_all_reduce=None):
    """Early-exit Sinkhorn loop with per-query freeze masks (shared core).

    ``iteration`` maps x -> x_new for the whole (Q, v_r, N) batch. A query
    whose relative iterate delta drops below ``tol`` is *frozen*: its x block
    stops being written (freezing is exact -- queries never interact), and
    the loop exits when every query has converged or at ``max_iter``. With
    ``tol = 0.0`` no query ever freezes (``delta >= 0.0`` always holds, even
    at an exact fixpoint), so all ``max_iter`` iterations run and the result
    equals the fixed-``max_iter`` fori_loop exactly -- callers on a fixed
    budget should prefer a plain fori_loop and skip the delta bookkeeping.

    ``delta_all_reduce`` (distributed hook): maps the (Q,) local delta to the
    global one, e.g. a pmax over mesh axes -- required under shard_map where
    each device sees only its doc slice but the vote must be unanimous.

    Returns (x, delta, n_iter): final iterate, per-query relative |dx|_inf,
    and per-query executed iteration counts (Q,) int32.
    """
    q = x0.shape[0]

    def cond(carry):
        _, delta, _, it = carry
        return (it < max_iter) & jnp.any(delta >= tol)

    def body(carry):
        x, delta, n_iter, it = carry
        active = delta >= tol                              # (Q,)
        x_new = iteration(x)
        # relative iterate delta: x spans a huge dynamic range (x ~ K-scale),
        # so an absolute norm would never cross tol for strongly regularized
        # K (same rationale as core.convergence).
        rel = jnp.max(jnp.abs(x_new - x) / (jnp.abs(x) + 1e-30),
                      axis=(1, 2))                         # per-query delta
        if delta_all_reduce is not None:
            rel = delta_all_reduce(rel)
        x = jnp.where(active[:, None, None], x_new, x)     # freeze converged
        delta = jnp.where(active, rel, delta)
        n_iter = n_iter + active.astype(n_iter.dtype)
        return x, delta, n_iter, it + 1

    x, delta, n_iter, _ = jax.lax.while_loop(
        cond, body, (x0, jnp.full((q,), jnp.inf, x0.dtype),
                     jnp.zeros((q,), jnp.int32), jnp.asarray(0)))
    return x, delta, n_iter


@functools.partial(jax.jit,
                   static_argnames=("max_iter", "impl", "docs_chunk", "tol"))
def sinkhorn_wmd_sparse_batch(sel_idx: jax.Array, r_sel: jax.Array,
                              cols: jax.Array, vals: jax.Array,
                              vecs: jax.Array, lamb: float, max_iter: int,
                              row_mask: jax.Array | None = None,
                              impl: str = "fused",
                              docs_chunk: int | None = None,
                              tol: float = 0.0) -> jax.Array:
    """Multi-query sparse PASWD Sinkhorn-WMD. Returns (Q, N) distances.

    The per-query math is identical to `sinkhorn_wmd_sparse` with the same
    ``impl``; queries never interact -- the batch axis only amortizes the
    ELL gather, the dispatch, and the K precompute. Matches the sequential
    per-query solve to fp32 tolerance.

    impl:       "fused" | "unfused" | "kernel" (same table as the
                single-query solver).
    docs_chunk: cache-block the SOLVE over N-chunks of this size: the chunk
                loop sits outside the Sinkhorn loop (docs are independent
                OT problems), so each chunk's (Q, v_r, docs_chunk) iterate
                stays cache-resident across all its iterations. Identical
                results (fp32; bitwise per chunk).
    tol:        early-exit tolerance for the per-query freeze masks,
                applied per chunk (a query's docs-chunk block freezes when
                ITS delta crosses tol); 0.0 (default) reproduces the
                fixed-``max_iter`` loop exactly.
    """
    pre = precompute_batch(sel_idx, r_sel, vecs, lamb, row_mask)
    return _solve_batch_stripes(pad_k(pre.K), pad_k(pre.KM), pre.r,
                                cols, vals, max_iter=max_iter, impl=impl,
                                docs_chunk=docs_chunk, tol=tol)


def _solve_batch_stripes(k_pad: jax.Array, km_pad: jax.Array,
                         r_sel: jax.Array, cols: jax.Array, vals: jax.Array,
                         *, max_iter: int, impl: str,
                         docs_chunk: int | None, tol: float) -> jax.Array:
    """Shared solver core on preassembled (Q, v_r, V+1) stripes (with the
    zero pad column already appended -- `core.kcache` stores rows that way,
    so the cached hot path never runs `pad_k`)."""
    q, v_r = r_sel.shape
    n = cols.shape[0]
    x0 = jnp.full((q, v_r, n), 1.0 / v_r, dtype=k_pad.dtype)

    def solve_chunk(x0_c, cols_c, vals_c):
        # docs never interact across the Sinkhorn iteration (each doc is an
        # independent 2-marginal OT problem), so the chunk loop hoists
        # OUTSIDE the whole solve: each chunk runs all its iterations while
        # its (Q, v_r, docs_chunk) iterate stays cache-resident -- measured
        # 1.5-3.3x over the iteration-major unchunked loop at bulk shapes
        # on CPU (see "Batched engine & cache blocking").
        def iteration(x):
            return _iteration_batch(impl, k_pad, r_sel, x, cols_c, vals_c)

        if tol:
            x, _, _ = batched_sinkhorn_loop(iteration, x0_c,
                                            max_iter=max_iter, tol=tol)
        else:
            # fixed budget: skip the per-iteration delta/freeze bookkeeping
            # entirely (it could never fire -- delta >= 0.0 always holds)
            x = jax.lax.fori_loop(0, max_iter,
                                  lambda _, xx: iteration(xx), x0_c)
        return _final_batch(impl, k_pad, km_pad, safe_recip(x),
                            cols_c, vals_c)

    return _chunk_over_docs(solve_chunk, x0, cols, vals, docs_chunk,
                            pad_col=k_pad.shape[-1] - 1)


@functools.partial(jax.jit,
                   static_argnames=("max_iter", "impl", "docs_chunk", "tol"))
def sinkhorn_wmd_sparse_batch_stripes(k_pad: jax.Array, km_pad: jax.Array,
                                      r_sel: jax.Array, cols: jax.Array,
                                      vals: jax.Array, max_iter: int,
                                      impl: str = "fused",
                                      docs_chunk: int | None = None,
                                      tol: float = 0.0) -> jax.Array:
    """Batched solver on *preassembled* precompute stripes. Returns (Q, N).

    The cross-query cache entry point: callers (`core.kcache` via
    `serving.wmd_service`, or anything that hoists the precompute) pass
    k_pad / km_pad of shape (Q, v_r, V+1) -- per-query K and K.*M stripes
    with the trailing zero pad column already in place (ELL pad slots gather
    it) and pad query rows already zeroed. ``r_sel`` (Q, v_r) carries 1.0 in
    pad rows; K_over_r remains the in-solver per-row 1/r scale, so no third
    stripe is materialized. Identical math (same impl table, chunking and
    early-exit semantics) as `sinkhorn_wmd_sparse_batch`, which now merely
    computes the stripes from embeddings and delegates here.
    """
    return _solve_batch_stripes(k_pad, km_pad, r_sel, cols, vals,
                                max_iter=max_iter, impl=impl,
                                docs_chunk=docs_chunk, tol=tol)
