"""PASWD: the paper's sparse-heavy Sinkhorn-WMD with fused SDDMM-SpMM.

This is the paper's contribution, re-architected for TPU (DESIGN.md sections
2-3). The document-frequency matrix is doc-major padded ELL (`core.formats`);
the SDDMM samples only the nnz dot products, and the fusion reuses the
*single* VMEM gather of K columns for both the SDDMM contraction and the SpMM
contraction (K_over_r differs from K only by the per-row 1/r scale):

    SDDMM : w[j,k] = sum_i K[i, cols[j,k]] * u[i,j]
            v[j,k] = vals[j,k] / w[j,k]
    SpMM  : x[i,j] = (1/r[i]) * sum_k K[i, cols[j,k]] * v[j,k]

type2 (final distance) swaps the SpMM operand to K.*M and reduces in-kernel:

    WMD[j] = sum_i u[i,j] * sum_k (K.*M)[i, cols[j,k]] * v[j,k]

Three execution paths, selected by ``impl``:
  * "fused"    -- single gather per iteration (jnp). Production jnp path and
                  oracle for the Pallas kernel.
  * "unfused"  -- separate SDDMM / SpMM with independent gathers, mirroring
                  the paper's pre-fusion baseline (Fig. 9 numerator).
  * "kernel"   -- `repro.kernels.ops` Pallas kernels (interpret=True on CPU).

All paths consume K padded with one trailing zero column so ELL pad slots
(col == V) contribute exactly zero.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.cost_matrix import cdist
from repro.core.sinkhorn import SinkhornPrecompute, precompute

_IMPLS = ("fused", "unfused", "kernel")

# Reciprocal guard: K = exp(-lamb*M) underflows f32 for far word pairs, and
# the u = 1/x nonlinearity amplifies it to inf*0 = nan. Clamping the
# denominator at TINY is exact for healthy values and replaces inf by a huge
# finite number otherwise (the paper sidesteps this with f64 inputs).
TINY = 1e-30


def safe_recip(x: jax.Array) -> jax.Array:
    return 1.0 / jnp.maximum(x, TINY)


def pad_k(k: jax.Array) -> jax.Array:
    """Append a zero column: gathers of the ELL pad id (== V) read zeros.

    Works on both (v_r, V) single-query and (Q, v_r, V) batched stripes --
    the pad column is always appended on the vocab (last) axis.
    """
    widths = [(0, 0)] * (k.ndim - 1) + [(0, 1)]
    return jnp.pad(k, widths)


# ---------------------------------------------------------------------------
# jnp building blocks (also serve as kernel oracles via kernels/ref.py)
# ---------------------------------------------------------------------------

def gather_k(k_pad: jax.Array, cols: jax.Array) -> jax.Array:
    """Gather K columns per ELL slot: (v_r, V+1), (N, nnz) -> (N, nnz, v_r)."""
    return k_pad.T[cols]


def sddmm(k_pad: jax.Array, u: jax.Array, cols: jax.Array,
          vals: jax.Array) -> jax.Array:
    """Sampled dense-dense matmul: v[j,k] = vals[j,k] / (K^T u)[cols[j,k], j]."""
    kg = gather_k(k_pad, cols)                       # gather #1
    w = jnp.einsum("nki,in->nk", kg, u)
    return jnp.where(vals != 0.0, vals * safe_recip(w), 0.0)


def spmm(kor_pad: jax.Array, v: jax.Array, cols: jax.Array) -> jax.Array:
    """x[i,j] = sum_k K_over_r[i, cols[j,k]] * v[j,k] -- re-gathers K."""
    kg = gather_k(kor_pad, cols)                     # gather #2 (unfused cost)
    return jnp.einsum("nki,nk->in", kg, v)


def sddmm_spmm_type1(k_pad: jax.Array, r_sel: jax.Array, u: jax.Array,
                     cols: jax.Array, vals: jax.Array) -> jax.Array:
    """Fused iteration body: one gather feeds both contractions."""
    kg = gather_k(k_pad, cols)                       # the ONLY gather
    w = jnp.einsum("nki,in->nk", kg, u)
    v = jnp.where(vals != 0.0, vals * safe_recip(w), 0.0)
    x = jnp.einsum("nki,nk->in", kg, v)
    return x / r_sel[:, None]


def sddmm_spmm_type2(k_pad: jax.Array, km_pad: jax.Array, u: jax.Array,
                     cols: jax.Array, vals: jax.Array) -> jax.Array:
    """Fused final distance: 3 dense (K, K.*M, u) + 2 sparse (cols, vals)."""
    kg = gather_k(k_pad, cols)
    kmg = gather_k(km_pad, cols)
    w = jnp.einsum("nki,in->nk", kg, u)
    v = jnp.where(vals != 0.0, vals * safe_recip(w), 0.0)
    xm = jnp.einsum("nki,nk->in", kmg, v)
    return jnp.sum(u * xm, axis=0)                   # (N,)


def _iteration(impl: str, pre_kpad: jax.Array, r_sel: jax.Array,
               x: jax.Array, cols: jax.Array, vals: jax.Array) -> jax.Array:
    u = safe_recip(x)
    if impl == "fused":
        return sddmm_spmm_type1(pre_kpad, r_sel, u, cols, vals)
    if impl == "unfused":
        # independent gathers, with a barrier so XLA cannot CSE them back
        # into the fused form (keeps the Fig. 9 baseline honest).
        v = sddmm(pre_kpad, u, cols, vals)
        v = jax.lax.optimization_barrier(v)
        return spmm(pre_kpad / r_sel[:, None], v, cols)
    if impl == "kernel":
        from repro.kernels import ops
        return ops.sddmm_spmm_type1(pre_kpad, r_sel, u, cols, vals)
    raise ValueError(f"impl must be one of {_IMPLS}, got {impl!r}")


def _final(impl: str, k_pad: jax.Array, km_pad: jax.Array, u: jax.Array,
           cols: jax.Array, vals: jax.Array) -> jax.Array:
    if impl == "kernel":
        from repro.kernels import ops
        return ops.sddmm_spmm_type2(k_pad, km_pad, u, cols, vals)
    return sddmm_spmm_type2(k_pad, km_pad, u, cols, vals)


# ---------------------------------------------------------------------------
# Full solver
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("max_iter", "impl"))
def sinkhorn_wmd_sparse(sel_idx: jax.Array, r_sel: jax.Array,
                        cols: jax.Array, vals: jax.Array, vecs: jax.Array,
                        lamb: float, max_iter: int,
                        impl: str = "fused") -> jax.Array:
    """Sparse PASWD Sinkhorn-WMD. Returns (N,) distances.

    Args:
      sel_idx: (v_r,) nonzero-word indices of the query (host-selected).
      r_sel:   (v_r,) normalized query frequencies.
      cols:    (N, nnz_max) ELL word ids (pad == V).
      vals:    (N, nnz_max) ELL normalized counts (pad == 0).
      vecs:    (V, w) embeddings.
      impl:    "fused" | "unfused" | "kernel".
    """
    pre = precompute(sel_idx, r_sel, vecs, lamb)
    return sinkhorn_wmd_sparse_pre(pre, cols, vals, max_iter, impl)


def sinkhorn_wmd_sparse_pre(pre: SinkhornPrecompute, cols: jax.Array,
                            vals: jax.Array, max_iter: int,
                            impl: str = "fused") -> jax.Array:
    """Solver core on precomputed matrices (shared with the distributed path)."""
    k_pad = pad_k(pre.K)
    km_pad = pad_k(pre.KM)
    v_r = pre.r.shape[0]
    n = cols.shape[0]
    x0 = jnp.full((v_r, n), 1.0 / v_r, dtype=pre.K.dtype)

    def body(_, x):
        return _iteration(impl, k_pad, pre.r, x, cols, vals)

    x = jax.lax.fori_loop(0, max_iter, body, x0)
    u = safe_recip(x)
    return _final(impl, k_pad, km_pad, u, cols, vals)


# ---------------------------------------------------------------------------
# Multi-query batched engine: (Q, v_r, N) with ONE shared ELL gather
# ---------------------------------------------------------------------------
#
# The paper batches one query against N docs; the production axis on top of
# that is Q concurrent queries. The ELL structure (cols, vals) is a property
# of the *corpus*, identical for every query, so the irregular part of the
# iteration -- the gather of K columns at the nonzero word-ids -- becomes ONE
# batched gather op serving all Q queries (same index set, Q stripes), laid
# out (Q, N, nnz, v_r) so both downstream contractions consume it without
# transposing (see gather_k_batch). Everything downstream is dense einsum
# with a leading Q batch axis.
#
# Mixed-size queries ride the exact mask-based padding of core.distributed:
# pad rows carry r = 1 and a zeroed K row, so they contribute exactly zero
# to every w, x and WMD (no epsilon approximations).


class BatchedSinkhornPrecompute(NamedTuple):
    """Per-query iteration-invariant stripes, stacked on a leading Q axis."""

    K: jax.Array   # (Q, v_r, V) exp(-lambda * M), pad rows zeroed
    KM: jax.Array  # (Q, v_r, V) K .* M
    r: jax.Array   # (Q, v_r) pad rows carry 1.0


def precompute_batch(sel_idx: jax.Array, r_sel: jax.Array, vecs: jax.Array,
                     lamb: float, row_mask: jax.Array | None = None
                     ) -> BatchedSinkhornPrecompute:
    """Batched K / K.*M stripes for Q queries bucketed to a common v_r.

    Args:
      sel_idx:  (Q, v_r) word ids per query (pad slots point at word 0).
      r_sel:    (Q, v_r) frequencies (pad rows = 1.0, see pad_query).
      vecs:     (V, w) embeddings.
      row_mask: (Q, v_r) 1.0 for real rows, 0.0 for pad rows; None = all real.
    """
    m = jax.vmap(lambda a: cdist(a, vecs))(vecs[sel_idx])    # (Q, v_r, V)
    k = jnp.exp(-lamb * m)
    if row_mask is not None:
        k = k * row_mask[..., None]
    return BatchedSinkhornPrecompute(K=k, KM=k * m, r=r_sel)


def gather_k_batch(k_pad: jax.Array, cols: jax.Array) -> jax.Array:
    """One batched gather serving all Q queries.

    (Q, v_r, V+1), (N, nnz) -> (Q, N, nnz, v_r): one gather op whose batch
    dims (q, n) lead, so both downstream contractions consume it with NO
    transposition of the large tensor (the (N, nnz, Q, v_r) alternative
    forces XLA to re-lay it out before every dot -- measured ~2.3x slower
    on CPU).
    """
    return jnp.transpose(k_pad, (0, 2, 1))[:, cols]


def sddmm_spmm_type1_batch(k_pad: jax.Array, r_sel: jax.Array, u: jax.Array,
                           cols: jax.Array, vals: jax.Array) -> jax.Array:
    """Batched fused iteration body: (Q, v_r, N) <- one gather, two einsums.

    Same math per query as `sddmm_spmm_type1`; the explicit q-leading einsum
    spelling compiles to dot_generals whose batch dims (q, n) are already
    the gathered tensor's leading dims (measured ~2x faster than the
    vmap-of-single lowering on CPU, ~4x faster than a (N, nnz, Q, v_r)
    gather layout).

    k_pad (Q, v_r, V+1), r_sel (Q, v_r), u (Q, v_r, N), cols/vals (N, nnz).
    """
    kg = gather_k_batch(k_pad, cols)                 # the ONLY gather
    w = jnp.einsum("qnki,qin->qnk", kg, u)
    v = jnp.where(vals[None] != 0.0, vals[None] * safe_recip(w), 0.0)
    x = jnp.einsum("qnki,qnk->qin", kg, v)
    return x / r_sel[:, :, None]


def sddmm_spmm_type2_batch(k_pad: jax.Array, km_pad: jax.Array, u: jax.Array,
                           cols: jax.Array, vals: jax.Array) -> jax.Array:
    """Batched fused final distance: (Q, N) WMD for all queries at once."""
    kg = gather_k_batch(k_pad, cols)
    kmg = gather_k_batch(km_pad, cols)
    w = jnp.einsum("qnki,qin->qnk", kg, u)
    v = jnp.where(vals[None] != 0.0, vals[None] * safe_recip(w), 0.0)
    xm = jnp.einsum("qnki,qnk->qin", kmg, v)
    return jnp.sum(u * xm, axis=1)                   # (Q, N)


@functools.partial(jax.jit, static_argnames=("max_iter",))
def sinkhorn_wmd_sparse_batch(sel_idx: jax.Array, r_sel: jax.Array,
                              cols: jax.Array, vals: jax.Array,
                              vecs: jax.Array, lamb: float, max_iter: int,
                              row_mask: jax.Array | None = None) -> jax.Array:
    """Multi-query sparse PASWD Sinkhorn-WMD. Returns (Q, N) distances.

    The per-query math is identical to `sinkhorn_wmd_sparse` (fused impl);
    queries never interact -- the batch axis only amortizes the ELL gather,
    the dispatch, and the K precompute. Matches the sequential per-query
    solve to fp32 tolerance.
    """
    pre = precompute_batch(sel_idx, r_sel, vecs, lamb, row_mask)
    k_pad = pad_k(pre.K)
    km_pad = pad_k(pre.KM)
    q, v_r = r_sel.shape
    n = cols.shape[0]
    x0 = jnp.full((q, v_r, n), 1.0 / v_r, dtype=pre.K.dtype)

    def body(_, x):
        return sddmm_spmm_type1_batch(k_pad, pre.r, safe_recip(x), cols, vals)

    x = jax.lax.fori_loop(0, max_iter, body, x0)
    u = safe_recip(x)
    return sddmm_spmm_type2_batch(k_pad, km_pad, u, cols, vals)
