"""PASWD: the paper's sparse-heavy Sinkhorn-WMD with fused SDDMM-SpMM.

This is the paper's contribution, re-architected for TPU (DESIGN.md sections
2-3). The document-frequency matrix is doc-major padded ELL (`core.formats`);
the SDDMM samples only the nnz dot products, and the fusion reuses the
*single* VMEM gather of K columns for both the SDDMM contraction and the SpMM
contraction (K_over_r differs from K only by the per-row 1/r scale):

    SDDMM : w[j,k] = sum_i K[i, cols[j,k]] * u[i,j]
            v[j,k] = vals[j,k] / w[j,k]
    SpMM  : x[i,j] = (1/r[i]) * sum_k K[i, cols[j,k]] * v[j,k]

type2 (final distance) swaps the SpMM operand to K.*M and reduces in-kernel:

    WMD[j] = sum_i u[i,j] * sum_k (K.*M)[i, cols[j,k]] * v[j,k]

Three execution paths, selected by ``impl``:
  * "fused"    -- single gather per iteration (jnp). Production jnp path and
                  oracle for the Pallas kernel.
  * "unfused"  -- separate SDDMM / SpMM with independent gathers, mirroring
                  the paper's pre-fusion baseline (Fig. 9 numerator).
  * "kernel"   -- `repro.kernels.ops` Pallas kernels (interpret=True on CPU).

All paths consume K padded with one trailing zero column so ELL pad slots
(col == V) contribute exactly zero.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.sinkhorn import SinkhornPrecompute, precompute

_IMPLS = ("fused", "unfused", "kernel")

# Reciprocal guard: K = exp(-lamb*M) underflows f32 for far word pairs, and
# the u = 1/x nonlinearity amplifies it to inf*0 = nan. Clamping the
# denominator at TINY is exact for healthy values and replaces inf by a huge
# finite number otherwise (the paper sidesteps this with f64 inputs).
TINY = 1e-30


def safe_recip(x: jax.Array) -> jax.Array:
    return 1.0 / jnp.maximum(x, TINY)


def pad_k(k: jax.Array) -> jax.Array:
    """Append a zero column: gathers of the ELL pad id (== V) read zeros."""
    return jnp.pad(k, ((0, 0), (0, 1)))


# ---------------------------------------------------------------------------
# jnp building blocks (also serve as kernel oracles via kernels/ref.py)
# ---------------------------------------------------------------------------

def gather_k(k_pad: jax.Array, cols: jax.Array) -> jax.Array:
    """Gather K columns per ELL slot: (v_r, V+1), (N, nnz) -> (N, nnz, v_r)."""
    return k_pad.T[cols]


def sddmm(k_pad: jax.Array, u: jax.Array, cols: jax.Array,
          vals: jax.Array) -> jax.Array:
    """Sampled dense-dense matmul: v[j,k] = vals[j,k] / (K^T u)[cols[j,k], j]."""
    kg = gather_k(k_pad, cols)                       # gather #1
    w = jnp.einsum("nki,in->nk", kg, u)
    return jnp.where(vals != 0.0, vals * safe_recip(w), 0.0)


def spmm(kor_pad: jax.Array, v: jax.Array, cols: jax.Array) -> jax.Array:
    """x[i,j] = sum_k K_over_r[i, cols[j,k]] * v[j,k] -- re-gathers K."""
    kg = gather_k(kor_pad, cols)                     # gather #2 (unfused cost)
    return jnp.einsum("nki,nk->in", kg, v)


def sddmm_spmm_type1(k_pad: jax.Array, r_sel: jax.Array, u: jax.Array,
                     cols: jax.Array, vals: jax.Array) -> jax.Array:
    """Fused iteration body: one gather feeds both contractions."""
    kg = gather_k(k_pad, cols)                       # the ONLY gather
    w = jnp.einsum("nki,in->nk", kg, u)
    v = jnp.where(vals != 0.0, vals * safe_recip(w), 0.0)
    x = jnp.einsum("nki,nk->in", kg, v)
    return x / r_sel[:, None]


def sddmm_spmm_type2(k_pad: jax.Array, km_pad: jax.Array, u: jax.Array,
                     cols: jax.Array, vals: jax.Array) -> jax.Array:
    """Fused final distance: 3 dense (K, K.*M, u) + 2 sparse (cols, vals)."""
    kg = gather_k(k_pad, cols)
    kmg = gather_k(km_pad, cols)
    w = jnp.einsum("nki,in->nk", kg, u)
    v = jnp.where(vals != 0.0, vals * safe_recip(w), 0.0)
    xm = jnp.einsum("nki,nk->in", kmg, v)
    return jnp.sum(u * xm, axis=0)                   # (N,)


def _iteration(impl: str, pre_kpad: jax.Array, r_sel: jax.Array,
               x: jax.Array, cols: jax.Array, vals: jax.Array) -> jax.Array:
    u = safe_recip(x)
    if impl == "fused":
        return sddmm_spmm_type1(pre_kpad, r_sel, u, cols, vals)
    if impl == "unfused":
        # independent gathers, with a barrier so XLA cannot CSE them back
        # into the fused form (keeps the Fig. 9 baseline honest).
        v = sddmm(pre_kpad, u, cols, vals)
        v = jax.lax.optimization_barrier(v)
        return spmm(pre_kpad / r_sel[:, None], v, cols)
    if impl == "kernel":
        from repro.kernels import ops
        return ops.sddmm_spmm_type1(pre_kpad, r_sel, u, cols, vals)
    raise ValueError(f"impl must be one of {_IMPLS}, got {impl!r}")


def _final(impl: str, k_pad: jax.Array, km_pad: jax.Array, u: jax.Array,
           cols: jax.Array, vals: jax.Array) -> jax.Array:
    if impl == "kernel":
        from repro.kernels import ops
        return ops.sddmm_spmm_type2(k_pad, km_pad, u, cols, vals)
    return sddmm_spmm_type2(k_pad, km_pad, u, cols, vals)


# ---------------------------------------------------------------------------
# Full solver
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("max_iter", "impl"))
def sinkhorn_wmd_sparse(sel_idx: jax.Array, r_sel: jax.Array,
                        cols: jax.Array, vals: jax.Array, vecs: jax.Array,
                        lamb: float, max_iter: int,
                        impl: str = "fused") -> jax.Array:
    """Sparse PASWD Sinkhorn-WMD. Returns (N,) distances.

    Args:
      sel_idx: (v_r,) nonzero-word indices of the query (host-selected).
      r_sel:   (v_r,) normalized query frequencies.
      cols:    (N, nnz_max) ELL word ids (pad == V).
      vals:    (N, nnz_max) ELL normalized counts (pad == 0).
      vecs:    (V, w) embeddings.
      impl:    "fused" | "unfused" | "kernel".
    """
    pre = precompute(sel_idx, r_sel, vecs, lamb)
    return sinkhorn_wmd_sparse_pre(pre, cols, vals, max_iter, impl)


def sinkhorn_wmd_sparse_pre(pre: SinkhornPrecompute, cols: jax.Array,
                            vals: jax.Array, max_iter: int,
                            impl: str = "fused") -> jax.Array:
    """Solver core on precomputed matrices (shared with the distributed path)."""
    k_pad = pad_k(pre.K)
    km_pad = pad_k(pre.KM)
    v_r = pre.r.shape[0]
    n = cols.shape[0]
    x0 = jnp.full((v_r, n), 1.0 / v_r, dtype=pre.K.dtype)

    def body(_, x):
        return _iteration(impl, k_pad, pre.r, x, cols, vals)

    x = jax.lax.fori_loop(0, max_iter, body, x0)
    u = safe_recip(x)
    return _final(impl, k_pad, km_pad, u, cols, vals)
