"""Faithful port of the paper's Algorithm 1 / Fig. 3 -- dense Sinkhorn-WMD.

This is the *paper-faithful baseline*: a line-for-line translation of the
Python reference in Fig. 3 of the paper into jnp, with the same matrix
identities and iteration structure:

    I = (r > 0); r = r(I); M = M(I, :); K = exp(-lambda * M)
    x = ones(len(r), n_docs) / len(r)
    repeat:  u = 1/x
             v = c .* (1 / (K^T @ u))        # the dense-heavy hotspot (91.9%)
             x = (diag(1/r) K) @ v
    u = 1/x; v = c .* (1 / (K^T @ u))
    WMD = sum(u .* ((K .* M) @ v), axis=0)

``c`` is dense here (V x N) -- exactly the over-compute the paper removes; the
sparse-heavy PASWD version lives in `repro.core.sparse_sinkhorn`. Keeping both
is deliberate: the dense version is the correctness oracle and the Fig. 8
baseline ("C++ translation of the Python code, without the SDDMM kernel").

Shapes are static under jit: the nonzero selection of ``r`` happens host-side
(`select_query`) because XLA needs static v_r.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


class SinkhornPrecompute(NamedTuple):
    """Iteration-invariant matrices (paper Fig. 4: ``precompute_matrices``)."""

    K: jax.Array         # (v_r, V) exp(-lambda * M)
    K_over_r: jax.Array  # (v_r, V) diag(1/r) K
    KM: jax.Array        # (v_r, V) K .* M
    r: jax.Array         # (v_r,)


def select_query(r: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Host-side ``I = (r > 0); r = r(I)`` -- returns (sel_idx, r_sel).

    Separated from the jit'd solver because v_r must be a static shape.
    """
    (sel,) = np.nonzero(np.asarray(r) > 0)
    r_sel = np.asarray(r, dtype=np.float32)[sel]
    return sel.astype(np.int32), r_sel


def m_rows(word_ids: jax.Array, vecs: jax.Array,
           *, b2: jax.Array | None = None) -> jax.Array:
    """Cost-matrix rows M[i] = |vecs[id_i] - vecs| (MXU matmul expansion).

    THE single spelling of the M-row expression: the K/K.*M precompute
    (`precompute_rows`, and through it the K cache) and the RWMD prune
    bound (`core.rwmd`) both call it, which is what makes "the bound sees
    the same geometry the engine's K.*M encodes" a structural guarantee
    rather than a kept-in-sync convention -- the pruning exactness
    contract assumes bound-M and engine-M agree bit for bit. ``b2``
    optionally supplies precomputed per-vocab-word squared norms.
    """
    a = vecs[word_ids]                                  # (m, w)
    a2 = jnp.sum(a * a, axis=-1)[:, None]
    if b2 is None:
        b2 = jnp.sum(vecs * vecs, axis=-1)
    return jnp.sqrt(jnp.maximum(a2 + b2[None, :] - 2.0 * (a @ vecs.T), 0.0))


def precompute_rows(word_ids: jax.Array, vecs: jax.Array, lamb: float,
                    *, b2: jax.Array | None = None
                    ) -> tuple[jax.Array, jax.Array]:
    """The *cacheable* half of the precompute: (K, K.*M) rows keyed purely
    by (word_id, lamb) -- nothing query-specific enters.

    One row per requested word id: K[i] = exp(-lamb * |vecs[id_i] - vecs|),
    KM[i] = K[i] * M[i]. ``b2`` optionally supplies the precomputed
    per-vocab-word squared norms (sum(vecs**2, -1)); `core.kcache` passes it
    so the O(V*w) term is paid once per corpus instead of once per miss
    batch. The math is the `cdist_matmul` MXU expansion of `m_rows` so
    cached rows are bit-identical to the from-scratch `precompute` path.
    """
    m = m_rows(word_ids, vecs, b2=b2)
    k = jnp.exp(-lamb * m)
    return k, k * m


def assemble_precompute(k_rows: jax.Array, km_rows: jax.Array,
                        r_sel: jax.Array) -> SinkhornPrecompute:
    """The *per-query* half: a cheap row scale over gathered rows.

    K_over_r = diag(1/r) K is the only query-dependent matrix; K and K.*M
    come straight from `precompute_rows` (or the cross-query cache) for the
    query's word ids.
    """
    return SinkhornPrecompute(
        K=k_rows,
        K_over_r=k_rows / r_sel[:, None],
        KM=km_rows,
        r=r_sel,
    )


def precompute(sel_idx: jax.Array, r_sel: jax.Array, vecs: jax.Array,
               lamb: float) -> SinkhornPrecompute:
    """M = cdist(vecs[sel], vecs); K = exp(-lamb M); K/r; K*M.

    Composition of the cacheable rows (`precompute_rows`) and the per-query
    scale (`assemble_precompute`) -- `core.kcache` splits exactly here.
    """
    k, km = precompute_rows(sel_idx, vecs, lamb)
    return assemble_precompute(k, km, r_sel)


def _safe_recip(x):
    """Guard against exp-underflow-driven 0-division (see sparse_sinkhorn)."""
    return 1.0 / jnp.maximum(x, 1e-30)


def _iterate_dense(pre: SinkhornPrecompute, c: jax.Array, x: jax.Array):
    """One Sinkhorn iteration, dense formulation (the 91.9% hotspot)."""
    u = _safe_recip(x)                                  # (v_r, N)
    w = pre.K.T @ u                                     # (V, N) dense!
    v = c * jnp.where(c != 0.0, _safe_recip(w), 0.0)    # c .* (1/w)
    x = pre.K_over_r @ v                                # (v_r, N)
    return x, v


@functools.partial(jax.jit, static_argnames=("max_iter",))
def sinkhorn_wmd_dense(sel_idx: jax.Array, r_sel: jax.Array, c: jax.Array,
                       vecs: jax.Array, lamb: float, max_iter: int) -> jax.Array:
    """Dense Sinkhorn-WMD of one query against N docs. Returns (N,) distances.

    Args:
      sel_idx: (v_r,) int32 indices of the query's nonzero vocabulary words.
      r_sel:   (v_r,) f32 normalized query word frequencies (sum == 1).
      c:       (V, N) f32 dense doc-frequency matrix, columns sum to 1.
      vecs:    (V, w) f32 word embeddings.
      lamb:    entropy regularization strength (paper passes it negated; we
               follow Fig. 3 and negate inside: K = exp(-lamb * M)).
      max_iter: fixed iteration count (paper: practical cutoff).
    """
    pre = precompute(sel_idx, r_sel, vecs, lamb)
    v_r = r_sel.shape[0]
    n = c.shape[1]
    x0 = jnp.full((v_r, n), 1.0 / v_r, dtype=jnp.float32)

    def body(_, x):
        x, _ = _iterate_dense(pre, c, x)
        return x

    x = jax.lax.fori_loop(0, max_iter, body, x0)
    # final: u = 1/x; v = c .* (1/(K^T u)); WMD = sum(u .* (KM @ v), 0)
    u = _safe_recip(x)
    w = pre.K.T @ u
    v = c * jnp.where(c != 0.0, _safe_recip(w), 0.0)
    return jnp.sum(u * (pre.KM @ v), axis=0)


@functools.partial(jax.jit, static_argnames=("max_iter",))
def sinkhorn_wmd_dense_history(sel_idx, r_sel, c, vecs, lamb, max_iter):
    """Like sinkhorn_wmd_dense but also returns per-iteration |dx|_inf for
    convergence studies (`core.convergence`)."""
    pre = precompute(sel_idx, r_sel, vecs, lamb)
    v_r = r_sel.shape[0]
    n = c.shape[1]
    x0 = jnp.full((v_r, n), 1.0 / v_r, dtype=jnp.float32)

    def body(x, _):
        x_new, _ = _iterate_dense(pre, c, x)
        return x_new, jnp.max(jnp.abs(x_new - x))

    x, deltas = jax.lax.scan(body, x0, None, length=max_iter)
    u = _safe_recip(x)
    w = pre.K.T @ u
    v = c * jnp.where(c != 0.0, _safe_recip(w), 0.0)
    return jnp.sum(u * (pre.KM @ v), axis=0), deltas
