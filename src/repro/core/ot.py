"""Generic entropy-regularized optimal transport via Sinkhorn-Knopp.

The paper's solver is a specialization of Cuturi's Sinkhorn distance to the
1-query-vs-N-docs WMD shape. This module keeps the *general* (n x m) form,
which the framework reuses in two places:

  1. the MoE **Sinkhorn router** (`models.layers.moe`): tokens x experts
     balanced assignment is an OT problem with uniform expert marginals --
     the same sparse-dispatch structure the paper accelerates
     (DESIGN.md section 5);
  2. the patch-cloud vs token-cloud demo in `examples/doc_retrieval.py`.

All loops are `jax.lax` control flow; everything jits and differentiates
(implicit differentiation through the fixed iteration count).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp


class SinkhornResult(NamedTuple):
    plan: jax.Array       # (n, m) transport plan P = diag(u) K diag(v)
    cost: jax.Array       # <P, C> transport cost (scalar)
    n_iter: jax.Array     # iterations actually run
    marginal_err: jax.Array  # |P 1 - a|_inf at exit


def sinkhorn_plan(cost: jax.Array, a: jax.Array, b: jax.Array, *,
                  lamb: float, max_iter: int, tol: float = 0.0,
                  min_denom: float = 1e-30) -> SinkhornResult:
    """Solve min_P <P,C> - H(P)/lamb  s.t.  P 1 = a, P^T 1 = b.

    Args:
      cost: (n, m) cost matrix.
      a:    (n,) source marginal (sums to 1).
      b:    (m,) target marginal (sums to 1).
      lamb: regularization strength (larger = closer to exact OT).
      max_iter: iteration cap.
      tol:  if > 0, stop early when |u_new - u|_inf < tol (while_loop).
    """
    k = jnp.exp(-lamb * cost)                           # (n, m)
    n = a.shape[0]
    u0 = jnp.full((n,), 1.0 / n, dtype=cost.dtype)

    def step(u):
        v = b / jnp.maximum(k.T @ u, min_denom)
        return a / jnp.maximum(k @ v, min_denom)

    if tol > 0.0:
        def cond(carry):
            u, u_prev, it = carry
            return (it < max_iter) & (jnp.max(jnp.abs(u - u_prev)) >= tol)

        def body(carry):
            u, _, it = carry
            return step(u), u, it + 1

        u, _, n_iter = jax.lax.while_loop(
            cond, body, (step(u0), u0, jnp.asarray(1)))
    else:
        u = jax.lax.fori_loop(0, max_iter, lambda _, u: step(u), u0)
        n_iter = jnp.asarray(max_iter)

    v = b / jnp.maximum(k.T @ u, min_denom)
    plan = u[:, None] * k * v[None, :]
    return SinkhornResult(
        plan=plan,
        cost=jnp.sum(plan * cost),
        n_iter=n_iter,
        marginal_err=jnp.max(jnp.abs(plan.sum(axis=1) - a)),
    )


@functools.partial(jax.jit, static_argnames=("max_iter",))
def sinkhorn_divergence(cost: jax.Array, a: jax.Array, b: jax.Array,
                        lamb: float, max_iter: int) -> jax.Array:
    """Scalar Sinkhorn distance <P*, C> (the d_M^lambda of the paper)."""
    return sinkhorn_plan(cost, a, b, lamb=lamb, max_iter=max_iter).cost
