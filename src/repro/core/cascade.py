"""Tier-0 centroid screen + LC-RWMD: the cheap front of the retrieval cascade.

The two-tier retriever (`core.rwmd` + the exact stripes rerank) still pays
O(nnz * v_r) doc-side bound work for *all* N docs per query. This module adds
the two cheaper tiers in front of it:

Tier 0 -- centroid / nBOW screen (Werner & Laber)
-------------------------------------------------
One dense matmul over precomputed per-doc moments. With ``z`` any reference
point, ``R = max_i ||x_i - z||`` over the query's real word vectors, and the
doc moments ``g_d = sum_s vals[d,s] * y_s`` (mass-weighted vector sum) and
``m_d = sum_s vals[d,s]`` (doc mass), the triangle inequality gives, per ELL
slot ``s`` of doc ``d``:

    min_i ||x_i - y_s||  >=  ||y_s - z|| - max_i ||x_i - z||  =  ||y_s - z|| - R

and summing with weights ``vals[d, s] >= 0``:

    rwmd(q, d) = sum_s vals[d,s] * min_i ||x_i - y_s||
              >= sum_s vals[d,s] * ||y_s - z||  -  m_d * R
              >= || sum_s vals[d,s] * (y_s - z) ||  -  m_d * R      (Jensen)
               = || g_d - m_d * z ||  -  m_d * R

so ``tier0(q, d) = max(0, ||g_d - m_d z|| - m_d R)`` lower-bounds the
doc-side RWMD -- and hence, by the PR 5 chain, the engine's returned distance
at EVERY iteration budget (the derivation never touches the transport plan,
only the cost matrix geometry, so no convergence assumption enters). The
choice of ``z`` is free; the r-weighted query centroid keeps ``R`` small.
Norm expansion ``||g - m z||^2 = g2 - 2 m (z . g) + m^2 z2`` turns the whole
screen into one (Q, dim) x (dim, N) matmul plus rank-1 terms.

Tier 1 -- LC-RWMD (Atasu et al., linear-complexity RWMD)
--------------------------------------------------------
The doc-side RWMD's inner reduction ``min_i M[sel_q[i], c]`` depends only on
(query, vocab word), not on the doc: gather the per-vocab-word min-cost
vector ``minm[q, c] = min_i m_pad[q, i, c]`` ONCE per query (a (Q, v_r, V+1)
-> (Q, V+1) min), then every doc costs a single sparse dot
``sum_s vals[d,s] * minm[q, cols[d,s]]`` -- O(Q*V*v_r + N*nnz) for the whole
corpus instead of O(N * nnz * v_r) per batch. The value is mathematically
*identical* to `core.rwmd.rwmd_bound_batch` (same min over the same floats,
hoisted out of the doc loop), so its soundness is the doc-side bound's
soundness; the cascade treats it as a separate tier only because its cost
profile differs. Three spellings as usual: the fused jnp path below, the
Pallas dense-gather + SpMV kernel (`kernels.lcrwmd`, ``impl="kernel"``), and
the naive dense oracle (`kernels.ref.lc_rwmd_bound_batch`).

Pad conventions are inherited from `core.rwmd.assemble_m_stripes`: pad query
rows carry +inf (they never win the min, so ``minm`` of an all-pad filler
query is +inf and its bounds finite-ize to 0), pad ELL slots are masked by
``vals == 0``, empty docs and filler queries score exactly 0 -- a 0 bound
can never prune them, matching the engine's 0.0 distance.

Both tiers inherit the prune contract: bounds only reorder and skip; every
solved doc's distance bits come from the same stripes programs as the
exhaustive scan.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.sparse_sinkhorn import _chunk_over_docs

_LC_IMPLS = ("fused", "kernel")

TINY = 1e-30


@jax.jit
def doc_centroids(cols: jax.Array, vals: jax.Array,
                  vecs: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Per-doc moments for the tier-0 screen: (g, m) = (sum vals*y, sum vals).

    cols/vals: corpus ELL (N, nnz_max), pad col == V, pad val == 0. The
    vocab table gets a zero pad row so pad slots contribute nothing to
    either moment. Accumulated slot-by-slot (O(N * dim) live memory, never
    the (N, nnz, dim) gather). Empty docs yield g = 0, m = 0. Computed once
    per corpus version, reused across every query batch.
    """
    vp = jnp.concatenate(
        [vecs, jnp.zeros((1, vecs.shape[1]), vecs.dtype)], axis=0)
    n, nnz_max = cols.shape

    def slot(s, acc):
        return acc + vp[cols[:, s]] * vals[:, s, None]

    g = jax.lax.fori_loop(0, nnz_max, slot,
                          jnp.zeros((n, vecs.shape[1]), vecs.dtype))
    return g, jnp.sum(vals, axis=1)


@jax.jit
def centroid_bound_batch(sel_b: jax.Array, r_b: jax.Array, mask_b: jax.Array,
                         vecs: jax.Array, g: jax.Array,
                         m: jax.Array) -> jax.Array:
    """Tier-0 centroid lower bounds. Returns (Q, N).

    sel_b / r_b / mask_b: the (Q, v_r) padded-query arrays of
    `core.distributed.pad_query_batch` (pad rows mask 0). g / m: the
    corpus moments from `doc_centroids`. All-pad filler queries (mask-sum
    0) and empty docs (m = 0) score exactly 0 -- never pruned. The relu
    also absorbs the sqrt's fp slack; the service's ``prune_margin``
    covers the rest, same as the other tiers.
    """
    x = vecs[sel_b]                                     # (Q, v_r, dim)
    w = r_b * mask_b
    ws = jnp.sum(w, axis=1)                             # (Q,)
    z = jnp.sum(w[:, :, None] * x, axis=1) / jnp.maximum(ws, TINY)[:, None]
    d2 = jnp.sum((x - z[:, None, :]) ** 2, axis=-1)     # (Q, v_r)
    radius = jnp.sqrt(jnp.max(jnp.where(mask_b > 0, d2, 0.0), axis=1))
    g2 = jnp.sum(g * g, axis=-1)                        # (N,)
    z2 = jnp.sum(z * z, axis=-1)                        # (Q,)
    n2 = (g2[None, :] - 2.0 * m[None, :] * (z @ g.T)
          + (m[None, :] ** 2) * z2[:, None])            # ||g - m z||^2, (Q,N)
    lb = jnp.sqrt(jnp.maximum(n2, 0.0)) - m[None, :] * radius[:, None]
    lb = jnp.maximum(lb, 0.0)
    return jnp.where(ws[:, None] > 0, lb, 0.0)          # filler queries -> 0


@jax.jit
def min_cost_vectors(m_pad: jax.Array) -> jax.Array:
    """(Q, v_r, V+1) M stripes -> (Q, V+1) per-vocab-word min-cost vectors.

    Pad query rows are +inf by the `assemble_m_stripes` convention, so they
    never win; an all-pad filler query's vector is all +inf and its LC
    bounds finite-ize to 0 downstream. The pad column (index V) rides along
    -- pad ELL slots gather it but are val-masked out anyway.
    """
    return jnp.min(m_pad, axis=1)


def _lc_chunk_jnp(minm: jax.Array, cols_c: jax.Array,
                  vals_c: jax.Array) -> jax.Array:
    """One doc chunk of the fused LC sparse dot: (Q, docs) partial bounds."""
    mg = minm[:, cols_c]                                # (Q, n_c, nnz)
    mg = jnp.where(vals_c[None] != 0.0, mg, 0.0)        # pad slots out
    return jnp.einsum("qnk,nk->qn", mg, vals_c)


@functools.partial(jax.jit, static_argnames=("impl", "docs_chunk"))
def lc_rwmd_bound_batch(minm: jax.Array, cols: jax.Array, vals: jax.Array,
                        impl: str = "fused",
                        docs_chunk: int | None = None) -> jax.Array:
    """Batched LC-RWMD lower bounds: one sparse dot per doc. Returns (Q, N).

    Args:
      minm: (Q, V+1) per-query min-cost vectors from `min_cost_vectors`
            (filler queries all +inf -- finited to 0 here).
      cols / vals: the corpus ELL (N, nnz_max), pad col == V, pad val == 0.
      impl: "fused" (jnp gather + einsum) | "kernel" (the Pallas
            dense-gather + SpMV, `kernels.lcrwmd`).
      docs_chunk: cache-block over static N-chunks via the engine's
            `_chunk_over_docs` (bitwise exactness included).
    """
    if impl not in _LC_IMPLS:
        raise ValueError(f"impl must be one of {_LC_IMPLS}, got {impl!r}")
    if impl == "kernel":
        from repro.kernels import ops
        kw = {} if not docs_chunk else {"docs_blk": docs_chunk}
        return ops.lc_rwmd_bound_batch(minm, cols, vals, **kw)
    q, n = minm.shape[0], cols.shape[0]
    u_dummy = jnp.zeros((q, 1, n), minm.dtype)          # doc-axis carrier
    lb = _chunk_over_docs(
        lambda _, cols_c, vals_c: _lc_chunk_jnp(minm, cols_c, vals_c),
        u_dummy, cols, vals, docs_chunk, pad_col=minm.shape[-1] - 1)
    return jnp.where(jnp.isfinite(lb), lb, 0.0)         # filler queries -> 0
