"""Cross-query precompute cache: word-id-keyed K / K.*M row store.

The paper's Table I / Fig. 7 puts the precompute (``M = cdist(vecs[sel],
vecs)``, ``K = exp(-lambda M)``) second only to the Sinkhorn loop, and the
batched engine used to pay it in full -- a fresh (Q, v_r, V) stripe pair --
on every `query_batch` call. But each row of those stripes is keyed purely
by ``(word_id, lambda)``: nothing query-specific enters until the cheap
per-query 1/r scale (`core.sinkhorn.assemble_precompute`). Real query
streams are Zipf-distributed, so across queries most rows repeat; this
module keeps them resident and turns the per-batch precompute cost from
O(Q * v_r * V * w) into O(misses * V * w) -- the amortization argument of
Atasu et al.'s linear-complexity RWMD and of Tithi & Petrini's shared-memory
precompute hoisting, applied to the jax_pallas engine.

Layout. Rows live in two device ring buffers of shape

    (S, capacity + 1, Vloc + 1)      S = model-axis shards, Vloc = V // S

sharded ``P(model, None, None)`` -- i.e. each vocab shard owns the same
slice of every cached row that it owns of the rebucketed ELL
(`core.formats.rebucket_for_vocab_shards`). Two pad tricks keep the
assembly a *pure* slot-gather, ``k_buf[:, slots]``, with no transpose and
no mask pass over the gathered stripes:

  * the trailing column of every shard block is the shard-local zero pad
    column that ELL pad slots gather -- ``pad_k`` disappears from the hot
    path entirely;
  * row index ``capacity`` is a reserved all-zero row that pad *query* rows
    (row_mask == 0) are pointed at, so masking costs a host-side
    ``np.where`` on the (Q, v_r) slot map instead of an elementwise pass
    over the (S, Q, v_r, Vloc+1) stripes (zeros stored exactly -- same bits
    as the 0.0 * row the in-solver `masked_k_batch` produces).

The gather output IS the ``(S, Q, v_r, Vloc+1)`` operand
`core.distributed.build_wmd_batch_fn_stripes` consumes.

Bookkeeping is host-side (the id -> slot map is tiny and the decisions are
per *batch*, not per element): exact LRU over a monotone tick, with the
current batch's rows pinned so a miss can never evict a row the same batch
hits. Misses are computed by the row-subset fused kexp
(`kernels.ops.cdist_kexp_rows`, or its jnp twin
`core.sinkhorn.precompute_rows`) in fixed ``rows_bucket`` chunks -- one
compiled program regardless of miss count, which both bounds retracing and
makes row values bit-reproducible across calls (an XLA executable computes
row i of a fixed-shape batch from ``vecs[id_i]`` alone, so a row's bits do
not depend on which other ids happened to miss alongside it). That is what
makes the cache *exact*: cached rows are bitwise equal to recomputed rows,
and solver output is bitwise identical with the cache on or off.

Batches whose unique-id count exceeds ``capacity`` (and every call when
``capacity == 0`` or ``use_cache=False``) take the *transient* path: the
same dedup + row compute + slot-gather, assembled from a throwaway row
store instead of the resident buffers. The transient path IS the cache-off
baseline, so on/off produce identical bits by construction.

Invalidation: rows are keyed by (word_id, lambda); `ensure_lamb` drops the
whole store when lambda changes (embedding updates should call
`invalidate()` explicitly -- the cache holds no vecs version hash).

`MCache` is the same machinery for the retrieval cascade's *M-row* store
(PR 5 open item): the bound tiers (`core.rwmd`, `core.cascade`) consume
(Q, v_r, V+1) cost-matrix stripes whose rows are keyed by ``word_id`` alone
(no lambda -- M is pure geometry), and `core.rwmd.assemble_m_stripes` used
to rebuild every row per dispatch. The differences from the K store are
sign conventions, not structure: ONE buffer instead of a K/K.*M pair, no
vocab sharding (the bound ELL is replicated), and the reserved row that pad
*query* rows gather is **+inf** instead of zero (a pad row must never win
the doc-side min; a zero row would collapse it). Misses go through the same
`core.rwmd._m_row_block` fixed-bucket spelling the transient assembly uses,
so cache on/off is bitwise identical by the same argument as the K store.
Both caches share the host-side bookkeeping (`_RowCacheBase`): exact LRU,
batch-pinned hits, free-list slot allocation, scoped invalidation.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.sinkhorn import precompute_rows


@dataclasses.dataclass
class KCacheStats:
    """Cumulative counters (unique rows, not query-row slots). Shared by
    the K/K.*M store and the M-row store (`MCache`)."""

    lookups: int = 0        # stripes_for_batch calls
    hit_rows: int = 0       # unique ids served from resident rows
    miss_rows: int = 0      # unique ids computed fresh
    evictions: int = 0
    bypasses: int = 0       # calls that skipped the store entirely
    invalidations: int = 0

    @property
    def hit_rate(self) -> float:
        total = self.hit_rows + self.miss_rows
        return self.hit_rows / total if total else 0.0


@functools.partial(jax.jit,
                   static_argnames=("lamb", "num_shards", "kexp_impl"))
def _row_stripes(ids: jax.Array, vecs: jax.Array, b2: jax.Array, *,
                 lamb: float, num_shards: int, kexp_impl: str):
    """(m,) word ids -> (K, K.*M) rows in cache layout (S, m, Vloc+1).

    The reshape splits the vocab axis exactly on the shard boundaries of the
    ``P(model)`` vecs sharding, and the appended zero column is each shard's
    local ELL pad column.
    """
    if kexp_impl == "kernel":
        from repro.kernels import ops
        k, km = ops.cdist_kexp_rows(vecs[ids], vecs, lamb=lamb)
    else:
        k, km = precompute_rows(ids, vecs, lamb, b2=b2)
    m = ids.shape[0]
    widths = ((0, 0), (0, 0), (0, 1))

    def shard_layout(x):
        x = jnp.transpose(x.reshape(m, num_shards, -1), (1, 0, 2))
        return jnp.pad(x, widths)

    return shard_layout(k), shard_layout(km)


@functools.partial(jax.jit, donate_argnums=(0, 1))
def _scatter_rows(k_buf, km_buf, slots, k_rows, km_rows):
    """Write freshly computed rows into their slots. Chunk-pad slots carry
    an out-of-bounds index (capacity + 1) and are dropped; the reserved zero
    row at index capacity is never a target. Buffers are donated: on
    backends with donation support the update is in place."""
    return (k_buf.at[:, slots].set(k_rows, mode="drop"),
            km_buf.at[:, slots].set(km_rows, mode="drop"))


@jax.jit
def _gather_stripes(k_buf, km_buf, slots):
    """Slot-gather the batch's stripes: (Q, v_r) slots ->
    (S, Q, v_r, Vloc+1) K and K.*M. A pure gather -- pad query rows point at
    the reserved zero row, so no mask pass or transpose touches the output."""
    return k_buf[:, slots], km_buf[:, slots]


class _RowCacheBase:
    """Host-side bookkeeping shared by the K/K.*M and M-row stores: exact
    LRU over a monotone tick with the current batch's rows pinned, free-list
    slot allocation, full and scoped invalidation, registry mirroring.
    Subclasses own the device buffers and the row compute; they must set
    ``capacity``, ``stats`` and ``_m`` before calling `_reset_map`."""

    def _mirror(self, name: str, n: float = 1) -> None:
        """Mirror a KCacheStats bump into the registry (no-op unattached)."""
        if self._m is not None:
            self._m[name].inc(n)
            self._m["resident"].set(len(self._slot_of))

    def _reset_map(self):
        self._slot_of: dict[int, int] = {}
        self._id_of = np.full(self.capacity, -1, np.int64)
        self._last_used = np.zeros(self.capacity, np.int64)
        self._free = list(range(self.capacity - 1, -1, -1))  # pop() -> 0,1,..
        self._tick = 0

    @property
    def resident(self) -> int:
        return len(self._slot_of)

    def invalidate(self):
        """Drop every cached row (all ids become misses)."""
        self._reset_map()
        self.stats.invalidations += 1
        self._mirror("invalidations")

    def invalidate_ids(self, word_ids) -> int:
        """Drop exactly the rows for ``word_ids``; returns how many were
        resident. The scoped invalidation for *embedding* updates: a row is
        a pure function of (word_id, vecs) (plus lambda for the K store), so
        changing the vectors of some words poisons only those words' rows.
        Corpus mutations, by contrast, need NO invalidation at all -- rows
        never depend on which documents exist (see
        `serving.wmd_service.WMDService.add_docs`)."""
        dropped = 0
        for wid in word_ids:
            s = self._slot_of.pop(int(wid), None)
            if s is None:
                continue
            self._id_of[s] = -1
            self._last_used[s] = 0
            self._free.append(s)
            dropped += 1
        if dropped:
            self.stats.invalidations += 1
            self._mirror("invalidations")
        return dropped

    def _alloc_slots(self, n: int) -> list[int]:
        """Free slots first, then exact-LRU eviction among rows not touched
        this tick (the current batch's hits are pinned by construction)."""
        slots = []
        while self._free and len(slots) < n:
            slots.append(self._free.pop())
        need = n - len(slots)
        if need:
            evictable = (self._id_of >= 0) & (self._last_used < self._tick)
            cand = np.nonzero(evictable)[0]
            order = cand[np.argsort(self._last_used[cand], kind="stable")]
            for s in order[:need]:
                del self._slot_of[int(self._id_of[s])]
                self._id_of[s] = -1
            self.stats.evictions += need
            self._mirror("evictions", need)
            slots.extend(int(s) for s in order[:need])
        return slots


class KCache(_RowCacheBase):
    """Device-resident (word_id, lambda)-keyed K / K.*M row cache.

    Args:
      capacity:    resident row slots; 0 disables the store (every call takes
                   the transient path -- the exact cache-off baseline).
      vecs:        (V, w) embeddings, host or device (ideally already placed
                   ``P(model)`` by `core.distributed.shard_wmd_inputs`).
      lamb:        entropy regularization the rows are keyed under.
      mesh:        optional mesh; with a ``model`` axis of size S the buffers
                   are sharded ``P(model, None, None)`` to match the vocab
                   striping. None = single-shard layout (S = 1).
      rows_bucket: static chunk size for the miss compute (one compiled
                   program; also the bit-reproducibility guarantee above).
      kexp_impl:   "jnp" (`core.sinkhorn.precompute_rows`) or "kernel" (the
                   row-subset Pallas kexp; single-shard meshes only).
      metrics:     optional `repro.obs.MetricsRegistry`; when set, every
                   KCacheStats counter is mirrored into ``wmd_kcache_*``
                   registry metrics at the same mutation sites, making the
                   cache scrapeable live. None = no mirroring, no overhead.
    """

    def __init__(self, capacity: int, vecs, lamb: float, *,
                 mesh=None, model_axis: str = "model",
                 rows_bucket: int = 128, kexp_impl: str = "jnp",
                 metrics=None):
        if kexp_impl not in ("jnp", "kernel"):
            raise ValueError(f"kexp_impl must be 'jnp' or 'kernel', "
                             f"got {kexp_impl!r}")
        self.capacity = int(capacity)
        self.lamb = float(lamb)
        self.rows_bucket = int(rows_bucket)
        self.kexp_impl = kexp_impl
        self._vecs = vecs if isinstance(vecs, jax.Array) else jnp.asarray(vecs)
        v = self._vecs.shape[0]
        self.num_shards = (int(mesh.shape[model_axis])
                           if mesh is not None else 1)
        if v % self.num_shards:
            raise ValueError(f"vocab {v} not divisible by model shards "
                             f"{self.num_shards}")
        if kexp_impl == "kernel" and self.num_shards > 1:
            raise ValueError("kexp_impl='kernel' supports single-shard "
                             "meshes only (Pallas does not run under GSPMD "
                             "vocab sharding)")
        self.vocab = v
        self.vloc = v // self.num_shards
        self._b2 = jnp.sum(self._vecs * self._vecs, axis=-1)
        self._sharding = (NamedSharding(mesh, P(model_axis, None, None))
                          if mesh is not None and self.num_shards > 1
                          else None)
        self._alloc_buffers()
        self.stats = KCacheStats()
        self._m = None
        if metrics is not None:
            self._m = {
                "lookups": metrics.counter(
                    "wmd_kcache_lookups_total",
                    "stripes_for_batch calls"),
                "hit_rows": metrics.counter(
                    "wmd_kcache_hit_rows_total",
                    "unique rows served from the resident store"),
                "miss_rows": metrics.counter(
                    "wmd_kcache_miss_rows_total",
                    "unique rows computed fresh"),
                "evictions": metrics.counter(
                    "wmd_kcache_evictions_total", "LRU evictions"),
                "bypasses": metrics.counter(
                    "wmd_kcache_bypasses_total",
                    "calls that skipped the resident store"),
                "invalidations": metrics.counter(
                    "wmd_kcache_invalidations_total",
                    "full or scoped row invalidations"),
                "resident": metrics.gauge(
                    "wmd_kcache_resident_rows",
                    "rows currently resident"),
            }
        self._reset_map()

    def _alloc_buffers(self):
        """Fresh all-zero row buffers (+1 row: the reserved zero row pad
        query rows gather). Also the recovery path when a failed donated
        scatter consumed the previous buffers."""
        shape = (self.num_shards, self.capacity + 1, self.vloc + 1)
        k = jnp.zeros(shape, jnp.float32)
        km = jnp.zeros(shape, jnp.float32)
        if self._sharding is not None:
            k = jax.device_put(k, self._sharding)
            km = jax.device_put(km, self._sharding)
        self._k_buf, self._km_buf = k, km

    # -- host-side bookkeeping (LRU machinery in `_RowCacheBase`) -------------

    def invalidate(self, lamb: float | None = None):
        """Drop every cached row (all ids become misses). Pass ``lamb`` to
        re-key the store under a new regularization strength."""
        if lamb is not None:
            self.lamb = float(lamb)
        super().invalidate()

    def ensure_lamb(self, lamb: float):
        """Invalidate iff ``lamb`` differs from the store's key (rows are
        keyed by (word_id, lambda) -- a changed lambda changes every row)."""
        if float(lamb) != self.lamb:
            self.invalidate(lamb)

    # -- row compute ----------------------------------------------------------

    def _compute_chunks(self, ids: np.ndarray):
        """Yield (chunk_len, k_rows, km_rows) over fixed rows_bucket chunks
        (pad ids point at word 0; their rows are discarded by the caller)."""
        rb = self.rows_bucket
        for lo in range(0, len(ids), rb):
            chunk = ids[lo:lo + rb]
            ids_p = np.zeros(rb, np.int32)
            ids_p[:len(chunk)] = chunk
            k_r, km_r = _row_stripes(jnp.asarray(ids_p), self._vecs,
                                     self._b2, lamb=self.lamb,
                                     num_shards=self.num_shards,
                                     kexp_impl=self.kexp_impl)
            yield len(chunk), k_r, km_r

    # -- the batch entry point ------------------------------------------------

    def stripes_for_batch(self, sel_b: np.ndarray, row_mask: np.ndarray, *,
                          use_cache: bool = True):
        """Assemble the batch's precompute stripes, computing only missing
        rows.

        Args:
          sel_b:    (Q, v_r) int word ids (pad slots point at word 0).
          row_mask: (Q, v_r) f32, 0.0 on pad query rows.
          use_cache: False forces the transient path (the cache-off
                     baseline) without reading or mutating the store.

        Returns (k_stripes, km_stripes, info): device (S, Q, v_r, Vloc+1)
        stripe pairs ready for `build_wmd_batch_fn_stripes` (slice ``[0]``
        for the single-host `sinkhorn_wmd_sparse_batch_stripes`), and a
        per-call info dict (unique / hits / misses / hit_rate / cached).
        """
        sel_b = np.asarray(sel_b)
        ids = np.unique(sel_b)                       # sorted: stable dedup
        self.stats.lookups += 1
        self._mirror("lookups")
        cached = use_cache and 0 < len(ids) <= self.capacity
        if not cached:
            return self._transient(ids, sel_b, row_mask, use_cache)
        self._tick += 1
        slot_arr = np.array([self._slot_of.get(int(i), -1) for i in ids],
                            np.int64)
        hit = slot_arr >= 0
        self._last_used[slot_arr[hit]] = self._tick  # pin the batch's hits
        miss_ids = ids[~hit]
        if len(miss_ids):
            new_slots = self._alloc_slots(len(miss_ids))
            try:
                rb = self.rows_bucket
                for lo, (n_c, k_r, km_r) in zip(
                        range(0, len(miss_ids), rb),
                        self._compute_chunks(miss_ids)):
                    # chunk-pad slots target capacity + 1: out of bounds of
                    # the (capacity + 1)-row buffer, dropped by the scatter
                    slots_p = np.full(rb, self.capacity + 1, np.int32)
                    slots_p[:n_c] = new_slots[lo:lo + n_c]
                    self._k_buf, self._km_buf = _scatter_rows(
                        self._k_buf, self._km_buf, jnp.asarray(slots_p),
                        k_r, km_r)
            except BaseException:
                # a failed row compute/scatter must not poison the map: the
                # new ids were never (fully) materialized, so return their
                # slots to the free list unmapped. Already-evicted victims
                # stay evicted (a later miss recomputes them) -- only
                # *unsubstantiated residency* would break exactness. If the
                # error struck inside the donated scatter itself, the old
                # buffers may already be consumed (donation) -- rebuild an
                # empty store so the cache stays usable after the raise.
                deleted = getattr(self._k_buf, "is_deleted", bool)() or \
                    getattr(self._km_buf, "is_deleted", bool)()
                if deleted:
                    self._alloc_buffers()
                    self._reset_map()
                else:
                    self._free.extend(new_slots)
                raise
            # map ids -> slots only after every scatter succeeded
            for i, s in zip(miss_ids, new_slots):
                self._slot_of[int(i)] = s
                self._id_of[s] = int(i)
                self._last_used[s] = self._tick
            slot_arr[~hit] = new_slots
        n_hit, n_miss = int(hit.sum()), len(miss_ids)
        self.stats.hit_rows += n_hit
        self.stats.miss_rows += n_miss
        if self._m is not None:
            self._mirror("hit_rows", n_hit)
            self._mirror("miss_rows", n_miss)
        slots_b = slot_arr[np.searchsorted(ids, sel_b)]
        # pad query rows gather the reserved zero row (index capacity)
        slots_b = np.where(np.asarray(row_mask) > 0, slots_b,
                           self.capacity).astype(np.int32)
        k_s, km_s = _gather_stripes(self._k_buf, self._km_buf,
                                    jnp.asarray(slots_b))
        return k_s, km_s, {"unique": len(ids), "hits": n_hit,
                           "misses": n_miss,
                           "hit_rate": n_hit / len(ids), "cached": True}

    def _transient(self, ids, sel_b, row_mask, use_cache):
        """Compute every unique row fresh into a throwaway store (cache off,
        or the batch's unique ids exceed capacity). Identical dedup, row
        compute and slot-gather as the resident path -- so cache on/off are
        bitwise equal by construction."""
        if use_cache and self.capacity > 0:
            # capacity overflow: these are real misses of an enabled store.
            # Calls with the store disabled (capacity 0) or explicitly
            # bypassed (use_cache=False) never had anything to hit, so they
            # count only as bypasses -- not into the hit-rate denominator.
            self.stats.miss_rows += len(ids)
            self._mirror("miss_rows", len(ids))
        self.stats.bypasses += 1
        self._mirror("bypasses")
        parts = [(k_r, km_r) for _, k_r, km_r in self._compute_chunks(ids)]
        zero = jnp.zeros((self.num_shards, 1, self.vloc + 1), jnp.float32)
        k_t = jnp.concatenate([p[0] for p in parts] + [zero], axis=1)
        km_t = jnp.concatenate([p[1] for p in parts] + [zero], axis=1)
        zero_row = k_t.shape[1] - 1
        pos_b = np.where(np.asarray(row_mask) > 0,
                         np.searchsorted(ids, sel_b),
                         zero_row).astype(np.int32)
        k_s, km_s = _gather_stripes(k_t, km_t, jnp.asarray(pos_b))
        return k_s, km_s, {"unique": len(ids), "hits": 0,
                           "misses": len(ids), "hit_rate": 0.0,
                           "cached": False}


@functools.partial(jax.jit, donate_argnums=(0,))
def _scatter_m_rows(m_buf, slots, rows):
    """Write freshly computed M rows into their slots. Chunk-pad slots carry
    an out-of-bounds index (capacity + 1) and are dropped; the reserved +inf
    row at index capacity is never a target."""
    return m_buf.at[slots].set(rows, mode="drop")


class MCache(_RowCacheBase):
    """Device-resident word-id-keyed M-row cache for the bound tiers.

    The retrieval cascade's M-stripe assembly (`core.rwmd.assemble_m_stripes`)
    recomputes every unique row per dispatch; on Zipf streams most rows
    repeat across batches exactly as the K rows do. This store keeps them
    resident in ONE (capacity + 1, V + 1) buffer -- rows are keyed by
    ``word_id`` alone (M is pure geometry: no lambda enters), replicated
    (the bound ELL is replicated, not vocab-sharded), and row index
    ``capacity`` is a reserved **+inf** row that pad query rows gather (the
    doc-side min must never be won by a pad row -- the opposite sign of the
    K store's reserved zero row). Misses go through the same
    `core.rwmd._m_row_block` fixed ``rows_bucket`` spelling as the transient
    assembly, so a row's bits never depend on its chunk-mates and cache
    on/off stripes are bitwise identical by construction.

    Args:
      capacity:    resident row slots; 0 disables the store.
      vecs:        (V, w) embeddings (same array the bound path uses).
      rows_bucket: static miss-compute chunk (must match the service's
                   transient ``rows_bucket`` for the on/off bitwise pin).
      metrics:     optional `repro.obs.MetricsRegistry` -> ``wmd_mcache_*``.
    """

    def __init__(self, capacity: int, vecs, *, rows_bucket: int = 128,
                 metrics=None):
        self.capacity = int(capacity)
        self.rows_bucket = int(rows_bucket)
        self._vecs = vecs if isinstance(vecs, jax.Array) else jnp.asarray(vecs)
        self.vocab = self._vecs.shape[0]
        self._b2 = jnp.sum(self._vecs * self._vecs, axis=-1)
        self._alloc_buffers()
        self.stats = KCacheStats()
        self._m = None
        if metrics is not None:
            self._m = {
                "lookups": metrics.counter(
                    "wmd_mcache_lookups_total",
                    "m_stripes_for_batch calls"),
                "hit_rows": metrics.counter(
                    "wmd_mcache_hit_rows_total",
                    "unique M rows served from the resident store"),
                "miss_rows": metrics.counter(
                    "wmd_mcache_miss_rows_total",
                    "unique M rows computed fresh"),
                "evictions": metrics.counter(
                    "wmd_mcache_evictions_total", "LRU evictions"),
                "bypasses": metrics.counter(
                    "wmd_mcache_bypasses_total",
                    "calls that skipped the resident store"),
                "invalidations": metrics.counter(
                    "wmd_mcache_invalidations_total",
                    "full or scoped M-row invalidations"),
                "resident": metrics.gauge(
                    "wmd_mcache_resident_rows",
                    "M rows currently resident"),
            }
        self._reset_map()

    def _alloc_buffers(self):
        """Fresh all-+inf buffer (+1 row: the reserved +inf row pad query
        rows gather -- scatters never target it, so any slot a real id has
        not yet claimed is also harmlessly +inf). Also the recovery path
        when a failed donated scatter consumed the previous buffer."""
        self._m_buf = jnp.full((self.capacity + 1, self.vocab + 1),
                               jnp.inf, jnp.float32)

    def _compute_chunks(self, ids: np.ndarray):
        """Yield (chunk_len, m_rows) over fixed rows_bucket chunks (pad ids
        point at word 0; their rows are discarded by the caller)."""
        from repro.core.rwmd import _m_row_block
        rb = self.rows_bucket
        for lo in range(0, len(ids), rb):
            chunk = ids[lo:lo + rb]
            ids_p = np.zeros(rb, np.int32)
            ids_p[:len(chunk)] = chunk
            yield len(chunk), _m_row_block(jnp.asarray(ids_p), self._vecs,
                                           self._b2)

    def m_stripes_for_batch(self, sel_b: np.ndarray, row_mask: np.ndarray, *,
                            use_cache: bool = True):
        """Assemble the batch's (Q, v_r, V+1) M stripes, computing only
        missing rows. Mirrors `KCache.stripes_for_batch`; the transient path
        IS `core.rwmd.assemble_m_stripes`, so cache on/off (and this store
        vs. no store at all) are bitwise equal by construction."""
        from repro.core.rwmd import _gather_m_stripes, assemble_m_stripes
        sel_b = np.asarray(sel_b)
        ids = np.unique(sel_b)                       # sorted: stable dedup
        self.stats.lookups += 1
        self._mirror("lookups")
        cached = use_cache and 0 < len(ids) <= self.capacity
        if not cached:
            if use_cache and self.capacity > 0:
                self.stats.miss_rows += len(ids)
                self._mirror("miss_rows", len(ids))
            self.stats.bypasses += 1
            self._mirror("bypasses")
            m_pad = assemble_m_stripes(sel_b, row_mask, self._vecs,
                                       b2=self._b2,
                                       rows_bucket=self.rows_bucket)
            return m_pad, {"unique": len(ids), "hits": 0,
                           "misses": len(ids), "hit_rate": 0.0,
                           "cached": False}
        self._tick += 1
        slot_arr = np.array([self._slot_of.get(int(i), -1) for i in ids],
                            np.int64)
        hit = slot_arr >= 0
        self._last_used[slot_arr[hit]] = self._tick  # pin the batch's hits
        miss_ids = ids[~hit]
        if len(miss_ids):
            new_slots = self._alloc_slots(len(miss_ids))
            try:
                rb = self.rows_bucket
                for lo, (n_c, m_r) in zip(range(0, len(miss_ids), rb),
                                          self._compute_chunks(miss_ids)):
                    slots_p = np.full(rb, self.capacity + 1, np.int32)
                    slots_p[:n_c] = new_slots[lo:lo + n_c]
                    self._m_buf = _scatter_m_rows(
                        self._m_buf, jnp.asarray(slots_p), m_r)
            except BaseException:
                # same rollback contract as the K store: never leave
                # unsubstantiated residency behind; rebuild the (donated)
                # buffer if the failed scatter consumed it.
                if getattr(self._m_buf, "is_deleted", bool)():
                    self._alloc_buffers()
                    self._reset_map()
                else:
                    self._free.extend(new_slots)
                raise
            for i, s in zip(miss_ids, new_slots):
                self._slot_of[int(i)] = s
                self._id_of[s] = int(i)
                self._last_used[s] = self._tick
            slot_arr[~hit] = new_slots
        n_hit, n_miss = int(hit.sum()), len(miss_ids)
        self.stats.hit_rows += n_hit
        self.stats.miss_rows += n_miss
        if self._m is not None:
            self._mirror("hit_rows", n_hit)
            self._mirror("miss_rows", n_miss)
        slots_b = slot_arr[np.searchsorted(ids, sel_b)]
        # pad query rows gather the reserved +inf row (index capacity)
        slots_b = np.where(np.asarray(row_mask) > 0, slots_b,
                           self.capacity).astype(np.int32)
        m_pad = _gather_m_stripes(self._m_buf, jnp.asarray(slots_b))
        return m_pad, {"unique": len(ids), "hits": n_hit, "misses": n_miss,
                       "hit_rate": n_hit / len(ids), "cached": True}
