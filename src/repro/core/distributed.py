"""Multi-chip / multi-pod Sinkhorn-WMD engine (shard_map).

Distribution plan (DESIGN.md section 4.1) -- the TPU analogue of the paper's
PIUMA DGAS scale-out:

  * docs (N)  shard over the ``data`` (and ``pod``) mesh axes. Documents are
    independent given K, so this axis needs **zero** communication -- the
    paper's "one query vs many target docs" parallelism.
  * vocab (V) shards over ``model``. Each chip holds the K/K.*M stripe for
    its vocab range and exactly the ELL nonzeros whose word-id falls in that
    range (`formats.rebucket_for_vocab_shards`). The SDDMM dot product
    w[j,k] = <K[:, col], u[:, j]> is therefore **fully local** -- a word's K
    column lives with its nonzero, the DGAS locality argument made explicit.
  * the only collective is one ``psum`` over ``model`` per Sinkhorn iteration
    (the partial SpMM contributions, v_r x N_local floats per chip), plus one
    scalar-per-doc psum for the final distances. Per-chip psum bytes are
    independent of pod count at fixed per-chip work -- the TPU version of the
    paper's "no performance hit from 1 die to 8 dies".

The per-device compute reuses the *same* fused SDDMM-SpMM code (jnp or
Pallas) as the single-chip path; `ops.sddmm_spmm_chunked` is the one-chip
replay of this exact decomposition.

Query padding: multiple queries are bucketed to a common v_r; pad rows carry
r = 1 and an all-zero K row (`pad_query` + the row mask in `masked_k`), which
makes padded rows contribute *exactly* zero to every w, x and WMD -- no
epsilon approximations.
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import shard_map
from repro.core.cost_matrix import cdist
from repro.core.sparse_sinkhorn import pad_k, safe_recip
from repro.core import sparse_sinkhorn as ss


# ---------------------------------------------------------------------------
# Query padding (exact, mask-based)
# ---------------------------------------------------------------------------

def pad_query(sel_idx: np.ndarray, r_sel: np.ndarray, v_r_target: int
              ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Pad a query to a bucket size. Returns (sel_idx, r_sel, row_mask).

    Pad rows point at word 0 with r = 1.0; the row mask zeroes their K rows
    so they contribute nothing anywhere (see module docstring).
    """
    v_r = sel_idx.shape[0]
    if v_r > v_r_target:
        raise ValueError(f"query v_r {v_r} exceeds bucket {v_r_target}")
    pad = v_r_target - v_r
    sel_p = np.concatenate([sel_idx, np.zeros(pad, sel_idx.dtype)])
    r_p = np.concatenate([r_sel.astype(np.float32), np.ones(pad, np.float32)])
    mask = np.concatenate([np.ones(v_r, np.float32), np.zeros(pad, np.float32)])
    return sel_p, r_p, mask


def pad_query_batch(sels: Sequence[np.ndarray], rs: Sequence[np.ndarray],
                    v_r_target: int
                    ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Bucket Q mixed-size queries to a common v_r. Returns (Q, v_r) arrays
    (sel_idx, r_sel, row_mask) -- each query padded by `pad_query`, stacked."""
    padded = [pad_query(s, r, v_r_target) for s, r in zip(sels, rs)]
    return (np.stack([p[0] for p in padded]),
            np.stack([p[1] for p in padded]),
            np.stack([p[2] for p in padded]))


def masked_k(vecs_sel: jax.Array, vecs_loc: jax.Array, lamb: float,
             row_mask: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Local K / K.*M stripes with padded query rows zeroed."""
    m = cdist(vecs_sel, vecs_loc)                      # (v_r, Vloc)
    k = jnp.exp(-lamb * m) * row_mask[:, None]
    return k, k * m


def masked_k_batch(vecs_sel: jax.Array, vecs_loc: jax.Array, lamb: float,
                   row_mask: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Batched local stripes: (Q, v_r, w) queries -> (Q, v_r, Vloc) K, K.*M."""
    m = jax.vmap(lambda a: cdist(a, vecs_loc))(vecs_sel)
    k = jnp.exp(-lamb * m) * row_mask[..., None]
    return k, k * m


# ---------------------------------------------------------------------------
# The per-device program
# ---------------------------------------------------------------------------

def _local_solve(vecs_sel, r_sel, row_mask, vecs_loc, cols_loc, vals_loc, *,
                 lamb: float, max_iter: int, model_axis: str,
                 use_kernel: bool):
    """Runs on every device under shard_map. Doc axis: local slice; vocab
    axis: local stripe. Returns the (N_local,) WMD slice."""
    k, km = masked_k(vecs_sel, vecs_loc, lamb, row_mask)
    k_pad, km_pad = pad_k(k), pad_k(km)
    v_r = r_sel.shape[0]
    n_loc = cols_loc.shape[0]
    ones_r = jnp.ones_like(r_sel)

    def type1_partial(u):
        if use_kernel:
            from repro.kernels import ops
            return ops.sddmm_spmm_type1(k_pad, ones_r, u, cols_loc, vals_loc)
        return ss.sddmm_spmm_type1(k_pad, ones_r, u, cols_loc, vals_loc)

    def body(_, x):
        u = safe_recip(x)
        x_part = type1_partial(u)                      # local vocab stripe
        x_full = jax.lax.psum(x_part, model_axis)      # THE collective
        return x_full / r_sel[:, None]

    x0 = jnp.full((v_r, n_loc), 1.0 / v_r, dtype=k.dtype)
    x = jax.lax.fori_loop(0, max_iter, body, x0)
    u = safe_recip(x)
    # final distance: local xm then scalar-per-doc psum (v_r x cheaper than
    # reducing xm itself)
    if use_kernel:
        from repro.kernels import ops
        wmd_part = ops.sddmm_spmm_type2(k_pad, km_pad, u, cols_loc, vals_loc)
    else:
        wmd_part = ss.sddmm_spmm_type2(k_pad, km_pad, u, cols_loc, vals_loc)
    return jax.lax.psum(wmd_part, model_axis)


# ---------------------------------------------------------------------------
# Public driver
# ---------------------------------------------------------------------------

def build_wmd_fn(mesh: Mesh, *, lamb: float, max_iter: int,
                 doc_axes: Sequence[str] = ("data",),
                 model_axis: str = "model",
                 use_kernel: bool = False):
    """Build the jit'd multi-chip WMD solver for ``mesh``.

    The returned fn takes (vecs_sel, r_sel, row_mask, vecs, cols_b, vals_b):
      vecs_sel (v_r, w)              replicated   -- query word embeddings
      r_sel    (v_r,)                replicated
      row_mask (v_r,)                replicated
      vecs     (V, w)                P(model)     -- vocab-striped embeddings
      cols_b   (S_model, N, nnz_loc) P(model, doc_axes) -- rebucketed ELL
      vals_b   (S_model, N, nnz_loc) P(model, doc_axes)
    and returns wmd (N,) sharded over doc_axes.
    """
    doc_spec = P(tuple(doc_axes))
    in_specs = (P(None, None), P(None), P(None),
                P(model_axis, None),
                P(model_axis, *[tuple(doc_axes)], None),
                P(model_axis, *[tuple(doc_axes)], None))
    out_specs = doc_spec

    def per_device(vecs_sel, r_sel, row_mask, vecs_loc, cols_b, vals_b):
        # leading (shard-local) model axis is size 1 after sharding
        cols_loc = cols_b[0]
        vals_loc = vals_b[0]
        return _local_solve(vecs_sel, r_sel, row_mask, vecs_loc,
                            cols_loc, vals_loc, lamb=lamb, max_iter=max_iter,
                            model_axis=model_axis, use_kernel=use_kernel)

    fn = shard_map(per_device, mesh=mesh, in_specs=in_specs,
                   out_specs=out_specs, check_vma=False)
    return jax.jit(fn)


def build_wmd_batch_fn(mesh: Mesh, *, lamb: float, max_iter: int,
                       doc_axes: Sequence[str] = ("data",),
                       model_axis: str = "model", impl: str = "fused",
                       docs_chunk: int | None = None,
                       chunk_placement: str = "solve", tol: float = 0.0,
                       with_info: bool = False):
    """Build the jit'd multi-query batched WMD solver for ``mesh``.

    The (Q, v_r, N) analogue of `build_wmd_fn`: per iteration, every device
    performs ONE shared ELL gather feeding all Q queries' SDDMM and SpMM
    contractions (`sddmm_spmm_type1_batch`), and the Q solves share the same
    single psum over ``model`` -- collective count per iteration is
    independent of Q, so batching amortizes both the gather and the
    communication latency.

    impl selects the contraction path ("fused" | "unfused" | "kernel", the
    same table as the single-chip solvers). docs_chunk cache-blocks each
    device's local doc slice, with ``chunk_placement`` choosing where the
    chunk loop sits (see sparse_sinkhorn "Batched engine & cache blocking"):
      * "solve" (default) -- chunk loop OUTSIDE the Sinkhorn loop: each
        chunk runs all its iterations cache-resident. Fastest on CPU /
        small meshes, but the psum count becomes iterations x chunks, and
        tol freezes each (query, chunk) block at its own convergence (the
        reported n_iter/delta are per-query maxima over chunks).
      * "iteration" -- per-op chunking inside the iteration-major loop:
        keeps ONE psum per iteration (the multi-chip contract) and global
        per-query freeze semantics exactly matching
        `core.convergence.sinkhorn_wmd_converged_batch`.

    Early exit (tol > 0): the loop is `ss.batched_sinkhorn_loop` with an
    **all-shards convergence vote** -- each device reduces its local doc
    slice to a per-query delta, and a pmax all-reduce over (model, *doc_axes)
    makes the vote unanimous. The pmax of per-shard inf-norms IS the global
    inf-norm, so per-query freeze/n_iter decisions match the single-host
    `sinkhorn_wmd_converged_batch` exactly (equivalently one could psum
    per-shard "still active" votes; the pmax also reproduces the reported
    delta). Converged queries stop contributing writes on every shard; the
    loop (and with it all collectives) exits when every query has converged
    or at ``max_iter``. With tol = 0.0 the loop runs the fixed budget and no
    vote collective is issued.

    The returned fn takes (vecs_sel, r_sel, row_mask, vecs, cols_b, vals_b):
      vecs_sel (Q, v_r, w)           replicated -- bucketed query embeddings
      r_sel    (Q, v_r)              replicated    (pad rows = 1.0)
      row_mask (Q, v_r)              replicated    (pad rows = 0.0)
      vecs     (V, w)                P(model)
      cols_b   (S_model, N, nnz_loc) P(model, doc_axes)
      vals_b   (S_model, N, nnz_loc) P(model, doc_axes)
    and returns wmd (Q, N) with the doc axis sharded over doc_axes -- or,
    with with_info=True, (wmd, n_iter (Q,), delta (Q,)) where the trailing
    two are replicated (the vote makes them identical on every device).

    Retracing happens per distinct Q; callers bound it by bucketing Q
    (see serving.wmd_service admission).
    """
    if chunk_placement not in ("solve", "iteration"):
        raise ValueError(f"chunk_placement must be 'solve' or 'iteration', "
                         f"got {chunk_placement!r}")
    in_specs = (P(None, None, None), P(None, None), P(None, None),
                P(model_axis, None),
                P(model_axis, *[tuple(doc_axes)], None),
                P(model_axis, *[tuple(doc_axes)], None))
    wmd_spec = P(None, tuple(doc_axes))
    out_specs = (wmd_spec, P(None), P(None)) if with_info else wmd_spec
    vote_axes = (model_axis, *doc_axes)

    def per_device(vecs_sel, r_sel, row_mask, vecs_loc, cols_b, vals_b):
        k, km = masked_k_batch(vecs_sel, vecs_loc, lamb, row_mask)
        wmd, n_iter, delta = _local_batched_solve(
            pad_k(k), pad_k(km), r_sel, cols_b[0], vals_b[0],
            max_iter=max_iter, model_axis=model_axis, impl=impl,
            docs_chunk=docs_chunk, chunk_placement=chunk_placement, tol=tol,
            vote_axes=vote_axes)
        if with_info:
            return wmd, n_iter, delta
        return wmd

    fn = shard_map(per_device, mesh=mesh, in_specs=in_specs,
                   out_specs=out_specs, check_vma=False)
    return jax.jit(fn)


def _local_batched_solve(k_pad, km_pad, r_sel, cols_loc, vals_loc, *,
                         max_iter: int, model_axis: str, impl: str,
                         docs_chunk: int | None, chunk_placement: str,
                         tol: float, vote_axes):
    """Per-device batched Sinkhorn solve on local (Q, v_r, Vloc+1) stripes.

    The shared core of `build_wmd_batch_fn` (stripes computed in-program
    from embeddings) and `build_wmd_batch_fn_stripes` (stripes preassembled
    by the cross-query cache). Returns (wmd, n_iter, delta); runs under
    shard_map, issuing one psum over ``model_axis`` per iteration.
    """
    q, v_r = r_sel.shape
    ones_r = jnp.ones_like(r_sel)
    type1 = ss._resolve_impl("type1", impl, True)
    type2 = ss._resolve_impl("type2", impl, True)
    iter_chunk = docs_chunk if chunk_placement == "iteration" else None

    def solve_chunk(x0_c, cols_c, vals_c):
        def iteration(x):
            u = safe_recip(x)
            x_part = type1(k_pad, ones_r, u, cols_c, vals_c,
                           docs_chunk=iter_chunk)
            x_full = jax.lax.psum(x_part, model_axis)  # THE collective
            return x_full / r_sel[:, :, None]

        if tol:
            x, delta, n_iter = ss.batched_sinkhorn_loop(
                iteration, x0_c, max_iter=max_iter, tol=tol,
                delta_all_reduce=lambda d: jax.lax.pmax(d, vote_axes))
        else:
            x = jax.lax.fori_loop(0, max_iter,
                                  lambda _, xx: iteration(xx), x0_c)
            delta = jnp.zeros((q,), x0_c.dtype)
            n_iter = jnp.full((q,), max_iter, jnp.int32)
        u = safe_recip(x)
        wmd_part = type2(k_pad, km_pad, u, cols_c, vals_c,
                         docs_chunk=iter_chunk)
        return jax.lax.psum(wmd_part, model_axis), n_iter, delta

    n_loc = cols_loc.shape[0]
    x0 = jnp.full((q, v_r, n_loc), 1.0 / v_r, dtype=k_pad.dtype)
    if chunk_placement == "solve" and docs_chunk and docs_chunk < n_loc:
        # unrolled chunk loop (trailing chunk may be smaller -- python
        # slicing keeps shapes static per chunk, no doc padding needed)
        parts = [solve_chunk(x0[:, :, s:s + docs_chunk],
                             cols_loc[s:s + docs_chunk],
                             vals_loc[s:s + docs_chunk])
                 for s in range(0, n_loc, docs_chunk)]
        wmd = jnp.concatenate([p[0] for p in parts], axis=-1)
        n_iter = jnp.max(jnp.stack([p[1] for p in parts]), axis=0)
        delta = jnp.max(jnp.stack([p[2] for p in parts]), axis=0)
    else:
        wmd, n_iter, delta = solve_chunk(x0, cols_loc, vals_loc)
    return wmd, n_iter, delta


def build_wmd_batch_fn_stripes(mesh: Mesh, *, max_iter: int,
                               doc_axes: Sequence[str] = ("data",),
                               model_axis: str = "model",
                               impl: str = "fused",
                               docs_chunk: int | None = None,
                               chunk_placement: str = "solve",
                               tol: float = 0.0, with_info: bool = False):
    """Batched WMD solver consuming *preassembled* K / K.*M stripes.

    The distributed consumer of the cross-query cache (`core.kcache`): the
    per-query precompute no longer happens inside the device program -- the
    cache hands each vocab shard its stripe slice, already masked for pad
    query rows and carrying the shard-local zero pad column, laid out like
    the rebucketed ELL:

      k_b, km_b (S_model, Q, v_r, Vloc+1)  P(model)  -- per-shard stripes
      r_sel     (Q, v_r)                   replicated (pad rows = 1.0)
      cols_b    (S_model, N, nnz_loc)      P(model, doc_axes)
      vals_b    (S_model, N, nnz_loc)      P(model, doc_axes)

    and returns wmd (Q, N) sharded over doc_axes (plus (n_iter, delta) with
    ``with_info=True``). No ``lamb``: it is baked into the cached rows, and
    the cache invalidates itself on a lambda change. Everything else
    (impl table, docs_chunk/chunk_placement, early-exit vote) is identical
    to `build_wmd_batch_fn`, with which it shares `_local_batched_solve`.
    """
    if chunk_placement not in ("solve", "iteration"):
        raise ValueError(f"chunk_placement must be 'solve' or 'iteration', "
                         f"got {chunk_placement!r}")
    in_specs = (P(model_axis, None, None, None),
                P(model_axis, None, None, None),
                P(None, None),
                P(model_axis, *[tuple(doc_axes)], None),
                P(model_axis, *[tuple(doc_axes)], None))
    wmd_spec = P(None, tuple(doc_axes))
    out_specs = (wmd_spec, P(None), P(None)) if with_info else wmd_spec
    vote_axes = (model_axis, *doc_axes)

    def per_device(k_b, km_b, r_sel, cols_b, vals_b):
        wmd, n_iter, delta = _local_batched_solve(
            k_b[0], km_b[0], r_sel, cols_b[0], vals_b[0],
            max_iter=max_iter, model_axis=model_axis, impl=impl,
            docs_chunk=docs_chunk, chunk_placement=chunk_placement, tol=tol,
            vote_axes=vote_axes)
        if with_info:
            return wmd, n_iter, delta
        return wmd

    fn = shard_map(per_device, mesh=mesh, in_specs=in_specs,
                   out_specs=out_specs, check_vma=False)
    return jax.jit(fn)


def build_wmd_fn_docsharded(mesh: Mesh, *, lamb: float, max_iter: int,
                            use_kernel: bool = False):
    """Doc-sharded / K-replicated layout (the §Perf-optimized engine for
    moderate v_r): K is only v_r x V x 4B (12.8 MB at the paper's scale), so
    every chip keeps the whole stripe and docs shard over ALL mesh axes --
    the Sinkhorn loop then has ZERO collectives (vs one psum/iter for the
    vocab-sharded engine). The vocab-sharded engine remains the scale-out
    path for large v_r buckets where K would not fit (DESIGN.md section 4.1).

    Returned fn takes (vecs_sel, r_sel, row_mask, vecs, cols, vals):
      vecs (V, w) replicated; cols/vals (N, nnz) sharded over every mesh
      axis on the doc dim.
    """
    all_axes = tuple(mesh.axis_names)
    in_specs = (P(None, None), P(None), P(None), P(None, None),
                P(all_axes, None), P(all_axes, None))

    def per_device(vecs_sel, r_sel, row_mask, vecs, cols_loc, vals_loc):
        k, km = masked_k(vecs_sel, vecs, lamb, row_mask)
        k_pad, km_pad = pad_k(k), pad_k(km)
        v_r = r_sel.shape[0]
        n_loc = cols_loc.shape[0]
        x0 = jnp.full((v_r, n_loc), 1.0 / v_r, dtype=k.dtype)

        def t1(u):
            if use_kernel:
                from repro.kernels import ops
                return ops.sddmm_spmm_type1(k_pad, r_sel, u, cols_loc,
                                            vals_loc)
            return ss.sddmm_spmm_type1(k_pad, r_sel, u, cols_loc, vals_loc)

        x = jax.lax.fori_loop(0, max_iter,
                              lambda _, x: t1(safe_recip(x)), x0)
        u = safe_recip(x)
        if use_kernel:
            from repro.kernels import ops
            return ops.sddmm_spmm_type2(k_pad, km_pad, u, cols_loc, vals_loc)
        return ss.sddmm_spmm_type2(k_pad, km_pad, u, cols_loc, vals_loc)

    fn = shard_map(per_device, mesh=mesh, in_specs=in_specs,
                   out_specs=P(all_axes), check_vma=False)
    return jax.jit(fn)


def shard_wmd_inputs(mesh: Mesh, vecs: np.ndarray, cols_b: np.ndarray,
                     vals_b: np.ndarray, *, doc_axes: Sequence[str] = ("data",),
                     model_axis: str = "model"):
    """Place host arrays on the mesh with the layouts build_wmd_fn expects."""
    dev = lambda spec: NamedSharding(mesh, spec)
    vecs_d = jax.device_put(vecs, dev(P(model_axis, None)))
    cols_d = jax.device_put(cols_b, dev(P(model_axis, tuple(doc_axes), None)))
    vals_d = jax.device_put(vals_b, dev(P(model_axis, tuple(doc_axes), None)))
    return vecs_d, cols_d, vals_d
