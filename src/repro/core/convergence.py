"""Convergence-monitored Sinkhorn solve ("while x changes" done properly).

The paper (section III-B1) notes the ideal loop runs "as long as there is any
change in the output" but uses a fixed ``max_iter`` cutoff in practice. This
module provides the ideal form -- a `jax.lax.while_loop` on the infinity-norm
iterate delta -- used by the serving path where query latency matters and
most queries converge in far fewer than max_iter iterations.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.sinkhorn import precompute
from repro.core.sparse_sinkhorn import (_final_batch, _iteration_batch,
                                        batched_sinkhorn_loop, pad_k,
                                        precompute_batch, safe_recip,
                                        sddmm_spmm_type1, sddmm_spmm_type2)


class ConvergedWMD(NamedTuple):
    wmd: jax.Array     # (N,) distances
    n_iter: jax.Array  # iterations actually executed
    delta: jax.Array   # final |dx|_inf


@functools.partial(jax.jit, static_argnames=("max_iter",))
def sinkhorn_wmd_converged(sel_idx: jax.Array, r_sel: jax.Array,
                           cols: jax.Array, vals: jax.Array, vecs: jax.Array,
                           lamb: float, max_iter: int,
                           tol: float = 1e-6) -> ConvergedWMD:
    """Sparse fused Sinkhorn-WMD with early exit on |x_t - x_{t-1}|_inf < tol."""
    pre = precompute(sel_idx, r_sel, vecs, lamb)
    k_pad = pad_k(pre.K)
    km_pad = pad_k(pre.KM)
    v_r = r_sel.shape[0]
    n = cols.shape[0]
    x0 = jnp.full((v_r, n), 1.0 / v_r, dtype=pre.K.dtype)

    def cond(carry):
        _, delta, it = carry
        return (it < max_iter) & (delta >= tol)

    def body(carry):
        x, _, it = carry
        x_new = sddmm_spmm_type1(k_pad, pre.r, safe_recip(x), cols, vals)
        # relative iterate delta: x spans a huge dynamic range (x ~ K-scale),
        # so an absolute norm would never cross tol for strongly regularized K.
        rel = jnp.max(jnp.abs(x_new - x) / (jnp.abs(x) + 1e-30))
        return x_new, rel, it + 1

    x, delta, n_iter = jax.lax.while_loop(
        cond, body, (x0, jnp.asarray(jnp.inf, x0.dtype), jnp.asarray(0)))
    wmd = sddmm_spmm_type2(k_pad, km_pad, safe_recip(x), cols, vals)
    return ConvergedWMD(wmd=wmd, n_iter=n_iter, delta=delta)


class BatchConvergedWMD(NamedTuple):
    wmd: jax.Array     # (Q, N) distances
    n_iter: jax.Array  # (Q,) iterations each query actually ran
    delta: jax.Array   # (Q,) final per-query relative |dx|_inf


@functools.partial(jax.jit,
                   static_argnames=("max_iter", "impl", "docs_chunk"))
def sinkhorn_wmd_converged_batch(sel_idx: jax.Array, r_sel: jax.Array,
                                 cols: jax.Array, vals: jax.Array,
                                 vecs: jax.Array, lamb: float, max_iter: int,
                                 tol: float = 1e-6,
                                 row_mask: jax.Array | None = None,
                                 impl: str = "fused",
                                 docs_chunk: int | None = None
                                 ) -> BatchConvergedWMD:
    """Batched early-exit solve with **per-query convergence masking**.

    All Q queries advance through the shared-gather batched iteration, but a
    query whose relative iterate delta drops below ``tol`` is *frozen*: its x
    block is carried forward unchanged (`jnp.where` on the per-query active
    mask) while stragglers keep iterating. Freezing is exact -- a frozen
    query's trajectory is bit-identical to one that stopped at its own
    convergence point, because queries never interact. The loop exits when
    every query has converged or at ``max_iter``. (The loop core is
    `sparse_sinkhorn.batched_sinkhorn_loop`, shared with the fixed-budget
    solver and the distributed shard_map engine.)

    sel_idx/r_sel/row_mask are (Q, v_r) bucketed queries (see pad_query).
    impl selects the contraction path (same table as
    `sinkhorn_wmd_sparse_batch`). docs_chunk here is PER-OP (inside each
    iteration-major step, bitwise exact) -- unlike the per-solve chunk
    hoisting of `sinkhorn_wmd_sparse_batch` -- because the global per-query
    freeze masks and the reported n_iter/delta are defined over the full
    doc axis.
    """
    pre = precompute_batch(sel_idx, r_sel, vecs, lamb, row_mask)
    k_pad = pad_k(pre.K)
    km_pad = pad_k(pre.KM)
    q, v_r = r_sel.shape
    n = cols.shape[0]
    x0 = jnp.full((q, v_r, n), 1.0 / v_r, dtype=pre.K.dtype)

    def iteration(x):
        return _iteration_batch(impl, k_pad, pre.r, x, cols, vals,
                                docs_chunk)

    x, delta, n_iter = batched_sinkhorn_loop(iteration, x0,
                                             max_iter=max_iter, tol=tol)
    wmd = _final_batch(impl, k_pad, km_pad, safe_recip(x), cols, vals,
                       docs_chunk)
    return BatchConvergedWMD(wmd=wmd, n_iter=n_iter, delta=delta)
