"""RWMD lower bounds: the O(nnz)-per-doc prefilter of the two-tier retriever.

Atasu et al. (*Linear-Complexity Relaxed Word Mover's Distance*) relax one
marginal constraint of the WMD transport problem: with the constraint on one
side dropped, every unit of mass moves to its cheapest admissible partner,
so the relaxed optimum is a per-word min over the cost matrix -- O(V) per
doc instead of a Sinkhorn solve -- and a *lower bound* on the true WMD.
Werner & Laber show such bounds prune exact top-k retrieval without changing
the answer: score every doc with the cheap bound, solve exactly only those
whose bound does not already exceed the running k-th exact distance.

Which side may be relaxed is NOT a free choice here
---------------------------------------------------
The bound must hold against what the engine actually *returns*, and the
engine (`core.sparse_sinkhorn`) runs a **fixed iteration budget**: its
output is ``sum_{i,s} P_is M[i, c_s]`` for the plan
``P_is = u_i K[i, c_s] v_s`` of the final iterate. At a finite iterate the
two marginals are not equally trustworthy:

  * **doc side (exact at every iterate)**: ``v`` is computed *from the
    current* ``u`` (``v_s = val_s / (K^T u)_s``), so
    ``sum_i P_is = v_s (K^T u)_s = val_s`` holds by construction -- at
    iteration 1 as much as at convergence (up to fp rounding; `safe_recip`'s
    TINY clamp only fires on exp-underflow-saturated columns).
  * **query side (exact only at the fixed point)**: ``u`` is one iteration
    *stale* relative to ``v``, so ``sum_s P_is = r_i x'_i / x_i`` where
    ``x'`` is the *next* iterate -- off by the convergence ratio. Measured on
    the bench corpus at 15 iterations the classic query-side bound
    ``sum_i r_i min_s M`` overshoots the returned distance by up to ~9%
    (and by >2x once exp underflow truncates ``K.*M``): it bounds the
    *converged* distance, not the engine's output.

Hence the pruning bound used here is the **doc-side RWMD**:

    rwmd(q, d) = sum_s vals[d, s] * min_i M[sel_q[i], cols[d, s]]

i.e. per target-doc word, the cost of its cheapest query word, weighted by
the doc's frequencies -- one sparse-aware *min-SDDMM* over the same ELL
structure and M rows the engine already works with. It satisfies
``rwmd(q, d) <= sinkhorn_wmd(q, d)`` for every iteration budget, every impl
and every tol (each addend of the returned distance is ``P_is M_is >=
P_is min_i M_is``, and the doc-side mass identity closes the sum), with
only dot-product-rounding slack -- which the service's ``prune_margin``
(default 1e-3, ~100x the observed fp slop, ~1/40 of the observed bound
gap) absorbs. The classic query-side bound is kept as
`rwmd_query_side_bound` for converged-regime use and for the property tests
that document this asymmetry.

Batched computation mirrors the K-cache's word-id dedup
(`core.kcache.stripes_for_batch`): unique word ids across the whole Q-batch
are deduped host-side, M rows are computed once per unique id in fixed
``rows_bucket`` chunks (bit-reproducible across batch compositions, same
argument as the K cache), and per-query (v_r, V+1) M stripes are assembled
by one slot-gather -- pad *query rows* gather a reserved +inf row (they must
never win the min; contrast the K stripes, where pad rows are zeroed), pad
*ELL slots* are masked out by ``vals == 0``. The min-SDDMM itself has the
usual three spellings: the fused jnp path below, the Pallas kernel
(`kernels.rwmd`, dispatched via ``impl="kernel"``), and the naive dense
oracle (`kernels.ref.rwmd_bound_batch`).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.sinkhorn import m_rows
from repro.core.sparse_sinkhorn import _chunk_over_docs, gather_k_batch

_BOUND_IMPLS = ("fused", "kernel")


@jax.jit
def _m_row_block(ids: jax.Array, vecs: jax.Array, b2: jax.Array) -> jax.Array:
    """(m,) word ids -> (m, V+1) cost-matrix rows with a zero pad column.

    Delegates to `core.sinkhorn.m_rows` -- the ONE spelling of the M-row
    expression, shared with the K/K.*M precompute -- so the bound sees
    bit-for-bit the geometry the engine's K.*M encodes (the soundness
    argument needs no cross-file convention). Fixed-shape blocks (the
    caller pads to ``rows_bucket``) make row bits independent of which
    other ids happened to be in the batch -- the K cache's
    bit-reproducibility argument.
    """
    return jnp.pad(m_rows(ids, vecs, b2=b2), ((0, 0), (0, 1)))


@jax.jit
def _gather_m_stripes(table: jax.Array, pos: jax.Array) -> jax.Array:
    """(U+1, V+1) row table, (Q, v_r) positions -> (Q, v_r, V+1) stripes."""
    return table[pos]


def assemble_m_stripes(sel_b: np.ndarray, row_mask: np.ndarray, vecs,
                       *, b2=None, rows_bucket: int = 128) -> jax.Array:
    """Dedup a (Q, v_r) word-id batch and assemble its M stripes.

    Mirrors the K-cache's transient path: unique ids once, rows in fixed
    ``rows_bucket`` chunks, one slot-gather. Pad query rows (row_mask == 0)
    gather a reserved +inf row: for the doc-side min-reduction a pad row
    must never be the cheapest query word (an all-pad filler query yields
    +inf/NaN bounds, finited to 0 by the bound fns -- its rows are sliced
    off by the caller anyway). Returns a device (Q, v_r, V+1) array.
    """
    vecs = vecs if isinstance(vecs, jax.Array) else jnp.asarray(vecs)
    if b2 is None:
        b2 = jnp.sum(vecs * vecs, axis=-1)
    sel_b = np.asarray(sel_b)
    ids = np.unique(sel_b)                          # sorted: stable dedup
    blocks = []
    for lo in range(0, len(ids), rows_bucket):
        chunk = ids[lo:lo + rows_bucket]
        ids_p = np.zeros(rows_bucket, np.int32)     # pad ids point at word 0
        ids_p[:len(chunk)] = chunk
        blocks.append(_m_row_block(jnp.asarray(ids_p), vecs, b2))
    v = vecs.shape[0]
    inf_row = jnp.full((1, v + 1), jnp.inf, jnp.float32)
    table = jnp.concatenate(blocks + [inf_row], axis=0)
    inf_pos = table.shape[0] - 1
    # every block is exactly rows_bucket rows with ids packed front-to-back
    # across blocks, so an id's sorted position IS its table row (only the
    # last block carries pad rows, past every real position)
    pos = np.searchsorted(ids, sel_b)
    pos_b = np.where(np.asarray(row_mask) > 0, pos, inf_pos).astype(np.int32)
    return _gather_m_stripes(table, jnp.asarray(pos_b))


def _bound_chunk_jnp(m_pad: jax.Array, cols_c: jax.Array,
                     vals_c: jax.Array) -> jax.Array:
    """One doc chunk of the fused min-SDDMM: (Q, docs) partial bounds."""
    mg = gather_k_batch(m_pad, cols_c)              # (Q, n_c, nnz, v_r)
    slot_min = jnp.min(mg, axis=-1)                 # min over query words
    slot_min = jnp.where(vals_c[None] != 0.0, slot_min, 0.0)  # pad slots out
    return jnp.einsum("qnk,nk->qn", slot_min, vals_c)


@functools.partial(jax.jit, static_argnames=("impl", "docs_chunk"))
def rwmd_bound_batch(m_pad: jax.Array, cols: jax.Array, vals: jax.Array,
                     impl: str = "fused",
                     docs_chunk: int | None = None) -> jax.Array:
    """Batched doc-side RWMD lower bounds. Returns (Q, N).

    Args:
      m_pad: (Q, v_r, V+1) per-query cost-matrix stripes (pad query rows
             +inf, pad column value irrelevant -- pad slots are masked by
             ``vals == 0``), e.g. from `assemble_m_stripes`.
      cols / vals: the corpus ELL (N, nnz_max), pad col == V, pad val == 0.
      impl: "fused" (jnp gather + masked min + einsum) | "kernel" (the
            Pallas min-SDDMM, `kernels.rwmd`).
      docs_chunk: cache-block the reduction over static N-chunks -- the
            gathered working set is (Q, docs_chunk, nnz, v_r), same
            rationale (and same `_chunk_over_docs` machinery, bitwise
            exactness included) as the solve engine's chunking.

    All-pad filler queries and empty docs produce exactly 0.0 (matching the
    engine's 0.0 distance for both), so a bound of 0 can never prune them.
    """
    if impl not in _BOUND_IMPLS:
        raise ValueError(f"impl must be one of {_BOUND_IMPLS}, got {impl!r}")
    if impl == "kernel":
        from repro.kernels import ops
        kw = {} if not docs_chunk else {"docs_blk": docs_chunk}
        return ops.rwmd_bound_batch(m_pad, cols, vals, **kw)
    q, n = m_pad.shape[0], cols.shape[0]
    u_dummy = jnp.zeros((q, 1, n), m_pad.dtype)     # doc-axis carrier only
    lb = _chunk_over_docs(
        lambda _, cols_c, vals_c: _bound_chunk_jnp(m_pad, cols_c, vals_c),
        u_dummy, cols, vals, docs_chunk, pad_col=m_pad.shape[-1] - 1)
    return jnp.where(jnp.isfinite(lb), lb, 0.0)     # filler queries -> 0


@functools.partial(jax.jit, static_argnames=("docs_chunk",))
def rwmd_query_side_bound(m_pad: jax.Array, r_sel: jax.Array,
                          cols: jax.Array, vals: jax.Array,
                          docs_chunk: int | None = None) -> jax.Array:
    """The classic query-side RWMD: sum_i r_i * min_{s in doc} M[i, c_s].

    A lower bound on the *converged* Sinkhorn-WMD only -- at a finite
    iteration budget the engine's query-side marginal is off by the
    convergence ratio and this bound can EXCEED the returned distance (see
    the module docstring), which is why the pruning path uses
    `rwmd_bound_batch` instead. Kept for converged-regime use (tol-driven
    solves run to convergence) and for the property suite that documents
    the asymmetry. Empty docs score 0 (the min over an empty support is
    replaced by 0, matching the engine). Returns (Q, N).
    """
    def chunk(_, cols_c, vals_c):
        mg = gather_k_batch(m_pad, cols_c)          # (Q, n_c, nnz, v_r)
        mg = jnp.where(vals_c[None, :, :, None] != 0.0, mg, jnp.inf)
        mins = jnp.min(mg, axis=2)                  # (Q, n_c, v_r) over slots
        mins = jnp.where(jnp.isfinite(mins), mins, 0.0)   # empty docs
        return jnp.einsum("qnv,qv->qn", mins, r_sel)

    q, n = m_pad.shape[0], cols.shape[0]
    u_dummy = jnp.zeros((q, 1, n), m_pad.dtype)
    lb = _chunk_over_docs(chunk, u_dummy, cols, vals, docs_chunk,
                          pad_col=m_pad.shape[-1] - 1)
    return jnp.where(jnp.isfinite(lb), lb, 0.0)


def rwmd_lower_bound(sel_b: np.ndarray, row_mask: np.ndarray,
                     cols: jax.Array, vals: jax.Array, vecs, *,
                     b2=None, rows_bucket: int = 128, impl: str = "fused",
                     docs_chunk: int | None = None) -> jax.Array:
    """Convenience composition: dedup + M stripes + batched bound.

    ``sel_b`` / ``row_mask`` are the (Q, v_r) padded-query arrays of
    `core.distributed.pad_query_batch`; returns (Q, N) device bounds.
    """
    m_pad = assemble_m_stripes(sel_b, row_mask, vecs, b2=b2,
                               rows_bucket=rows_bucket)
    return rwmd_bound_batch(m_pad, cols, vals, impl=impl,
                            docs_chunk=docs_chunk)
