"""Pallas TPU kernels for the Sinkhorn-WMD hot spots.

  sddmm_spmm -- the paper's contribution: fused sampled-dense-dense +
                sparse-dense matmul (type1: iteration, type2: final distance)
  cdist      -- euclidean transportation-cost matrix (MXU matmul expansion)
  kexp       -- beyond-paper fused cdist -> (K, K.*M) precompute

`ops` holds the jit'd public wrappers (padding + CPU-interpret dispatch);
`ref` holds the deliberately naive jnp oracles used by the kernel tests.
"""
from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
