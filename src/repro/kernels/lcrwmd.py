"""Pallas TPU kernel for the LC-RWMD dense-gather + SpMV tier.

Tier 1 of the retrieval cascade (`core.cascade`): the per-vocab-word
min-cost vector ``minm[q, c] = min_i M[q, i, c]`` is gathered once per
query *outside* the kernel, so scoring a doc is a single sparse dot over
its ELL slots -- the min-SDDMM of `kernels.rwmd` with the min hoisted out
of the doc loop:

  grid = (Q/q_blk, N/docs_blk)          # minm stripe resident per Q stripe
  for j in docs_blk:                    # docs of this tile
    for s in nnz_max:                   # slots of doc j
      mc   = minm[:, cols[j,s]]         # (q_blk,) -- ONE gather, no min
      acc += where(vals[j,s] != 0, vals[j,s] * mc, 0)
  lb[:, tile_j] = acc

Pad conventions (enforced by the `ops.lc_rwmd_bound_batch` wrapper):
  * all-pad filler queries carry an all-+inf minm row (the
    `core.rwmd.assemble_m_stripes` +inf pad-row convention survives the
    min), producing +inf partials the wrapper finites to 0;
  * pad *ELL slots* (val == 0) are excluded by the val mask, so the minm
    pad column's value is irrelevant;
  * pad docs gather the pad column with val 0 and are sliced off.

VMEM working set per grid step is the min-SDDMM's divided by v_r: the
(q_blk, Vloc+1) minm stripe dominates; cols/vals tiles add
2 * docs_blk * nnz_max * 4B; the output tile is (q_blk, docs_blk).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _lc_kernel(minm_ref, cols_ref, vals_ref, lb_ref):
    """One (doc tile, Q stripe): per-slot gather feeds all q_blk dots."""
    q_blk = minm_ref.shape[0]
    docs_blk, nnz_max = cols_ref.shape
    dtype = lb_ref.dtype

    def doc_body(j, _):
        def slot_body(s, acc):
            col = cols_ref[j, s]
            mc = minm_ref[:, col]                    # (q_blk,) ONE gather
            val = vals_ref[j, s]
            return acc + jnp.where(val != 0.0, val * mc, 0.0)

        acc = jax.lax.fori_loop(
            0, nnz_max, slot_body, jnp.zeros((q_blk,), dtype))
        lb_ref[:, 0, j] = acc
        return 0

    jax.lax.fori_loop(0, docs_blk, doc_body, 0)


@functools.partial(jax.jit,
                   static_argnames=("docs_blk", "q_blk", "interpret"))
def lc_rwmd_bound_batch(minm: jax.Array, cols: jax.Array, vals: jax.Array, *,
                        docs_blk: int = 8, q_blk: int = 8,
                        interpret: bool = False) -> jax.Array:
    """Batched LC sparse dot. Shapes: minm (Q, Vloc+1), cols/vals
    (N, nnz_max) with N % docs_blk == 0 and Q % q_blk == 0. Returns (Q, N)
    raw partial bounds (callers finite-ize filler-query rows)."""
    q = minm.shape[0]
    n, nnz_max = cols.shape
    grid = (q // q_blk, n // docs_blk)       # minm stripes stay VMEM-resident
    out = pl.pallas_call(
        _lc_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((q_blk, minm.shape[1]), lambda qi, i: (qi, 0)),
            pl.BlockSpec((docs_blk, nnz_max), lambda qi, i: (i, 0)),
            pl.BlockSpec((docs_blk, nnz_max), lambda qi, i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((q_blk, 1, docs_blk),
                               lambda qi, i: (qi, 0, i)),
        out_shape=jax.ShapeDtypeStruct((q, 1, n), vals.dtype),
        interpret=interpret,
    )(minm, cols, vals)
    return out[:, 0]
