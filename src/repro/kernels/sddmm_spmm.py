"""Pallas TPU kernels for the paper's fused SDDMM-SpMM (type1 and type2).

TPU mapping of the paper's fusion (DESIGN.md section 2): the kernel gathers
each sampled K column from VMEM **once** and feeds it to both the SDDMM dot
product and the SpMM accumulation, so the column's HBM->VMEM traffic is paid
once instead of twice. The on-the-fly transpose of the paper becomes the
BlockSpec layout: K is held column-major-gatherable (v_r contiguous), u and x
live as (v_r, docs) tiles.

VMEM contract: the kernel holds the *local vocab slice* of K (v_r x (Vloc+1))
resident in VMEM across all grid steps (constant index_map). This is exactly
the shape produced by the vocab-sharded distributed engine
(`core.distributed`), where Vloc = V / model_parallelism <= ~8k. For
single-chip V=100k, `ops.sddmm_spmm_chunked` replays the same decomposition
over host-side vocab chunks -- the kernel and the multi-chip algorithm share
one structure.

Grid: one step per tile of ``docs_blk`` documents. Each step:
  for j in docs_blk:                (lax.fori_loop)
    for s in nnz_max:               (lax.fori_loop)
      kcol = K[:, cols[j,s]]        <- single VMEM gather (dynamic slice)
      w    = <kcol, u[:,j]>         SDDMM half
      v    = vals[j,s] / w
      acc += kcol * v               SpMM half (same kcol, in-register)
  x[:, tile_j] = acc / r            (type1)   or
  wmd[tile_j]  = <u[:,j], acc_km>   (type2, acc over K.*M columns)

A production Mosaic build would stage the cols tile through scalar prefetch
(PrefetchScalarGridSpec) and issue the K-column loads as async copies; the
dynamic-slice form below expresses the same dataflow and validates bit-for-bit
in interpret mode (this container is CPU-only).

Batched kernel & cache blocking
-------------------------------
`_type1_batch_kernel` / `_type2_batch_kernel` extend the fusion along the Q
(concurrent-query) axis. The ELL structure (cols, vals) is a property of the
corpus, identical for every query, so the irregular work of a slot -- locating
and loading the K column at ``cols[j, s]`` -- is done ONCE per (doc tile,
Q-stripe) and the loaded ``(q_blk, v_r)`` column stripe feeds all q_blk
queries' SDDMM dots *and* SpMM accumulations:

  grid = (Q/q_blk, N/docs_blk)        # Q stripe outer: the multi-MB K
                                      # stripe block is revisited, not
                                      # re-fetched, across inner doc tiles
  for j in docs_blk:                  # docs of this tile
    for s in nnz_max:                 # slots of doc j
      kcols = K[:, :, cols[j,s]]      # (q_blk, v_r) -- ONE gather, all queries
      w[q]  = <kcols[q], u[q,:,j]>    # q_blk SDDMM dots
      acc  += kcols * (vals[j,s]/w)[:, None]   # q_blk SpMM accumulations

VMEM working set per grid step (f32): the K stripe dominates at
``q_blk * v_r * (Vloc+1) * 4B`` -- e.g. q_blk=8, v_r=32, Vloc=8192 is 8 MB,
which is why Q is striped instead of resident wholesale; u/x tiles add
``2 * q_blk * v_r * docs_blk * 4B`` (KBs) and cols/vals
``2 * docs_blk * nnz_max * 4B``. Shrink ``q_blk`` (more grid steps, same
total traffic) when v_r * Vloc grows; shrink ``docs_blk`` only to bound the
x tile. The jnp mirror of the same idea is `core.sparse_sinkhorn`'s
``docs_chunk`` scan (its "Batched engine & cache blocking" section).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TINY = 1e-30  # see core.sparse_sinkhorn.safe_recip


def _type1_kernel(k_ref, r_ref, u_ref, cols_ref, vals_ref, x_ref):
    """One doc tile: x[:, tile] = diag(1/r) . SpMM(K, SDDMM(K, u, c))."""
    v_r = u_ref.shape[0]
    docs_blk, nnz_max = cols_ref.shape
    dtype = x_ref.dtype

    def doc_body(j, _):
        u_j = u_ref[:, j]                                    # (v_r,)

        def slot_body(s, acc):
            col = cols_ref[j, s]
            kcol = k_ref[:, col]                             # gather ONCE
            w = jnp.sum(kcol * u_j)                          # SDDMM dot
            val = vals_ref[j, s]
            v = jnp.where(val != 0.0,
                          val / jnp.maximum(w, TINY), 0.0)
            return acc + kcol * v                            # SpMM, in-register

        acc = jax.lax.fori_loop(
            0, nnz_max, slot_body, jnp.zeros((v_r,), dtype))
        x_ref[:, j] = acc / r_ref[:, 0]
        return 0

    jax.lax.fori_loop(0, docs_blk, doc_body, 0)


def _type2_kernel(k_ref, km_ref, u_ref, cols_ref, vals_ref, wmd_ref):
    """One doc tile: wmd[tile] = sum_i u .* SpMM(K.*M, SDDMM(K, u, c))."""
    v_r = u_ref.shape[0]
    docs_blk, nnz_max = cols_ref.shape
    dtype = wmd_ref.dtype

    def doc_body(j, _):
        u_j = u_ref[:, j]

        def slot_body(s, acc):
            col = cols_ref[j, s]
            kcol = k_ref[:, col]                             # shared gather
            kmcol = km_ref[:, col]
            w = jnp.sum(kcol * u_j)
            val = vals_ref[j, s]
            v = jnp.where(val != 0.0,
                          val / jnp.maximum(w, TINY), 0.0)
            return acc + kmcol * v

        acc = jax.lax.fori_loop(
            0, nnz_max, slot_body, jnp.zeros((v_r,), dtype))
        wmd_ref[0, j] = jnp.sum(u_j * acc)
        return 0

    jax.lax.fori_loop(0, docs_blk, doc_body, 0)


@functools.partial(jax.jit,
                   static_argnames=("docs_blk", "interpret"))
def sddmm_spmm_type1(k_pad: jax.Array, r_sel: jax.Array, u: jax.Array,
                     cols: jax.Array, vals: jax.Array, *,
                     docs_blk: int = 8, interpret: bool = False) -> jax.Array:
    """Fused iteration body. Shapes: k_pad (v_r, Vloc+1), r_sel (v_r,),
    u (v_r, N), cols/vals (N, nnz_max) with N % docs_blk == 0. Returns x
    (v_r, N)."""
    v_r, n = u.shape
    _, nnz_max = cols.shape
    grid = (n // docs_blk,)
    return pl.pallas_call(
        _type1_kernel,
        grid=grid,
        in_specs=[
            # K slice resident in VMEM across the whole grid (constant index).
            pl.BlockSpec(k_pad.shape, lambda i: (0, 0)),
            pl.BlockSpec((v_r, 1), lambda i: (0, 0)),
            pl.BlockSpec((v_r, docs_blk), lambda i: (0, i)),
            pl.BlockSpec((docs_blk, nnz_max), lambda i: (i, 0)),
            pl.BlockSpec((docs_blk, nnz_max), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((v_r, docs_blk), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((v_r, n), u.dtype),
        interpret=interpret,
    )(k_pad, r_sel[:, None], u, cols, vals)


@functools.partial(jax.jit,
                   static_argnames=("docs_blk", "interpret"))
def sddmm_spmm_type2(k_pad: jax.Array, km_pad: jax.Array, u: jax.Array,
                     cols: jax.Array, vals: jax.Array, *,
                     docs_blk: int = 8, interpret: bool = False) -> jax.Array:
    """Fused final distance (3 dense + 2 sparse inputs). Returns wmd (N,)."""
    v_r, n = u.shape
    _, nnz_max = cols.shape
    grid = (n // docs_blk,)
    out = pl.pallas_call(
        _type2_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(k_pad.shape, lambda i: (0, 0)),
            pl.BlockSpec(km_pad.shape, lambda i: (0, 0)),
            pl.BlockSpec((v_r, docs_blk), lambda i: (0, i)),
            pl.BlockSpec((docs_blk, nnz_max), lambda i: (i, 0)),
            pl.BlockSpec((docs_blk, nnz_max), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, docs_blk), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, n), u.dtype),
        interpret=interpret,
    )(k_pad, km_pad, u, cols, vals)
    return out[0]


# ---------------------------------------------------------------------------
# Batched (multi-query) kernels -- see "Batched kernel & cache blocking" above
# ---------------------------------------------------------------------------

def _type1_batch_kernel(k_ref, r_ref, u_ref, cols_ref, vals_ref, x_ref):
    """One (doc tile, Q stripe): the per-slot K-column gather serves all
    q_blk queries' SDDMM dots and SpMM accumulations."""
    q_blk, v_r = u_ref.shape[0], u_ref.shape[1]
    docs_blk, nnz_max = cols_ref.shape
    dtype = x_ref.dtype

    def doc_body(j, _):
        u_j = u_ref[:, :, j]                                 # (q_blk, v_r)

        def slot_body(s, acc):
            col = cols_ref[j, s]
            kcols = k_ref[:, :, col]                         # ONE gather
            w = jnp.sum(kcols * u_j, axis=1)                 # q_blk SDDMM dots
            val = vals_ref[j, s]
            v = jnp.where(val != 0.0,
                          val / jnp.maximum(w, TINY), 0.0)   # (q_blk,)
            return acc + kcols * v[:, None]                  # q_blk SpMM accs

        acc = jax.lax.fori_loop(
            0, nnz_max, slot_body, jnp.zeros((q_blk, v_r), dtype))
        x_ref[:, :, j] = acc / r_ref[:, :, 0]
        return 0

    jax.lax.fori_loop(0, docs_blk, doc_body, 0)


def _type2_batch_kernel(k_ref, km_ref, u_ref, cols_ref, vals_ref, wmd_ref):
    """Batched final distance: shared gather of the K and K.*M column
    stripes, per-query reduction in-register."""
    q_blk, v_r = u_ref.shape[0], u_ref.shape[1]
    docs_blk, nnz_max = cols_ref.shape
    dtype = wmd_ref.dtype

    def doc_body(j, _):
        u_j = u_ref[:, :, j]

        def slot_body(s, acc):
            col = cols_ref[j, s]
            kcols = k_ref[:, :, col]                         # shared gather
            kmcols = km_ref[:, :, col]
            w = jnp.sum(kcols * u_j, axis=1)
            val = vals_ref[j, s]
            v = jnp.where(val != 0.0,
                          val / jnp.maximum(w, TINY), 0.0)
            return acc + kmcols * v[:, None]

        acc = jax.lax.fori_loop(
            0, nnz_max, slot_body, jnp.zeros((q_blk, v_r), dtype))
        wmd_ref[:, 0, j] = jnp.sum(u_j * acc, axis=1)
        return 0

    jax.lax.fori_loop(0, docs_blk, doc_body, 0)


@functools.partial(jax.jit,
                   static_argnames=("docs_blk", "q_blk", "interpret"))
def sddmm_spmm_type1_batch(k_pad: jax.Array, r_sel: jax.Array, u: jax.Array,
                           cols: jax.Array, vals: jax.Array, *,
                           docs_blk: int = 8, q_blk: int = 8,
                           interpret: bool = False) -> jax.Array:
    """Batched fused iteration body. Shapes: k_pad (Q, v_r, Vloc+1),
    r_sel (Q, v_r), u (Q, v_r, N), cols/vals (N, nnz_max) with
    N % docs_blk == 0 and Q % q_blk == 0. Returns x (Q, v_r, N)."""
    q, v_r, n = u.shape
    _, nnz_max = cols.shape
    # Q stripe OUTER, doc tile inner: the multi-MB K stripe's block index is
    # constant across all inner doc steps (Pallas skips the re-fetch), so K
    # is copied into VMEM once per stripe while only the KB-scale cols/vals/u
    # tiles re-stream -- the dominant-operand-resident grid order.
    grid = (q // q_blk, n // docs_blk)
    return pl.pallas_call(
        _type1_batch_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((q_blk,) + k_pad.shape[1:], lambda qi, i: (qi, 0, 0)),
            pl.BlockSpec((q_blk, v_r, 1), lambda qi, i: (qi, 0, 0)),
            pl.BlockSpec((q_blk, v_r, docs_blk), lambda qi, i: (qi, 0, i)),
            pl.BlockSpec((docs_blk, nnz_max), lambda qi, i: (i, 0)),
            pl.BlockSpec((docs_blk, nnz_max), lambda qi, i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((q_blk, v_r, docs_blk),
                               lambda qi, i: (qi, 0, i)),
        out_shape=jax.ShapeDtypeStruct((q, v_r, n), u.dtype),
        interpret=interpret,
    )(k_pad, r_sel[:, :, None], u, cols, vals)


@functools.partial(jax.jit,
                   static_argnames=("docs_blk", "q_blk", "interpret"))
def sddmm_spmm_type2_batch(k_pad: jax.Array, km_pad: jax.Array, u: jax.Array,
                           cols: jax.Array, vals: jax.Array, *,
                           docs_blk: int = 8, q_blk: int = 8,
                           interpret: bool = False) -> jax.Array:
    """Batched fused final distance. Returns wmd (Q, N)."""
    q, v_r, n = u.shape
    _, nnz_max = cols.shape
    grid = (q // q_blk, n // docs_blk)       # K/K.*M stripes stay resident
    out = pl.pallas_call(
        _type2_batch_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((q_blk,) + k_pad.shape[1:], lambda qi, i: (qi, 0, 0)),
            pl.BlockSpec((q_blk,) + km_pad.shape[1:],
                         lambda qi, i: (qi, 0, 0)),
            pl.BlockSpec((q_blk, v_r, docs_blk), lambda qi, i: (qi, 0, i)),
            pl.BlockSpec((docs_blk, nnz_max), lambda qi, i: (i, 0)),
            pl.BlockSpec((docs_blk, nnz_max), lambda qi, i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((q_blk, 1, docs_blk),
                               lambda qi, i: (qi, 0, i)),
        out_shape=jax.ShapeDtypeStruct((q, 1, n), u.dtype),
        interpret=interpret,
    )(k_pad, km_pad, u, cols, vals)
    return out[:, 0]
