"""Public jit'd entry points for the Pallas kernels.

Responsibilities:
  * backend dispatch -- ``interpret=True`` everywhere except real TPU, so the
    same call sites validate on CPU (this container) and run Mosaic on TPU;
  * alignment padding -- v_r to the f32 sublane multiple (8), docs to the
    doc-tile, so callers never think about hardware shapes;
  * the vocab-chunked driver (`sddmm_spmm_chunked`) that replays the
    multi-chip vocab decomposition on one chip when K does not fit VMEM.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import cdist as _cdist_kernel
from repro.kernels import kexp as _kexp_kernel
from repro.kernels import lcrwmd as _lcrwmd_kernel
from repro.kernels import rwmd as _rwmd_kernel
from repro.kernels import sddmm_spmm as _sddmm_spmm
from repro.kernels._pad import pad_axis


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


_pad_to = pad_axis


def sddmm_spmm_type1(k_pad: jax.Array, r_sel: jax.Array, u: jax.Array,
                     cols: jax.Array, vals: jax.Array, *,
                     docs_blk: int = 8) -> jax.Array:
    """Fused Sinkhorn iteration body; see kernels.sddmm_spmm.

    Pads v_r to 8 (r pads with 1.0 to keep 1/r finite) and docs to docs_blk;
    un-pads the result. K's zero pad column must already be present.
    """
    v_r, n = u.shape
    k_p = _pad_to(k_pad, 0, 8)
    r_p = _pad_to(r_sel, 0, 8, value=1.0)
    u_p = _pad_to(_pad_to(u, 0, 8), 1, docs_blk)
    # padded docs gather the K pad column (id Vloc) with val 0 -> contribute 0
    cols_p = _pad_to(cols, 0, docs_blk, value=k_pad.shape[1] - 1)
    vals_p = _pad_to(vals, 0, docs_blk)
    x = _sddmm_spmm.sddmm_spmm_type1(
        k_p, r_p, u_p, cols_p, vals_p,
        docs_blk=docs_blk, interpret=_interpret())
    return x[:v_r, :n]


def sddmm_spmm_type2(k_pad: jax.Array, km_pad: jax.Array, u: jax.Array,
                     cols: jax.Array, vals: jax.Array, *,
                     docs_blk: int = 8) -> jax.Array:
    """Fused final-distance kernel; returns (N,) WMD."""
    v_r, n = u.shape
    k_p = _pad_to(k_pad, 0, 8)
    km_p = _pad_to(km_pad, 0, 8)
    u_p = _pad_to(_pad_to(u, 0, 8), 1, docs_blk)
    cols_p = _pad_to(cols, 0, docs_blk, value=k_pad.shape[1] - 1)
    vals_p = _pad_to(vals, 0, docs_blk)
    wmd = _sddmm_spmm.sddmm_spmm_type2(
        k_p, km_p, u_p, cols_p, vals_p,
        docs_blk=docs_blk, interpret=_interpret())
    return wmd[:n]


def sddmm_spmm_type1_batch(k_pad: jax.Array, r_sel: jax.Array, u: jax.Array,
                           cols: jax.Array, vals: jax.Array, *,
                           docs_blk: int = 8,
                           q_blk: int | None = None) -> jax.Array:
    """Batched (Q-stripe) fused iteration body; see kernels.sddmm_spmm.

    Pads v_r to 8 (r pads with 1.0), docs to docs_blk, and Q to q_blk
    (default min(Q, 8)); un-pads the result. Q-pad stripes carry an all-zero
    K (so w = 0 and the masked v multiplies a zero column -> exact zeros,
    sliced off). K's zero pad column must already be present.
    """
    q, v_r, n = u.shape
    if q_blk is None:
        q_blk = min(q, 8)
    k_p = _pad_to(_pad_to(k_pad, 1, 8), 0, q_blk)
    r_p = _pad_to(_pad_to(r_sel, 1, 8, value=1.0), 0, q_blk, value=1.0)
    u_p = _pad_to(_pad_to(_pad_to(u, 1, 8), 2, docs_blk), 0, q_blk)
    cols_p = _pad_to(cols, 0, docs_blk, value=k_pad.shape[-1] - 1)
    vals_p = _pad_to(vals, 0, docs_blk)
    x = _sddmm_spmm.sddmm_spmm_type1_batch(
        k_p, r_p, u_p, cols_p, vals_p,
        docs_blk=docs_blk, q_blk=q_blk, interpret=_interpret())
    return x[:q, :v_r, :n]


def sddmm_spmm_type2_batch(k_pad: jax.Array, km_pad: jax.Array, u: jax.Array,
                           cols: jax.Array, vals: jax.Array, *,
                           docs_blk: int = 8,
                           q_blk: int | None = None) -> jax.Array:
    """Batched fused final-distance kernel; returns (Q, N) WMD."""
    q, v_r, n = u.shape
    if q_blk is None:
        q_blk = min(q, 8)
    k_p = _pad_to(_pad_to(k_pad, 1, 8), 0, q_blk)
    km_p = _pad_to(_pad_to(km_pad, 1, 8), 0, q_blk)
    u_p = _pad_to(_pad_to(_pad_to(u, 1, 8), 2, docs_blk), 0, q_blk)
    cols_p = _pad_to(cols, 0, docs_blk, value=k_pad.shape[-1] - 1)
    vals_p = _pad_to(vals, 0, docs_blk)
    wmd = _sddmm_spmm.sddmm_spmm_type2_batch(
        k_p, km_p, u_p, cols_p, vals_p,
        docs_blk=docs_blk, q_blk=q_blk, interpret=_interpret())
    return wmd[:q, :n]


def rwmd_bound_batch(m_pad: jax.Array, cols: jax.Array, vals: jax.Array, *,
                     docs_blk: int = 8,
                     q_blk: int | None = None) -> jax.Array:
    """Batched doc-side RWMD min-SDDMM; see kernels.rwmd. Returns (Q, N).

    Pads v_r to 8 and Q to q_blk with **+inf** (a pad query row must never
    win the min -- the opposite of the K stripes' zero pad rows), docs to
    docs_blk with ELL pad slots (val 0 -> masked out); un-pads the result
    and finites all-pad filler-query rows to 0 (the engine's distance for
    them is exactly 0, so a 0 bound can never prune them).
    """
    q, v_r, _ = m_pad.shape
    n = cols.shape[0]
    if q_blk is None:
        q_blk = min(q, 8)
    inf = float("inf")
    m_p = _pad_to(_pad_to(m_pad, 1, 8, value=inf), 0, q_blk, value=inf)
    cols_p = _pad_to(cols, 0, docs_blk, value=m_pad.shape[-1] - 1)
    vals_p = _pad_to(vals, 0, docs_blk)
    lb = _rwmd_kernel.rwmd_bound_batch(
        m_p, cols_p, vals_p,
        docs_blk=docs_blk, q_blk=q_blk, interpret=_interpret())
    lb = lb[:q, :n]
    return jnp.where(jnp.isfinite(lb), lb, 0.0)


def lc_rwmd_bound_batch(minm: jax.Array, cols: jax.Array, vals: jax.Array, *,
                        docs_blk: int = 8,
                        q_blk: int | None = None) -> jax.Array:
    """Batched LC-RWMD sparse dot; see kernels.lcrwmd. Returns (Q, N).

    Pads Q to q_blk with **+inf** minm rows (matching the all-+inf rows
    real filler queries carry), docs to docs_blk with ELL pad slots (val 0
    -> masked out); un-pads the result and finites all-pad filler-query
    rows to 0 (the engine's distance for them is exactly 0, so a 0 bound
    can never prune them).
    """
    q = minm.shape[0]
    n = cols.shape[0]
    if q_blk is None:
        q_blk = min(q, 8)
    minm_p = _pad_to(minm, 0, q_blk, value=float("inf"))
    cols_p = _pad_to(cols, 0, docs_blk, value=minm.shape[-1] - 1)
    vals_p = _pad_to(vals, 0, docs_blk)
    lb = _lcrwmd_kernel.lc_rwmd_bound_batch(
        minm_p, cols_p, vals_p,
        docs_blk=docs_blk, q_blk=q_blk, interpret=_interpret())
    lb = lb[:q, :n]
    return jnp.where(jnp.isfinite(lb), lb, 0.0)


def sddmm_spmm_chunked(k_chunks: jax.Array, r_sel: jax.Array, u: jax.Array,
                       cols_chunks: jax.Array, vals_chunks: jax.Array, *,
                       docs_blk: int = 8) -> jax.Array:
    """Single-chip driver for K too large for VMEM: vocab-chunked type1.

    Args mirror the multi-chip layout (`core.formats.rebucket_for_vocab_shards`):
      k_chunks:    (S, v_r, Vc+1) -- per-chunk K slice with zero pad column.
      cols_chunks: (S, N, nnz_c)  -- localized ids per chunk.
      vals_chunks: (S, N, nnz_c)
    Partial x contributions are summed across chunks (the psum of the
    distributed engine becomes an on-chip accumulation).
    """
    def chunk(carry, operand):
        k_c, cols_c, vals_c = operand
        x_c = sddmm_spmm_type1(k_c, jnp.ones_like(r_sel), u, cols_c, vals_c,
                               docs_blk=docs_blk)
        return carry + x_c, None

    v_r, n = u.shape
    x0 = jnp.zeros((v_r, n), u.dtype)
    x, _ = jax.lax.scan(chunk, x0, (k_chunks, cols_chunks, vals_chunks))
    return x / r_sel[:, None]


def cdist(a: jax.Array, b: jax.Array, *, v_tile: int = 512,
          squared: bool = False) -> jax.Array:
    """Tiled euclidean distance. Pads v_r to 8 and w to 128 lanes (the kernel
    itself pads V to v_tile and slices back)."""
    v_r = a.shape[0]
    a_p = _pad_to(_pad_to(a, 1, 128), 0, 8)
    b_p = _pad_to(b, 1, 128)
    out = _cdist_kernel.cdist(a_p, b_p, v_tile=v_tile, squared=squared,
                              interpret=_interpret())
    return out[:v_r]


def cdist_kexp(a: jax.Array, b: jax.Array, *, lamb: float,
               v_tile: int = 512) -> tuple[jax.Array, jax.Array]:
    """Fused precompute -> (K, K.*M), un-padded to (v_r, V)."""
    v_r = a.shape[0]
    a_p = _pad_to(_pad_to(a, 1, 128), 0, 8)
    b_p = _pad_to(b, 1, 128)
    k, km = _kexp_kernel.cdist_kexp(a_p, b_p, lamb=lamb, v_tile=v_tile,
                                    interpret=_interpret())
    return k[:v_r], km[:v_r]


def cdist_kexp_rows(a: jax.Array, b: jax.Array, *, lamb: float,
                    rows_blk: int = 8, v_tile: int = 512
                    ) -> tuple[jax.Array, jax.Array]:
    """Row-subset fused precompute (the cache-miss path of `core.kcache`):
    a (m, w) miss-row embeddings, b (V, w) -> (K, K.*M), each (m, V).

    Unlike `cdist_kexp` the row operand is not VMEM-resident -- the kernel
    grids over (row tiles x vocab tiles), so m is unbounded. Pads w to 128
    lanes here; the kernel pads rows to rows_blk and V to v_tile.
    """
    m = a.shape[0]
    a_p = _pad_to(a, 1, 128)
    b_p = _pad_to(b, 1, 128)
    k, km = _kexp_kernel.cdist_kexp_rows(a_p, b_p, lamb=lamb,
                                         rows_blk=rows_blk, v_tile=v_tile,
                                         interpret=_interpret())
    return k[:m], km[:m]
