"""Pure-jnp oracles for every Pallas kernel -- deliberately *naive*.

These are written in the most transparent form possible (dense materialization
+ masking; no gather tricks, no fusion) so they are independent of both the
Pallas kernels and the production jnp path in `core.sparse_sinkhorn`. Kernel
tests assert a three-way agreement: pallas == core-jnp == this oracle.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

TINY = 1e-30


def _ell_to_dense(cols: jax.Array, vals: jax.Array, num_vocab: int):
    """(N, nnz) ELL -> (V, N) dense, dropping pad slots (col == V)."""
    n, nnz = cols.shape
    one_hot = jax.nn.one_hot(cols, num_vocab + 1, dtype=vals.dtype)
    dense = jnp.einsum("nkv,nk->vn", one_hot, vals)
    return dense[:num_vocab]                                  # (V, N)


def sddmm_spmm_type1(k_pad: jax.Array, r_sel: jax.Array, u: jax.Array,
                     cols: jax.Array, vals: jax.Array) -> jax.Array:
    """Oracle: dense w = K^T u; v = c/w (on support); x = (K/r) v."""
    v = _sampled_inverse_product(k_pad, u, cols, vals)        # (V, N) dense
    k = k_pad[:, :-1]
    return (k @ v) / r_sel[:, None]


def sddmm_spmm_type2(k_pad: jax.Array, km_pad: jax.Array, u: jax.Array,
                     cols: jax.Array, vals: jax.Array) -> jax.Array:
    v = _sampled_inverse_product(k_pad, u, cols, vals)
    km = km_pad[:, :-1]
    return jnp.sum(u * (km @ v), axis=0)


def _sampled_inverse_product(k_pad, u, cols, vals):
    """Dense SDDMM: full K^T @ u then mask to the sparsity pattern of c."""
    num_vocab = k_pad.shape[1] - 1
    c = _ell_to_dense(cols, vals, num_vocab)                  # (V, N)
    w = k_pad[:, :-1].T @ u                                   # (V, N), dense
    return jnp.where(c != 0.0, c / jnp.maximum(w, TINY), 0.0)


def sddmm_spmm_type1_batch(k_pad: jax.Array, r_sel: jax.Array, u: jax.Array,
                           cols: jax.Array, vals: jax.Array) -> jax.Array:
    """Batched oracle: the single-query oracle vmapped over the Q axis --
    deliberately blind to the shared-gather structure of the real paths."""
    return jax.vmap(
        lambda k, r, uu: sddmm_spmm_type1(k, r, uu, cols, vals)
    )(k_pad, r_sel, u)


def sddmm_spmm_type2_batch(k_pad: jax.Array, km_pad: jax.Array, u: jax.Array,
                           cols: jax.Array, vals: jax.Array) -> jax.Array:
    return jax.vmap(
        lambda k, km, uu: sddmm_spmm_type2(k, km, uu, cols, vals)
    )(k_pad, km_pad, u)


def rwmd_bound_batch(m_pad: jax.Array, cols: jax.Array,
                     vals: jax.Array) -> jax.Array:
    """Oracle for the doc-side RWMD min-SDDMM (core.rwmd / kernels.rwmd):
    densify the ELL, take the per-vocab-word min over query rows of the full
    M stripe, and contract with the dense doc frequencies -- no gather, no
    slot loop. Pad query rows carry +inf in m_pad (never win the min);
    all-pad filler queries produce inf/NaN rows finited to 0 here exactly
    like the production paths."""
    num_vocab = m_pad.shape[-1] - 1
    c = _ell_to_dense(cols, vals, num_vocab)                  # (V, N)
    mins = jnp.min(m_pad[:, :, :num_vocab], axis=1)           # (Q, V)
    lb = jnp.einsum("qv,vn->qn", mins, c)
    return jnp.where(jnp.isfinite(lb), lb, 0.0)


def lc_rwmd_bound_batch(minm: jax.Array, cols: jax.Array,
                        vals: jax.Array) -> jax.Array:
    """Oracle for the LC-RWMD sparse dot (core.cascade / kernels.lcrwmd):
    densify the ELL and contract the (Q, V) min-cost vectors against it as
    one dense matmul -- no gather, no slot loop. Filler queries carry
    all-+inf minm rows, finited to 0 here exactly like the production
    paths."""
    num_vocab = minm.shape[-1] - 1
    c = _ell_to_dense(cols, vals, num_vocab)                  # (V, N)
    lb = minm[:, :num_vocab] @ c
    return jnp.where(jnp.isfinite(lb), lb, 0.0)


def cdist(a: jax.Array, b: jax.Array, *, squared: bool = False) -> jax.Array:
    """Oracle: direct elementwise |a_i - b_j|."""
    d2 = jnp.sum((a[:, None, :] - b[None, :, :]) ** 2, axis=-1)
    return d2 if squared else jnp.sqrt(d2)


def cdist_kexp(a: jax.Array, b: jax.Array, *, lamb: float):
    m = cdist(a, b)
    k = jnp.exp(-lamb * m)
    return k, k * m
