"""Pallas TPU kernel for the euclidean transportation-cost matrix (paper
hotspot: ``M = cdist(vecs[sel], vecs)``, Table I / Fig. 7).

MXU form (DESIGN.md section 2): |a-b|^2 = |a|^2 + |b|^2 - 2 a.b routes the
O(v_r * V * w) work through the systolic array. The grid tiles the vocab axis;
the (small) query-side matrix ``a`` stays VMEM-resident across all steps.

Tile sizing: v_tile defaults to 512 so a (512, 300) f32 embedding tile plus
the (v_r, 512) output tile stay well under VMEM; both 512 and the padded
embedding width are 128-lane aligned for MXU occupancy.

The vocab axis is padded up to ``v_tile`` inside the kernel wrapper and the
result sliced back, so arbitrary V works (zero-padded embedding rows yield
throwaway columns that never escape).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels._pad import pad_axis


def _cdist_kernel(a_ref, b_ref, out_ref, *, squared: bool):
    a = a_ref[...]                       # (v_r, w) resident
    b = b_ref[...]                       # (v_tile, w)
    a2 = jnp.sum(a * a, axis=-1)[:, None]
    b2 = jnp.sum(b * b, axis=-1)[None, :]
    # MXU: contract over the embedding width.
    ab = jax.lax.dot_general(a, b, (((1,), (1,)), ((), ())),
                             preferred_element_type=out_ref.dtype)
    d2 = jnp.maximum(a2 + b2 - 2.0 * ab, 0.0)
    out_ref[...] = d2 if squared else jnp.sqrt(d2)


@functools.partial(jax.jit,
                   static_argnames=("v_tile", "squared", "interpret"))
def cdist(a: jax.Array, b: jax.Array, *, v_tile: int = 512,
          squared: bool = False, interpret: bool = False) -> jax.Array:
    """Pairwise distance a (v_r, w) vs b (V, w) -> (v_r, V).

    V is padded to a multiple of ``v_tile`` and the result sliced back, so
    arbitrary vocab sizes work.
    """
    v_r, w = a.shape
    v = b.shape[0]
    b_p = pad_axis(b, 0, v_tile)
    v_p = b_p.shape[0]
    grid = (v_p // v_tile,)
    out = pl.pallas_call(
        functools.partial(_cdist_kernel, squared=squared),
        grid=grid,
        in_specs=[
            pl.BlockSpec((v_r, w), lambda i: (0, 0)),
            pl.BlockSpec((v_tile, w), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((v_r, v_tile), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((v_r, v_p), a.dtype),
        interpret=interpret,
    )(a, b_p)
    return out[:, :v]
