"""Fused cdist -> (K, K.*M) precompute kernel (beyond-paper fusion).

The paper precomputes M, K = exp(-lambda M), K_over_r and K.*M as separate
passes (Fig. 4 ``precompute_matrices``). Each pass round-trips a (v_r, V)
matrix through memory. This kernel fuses the whole precompute: each vocab
tile's distance block is produced in VMEM (MXU matmul expansion, as in
`kernels.cdist`), exponentiated and scaled in-register, and only the two
matrices the solver actually reads (K and K.*M) are written to HBM. M itself
never exists in memory -- a pure TPU-side win the CPU paper could not take
because its K/KM layouts are row-scaled on the fly instead.

Saves, per precompute: one (v_r, V) store + one load of M, and one full
elementwise pass -- at dbpedia scale (32 x 100k f32) ~25 MB of traffic per
query, i.e. the precompute memory term drops by ~1/3 (EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kexp_kernel(a_ref, b_ref, k_ref, km_ref, *, lamb: float):
    a = a_ref[...]
    b = b_ref[...]
    a2 = jnp.sum(a * a, axis=-1)[:, None]
    b2 = jnp.sum(b * b, axis=-1)[None, :]
    ab = jax.lax.dot_general(a, b, (((1,), (1,)), ((), ())),
                             preferred_element_type=k_ref.dtype)
    m = jnp.sqrt(jnp.maximum(a2 + b2 - 2.0 * ab, 0.0))  # never leaves VMEM
    k = jnp.exp(-lamb * m)
    k_ref[...] = k
    km_ref[...] = k * m


@functools.partial(jax.jit,
                   static_argnames=("lamb", "v_tile", "interpret"))
def cdist_kexp(a: jax.Array, b: jax.Array, *, lamb: float,
               v_tile: int = 512, interpret: bool = False
               ) -> tuple[jax.Array, jax.Array]:
    """Fused precompute: a (v_r, w), b (V, w) -> (K, K.*M), each (v_r, V)."""
    v_r, w = a.shape
    v, _ = b.shape
    grid = (v // v_tile,)
    return pl.pallas_call(
        functools.partial(_kexp_kernel, lamb=lamb),
        grid=grid,
        in_specs=[
            pl.BlockSpec((v_r, w), lambda i: (0, 0)),
            pl.BlockSpec((v_tile, w), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((v_r, v_tile), lambda i: (0, i)),
            pl.BlockSpec((v_r, v_tile), lambda i: (0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((v_r, v), a.dtype),
            jax.ShapeDtypeStruct((v_r, v), a.dtype),
        ],
        interpret=interpret,
    )(a, b)
