"""Fused cdist -> (K, K.*M) precompute kernels (beyond-paper fusion).

The paper precomputes M, K = exp(-lambda M), K_over_r and K.*M as separate
passes (Fig. 4 ``precompute_matrices``). Each pass round-trips a (v_r, V)
matrix through memory. These kernels fuse the whole precompute: each vocab
tile's distance block is produced in VMEM (MXU matmul expansion, as in
`kernels.cdist`), exponentiated and scaled in-register, and only the two
matrices the solver actually reads (K and K.*M) are written to HBM. M itself
never exists in memory -- a pure TPU-side win the CPU paper could not take
because its K/KM layouts are row-scaled on the fly instead.

Saves, per precompute: one (v_r, V) store + one load of M, and one full
elementwise pass -- at dbpedia scale (32 x 100k f32) ~25 MB of traffic per
query, i.e. the precompute memory term drops by ~1/3 (EXPERIMENTS.md §Perf).

Two entry points:

  * `cdist_kexp`      -- the per-query stripe: ``a`` (one query's v_r words)
                         stays VMEM-resident, grid tiles the vocab axis only.
  * `cdist_kexp_rows` -- the row-subset variant backing the cross-query
                         K cache (`core.kcache`): the row operand is an
                         arbitrary batch of *cache-miss* word embeddings, so
                         the grid tiles rows x vocab tiles -- row count is
                         unbounded (it is the batch's unique-miss count, not
                         a query's v_r) and each (rows_blk, v_tile) block is
                         produced independently.

Both pad the vocab axis up to ``v_tile`` internally and slice the result, so
arbitrary V works (zero-padded embedding rows produce garbage columns that
never leave the kernel wrapper).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels._pad import pad_axis


def _kexp_kernel(a_ref, b_ref, k_ref, km_ref, *, lamb: float):
    a = a_ref[...]
    b = b_ref[...]
    a2 = jnp.sum(a * a, axis=-1)[:, None]
    b2 = jnp.sum(b * b, axis=-1)[None, :]
    ab = jax.lax.dot_general(a, b, (((1,), (1,)), ((), ())),
                             preferred_element_type=k_ref.dtype)
    m = jnp.sqrt(jnp.maximum(a2 + b2 - 2.0 * ab, 0.0))  # never leaves VMEM
    k = jnp.exp(-lamb * m)
    k_ref[...] = k
    km_ref[...] = k * m


@functools.partial(jax.jit,
                   static_argnames=("lamb", "v_tile", "interpret"))
def cdist_kexp(a: jax.Array, b: jax.Array, *, lamb: float,
               v_tile: int = 512, interpret: bool = False
               ) -> tuple[jax.Array, jax.Array]:
    """Fused precompute: a (v_r, w), b (V, w) -> (K, K.*M), each (v_r, V).

    The vocab axis is padded to a multiple of ``v_tile`` and the result
    sliced back, so arbitrary V works.
    """
    v_r, w = a.shape
    v = b.shape[0]
    b_p = pad_axis(b, 0, v_tile)
    v_p = b_p.shape[0]
    grid = (v_p // v_tile,)
    k, km = pl.pallas_call(
        functools.partial(_kexp_kernel, lamb=lamb),
        grid=grid,
        in_specs=[
            pl.BlockSpec((v_r, w), lambda i: (0, 0)),
            pl.BlockSpec((v_tile, w), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((v_r, v_tile), lambda i: (0, i)),
            pl.BlockSpec((v_r, v_tile), lambda i: (0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((v_r, v_p), a.dtype),
            jax.ShapeDtypeStruct((v_r, v_p), a.dtype),
        ],
        interpret=interpret,
    )(a, b_p)
    return k[:, :v], km[:, :v]


@functools.partial(jax.jit,
                   static_argnames=("lamb", "rows_blk", "v_tile", "interpret"))
def cdist_kexp_rows(a: jax.Array, b: jax.Array, *, lamb: float,
                    rows_blk: int = 8, v_tile: int = 512,
                    interpret: bool = False) -> tuple[jax.Array, jax.Array]:
    """Row-subset fused precompute: a (m, w) miss rows, b (V, w) -> (K, K.*M).

    The cache-miss path of `core.kcache`: ``m`` is the number of word-ids the
    batch needs that are not resident, so unlike `cdist_kexp` the row operand
    cannot be assumed VMEM-resident -- the grid tiles (rows x vocab tiles)
    and each step reads one (rows_blk, w) row block + one (v_tile, w) vocab
    block. Rows and vocab are both padded to their tile and sliced back.
    """
    m, w = a.shape
    v = b.shape[0]
    a_p = pad_axis(a, 0, rows_blk)
    b_p = pad_axis(b, 0, v_tile)
    m_p, v_p = a_p.shape[0], b_p.shape[0]
    grid = (m_p // rows_blk, v_p // v_tile)
    k, km = pl.pallas_call(
        functools.partial(_kexp_kernel, lamb=lamb),
        grid=grid,
        in_specs=[
            pl.BlockSpec((rows_blk, w), lambda i, j: (i, 0)),
            pl.BlockSpec((v_tile, w), lambda i, j: (j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((rows_blk, v_tile), lambda i, j: (i, j)),
            pl.BlockSpec((rows_blk, v_tile), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m_p, v_p), a.dtype),
            jax.ShapeDtypeStruct((m_p, v_p), a.dtype),
        ],
        interpret=interpret,
    )(a_p, b_p)
    return k[:m, :v], km[:m, :v]
