"""Pallas TPU kernel for the batched doc-side RWMD min-SDDMM.

The prefilter's reduction (`core.rwmd`) has the same sampled-access
structure as the engine's SDDMM (`kernels.sddmm_spmm`): per ELL slot, one
gather of a column stripe at ``cols[j, s]``. The contraction differs -- a
min over the query-word axis instead of a dot with u, and a val-weighted
accumulation of the scalar mins instead of a column accumulation:

  grid = (Q/q_blk, N/docs_blk)          # M stripe resident per Q stripe
  for j in docs_blk:                    # docs of this tile
    for s in nnz_max:                   # slots of doc j
      mcols = M[:, :, cols[j,s]]        # (q_blk, v_r) -- ONE gather
      mn    = min_i mcols[:, i]         # q_blk min-reductions
      acc  += where(vals[j,s] != 0, vals[j,s] * mn, 0)
  lb[:, tile_j] = acc

Pad conventions (enforced by the `ops.rwmd_bound_batch` wrapper):
  * pad *query rows* carry +inf so they never win the min (the opposite of
    the K stripes' zeroed pad rows: a zero row would collapse every min);
  * pad *ELL slots* (val == 0) are excluded by the val mask, so the M pad
    column's value is irrelevant;
  * pad docs / all-pad filler queries produce 0 / +inf partials that the
    wrapper slices off resp. finites to 0.

VMEM working set per grid step mirrors the batched SDDMM-SpMM kernels with
one operand fewer: the (q_blk, v_r, Vloc+1) M stripe dominates; cols/vals
tiles add 2 * docs_blk * nnz_max * 4B; the output tile is (q_blk, docs_blk).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rwmd_kernel(m_ref, cols_ref, vals_ref, lb_ref):
    """One (doc tile, Q stripe): per-slot gather feeds all q_blk mins."""
    q_blk = m_ref.shape[0]
    docs_blk, nnz_max = cols_ref.shape
    dtype = lb_ref.dtype

    def doc_body(j, _):
        def slot_body(s, acc):
            col = cols_ref[j, s]
            mcols = m_ref[:, :, col]                 # (q_blk, v_r) ONE gather
            mn = jnp.min(mcols, axis=1)              # q_blk min-reductions
            val = vals_ref[j, s]
            return acc + jnp.where(val != 0.0, val * mn, 0.0)

        acc = jax.lax.fori_loop(
            0, nnz_max, slot_body, jnp.zeros((q_blk,), dtype))
        lb_ref[:, 0, j] = acc
        return 0

    jax.lax.fori_loop(0, docs_blk, doc_body, 0)


@functools.partial(jax.jit,
                   static_argnames=("docs_blk", "q_blk", "interpret"))
def rwmd_bound_batch(m_pad: jax.Array, cols: jax.Array, vals: jax.Array, *,
                     docs_blk: int = 8, q_blk: int = 8,
                     interpret: bool = False) -> jax.Array:
    """Batched min-SDDMM. Shapes: m_pad (Q, v_r, Vloc+1), cols/vals
    (N, nnz_max) with N % docs_blk == 0 and Q % q_blk == 0. Returns (Q, N)
    raw partial bounds (callers finite-ize filler-query rows)."""
    q = m_pad.shape[0]
    n, nnz_max = cols.shape
    grid = (q // q_blk, n // docs_blk)       # M stripes stay VMEM-resident
    out = pl.pallas_call(
        _rwmd_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((q_blk,) + m_pad.shape[1:], lambda qi, i: (qi, 0, 0)),
            pl.BlockSpec((docs_blk, nnz_max), lambda qi, i: (i, 0)),
            pl.BlockSpec((docs_blk, nnz_max), lambda qi, i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((q_blk, 1, docs_blk),
                               lambda qi, i: (qi, 0, i)),
        out_shape=jax.ShapeDtypeStruct((q, 1, n), vals.dtype),
        interpret=interpret,
    )(m_pad, cols, vals)
    return out[:, 0]
