"""The one pad-axis-to-multiple helper shared by the kernel wrappers.

Every Pallas wrapper in this package pads some axis up to a tile/sublane
multiple and slices the result back; keeping a single implementation stops
the copies from drifting (pad value, dtype handling) independently.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def pad_axis(x: jax.Array, axis: int, mult: int, value=0.0) -> jax.Array:
    """Zero-pad (or ``value``-pad) ``axis`` of ``x`` up to a multiple of
    ``mult``; returns ``x`` unchanged when already aligned."""
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)
