"""Sharded, async, elastic checkpointing (msgpack, optionally zstd; no orbax).

Layout per step:  <dir>/step_<n>/
    meta.json            step, mesh signature, tree structure hash
    shard_<p>.msgpack[.zst]  one file per host process (this container: p=0);
                         ``.zst`` when the optional ``zstandard`` codec is
                         installed, plain msgpack otherwise (restore handles
                         both, but reading a ``.zst`` shard requires the dep)

Properties required at 1000+-node scale (DESIGN.md section 7):
  * **atomic**: written to ``step_<n>.tmp`` then renamed -- a crashed writer
    never corrupts the latest checkpoint;
  * **async**: `save_async` snapshots to host memory synchronously (cheap)
    and serializes/writes on a background thread, so the train loop is
    blocked only for the device->host copy;
  * **elastic**: arrays are saved unsharded-logical (per-host shards hold
    host-local slices; single-process here = full arrays). `restore` takes
    the *current* shardings and device_puts accordingly, so a checkpoint
    written on a (2,16,16) mesh restores onto any other factoring;
  * **self-describing**: dtypes/shapes/tree paths in the file, verified
    against the restore target.
"""
from __future__ import annotations

import hashlib
import json
import os
import threading
from typing import Any, Optional

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

try:  # optional dep (`pip install .[zstd]`): fall back to uncompressed
    import zstandard
except ImportError:
    zstandard = None

_COMPRESS_LEVEL = 3


class CheckpointCorruptionError(RuntimeError):
    """A checkpoint on disk fails its integrity check (shard checksum
    mismatch, missing shard, or unreadable metadata)."""


def _path_str(path) -> str:
    return jax.tree_util.keystr(path)


def _tree_signature(tree: Any) -> str:
    paths = [_path_str(p) for p, _ in
             jax.tree_util.tree_flatten_with_path(tree)[0]]
    return hashlib.sha1("|".join(sorted(paths)).encode()).hexdigest()


def save(ckpt_dir: str, step: int, state: Any, *,
         mesh_signature: str = "", process_index: int = 0) -> str:
    """Blocking save. Returns the final checkpoint path."""
    flat = jax.tree_util.tree_flatten_with_path(state)[0]
    host = {_path_str(p): np.asarray(jax.device_get(v)) for p, v in flat}
    return _write(ckpt_dir, step, host, _tree_signature(state),
                  mesh_signature, process_index)


class AsyncCheckpointer:
    """Snapshot synchronously, serialize+write in the background."""

    def __init__(self, ckpt_dir: str, *, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save(self, step: int, state: Any, *, mesh_signature: str = "") -> None:
        self.wait()
        flat = jax.tree_util.tree_flatten_with_path(state)[0]
        host = {_path_str(p): np.asarray(jax.device_get(v)) for p, v in flat}
        sig = _tree_signature(state)

        def work():
            try:
                _write(self.ckpt_dir, step, host, sig, mesh_signature, 0)
                _gc(self.ckpt_dir, self.keep)
            except BaseException as e:  # surfaced on the next wait()/save()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        """Join the in-flight write. A failure on the background thread is
        re-raised here (once) rather than dying silently -- otherwise the
        train loop keeps running while every checkpoint is lost."""
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err


def _write(ckpt_dir, step, host: dict, tree_sig, mesh_sig, proc) -> str:
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):  # leftovers from a crashed writer (possibly a
        import shutil        # different codec) must not leak into this save
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    payload = {k: {"dtype": str(v.dtype), "shape": list(v.shape),
                   "data": v.tobytes()} for k, v in host.items()}
    blob = msgpack.packb(payload, use_bin_type=True)
    if zstandard is not None:
        blob = zstandard.ZstdCompressor(level=_COMPRESS_LEVEL).compress(blob)
        shard_name = f"shard_{proc}.msgpack.zst"
    else:
        shard_name = f"shard_{proc}.msgpack"
    with open(os.path.join(tmp, shard_name), "wb") as f:
        f.write(blob)
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump({"step": step, "tree_signature": tree_sig,
                   "mesh_signature": mesh_sig,
                   "num_arrays": len(host),
                   "shards": {shard_name: {
                       "sha256": hashlib.sha256(blob).hexdigest(),
                       "bytes": len(blob)}}}, f)
    if os.path.exists(final):
        import shutil
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def _gc(ckpt_dir: str, keep: int) -> None:
    steps = sorted(d for d in os.listdir(ckpt_dir) if d.startswith("step_")
                   and not d.endswith(".tmp"))
    for d in steps[:-keep]:
        import shutil
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def _step_intact(path: str) -> bool:
    """True when a step dir's metadata is readable and every shard listed
    in it exists with a matching sha256.  Legacy checkpoints (no "shards"
    key in meta.json) are trusted as-is."""
    try:
        with open(os.path.join(path, "meta.json")) as f:
            meta = json.load(f)
    except (OSError, ValueError):
        return False
    for name, rec in meta.get("shards", {}).items():
        shard = os.path.join(path, name)
        try:
            with open(shard, "rb") as f:
                blob = f.read()
        except OSError:
            return False
        if len(blob) != rec["bytes"]:
            return False
        if hashlib.sha256(blob).hexdigest() != rec["sha256"]:
            return False
    return True


def latest_step(ckpt_dir: str) -> Optional[int]:
    """Newest step whose checkpoint is intact.  Corrupt or incomplete
    steps (truncated shard, bit-flip, missing meta) are skipped so a
    restart falls back to the last good one instead of crashing."""
    if not os.path.isdir(ckpt_dir):
        return None
    steps = sorted((int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
                    if d.startswith("step_") and not d.endswith(".tmp")),
                   reverse=True)
    for step in steps:
        if _step_intact(os.path.join(ckpt_dir, f"step_{step:08d}")):
            return step
    return None


def _verify_shard(meta: dict, name: str, blob: bytes) -> None:
    rec = meta.get("shards", {}).get(name)
    if rec is None:  # legacy checkpoint written before checksums existed
        return
    if len(blob) != rec["bytes"] or \
            hashlib.sha256(blob).hexdigest() != rec["sha256"]:
        raise CheckpointCorruptionError(
            f"shard {name}: on-disk bytes do not match the checksum in "
            f"meta.json (expected {rec['bytes']}B sha256={rec['sha256']}, "
            f"got {len(blob)}B) -- the checkpoint is corrupt")


def restore(ckpt_dir: str, step: int, like: Any, *,
            shardings: Any = None, process_index: int = 0) -> Any:
    """Restore into the structure of ``like``; re-shard to ``shardings``
    (current mesh) if given -- the elastic path."""
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "meta.json")) as f:
        meta = json.load(f)
    if meta["tree_signature"] != _tree_signature(like):
        raise ValueError("checkpoint tree does not match restore target "
                         "(structure changed?)")
    raw_path = os.path.join(path, f"shard_{process_index}.msgpack")
    zst_path = raw_path + ".zst"
    if os.path.exists(zst_path):
        if zstandard is None:
            raise RuntimeError(
                f"{zst_path} is zstd-compressed but zstandard is not "
                "installed (pip install .[zstd])")
        with open(zst_path, "rb") as f:
            raw = f.read()
        _verify_shard(meta, os.path.basename(zst_path), raw)
        blob = zstandard.ZstdDecompressor().decompress(raw)
    else:
        with open(raw_path, "rb") as f:
            blob = f.read()
        _verify_shard(meta, os.path.basename(raw_path), blob)
    payload = msgpack.unpackb(blob, raw=False)

    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    shard_flat = (jax.tree_util.tree_flatten(shardings)[0]
                  if shardings is not None else [None] * len(flat))
    out = []
    for (p, leaf), shard in zip(flat, shard_flat):
        rec = payload[_path_str(p)]
        arr = np.frombuffer(rec["data"],
                            dtype=np.dtype(rec["dtype"])).reshape(rec["shape"])
        if shard is not None:
            out.append(jax.device_put(arr, shard))
        else:
            out.append(jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like), out)
