"""Sharded async elastic checkpointing."""
from repro.checkpoint.checkpointer import (AsyncCheckpointer,
                                           CheckpointCorruptionError,
                                           latest_step, restore, save)

__all__ = ["AsyncCheckpointer", "CheckpointCorruptionError", "latest_step",
           "restore", "save"]
