"""Sharded async elastic checkpointing."""
from repro.checkpoint.checkpointer import (AsyncCheckpointer, latest_step,
                                           restore, save)

__all__ = ["AsyncCheckpointer", "latest_step", "restore", "save"]
