"""Fault-tolerant training loop: checkpoint/restart, straggler accounting.

The loop is deliberately boring -- that is the point of fault tolerance:
  * deterministic data indexed by global step (restart-safe),
  * async checkpoint every ``ckpt_every`` steps, atomic on disk,
  * automatic resume from the latest checkpoint (``restore_or_init``),
  * a failure-injection hook used by the integration tests to prove the
    restart path end-to-end (simulated node failure mid-run),
  * per-step wall-time tracking with a straggler monitor (steps slower than
    ``straggler_factor`` x median are counted and logged; on real multi-host
    deployments this signal feeds the launcher's respawn policy --
    `repro.distributed.fault_tolerance`).
"""
from __future__ import annotations

import os
import time
from typing import Any, Callable, Optional

import jax
import numpy as np

from repro.checkpoint import checkpointer as ckpt
from repro.data.tokens import TokenPipeline
from repro.distributed import partitioning
from repro.models.registry import ModelAPI
from repro.models.sharding_hints import activation_sharding
from repro.optim import AdamW
from repro.train import step as train_step_mod


class Trainer:
    def __init__(self, model: ModelAPI, optimizer: AdamW, mesh,
                 pipeline: TokenPipeline, *, ckpt_dir: str,
                 microbatches: int = 1, grad_compression: bool = False,
                 ckpt_every: int = 50, straggler_factor: float = 2.0,
                 log_fn: Callable[[str], None] = print):
        self.model = model
        self.optimizer = optimizer
        self.mesh = mesh
        self.pipeline = pipeline
        self.ckpt_dir = ckpt_dir
        self.ckpt_every = ckpt_every
        self.straggler_factor = straggler_factor
        self.log = log_fn
        self.grad_compression = grad_compression
        self.step_fn = train_step_mod.build_train_step(
            model, optimizer, mesh, microbatches=microbatches,
            grad_compression=grad_compression)
        self.async_ckpt = ckpt.AsyncCheckpointer(ckpt_dir)
        self.step_times: list[float] = []
        self.stragglers = 0

    # -- state ------------------------------------------------------------
    def _mesh_signature(self) -> str:
        return "x".join(f"{n}={s}" for n, s in
                        zip(self.mesh.axis_names, self.mesh.devices.shape))

    def restore_or_init(self, key) -> tuple[Any, int]:
        state_struct = jax.eval_shape(
            lambda k: train_step_mod.init_state(
                self.model, self.optimizer, k,
                grad_compression=self.grad_compression), key)
        shardings = train_step_mod.state_shardings(self.mesh, state_struct)
        last = ckpt.latest_step(self.ckpt_dir)
        if last is not None:
            self.log(f"[trainer] restoring step {last} from {self.ckpt_dir}")
            state = ckpt.restore(self.ckpt_dir, last, state_struct,
                                 shardings=shardings)
            return state, last
        with self.mesh:
            state = train_step_mod.init_state(
                self.model, self.optimizer, key,
                grad_compression=self.grad_compression)
            state = jax.device_put(state, shardings)
        return state, 0

    # -- loop ---------------------------------------------------------------
    def run(self, key, num_steps: int, *,
            fail_at: Optional[int] = None) -> dict:
        """Train to ``num_steps`` global steps (resuming if checkpoints
        exist). ``fail_at`` raises a simulated failure at that step once."""
        state, start = self.restore_or_init(key)
        metrics_hist = []
        for step_idx in range(start, num_steps):
            if fail_at is not None and step_idx == fail_at \
                    and not os.environ.get("REPRO_FAILED_ONCE"):
                os.environ["REPRO_FAILED_ONCE"] = "1"
                raise RuntimeError(f"injected node failure at step {step_idx}")
            batch = jax.device_put(
                self.pipeline.batch_at(step_idx),
                partitioning.batch_shardings(
                    self.mesh, self.pipeline.batch_at(step_idx)))
            t0 = time.perf_counter()
            with self.mesh, activation_sharding(self.mesh):
                state, metrics = self.step_fn(state, batch)
            loss = float(metrics["loss"])
            dt = time.perf_counter() - t0
            self._track_straggler(dt)
            metrics_hist.append({"step": step_idx, "loss": loss,
                                 "sec": dt})
            if (step_idx + 1) % self.ckpt_every == 0 \
                    or step_idx + 1 == num_steps:
                self.async_ckpt.save(step_idx + 1, state,
                                     mesh_signature=self._mesh_signature())
                self.log(f"[trainer] step {step_idx + 1} "
                         f"loss={loss:.4f} ckpt queued")
        self.async_ckpt.wait()
        return {"history": metrics_hist, "stragglers": self.stragglers,
                "final_state": state}

    def _track_straggler(self, dt: float) -> None:
        self.step_times.append(dt)
        if len(self.step_times) >= 8:
            med = float(np.median(self.step_times[-50:]))
            if dt > self.straggler_factor * med:
                self.stragglers += 1
                self.log(f"[trainer] straggler step: {dt:.3f}s "
                         f"(median {med:.3f}s)")
