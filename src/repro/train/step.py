"""Training step builder: loss + grad + AdamW under pjit, with microbatch
gradient accumulation and optional int8 gradient compression.

``build_train_step`` returns a jit'd function with explicit in/out shardings
(params/opt FSDP+TP per partitioning.py, batch over (pod, data)), donated
params/opt buffers, and remat already applied inside the model stack. The
dry-run lowers exactly this function.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.distributed import partitioning
from repro.models.registry import ModelAPI
from repro.optim import AdamW, AdamWState
from repro.optim import compression as comp


class TrainState(NamedTuple):
    params: Any
    opt: AdamWState
    comp: Optional[comp.CompressionState]


def init_state(model: ModelAPI, optimizer: AdamW, key,
               *, grad_compression: bool = False) -> TrainState:
    params = model.init(key)
    opt = optimizer.init(params)
    cstate = comp.init_state(params) if grad_compression else None
    return TrainState(params=params, opt=opt, comp=cstate)


def state_shardings(mesh: Mesh, state: TrainState) -> TrainState:
    pshard = partitioning.param_shardings(mesh, state.params)
    rep = NamedSharding(mesh, P())
    opt = AdamWState(step=rep,
                     mu=partitioning.param_shardings(mesh, state.opt.mu),
                     nu=partitioning.param_shardings(mesh, state.opt.nu))
    cshard = None
    if state.comp is not None:
        cshard = comp.CompressionState(residual=partitioning.param_shardings(
            mesh, state.comp.residual))
    return TrainState(params=pshard, opt=opt, comp=cshard)


def build_train_step(model: ModelAPI, optimizer: AdamW, mesh: Mesh, *,
                     microbatches: int = 1, grad_compression: bool = False,
                     donate: bool = True):
    """Returns jit'd (state, batch) -> (state, metrics)."""

    def loss_fn(params, batch):
        return model.loss(params, batch)

    def step(state: TrainState, batch):
        if microbatches > 1:
            # gradient accumulation: scan over microbatch slices
            def split(x):
                b = x.shape[0]
                return x.reshape(microbatches, b // microbatches,
                                 *x.shape[1:])
            mb = jax.tree.map(split, batch)

            def acc_fn(carry, mbatch):
                gsum, lsum = carry
                (l, _), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    state.params, mbatch)
                gsum = jax.tree.map(jnp.add, gsum, g)
                return (gsum, lsum + l), None

            g0 = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32),
                              state.params)
            (gsum, lsum), _ = jax.lax.scan(acc_fn, (g0, 0.0), mb)
            grads = jax.tree.map(lambda g: g / microbatches, gsum)
            loss = lsum / microbatches
            metrics = {}
        else:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(state.params, batch)

        cstate = state.comp
        if grad_compression and cstate is not None:
            grads, cstate = comp.compress_grads(grads, cstate)

        params, opt = optimizer.update(grads, state.opt, state.params)
        out_metrics = {"loss": loss,
                       "grad_norm": jax.tree.reduce(
                           lambda a, b: a + b,
                           jax.tree.map(lambda g: jnp.sum(
                               jnp.square(g.astype(jnp.float32))), grads),
                           0.0) ** 0.5}
        out_metrics.update({k: v for k, v in metrics.items()})
        return TrainState(params=params, opt=opt, comp=cstate), out_metrics

    dummy = jax.eval_shape(
        lambda k: init_state(model, optimizer, k,
                             grad_compression=grad_compression),
        jax.random.PRNGKey(0))
    sshard = state_shardings(mesh, dummy)
    rep = NamedSharding(mesh, P())

    return jax.jit(
        step,
        in_shardings=(sshard, None),
        out_shardings=(sshard, rep),
        donate_argnums=(0,) if donate else (),
    )


def batch_shardings(mesh: Mesh, batch_struct: Any):
    return partitioning.batch_shardings(mesh, batch_struct)
