"""Training substrate: step builder + fault-tolerant trainer loop."""
from repro.train.step import TrainState, build_train_step, init_state, state_shardings
from repro.train.trainer import Trainer

__all__ = ["TrainState", "build_train_step", "init_state", "state_shardings", "Trainer"]
