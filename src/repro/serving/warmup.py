"""Ahead-of-time program warmup: a registry of every program shape the
serving envelope can dispatch, precompiled at startup through a persisted
jax compilation cache.

Why a registry
--------------
The engine compiles one XLA program per *dispatch shape*: the pow2 Q
admission bucket x the request kind (plain distances / pruned top-k) x k x
the engine knobs baked into the jitted fns (impl, docs_chunk, tol,
prune_chunk). A first-hit compile costs 100-1000x a warm solve (PR 5
measured serve-loop p50 dropping 335 -> 58 ms from warming one program), so
a latency-mode service must never meet a shape cold. The ad-hoc warmers
this module replaces (`QueryCoalescer.warm` / `warm_top_k`, now shims over
this registry) each hand-walked one kind's buckets; the registry instead
*enumerates the whole envelope from the service config* -- the same
config the coalescer's admission rules read -- so "every shape the
coalescer can dispatch is warm" is a checkable statement
(tests/test_warmup.py cross-checks the registry against a randomized
session's dispatch log and asserts zero first-hit compiles after warmup).

    registry = ShapeRegistry.from_service(svc, max_batch=16, ks=(8,))
    report = warm(svc, registry)          # one dispatch per shape
    report.compile_s                      # total backend-compile seconds
    report.shapes["top_k/q8/k8"].compile_s  # ... per shape

Persisted compilation cache
---------------------------
`enable_compilation_cache(dir)` points jax's persistent compilation cache
at ``dir`` (entry thresholds zeroed so CPU-sized programs persist too).
Compiled programs are keyed by (HLO, jaxlib, flags) and written at compile
time; a later process -- the next serve run, a CI job restoring the
directory from `actions/cache` -- *re-lowers* each shape but skips the
XLA backend compile, which is where nearly all of the time goes. `warm`
reports both sides of that split per shape (``compile_s`` vs
``persistent_hits``/``retrieval_s``), which is how
benchmarks/bench_serving.py measures its cold-vs-warm-start delta.

Compile accounting
------------------
`measure_compiles()` counts *backend compiles* (the jax monitoring event
``/jax/core/compile/backend_compile_duration``) and persistent-cache
retrievals inside a ``with`` block. A shape served entirely from live jit
caches fires neither -- the post-warmup steady state the zero-first-hit
tests assert.

Cascade shapes
--------------
The top-k warm dispatches run the full retrieval cascade, so the tier-0
moments matmul, the LC-RWMD program for the configured ``lc_impl``, the
capped doc-side bound, and the M-cache's miss-compute/scatter programs
(shapes keyed by the same rows_bucket sweep as the K cache's) all compile
during warmup; no extra registry entries are needed because the tiers are
internal to the ``top_k``/``top_k_union`` dispatch shapes.
"""
from __future__ import annotations

import contextlib
import dataclasses
import os
import threading
import time
from typing import Iterable, Sequence

import numpy as np

# the service rounds Q up to these buckets; one copy of the rule
from repro.serving.coalescer import _next_pow2

# jax monitoring events. BACKEND_COMPILE_EVENT wraps the whole
# compile-OR-retrieve step (pxla times `compile_or_get_cached`), so it fires
# on persistent-cache hits too; the retrieval event fires only on hits,
# nested inside the compile span. True backend compiles are therefore
# events - hits (CompileCounter derives exactly that).
_COMPILE_EVENT = "/jax/core/compile/backend_compile_duration"
_RETRIEVAL_EVENT = "/jax/compilation_cache/cache_retrieval_time_sec"

_KINDS = ("plain", "top_k", "top_k_union")


# -- compile-event accounting -------------------------------------------------

_listener_lock = threading.Lock()
_listener_installed = False
_active_counters: list["CompileCounter"] = []


@dataclasses.dataclass
class CompileCounter:
    """Compile-or-retrieve tallies for one measured span.

    ``events`` counts every compile-OR-retrieve step jax performed (one per
    program lowered to XLA, whether backend-compiled or deserialized from
    the persistent cache); ``persistent_hits`` the subset served from the
    cache. ``compiles`` -- what the zero-first-hit and cold-start numbers
    mean -- is the difference: programs that actually paid an XLA backend
    compile."""
    events: int = 0
    event_s: float = 0.0
    persistent_hits: int = 0
    retrieval_s: float = 0.0

    @property
    def compiles(self) -> int:
        return self.events - self.persistent_hits

    @property
    def compile_s(self) -> float:
        # retrieval spans are nested inside their compile-event span, so
        # subtracting leaves the pure backend-compile time
        return max(0.0, self.event_s - self.retrieval_s)


def _on_event_duration(event: str, duration: float, **_kw) -> None:
    if event not in (_COMPILE_EVENT, _RETRIEVAL_EVENT):
        return
    with _listener_lock:
        for c in _active_counters:
            if event == _COMPILE_EVENT:
                c.events += 1
                c.event_s += duration
            else:
                c.persistent_hits += 1
                c.retrieval_s += duration


def _install_listener() -> None:
    # one process-wide listener, installed lazily on first measurement
    # (jax.monitoring has no deregistration, so registering per-measure
    # would leak a listener per call)
    global _listener_installed
    import jax.monitoring
    with _listener_lock:
        if not _listener_installed:
            jax.monitoring.register_event_duration_secs_listener(
                _on_event_duration)
            _listener_installed = True


@contextlib.contextmanager
def measure_compiles():
    """Count XLA backend compiles (and persistent-cache retrievals) issued
    while the block runs. Nestable; yields a `CompileCounter` whose fields
    are final once the block exits."""
    _install_listener()
    counter = CompileCounter()
    with _listener_lock:
        _active_counters.append(counter)
    try:
        yield counter
    finally:
        with _listener_lock:
            _active_counters.remove(counter)


# -- persisted compilation cache ---------------------------------------------

def enable_compilation_cache(cache_dir: str | os.PathLike) -> str:
    """Point jax's persistent compilation cache at ``cache_dir``.

    Zeroes the entry thresholds (min compile time / min entry size) so the
    CPU-sized programs of the test and CI shapes persist too -- the
    defaults only persist second-scale compiles. Safe to call before any
    compile in the process; programs compiled afterwards are written
    eagerly, keyed by (HLO, jaxlib version, compile flags), so a crash or
    SIGINT after the first compile still leaves a warm cache behind.
    Returns the directory (created if missing)."""
    import jax
    cache_dir = os.fspath(cache_dir)
    os.makedirs(cache_dir, exist_ok=True)
    jax.config.update("jax_compilation_cache_dir", cache_dir)
    jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.0)
    jax.config.update("jax_persistent_cache_min_entry_size_bytes", -1)
    return cache_dir


def flush_compilation_cache() -> dict | None:
    """Surface the persisted compilation cache's on-disk state.

    jax writes cache entries eagerly at compile time, so there is no
    buffered data to force out; "flush" here means walking the configured
    directory so shutdown paths (serve.py's SIGINT handler) exit with the
    persisted state on record -- an interrupted serve run should still
    report the warm cache it leaves behind for the next start. Returns
    ``{"dir", "entries", "bytes"}`` or None when no cache is configured."""
    import jax
    cache_dir = jax.config.jax_compilation_cache_dir
    if not cache_dir or not os.path.isdir(cache_dir):
        return None
    entries = 0
    n_bytes = 0
    for name in os.listdir(cache_dir):
        if name.endswith("-cache"):
            entries += 1
            with contextlib.suppress(OSError):
                n_bytes += os.path.getsize(os.path.join(cache_dir, name))
    return {"dir": cache_dir, "entries": entries, "bytes": n_bytes}


# -- the registry -------------------------------------------------------------

@dataclasses.dataclass(frozen=True, order=True)
class ProgramShape:
    """One dispatch shape of the serving envelope.

    ``kind`` is the request kind the coalescer cuts batches by ("plain"
    distance rows, "top_k" = pruned per-query rerank, "top_k_union" = the
    offline bulk mode's (Q, chunk) union rerank); ``q_bucket`` the pow2
    admission bucket; ``k`` the retrieval size (None for plain);
    ``impl`` the contraction path baked into the solver fns."""
    kind: str
    q_bucket: int
    k: int | None = None
    impl: str = "fused"

    def __post_init__(self):
        if self.kind not in _KINDS:
            raise ValueError(f"kind must be one of {_KINDS}, "
                             f"got {self.kind!r}")
        if self.q_bucket != _next_pow2(self.q_bucket):
            raise ValueError(f"q_bucket must be a power of two, "
                             f"got {self.q_bucket}")
        if (self.k is None) == (self.kind != "plain"):
            raise ValueError(f"k must be set iff kind is top_k*, "
                             f"got kind={self.kind!r} k={self.k}")

    @property
    def label(self) -> str:
        tail = "" if self.k is None else f"/k{self.k}"
        return f"{self.kind}/q{self.q_bucket}{tail}"


class ShapeRegistry:
    """The serving envelope as an explicit, enumerable set of shapes.

    Built from the service config (`from_service`) rather than hand-listed:
    the pow2 Q buckets come from the admission rule (`_next_pow2`, the same
    rounding `WMDService._padded_query_batch` and the coalescer's
    ``max_batch`` use), the kinds and ks from what the deployment serves.
    ``covers`` is the membership test the warmup tests use to prove the
    coalescer can never dispatch a shape outside the registry."""

    def __init__(self, shapes: Iterable[ProgramShape]):
        self.shapes: tuple[ProgramShape, ...] = \
            tuple(dict.fromkeys(shapes))           # de-dup, keep order

    @classmethod
    def from_service(cls, svc, *, max_batch: int = 16,
                     ks: Sequence[int] = (),
                     kinds: Sequence[str] | None = None,
                     impl: str | None = None) -> "ShapeRegistry":
        """Enumerate the envelope: every pow2 Q bucket up to ``max_batch``
        x every request kind x every k the deployment serves.

        ``kinds`` defaults to "plain" plus "top_k" when ``ks`` is
        non-empty ("top_k_union" -- the offline mode's rerank shape -- must
        be requested explicitly: it is never dispatched by the online
        coalescer). ``impl`` defaults to the service's configured impl, so
        the registry follows the config instead of restating it."""
        if kinds is None:
            kinds = ("plain",) + (("top_k",) if ks else ())
        for kind in kinds:
            if kind not in _KINDS:
                raise ValueError(f"unknown kind {kind!r}")
        if any(kind != "plain" for kind in kinds) and not ks:
            raise ValueError("top_k kinds need at least one k in ks")
        impl = svc.impl if impl is None else impl
        buckets = []
        b = 1
        while b <= _next_pow2(max_batch):
            buckets.append(b)
            b *= 2
        shapes = []
        for kind in kinds:
            for b in buckets:
                if kind == "plain":
                    shapes.append(ProgramShape(kind, b, impl=impl))
                else:
                    shapes.extend(ProgramShape(kind, b, k=int(k), impl=impl)
                                  for k in ks)
        return cls(shapes)

    def __len__(self) -> int:
        return len(self.shapes)

    def __iter__(self):
        return iter(self.shapes)

    def covers(self, kind: str, q: int, k: int | None = None) -> bool:
        """True iff a dispatch of ``q`` requests of ``kind`` (with ``k``)
        pads into a bucket this registry enumerates."""
        b = _next_pow2(max(int(q), 1))
        return any(s.kind == kind and s.q_bucket == b and s.k == k
                   for s in self.shapes)

    @property
    def labels(self) -> list[str]:
        return [s.label for s in self.shapes]


# -- the warmup pass ----------------------------------------------------------

@dataclasses.dataclass
class ShapeWarmup:
    """Per-shape outcome of one warmup dispatch."""
    shape: ProgramShape
    wall_s: float                 # whole dispatch (compile + solve)
    compiles: int                 # XLA backend compiles triggered
    compile_s: float              # ... their total duration
    persistent_hits: int          # programs served from the persisted cache
    retrieval_s: float            # ... their deserialization time


@dataclasses.dataclass
class WarmupReport:
    """Outcome of one registry-driven warmup pass.

    ``shapes`` maps `ProgramShape.label` to its `ShapeWarmup`; the scalar
    totals are what `ServingStats` and the bench artifact record. A
    *cold* start shows ``compiles > 0`` and ``persistent_hits == 0``; a
    *warm* start (persisted cache primed by an earlier process) flips
    both -- the delta is the startup time the cache buys."""
    registry: ShapeRegistry
    shapes: dict[str, ShapeWarmup]
    wall_s: float

    @property
    def compiles(self) -> int:
        return sum(s.compiles for s in self.shapes.values())

    @property
    def compile_s(self) -> float:
        return sum(s.compile_s for s in self.shapes.values())

    @property
    def persistent_hits(self) -> int:
        return sum(s.persistent_hits for s in self.shapes.values())

    @property
    def retrieval_s(self) -> float:
        return sum(s.retrieval_s for s in self.shapes.values())

    def compile_s_by_label(self) -> dict[str, float]:
        return {lbl: s.compile_s for lbl, s in self.shapes.items()}

    def summary(self) -> dict:
        """JSON-friendly form (the bench artifact's warmup block)."""
        return {"shapes": self.registry.labels,
                "wall_s": self.wall_s,
                "compiles": self.compiles,
                "compile_s": self.compile_s,
                "persistent_hits": self.persistent_hits,
                "retrieval_s": self.retrieval_s,
                "per_shape": {
                    lbl: {"wall_s": s.wall_s, "compiles": s.compiles,
                          "compile_s": s.compile_s,
                          "persistent_hits": s.persistent_hits}
                    for lbl, s in self.shapes.items()}}


def synth_queries(cfg, n: int, *, seed: int = 0) -> list[np.ndarray]:
    """Deterministic synthetic (V,) query histograms for warmup dispatches.

    Shapes are all that matter to compilation -- the padded batch is
    (Q_pow2, cfg.v_r) regardless of content -- so warmup does not need
    real traffic; it draws ``v_r - 1`` distinct words per query (the
    densest admissible support) from a seeded rng."""
    rng = np.random.default_rng(seed)
    words = max(1, min(cfg.v_r - 1, cfg.vocab_size - 1))
    qs = []
    for _ in range(n):
        r = np.zeros(cfg.vocab_size, np.float32)
        idx = rng.choice(cfg.vocab_size, size=words, replace=False)
        r[idx] = rng.random(words).astype(np.float32) + 0.1
        r /= r.sum()
        qs.append(r)
    return qs


def _bound_chunk_payloads(cfg, q: int, rows_bucket: int, *, seed: int = 0):
    """One payload batch per feasible M-table chunk count of a top-k shape.

    The bound tier assembles its M-row table in fixed ``rows_bucket``
    blocks, so the table (and its slot-gather program) has
    ``ceil(unique_ids / rows_bucket) * rows_bucket + 1`` rows -- a program
    shape set by the batch's UNIQUE WORD COUNT, not by (kind, Q, k). One
    dispatch per (kind, Q, k) therefore leaves every other chunk count
    cold (the compile-counter tests caught exactly that). Sweep it: for
    each chunk count c, craft ``q`` queries whose supports union to
    ``min(c * rows_bucket, u_max)`` ids -- word 0 always in the pool (pad
    slots point at it, so it is resident in any real batch's id set),
    per-query supports striding the pool so the union is exact."""
    rng = np.random.default_rng(seed)
    words_max = max(1, min(cfg.v_r - 1, cfg.vocab_size - 1))
    u_max = min(q * words_max, cfg.vocab_size)
    c_max = -(-u_max // rows_bucket)
    for c in range(1, c_max + 1):
        u = min(c * rows_bucket, u_max)
        pool = np.zeros(u, np.int64)
        if u > 1:
            pool[1:] = rng.choice(np.arange(1, cfg.vocab_size),
                                  size=u - 1, replace=False)
        w = min(words_max, u)
        stride = -(-u // q)
        batch = []
        for i in range(q):
            idx = pool[[(i * stride + j) % u for j in range(w)]]
            r = np.zeros(cfg.vocab_size, np.float32)
            r[idx] = rng.random(w).astype(np.float32) + 0.1
            r /= r.sum()
            batch.append(r)
        yield batch


def warm(svc, registry: ShapeRegistry, *,
         queries: Sequence[np.ndarray] | None = None,
         seed: int = 0) -> WarmupReport:
    """Precompile every shape in ``registry`` with one dispatch each.

    Dispatches go through the *public* entry points (`query_batch` /
    `top_k_batch`), so whatever the admission policy routes a bucket to --
    the sequential singleton path, the stripes engine, the pruned rerank --
    is exactly what gets compiled, including the K-cache's fixed-shape
    row-compute/scatter/gather programs on the very first dispatch. Shapes
    run smallest-bucket first so per-shape compile attribution is sharp
    (a bucket never pre-compiles a larger bucket's program).

    ``queries`` (optional) supplies the warmup payloads -- the deprecation
    shims pass the caller's real queries through; by default seeded
    synthetic histograms are used (`synth_queries`). Warmup dispatches hit
    the real engine, so with a K cache enabled they also pre-populate row
    residency (synthetic payloads then fill the store with synthetic ids;
    real Zipf traffic evicts them within a few batches).

    Top-k shapes additionally sweep the bound tier's unique-word-count
    dimension (`_bound_chunk_payloads`): the M-row table's chunk count is
    a program shape of its own, so each (top_k*, Q, k) dispatches once
    per feasible chunk count on top of the ``queries`` payload. The
    zero-first-hit guarantee covers batches whose unique ids fit the K
    cache; a capacity-overflow batch takes the transient bypass, whose
    variably-shaped programs are deliberately outside the envelope.
    """
    max_q = max((s.q_bucket for s in registry), default=0)
    if queries is None:
        qs = synth_queries(svc.cfg, max_q, seed=seed)
    else:
        qs = list(queries)
        if 0 < len(qs) < max_q:                # cycle short payload lists
            reps = -(-max_q // len(qs))
            qs = (qs * reps)[:max_q]
    rows_bucket = getattr(svc, "cache_rows_bucket", 128)
    shapes: dict[str, ShapeWarmup] = {}
    t_start = time.perf_counter()
    for shape in sorted(registry, key=lambda s: (s.q_bucket, s.kind)):
        batch = [qs[i] for i in range(shape.q_bucket)]
        t0 = time.perf_counter()
        with measure_compiles() as counter:
            if shape.kind == "plain":
                svc.query_batch(batch, impl=shape.impl)
            else:
                rerank = "union" if shape.kind == "top_k_union" \
                    else "per_query"
                svc.top_k_batch(batch, shape.k, prune=True,
                                impl=shape.impl, rerank=rerank)
                for sweep in _bound_chunk_payloads(
                        svc.cfg, shape.q_bucket, rows_bucket, seed=seed):
                    svc.top_k_batch(sweep, shape.k, prune=True,
                                    impl=shape.impl, rerank=rerank)
        shapes[shape.label] = ShapeWarmup(
            shape=shape, wall_s=time.perf_counter() - t0,
            compiles=counter.compiles, compile_s=counter.compile_s,
            persistent_hits=counter.persistent_hits,
            retrieval_s=counter.retrieval_s)
    return WarmupReport(registry=registry, shapes=shapes,
                        wall_s=time.perf_counter() - t_start)
