"""Batched Sinkhorn-WMD query service (the paper's workload, production-shaped).

Serves WMD requests against a corpus held sharded on the mesh: vocab-striped
embeddings + rebucketed ELL (loaded once), solved by the fused SDDMM-SpMM
engine with one psum per iteration.

Service API
-----------
  query(r)                  -- one (V,) histogram -> (N,) distances.
  query_batch(rs, impl=...) -- Q histograms -> (Q, N) batched:
      queries are padded to the service's v_r bucket (exact mask-based
      padding, `core.distributed.pad_query_batch`) and admitted in
      power-of-two Q buckets (bounding retrace count). With the cross-query
      K cache enabled (``cache_capacity > 0``) the precompute runs through
      `core.kcache`: word-ids are deduped across the whole batch, only rows
      not already resident are computed (row-subset fused kexp), and each
      query's (v_r, Vloc+1) stripe -- zero pad column included, so `pad_k`
      never runs in the hot path -- is assembled by a single slot-gather
      feeding the stripes engine (`build_wmd_batch_fn_stripes`); with the
      cache disabled the legacy single-program engine (precompute fused
      into the solve, `build_wmd_batch_fn`) runs instead -- faster for a
      one-shot batch since the split path pays an extra dispatch. Either
      way the (Q, v_r, N) solve shares a single ELL gather and a single
      psum per Sinkhorn iteration across all Q queries. Slots added by
      Q-bucketing carry an all-zero row mask, so they cost flops but
      contribute nothing and are sliced off before returning.
      ``impl`` ("fused" | "unfused" | "kernel") overrides the service
      default per call (built fns are cached per impl).
      ``use_cache`` routes explicitly: False = the transient
      (dedup + recompute-everything) stripes path, the cache-off baseline
      that is *bitwise identical* to the cached path; True = the stripes
      engine even on a cache-less service (how the bench phase-splits).
      Admission policy: with the cache disabled, Q = 1 routes to the
      sequential path -- the batched engine's (Q, v_r, N) padding/precompute
      overhead makes a singleton *slower* than the per-query program
      (speedup 0.96x at Q=1 in the BENCH_query_batch.json artifact). With
      the cache enabled even singletons go through the batched stripes path
      so they hit (and warm) the row store.
  query_batch_sequential(rs) -- the per-query dispatch loop, kept as the
      correctness oracle and the baseline for bench_query_batch.py.
  top_k(r, k) / top_k_batch(rs, k) -- nearest-k doc ids + distances
      (argpartition + a tie-deterministic local sort: O(N + k log k), not a
      full argsort; ties are broken by doc id so every route selects the
      same set).
      With ``prune=True`` the two-tier retrieval engine runs instead: every
      doc is scored with the O(nnz) doc-side RWMD lower bound (`core.rwmd`
      -- batched across the query set with the K-cache's word-id dedup),
      docs are visited in ascending-bound order in fixed ``prune_chunk``
      doc blocks (candidate sets stay cache-resident), and the exact
      Sinkhorn rerank (the stripes engine, precompute served by the
      cross-query K cache) runs only until the next block's bound exceeds
      the running k-th exact distance -- every doc past that point is
      provably outside the top-k. The contract is exact: pruned top-k
      returns the bitwise-identical (distance, doc-id) set as
      `top_k_scan_batch`, the exhaustive scan through the SAME chunked
      rerank programs (asserted by tests/test_rwmd_properties.py, the
      golden table, and every bench_prune.py batch), while skipping the
      pruned docs' solves entirely (``last_prune_stats['solves_avoided']``
      -- >= 0.9 at N >= 1024, k <= 16 on the Zipf corpus). Bound soundness
      at a *finite iteration budget* is why the DOC-side RWMD is used --
      see core.rwmd's module docstring.
  top_k_scan_batch(rs, k) -- the pruned path's oracle: exact full scan
      through the same per-query chunked rerank engine (bound order, no
      pruning). Slower than top_k_batch's one-program full scan by
      construction; exists to make "pruned == exact scan" a bitwise
      statement rather than an fp32 one.
  async_service(**kw)       -- async admission front-end: a
      `serving.coalescer.QueryCoalescer` that turns a concurrent stream of
      single-query ``submit(r) -> Future`` calls into full `query_batch`
      dispatches (fill/window/deadline micro-batching, backpressure,
      ServingStats); `drain_async()` flushes every live front-end.
  add_docs / remove_docs / compact -- live-corpus mutation, available on a
      service built via `WMDService.from_live` over a
      `data.live_corpus.LiveCorpus`: WAL-durable upserts/tombstones (the
      return acks fsynced state), lazy per-segment device refresh, and
      interruptible compaction. Live dispatches answer over the live doc
      set in ascending-doc-id order, bitwise identical to a one-shot
      build of the same docs (the incremental == batch contract); top-k
      returns real doc ids via `live_doc_ids`. The K cache is never
      invalidated by corpus mutation (rows don't depend on docs);
      `invalidate_embedding_rows` is the scoped hook for vector updates.

Perf knobs (constructor fields):
  impl           -- default contraction path for query_batch.
  docs_chunk     -- cache-block the batched iteration over doc chunks of
                    this size; at bulk shapes this keeps the (Q, docs_chunk,
                    nnz, v_r) gathered working set cache-resident (see
                    core.sparse_sinkhorn "Batched engine & cache blocking").
  tol            -- early-exit tolerance: converged queries freeze, the
                    solve stops when all queries converge (0.0 = fixed
                    max_iter).
  cache_capacity -- resident row slots of the cross-query K/KM cache
                    (0 = off: every batch recomputes its deduped rows).
                    Memory: capacity x (V+1) x 2 matrices x 4 B, sharded
                    over the ``model`` axis like the vocab striping.
  cache_rows_bucket -- static chunk size of the cache-miss row compute
                    (one compiled program per bucket; also the cache's
                    bit-reproducibility guarantee, see core.kcache). The
                    RWMD prefilter's M-row dedup reuses the same bucket.
  kexp_impl      -- "jnp" | "kernel": row-precompute path for cache misses.
  prune_chunk    -- doc-block size of the pruned rerank (rounded up to the
                    doc-shard product; one fixed-shape (1, prune_chunk)
                    stripes program reranks every candidate block, which is
                    both the cache-blocking and the bitwise argument: every
                    exact distance -- pruned or scan -- comes from the same
                    program shape).
  prune_margin   -- relative safety slack of the prune test (a doc is
                    pruned only when bound * (1 - margin) exceeds the k-th
                    exact distance): covers fp dot-rounding between the
                    bound and the engine's distance (~1e-6 observed) with
                    ~1000x headroom while costing a negligible number of
                    extra solves (the bound's real gap is >= 4% on the
                    bench corpus).
  bound_impl     -- "fused" | "kernel": min-SDDMM path of the prefilter.
  bound_docs_chunk -- cache-block the (Q, N, nnz, v_r) bound gather over
                    doc chunks (None = unchunked; the default keeps the
                    prefilter's working set ~tens of MB at bulk N).

Cache observability: ``cache_stats`` (cumulative hits / misses / evictions /
hit_rate) and ``last_batch_stats`` (per-call ``precompute_s`` / ``solve_s``
phase split + that batch's hit_rate -- the fields the bench artifact
records). The cache re-keys itself if ``cfg.lamb`` changes between calls
(lambda-invalidation: K rows are keyed by (word_id, lambda)).

`examples/wmd_query_service.py` runs it end-to-end (including a Zipf
query-stream demo of the cache); `launch/serve.py` exposes it via
--arch sinkhorn-wmd (add --batch-queries for the batched path).
"""
from __future__ import annotations

import dataclasses
import functools
import threading
import time
import weakref
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import sinkhorn_wmd as wmd_cfg
from repro.core import cascade as cascade_core
from repro.core import formats, select_query
from repro.core import guards as _guards
from repro.core import rwmd as rwmd_core
from repro.core.kcache import KCache, MCache
from repro.core.distributed import (build_wmd_batch_fn,
                                    build_wmd_batch_fn_stripes, build_wmd_fn,
                                    pad_query, pad_query_batch,
                                    shard_wmd_inputs)
# one copy of the pow2 bucket-rounding rule for the whole serving layer:
# the coalescer's admission buckets must match the service's Q padding
from repro.serving.coalescer import _next_pow2


def _serialized(fn):
    """Serialize an engine entry point on the service's reentrant lock.

    The engine is stateful (last_batch_stats; the K cache mutates a host
    slot map and donates its device ring buffers), so concurrent callers --
    several `async_service` dispatcher threads, or `warm()` on a client
    thread while a dispatcher is live -- must take turns. Reentrant because
    query_batch routes singletons through query."""
    @functools.wraps(fn)
    def wrapper(self, *args, **kwargs):
        with self._engine_lock:
            return fn(self, *args, **kwargs)
    return wrapper


# sentinel: "use the service's docs_chunk" (None already means unchunked)
_UNSET = object()


@dataclasses.dataclass
class WMDService:
    mesh: jax.sharding.Mesh
    cfg: wmd_cfg.WMDConfig
    vecs: np.ndarray
    ell: formats.EllDocs | None = None
    impl: str = "fused"
    docs_chunk: int | None = None
    tol: float = 0.0
    cache_capacity: int = 0
    cache_rows_bucket: int = 128
    kexp_impl: str = "jnp"
    prune_chunk: int = 64
    prune_margin: float = 1e-3
    bound_impl: str = "fused"
    bound_docs_chunk: int | None = 256
    mcache_capacity: int = 0
    tier0: bool = True
    lc_impl: str | None = "fused"
    tier2_cap: int | None = None
    guards: bool = True
    live: object | None = None          # data.live_corpus.LiveCorpus
    metrics: object | None = None       # repro.obs.MetricsRegistry

    @classmethod
    def from_live(cls, mesh, cfg, vecs, live, **kw) -> "WMDService":
        """Build a service over a mutable `data.live_corpus.LiveCorpus`.

        The corpus's base segment becomes the service ELL; a delta segment
        (and the tombstone gather map) is refreshed lazily before every
        live dispatch (`_refresh_live`). ``add_docs`` / ``remove_docs`` /
        ``compact`` then mutate the corpus through the service under the
        engine lock."""
        return cls(mesh=mesh, cfg=cfg, vecs=vecs, live=live, **kw)

    def __post_init__(self):
        if self.live is not None:
            # the base segment IS the service corpus; ell, if also passed,
            # is ignored in favor of the live corpus's current base
            self.ell = self.live.base_ell
        if self.ell is None:
            raise ValueError("WMDService needs either ell= or live=")
        model_size = self.mesh.shape["model"]
        self._rb = formats.rebucket_for_vocab_shards(self.ell, model_size)
        self._doc_axes = tuple(a for a in ("pod", "data")
                               if a in self.mesh.axis_names)
        self._fns: dict[tuple, object] = {}
        self._batch_fns: dict[tuple, object] = {}
        self._stripe_fns: dict[tuple, object] = {}
        self._vecs_d, self._cols_d, self._vals_d = shard_wmd_inputs(
            self.mesh, self.vecs, self._rb.cols, self._rb.vals,
            doc_axes=self._doc_axes)
        if self.metrics is None:
            # every service owns a registry: it is the single backing
            # store scrape/export read, and async_service shares it with
            # the coalescer so the whole stack lands in one namespace
            from repro.obs.metrics import MetricsRegistry
            self.metrics = MetricsRegistry()
        self._kcache = KCache(self.cache_capacity, self._vecs_d,
                              self.cfg.lamb, mesh=self.mesh,
                              rows_bucket=self.cache_rows_bucket,
                              kexp_impl=self.kexp_impl,
                              metrics=self.metrics)
        # M-row cache for the bound tiers: same LRU machinery, rows keyed
        # by word_id alone (no lambda), replicated like the bound ELL. Its
        # transient path IS assemble_m_stripes, so capacity 0 (the default)
        # changes nothing but the amortization.
        self._mcache = MCache(self.mcache_capacity, self._vecs_d,
                              rows_bucket=self.cache_rows_bucket,
                              metrics=self.metrics)
        # any pruned dispatch that silently degrades to an exact full scan
        # must be countable, not just visible in last_prune_stats
        self._prune_fallbacks = self.metrics.counter(
            "wmd_prune_fallback_total",
            "pruned top-k dispatches that fell back to the exact full scan")
        # prefilter state: the bound runs replicated on the ORIGINAL
        # (un-rebucketed) ELL -- the min over a doc's words needs the doc's
        # whole support, which vocab re-bucketing splits across shards
        self._ell_cols_d = jnp.asarray(self.ell.cols)
        self._ell_vals_d = jnp.asarray(self.ell.vals)
        self._b2 = jnp.sum(self._vecs_d * self._vecs_d, axis=-1)
        self._doc_shards = 1
        for a in self._doc_axes:
            self._doc_shards *= self.mesh.shape[a]
        # rerank chunks are placed like the corpus ELL, so the chunk must
        # divide across the doc shards
        self._rerank_chunk = -(-max(self.prune_chunk, 1)
                               // self._doc_shards) * self._doc_shards
        self._rerank_spec = NamedSharding(
            self.mesh, P("model", tuple(self._doc_axes), None))
        # numeric-guard state: the a-priori underflow gate needs the
        # largest embedding norm (cost bound 2*max||v||); docs with zero
        # total mass legitimately solve to distance 0 and are exempt from
        # the armed-gate zero-cell check
        self._max_vec_norm = float(np.sqrt(
            (self.vecs.astype(np.float64) ** 2).sum(axis=-1).max())) \
            if self.vecs.size else 0.0
        self._empty_doc_mask = np.asarray(self.ell.vals.sum(axis=-1) == 0)
        # tier-0 moments (per-doc mass-weighted vector sum + mass) are a
        # pure function of the corpus ELL: computed lazily on the first
        # pruned dispatch, dropped whenever the base segment changes
        self._cent: tuple | None = None
        self.last_batch_stats: dict = {}
        self.last_prune_stats: dict = {}
        self._engine_lock = threading.RLock()   # see _serialized
        # live async front-ends (async_service); weak so a shut-down
        # coalescer the caller dropped doesn't accumulate on the service
        self._coalescers: weakref.WeakSet = weakref.WeakSet()
        # live-corpus device state (refreshed lazily; see _refresh_live).
        # base state was just built from live.base_ell above, so only the
        # delta/gather state starts stale.
        self._live_base_version = (self.live.base_version
                                   if self.live is not None else -1)
        self._live_version = -1
        if self.live is not None and self.live.metrics is None:
            # arm the corpus's compaction lock-hold histogram on this
            # service's registry (late-bindable, like its tracer)
            self.live.metrics = self.metrics

    def async_service(self, **kw):
        """Async admission front-end: a `serving.coalescer.QueryCoalescer`
        whose dispatcher feeds this service's `query_batch` (thread-safe
        ``submit(r) -> Future``, micro-batching by fill/window/deadline --
        see the coalescer module docstring for knobs). Usable as a context
        manager (shutdown-with-drain on exit); `drain_async` flushes every
        front-end this service has handed out."""
        from repro.serving.coalescer import QueryCoalescer
        co = QueryCoalescer(self, **kw)
        self._coalescers.add(co)
        return co

    def drain_async(self, timeout: float | None = None) -> None:
        """Drain hook: block until every live `async_service` front-end has
        an empty queue and no in-flight batch (coalescers stay open)."""
        for co in list(self._coalescers):
            co.drain(timeout=timeout)

    # -- live corpus (mutable base + delta segments) ----------------------
    #
    # With ``live`` set, every dispatch runs per-SEGMENT: the same stripes
    # program solves the base and delta ELLs (corpus cols/vals are runtime
    # arguments, so one compiled fn serves both shapes whenever their
    # capacities match, and at most two shapes otherwise), and the results
    # are gathered into ascending-doc-id order through the corpus's
    # (segment, row) location map. Tombstoned/pad rows are solved but never
    # gathered -- pad-slot inertness makes them free of side effects -- so
    # per-doc distances are bitwise identical to a one-shot build of the
    # same logical docs (the incremental == batch contract, pinned by the
    # golden table's live_* routes and the ingest chaos suite).
    #
    # K-cache scoping: cached K rows are functions of (word_id, lambda,
    # vecs) ONLY -- no row depends on which documents exist -- so corpus
    # mutation invalidates NOTHING (the correctly-scoped invalidation set
    # for a corpus mutation is empty; tests pin that resident rows survive
    # add/remove/compact and still hit). Embedding updates are the event
    # that poisons rows by word-id; `invalidate_embedding_rows` is that
    # scoped hook (`core.kcache.KCache.invalidate_ids`). The RWMD bound
    # tier needs no invalidation either: bounds are recomputed per call
    # against the current segment ELLs.

    def _require_live(self):
        if self.live is None:
            raise ValueError("this WMDService has no live corpus "
                             "(construct with WMDService.from_live)")

    def _refresh_live(self) -> None:
        """Sync device state with the corpus (cheap when nothing changed).

        base_version bump (a compaction swapped segments): rebuild the
        rebucketed base, its sharded device arrays and the bound tier's
        replicated ELL. version bump (any mutation): re-place the delta
        segment and rebuild the gather map. Versions are read under the
        engine lock, which every mutating service entry point also holds --
        and under the CORPUS lock (reentrant), because `LiveCorpus.compact`
        builds outside its lock and swaps under it: without the corpus
        lock, the version reads, the base_ell read and the locations()
        read here could straddle a concurrent swap and mix segments."""
        lc = self.live
        with lc._lock:
            self._refresh_live_locked(lc)

    def _refresh_live_locked(self, lc) -> None:
        if lc.base_version != self._live_base_version:
            self.ell = lc.base_ell
            model_size = self.mesh.shape["model"]
            self._rb = formats.rebucket_for_vocab_shards(self.ell,
                                                         model_size)
            _, self._cols_d, self._vals_d = shard_wmd_inputs(
                self.mesh, self.vecs, self._rb.cols, self._rb.vals,
                doc_axes=self._doc_axes)
            self._ell_cols_d = jnp.asarray(self.ell.cols)
            self._ell_vals_d = jnp.asarray(self.ell.vals)
            self._empty_doc_mask = np.asarray(
                self.ell.vals.sum(axis=-1) == 0)
            self._cent = None                # tier-0 moments follow the base
            self._live_base_version = lc.base_version
            self._live_version = -1          # gather map must follow
        if lc.version != self._live_version:
            d_ell = lc.delta_ell
            drb = formats.rebucket_for_vocab_shards(
                d_ell, self.mesh.shape["model"])
            self._dcols_d = jax.device_put(drb.cols, self._rerank_spec)
            self._dvals_d = jax.device_put(drb.vals, self._rerank_spec)
            self._dell_cols_d = jnp.asarray(d_ell.cols)
            self._dell_vals_d = jnp.asarray(d_ell.vals)
            ids, seg, row = lc.locations()
            self._live_ids = ids
            self._live_seg = seg
            self._live_row = row
            self._live_empty = lc.live_empty_mask()
            self._live_version = lc.version

    @_serialized
    def _query_batch_live(self, rs: Sequence[np.ndarray],
                          impl: str | None = None,
                          use_cache: bool | None = None) -> np.ndarray:
        """(Q, num_live) exact distances over the live corpus, columns in
        ascending doc-id order. One K-cache stripes assembly feeds one
        stripes dispatch per non-empty segment; a segment holding no live
        doc is skipped outright. docs_chunk is forced to None -- segments
        are capacity-bounded, and per-doc bits are chunking-independent
        anyway, so one unchunked program per segment is the simplest
        correct plan."""
        self._refresh_live()
        n_live = self._live_ids.size
        q = len(rs)
        if q == 0 or n_live == 0:
            self.last_batch_stats = {}
            return np.zeros((q, n_live), np.float32)
        self._validate_queries(rs)
        sel_b, r_b, mask_b = self._padded_query_batch(rs)
        self._kcache.ensure_lamb(self.cfg.lamb)
        use = use_cache is not False
        t0 = time.perf_counter()
        k_s, km_s, info = self._kcache.stripes_for_batch(sel_b, mask_b,
                                                         use_cache=use)
        jax.block_until_ready((k_s, km_s))
        t_pre = time.perf_counter() - t0
        self._check_km(km_s, mask_b)
        fn = self._stripe_fn(impl or self.impl, None)
        r_d = jnp.asarray(r_b)
        out = np.empty((q, n_live), np.float32)
        segments = 0
        t0 = time.perf_counter()
        for seg_id, (cols_d, vals_d) in enumerate(
                ((self._cols_d, self._vals_d),
                 (self._dcols_d, self._dvals_d))):
            pick = self._live_seg == seg_id
            if not pick.any():
                continue
            d_seg = np.asarray(fn(k_s, km_s, r_d, cols_d, vals_d))[:q]
            out[:, pick] = d_seg[:, self._live_row[pick]]
            segments += 1
        t_solve = time.perf_counter() - t0
        self.last_batch_stats = {"precompute_s": t_pre, "solve_s": t_solve,
                                 "segments": segments, **info}
        self._check_result(out, what="live query_batch distances",
                           empty_doc_mask=self._live_empty)
        return out

    def _bounds_live(self, rs: Sequence[np.ndarray]) -> np.ndarray:
        """(Q, num_live) RWMD lower bounds over the live corpus: one M-row
        assembly, one prefilter program per non-empty segment, the same
        ascending-id gather as the exact path."""
        self._refresh_live()
        n_live = self._live_ids.size
        q = len(rs)
        if q == 0 or n_live == 0:
            return np.zeros((q, n_live), np.float32)
        self._validate_queries(rs)
        sel_b, r_b, mask_b = self._padded_query_batch(rs)
        m_pad, _ = self._mcache.m_stripes_for_batch(sel_b, mask_b)
        out = np.empty((q, n_live), np.float32)
        for seg_id, (cols_d, vals_d) in enumerate(
                ((self._ell_cols_d, self._ell_vals_d),
                 (self._dell_cols_d, self._dell_vals_d))):
            pick = self._live_seg == seg_id
            if not pick.any():
                continue
            lb = np.asarray(rwmd_core.rwmd_bound_batch(
                m_pad, cols_d, vals_d, impl=self.bound_impl,
                docs_chunk=None))[:q]
            out[:, pick] = lb[:, self._live_row[pick]]
        return out

    @property
    def live_doc_ids(self) -> np.ndarray:
        """Ascending doc ids of the live corpus -- result column j of a
        live dispatch scores the doc ``live_doc_ids[j]`` (and live top-k
        returns these ids, not positions)."""
        self._require_live()
        with self._engine_lock:
            self._refresh_live()
            return self._live_ids

    @_serialized
    def add_docs(self, ids, docs) -> int:
        """Durable live upsert (see `data.live_corpus.LiveCorpus.add_docs`;
        the return acknowledges WAL-fsynced docs). Device state refreshes
        lazily at the next dispatch; the K cache is deliberately NOT
        invalidated -- see the section comment above."""
        self._require_live()
        return self.live.add_docs(ids, docs)

    @_serialized
    def remove_docs(self, ids) -> int:
        """Durable live remove; returns how many ids were actually live."""
        self._require_live()
        return self.live.remove_docs(ids)

    @_serialized
    def compact(self) -> None:
        """Run one interruptible corpus compaction (base <- base + delta,
        atomic swap); the next dispatch picks up the new base segment."""
        self._require_live()
        self.live.compact()

    @_serialized
    def invalidate_embedding_rows(self, word_ids) -> int:
        """Scoped cache invalidation for *embedding* updates: drops exactly
        the rows of ``word_ids`` from BOTH row stores (the K/KM cache and
        the bound tiers' M-row cache -- an M row is a pure function of
        (word_id, vecs) too). Returns the total rows dropped across the two
        stores. Corpus mutations never need this -- rows don't depend on
        docs."""
        return (self._kcache.invalidate_ids(word_ids)
                + self._mcache.invalidate_ids(word_ids))

    # -- numeric guards ---------------------------------------------------

    def _underflow_risk(self) -> bool:
        """Is the lambda-underflow post-check armed for the current lambda?
        Recomputed per call (cfg.lamb is mutable, see ensure_lamb); False
        at every shipped config so the zero-cell check costs nothing."""
        return self.guards and _guards.underflow_possible(
            self.cfg.lamb, self._max_vec_norm)

    def _validate_queries(self, rs) -> None:
        if not self.guards:
            return
        v = self.vecs.shape[0]
        for i, r in enumerate(rs):
            try:
                _guards.validate_query(r, v)
            except _guards.InvalidQueryError as e:
                e.context["query_index"] = i
                raise

    def _check_km(self, km_s, mask_b) -> None:
        """Lambda-underflow pre-check on assembled K*M stripes; the big
        reduction runs on device so only (Q, v_r) scalars come to host."""
        if not self.guards:
            return
        rowmax = np.asarray(jnp.max(jnp.abs(km_s), axis=(0, -1)))
        _guards.check_km_rows(rowmax, mask_b, lamb=self.cfg.lamb)

    def _check_result(self, d, *, what: str,
                      empty_doc_mask: np.ndarray | None = None) -> None:
        if not self.guards:
            return
        if empty_doc_mask is None:
            empty_doc_mask = self._empty_doc_mask
        _guards.check_distances(d, lamb=self.cfg.lamb,
                                risk=self._underflow_risk(),
                                empty_doc_mask=empty_doc_mask, what=what)

    @property
    def cache_stats(self):
        """Cumulative cross-query cache counters (`core.kcache.KCacheStats`)."""
        return self._kcache.stats

    @property
    def cache_resident(self) -> int:
        """Word-id rows currently resident in the cross-query cache."""
        return self._kcache.resident

    @property
    def mcache_stats(self):
        """Cumulative M-row cache counters (`core.kcache.KCacheStats`)."""
        return self._mcache.stats

    @property
    def mcache_resident(self) -> int:
        """M rows currently resident in the bound tiers' row cache."""
        return self._mcache.resident

    def _single_fn(self):
        """Per-query solver, keyed by lamb so a mutated cfg.lamb can't serve
        a stale program (lamb is baked into the jitted fn -- the same reason
        `_batch_fn` keys on it and the cache re-keys via `ensure_lamb`)."""
        key = (self.cfg.lamb,)
        fn = self._fns.get(key)
        if fn is None:
            fn = build_wmd_fn(self.mesh, lamb=self.cfg.lamb,
                              max_iter=self.cfg.max_iter,
                              doc_axes=self._doc_axes)
            self._fns[key] = fn
        return fn

    def _batch_fn(self, impl: str, docs_chunk: int | None):
        """Single-program batched solver (precompute fused into the device
        program) -- the engine `query_batch` runs when the cross-query cache
        is disabled; the cache routes through `_stripe_fn` instead. tol and
        lamb are part of the key so mutating svc.tol / svc.cfg.lamb can't
        serve a stale solver."""
        key = (impl, docs_chunk, self.tol, self.cfg.lamb)
        fn = self._batch_fns.get(key)
        if fn is None:
            fn = build_wmd_batch_fn(self.mesh, lamb=self.cfg.lamb,
                                    max_iter=self.cfg.max_iter,
                                    doc_axes=self._doc_axes, impl=impl,
                                    docs_chunk=docs_chunk,
                                    tol=self.tol)
            self._batch_fns[key] = fn
        return fn

    def _stripe_fn(self, impl: str, docs_chunk: int | None):
        """Batched solver on cache-assembled stripes, built once per
        (impl, docs_chunk, tol) -- same caching contract as `_batch_fn`."""
        key = (impl, docs_chunk, self.tol)
        fn = self._stripe_fns.get(key)
        if fn is None:
            fn = build_wmd_batch_fn_stripes(
                self.mesh, max_iter=self.cfg.max_iter,
                doc_axes=self._doc_axes, impl=impl, docs_chunk=docs_chunk,
                tol=self.tol)
            self._stripe_fns[key] = fn
        return fn

    @_serialized
    def query(self, r: np.ndarray) -> np.ndarray:
        """r: (V,) sparse query histogram -> (N,) distances (num_live
        columns in ascending doc-id order on a live service)."""
        if self.live is not None:
            return self._query_batch_live([r])[0]
        self._validate_queries([r])
        sel_idx, r_sel = select_query(r)
        sel_p, r_p, mask = pad_query(sel_idx, r_sel, self.cfg.v_r)
        wmd = self._single_fn()(jnp.asarray(self.vecs[sel_p]),
                                jnp.asarray(r_p), jnp.asarray(mask),
                                self._vecs_d, self._cols_d, self._vals_d)
        wmd = np.asarray(wmd)
        self._check_result(wmd, what="query distances")
        return wmd

    @_serialized
    def query_batch(self, rs: Sequence[np.ndarray],
                    impl: str | None = None,
                    docs_chunk=_UNSET,
                    use_cache: bool | None = None) -> np.ndarray:
        """Multiple queries -> (Q, N) via the batched (Q, v_r, N) engine.

        With the cache enabled, the precompute phase dedups word-ids across
        the whole batch and computes only rows missing from the cross-query
        cache; cache-less services run the legacy fused-precompute program.
        The solve runs one ELL gather and one psum per Sinkhorn iteration
        for the whole batch either way. Q is rounded up to a power of two
        (retrace bound), with the filler slots masked to contribute exactly
        zero. ``impl`` / ``docs_chunk`` override the service defaults for
        this call (pass docs_chunk=0 for explicitly unchunked);
        ``use_cache`` overrides the engine routing (False = transient
        stripes baseline, bitwise identical to the cached path; True =
        stripes engine even with the cache disabled). Built fns are cached
        per (impl, docs_chunk).

        Live services route every call through the per-segment dispatch
        (`_query_batch_live`; docs_chunk is forced unchunked there) --
        (Q, num_live) columns in ascending doc-id order, bitwise identical
        to a one-shot build of the same docs.
        """
        if self.live is not None:
            return self._query_batch_live(rs, impl=impl,
                                          use_cache=use_cache)
        if len(rs) == 0:
            return np.zeros((0, self.ell.num_docs), np.float32)
        self._validate_queries(rs)
        # under an armed underflow gate every dispatch routes through the
        # stripes engine so the K*M pre-check (`core.guards.check_km_rows`)
        # sees the assembled rows; off at every shipped lambda, so the
        # fast-path routing below is untouched in production
        risk = self._underflow_risk()
        if (len(rs) == 1 and impl is None and docs_chunk is _UNSET
                and self.impl == "fused" and self.tol == 0.0
                and self.cache_capacity == 0 and not risk):
            # admission policy: a singleton is *slower* batched than
            # sequential (0.96x in BENCH_query_batch.json -- the (Q, v_r, N)
            # precompute/padding overhead has nothing to amortize), so route
            # Q = 1 to the per-query program. Taken only when the sequential
            # path implements the configured engine: an explicit per-call
            # override, a non-fused service impl, or early-exit tol all
            # bypass it (the sequential program is fused fixed-iteration),
            # and so does an enabled cache (singletons should hit and warm
            # the row store). A service-level docs_chunk does NOT bypass --
            # chunking is result-identical and the sequential route is the
            # faster singleton plan either way.
            # no stripes phase split for this route, but the call must not
            # vanish from attribution: report total solve wall time with an
            # explicit phases_separable=False marker
            t0 = time.perf_counter()
            out = self.query_batch_sequential(rs)
            self.last_batch_stats = {
                "solve_s": time.perf_counter() - t0,
                "phases_separable": False, "route": "sequential"}
            return out
        sel_b, r_b, mask_b = self._padded_query_batch(rs)
        q = len(rs)
        dc = self.docs_chunk if docs_chunk is _UNSET else (docs_chunk or None)
        if use_cache is None and self.cache_capacity == 0 and not risk:
            # cache disabled and no explicit routing request: the legacy
            # single-program engine (precompute fused into the solve) is the
            # faster plan -- the split stripes path pays an extra dispatch
            # that only the cache can win back. Pass use_cache=True/False to
            # route a cache-less service through the stripes engine anyway
            # (e.g. for the bench's phase split).
            fn = self._batch_fn(impl or self.impl, dc)
            # precompute is fused into the solve program here, so the
            # phases are not separable -- still report the total wall time
            # instead of silently dropping the call from attribution
            t0 = time.perf_counter()
            wmd = fn(jnp.asarray(self.vecs[sel_b]), jnp.asarray(r_b),
                     jnp.asarray(mask_b), self._vecs_d, self._cols_d,
                     self._vals_d)
            wmd = np.asarray(wmd)[:q]
            self.last_batch_stats = {
                "solve_s": time.perf_counter() - t0,
                "phases_separable": False, "route": "legacy_fused"}
            self._check_result(wmd, what="query_batch distances")
            return wmd
        fn = self._stripe_fn(impl or self.impl, dc)
        self._kcache.ensure_lamb(self.cfg.lamb)   # lambda-invalidation
        use = use_cache is not False              # False = transient baseline
        t0 = time.perf_counter()
        k_s, km_s, info = self._kcache.stripes_for_batch(sel_b, mask_b,
                                                         use_cache=use)
        jax.block_until_ready((k_s, km_s))
        t_pre = time.perf_counter() - t0
        self._check_km(km_s, mask_b)
        t0 = time.perf_counter()
        wmd = np.asarray(fn(k_s, km_s, jnp.asarray(r_b),
                            self._cols_d, self._vals_d))[:q]
        t_solve = time.perf_counter() - t0
        self.last_batch_stats = {"precompute_s": t_pre, "solve_s": t_solve,
                                 **info}
        self._check_result(wmd, what="query_batch distances")
        return wmd

    def query_batch_sequential(self, rs: Sequence[np.ndarray]) -> np.ndarray:
        """Per-query dispatch loop -- the oracle/baseline for query_batch."""
        return np.stack([self.query(r) for r in rs])

    def _padded_query_batch(self, rs: Sequence[np.ndarray]):
        """Select + bucket-pad queries and append pow2 admission filler.

        Filler queries are all-pad (mask == 0 everywhere): their stripe
        rows are zeroed (K path) resp. +inf (M path), so they solve to 0 /
        bound to 0 and are sliced off. Returns (sel_b, r_b, mask_b), each
        (Q_pow2, v_r)."""
        sels, rsels = zip(*[select_query(r) for r in rs])
        sel_b, r_b, mask_b = pad_query_batch(sels, rsels, self.cfg.v_r)
        q_pad = _next_pow2(len(rs)) - len(rs)
        if q_pad:
            sel_b = np.concatenate(
                [sel_b, np.zeros((q_pad, self.cfg.v_r), sel_b.dtype)])
            r_b = np.concatenate(
                [r_b, np.ones((q_pad, self.cfg.v_r), r_b.dtype)])
            mask_b = np.concatenate(
                [mask_b, np.zeros((q_pad, self.cfg.v_r), mask_b.dtype)])
        return sel_b, r_b, mask_b

    @staticmethod
    def _top_k(d: np.ndarray, k: int) -> np.ndarray:
        """Indices of the k smallest distances, ordered by (distance,
        doc id): argpartition (O(N)) + an O(N) tie sweep + a local sort of
        k (O(k log k)) instead of a full O(N log N) argsort.

        Ties at the k-th value are broken by the smallest doc id --
        argpartition's internal tie placement is arbitrary, and a
        deterministic selection rule is what lets every route (full scan,
        exhaustive chunked scan, pruned) return the *identical* set even
        when the corpus contains duplicate docs. (On a live corpus the
        positions are ascending-id order, so position ties ARE id ties.)"""
        k = min(k, d.shape[-1])
        if k <= 0:                 # empty live corpus: (Q, 0) selections
            return np.zeros((*d.shape[:-1], 0), np.int64)
        flat = d.reshape(-1, d.shape[-1])
        out = np.empty((flat.shape[0], k), np.int64)
        for i, row in enumerate(flat):
            kth = np.partition(row, k - 1)[k - 1]
            below = np.nonzero(row < kth)[0]           # <= k - 1 of these
            ties = np.nonzero(row == kth)[0][:k - below.size]
            idx = np.concatenate([below, ties])
            out[i] = idx[np.lexsort((idx, row[idx]))]
        return out.reshape(*d.shape[:-1], k)

    def top_k(self, r: np.ndarray, k: int = 10, *, prune: bool = False,
              **kw) -> tuple[np.ndarray, np.ndarray]:
        """Nearest-k docs for one query. ``prune=True`` routes through the
        two-tier pruned engine (see `top_k_batch`)."""
        if prune:
            idx, dist = self.top_k_batch([r], k, prune=True, **kw)
            return idx[0], dist[0]
        d = self.query(r)
        idx = self._top_k(d, k)
        dist = d[idx]
        if self.live is not None and idx.size:
            idx = self._live_ids[idx]      # positions -> real doc ids
        return idx, dist

    def top_k_batch(self, rs: Sequence[np.ndarray], k: int = 10, *,
                    prune: bool = False, rerank: str = "per_query",
                    **kw) -> tuple[np.ndarray, np.ndarray]:
        """Batched nearest-k: (Q, k) doc ids + distances.

        Default: `query_batch` (one device program for all Q x N solves)
        followed by the tie-deterministic selection; ``**kw`` forwards
        impl / docs_chunk / use_cache. With ``prune=True`` the two-tier
        engine runs instead -- RWMD prefilter over all N docs, exact
        Sinkhorn rerank only on the candidate prefix -- and returns the
        bitwise-identical set as `top_k_scan_batch` while skipping the
        pruned docs' solves (stats in ``last_prune_stats``). ``**kw`` then
        forwards impl / use_cache / prune_chunk / prune_margin.

        ``rerank`` picks the pruned rerank strategy: ``"per_query"`` (the
        online default -- each query visits its own candidate blocks with
        (1, chunk) programs) or ``"union"`` (the offline bulk strategy --
        all Q queries rerank shared candidate blocks with ONE (Q, chunk)
        program per block, so correlated batches pay ~1/Q the program
        dispatches). Both return the bitwise-identical set: every solved
        (query, doc) distance comes from the same fixed-shape program
        family, and both prune only docs provably outside the top-k (see
        `_top_k_union`).

        Live services return REAL doc ids (ascending-id positions mapped
        through `live_doc_ids`), and ``prune=True`` runs the cascade over
        the immutable base segment while exact-solving the small delta
        outright (`_top_k_live_pruned`) -- same bits as the full scan,
        most of its speedup. Only ``rerank="union"`` still degrades to the
        exact full scan (`_top_k_live_fallback`, counted by the
        ``wmd_prune_fallback_total`` metric): the answer is identical by
        the pruned == scan contract, only the speedup is forfeited."""
        if rerank not in ("per_query", "union"):
            raise ValueError(f"rerank must be per_query|union, "
                             f"got {rerank!r}")
        if rerank == "union" and not prune:
            raise ValueError("rerank='union' is a pruned-rerank strategy; "
                             "pass prune=True")
        if prune:
            if self.live is not None:
                if rerank == "union":
                    return self._top_k_live_fallback(rs, k, **kw)
                return self._top_k_live_pruned(rs, k, exhaustive=False,
                                               **kw)
            if rerank == "union":
                return self._top_k_union(rs, k, **kw)
            return self._top_k_pruned(rs, k, exhaustive=False, **kw)
        d = self.query_batch(rs, **kw)
        idx = self._top_k(d, k)
        dist = np.take_along_axis(d, idx, axis=-1)
        if self.live is not None and idx.size:
            idx = self._live_ids[idx]      # positions -> real doc ids
        return idx, dist

    def top_k_scan_batch(self, rs: Sequence[np.ndarray], k: int = 10,
                         **kw) -> tuple[np.ndarray, np.ndarray]:
        """The pruned path's exactness oracle: solve EVERY doc through the
        same bound-ordered, fixed-shape chunked rerank programs, then
        select. Bitwise-identical to ``top_k_batch(prune=True)`` by
        construction of the shared prefix (identical programs on identical
        inputs) plus bound soundness for the pruned suffix."""
        if self.live is not None:
            return self._top_k_live_pruned(rs, k, exhaustive=True, **kw)
        return self._top_k_pruned(rs, k, exhaustive=True, **kw)

    @_serialized
    def _top_k_live_fallback(self, rs: Sequence[np.ndarray], k: int, *,
                             impl: str | None = None,
                             use_cache: bool | None = None,
                             prune_chunk: int | None = None,
                             prune_margin: float | None = None
                             ) -> tuple[np.ndarray, np.ndarray]:
        """Pruned-top-k fallback on a live corpus: the exact full scan
        through the per-segment dispatch. The prune knobs are accepted and
        ignored (there is nothing to prune); ``last_prune_stats`` records
        the route and ``wmd_prune_fallback_total`` counts the dispatch so
        callers/benches/dashboards see the forfeited speedup. Since the
        segment-aware pruned path landed, only ``rerank="union"`` (whose
        shared block schedule does not yet span segments) routes here."""
        self._prune_fallbacks.inc()
        t0 = time.perf_counter()
        d = self._query_batch_live(rs, impl=impl, use_cache=use_cache)
        q, n = d.shape
        k_eff = min(k, n)
        idx = self._top_k(d, k_eff)
        dist = np.take_along_axis(d, idx, axis=-1)
        self.last_prune_stats = {
            "queries": q, "docs": n, "k": k_eff, "chunk": 0, "margin": 0.0,
            "exhaustive": True, "rerank": "live_full_scan",
            "exact_solves": q * n, "scan_solves": q * n,
            "solves_avoided": 0.0, "rerank_programs": 0,
            "bound_s": 0.0, "rerank_s": time.perf_counter() - t0,
        }
        ids = self._live_ids[idx] if idx.size else idx
        return ids, dist

    @_serialized
    def _top_k_live_pruned(self, rs: Sequence[np.ndarray], k: int, *,
                           exhaustive: bool, impl: str | None = None,
                           use_cache: bool | None = None,
                           prune_chunk: int | None = None,
                           prune_margin: float | None = None
                           ) -> tuple[np.ndarray, np.ndarray]:
        """Pruned top-k over a live corpus: cascade bounds over the
        immutable base segment, exact-solve the delta outright.

        Per query, the delta segment -- small, capacity-bounded, and the
        only part that mutates between compactions -- is solved whole with
        the same unchunked per-segment program `_query_batch_live`
        dispatches, seeding the running k-th-distance threshold. Live base
        docs are then visited in ascending cascade-bound order through the
        same fixed ``(1, chunk)`` stripes programs as the static pruned
        path, pruning against that threshold. The result is bitwise the
        full-scan answer for the usual three reasons: per-doc distance
        bits are independent of chunk-mates and batch-mates, the K cache
        assembles bit-identical rows either way, and a pruned doc's exact
        distance strictly exceeds the final threshold so it can neither
        enter nor tie into the top-k. ``exhaustive`` disables the drop
        (same programs, same order) -- the live scan oracle."""
        self._refresh_live()
        n_live = self._live_ids.size
        q = len(rs)
        k_eff = min(k, n_live)
        if q == 0 or n_live == 0:
            return (np.zeros((q, k_eff), np.int64),
                    np.zeros((q, k_eff), np.float32))
        self._validate_queries(rs)
        chunk = self._rerank_chunk if prune_chunk is None else \
            -(-max(prune_chunk, 1) // self._doc_shards) * self._doc_shards
        margin = self.prune_margin if prune_margin is None else prune_margin
        sel_b, r_b, mask_b = self._padded_query_batch(rs)
        use = use_cache is not False
        t0 = time.perf_counter()
        combined, tiers = self._cascade_bounds(sel_b, r_b, mask_b,
                                               use_cache=use)
        bounds = combined[:q]               # columns: base-segment rows
        t_bound = time.perf_counter() - t0
        self._kcache.ensure_lamb(self.cfg.lamb)   # lambda-invalidation
        fn = self._stripe_fn(impl or self.impl, None)
        bpos = np.nonzero(self._live_seg == 0)[0]   # live base positions
        dpos = np.nonzero(self._live_seg == 1)[0]   # live delta positions
        brow = self._live_row[bpos]                 # base-segment rows
        idx_out = np.empty((q, k_eff), np.int64)
        d_out = np.empty((q, k_eff), np.float32)
        solves = 0
        programs = 0
        hits = misses = 0
        t0 = time.perf_counter()
        for i in range(q):
            k_s, km_s, info = self._kcache.stripes_for_batch(
                sel_b[i:i + 1], mask_b[i:i + 1], use_cache=use)
            self._check_km(km_s, mask_b[i:i + 1])
            hits += info["hits"]
            misses += info["misses"]
            r_q = jnp.asarray(r_b[i:i + 1])
            solved_d = np.full(n_live, np.inf, np.float32)
            if dpos.size:
                d_seg = np.asarray(fn(k_s, km_s, r_q, self._dcols_d,
                                      self._dvals_d))[0]
                solved_d[dpos] = d_seg[self._live_row[dpos]]
                programs += 1
            n_solved = dpos.size
            threshold = np.inf
            if n_solved >= k_eff:
                cur = self._top_k(solved_d, k_eff)
                threshold = float(solved_d[cur[-1]])
            lb = bounds[i][brow]            # bounds per live base position
            order = np.argsort(lb, kind="stable")
            pos = 0
            while pos < bpos.size:
                block = order[pos:pos + chunk]
                if not exhaustive and n_solved >= k_eff:
                    block = block[lb[block] * (1.0 - margin) <= threshold]
                    if block.size == 0:
                        break
                solved_d[bpos[block]] = self._solve_docs(
                    fn, k_s, km_s, r_q, brow[block], chunk)[0]
                solves += block.size
                programs += 1
                n_solved += block.size
                pos += block.size
                if n_solved >= k_eff:
                    cur = self._top_k(solved_d, k_eff)
                    threshold = float(solved_d[cur[-1]])
            sel = self._top_k(solved_d, k_eff)
            idx_out[i] = sel
            d_out[i] = solved_d[sel]
        t_rerank = time.perf_counter() - t0
        exact = solves + q * int(dpos.size)
        final_thresh = (d_out[:, -1].astype(np.float32) if k_eff
                        else np.full(q, np.inf, np.float32))
        n_base = int(self._ell_cols_d.shape[0])
        self.last_prune_stats = {
            "queries": q, "docs": n_live, "k": k_eff, "chunk": chunk,
            "margin": margin, "exhaustive": exhaustive,
            "rerank": "live_pruned",
            "exact_solves": exact, "scan_solves": q * n_live,
            "solves_avoided": 1.0 - exact / (q * n_live),
            "rerank_programs": programs, "delta_docs": int(dpos.size),
            "bound_s": t_bound, "rerank_s": t_rerank,
            "tiers": self._tier_stats(tiers, final_thresh, q, n_base,
                                      margin),
        }
        self._check_result(d_out, what="top_k distances",
                           empty_doc_mask=self._live_empty[idx_out])
        total = hits + misses
        self.last_batch_stats = {
            "hit_rate": hits / total if total else 0.0,
            "precompute_s": t_bound, "solve_s": t_rerank,
        }
        ids = self._live_ids[idx_out] if idx_out.size else idx_out
        return ids, d_out

    # -- two-tier pruned retrieval ---------------------------------------

    def _bounds_for_batch(self, sel_b: np.ndarray, mask_b: np.ndarray, *,
                          use_cache: bool = True) -> np.ndarray:
        """(Q_pow2, v_r) padded queries -> (Q_pow2, N) RWMD lower bounds.

        One batched prefilter program: word ids deduped across the whole
        batch (the K-cache's dedup pattern), M rows served by the M-row
        cache (transient path == `assemble_m_stripes`, bitwise), one
        min-SDDMM over the replicated corpus ELL. This is the brownout
        tier's bound; the pruned top-k paths use `_cascade_bounds`."""
        m_pad, _ = self._mcache.m_stripes_for_batch(sel_b, mask_b,
                                                    use_cache=use_cache)
        lb = rwmd_core.rwmd_bound_batch(
            m_pad, self._ell_cols_d, self._ell_vals_d,
            impl=self.bound_impl, docs_chunk=self.bound_docs_chunk)
        return np.asarray(lb)

    def _base_centroids(self):
        """Cached tier-0 moments of the current base ELL (lazy; dropped by
        `_refresh_live` when a compaction swaps the base segment)."""
        if self._cent is None:
            self._cent = cascade_core.doc_centroids(
                self._ell_cols_d, self._ell_vals_d, self._vecs_d)
        return self._cent

    def _cascade_bounds(self, sel_b: np.ndarray, r_b: np.ndarray,
                        mask_b: np.ndarray, *, use_cache: bool = True
                        ) -> tuple[np.ndarray, list]:
        """Run the enabled bound tiers over the (base) corpus and compose.

        Returns ``(combined, tiers)``: combined (Q_pow2, N) is the
        elementwise max of every enabled tier's bounds -- a max of lower
        bounds is a lower bound, so the composition is sound tier-by-tier
        and the prune contract (bounds only reorder and skip) is inherited
        unchanged. With every tier disabled the combined bound is all
        zeros: distances are >= 0, so a zero bound never prunes and the
        pruned path degenerates to the exhaustive scan -- same bits, no
        speedup. ``tiers`` carries per-tier (name, bounds, seconds) for
        the post-hoc survivor stats (`_tier_stats`).

        Tier 0 (centroid screen) is one dense (Q, dim) x (dim, N) matmul
        over cached per-doc moments. Tier 1 (LC-RWMD) reduces the M
        stripes to per-vocab-word min-cost vectors once per query, then
        scores every doc with one sparse dot. Tier 2 re-derives the
        doc-side RWMD on the ``tier2_cap`` most-promising docs only (by
        min-over-queries combined bound so every query shares one subset)
        -- numerically it equals tier 1 where both run (the LC hoist is an
        identity), so its role is covering LC-disabled configs and pinning
        the tier-subsumption property; its cost is capped by the subset.
        """
        tiers: list[dict] = []
        n = int(self._ell_cols_d.shape[0])
        qp = sel_b.shape[0]
        combined = np.zeros((qp, n), np.float32)
        if self.tier0:
            t0 = time.perf_counter()
            g, m = self._base_centroids()
            b = np.asarray(cascade_core.centroid_bound_batch(
                jnp.asarray(sel_b), jnp.asarray(r_b), jnp.asarray(mask_b),
                self._vecs_d, g, m))
            tiers.append({"tier": "centroid", "bounds": b,
                          "seconds": time.perf_counter() - t0})
            combined = np.maximum(combined, b)
        need_m = self.lc_impl is not None or self.tier2_cap != 0
        if need_m:
            m_pad, _ = self._mcache.m_stripes_for_batch(
                sel_b, mask_b, use_cache=use_cache)
        if self.lc_impl is not None:
            t0 = time.perf_counter()
            minm = cascade_core.min_cost_vectors(m_pad)
            b = np.asarray(cascade_core.lc_rwmd_bound_batch(
                minm, self._ell_cols_d, self._ell_vals_d,
                impl=self.lc_impl, docs_chunk=self.bound_docs_chunk))
            tiers.append({"tier": "lc_rwmd", "bounds": b,
                          "seconds": time.perf_counter() - t0})
            combined = np.maximum(combined, b)
        t2 = (4 * self._rerank_chunk if self.tier2_cap is None
              else self.tier2_cap)
        t2 = min(t2, n)
        if t2 > 0:
            t0 = time.perf_counter()
            key = combined.min(axis=0)
            subset = np.sort(np.argsort(key, kind="stable")[:t2])
            lb2 = np.asarray(rwmd_core.rwmd_bound_batch(
                m_pad, self._ell_cols_d[subset], self._ell_vals_d[subset],
                impl=self.bound_impl, docs_chunk=None))
            b = np.zeros_like(combined)
            b[:, subset] = lb2
            tiers.append({"tier": "rwmd", "bounds": b,
                          "seconds": time.perf_counter() - t0})
            combined = np.maximum(combined, b)
        return combined, tiers

    @staticmethod
    def _tier_stats(tiers: list, thresholds: np.ndarray, q: int, n: int,
                    margin: float) -> list[dict]:
        """Post-hoc per-tier survivor counts against the FINAL per-query
        thresholds: how many (query, doc) cells each tier's bound alone
        fails to prune (the same ``bound * (1 - margin) <= threshold``
        test the rerank loop applies), plus the cumulative survivors of
        the tiers composed so far -- the cascade's actual funnel."""
        out = []
        cum = None
        for t in tiers:
            b = t["bounds"][:q]
            cum = b if cum is None else np.maximum(cum, b)
            alive = b * (1.0 - margin) <= thresholds[:, None]
            alive_cum = cum * (1.0 - margin) <= thresholds[:, None]
            cells = max(q * n, 1)
            out.append({
                "tier": t["tier"], "seconds": t["seconds"],
                "survivors": int(alive.sum()),
                "solves_avoided": 1.0 - int(alive.sum()) / cells,
                "cascade_survivors": int(alive_cum.sum()),
                "cascade_solves_avoided":
                    1.0 - int(alive_cum.sum()) / cells,
            })
        return out

    def _solve_docs(self, fn, k_s, km_s, r_q, doc_ids: np.ndarray,
                    chunk: int) -> np.ndarray:
        """Exact distances of the stripes batch against a doc subset via
        ONE fixed-shape (Q, chunk) stripes program (Q = 1 on the per-query
        rerank path, the pow2 batch on the union path). Shorter subsets are
        padded with ELL pad docs (every slot the shard-local pad id, val 0
        -> the engine solves them to 0) and sliced off. Per-doc bits are
        independent of the chunk-mates, the position in the chunk, AND the
        Q-mates in the batch (each (q, doc) cell reduces over its own nnz /
        v_r axes only) -- the K-cache's fixed-shape-batch reproducibility
        argument extended across Q, which is what makes pruned == scan ==
        union-reranked a bitwise statement (pinned by tests/test_warmup.py
        and the rwmd property suite)."""
        m = doc_ids.size
        cols = self._rb.cols[:, doc_ids, :]
        vals = self._rb.vals[:, doc_ids, :]
        if m < chunk:
            pad = ((0, 0), (0, chunk - m), (0, 0))
            cols = np.pad(cols, pad, constant_values=self._rb.num_vocab)
            vals = np.pad(vals, pad)
        cols_d = jax.device_put(cols, self._rerank_spec)
        vals_d = jax.device_put(vals, self._rerank_spec)
        d = np.asarray(fn(k_s, km_s, r_q, cols_d, vals_d))
        return d[:, :m]

    @_serialized
    def _top_k_pruned(self, rs: Sequence[np.ndarray], k: int, *,
                      exhaustive: bool, impl: str | None = None,
                      use_cache: bool | None = None,
                      prune_chunk: int | None = None,
                      prune_margin: float | None = None
                      ) -> tuple[np.ndarray, np.ndarray]:
        """Shared core of the pruned top-k and its exhaustive-scan oracle.

        Per query: visit docs in ascending-bound order in fixed ``chunk``
        blocks; solve each block with one (1, chunk) stripes program
        (precompute via the cross-query K cache); once k docs are solved,
        drop every doc whose ``bound * (1 - margin)`` exceeds the running
        k-th exact distance -- ascending order makes the survivors a
        prefix, so the first empty block ends the query. ``exhaustive``
        disables the drop (the oracle solves everything, same programs,
        same order). Docs pruned have exact distance >= bound > threshold
        *strictly*, so they cannot displace or tie any selected doc.
        """
        n = self.ell.num_docs
        k_eff = min(k, n)
        if len(rs) == 0:
            return (np.zeros((0, k_eff), np.int64),
                    np.zeros((0, k_eff), np.float32))
        self._validate_queries(rs)
        chunk = self._rerank_chunk if prune_chunk is None else \
            -(-max(prune_chunk, 1) // self._doc_shards) * self._doc_shards
        margin = self.prune_margin if prune_margin is None else prune_margin
        q = len(rs)
        sel_b, r_b, mask_b = self._padded_query_batch(rs)
        use = use_cache is not False
        t0 = time.perf_counter()
        combined, tiers = self._cascade_bounds(sel_b, r_b, mask_b,
                                               use_cache=use)
        bounds = combined[:q]
        t_bound = time.perf_counter() - t0
        self._kcache.ensure_lamb(self.cfg.lamb)   # lambda-invalidation
        fn = self._stripe_fn(impl or self.impl, None)  # chunk IS the block
        idx_out = np.empty((q, k_eff), np.int64)
        d_out = np.empty((q, k_eff), np.float32)
        solves = 0
        programs = 0
        hits = misses = 0
        t0 = time.perf_counter()
        for i in range(q):
            k_s, km_s, info = self._kcache.stripes_for_batch(
                sel_b[i:i + 1], mask_b[i:i + 1], use_cache=use)
            self._check_km(km_s, mask_b[i:i + 1])
            hits += info["hits"]
            misses += info["misses"]
            r_q = jnp.asarray(r_b[i:i + 1])
            lb = bounds[i]
            order = np.argsort(lb, kind="stable")      # ascending bounds
            solved_d = np.full(n, np.inf, np.float32)
            n_solved = 0
            threshold = np.inf
            pos = 0
            while pos < n:
                block = order[pos:pos + chunk]
                if not exhaustive and n_solved >= k_eff:
                    # bounds ascend within the block, so the survivors are
                    # its prefix; an empty prefix proves every remaining
                    # doc is outside the top-k
                    block = block[lb[block] * (1.0 - margin) <= threshold]
                    if block.size == 0:
                        break
                solved_d[block] = self._solve_docs(fn, k_s, km_s, r_q,
                                                   block, chunk)[0]
                solves += block.size
                programs += 1
                n_solved += block.size
                pos += block.size
                if n_solved >= k_eff:
                    cur = self._top_k(solved_d, k_eff)
                    threshold = float(solved_d[cur[-1]])
            sel = self._top_k(solved_d, k_eff)
            idx_out[i] = sel
            d_out[i] = solved_d[sel]
        t_rerank = time.perf_counter() - t0
        final_thresh = (d_out[:, -1].astype(np.float32) if k_eff
                        else np.full(q, np.inf, np.float32))
        self.last_prune_stats = {
            "queries": q, "docs": n, "k": k_eff, "chunk": chunk,
            "margin": margin, "exhaustive": exhaustive,
            "rerank": "per_query",
            "exact_solves": solves, "scan_solves": q * n,
            "solves_avoided": 1.0 - solves / (q * n),
            "rerank_programs": programs,
            "bound_s": t_bound, "rerank_s": t_rerank,
            "tiers": self._tier_stats(tiers, final_thresh, q, n, margin),
        }
        # underflowed zeros sort first, so the selected top-k surfaces them
        self._check_result(d_out, what="top_k distances",
                           empty_doc_mask=self._empty_doc_mask[idx_out])
        # aggregate cache telemetry so coalesced top-k dispatches feed the
        # same hit-rate passthrough as plain query dispatches
        total = hits + misses
        self.last_batch_stats = {
            "hit_rate": hits / total if total else 0.0,
            "precompute_s": t_bound, "solve_s": t_rerank,
        }
        return idx_out, d_out

    @_serialized
    def _top_k_union(self, rs: Sequence[np.ndarray], k: int, *,
                     impl: str | None = None,
                     use_cache: bool | None = None,
                     prune_chunk: int | None = None,
                     prune_margin: float | None = None
                     ) -> tuple[np.ndarray, np.ndarray]:
        """Union rerank: the offline bulk-scoring strategy for correlated
        query batches -- one (Q, chunk) stripes program per candidate
        block instead of Q separate (1, chunk) programs.

        All Q queries share one block schedule: among docs still *needed*
        by at least one query, visit the lowest min-over-queries bound
        first, and every program solves the block for the whole batch (the
        rows a query did not ask for are free -- the program's cost is set
        by its shape). A doc is needed by query q until q has k exact
        distances and ``bound_q(doc) * (1 - margin) > threshold_q`` (the
        same sound prune test as the per-query path); thresholds only
        tighten and solved counts only grow, so "needed" is monotone
        decreasing and the loop ends at the first round with no needed doc.

        Bitwise identity with the per-query rerank (and hence with
        `top_k_scan_batch`) rests on three facts, each pinned by tests:
        (1) every solved (query, doc) distance is bit-identical across
        program shapes -- the stripes engine's per-cell contractions never
        cross the Q or chunk axes; (2) the K-cache assembles bit-identical
        stripe rows regardless of batch composition; (3) pruning is sound
        and *strict* -- a skipped doc has exact distance > the running
        threshold >= the true k-th distance, so it can neither enter nor
        tie into the top-k, and extra docs the union schedule solves that
        the per-query path pruned change nothing for the same reason.
        """
        n = self.ell.num_docs
        k_eff = min(k, n)
        if len(rs) == 0:
            return (np.zeros((0, k_eff), np.int64),
                    np.zeros((0, k_eff), np.float32))
        self._validate_queries(rs)
        chunk = self._rerank_chunk if prune_chunk is None else \
            -(-max(prune_chunk, 1) // self._doc_shards) * self._doc_shards
        margin = self.prune_margin if prune_margin is None else prune_margin
        q = len(rs)
        sel_b, r_b, mask_b = self._padded_query_batch(rs)
        use = use_cache is not False
        t0 = time.perf_counter()
        combined, tiers = self._cascade_bounds(sel_b, r_b, mask_b,
                                               use_cache=use)
        lb = combined[:q]                                     # (q, N)
        t_bound = time.perf_counter() - t0
        self._kcache.ensure_lamb(self.cfg.lamb)   # lambda-invalidation
        fn = self._stripe_fn(impl or self.impl, None)
        # ONE stripes assembly for the whole batch (vs per-query on the
        # online path) -- rows are bit-reproducible either way
        k_s, km_s, info = self._kcache.stripes_for_batch(sel_b, mask_b,
                                                         use_cache=use)
        self._check_km(km_s, mask_b)
        r_all = jnp.asarray(r_b)                  # (Q_pow2, v_r)
        min_lb = lb.min(axis=0)                   # union visit order key
        solved_d = np.full((q, n), np.inf, np.float32)
        unsolved = np.ones(n, bool)
        thresholds = np.full(q, np.inf, np.float32)
        n_solved = 0
        programs = 0
        t0 = time.perf_counter()
        while True:
            if n_solved >= k_eff:
                need = unsolved & (lb * (1.0 - margin)
                                   <= thresholds[:, None]).any(axis=0)
            else:
                # until every query has k exact distances, every unsolved
                # doc is a candidate (thresholds are still +inf)
                need = unsolved
            cand = np.nonzero(need)[0]
            if cand.size == 0:
                break
            block = cand[np.argsort(min_lb[cand], kind="stable")][:chunk]
            solved_d[:, block] = self._solve_docs(fn, k_s, km_s, r_all,
                                                  block, chunk)[:q]
            unsolved[block] = False
            programs += 1
            n_solved += block.size
            if n_solved >= k_eff:
                for i in range(q):
                    cur = self._top_k(solved_d[i], k_eff)
                    thresholds[i] = solved_d[i][cur[-1]]
        t_rerank = time.perf_counter() - t0
        idx_out = np.empty((q, k_eff), np.int64)
        d_out = np.empty((q, k_eff), np.float32)
        for i in range(q):
            sel = self._top_k(solved_d[i], k_eff)
            idx_out[i] = sel
            d_out[i] = solved_d[i][sel]
        solves = q * (n - int(unsolved.sum()))
        final_thresh = (d_out[:, -1].astype(np.float32) if k_eff
                        else np.full(q, np.inf, np.float32))
        self.last_prune_stats = {
            "queries": q, "docs": n, "k": k_eff, "chunk": chunk,
            "margin": margin, "exhaustive": False,
            "rerank": "union",
            "exact_solves": solves, "scan_solves": q * n,
            "solves_avoided": 1.0 - solves / (q * n),
            "rerank_programs": programs,
            "bound_s": t_bound, "rerank_s": t_rerank,
            "tiers": self._tier_stats(tiers, final_thresh, q, n, margin),
        }
        self.last_batch_stats = {
            "hit_rate": info.get("hit_rate", 0.0),
            "precompute_s": t_bound, "solve_s": t_rerank,
        }
        self._check_result(d_out, what="top_k distances",
                           empty_doc_mask=self._empty_doc_mask[idx_out])
        return idx_out, d_out

    # -- degraded tier: bound-only answers --------------------------------

    @_serialized
    def query_batch_bounds(self, rs: Sequence[np.ndarray]) -> np.ndarray:
        """Degraded tier: (Q, N) doc-side RWMD *lower bounds* instead of
        exact Sinkhorn distances -- the brownout answer.

        One O(nnz * v_r) prefilter program, no Sinkhorn iterations at all:
        orders of magnitude cheaper than `query_batch` and a sound lower
        bound at any budget (see core.rwmd). `serving.resilience` serves
        these (wrapped in `DegradedResult`, never raw) when the engine is
        browned out or every exact rung has failed."""
        if self.live is not None:
            t0 = time.perf_counter()
            lb = self._bounds_live(rs)
            self.last_batch_stats = {
                "precompute_s": time.perf_counter() - t0, "solve_s": 0.0,
                "degraded": True}
            if self.guards and lb.size:
                _guards.check_finite(lb, "rwmd bounds", lamb=self.cfg.lamb)
            return lb
        if len(rs) == 0:
            return np.zeros((0, self.ell.num_docs), np.float32)
        self._validate_queries(rs)
        q = len(rs)
        sel_b, r_b, mask_b = self._padded_query_batch(rs)
        t0 = time.perf_counter()
        lb = self._bounds_for_batch(sel_b, mask_b)[:q]
        t_bound = time.perf_counter() - t0
        self.last_batch_stats = {"precompute_s": t_bound, "solve_s": 0.0,
                                 "degraded": True}
        if self.guards:
            _guards.check_finite(lb, "rwmd bounds", lamb=self.cfg.lamb)
        return lb

    @_serialized
    def top_k_batch_bounds(self, rs: Sequence[np.ndarray], k: int = 10
                           ) -> tuple[np.ndarray, np.ndarray]:
        """Degraded top-k: nearest-k by RWMD bound only (no rerank). Same
        tie-deterministic selection as the exact paths, so a given bound
        matrix always yields the same id set."""
        lb = self.query_batch_bounds(rs)
        k_eff = min(k, lb.shape[-1])
        if len(rs) == 0:
            return (np.zeros((0, k_eff), np.int64),
                    np.zeros((0, k_eff), np.float32))
        idx = self._top_k(lb, k_eff)
        dist = np.take_along_axis(lb, idx, axis=-1)
        if self.live is not None and idx.size:
            idx = self._live_ids[idx]      # positions -> real doc ids
        return idx, dist

    # -- ahead-of-time warmup ---------------------------------------------

    def warmup(self, *, max_batch: int = 16, ks: Sequence[int] = (),
               kinds: Sequence[str] | None = None,
               queries: Sequence[np.ndarray] | None = None,
               seed: int = 0):
        """Precompile the full serving envelope (`serving.warmup`).

        Enumerates every program shape this service can be dispatched --
        pow2 Q buckets up to ``max_batch`` x request kinds ("plain", plus
        "top_k" per k in ``ks``; pass ``kinds`` to add the offline mode's
        "top_k_union") -- and runs one dispatch per shape, so a following
        serving session never meets a first-hit XLA compile. Combine with
        `serving.warmup.enable_compilation_cache` to persist the compiled
        programs across processes. Returns the `WarmupReport` (per-shape
        compile times; hand it to `QueryCoalescer.record_warmup` to
        surface in `ServingStats`)."""
        from repro.serving import warmup as _warmup
        registry = _warmup.ShapeRegistry.from_service(
            self, max_batch=max_batch, ks=ks, kinds=kinds)
        return _warmup.warm(self, registry, queries=queries, seed=seed)
