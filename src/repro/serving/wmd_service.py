"""Batched Sinkhorn-WMD query service (the paper's workload, production-shaped).

Serves "1 query vs N docs" requests against a corpus held sharded on the
mesh: vocab-striped embeddings + rebucketed ELL (loaded once), queries
bucketed by padded v_r (exact mask-based padding, core.distributed), solved
by the fused SDDMM-SpMM engine, one psum per iteration.

This is deliverable (b)'s serving driver: `examples/wmd_query_service.py`
runs it end-to-end; `launch/serve.py` exposes it via --arch sinkhorn-wmd.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import sinkhorn_wmd as wmd_cfg
from repro.core import formats, select_query
from repro.core.distributed import build_wmd_fn, pad_query, shard_wmd_inputs


@dataclasses.dataclass
class WMDService:
    mesh: jax.sharding.Mesh
    cfg: wmd_cfg.WMDConfig
    vecs: np.ndarray
    ell: formats.EllDocs

    def __post_init__(self):
        model_size = self.mesh.shape["model"]
        self._rb = formats.rebucket_for_vocab_shards(self.ell, model_size)
        doc_axes = tuple(a for a in ("pod", "data")
                         if a in self.mesh.axis_names)
        self._fn = build_wmd_fn(self.mesh, lamb=self.cfg.lamb,
                                max_iter=self.cfg.max_iter,
                                doc_axes=doc_axes)
        self._vecs_d, self._cols_d, self._vals_d = shard_wmd_inputs(
            self.mesh, self.vecs, self._rb.cols, self._rb.vals,
            doc_axes=doc_axes)

    def query(self, r: np.ndarray) -> np.ndarray:
        """r: (V,) sparse query histogram -> (N,) distances."""
        sel_idx, r_sel = select_query(r)
        sel_p, r_p, mask = pad_query(sel_idx, r_sel, self.cfg.v_r)
        wmd = self._fn(jnp.asarray(self.vecs[sel_p]), jnp.asarray(r_p),
                       jnp.asarray(mask), self._vecs_d, self._cols_d,
                       self._vals_d)
        return np.asarray(wmd)

    def query_batch(self, rs: Sequence[np.ndarray]) -> np.ndarray:
        """Multiple queries -> (Q, N). Sequential dispatch per query; queries
        share the resident sharded corpus (the expensive part)."""
        return np.stack([self.query(r) for r in rs])

    def top_k(self, r: np.ndarray, k: int = 10) -> tuple[np.ndarray,
                                                         np.ndarray]:
        d = self.query(r)
        idx = np.argsort(d)[:k]
        return idx, d[idx]
