"""Batched Sinkhorn-WMD query service (the paper's workload, production-shaped).

Serves WMD requests against a corpus held sharded on the mesh: vocab-striped
embeddings + rebucketed ELL (loaded once), solved by the fused SDDMM-SpMM
engine with one psum per iteration.

Service API
-----------
  query(r)                  -- one (V,) histogram -> (N,) distances.
  query_batch(rs, impl=...) -- Q histograms -> (Q, N) in ONE device program:
      queries are padded to the service's v_r bucket (exact mask-based
      padding, `core.distributed.pad_query_batch`) and admitted in
      power-of-two Q buckets (bounding retrace count); the batched
      (Q, v_r, N) engine shares a single ELL gather and a single psum per
      Sinkhorn iteration across all Q queries (`build_wmd_batch_fn`).
      Slots added by Q-bucketing carry an all-zero row mask, so they cost
      flops but contribute nothing and are sliced off before returning.
      ``impl`` ("fused" | "unfused" | "kernel") overrides the service
      default per call (built fns are cached per impl).
      Admission policy: Q = 1 routes to the sequential path -- the batched
      engine's (Q, v_r, N) padding/precompute overhead makes a singleton
      *slower* than the per-query program (speedup 0.96x at Q=1 in the
      BENCH_query_batch.json artifact).
  query_batch_sequential(rs) -- the per-query dispatch loop, kept as the
      correctness oracle and the baseline for bench_query_batch.py.
  top_k(r, k)               -- nearest-k doc ids + distances.

Perf knobs (constructor fields, forwarded to `build_wmd_batch_fn`):
  impl       -- default contraction path for query_batch.
  docs_chunk -- cache-block the batched iteration over doc chunks of this
                size; at bulk shapes this keeps the (Q, docs_chunk, nnz,
                v_r) gathered working set cache-resident (see
                core.sparse_sinkhorn "Batched engine & cache blocking").
  tol        -- early-exit tolerance: converged queries freeze, the solve
                stops when all queries converge (0.0 = fixed max_iter).

`examples/wmd_query_service.py` runs it end-to-end; `launch/serve.py`
exposes it via --arch sinkhorn-wmd (add --batch-queries for the batched
path).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import sinkhorn_wmd as wmd_cfg
from repro.core import formats, select_query
from repro.core.distributed import (build_wmd_batch_fn, build_wmd_fn,
                                    pad_query, pad_query_batch,
                                    shard_wmd_inputs)


def _next_pow2(q: int) -> int:
    return 1 << (q - 1).bit_length()


# sentinel: "use the service's docs_chunk" (None already means unchunked)
_UNSET = object()


@dataclasses.dataclass
class WMDService:
    mesh: jax.sharding.Mesh
    cfg: wmd_cfg.WMDConfig
    vecs: np.ndarray
    ell: formats.EllDocs
    impl: str = "fused"
    docs_chunk: int | None = None
    tol: float = 0.0

    def __post_init__(self):
        model_size = self.mesh.shape["model"]
        self._rb = formats.rebucket_for_vocab_shards(self.ell, model_size)
        self._doc_axes = tuple(a for a in ("pod", "data")
                               if a in self.mesh.axis_names)
        self._fn = build_wmd_fn(self.mesh, lamb=self.cfg.lamb,
                                max_iter=self.cfg.max_iter,
                                doc_axes=self._doc_axes)
        self._batch_fns: dict[tuple, object] = {}
        self._vecs_d, self._cols_d, self._vals_d = shard_wmd_inputs(
            self.mesh, self.vecs, self._rb.cols, self._rb.vals,
            doc_axes=self._doc_axes)

    def _batch_fn(self, impl: str, docs_chunk: int | None):
        """Batched solver for (impl, docs_chunk, tol), built once and cached
        -- sweeping chunk sizes (bench_query_batch) shares one service and
        one device-sharded corpus instead of one service per variant. tol is
        part of the key so mutating svc.tol can't serve a stale solver."""
        key = (impl, docs_chunk, self.tol)
        fn = self._batch_fns.get(key)
        if fn is None:
            fn = build_wmd_batch_fn(self.mesh, lamb=self.cfg.lamb,
                                    max_iter=self.cfg.max_iter,
                                    doc_axes=self._doc_axes, impl=impl,
                                    docs_chunk=docs_chunk,
                                    tol=self.tol)
            self._batch_fns[key] = fn
        return fn

    def query(self, r: np.ndarray) -> np.ndarray:
        """r: (V,) sparse query histogram -> (N,) distances."""
        sel_idx, r_sel = select_query(r)
        sel_p, r_p, mask = pad_query(sel_idx, r_sel, self.cfg.v_r)
        wmd = self._fn(jnp.asarray(self.vecs[sel_p]), jnp.asarray(r_p),
                       jnp.asarray(mask), self._vecs_d, self._cols_d,
                       self._vals_d)
        return np.asarray(wmd)

    def query_batch(self, rs: Sequence[np.ndarray],
                    impl: str | None = None,
                    docs_chunk=_UNSET) -> np.ndarray:
        """Multiple queries -> (Q, N) via the batched (Q, v_r, N) engine.

        One ELL gather and one psum per Sinkhorn iteration serve the whole
        batch; Q is rounded up to a power of two (retrace bound), with the
        filler slots masked to contribute exactly zero. ``impl`` /
        ``docs_chunk`` override the service defaults for this call (pass
        docs_chunk=0 for explicitly unchunked); built fns are cached per
        (impl, docs_chunk).
        """
        if len(rs) == 0:
            return np.zeros((0, self.ell.num_docs), np.float32)
        if (len(rs) == 1 and impl is None and docs_chunk is _UNSET
                and self.impl == "fused" and self.tol == 0.0):
            # admission policy: a singleton is *slower* batched than
            # sequential (0.96x in BENCH_query_batch.json -- the (Q, v_r, N)
            # precompute/padding overhead has nothing to amortize), so route
            # Q = 1 to the per-query program. Taken only when the sequential
            # path implements the configured engine: an explicit per-call
            # override, a non-fused service impl, or early-exit tol all
            # bypass it (the sequential program is fused fixed-iteration).
            # A service-level docs_chunk does NOT bypass -- chunking is
            # result-identical and the sequential route is the faster
            # singleton plan either way.
            return self.query_batch_sequential(rs)
        sels, rsels = zip(*[select_query(r) for r in rs])
        sel_b, r_b, mask_b = pad_query_batch(sels, rsels, self.cfg.v_r)
        q = len(rs)
        q_pad = _next_pow2(q) - q
        if q_pad:
            # admission filler: all-pad queries (mask == 0 everywhere) whose
            # rows are zeroed in K, so they solve to 0 and are discarded.
            sel_b = np.concatenate(
                [sel_b, np.zeros((q_pad, self.cfg.v_r), sel_b.dtype)])
            r_b = np.concatenate(
                [r_b, np.ones((q_pad, self.cfg.v_r), r_b.dtype)])
            mask_b = np.concatenate(
                [mask_b, np.zeros((q_pad, self.cfg.v_r), mask_b.dtype)])
        dc = self.docs_chunk if docs_chunk is _UNSET else (docs_chunk or None)
        fn = self._batch_fn(impl or self.impl, dc)
        wmd = fn(jnp.asarray(self.vecs[sel_b]), jnp.asarray(r_b),
                 jnp.asarray(mask_b), self._vecs_d, self._cols_d,
                 self._vals_d)
        return np.asarray(wmd)[:q]

    def query_batch_sequential(self, rs: Sequence[np.ndarray]) -> np.ndarray:
        """Per-query dispatch loop -- the oracle/baseline for query_batch."""
        return np.stack([self.query(r) for r in rs])

    def top_k(self, r: np.ndarray, k: int = 10) -> tuple[np.ndarray,
                                                         np.ndarray]:
        d = self.query(r)
        idx = np.argsort(d)[:k]
        return idx, d[idx]
