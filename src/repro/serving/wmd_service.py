"""Batched Sinkhorn-WMD query service (the paper's workload, production-shaped).

Serves WMD requests against a corpus held sharded on the mesh: vocab-striped
embeddings + rebucketed ELL (loaded once), solved by the fused SDDMM-SpMM
engine with one psum per iteration.

Service API
-----------
  query(r)                  -- one (V,) histogram -> (N,) distances.
  query_batch(rs)           -- Q histograms -> (Q, N) in ONE device program:
      queries are padded to the service's v_r bucket (exact mask-based
      padding, `core.distributed.pad_query_batch`) and admitted in
      power-of-two Q buckets (bounding retrace count); the batched
      (Q, v_r, N) engine shares a single ELL gather and a single psum per
      Sinkhorn iteration across all Q queries (`build_wmd_batch_fn`).
      Slots added by Q-bucketing carry an all-zero row mask, so they cost
      flops but contribute nothing and are sliced off before returning.
  query_batch_sequential(rs) -- the per-query dispatch loop, kept as the
      correctness oracle and the baseline for bench_query_batch.py.
  top_k(r, k)               -- nearest-k doc ids + distances.

`examples/wmd_query_service.py` runs it end-to-end; `launch/serve.py`
exposes it via --arch sinkhorn-wmd (add --batch-queries for the batched
path).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import sinkhorn_wmd as wmd_cfg
from repro.core import formats, select_query
from repro.core.distributed import (build_wmd_batch_fn, build_wmd_fn,
                                    pad_query, pad_query_batch,
                                    shard_wmd_inputs)


def _next_pow2(q: int) -> int:
    return 1 << (q - 1).bit_length()


@dataclasses.dataclass
class WMDService:
    mesh: jax.sharding.Mesh
    cfg: wmd_cfg.WMDConfig
    vecs: np.ndarray
    ell: formats.EllDocs

    def __post_init__(self):
        model_size = self.mesh.shape["model"]
        self._rb = formats.rebucket_for_vocab_shards(self.ell, model_size)
        doc_axes = tuple(a for a in ("pod", "data")
                         if a in self.mesh.axis_names)
        self._fn = build_wmd_fn(self.mesh, lamb=self.cfg.lamb,
                                max_iter=self.cfg.max_iter,
                                doc_axes=doc_axes)
        self._batch_fn = build_wmd_batch_fn(self.mesh, lamb=self.cfg.lamb,
                                            max_iter=self.cfg.max_iter,
                                            doc_axes=doc_axes)
        self._vecs_d, self._cols_d, self._vals_d = shard_wmd_inputs(
            self.mesh, self.vecs, self._rb.cols, self._rb.vals,
            doc_axes=doc_axes)

    def query(self, r: np.ndarray) -> np.ndarray:
        """r: (V,) sparse query histogram -> (N,) distances."""
        sel_idx, r_sel = select_query(r)
        sel_p, r_p, mask = pad_query(sel_idx, r_sel, self.cfg.v_r)
        wmd = self._fn(jnp.asarray(self.vecs[sel_p]), jnp.asarray(r_p),
                       jnp.asarray(mask), self._vecs_d, self._cols_d,
                       self._vals_d)
        return np.asarray(wmd)

    def query_batch(self, rs: Sequence[np.ndarray]) -> np.ndarray:
        """Multiple queries -> (Q, N) via the batched (Q, v_r, N) engine.

        One ELL gather and one psum per Sinkhorn iteration serve the whole
        batch; Q is rounded up to a power of two (retrace bound), with the
        filler slots masked to contribute exactly zero.
        """
        if len(rs) == 0:
            return np.zeros((0, self.ell.num_docs), np.float32)
        sels, rsels = zip(*[select_query(r) for r in rs])
        sel_b, r_b, mask_b = pad_query_batch(sels, rsels, self.cfg.v_r)
        q = len(rs)
        q_pad = _next_pow2(q) - q
        if q_pad:
            # admission filler: all-pad queries (mask == 0 everywhere) whose
            # rows are zeroed in K, so they solve to 0 and are discarded.
            sel_b = np.concatenate(
                [sel_b, np.zeros((q_pad, self.cfg.v_r), sel_b.dtype)])
            r_b = np.concatenate(
                [r_b, np.ones((q_pad, self.cfg.v_r), r_b.dtype)])
            mask_b = np.concatenate(
                [mask_b, np.zeros((q_pad, self.cfg.v_r), mask_b.dtype)])
        wmd = self._batch_fn(jnp.asarray(self.vecs[sel_b]), jnp.asarray(r_b),
                             jnp.asarray(mask_b), self._vecs_d, self._cols_d,
                             self._vals_d)
        return np.asarray(wmd)[:q]

    def query_batch_sequential(self, rs: Sequence[np.ndarray]) -> np.ndarray:
        """Per-query dispatch loop -- the oracle/baseline for query_batch."""
        return np.stack([self.query(r) for r in rs])

    def top_k(self, r: np.ndarray, k: int = 10) -> tuple[np.ndarray,
                                                         np.ndarray]:
        d = self.query(r)
        idx = np.argsort(d)[:k]
        return idx, d[idx]
