"""Offline bulk-scoring mode: stream a query file through the engine at
maximum batch occupancy -- no admission windows, no deadlines, no queue.

The online path (`QueryCoalescer`) optimizes *latency under uncertainty*:
it cuts a batch the moment waiting longer would hurt the oldest request,
so batches are as full as traffic allows. Offline scoring inverts the
contract -- the whole workload is known up front, nobody is waiting on any
single row -- so the right schedule is trivial and maximal: walk the query
list in order, cut every batch at the full ``max_batch`` bucket, and keep
the device at 100% occupancy. This is MLPerf's offline scenario applied to
WMD retrieval, and the bench's *throughput-mode* headline
(`benchmarks/bench_serving.py`) is this driver's qps.

Top-k batches additionally use **union rerank** (``rerank="union"``,
`WMDService._top_k_union`): one (Q, chunk) stripes program per candidate
block for the whole batch instead of Q separate (1, chunk) programs --
exactly the batch-amortization the paper's headline is built on, now
applied to the rerank tier. For correlated queries (the realistic Zipf
workload) the candidate sets overlap heavily, so the union schedule runs
close to 1/Q the programs of the per-query loop.

Bitwise contract (gated by tests/test_warmup.py on a golden query file):

* **top-k** output is bit-identical to the online path on the same queries
  REGARDLESS of batch composition: the rerank tier's fixed-shape stripes
  programs compute each (query, doc) cell over its own nnz/v_r axes only
  (bit-stable across chunk-mates AND Q-mates -- the K-cache's fixed-shape
  reproducibility argument extended across Q), and union rerank prunes
  only docs provably outside the top-k. pruned == scan == union, bitwise.
* **plain** distance rows carry the coalescer's contract: bit-identical
  to a direct ``query_batch`` of the same queries in the same buckets.
  The full-solve program's last bits CAN differ across Q buckets (XLA may
  tile a (1, v_r, N) and an (8, v_r, N) program differently), so the
  online serving stack matches offline exactly when it cuts the same
  compositions -- which a saturating in-order stream does -- and to fp32
  tolerance otherwise. Anything beyond that is a correctness bug, not a
  tuning regression.

CLI: ``launch/serve.py --offline queries.npz [--offline-out out.npz]``.
"""
from __future__ import annotations

import dataclasses
import os
import time
from typing import Sequence

import numpy as np

from repro.serving.coalescer import _next_pow2


def load_query_file(path: str | os.PathLike) -> list[np.ndarray]:
    """Load an offline query workload: a ``.npz`` with a ``queries`` array
    (or a single unnamed array), or a ``.npy`` -- either way an (n, V)
    float matrix of query histograms, returned as n (V,) float32 rows."""
    path = os.fspath(path)
    if path.endswith(".npz"):
        with np.load(path) as z:
            if "queries" in z.files:
                mat = z["queries"]
            elif len(z.files) == 1:
                mat = z[z.files[0]]
            else:
                raise ValueError(
                    f"{path}: expected a 'queries' array, found {z.files}")
    else:
        mat = np.load(path)
    mat = np.asarray(mat, np.float32)
    if mat.ndim != 2:
        raise ValueError(f"{path}: expected (n, V) queries, "
                         f"got shape {mat.shape}")
    return [mat[i] for i in range(mat.shape[0])]


def save_query_file(path: str | os.PathLike,
                    queries: Sequence[np.ndarray]) -> str:
    """Write a query workload in `load_query_file`'s format."""
    path = os.fspath(path)
    mat = np.stack([np.asarray(q, np.float32) for q in queries])
    if path.endswith(".npz"):
        np.savez(path, queries=mat)
    else:
        np.save(path, mat)
    return path


@dataclasses.dataclass
class OfflineResult:
    """Outcome of one offline bulk-scoring run (results in input order)."""
    mode: str                     # "plain" | "top_k"
    n: int                        # queries scored
    batches: int                  # engine dispatches
    max_batch: int                # occupancy target (pow2)
    wall_s: float                 # first dispatch -> last result
    k: int | None
    rerank: str | None            # top-k only: "union" | "per_query"
    dists: np.ndarray | None      # plain: (n, N)
    topk_idx: np.ndarray | None   # top-k: (n, k)
    topk_dist: np.ndarray | None  # top-k: (n, k)
    solves_avoided: float | None  # top-k: pruned fraction, query-weighted
    rerank_programs: int | None   # top-k: total rerank dispatches

    @property
    def throughput_qps(self) -> float:
        return self.n / self.wall_s if self.wall_s else 0.0

    def summary(self) -> dict:
        """JSON-friendly fields for the bench artifact / --offline-out."""
        out = {"mode": self.mode, "n": self.n, "batches": self.batches,
               "max_batch": self.max_batch, "wall_s": self.wall_s,
               "throughput_qps": self.throughput_qps}
        if self.mode == "top_k":
            out.update(k=self.k, rerank=self.rerank,
                       solves_avoided=self.solves_avoided,
                       rerank_programs=self.rerank_programs)
        return out

    def save(self, path: str | os.PathLike) -> str:
        """Persist the scored outputs (npz) next to the summary fields."""
        arrays = {k: v for k, v in
                  (("dists", self.dists), ("topk_idx", self.topk_idx),
                   ("topk_dist", self.topk_dist)) if v is not None}
        np.savez(os.fspath(path), **arrays)
        return os.fspath(path)


def run_offline(svc, queries: Sequence[np.ndarray], *,
                k: int | None = None, max_batch: int = 16,
                rerank: str = "union", impl: str | None = None,
                use_cache: bool | None = None) -> OfflineResult:
    """Score every query at maximum batch occupancy.

    ``k=None`` scores plain distance rows; otherwise pruned top-k with
    ``rerank`` picking the rerank batching ("union" -- the offline
    default -- or "per_query", the online path's strategy, kept callable
    so the bitwise gate can compare both in one process). Queries are
    walked in order and cut into full ``max_batch`` buckets (the final
    partial batch pads like any online dispatch), so results are in input
    order; top-k output is bit-identical to ANY other batching of the
    same queries, plain rows to the same buckets (module docstring)."""
    if rerank not in ("union", "per_query"):
        raise ValueError(f"rerank must be union|per_query, got {rerank!r}")
    qs = list(queries)
    bucket = _next_pow2(max(int(max_batch), 1))
    kw = {}
    if impl is not None:
        kw["impl"] = impl
    if use_cache is not None:
        kw["use_cache"] = use_cache
    rows, idxs, dists = [], [], []
    solves = avoided_w = 0.0
    programs = 0
    batches = 0
    t0 = time.perf_counter()
    for lo in range(0, len(qs), bucket):
        batch = qs[lo:lo + bucket]
        batches += 1
        if k is None:
            rows.append(svc.query_batch(batch, **kw))
        else:
            idx_b, d_b = svc.top_k_batch(batch, k, prune=True,
                                         rerank=rerank, **kw)
            idxs.append(idx_b)
            dists.append(d_b)
            st = getattr(svc, "last_prune_stats", None) or {}
            if "solves_avoided" in st:
                avoided_w += st["solves_avoided"] * len(batch)
                solves += len(batch)
            programs += int(st.get("rerank_programs", 0))
    wall = time.perf_counter() - t0
    if k is None:
        return OfflineResult(
            mode="plain", n=len(qs), batches=batches, max_batch=bucket,
            wall_s=wall, k=None, rerank=None,
            dists=np.concatenate(rows) if rows else
            np.zeros((0, svc.ell.num_docs), np.float32),
            topk_idx=None, topk_dist=None,
            solves_avoided=None, rerank_programs=None)
    k_eff = min(k, svc.ell.num_docs)
    return OfflineResult(
        mode="top_k", n=len(qs), batches=batches, max_batch=bucket,
        wall_s=wall, k=k, rerank=rerank, dists=None,
        topk_idx=np.concatenate(idxs) if idxs else
        np.zeros((0, k_eff), np.int64),
        topk_dist=np.concatenate(dists) if dists else
        np.zeros((0, k_eff), np.float32),
        solves_avoided=(avoided_w / solves) if solves else None,
        rerank_programs=programs)
