"""Serving substrate: sharded prefill/decode, the WMD query service, the
async admission layer (request coalescer + load generators), AOT program
warmup, the offline bulk-scoring driver, and the resilience layer
(circuit breakers, retry, brownout degradation; fault injection lives in
serving.faultinject and is test-only by contract)."""
from repro.serving.coalescer import (CoalescerClosedError, QueryCoalescer,
                                     QueueFullError, ServingStats)
from repro.serving.loadgen import LoadgenResult, closed_loop, open_loop
from repro.serving.resilience import (BrownoutController, CircuitBreaker,
                                      DegradedResult, EngineGuard,
                                      ResiliencePolicy, ResilienceStats)
from repro.serving.offline import (OfflineResult, load_query_file,
                                   run_offline, save_query_file)
from repro.serving.serve_step import build_serve_fns
from repro.serving.warmup import (ProgramShape, ShapeRegistry, WarmupReport,
                                  enable_compilation_cache,
                                  flush_compilation_cache, measure_compiles,
                                  warm)
from repro.serving.wmd_service import WMDService

__all__ = ["build_serve_fns", "WMDService", "QueryCoalescer",
           "ServingStats", "QueueFullError", "CoalescerClosedError",
           "LoadgenResult", "open_loop", "closed_loop",
           "ProgramShape", "ShapeRegistry", "WarmupReport", "warm",
           "enable_compilation_cache", "flush_compilation_cache",
           "measure_compiles",
           "OfflineResult", "run_offline", "load_query_file",
           "save_query_file",
           "ResiliencePolicy", "EngineGuard", "DegradedResult",
           "CircuitBreaker", "BrownoutController", "ResilienceStats"]
