"""Serving substrate: sharded prefill/decode, the WMD query service, and the
async admission layer (request coalescer + load generators)."""
from repro.serving.coalescer import (CoalescerClosedError, QueryCoalescer,
                                     QueueFullError, ServingStats)
from repro.serving.loadgen import LoadgenResult, closed_loop, open_loop
from repro.serving.serve_step import build_serve_fns
from repro.serving.wmd_service import WMDService

__all__ = ["build_serve_fns", "WMDService", "QueryCoalescer",
           "ServingStats", "QueueFullError", "CoalescerClosedError",
           "LoadgenResult", "open_loop", "closed_loop"]
