"""Serving substrate: sharded prefill/decode + the WMD query service."""
from repro.serving.serve_step import build_serve_fns
from repro.serving.wmd_service import WMDService

__all__ = ["build_serve_fns", "WMDService"]
