"""Serving resilience: circuit-breaker impl demotion, bounded retry, and
brownout degradation in front of the WMD engine.

The coalescer (serving.coalescer) turns client streams into engine
dispatches; this module decides *which* engine those dispatches hit when
things go wrong, without ever blocking the serving loop:

  EngineGuard     -- the dispatch wrapper. Every batch walks an ordered
                     ladder of rungs (impl fallbacks: the service default,
                     then cheaper contraction paths; pruned top-k falls
                     back to the exhaustive scan route), each rung behind
                     its own `CircuitBreaker`. Failures retry with seeded
                     exponential backoff + jitter (`ResiliencePolicy`),
                     trip the rung's breaker after a failure streak, and
                     demote to the next rung; when every exact rung is
                     down (or the `BrownoutController` says the server is
                     overloaded) the dispatch is served from the RWMD
                     bound-only degraded tier (`WMDService.
                     query_batch_bounds` / `top_k_batch_bounds`) and
                     wrapped in `DegradedResult` so clients can tell.
  CircuitBreaker  -- classic closed -> open -> half_open machine: a
                     failure streak opens the rung, a cooldown later one
                     probe dispatch is let through (half_open), and
                     `breaker_probes` consecutive probe successes close it
                     again. A probe failure re-opens immediately.
  BrownoutController -- hysteretic overload detector: enters brownout when
                     queue depth or the deadline-miss EWMA crosses its hi
                     threshold, exits only when BOTH are back under their
                     lo thresholds AND the brownout has dwelled
                     ``brownout_dwell_s`` (no flapping at the boundary).

Design rules, each load-bearing for the chaos suite's contracts
(tests/test_resilience.py):

* Rung 0 dispatches with ``impl=None`` -- byte-for-byte the call the
  coalescer makes without a guard -- so fault-free dispatches stay
  *bitwise identical* to the unguarded baseline.
* `DegradedResult` is a wrapper, never a mutation: normal responses remain
  raw arrays, so the success path's bitwise contract is untouched and
  ``isinstance(x, DegradedResult)`` is the complete client-side detection
  rule.
* `InvalidQueryError` propagates un-retried (a malformed input is the
  caller's bug, deterministic forever); everything else -- injected
  dispatch exceptions, jax runtime errors, `NumericalError` from the
  guards layer (which is also how *injected non-finite outputs* surface:
  the guard re-checks every result) -- is retryable up to
  ``max_retries`` per rung, because the guard cannot distinguish a
  transient corruption from a persistent one and the breaker bounds the
  damage either way.
* All waiting is bounded (retry backoff caps at ``backoff_max_s``); the
  guard never blocks on a lock while calling the engine, so a slow solve
  cannot deadlock stats readers.

`distributed.fault_tolerance.ServingWatchdog` plugs in via `trip()`:
straggler strikes force-open the active rung's breaker from outside.
"""
from __future__ import annotations

import collections
import dataclasses
import threading
import time
from typing import Callable, Sequence

import numpy as np

from repro.core import guards as _guards
from repro.obs.trace import NULL_TRACER

# the full contraction-path ladder, fastest-and-twitchiest first; a
# service's ladder starts at its own impl and demotes rightward
_IMPL_ORDER = ("kernel", "fused", "unfused")


@dataclasses.dataclass(frozen=True)
class ResiliencePolicy:
    """Knobs of the resilience layer (all times in seconds).

    ``impl_ladder``: explicit demotion ladder; () derives it from the
    service impl (e.g. "kernel" -> (None, "fused", "unfused") -- None is
    "the service default", kept first so fault-free dispatches are the
    exact unguarded call). ``brownout_queue_hi`` / ``brownout_miss_hi``
    of None disable that brownout signal; both None disables brownout
    entirely."""
    impl_ladder: tuple = ()
    breaker_failures: int = 3          # failure streak that opens a rung
    breaker_cooldown_s: float = 5.0    # open -> half_open delay
    breaker_probes: int = 1            # half_open successes to close
    max_retries: int = 2               # extra attempts per rung per dispatch
    backoff_base_s: float = 0.02
    backoff_mult: float = 2.0
    backoff_max_s: float = 0.5
    backoff_jitter: float = 0.5        # uniform [0, j] fraction added
    seed: int = 0                      # jitter rng seed
    brownout_queue_hi: int | None = None
    brownout_queue_lo: int = 0
    brownout_miss_hi: float | None = None
    brownout_miss_lo: float = 0.0
    brownout_dwell_s: float = 1.0      # min time browned out before exit
    degrade_on_failure: bool = True    # bound-only answers when rungs die


@dataclasses.dataclass
class DegradedResult:
    """A degraded (bound-only) response. ``value`` carries whatever the
    normal response would have been shaped like -- a (N,) bound row for a
    plain query, an ``(idx, dist)`` pair for top-k -- computed by the RWMD
    lower-bound tier instead of the exact Sinkhorn engine. ``reason`` says
    why ("brownout" or the engine failure), ``tier`` what produced it.
    Clients detect degradation with ``isinstance(x, DegradedResult)``;
    non-degraded responses are never wrapped."""
    value: object
    reason: str
    tier: str = "rwmd_bound"


class CircuitBreaker:
    """closed -> open -> half_open -> closed, with a transition log.

    Not thread-safe by itself; `EngineGuard` serializes access under its
    own lock. ``clock`` is injectable for deterministic tests."""

    def __init__(self, *, failures: int = 3, cooldown_s: float = 5.0,
                 probes: int = 1, clock: Callable[[], float] = time.monotonic,
                 on_transition: Callable[[str, str], None] | None = None):
        self.failures = max(1, failures)
        self.cooldown_s = cooldown_s
        self.probes = max(1, probes)
        self._clock = clock
        self._on_transition = on_transition
        self.state = "closed"
        self.transitions: list[tuple[str, str]] = []
        self._streak = 0
        self._probe_ok = 0
        self._opened_at = 0.0

    def _to(self, state: str) -> None:
        if state != self.state:
            self.transitions.append((self.state, state))
            old, self.state = self.state, state
            if self._on_transition is not None:
                self._on_transition(old, state)

    def allow(self) -> bool:
        """May a dispatch use this rung right now? An open breaker past
        its cooldown transitions to half_open and admits one probe."""
        if self.state == "open":
            if self._clock() - self._opened_at >= self.cooldown_s:
                self._probe_ok = 0
                self._to("half_open")
                return True
            return False
        return True

    def record_success(self) -> None:
        self._streak = 0
        if self.state == "half_open":
            self._probe_ok += 1
            if self._probe_ok >= self.probes:
                self._to("closed")

    def record_failure(self) -> None:
        if self.state == "half_open":       # failed probe: back to open
            self._opened_at = self._clock()
            self._to("open")
            return
        self._streak += 1
        if self._streak >= self.failures and self.state == "closed":
            self._opened_at = self._clock()
            self._to("open")

    def force_open(self) -> None:
        """External trip (watchdog straggler strikes)."""
        self._opened_at = self._clock()
        self._streak = 0
        self._to("open")


class BrownoutController:
    """Hysteretic overload detector driving the degraded tier.

    Enter when EITHER signal crosses its hi threshold; exit only when
    BOTH are at/below their lo thresholds and at least ``dwell_s`` has
    passed since entering (flap suppression). Signals with a None hi
    threshold never trigger entry and never hold exit."""

    def __init__(self, *, queue_hi: int | None = None, queue_lo: int = 0,
                 miss_hi: float | None = None, miss_lo: float = 0.0,
                 dwell_s: float = 1.0,
                 clock: Callable[[], float] = time.monotonic):
        self.queue_hi, self.queue_lo = queue_hi, queue_lo
        self.miss_hi, self.miss_lo = miss_hi, miss_lo
        self.dwell_s = dwell_s
        self._clock = clock
        self.active = False
        self.entries = 0
        self._entered_at = 0.0

    def update(self, queue_depth: int, miss_ewma: float) -> bool:
        hot = ((self.queue_hi is not None and queue_depth >= self.queue_hi)
               or (self.miss_hi is not None and miss_ewma >= self.miss_hi))
        if not self.active:
            if hot:
                self.active = True
                self.entries += 1
                self._entered_at = self._clock()
            return self.active
        calm = ((self.queue_hi is None or queue_depth <= self.queue_lo)
                and (self.miss_hi is None or miss_ewma <= self.miss_lo))
        if calm and self._clock() - self._entered_at >= self.dwell_s:
            self.active = False
        return self.active


@dataclasses.dataclass(frozen=True)
class ResilienceStats:
    """Snapshot of the guard's counters (cumulative)."""
    dispatches: int
    retries: int
    failures: int                 # failed attempts (incl. retried ones)
    demoted: int                  # dispatches served below rung 0
    degraded: int                 # dispatches served by the bound tier
    degraded_requests: int        # requests inside those dispatches
    breaker_transitions: int
    breaker_open: int             # rungs currently open (incl. half_open)
    brownout_active: bool
    brownout_entries: int
    breaker_states: dict[str, str]   # "kind/rung" -> state


def _default_ladder(svc_impl: str) -> tuple:
    """(None, <impls strictly below svc_impl in the order>): None = the
    service default (the exact unguarded dispatch), demotions follow."""
    try:
        start = _IMPL_ORDER.index(svc_impl)
    except ValueError:
        return (None,)
    return (None,) + _IMPL_ORDER[start + 1:]


class EngineGuard:
    """Resilient dispatch wrapper around a `WMDService`-shaped engine.

    The coalescer (or any caller) routes batches through `dispatch`; the
    guard walks the rung ladder, retries, trips breakers, and falls back
    to the degraded bound tier. ``clock`` / ``sleep`` are injectable so
    the chaos suite runs the whole machine on a fake clock."""

    def __init__(self, svc, policy: ResiliencePolicy | None = None, *,
                 clock: Callable[[], float] = time.monotonic,
                 sleep: Callable[[float], None] = time.sleep,
                 tracer=None, metrics=None):
        self.svc = svc
        self.policy = policy or ResiliencePolicy()
        self._clock = clock
        self._sleep = sleep
        # late-bound on purpose: the coalescer attaches its tracer to a
        # prebuilt guard after construction; breaker callbacks read the
        # attribute at fire time, so attachment is retroactive
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self._mx = None
        if metrics is not None:
            self._mx = {
                "dispatches": metrics.counter(
                    "wmd_guard_dispatches_total",
                    "batches routed through the resilience guard"),
                "retries": metrics.counter(
                    "wmd_guard_retries_total", "per-rung retry attempts"),
                "failures": metrics.counter(
                    "wmd_guard_failures_total",
                    "failed dispatch attempts (incl. retried)"),
                "demoted": metrics.counter(
                    "wmd_guard_demoted_total",
                    "dispatches served below rung 0"),
                "degraded": metrics.counter(
                    "wmd_guard_degraded_total",
                    "dispatches answered by the RWMD bound tier"),
                "transitions": metrics.counter(
                    "wmd_breaker_transitions_total",
                    "circuit-breaker state transitions"),
                "brownout_entries": metrics.counter(
                    "wmd_brownout_entries_total", "brownout activations"),
                "brownout_active": metrics.gauge(
                    "wmd_brownout_active", "1 while browned out"),
                "breaker_open": metrics.gauge(
                    "wmd_breaker_open_rungs",
                    "rungs currently open or half_open"),
            }
        self._rng = np.random.default_rng(self.policy.seed)
        self._lock = threading.Lock()
        ladder = tuple(self.policy.impl_ladder) or _default_ladder(
            getattr(svc, "impl", "fused"))
        # rung tables: ("impl", x) dispatches query_batch(impl=x);
        # ("pruned", x) dispatches the two-tier top-k with impl x;
        # ("scan", None) the exhaustive one-program top-k route -- a
        # genuinely different code path for when the prune machinery
        # itself is what's failing
        self._rungs: dict[str, list[tuple[str, object]]] = {
            "plain": [("impl", impl) for impl in ladder],
            "top_k": [("pruned", impl) for impl in ladder]
                     + [("scan", None)],
        }
        def mk(kind: str, i: int) -> CircuitBreaker:
            return CircuitBreaker(
                failures=self.policy.breaker_failures,
                cooldown_s=self.policy.breaker_cooldown_s,
                probes=self.policy.breaker_probes, clock=clock,
                on_transition=lambda old, new, kind=kind, i=i:
                    self._on_breaker(kind, i, old, new))

        self._breakers = {(kind, i): mk(kind, i)
                          for kind, rungs in self._rungs.items()
                          for i in range(len(rungs))}
        self.brownout = BrownoutController(
            queue_hi=self.policy.brownout_queue_hi,
            queue_lo=self.policy.brownout_queue_lo,
            miss_hi=self.policy.brownout_miss_hi,
            miss_lo=self.policy.brownout_miss_lo,
            dwell_s=self.policy.brownout_dwell_s, clock=clock)
        # counters (under _lock)
        self._dispatches = 0
        self._retries = 0
        self._failures = 0
        self._demoted = 0
        self._degraded = 0
        self._degraded_requests = 0
        # (kind, rung_index, degraded) of recent dispatches, for the chaos
        # suite's replay oracle (which rung actually served each batch);
        # bounded like the coalescer's batch_log so a long-lived server
        # can't grow it without bound
        self.dispatch_log: collections.deque[tuple[str, int, bool]] = \
            collections.deque(maxlen=4096)

    # -- observability taps ----------------------------------------------
    # (event emission only appends to the tracer's own deque under the
    # tracer's lock -- no callbacks back into guard state, so firing them
    # while holding self._lock cannot deadlock)

    def _on_breaker(self, kind: str, rung: int, old: str, new: str) -> None:
        self.tracer.event("breaker.transition", kind=kind, rung=rung,
                          frm=old, to=new)
        if self._mx is not None:
            self._mx["transitions"].inc()
            self._mx["breaker_open"].set(
                sum(1 for br in self._breakers.values()
                    if br.state != "closed"))

    def _update_brownout(self, queue_depth: int, miss_ewma: float) -> bool:
        """brownout.update + enter/exit edge detection (caller holds
        self._lock)."""
        was = self.brownout.active
        active = self.brownout.update(queue_depth, miss_ewma)
        if active != was:
            self.tracer.event("brownout.enter" if active else "brownout.exit",
                              queue_depth=queue_depth,
                              miss_ewma=round(float(miss_ewma), 6),
                              entries=self.brownout.entries)
            if self._mx is not None:
                self._mx["brownout_active"].set(1.0 if active else 0.0)
                if active:
                    self._mx["brownout_entries"].inc()
        return active

    # -- dispatch ---------------------------------------------------------

    def _call(self, kind: str, rung: tuple[str, object],
              payloads: Sequence[np.ndarray], k: int | None):
        mode, impl = rung
        if mode == "impl":
            if impl is None:
                return self.svc.query_batch(payloads)
            return self.svc.query_batch(payloads, impl=impl)
        if mode == "pruned":
            kw = {} if impl is None else {"impl": impl}
            return self.svc.top_k_batch(payloads, k, prune=True, **kw)
        return self.svc.top_k_batch(payloads, k, prune=False)

    def _post_check(self, kind: str, res) -> None:
        """Re-verify the result at the guard boundary: the service's own
        guards run *inside* the engine, so corruption injected at the
        engine boundary (faultinject) -- or a service with guards off --
        is caught here and treated as a dispatch failure."""
        if kind == "plain":
            _guards.check_finite(res, "dispatch result")
        else:
            _guards.check_finite(res[1], "top_k dispatch distances")

    def _backoff(self, attempt: int) -> float:
        p = self.policy
        base = min(p.backoff_base_s * (p.backoff_mult ** attempt),
                   p.backoff_max_s)
        with self._lock:
            jitter = float(self._rng.random()) * p.backoff_jitter
        return base * (1.0 + jitter)

    def _degrade(self, kind: str, payloads, k: int | None,
                 reason: str) -> DegradedResult:
        if kind == "plain":
            val = self.svc.query_batch_bounds(payloads)
        else:
            val = self.svc.top_k_batch_bounds(payloads, k)
        with self._lock:
            self._degraded += 1
            self._degraded_requests += len(payloads)
        self.tracer.event("degraded", kind=kind, reason=reason,
                          requests=len(payloads))
        if self._mx is not None:
            self._mx["degraded"].inc()
        return DegradedResult(value=val, reason=reason)

    def dispatch(self, kind: str, payloads: Sequence[np.ndarray],
                 k: int | None = None, *, queue_depth: int = 0,
                 miss_ewma: float = 0.0):
        """Serve one batch resiliently. Returns the engine result (raw --
        bitwise identical to an unguarded dispatch when rung 0 succeeds
        first try) or a `DegradedResult`; raises only when every rung AND
        the degraded tier failed (or degradation is disabled)."""
        if kind not in self._rungs:
            raise ValueError(f"unknown dispatch kind {kind!r}")
        with self._lock:
            self._dispatches += 1
            browned = self._update_brownout(queue_depth, miss_ewma)
        if self._mx is not None:
            self._mx["dispatches"].inc()
        if browned:
            try:
                res = self._degrade(kind, payloads, k, "brownout")
                with self._lock:
                    self.dispatch_log.append((kind, -1, True))
                return res
            except _guards.InvalidQueryError:
                raise
            except Exception:
                pass          # bound tier down too: fall through to exact
        last_err: BaseException | None = None
        for i, rung in enumerate(self._rungs[kind]):
            br = self._breakers[(kind, i)]
            attempt = 0
            while True:
                with self._lock:
                    if not br.allow():
                        break
                try:
                    res = self._call(kind, rung, payloads, k)
                    self._post_check(kind, res)
                except _guards.InvalidQueryError:
                    raise     # caller bug: deterministic, never retried
                except Exception as e:    # noqa: BLE001 -- rung fault
                    last_err = e
                    with self._lock:
                        self._failures += 1
                        br.record_failure()
                        retry = (attempt < self.policy.max_retries
                                 and br.allow())
                        if retry:
                            self._retries += 1
                    self.tracer.event("dispatch.failure", kind=kind, rung=i,
                                      error=type(e).__name__, retry=retry)
                    if self._mx is not None:
                        self._mx["failures"].inc()
                        if retry:
                            self._mx["retries"].inc()
                    if not retry:
                        break             # rung exhausted: demote
                    attempt += 1
                    self._sleep(self._backoff(attempt))
                    continue
                with self._lock:
                    br.record_success()
                    if i > 0:
                        self._demoted += 1
                    self.dispatch_log.append((kind, i, False))
                if i > 0 and self._mx is not None:
                    self._mx["demoted"].inc()
                return res
        if self.policy.degrade_on_failure:
            try:
                res = self._degrade(
                    kind, payloads, k,
                    f"engine_failure: {type(last_err).__name__}: {last_err}"
                    if last_err is not None else "all rungs open")
                with self._lock:
                    self.dispatch_log.append((kind, -1, True))
                return res
            except _guards.InvalidQueryError:
                raise
            except Exception as e:        # noqa: BLE001
                last_err = last_err or e
        if last_err is None:
            last_err = RuntimeError("every rung breaker is open")
        raise last_err

    # -- external hooks ---------------------------------------------------

    def observe(self, queue_depth: int, miss_ewma: float) -> bool:
        """Feed overload signals outside a dispatch (e.g. a monitoring
        loop); returns whether brownout is active."""
        with self._lock:
            return self._update_brownout(queue_depth, miss_ewma)

    def trip(self, kind: str = "plain", reason: str = "") -> None:
        """Force-open the first non-open rung of ``kind`` (watchdog hook:
        straggler strikes demote the engine from outside)."""
        with self._lock:
            for i in range(len(self._rungs[kind])):
                br = self._breakers[(kind, i)]
                if br.state != "open":
                    br.force_open()
                    self.tracer.event("breaker.tripped", kind=kind, rung=i,
                                      reason=reason or "external trip")
                    return

    def stats(self) -> ResilienceStats:
        with self._lock:
            states = {f"{kind}/{i}": br.state
                      for (kind, i), br in sorted(self._breakers.items())}
            return ResilienceStats(
                dispatches=self._dispatches,
                retries=self._retries,
                failures=self._failures,
                demoted=self._demoted,
                degraded=self._degraded,
                degraded_requests=self._degraded_requests,
                breaker_transitions=sum(len(br.transitions)
                                        for br in self._breakers.values()),
                breaker_open=sum(1 for br in self._breakers.values()
                                 if br.state != "closed"),
                brownout_active=self.brownout.active,
                brownout_entries=self.brownout.entries,
                breaker_states=states)
