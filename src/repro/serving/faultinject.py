"""Deterministic fault injection at the engine boundary -- the chaos
suite's substrate. TEST-ONLY by contract: nothing in the serving stack
imports this module; production code must never see a `FaultyEngine`.

`FaultyEngine` wraps a `WMDService`-shaped object and intercepts the three
exact-tier entry points (``query_batch``, ``top_k_batch``, ``query``),
injecting, per intercepted call:

  error    -- raise `InjectedFault` instead of dispatching (a transient
              dispatch exception: the retry/breaker path's food);
  latency  -- sleep before dispatching (a straggler: the watchdog's and
              deadline-miss machinery's food);
  corrupt  -- dispatch normally, then overwrite one result cell with NaN
              (a silent numeric fault: the guard layer's food -- the
              `EngineGuard` post-check turns it into a retryable failure).

The degraded tier (``query_batch_bounds`` / ``top_k_batch_bounds``) and
everything else forward untouched by default (``protect`` lists the names
exempt from interception), so brownout fallbacks stay reliable while the
exact tier burns -- flip ``protect=()`` to chaos-test the fallback too.

Determinism: faults are drawn per *call index*, not per wall-clock --
``rng = default_rng((seed, idx))`` -- so a schedule replays identically
regardless of thread timing, and a retried dispatch (a NEW call index)
legitimately sees fresh luck. `FaultSchedule.from_events` pins exact
faults to exact call indices for state-machine tests that cannot tolerate
probability.

``dispatch_log`` records (idx, method, fault, payloads, result) for every
intercepted call; the chaos suite replays the non-faulted compositions
directly against a clean service to assert the bitwise no-fault contract.

`CrashInjector` + `InjectedCrash` are the *data-path* counterpart: where
`FaultyEngine` injects query-side faults at the engine boundary, the
crash injector kills the writer at the live corpus's WAL / snapshot /
compaction boundaries (hook-based, seeded per boundary index with the
same ``default_rng((seed, idx))`` determinism) so the ingest chaos suite
can assert crash-consistent recovery at every single kill site.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Callable, Mapping

import numpy as np


class InjectedFault(RuntimeError):
    """A fault raised by the injector (never by the real engine)."""


class InjectedCrash(BaseException):
    """A simulated process kill (kill -9) raised at a crash boundary.

    Deliberately a BaseException, not an Exception: a real kill gives no
    code the chance to clean up, so an injected one must sail through
    every ``except Exception`` recovery handler in the write path --
    anything those handlers would have repaired must instead be repaired
    by *recovery from disk*, which is the property the chaos suite
    asserts. Only the test harness (and the corpus lock's ``finally``
    unwinding, which a real kill also cannot prevent from mattering --
    the process is gone either way) may catch it."""


class CrashInjector:
    """Counting crash-point hook for the live corpus's write boundaries.

    The corpus calls ``hook(name)`` at every WAL / snapshot / compaction
    boundary (`data.wal.WalWriter` and `data.live_corpus.LiveCorpus` list
    them). This hook counts the calls and raises `InjectedCrash` at a
    chosen one, in either of two modes:

      * **target mode** -- ``CrashInjector(target=i)`` crashes at exactly
        the i-th boundary crossed (after ``match`` filtering). With
        ``target=None`` nothing ever crashes and the hook is a pure
        counter: the dry-run pass the chaos suite uses to *enumerate* the
        boundaries of an op sequence before sweeping a crash over every
        single one.
      * **seeded mode** -- ``CrashInjector(seed=s, p_crash=p)`` draws the
        crash decision per boundary index from ``default_rng((seed,
        idx))``, the same replay-deterministic rule as `FaultSchedule`:
        a schedule replays identically regardless of thread timing.

    ``match`` restricts counting (and crashing) to boundaries whose name
    contains the substring -- e.g. ``match="compact"`` sweeps compaction
    boundaries only. ``log`` records every counted boundary name, so a
    failing sweep names the exact kill site.
    """

    def __init__(self, target: int | None = None, *, seed: int | None = None,
                 p_crash: float = 0.0, match: str | None = None):
        self.target = target
        self.seed = seed
        self.p_crash = p_crash
        self.match = match
        self.count = 0
        self.log: list[str] = []
        self.crashed_at: tuple[int, str] | None = None

    def __call__(self, name: str) -> None:
        if self.match is not None and self.match not in name:
            return
        idx = self.count
        self.count += 1
        self.log.append(name)
        crash = idx == self.target if self.target is not None else (
            self.seed is not None and self.p_crash > 0.0
            and np.random.default_rng((self.seed, idx)).random()
            < self.p_crash)
        if crash:
            self.crashed_at = (idx, name)
            raise InjectedCrash(f"injected crash at boundary {idx} ({name})")


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """What to inject into one intercepted call."""
    error: bool = False
    latency_s: float = 0.0
    corrupt: bool = False


_NO_FAULT = FaultSpec()


class FaultSchedule:
    """Seeded per-call-index fault draws.

    Probabilistic mode: each intercepted call ``idx`` draws error /
    latency / corruption independently from ``default_rng((seed, idx))``
    -- deterministic in the call index alone. Event mode
    (`from_events`): an explicit {idx: FaultSpec} table, everything else
    fault-free. ``window`` restricts the probabilistic mode to
    ``start <= idx < stop`` (fault storms with clean ramp-in/out)."""

    def __init__(self, *, seed: int = 0, p_error: float = 0.0,
                 p_latency: float = 0.0, p_corrupt: float = 0.0,
                 latency_s: float = 0.02,
                 window: tuple[int, int | None] = (0, None)):
        self.seed = seed
        self.p_error = p_error
        self.p_latency = p_latency
        self.p_corrupt = p_corrupt
        self.latency_s = latency_s
        self.window = window
        self._events: Mapping[int, FaultSpec] | None = None

    @classmethod
    def from_events(cls, events: Mapping[int, FaultSpec]) -> "FaultSchedule":
        """Exact-fault schedule: call ``idx`` gets ``events[idx]``, every
        other call is clean. For breaker/brownout state-machine tests."""
        sched = cls()
        sched._events = dict(events)
        return sched

    def faults_for(self, idx: int) -> FaultSpec:
        if self._events is not None:
            return self._events.get(idx, _NO_FAULT)
        lo, hi = self.window
        if idx < lo or (hi is not None and idx >= hi):
            return _NO_FAULT
        draws = np.random.default_rng((self.seed, idx)).random(3)
        return FaultSpec(
            error=bool(draws[0] < self.p_error),
            latency_s=self.latency_s if draws[1] < self.p_latency else 0.0,
            corrupt=bool(draws[2] < self.p_corrupt))


@dataclasses.dataclass
class _Call:
    """One intercepted call, as recorded in ``dispatch_log``."""
    idx: int
    method: str
    fault: FaultSpec
    payloads: list
    kwargs: dict
    result: object          # None when the call raised


class FaultyEngine:
    """Engine-boundary fault injector. See the module docstring.

    Duck-types the service: intercepted methods are defined explicitly,
    everything else (``query_batch_bounds``, ``last_batch_stats``,
    ``impl``, ``cfg``, ...) forwards via ``__getattr__`` so the coalescer,
    `EngineGuard`, and warmup all treat it as the service itself."""

    INTERCEPTED = ("query_batch", "top_k_batch", "query")

    def __init__(self, svc, schedule: FaultSchedule, *,
                 protect: tuple[str, ...] = ("query_batch_bounds",
                                             "top_k_batch_bounds"),
                 sleep: Callable[[float], None] = time.sleep,
                 log_size: int = 65536):
        self._svc = svc
        self.schedule = schedule
        self.protect = protect          # informational: these never inject
        self._sleep = sleep
        self._lock = threading.Lock()
        self._calls = 0
        self.injected = {"error": 0, "latency": 0, "corrupt": 0}
        self.dispatch_log: list[_Call] = []
        self._log_size = log_size

    def __getattr__(self, name):
        return getattr(self._svc, name)

    @property
    def calls(self) -> int:
        with self._lock:
            return self._calls

    def _intercept(self, method: str, payloads: list, kwargs: dict,
                   fn, corrupt_fn):
        with self._lock:
            idx = self._calls
            self._calls += 1
            fault = self.schedule.faults_for(idx)
            if fault.latency_s:
                self.injected["latency"] += 1
            if fault.error:
                self.injected["error"] += 1
            elif fault.corrupt:
                self.injected["corrupt"] += 1
        if fault.latency_s:
            self._sleep(fault.latency_s)
        rec = _Call(idx=idx, method=method, fault=fault,
                    payloads=payloads, kwargs=kwargs, result=None)
        try:
            if fault.error:
                raise InjectedFault(
                    f"injected dispatch error (call {idx}, {method})")
            res = fn()
            if fault.corrupt:
                res = corrupt_fn(res, idx)
            rec.result = res
            return res
        finally:
            with self._lock:
                if len(self.dispatch_log) < self._log_size:
                    self.dispatch_log.append(rec)

    @staticmethod
    def _corrupt_dists(res, idx: int):
        """Overwrite one seeded cell with NaN (copy -- the real engine's
        arrays are never mutated)."""
        out = np.array(res, copy=True)
        if out.size:
            flat = out.reshape(-1)
            pos = int(np.random.default_rng((idx, 1)).integers(flat.size))
            flat[pos] = np.nan
        return out

    @classmethod
    def _corrupt_topk(cls, res, idx: int):
        i, d = res
        return i, cls._corrupt_dists(d, idx)

    # -- intercepted entry points -----------------------------------------

    def query_batch(self, rs, **kw):
        return self._intercept(
            "query_batch", list(rs), dict(kw),
            lambda: self._svc.query_batch(rs, **kw), self._corrupt_dists)

    def top_k_batch(self, rs, k=10, **kw):
        return self._intercept(
            "top_k_batch", list(rs), {"k": k, **kw},
            lambda: self._svc.top_k_batch(rs, k, **kw), self._corrupt_topk)

    def query(self, r, **kw):
        return self._intercept(
            "query", [r], dict(kw),
            lambda: self._svc.query(r, **kw), self._corrupt_dists)
