"""Async request coalescer: turns a stream of single WMD queries into full
cache-friendly batches for the (Q, v_r, N) engine.

The paper's speedup is batch amortization: one fused SDDMM-SpMM program (one
ELL gather, one psum per Sinkhorn iteration) serves every query in the batch,
so the engine only reaches peak when it is fed full batches. `WMDService`
solves whatever one `query_batch` call brings; this module supplies the
missing admission layer for an *asynchronous* workload -- independent clients
submitting one query at a time ("heavy traffic from millions of users").

Serving architecture (queue -> dispatcher -> engine)
----------------------------------------------------
::

    clients                 QueryCoalescer                       WMDService
    submit(r) ---> [priority lane | admission queue] --+
    submit(r) ----------------^                        |  dispatcher thread
    submit_many -------------^                         +-> query_batch(batch)
       ...                                                  |  (one device
    Future <---- set_result(row i of the batch result) <----+   program)

* **Admission queue** -- bounded (``max_queue``) FIFO of pending requests,
  plus an optional priority lane (``submit(..., priority=1)``) drained first
  at batch-formation time. When the queue is full, ``backpressure`` picks the
  policy: ``"block"`` parks the submitter until space frees (optional
  ``timeout``), ``"reject"`` raises `QueueFullError` immediately.
* **Dispatcher thread** -- the only thread that touches the device, so
  coalesced serving keeps the engine's determinism: each dispatched batch is
  one plain ``svc.query_batch(rs)`` call, and every request's result row is
  **bitwise identical** to a direct ``query_batch`` of the same queries in
  the same order (asserted by tests/test_coalescer.py via `batch_log`
  oracle replay, cache on and off).
* **Top-k requests** -- ``submit_top_k(r, k)`` coalesces retrieval requests
  exactly like plain queries: batches are cut *homogeneous* (one kind, one
  k -- the cut stops at the first kind change, the next cut picks up the
  other run), so a top-k batch is literally one
  ``svc.top_k_batch(rs, k, prune=True)`` dispatch of the two-tier pruned
  engine, whose results are bitwise-identical to the exact full scan.
  The deadline trigger budgets with a per-kind service-time EWMA (top-k
  and plain dispatches cost very differently). Mixed-kind caveat: cuts
  are FIFO, so a deadline request queued behind a foreign-kind run waits
  out that one dispatch before its own cut -- under mixed traffic,
  deadline budgets should leave one foreign service time of slack (the
  same slack a request arriving behind an already-full bucket needs).
* **Writer lane** -- ``submit_add_docs(ids, docs)`` / ``submit_remove_docs
  (ids)`` enqueue live-corpus mutations (services built via
  `WMDService.from_live`) through the same admission queue: FIFO against
  queries (read-your-writes: a query submitted after a write ack
  dispatches after the write applied), homogeneous cuts per op, and a
  write dispatch merges its batch into ONE durable ``add_docs`` /
  ``remove_docs`` call -- ingest bursts amortize WAL fsyncs the way query
  bursts amortize programs. Write futures resolve to the acked doc count
  once the mutation is WAL-fsynced; writes bypass the resilience guard
  (durability is the corpus's contract, a degraded write has no meaning)
  and contribute ``write_dispatches`` / ``docs_added`` / ``docs_removed``
  to `ServingStats` instead of program-shape telemetry.
* **Dispatch triggers** -- a batch is cut when the first of these fires
  (per-dispatch counts are in `ServingStats`):
    - *fill*:     the ``max_batch`` Q bucket is full (``max_batch`` is
                  rounded up to a power of two to match the service's
                  pow2 admission buckets -- a coalescer batch never
                  straddles two bucket retraces);
    - *window*:   the oldest queued request has waited ``window_ms``
                  (2-10 ms spans the sweet spot on the bench box:
                  long enough to fill buckets at load, short enough to
                  stay invisible next to a solve);
    - *deadline*: waiting any longer would violate the earliest queued
                  request's deadline budget, i.e.
                  ``now + service_estimate >= min(deadline)`` where
                  ``service_estimate`` is an EWMA of recent dispatch wall
                  times (first dispatches include compile time, so warm the
                  service before relying on tight deadlines);
    - *drain*:    `drain()` and shutdown flush whatever is queued
                  immediately (no waiting out the window).
* **Cancellation** -- a client may ``Future.cancel()`` a request that is
  still queued; it is discarded at batch-formation time (never dispatched,
  counted in ``ServingStats.cancelled``). Requests that survive the cut are
  marked running, so a late cancel can never race the result fan-out.
* **Deadlines** -- ``submit(..., deadline_ms=...)`` (or the constructor's
  ``default_deadline_ms``) sets a per-request budget measured from submit
  time. Deadlines pull dispatch *earlier*; a request that still finishes
  past its deadline is served anyway and counted in
  ``ServingStats.deadline_misses`` (serving late beats dropping work; a
  dropping policy belongs in the client).
* **Shutdown** -- `drain()` blocks until the queue and in-flight batch are
  empty (coalescer stays open); `shutdown(drain=True)` closes admission,
  flushes, and joins the thread; `shutdown(drain=False)` fails pending
  futures with `CoalescerClosedError`. The context-manager form
  (``with svc.async_service() as co:``) is shutdown-with-drain, which is
  what makes the serve loop SIGINT-safe.

Observability: `stats()` returns a `ServingStats` snapshot -- queue depth,
batch-size histogram, per-trigger dispatch counts, p50/p95/p99 request
latency, and the cross-query cache hit rate passed through from the
service's ``last_batch_stats``. `batch_log` keeps the request-id composition
of recent dispatches: the replay oracle for the bitwise contract and the
provenance record for tail-latency debugging.

`loadgen.py` drives this layer (open-loop Poisson / closed-loop workers)
and `benchmarks/bench_serving.py` sweeps arrival rate x window into
``BENCH_serving.json``.
"""
from __future__ import annotations

import collections
import dataclasses
import heapq
import threading
import time
from concurrent.futures import Future
from typing import Callable, Sequence

import numpy as np

from repro.core import guards as _guards
from repro.obs.metrics import (DEFAULT_SIZE_BUCKETS, DEFAULT_TIME_BUCKETS,
                               MetricsRegistry)
from repro.obs.trace import NULL_TRACER
from repro.serving.resilience import (DegradedResult, EngineGuard,
                                      ResiliencePolicy)


class QueueFullError(RuntimeError):
    """Admission queue at max_queue and backpressure policy gave up."""


class CoalescerClosedError(RuntimeError):
    """submit() after shutdown, or a pending request failed by a no-drain
    shutdown."""


@dataclasses.dataclass(frozen=True)
class ServingStats:
    """Point-in-time snapshot of the coalescer (all counters cumulative)."""
    queue_depth: int              # requests waiting (both lanes)
    in_flight: int                # requests inside the current dispatch
    submitted: int
    completed: int
    rejected: int                 # backpressure rejections (QueueFullError)
    failed: int                   # requests whose dispatch raised
    cancelled: int                # futures cancelled by clients while queued
    deadline_misses: int          # served, but past their deadline
    dispatches: int
    dispatch_fill: int            # per-trigger dispatch counts
    dispatch_window: int
    dispatch_deadline: int
    dispatch_drain: int
    batch_size_hist: dict[int, int]
    mean_batch_size: float
    latency_ms_mean: float        # request latency = submit -> result set
    latency_ms_p50: float
    latency_ms_p95: float
    latency_ms_p99: float
    hit_rate: float | None        # mean per-dispatch cache hit rate
    service_estimate_ms: float    # EWMA dispatch wall time (deadline trigger)
    # registry warmup (serving.warmup): shapes precompiled before serving and
    # the per-shape compile seconds -- None until record_warmup() is called
    warmed_shapes: int = 0
    warmup_compile_s: dict[str, float] | None = None
    # resilience (serving.resilience; all zero/False without a policy)
    quarantined: int = 0          # rejected at admission (InvalidQueryError)
    degraded: int = 0             # requests served bound-only (DegradedResult)
    retries: int = 0              # engine dispatch retries
    breaker_transitions: int = 0  # circuit-breaker state changes
    breaker_open: int = 0         # rungs currently not closed
    brownout_active: bool = False
    # writer lane (live-corpus ingest; all zero on a read-only service)
    write_dispatches: int = 0     # add/remove batches dispatched
    docs_added: int = 0           # docs durably acked via submit_add_docs
    docs_removed: int = 0         # ids durably logged via submit_remove_docs

    @property
    def degraded_fraction(self) -> float:
        """Fraction of completed requests served by the degraded tier."""
        return self.degraded / self.completed if self.completed else 0.0


@dataclasses.dataclass
class _Request:
    seq: int
    r: np.ndarray
    future: Future
    t_submit: float
    deadline: float | None        # absolute monotonic time, or None
    priority: int
    k: int | None = None          # top-k request (None = plain distances);
                                  # batches are cut homogeneous per kind
    op: str = "plain"             # "plain" | "top_k" | "add" | "remove";
                                  # write ops carry their payload in ``r``
                                  # ((ids, docs) resp. ids) and cut into
                                  # their own homogeneous batches
    popped: bool = False          # left the queue (dispatched or discarded);
                                  # lazily expires stale deadline-heap entries


def _next_pow2(q: int) -> int:
    return 1 << (q - 1).bit_length()


# scheduling slack subtracted from deadline fire times on top of the
# service-time EWMA: covers dispatcher wakeup + batch pop + result fan-out,
# which the EWMA (pure query_batch wall time) does not see
_DEADLINE_MARGIN_S = 1e-3


class QueryCoalescer:
    """Thread-safe admission queue + dispatcher in front of a `WMDService`.

    See the module docstring for the architecture. ``svc`` only needs a
    ``query_batch(list[np.ndarray]) -> (Q, N)`` method and (optionally) a
    ``last_batch_stats`` dict -- the coalescer is engine-agnostic by design.

    Args:
      svc:            the service whose ``query_batch`` dispatches run on.
      window_ms:      coalescing window measured from the oldest queued
                      request (trigger *window*).
      max_batch:      Q bucket that cuts a batch on fill; rounded up to a
                      power of two (the service's admission granularity).
      max_queue:      bound on queued requests (both lanes); 0 = unbounded.
      backpressure:   "block" | "reject" when the queue is full.
      default_deadline_ms: deadline applied to submits that don't pass one
                      (None = no deadline).
      batch_log_size: dispatched-batch compositions kept for oracle replay /
                      debugging (`batch_log`).
      latency_window: completed-request latencies kept for the percentile
                      snapshot (bounded so a long-lived server can't grow
                      without bound; percentiles are over this window, and
                      stats() copies it under the lock -- the default keeps
                      that copy well under the coalescing-window scale).
      validate:       admission-boundary input validation. Against a real
                      WMD service (one exposing ``cfg.vocab_size``) every
                      submit runs `core.guards.validate_query` (shape /
                      finiteness / non-negativity / non-zero mass) and a
                      bad query raises `InvalidQueryError` at submit time
                      -- quarantined (``ServingStats.quarantined``), never
                      enqueued, so one poisoned row can't NaN a whole
                      coalesced batch. Duck-typed services without a
                      vocab size get a finite-only check (their payload
                      contract is theirs).
      resilience:     a `serving.resilience.ResiliencePolicy` (or a
                      pre-built `EngineGuard`, e.g. one shared across
                      coalescers) that routes every dispatch through the
                      breaker/retry/brownout machinery; degraded responses
                      resolve futures with `DegradedResult` wrappers.
                      None (default) dispatches the engine directly.
      heartbeat:      callback ``(kind, wall_s, ok)`` invoked after every
                      dispatch -- the `distributed.fault_tolerance.
                      ServingWatchdog` wiring point (liveness + straggler
                      strikes). Exceptions from it are swallowed.
      metrics:        a `repro.obs.MetricsRegistry` that becomes the
                      backing store of every `ServingStats` counter
                      (``wmd_requests_*`` / ``wmd_dispatches_total`` /
                      latency + batch-size histograms + phase-seconds
                      counters) -- scrape it live via `repro.obs.export`.
                      None creates a private registry, so each coalescer's
                      stats stay independent by default; pass the
                      *service's* registry (as `launch.serve` does) to get
                      the whole stack -- coalescer + K cache + guard -- in
                      one scrape namespace. Do NOT share one registry
                      across concurrently-live coalescers whose stats you
                      read individually: counters are get-or-create by
                      name, so sharing sums them.
      tracer:         a `repro.obs.Tracer` recording one span tree per
                      submitted request (queue wait, dispatch, engine
                      phase attribution, status) plus quarantine events;
                      it is also attached to a guard the coalescer
                      constructs (breaker/brownout/degraded events).
                      None (default) = the shared no-op recorder, zero
                      hot-path cost. Tracing never touches result arrays
                      -- obs-on is bitwise identical to obs-off.
    """

    def __init__(self, svc, *, window_ms: float = 5.0, max_batch: int = 16,
                 max_queue: int = 256, backpressure: str = "block",
                 default_deadline_ms: float | None = None,
                 batch_log_size: int = 4096, latency_window: int = 10_000,
                 validate: bool = True,
                 resilience: "ResiliencePolicy | EngineGuard | None" = None,
                 heartbeat: Callable[[str, float, bool], None] | None = None,
                 metrics: MetricsRegistry | None = None,
                 tracer=None):
        if backpressure not in ("block", "reject"):
            raise ValueError(f"backpressure must be block|reject, "
                             f"got {backpressure!r}")
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        self.svc = svc
        self.window_s = window_ms / 1e3
        self.max_batch = _next_pow2(max_batch)
        self.max_queue = max_queue
        self.backpressure = backpressure
        self.default_deadline_s = (None if default_deadline_ms is None
                                   else default_deadline_ms / 1e3)
        self.validate = validate
        # full validation needs the engine's vocab size; duck-typed fake
        # services (no cfg) get the finite-only check
        self._vocab_size = getattr(getattr(svc, "cfg", None),
                                   "vocab_size", None)
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self._tracer = tracer if tracer is not None else NULL_TRACER
        if resilience is None or isinstance(resilience, EngineGuard):
            self._guard = resilience
            # attach our tracer to a prebuilt guard that has none, so
            # breaker/brownout events land in the same log as the spans
            if (self._guard is not None and tracer is not None
                    and self._guard.tracer is NULL_TRACER):
                self._guard.tracer = self._tracer
        else:
            self._guard = EngineGuard(svc, resilience,
                                      tracer=self._tracer,
                                      metrics=self.metrics)
        self._heartbeat = heartbeat

        self._lock = threading.Lock()
        self._work = threading.Condition(self._lock)   # dispatcher waits
        self._space = threading.Condition(self._lock)  # blocked submitters
        self._idle = threading.Condition(self._lock)   # drain() waiters
        self._lo: collections.deque[_Request] = collections.deque()
        self._hi: collections.deque[_Request] = collections.deque()
        self._closed = False
        self._draining = 0            # active drain() calls force flushes
        self._seq = 0
        self._in_flight = 0

        # counters (mutated under _lock; backed by the metrics registry --
        # ServingStats is a *view* over these, and the same objects are
        # what a live Prometheus scrape reads)
        mx = self.metrics
        self._c = {
            "submitted": mx.counter("wmd_requests_submitted_total",
                                    "requests admitted to the queue"),
            "completed": mx.counter("wmd_requests_completed_total",
                                    "requests resolved with a result"),
            "rejected": mx.counter("wmd_requests_rejected_total",
                                   "backpressure rejections"),
            "failed": mx.counter("wmd_requests_failed_total",
                                 "requests whose dispatch raised"),
            "cancelled": mx.counter("wmd_requests_cancelled_total",
                                    "futures cancelled while queued"),
            "deadline_misses": mx.counter("wmd_deadline_misses_total",
                                          "requests served past deadline"),
            "quarantined": mx.counter("wmd_requests_quarantined_total",
                                      "invalid queries rejected at submit"),
            "degraded": mx.counter("wmd_requests_degraded_total",
                                   "requests answered bound-only"),
            "write_dispatches": mx.counter("wmd_write_dispatches_total",
                                           "merged add/remove dispatches"),
            "docs_added": mx.counter("wmd_docs_added_total",
                                     "docs durably acked via the writer "
                                     "lane"),
            "docs_removed": mx.counter("wmd_docs_removed_total",
                                       "ids durably logged for removal"),
        }
        self._c_disp = {
            trig: mx.counter("wmd_dispatches_total",
                             "batches cut, by trigger",
                             labels={"trigger": trig})
            for trig in ("fill", "window", "deadline", "drain")}
        self._c_phase = {
            ph: mx.counter("wmd_phase_seconds_total",
                           "engine wall seconds attributed per phase",
                           labels={"phase": ph})
            for ph in ("precompute", "solve", "bound", "rerank")}
        self._h_batch = mx.histogram("wmd_batch_size",
                                     "requests per dispatched batch",
                                     buckets=DEFAULT_SIZE_BUCKETS)
        self._h_latency = mx.histogram("wmd_request_latency_seconds",
                                       "submit -> result-set latency",
                                       buckets=DEFAULT_TIME_BUCKETS)
        self._g_queue = mx.gauge("wmd_queue_depth",
                                 "requests waiting (both lanes)")
        self._g_inflight = mx.gauge("wmd_in_flight",
                                    "requests inside the current dispatch")
        self._g_est = mx.gauge("wmd_service_estimate_seconds",
                               "EWMA dispatch wall time")
        # EWMA of the per-request deadline-miss indicator: one of the two
        # brownout overload signals (queue depth is the other)
        self._miss_ewma = 0.0
        # lazy min-heap of (deadline, seq, request): queued deadlines without
        # an O(queue) scan per wakeup; entries whose request already left the
        # queue (popped) are expired at read time
        self._dl_heap: list[tuple[float, int, _Request]] = []
        self._batch_hist: collections.Counter = collections.Counter()
        self._latencies = collections.deque(maxlen=latency_window)
        self._hit_rate_sum = 0.0
        self._hit_rate_n = 0
        self._service_est_s = 0.0             # combined (ServingStats)
        # per-op estimates for the deadline trigger: a pruned top-k
        # dispatch (bound + per-query rerank loop) costs orders of
        # magnitude more than a plain query_batch (and a WAL-fsync write
        # batch costs differently than either), and feeding one shared
        # EWMA would make plain deadlines fire absurdly early (degenerate
        # batch-of-1 cuts) and top-k deadlines far too late
        self._service_est_kind: dict[str, float] = {}
        self._warmed_shapes = 0
        self._warmup_compile_s: dict[str, float] | None = None
        self.batch_log: collections.deque[tuple[int, ...]] = \
            collections.deque(maxlen=batch_log_size)
        # (kind, Q, k) of recent dispatches: the program-shape counterpart
        # of batch_log, cross-checked against the warmup ShapeRegistry by
        # tests/test_warmup.py (every dispatched shape must be registered)
        self.shape_log: collections.deque[tuple[str, int, int | None]] = \
            collections.deque(maxlen=batch_log_size)

        self._thread = threading.Thread(target=self._run,
                                        name="wmd-coalescer", daemon=True)
        self._thread.start()

    # -- client side ------------------------------------------------------

    def submit(self, r: np.ndarray, *, deadline_ms: float | None = None,
               priority: int = 0, timeout: float | None = None) -> Future:
        """Enqueue one (V,) query histogram; returns a Future of its (N,)
        distance row. Thread-safe. ``deadline_ms`` overrides the default
        deadline; ``priority > 0`` routes via the priority lane; ``timeout``
        bounds a *blocking* backpressure wait (seconds)."""
        return self._submit(r, None, deadline_ms, priority, timeout)

    def submit_top_k(self, r: np.ndarray, k: int = 10, *,
                     deadline_ms: float | None = None, priority: int = 0,
                     timeout: float | None = None) -> Future:
        """Enqueue one top-k retrieval request; returns a Future of an
        ``(idx (k,), dist (k,))`` pair served by the two-tier pruned engine
        (`WMDService.top_k_batch(..., prune=True)`).

        Top-k requests coalesce with each other exactly like plain queries
        do: the dispatcher cuts *homogeneous* batches (one kind, one k), so
        a coalesced top-k batch is literally one ``top_k_batch(rs, k,
        prune=True)`` call -- the pruned engine's bitwise contract carries
        over unchanged. Under mixed traffic a cut stops at the first
        kind/k change (FIFO order is preserved; the next cut picks up the
        other run), so interleaving kinds costs batch size, not
        correctness."""
        if k < 1:
            raise ValueError("k must be >= 1")
        return self._submit(r, int(k), deadline_ms, priority, timeout,
                            op="top_k")

    def submit_add_docs(self, ids, docs, *, deadline_ms: float | None = None,
                        priority: int = 0,
                        timeout: float | None = None) -> Future:
        """Writer lane: enqueue a durable live-corpus upsert; the Future
        resolves to the number of docs acked (WAL-fsynced -- see
        `WMDService.add_docs`) once the write batch dispatches.

        Writes ride the same admission queue (FIFO order against queries
        is preserved, backpressure applies) but cut into their OWN
        homogeneous batches: a write dispatch merges consecutive queued
        add requests into one ``svc.add_docs`` call, so ingest bursts
        amortize WAL fsyncs exactly like query bursts amortize programs.
        Writes bypass the resilience guard -- durability is the corpus's
        WAL contract, and a degraded 'add' has no meaning."""
        if len(ids) != len(docs):
            raise ValueError(f"{len(ids)} ids but {len(docs)} docs")
        if not hasattr(self.svc, "add_docs"):
            raise ValueError("service has no live corpus (add_docs)")
        return self._submit((list(ids), list(docs)), None, deadline_ms,
                            priority, timeout, op="add")

    def submit_remove_docs(self, ids, *, deadline_ms: float | None = None,
                           priority: int = 0,
                           timeout: float | None = None) -> Future:
        """Writer lane: enqueue a durable live-corpus remove; the Future
        resolves to the number of ids durably logged (removing a
        never-added id is a logged no-op, so the count acks durability,
        not prior existence). Same batching/ordering rules as
        `submit_add_docs`."""
        if not hasattr(self.svc, "remove_docs"):
            raise ValueError("service has no live corpus (remove_docs)")
        return self._submit(list(ids), None, deadline_ms, priority,
                            timeout, op="remove")

    def _submit(self, r, k: int | None,
                deadline_ms: float | None, priority: int,
                timeout: float | None, op: str = "plain") -> Future:
        if self.validate and op in ("plain", "top_k"):
            try:
                if self._vocab_size is not None:
                    _guards.validate_query(r, self._vocab_size)
                elif (isinstance(r, np.ndarray)
                      and np.issubdtype(r.dtype, np.floating)
                      and not np.isfinite(r).all()):
                    raise _guards.InvalidQueryError(
                        "query has non-finite entries")
            except _guards.InvalidQueryError as e:
                with self._lock:
                    self._c["quarantined"].inc()
                # a quarantined request never opens a span (it is never
                # enqueued) but still leaves exactly one closed tree --
                # the chaos suite's submitted == closed invariant
                if self._tracer.enabled:
                    self._tracer.event("quarantine", op=op,
                                       error=str(e)[:200])
                    self._tracer.closed_request(status="quarantined", op=op)
                raise
        with self._lock:
            if self._closed:
                raise CoalescerClosedError("coalescer is shut down")
            if self.max_queue:
                deadline_wait = (None if timeout is None
                                 else time.monotonic() + timeout)
                while self._depth_locked() >= self.max_queue:
                    if self.backpressure == "reject":
                        self._c["rejected"].inc()
                        raise QueueFullError(
                            f"admission queue full ({self.max_queue})")
                    remaining = (None if deadline_wait is None
                                 else deadline_wait - time.monotonic())
                    if remaining is not None and remaining <= 0:
                        self._c["rejected"].inc()
                        raise QueueFullError(
                            f"blocked submit timed out after {timeout}s")
                    self._space.wait(timeout=remaining)
                    if self._closed:
                        raise CoalescerClosedError("coalescer is shut down")
            now = time.monotonic()
            dl_s = (self.default_deadline_s if deadline_ms is None
                    else deadline_ms / 1e3)
            req = _Request(seq=self._seq, r=r, future=Future(), t_submit=now,
                           deadline=None if dl_s is None else now + dl_s,
                           priority=priority, k=k, op=op)
            self._seq += 1
            (self._hi if priority > 0 else self._lo).append(req)
            if req.deadline is not None:
                heapq.heappush(self._dl_heap, (req.deadline, req.seq, req))
            self._c["submitted"].inc()
            self._g_queue.set(self._depth_locked())
            if self._tracer.enabled:
                self._tracer.begin_request(req.seq, t0=now, op=op, k=k,
                                           priority=priority)
            self._work.notify()
            return req.future

    def submit_many(self, rs: Sequence[np.ndarray], **kw) -> list[Future]:
        """Enqueue several queries in order (same kwargs as `submit`)."""
        return [self.submit(r, **kw) for r in rs]

    def warm_registry(self, *, ks: Sequence[int] = (),
                      kinds: Sequence[str] | None = None,
                      queries: Sequence[np.ndarray] | None = None,
                      seed: int = 0):
        """Precompile every program shape this coalescer can dispatch --
        pow2 Q buckets up to ``max_batch`` x kinds ("plain", plus "top_k"
        per k in ``ks``) -- via the `serving.warmup` shape registry, on the
        caller's thread. Call once before serving so no live dispatch pays
        compile time (first dispatches otherwise include it, which also
        skews the deadline trigger's service-time EWMA). Per-shape compile
        times are recorded and surface in `ServingStats.warmup_compile_s`.
        Returns the `WarmupReport`."""
        from repro.serving import warmup as _warmup
        registry = _warmup.ShapeRegistry.from_service(
            self.svc, max_batch=self.max_batch, ks=ks, kinds=kinds)
        report = _warmup.warm(self.svc, registry, queries=queries, seed=seed)
        self.record_warmup(report)
        return report

    def record_warmup(self, report) -> None:
        """Fold a `serving.warmup.WarmupReport` into the stats snapshot
        (idempotent per shape: repeated warmups merge by shape label)."""
        compile_s = report.compile_s_by_label()
        with self._lock:
            merged = dict(self._warmup_compile_s or {})
            merged.update(compile_s)
            self._warmup_compile_s = merged
            self._warmed_shapes = len(merged)

    def warm(self, qs: Sequence[np.ndarray]) -> None:
        """Deprecated shim: forwards to `warm_registry` (the one warmup
        code path). Compiles every plain pow2 Q bucket up to ``max_batch``;
        unlike the old ad-hoc loop, a short ``qs`` no longer truncates the
        bucket ladder (the registry pass cycles the queries to fill every
        bucket)."""
        if qs:
            self.warm_registry(queries=qs)

    def warm_top_k(self, qs: Sequence[np.ndarray], k: int) -> None:
        """Deprecated shim: forwards to `warm_registry` (top-k kind only),
        compiling the pruned engine's programs -- the per-pow2-bucket bound
        program + the shared rerank chunk program -- for this ``k``."""
        if qs:
            self.warm_registry(ks=(int(k),), kinds=("top_k",), queries=qs)

    # -- lifecycle --------------------------------------------------------

    def drain(self, timeout: float | None = None) -> None:
        """Flush the queue and block until it and the in-flight batch are
        empty (the coalescer stays open). Queued requests are dispatched
        immediately (*drain* trigger) rather than waiting out the coalescing
        window. Raises TimeoutError on timeout."""
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._lock:
            self._draining += 1
            self._work.notify()
            try:
                while self._depth_locked() or self._in_flight:
                    remaining = (None if deadline is None
                                 else deadline - time.monotonic())
                    if remaining is not None and remaining <= 0:
                        raise TimeoutError("drain timed out")
                    self._idle.wait(timeout=remaining)
            finally:
                self._draining -= 1

    def shutdown(self, *, drain: bool = True,
                 timeout: float | None = None) -> None:
        """Close admission and stop the dispatcher (idempotent). With
        ``drain`` the queue is flushed first; without, pending requests fail
        with `CoalescerClosedError`."""
        with self._lock:
            if not self._closed:
                self._closed = True
                if not drain:
                    for req in list(self._hi) + list(self._lo):
                        req.popped = True
                        if req.future.set_running_or_notify_cancel():
                            req.future.set_exception(
                                CoalescerClosedError("shutdown(drain=False)"))
                            self._c["failed"].inc()
                            self._tracer.end_request(
                                req.seq, status="failed",
                                reason="shutdown(drain=False)")
                        else:                  # client already cancelled it
                            self._c["cancelled"].inc()
                            self._tracer.end_request(req.seq,
                                                     status="cancelled")
                    self._hi.clear()
                    self._lo.clear()
                self._work.notify_all()
                self._space.notify_all()
                self._idle.notify_all()
        self._thread.join(timeout=timeout)

    def __enter__(self) -> "QueryCoalescer":
        return self

    def __exit__(self, *exc) -> None:
        self.shutdown(drain=True)

    # -- observability ----------------------------------------------------

    @property
    def guard(self):
        """The `EngineGuard` dispatches route through (None without a
        resilience policy) -- the watchdog's trip() target."""
        return self._guard

    def stats(self) -> ServingStats:
        """Consistent snapshot of counters + latency percentiles. Only the
        raw state is copied under the lock; the percentile math (O(latency
        window)) runs after release so a monitoring poll never stalls
        submitters or the dispatcher."""
        with self._lock:
            scalars = dict(
                queue_depth=self._depth_locked(),
                in_flight=self._in_flight,
                **{f: int(self._c[f].value) for f in (
                    "submitted", "completed", "rejected", "failed",
                    "cancelled", "deadline_misses", "quarantined",
                    "degraded", "write_dispatches", "docs_added",
                    "docs_removed")})
            counts = {t: int(c.value) for t, c in self._c_disp.items()}
            hist = dict(sorted(self._batch_hist.items()))
            lat_snap = list(self._latencies)
            hit_rate = (self._hit_rate_sum / self._hit_rate_n
                        if self._hit_rate_n else None)
            est_ms = self._service_est_s * 1e3
            warmed = self._warmed_shapes
            warm_s = (dict(self._warmup_compile_s)
                      if self._warmup_compile_s is not None else None)
        # the guard has its own lock; never nest it inside ours
        rs = self._guard.stats() if self._guard is not None else None
        lat = np.asarray(lat_snap, np.float64) * 1e3
        n_disp = sum(counts.values())
        total_in_batches = sum(q * c for q, c in hist.items())
        pct = (lambda p: float(np.percentile(lat, p))) if lat.size \
            else (lambda p: 0.0)
        return ServingStats(
            **scalars,
            dispatches=n_disp,
            dispatch_fill=counts["fill"],
            dispatch_window=counts["window"],
            dispatch_deadline=counts["deadline"],
            dispatch_drain=counts["drain"],
            batch_size_hist=hist,
            mean_batch_size=(total_in_batches / n_disp) if n_disp else 0.0,
            latency_ms_mean=float(lat.mean()) if lat.size else 0.0,
            latency_ms_p50=pct(50),
            latency_ms_p95=pct(95),
            latency_ms_p99=pct(99),
            hit_rate=hit_rate,
            service_estimate_ms=est_ms,
            warmed_shapes=warmed,
            warmup_compile_s=warm_s,
            retries=rs.retries if rs else 0,
            breaker_transitions=rs.breaker_transitions if rs else 0,
            breaker_open=rs.breaker_open if rs else 0,
            brownout_active=rs.brownout_active if rs else False)

    # -- dispatcher -------------------------------------------------------

    def _depth_locked(self) -> int:
        return len(self._hi) + len(self._lo)

    def _check_locked(self, now: float) -> tuple[str | None, float | None]:
        """(trigger satisfied right now | None, earliest future fire time).

        O(1) amortized: the oldest queued submit time is the head of each
        FIFO lane and the earliest deadline is the top of the lazy deadline
        heap (stale entries for requests that already left the queue are
        expired here), so the dispatcher never scans the queue.
        """
        n = self._depth_locked()
        if n == 0:
            return None, None
        if n >= self.max_batch:     # full bucket: attribute to fill even
            return "fill", None     # mid-drain/shutdown
        if self._closed or self._draining:
            return "drain", None
        oldest = min(dq[0].t_submit for dq in (self._hi, self._lo) if dq)
        t_window = oldest + self.window_s
        while self._dl_heap and (self._dl_heap[0][2].popped
                                 or self._dl_heap[0][2].future.cancelled()):
            heapq.heappop(self._dl_heap)   # left the queue, or will be
            # discarded at pop time -- either way its deadline must not
            # drive a premature dispatch
        if self._dl_heap:
            # budget with the estimate of the deadline request's OWN op
            # (top-k / plain / write dispatches cost very differently);
            # fall back to the combined EWMA before that op's first sample
            est = self._service_est_kind.get(
                self._dl_heap[0][2].op, self._service_est_s)
            t_deadline = self._dl_heap[0][0] - est - _DEADLINE_MARGIN_S
        else:
            t_deadline = float("inf")
        if now >= t_deadline:
            return "deadline", None
        if now >= t_window:
            return "window", None
        return None, min(t_window, t_deadline)

    def _pop_batch_locked(self) -> list[_Request]:
        """Cut one batch: priority lane first, FIFO within each lane, and
        HOMOGENEOUS in kind -- the cut stops at the first request whose
        (op, k) differs from the batch head's, so a batch is always one
        plain ``query_batch``, one ``top_k_batch(k, prune=True)``, one
        merged ``add_docs``, or one merged ``remove_docs`` call (the next
        cut picks up the other run; FIFO order is never violated --
        which, for the writer lane, is exactly the read-your-writes
        ordering argument: a query submitted after a write ack dispatches
        after the write applied). Requests whose future a client already
        cancelled are discarded here regardless of kind (never
        dispatched, never resolved again -- `set_running_or_notify_cancel`
        also locks the survivors against a later cancel, so the
        dispatcher's fan-out can never hit InvalidStateError)."""
        batch: list[_Request] = []
        kind: object = None
        now = time.monotonic()
        while self._depth_locked() and len(batch) < self.max_batch:
            lane = self._hi or self._lo
            head = lane[0]
            if batch and not head.future.cancelled() \
                    and (head.op, head.k) != kind:
                break               # kind change: leave it for the next cut
            rq = lane.popleft()
            rq.popped = True
            if rq.future.set_running_or_notify_cancel():
                kind = (rq.op, rq.k)
                batch.append(rq)
                if self._tracer.enabled:    # queue wait ends at the cut
                    self._tracer.add_span(rq.seq, "queue", rq.t_submit, now)
            else:
                self._c["cancelled"].inc()
                self._tracer.end_request(rq.seq, t1=now, status="cancelled")
        self._in_flight = len(batch)
        self._g_queue.set(self._depth_locked())
        self._g_inflight.set(len(batch))
        self._space.notify_all()
        return batch

    def _run(self) -> None:
        while True:
            with self._lock:
                while True:
                    if self._closed and not self._depth_locked():
                        self._idle.notify_all()
                        return
                    cause, t_next = self._check_locked(time.monotonic())
                    if cause is not None:
                        break
                    if t_next is not None:
                        self._work.wait(
                            timeout=max(0.0, t_next - time.monotonic()))
                    else:
                        self._work.wait()
                batch = self._pop_batch_locked()
                if not batch:            # every popped request was cancelled
                    self._idle.notify_all()
                    continue
                depth = self._depth_locked()   # post-cut backlog: the
            self._dispatch(batch, cause, depth)  # brownout queue signal

    def _dispatch(self, batch: list[_Request], cause: str,
                  queue_depth: int = 0) -> None:
        """Run one query_batch on the dispatcher thread and fan results out.

        Exactly ``svc.query_batch([r for each request, in batch order])`` --
        nothing is reordered or rewritten between the queue and the engine,
        which is the whole bitwise-identity argument: a direct query_batch
        of the same queries in the same order runs the same program on the
        same inputs.

        Top-k batches (homogeneous by the pop rule) run
        ``svc.top_k_batch(rs, k, prune=True)`` instead and fan out
        ``(idx, dist)`` row pairs -- same determinism argument, now backed
        by the pruned engine's bitwise-identical-to-exact-scan contract.

        Counters are updated BEFORE the result fan-out so a stats() call
        racing a just-resolved future can only see counts that lead the
        futures, never lag them; in_flight is cleared (and drain() woken)
        only AFTER the fan-out, so drain() implies every dispatched future
        is resolved."""
        t0 = time.monotonic()
        err: BaseException | None = None
        results: list = []
        kind = batch[0].k
        op = batch[0].op
        kind_str = op
        degraded: DegradedResult | None = None
        n_added = n_removed = 0
        try:
            if op == "add":
                # writer lane: merge the batch into ONE durable add_docs
                # call (one WAL record + fsync for the whole burst); each
                # future acks its own docs. Writes bypass the resilience
                # guard -- durability is the corpus WAL's contract, and a
                # crash surfaces as recovery, not as a retryable fault.
                ids: list = []
                docs: list = []
                for rq in batch:
                    ids.extend(rq.r[0])
                    docs.extend(rq.r[1])
                self.svc.add_docs(ids, docs)
                results = [len(rq.r[0]) for rq in batch]
                n_added = len(ids)
            elif op == "remove":
                ids = []
                for rq in batch:
                    ids.extend(rq.r)
                self.svc.remove_docs(ids)
                results = [len(rq.r) for rq in batch]
                n_removed = len(ids)
            elif self._guard is not None:
                # resilient route: breaker ladder + retry + brownout
                # (serving.resilience). Rung 0 is the exact call below, so
                # fault-free dispatches stay bitwise identical.
                res = self._guard.dispatch(
                    kind_str, [rq.r for rq in batch], k=kind,
                    queue_depth=queue_depth, miss_ewma=self._miss_ewma)
                if isinstance(res, DegradedResult):
                    degraded, res = res, res.value
            elif kind is None:
                res = self.svc.query_batch([rq.r for rq in batch])
            else:
                res = self.svc.top_k_batch(
                    [rq.r for rq in batch], kind, prune=True)
            if op == "plain":
                results = [res[i] for i in range(len(batch))]
            elif op == "top_k":
                idx, dist = res
                results = [(idx[i], dist[i]) for i in range(len(batch))]
        except BaseException as e:            # noqa: BLE001 -- fan out to
            err = e                           # futures, keep serving
        t_done = time.monotonic()
        with self._lock:
            is_write = op in ("add", "remove")
            info = getattr(self.svc, "last_batch_stats", None) or {}
            # writes don't run the query engine: last_batch_stats is the
            # PREVIOUS query dispatch's -- never fold it into hit_rate
            if err is None and not is_write and "hit_rate" in info:
                self._hit_rate_sum += float(info["hit_rate"])
                self._hit_rate_n += 1
            ewma = 0.7 * self._service_est_s + 0.3 * (t_done - t0)
            self._service_est_s = ewma if self._service_est_s else t_done - t0
            prev = self._service_est_kind.get(op)
            self._service_est_kind[op] = (
                t_done - t0 if prev is None
                else 0.7 * prev + 0.3 * (t_done - t0))
            self._c_disp[cause].inc()
            self._batch_hist[len(batch)] += 1
            self._h_batch.observe(len(batch))
            self._g_est.set(self._service_est_s)
            self.batch_log.append(tuple(rq.seq for rq in batch))
            prune = {}
            if is_write:
                self._c["write_dispatches"].inc()
                if err is None:
                    self._c["docs_added"].inc(n_added)
                    self._c["docs_removed"].inc(n_removed)
            else:
                # program-shape telemetry is query-only: a write dispatch
                # compiles nothing, so it must not trip the warmup
                # shape-coverage cross-check
                self.shape_log.append((op, len(batch), batch[0].k))
                if err is None:
                    if op == "top_k":
                        prune = getattr(self.svc, "last_prune_stats",
                                        None) or {}
                    for key, ph in (("precompute_s", "precompute"),
                                    ("solve_s", "solve")):
                        if key in info:
                            self._c_phase[ph].inc(float(info[key]))
                    for key, ph in (("bound_s", "bound"),
                                    ("rerank_s", "rerank")):
                        if key in prune:
                            self._c_phase[ph].inc(float(prune[key]))
            missed_by_seq: dict[int, bool] = {}
            for rq in batch:
                if err is None:
                    self._c["completed"].inc()
                    if degraded is not None:
                        self._c["degraded"].inc()
                    self._latencies.append(t_done - rq.t_submit)
                    self._h_latency.observe(t_done - rq.t_submit)
                    missed = (rq.deadline is not None
                              and t_done > rq.deadline)
                    missed_by_seq[rq.seq] = missed
                    if missed:
                        self._c["deadline_misses"].inc()
                    self._miss_ewma = (0.9 * self._miss_ewma
                                       + 0.1 * float(missed))
                else:
                    self._c["failed"].inc()
        if self._tracer.enabled:
            rung = None
            if self._guard is not None and self._guard.dispatch_log:
                rung = self._guard.dispatch_log[-1][1]
            pre_s = float(info.get("precompute_s", 0.0)) \
                if err is None and not is_write else 0.0
            solve_s = float(info.get("solve_s", 0.0)) \
                if err is None and not is_write else 0.0
            status = ("failed" if err is not None
                      else "degraded" if degraded is not None else "ok")
            for rq in batch:
                self._tracer.add_span(
                    rq.seq, "dispatch", t0, t_done, op=op, cause=cause,
                    batch=len(batch), rung=rung,
                    hit_rate=info.get("hit_rate"),
                    tier=(degraded.tier if degraded is not None else None))
                if pre_s:
                    self._tracer.add_span(
                        rq.seq, "precompute", t0, t0 + pre_s,
                        hits=info.get("hits"), misses=info.get("misses"))
                if solve_s:
                    self._tracer.add_span(
                        rq.seq, "solve", t0 + pre_s, t0 + pre_s + solve_s,
                        n_iter=getattr(getattr(self.svc, "cfg", None),
                                       "max_iter", None),
                        bound_s=prune.get("bound_s"),
                        rerank_s=prune.get("rerank_s"),
                        solves_avoided=prune.get("solves_avoided"))
                self._tracer.end_request(
                    rq.seq, t1=t_done, status=status,
                    deadline_missed=missed_by_seq.get(rq.seq, False),
                    reason=(degraded.reason if degraded is not None
                            else type(err).__name__ if err is not None
                            else None))
        if self._heartbeat is not None:
            try:
                self._heartbeat(kind_str, t_done - t0, err is None)
            except Exception:                 # noqa: BLE001 -- monitoring
                pass                          # must never kill serving
        for i, rq in enumerate(batch):
            if err is None:
                if degraded is not None:
                    rq.future.set_result(DegradedResult(
                        value=results[i], reason=degraded.reason,
                        tier=degraded.tier))
                else:
                    rq.future.set_result(results[i])
            else:
                rq.future.set_exception(err)
        with self._lock:
            self._in_flight = 0
            self._g_inflight.set(0)
            self._idle.notify_all()
