"""Serving steps: sharded prefill + decode under pjit.

``build_serve_fns`` returns jit'd prefill / decode with explicit shardings:
batch over (pod, data); KV caches batch-sharded (stack axis preserved);
params per the same partitioning rules as training. The dry-run lowers these
functions for the prefill_32k / decode_32k / long_500k cells.
"""
from __future__ import annotations

import functools

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.distributed import partitioning
from repro.models.registry import ModelAPI


def build_serve_fns(model: ModelAPI, mesh: Mesh, *, max_len: int):
    rep = NamedSharding(mesh, P())

    def prefill(params, batch):
        return model.prefill(params, batch, max_len=max_len)

    def decode(params, cache, tokens):
        return model.decode(params, cache, tokens)

    # shardings derived from abstract cache structure
    def cache_struct(batch_size):
        return jax.eval_shape(
            functools.partial(model.init_cache, batch_size, max_len))

    def shardings_for(batch_size):
        cstruct = cache_struct(batch_size)
        cshard = partitioning.cache_shardings(mesh, cstruct)
        pstruct = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        pshard = partitioning.param_shardings(mesh, pstruct)
        return pshard, cshard

    def jit_prefill(batch_size):
        pshard, cshard = shardings_for(batch_size)
        return jax.jit(prefill,
                       in_shardings=(pshard, None),
                       out_shardings=(rep, cshard))

    def jit_decode(batch_size, *, donate_cache: bool = True):
        pshard, cshard = shardings_for(batch_size)
        tok_shard = NamedSharding(mesh, partitioning.sanitize_spec(
            mesh, partitioning.batch_spec(mesh, 2), (batch_size, 1)))
        return jax.jit(decode,
                       in_shardings=(pshard, cshard, tok_shard),
                       out_shardings=(None, cshard),
                       donate_argnums=(1,) if donate_cache else ())

    return jit_prefill, jit_decode
