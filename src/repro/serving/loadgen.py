"""Load generators for the WMD serving stack (open-loop and closed-loop).

Two canonical client models drive `serving.coalescer.QueryCoalescer` (or any
``submit(r) -> Future`` callable, including a synchronous baseline wrapped to
return finished futures):

* **open loop** (`open_loop`) -- Poisson arrivals at ``rate_qps``: requests
  fire on an exponential-interarrival schedule *independent of completions*,
  the serving-systems model of "millions of users" (load does not politely
  wait for the server). Under saturation the queue grows and backpressure
  engages; rejected submits (`QueueFullError`) are counted, not retried.
* **closed loop** (`closed_loop`) -- ``concurrency`` worker threads each
  submit-and-wait in a loop: offered load adapts to service rate, the model
  of a fixed client pool. At high concurrency this is the *saturating load*
  used by the bench's throughput headline (the coalescer sees a full queue
  and cuts fill-triggered batches back to back).

Both measure **client-side** latency (submit call -> future resolved, via a
done-callback, so it includes queueing + coalescing + solve) and return a
`LoadgenResult` with throughput and percentiles. Query streams come from any
iterable of (V,) histograms -- `data.zipf_query_stream` is the realistic
skewed source (take ``itertools.islice(stream, n)``).

Used by `benchmarks/bench_serving.py` (arrival-rate x window sweep ->
``BENCH_serving.json``), `launch/serve.py --coalesce-window-ms` (the serving
loop) and the `--coalesce` demo in examples/wmd_query_service.py.
"""
from __future__ import annotations

import dataclasses
import itertools
import threading
import time
from typing import Callable, Iterable

import numpy as np

from repro.serving.coalescer import QueueFullError


@dataclasses.dataclass
class LoadgenResult:
    """Client-side view of one load-generation run."""
    mode: str                      # "open" | "closed"
    offered_qps: float             # open: configured rate; closed: achieved
    duration_s: float              # first submit -> last completion
    submitted: int
    completed: int
    rejected: int                  # QueueFullError submits (open loop)
    failed: int                    # futures that resolved to an exception
    latencies_ms: np.ndarray       # per completed request, submit order
    results: list | None           # per-request rows iff keep_results

    @property
    def throughput_qps(self) -> float:
        return self.completed / self.duration_s if self.duration_s else 0.0

    def percentile_ms(self, p: float) -> float:
        return (float(np.percentile(self.latencies_ms, p))
                if self.latencies_ms.size else 0.0)

    def summary(self) -> dict:
        """The JSON-friendly fields the bench artifact records."""
        return {"mode": self.mode, "offered_qps": self.offered_qps,
                "duration_s": self.duration_s, "submitted": self.submitted,
                "completed": self.completed, "rejected": self.rejected,
                "failed": self.failed,
                "throughput_qps": self.throughput_qps,
                "latency_ms_mean": (float(self.latencies_ms.mean())
                                    if self.latencies_ms.size else 0.0),
                "latency_ms_p50": self.percentile_ms(50),
                "latency_ms_p95": self.percentile_ms(95),
                "latency_ms_p99": self.percentile_ms(99)}


class _Tracker:
    """Per-request completion bookkeeping shared by both loops."""

    def __init__(self, keep_results: bool):
        self.lock = threading.Lock()
        self.done = threading.Condition(self.lock)
        self.latency_by_idx: dict[int, float] = {}
        self.results: dict[int, np.ndarray] | None = \
            {} if keep_results else None
        self.failed = 0
        self.pending = 0
        self.t_last_done = 0.0

    def attach(self, idx: int, t_submit: float, fut) -> None:
        with self.lock:
            self.pending += 1

        def _on_done(f, idx=idx, t_submit=t_submit):
            t = time.monotonic()
            with self.lock:
                if f.exception() is not None:
                    self.failed += 1
                else:
                    self.latency_by_idx[idx] = t - t_submit
                    if self.results is not None:
                        self.results[idx] = f.result()
                self.t_last_done = max(self.t_last_done, t)
                self.pending -= 1
                self.done.notify_all()
        fut.add_done_callback(_on_done)

    def wait_all(self) -> None:
        with self.lock:
            while self.pending:
                self.done.wait()

    def finish(self, *, mode: str, offered_qps: float, t_start: float,
               submitted: int, rejected: int) -> LoadgenResult:
        self.wait_all()
        with self.lock:
            order = sorted(self.latency_by_idx)
            lat = np.asarray([self.latency_by_idx[i] for i in order]) * 1e3
            results = ([self.results[i] for i in order]
                       if self.results is not None else None)
            duration = max(self.t_last_done - t_start, 1e-9)
            return LoadgenResult(
                mode=mode, offered_qps=offered_qps, duration_s=duration,
                submitted=submitted, completed=len(order),
                rejected=rejected, failed=self.failed,
                latencies_ms=lat, results=results)


def open_loop(submit: Callable, queries: Iterable[np.ndarray], *,
              rate_qps: float, n_requests: int | None = None,
              seed: int = 0, keep_results: bool = False) -> LoadgenResult:
    """Poisson open-loop driver: submit ``n_requests`` queries at
    exponential interarrivals of mean ``1/rate_qps``, never waiting for
    completions. ``queries`` is any iterable of (V,) histograms (truncated
    to ``n_requests`` when given). The schedule is seeded and absolute
    (submission k fires at t0 + sum of the first k gaps), so a slow submit
    makes the driver catch up rather than silently lower the offered rate.
    """
    qs = list(queries if n_requests is None
              else itertools.islice(queries, n_requests))
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / rate_qps, size=len(qs)))
    tracker = _Tracker(keep_results)
    rejected = submitted = 0
    t0 = time.monotonic()
    for r, at in zip(qs, arrivals):
        delay = t0 + at - time.monotonic()
        if delay > 0:
            time.sleep(delay)
        t_submit = time.monotonic()
        try:
            fut = submit(r)
        except QueueFullError:
            rejected += 1
            continue
        submitted += 1
        tracker.attach(submitted - 1, t_submit, fut)
    return tracker.finish(mode="open", offered_qps=rate_qps, t_start=t0,
                          submitted=submitted, rejected=rejected)


def closed_loop(submit: Callable, queries: Iterable[np.ndarray], *,
                concurrency: int = 4,
                keep_results: bool = False) -> LoadgenResult:
    """Fixed-concurrency closed-loop driver: ``concurrency`` threads each
    take the next query, submit, and block on the result before taking
    another. ``submit`` may return a Future or the result itself (so a
    synchronous per-query baseline plugs in unchanged)."""
    qs = list(queries)
    tracker = _Tracker(keep_results)
    it_lock = threading.Lock()
    it = iter(enumerate(qs))
    counts = {"submitted": 0, "rejected": 0}
    t0 = time.monotonic()

    def worker():
        while True:
            with it_lock:
                try:
                    idx, r = next(it)
                except StopIteration:
                    return
            t_submit = time.monotonic()
            try:
                out = submit(r)
            except QueueFullError:       # closed loop shouldn't hit this,
                with it_lock:            # but never let a worker die on it
                    counts["rejected"] += 1
                continue
            with it_lock:
                counts["submitted"] += 1
            if hasattr(out, "add_done_callback"):
                tracker.attach(idx, t_submit, out)
                try:
                    out.result()         # closed loop: wait before next
                except Exception:        # noqa: BLE001 -- counted failed by
                    pass                 # the done-callback; keep draining
            else:                        # synchronous baseline path
                t = time.monotonic()
                with tracker.lock:
                    tracker.latency_by_idx[idx] = t - t_submit
                    if tracker.results is not None:
                        tracker.results[idx] = out
                    tracker.t_last_done = max(tracker.t_last_done, t)

    threads = [threading.Thread(target=worker, daemon=True)
               for _ in range(concurrency)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    res = tracker.finish(mode="closed", offered_qps=0.0, t_start=t0,
                         submitted=counts["submitted"],
                         rejected=counts["rejected"])
    res.offered_qps = res.throughput_qps    # closed loop: offered == served
    return res
