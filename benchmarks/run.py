"""Benchmark harness -- one module per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run``  prints ``name,us_per_call,derived``
CSV rows. Roofline terms (the TPU-side performance statement) come from the
dry-run artifacts -- see launch/roofline.py and EXPERIMENTS.md.
"""
import sys
import traceback


def main() -> None:
    from benchmarks import (bench_asymptotic, bench_fusion, bench_hotspots,
                            bench_impl_comparison, bench_kernels,
                            bench_padding, bench_query_batch, bench_scaling)
    print("name,us_per_call,derived")
    modules = [
        ("fig8", bench_impl_comparison),
        ("table1", bench_hotspots),
        ("fig9", bench_fusion),
        ("fig10", bench_scaling),
        ("table2", bench_asymptotic),
        ("kernels", bench_kernels),
        ("padding", bench_padding),
        ("qbatch", bench_query_batch),
    ]
    failed = []
    for name, mod in modules:
        try:
            mod.run()
        except Exception as e:  # report and continue; harness must finish
            failed.append(name)
            print(f"{name}/ERROR,0.0,{type(e).__name__}:{e}",
                  file=sys.stderr)
            traceback.print_exc(file=sys.stderr)
    if failed:
        sys.exit(f"benchmarks failed: {failed}")


if __name__ == '__main__':
    main()
