"""ELL padding waste at dbpedia-like densities + the length-bucketing fix.

The paper's CSR has zero padding but needs atomics; plain ELL pays the
lognormal tail (~4x slots/nnz measured); power-of-two length bucketing
(beyond-paper, core.formats.bucket_by_length) recovers most of it while
keeping equal-shape tiles. The loop-time benchmark shows the win is real
compute, not just memory."""
from __future__ import annotations

import functools

from benchmarks.common import emit, timeit, wmd_problem
from repro.core import bucket_by_length, sinkhorn_wmd_sparse
from repro.data.corpus import make_corpus


def run() -> dict:
    out = {}
    for mean_words, tag in ((35.0, "dbpedia_like"), (12.0, "tweets"),
                            (80.0, "long_docs")):
        data = make_corpus(vocab_size=20_000, embed_dim=8, num_docs=1024,
                           num_queries=1, mean_words=mean_words, seed=3)
        slots_global = data.ell.cols.size / max(data.nnz, 1)
        bucketed = bucket_by_length(data.ell)
        slots_bucketed = bucketed.total_slots / max(data.nnz, 1)
        emit(f"padding/{tag}", 0.0,
             f"slots_per_nnz_global={slots_global:.2f};"
             f"bucketed={slots_bucketed:.2f}")
        out[tag] = (slots_global, slots_bucketed)

    # end-to-end: solver on global ELL vs per-bucket solve with a SHARED
    # precompute (first attempt re-ran the V-sized precompute per bucket and
    # was 0.59x -- refuted hypothesis, logged in EXPERIMENTS.md §Perf)
    import jax
    import jax.numpy as jnp
    from repro.core import precompute
    from repro.core.sparse_sinkhorn import sinkhorn_wmd_sparse_pre
    p = wmd_problem(docs=2048)
    base = functools.partial(sinkhorn_wmd_sparse, lamb=1.0, max_iter=10,
                             impl="fused")
    t_global = timeit(base, p["sel"], p["r_sel"], p["cols"], p["vals"],
                      p["vecs"])
    bk = bucket_by_length(p["ell"])
    bucket_arrays = [(jnp.asarray(b.cols), jnp.asarray(b.vals))
                     for b in bk.buckets]

    @jax.jit
    def bucketed_solve():
        pre = precompute(p["sel"], p["r_sel"], p["vecs"], 1.0)  # ONCE
        return [sinkhorn_wmd_sparse_pre(pre, cols, vals, 10)
                for cols, vals in bucket_arrays]

    t_bucketed = timeit(bucketed_solve)
    emit("padding/solver_global_ell", t_global * 1e6, "baseline")
    emit("padding/solver_bucketed_shared_pre", t_bucketed * 1e6,
         f"speedup={t_global / t_bucketed:.2f}x")
    return out
