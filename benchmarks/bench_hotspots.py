"""Table I analogue: per-phase hotspot profile of the solver.

Paper (Xeon, dense python): cdist 1.4%, SDDMM-ish line 91.9% + 6.1%, SpMM
0.5%. The sparse algorithm flips the profile -- the convergence loop stops
dominating. Phases timed: precompute (cdist+K), loop (type1 x iters),
final (type2)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from benchmarks.common import emit, timeit, wmd_problem
from repro.core import precompute
from repro.core.sparse_sinkhorn import (pad_k, safe_recip, sddmm_spmm_type1,
                                        sddmm_spmm_type2)

ITERS = 10


def run() -> dict:
    p = wmd_problem()

    pre_fn = jax.jit(functools.partial(precompute, p["sel"], p["r_sel"],
                                       p["vecs"], 1.0))
    pre = pre_fn()
    k_pad, km_pad = pad_k(pre.K), pad_k(pre.KM)
    x0 = jnp.full((p["v_r"], p["docs"]), 1.0 / p["v_r"], jnp.float32)

    @jax.jit
    def loop(k_pad, r, x, cols, vals):
        def body(_, x):
            return sddmm_spmm_type1(k_pad, r, safe_recip(x), cols, vals)
        return jax.lax.fori_loop(0, ITERS, body, x)

    @jax.jit
    def final(k_pad, km_pad, x, cols, vals):
        return sddmm_spmm_type2(k_pad, km_pad, safe_recip(x), cols, vals)

    x = loop(k_pad, pre.r, x0, p["cols"], p["vals"])
    t_pre = timeit(pre_fn)
    t_loop = timeit(loop, k_pad, pre.r, x0, p["cols"], p["vals"])
    t_final = timeit(final, k_pad, km_pad, x, p["cols"], p["vals"])
    total = t_pre + t_loop + t_final
    emit("table1/precompute_cdist_K", t_pre * 1e6,
         f"pct={100 * t_pre / total:.1f}%")
    emit("table1/loop_sddmm_spmm_t1", t_loop * 1e6,
         f"pct={100 * t_loop / total:.1f}%;per_iter_us={t_loop / ITERS * 1e6:.1f}")
    emit("table1/final_sddmm_spmm_t2", t_final * 1e6,
         f"pct={100 * t_final / total:.1f}%")
    return {"pre": t_pre, "loop": t_loop, "final": t_final}
