"""Cascade-pruned retrieval: tiered-bound top-k vs the exact full scan.

    PYTHONPATH=src python benchmarks/bench_prune.py [--tiny] \
        [--docs 4096] [--k 16] [--n-sweep 1024,2048,4096] \
        [--out BENCH_prune.json]

Per batch of Zipf queries three routes run on the same inputs:
  * ``pruned``    -- `WMDService.top_k_batch(prune=True)`: the retrieval
                     cascade (tier-0 centroid screen -> LC-RWMD ->
                     doc-side RWMD; see core.cascade / docs), then the
                     exact Sinkhorn rerank only on the candidate prefix,
                     in fixed prune_chunk doc blocks in ascending-bound
                     order.
  * ``scan``      -- `top_k_scan_batch`: the SAME chunked rerank programs
                     over every doc (bound order, no pruning) -- the
                     bitwise oracle. Pruned must equal it exactly
                     (asserted on EVERY batch: the exactness contract).
  * ``full``      -- the production full scan: one (Q, N) `query_batch`
                     program + tie-deterministic selection. The end-to-end
                     baseline a deployed retriever would otherwise run.

Headline fields: ``solves_avoided`` (fraction of the Q x N exact Sinkhorn
solves the cascade eliminated -- the paper-style work metric, machine
independent) and ``speedup_vs_full`` / ``speedup_vs_scan`` (end-to-end
wall-clock, interleaved-round medians). Each point also carries the
per-tier funnel (``tiers``: survivors and solves-avoided per tier, alone
and cumulative) so a regression can be blamed on the tier that widened.
``--tiny`` is the CI smoke shape and *gates*: solves_avoided must be
>= 0.85 (exit 1 otherwise), per the cascade's acceptance bar; the bitwise
gate runs at every scale. ``--n-sweep`` re-runs the whole bench at
several corpus sizes to expose how avoidance scales with N (the per-query
ceiling is 1 - chunk/N: one chunk must always be solved). At the headline
defaults (N=4096, chunk=32, ceiling 0.992) the cascade lands ~0.96.

The corpus matters: solves-avoided is a geometry property (how well the
tier bounds separate docs), so the artifact records the corpus shape
alongside the numbers. Longer docs separate better (more far-word mass),
which is why the defaults keep the generator's paper-ish mean_words=35.

Self-contained on purpose (no benchmarks.common import): CI invokes it as
a script with only the installed `repro` package on the path.
"""
from __future__ import annotations

import argparse
import json
import sys
import time


def bench_interleaved(calls: dict, *, warmup: int = 1, rounds: int = 3):
    """Median wall seconds per call, measured round-robin across variants."""
    for fn in calls.values():
        for _ in range(warmup):
            fn()
    times = {name: [] for name in calls}
    for _ in range(rounds):
        for name, fn in calls.items():
            t0 = time.perf_counter()
            fn()
            times[name].append(time.perf_counter() - t0)
    return {name: sorted(ts)[len(ts) // 2] for name, ts in times.items()}


def run(*, vocab: int = 2048, docs: int = 4096, q: int = 8, k: int = 16,
        query_words: int = 13, v_r: int = 16, mean_words: float = 35.0,
        zipf_s: float = 1.3, cache_capacity: int = 2048,
        mcache_capacity: int = 2048, prune_chunk: int = 32,
        batches: int = 3, rounds: int = 3,
        gate_avoided: float | None = None, out: str | None = None) -> dict:
    import numpy as np
    from repro.configs.sinkhorn_wmd import WMDConfig
    from repro.data import make_corpus, zipf_query_stream
    from repro.launch.mesh import make_mesh
    from repro.serving import WMDService

    cfg = WMDConfig(name="bench-prune", vocab_size=vocab, embed_dim=64,
                    num_docs=docs, nnz_max=64, v_r=v_r, lamb=1.0,
                    max_iter=15)
    data = make_corpus(vocab_size=vocab, embed_dim=cfg.embed_dim,
                       num_docs=docs, num_queries=1,
                       query_words=query_words, mean_words=mean_words,
                       seed=0)
    mesh = make_mesh((1, 1), ("data", "model"))
    svc = WMDService(mesh=mesh, cfg=cfg, vecs=data.vecs, ell=data.ell,
                     cache_capacity=cache_capacity,
                     mcache_capacity=mcache_capacity,
                     prune_chunk=prune_chunk)
    stream = zipf_query_stream(vocab_size=vocab, query_words=query_words,
                               s=zipf_s, seed=1)
    results = {"vocab": vocab, "docs": docs, "Q": q, "k": k, "v_r": v_r,
               "query_words": query_words, "mean_words": mean_words,
               "nnz_max": data.ell.nnz_max, "zipf_s": zipf_s,
               "max_iter": cfg.max_iter, "prune_chunk": prune_chunk,
               "cache_capacity": cache_capacity,
               "mcache_capacity": mcache_capacity, "points": [],
               "note": ("per batch: pruned top-k asserted bitwise equal to "
                        "the exhaustive chunked scan (the exactness "
                        "contract) and set-equal to the one-program full "
                        "scan; solves_avoided is the fraction of Q x N "
                        "exact Sinkhorn solves the cascade eliminated "
                        "(per-query ceiling 1 - chunk/N); tiers is the "
                        "per-tier funnel. Timing: interleaved-round "
                        "medians on the last batch's queries.")}
    last_qs = None
    for b in range(batches):
        qs = [next(stream) for _ in range(q)]
        last_qs = qs
        idx_p, d_p = svc.top_k_batch(qs, k, prune=True)
        ps = dict(svc.last_prune_stats)
        hit_rate = svc.last_batch_stats.get("hit_rate", 0.0)
        idx_s, d_s = svc.top_k_scan_batch(qs, k)
        bitwise = (np.array_equal(idx_p, idx_s)
                   and np.array_equal(d_p, d_s))
        assert bitwise, "pruned top-k must be bitwise equal to the scan"
        idx_f, d_f = svc.top_k_batch(qs, k)
        full_match = bool(np.array_equal(idx_p, idx_f))
        point = {"batch": b, "solves_avoided": ps["solves_avoided"],
                 "exact_solves": ps["exact_solves"],
                 "scan_solves": ps["scan_solves"],
                 "rerank_programs": ps["rerank_programs"],
                 "bound_s": ps["bound_s"], "rerank_s": ps["rerank_s"],
                 "hit_rate": hit_rate,
                 "tiers": ps.get("tiers", []),
                 "bitwise_vs_scan": bitwise,
                 "idx_match_vs_full": full_match,
                 "max_abs_err_vs_full": float(np.abs(d_p - d_f).max())}
        results["points"].append(point)
        results.setdefault("avoided_ceiling",
                           1.0 - ps["chunk"] / max(ps["docs"], 1))
        funnel = ":".join(
            f"{t['tier']}={t['cascade_solves_avoided']:.2f}"
            for t in point["tiers"])
        print(f"prune/b{b},{ps['rerank_s'] * 1e6:.1f},"
              f"avoided={ps['solves_avoided']:.2f}:"
              f"solves={ps['exact_solves']}/{ps['scan_solves']}:"
              f"bitwise={bitwise}:hit_rate={point['hit_rate']:.2f}:"
              f"{funnel}")
    med = bench_interleaved(
        {"pruned": lambda: svc.top_k_batch(last_qs, k, prune=True),
         "scan": lambda: svc.top_k_scan_batch(last_qs, k),
         "full": lambda: svc.top_k_batch(last_qs, k)},
        rounds=rounds)
    avoided = sorted(p["solves_avoided"] for p in results["points"])[
        len(results["points"]) // 2]
    results["solves_avoided"] = avoided
    results["t_pruned_s"] = med["pruned"]
    results["t_scan_s"] = med["scan"]
    results["t_full_s"] = med["full"]
    results["speedup_vs_full"] = med["full"] / med["pruned"]
    results["speedup_vs_scan"] = med["scan"] / med["pruned"]
    results["bitwise_ok"] = all(p["bitwise_vs_scan"]
                                for p in results["points"])
    results["tiers"] = results["points"][-1]["tiers"] \
        if results["points"] else []
    print(f"prune/headline,{med['pruned'] * 1e6:.1f},"
          f"avoided={avoided:.2f}:"
          f"speedup_vs_full={results['speedup_vs_full']:.2f}x:"
          f"speedup_vs_scan={results['speedup_vs_scan']:.2f}x")
    if out:
        with open(out, "w") as f:
            json.dump(results, f, indent=2)
        print(f"# wrote {out}")
    if gate_avoided is not None and avoided < gate_avoided:
        print(f"GATE FAILED: solves_avoided {avoided:.3f} < "
              f"{gate_avoided}", file=sys.stderr)
        raise SystemExit(1)
    return results


def run_sweep(n_list: list[int], out: str | None = None, **kw) -> dict:
    """Re-run the whole bench at each corpus size; the sweep artifact is
    the avoidance-vs-N curve (each point's ceiling is 1 - chunk/N)."""
    sweep = {"n_sweep": [], "points": []}
    for n in n_list:
        r = run(docs=n, out=None, **kw)
        sweep["n_sweep"].append(n)
        sweep["points"].append(
            {"docs": n, "solves_avoided": r["solves_avoided"],
             "avoided_ceiling": r.get("avoided_ceiling"),
             "speedup_vs_full": r["speedup_vs_full"],
             "speedup_vs_scan": r["speedup_vs_scan"],
             "tiers": r["tiers"]})
        print(f"prune/sweep-n{n},avoided={r['solves_avoided']:.3f}"
              f"(ceiling {r.get('avoided_ceiling', 0):.3f}):"
              f"speedup_vs_full={r['speedup_vs_full']:.2f}x")
    if out:
        with open(out, "w") as f:
            json.dump(sweep, f, indent=2)
        print(f"# wrote {out}")
    return sweep


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--vocab", type=int, default=2048)
    ap.add_argument("--docs", type=int, default=4096)
    ap.add_argument("--q", type=int, default=8)
    ap.add_argument("--k", type=int, default=16)
    ap.add_argument("--query-words", type=int, default=13)
    ap.add_argument("--v-r", type=int, default=16)
    ap.add_argument("--mean-words", type=float, default=35.0)
    ap.add_argument("--zipf-s", type=float, default=1.3)
    ap.add_argument("--cache-capacity", type=int, default=2048)
    ap.add_argument("--mcache-capacity", type=int, default=2048)
    ap.add_argument("--prune-chunk", type=int, default=32)
    ap.add_argument("--batches", type=int, default=3)
    ap.add_argument("--rounds", type=int, default=3)
    ap.add_argument("--n-sweep", default="",
                    help="comma-separated corpus sizes; re-runs the bench "
                         "at each and writes the avoidance-vs-N curve "
                         "instead of a single-point artifact")
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke shape; also gates solves_avoided >= "
                         "0.85")
    ap.add_argument("--out", default="BENCH_prune.json")
    args = ap.parse_args()
    if args.tiny:
        run(vocab=512, docs=256, q=4, k=8, query_words=13,
            mean_words=35.0, cache_capacity=512, mcache_capacity=512,
            prune_chunk=16, batches=2, rounds=2, gate_avoided=0.85,
            out=args.out)
    elif args.n_sweep:
        run_sweep([int(n) for n in args.n_sweep.split(",")],
                  vocab=args.vocab, q=args.q, k=args.k,
                  query_words=args.query_words, v_r=args.v_r,
                  mean_words=args.mean_words, zipf_s=args.zipf_s,
                  cache_capacity=args.cache_capacity,
                  mcache_capacity=args.mcache_capacity,
                  prune_chunk=args.prune_chunk, batches=args.batches,
                  rounds=args.rounds, out=args.out)
    else:
        run(vocab=args.vocab, docs=args.docs, q=args.q, k=args.k,
            query_words=args.query_words, v_r=args.v_r,
            mean_words=args.mean_words, zipf_s=args.zipf_s,
            cache_capacity=args.cache_capacity,
            mcache_capacity=args.mcache_capacity,
            prune_chunk=args.prune_chunk, batches=args.batches,
            rounds=args.rounds, out=args.out)


if __name__ == "__main__":
    main()
