"""Multi-query batching throughput: batched (Q, v_r, N) engine vs the
sequential per-query dispatch loop.

    PYTHONPATH=src python benchmarks/bench_query_batch.py [--tiny] \
        [--out BENCH_query_batch.json]

For each Q the sequential baseline replays `WMDService.query` Q times
(re-gathering K, re-running precompute, and paying one program dispatch per
query); the batched path runs ONE device program with a single batched ELL
gather per iteration. Emits ``name,us_per_call,derived`` CSV rows (the
harness idiom) and writes a JSON artifact for the perf trajectory
(`BENCH_*.json`, uploaded by the nightly CI smoke job).

Default shape is the low-latency serving regime (small per-query corpus
slice, short queries): there, per-query dispatch + precompute rivals solve
compute and batching amortizes both, giving the >= 2x throughput target at
Q = 16 on CPU. At bulk shapes (--docs/--vocab up) the solve is
gather-bandwidth-bound and K differs per query, so CPU batching converges
toward parity -- the win at those shapes is the collective amortization on
real meshes (one psum per iteration regardless of Q), which this single-host
bench cannot show.

Self-contained on purpose (no benchmarks.common import): CI invokes it as a
script with only the installed `repro` package on the path.
"""
from __future__ import annotations

import argparse
import json
import time


def bench(svc, queries, *, warmup: int = 1, repeat: int = 3):
    """Median wall seconds of sequential vs batched dispatch of ``queries``."""
    def run(fn):
        for _ in range(warmup):
            fn(queries)
        ts = []
        for _ in range(repeat):
            t0 = time.perf_counter()
            fn(queries)
            ts.append(time.perf_counter() - t0)
        ts.sort()
        return ts[len(ts) // 2]

    return run(svc.query_batch_sequential), run(svc.query_batch)


def run(*, vocab: int = 1024, docs: int = 128, qs=(1, 4, 16, 64),
        mean_words: float = 8.0, query_words: int = 13, v_r: int = 16,
        out: str | None = None) -> dict:
    import numpy as np
    from repro.configs.sinkhorn_wmd import WMDConfig
    from repro.data import make_corpus
    from repro.launch.mesh import make_mesh
    from repro.serving import WMDService

    cfg = WMDConfig(name="bench-qbatch", vocab_size=vocab, embed_dim=64,
                    num_docs=docs, nnz_max=64, v_r=v_r, lamb=1.0, max_iter=15)
    data = make_corpus(vocab_size=vocab, embed_dim=cfg.embed_dim,
                       num_docs=docs, num_queries=max(qs),
                       query_words=query_words, mean_words=mean_words,
                       seed=0)
    mesh = make_mesh((1, 1), ("data", "model"))
    svc = WMDService(mesh=mesh, cfg=cfg, vecs=data.vecs, ell=data.ell)

    results = {"vocab": vocab, "docs": docs, "v_r": cfg.v_r,
               "nnz_max": data.ell.nnz_max, "max_iter": cfg.max_iter,
               "points": []}
    for q in qs:
        queries = data.queries[:q]
        # correctness gate before timing: batched must match the oracle
        err = float(np.abs(svc.query_batch(queries)
                           - svc.query_batch_sequential(queries)).max())
        t_seq, t_bat = bench(svc, queries)
        qps_seq, qps_bat = q / t_seq, q / t_bat
        speedup = t_seq / t_bat
        print(f"qbatch/Q{q},{t_bat / q * 1e6:.1f},"
              f"qps_batched={qps_bat:.1f}:qps_seq={qps_seq:.1f}:"
              f"speedup={speedup:.2f}x")
        results["points"].append({
            "Q": q, "t_seq_s": t_seq, "t_batched_s": t_bat,
            "qps_seq": qps_seq, "qps_batched": qps_bat,
            "speedup": speedup, "max_abs_err": err,
        })
    if out:
        with open(out, "w") as f:
            json.dump(results, f, indent=2)
        print(f"# wrote {out}")
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--vocab", type=int, default=1024)
    ap.add_argument("--docs", type=int, default=128)
    ap.add_argument("--mean-words", type=float, default=8.0)
    ap.add_argument("--query-words", type=int, default=13)
    ap.add_argument("--v-r", type=int, default=16)
    ap.add_argument("--qs", type=int, nargs="+", default=[1, 4, 16, 64])
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke shape (small corpus, Q <= 8)")
    ap.add_argument("--out", default="BENCH_query_batch.json")
    args = ap.parse_args()
    if args.tiny:
        run(vocab=512, docs=64, qs=(1, 4, 8), out=args.out)
    else:
        run(vocab=args.vocab, docs=args.docs, qs=tuple(args.qs),
            mean_words=args.mean_words, query_words=args.query_words,
            v_r=args.v_r, out=args.out)


if __name__ == "__main__":
    main()
