"""Multi-query batching throughput: batched (Q, v_r, N) engine vs the
sequential per-query dispatch loop, with a ``--docs-chunk`` cache-blocking
sweep, an ``--impl`` (fused | kernel) mode, and a ``--zipf`` query-stream
mode exercising the cross-query K cache.

    PYTHONPATH=src python benchmarks/bench_query_batch.py [--tiny] \
        [--docs-chunk 0 64 128 256] [--impl fused] [--out BENCH_query_batch.json]
    PYTHONPATH=src python benchmarks/bench_query_batch.py --zipf \
        [--cache-capacity 2048] [--zipf-s 1.3] [--out BENCH_zipf_cache.json]

Every batched point records the *phase split* -- ``precompute_s`` (dedup +
cache lookup + row compute + stripe assembly) vs ``solve_s`` (the Sinkhorn
loop program) -- plus that batch's cache ``hit_rate``, so BENCH trajectories
can attribute wins to the right phase. ``--zipf`` replays a seeded
Zipf-skewed query stream (`repro.data.zipf_query_stream`) through one
service twice per batch -- cache ON then the transient cache-OFF baseline --
asserts the two are bitwise identical (the cache's exactness contract), and
reports the steady-state hit rate and precompute-phase speedup
(`precompute_speedup_steady`; the cache converts the phase from
O(Q*v_r*V*w) to O(misses*V*w), so at hit rate h it approaches 1/(1-h) minus
assembly overhead).

For each Q the sequential baseline replays `WMDService.query` Q times
(re-gathering K, re-running precompute, and paying one program dispatch per
query); the batched path runs ONE device program. ``--docs-chunk`` sweeps
`WMDService(docs_chunk=...)` (0 = unchunked): the chunk loop sits OUTSIDE
the Sinkhorn loop (docs are independent OT problems), so each chunk's
(Q, v_r, docs_chunk) iterate stays cache-resident across all iterations --
see core.sparse_sinkhorn "Batched engine & cache blocking". All variants are
timed INTERLEAVED (round-robin, median of rounds) so slow-box drift hits
every variant equally. Emits ``name,us_per_call,derived`` CSV rows (the
harness idiom) and writes a JSON artifact for the perf trajectory
(`BENCH_*.json`, uploaded by the nightly CI smoke job) recording the full
chunk sweep and the chosen chunk per Q.

Measured regimes on the 2-core CPU CI box (vocab 2k, nnz ~96, v_r 16):
  * low-latency (N = 128): batched >= 2.5x sequential qps -- per-query
    dispatch + precompute rival solve compute and batching amortizes both.
  * bulk (N >= 1024, Q = 16): the unchunked batched path loses to sequential
    (~0.6x, the (Q, v_r, N)-working-set cache blow); doc-chunking wins it
    back (1.5-1.9x over unchunked), recovering parity-to-1.4x vs sequential.
    The remaining gap to bigger wins is structural: at bulk the per-query
    program overhead batching amortizes is only ~10-15% of a solve, and the
    iteration math itself runs at the same roofline either way -- the bulk
    win of the batched engine is collective amortization on real meshes
    (one psum per iteration regardless of Q), which a single-host bench
    cannot show.
  * Q = 1 is routed to the sequential path by the service admission policy
    (speedup 0.96x batched in the PR-1 artifact; the `admission` field
    records the route).

Self-contained on purpose (no benchmarks.common import): CI invokes it as a
script with only the installed `repro` package on the path.
"""
from __future__ import annotations

import argparse
import json
import time


def bench_interleaved(calls: dict, *, warmup: int = 1, rounds: int = 5):
    """Median wall seconds per call, measured round-robin across variants."""
    for fn in calls.values():
        for _ in range(warmup):
            fn()
    times = {name: [] for name in calls}
    for _ in range(rounds):
        for name, fn in calls.items():
            t0 = time.perf_counter()
            fn()
            times[name].append(time.perf_counter() - t0)
    return {name: sorted(ts)[len(ts) // 2] for name, ts in times.items()}


def run(*, vocab: int = 1024, docs: int = 128, qs=(1, 4, 16, 64),
        mean_words: float = 8.0, query_words: int = 13, v_r: int = 16,
        docs_chunks=(0,), impl: str = "fused", rounds: int = 5,
        cache_capacity: int = 0, out: str | None = None) -> dict:
    import numpy as np
    from repro.configs.sinkhorn_wmd import WMDConfig
    from repro.data import make_corpus
    from repro.launch.mesh import make_mesh
    from repro.serving import WMDService

    cfg = WMDConfig(name="bench-qbatch", vocab_size=vocab, embed_dim=64,
                    num_docs=docs, nnz_max=64, v_r=v_r, lamb=1.0, max_iter=15)
    data = make_corpus(vocab_size=vocab, embed_dim=cfg.embed_dim,
                       num_docs=docs, num_queries=max(qs),
                       query_words=query_words, mean_words=mean_words,
                       seed=0)
    mesh = make_mesh((1, 1), ("data", "model"))
    docs_chunks = tuple(dict.fromkeys(docs_chunks))  # dedup, keep order
    if 0 not in docs_chunks:
        docs_chunks = (0,) + docs_chunks
    # ONE service (one device-sharded corpus); the chunk sweep rides the
    # per-(impl, docs_chunk) batch-fn cache via query_batch(docs_chunk=...)
    svc = WMDService(mesh=mesh, cfg=cfg, vecs=data.vecs, ell=data.ell,
                     impl=impl, cache_capacity=cache_capacity)

    results = {"vocab": vocab, "docs": docs, "v_r": cfg.v_r,
               "nnz_max": data.ell.nnz_max, "max_iter": cfg.max_iter,
               "impl": impl, "docs_chunks": list(docs_chunks),
               "cache_capacity": cache_capacity, "points": [],
               "note": ("chunk_times_s sweeps WMDService(docs_chunk=...); "
                        "chosen_chunk minimizes batched time. At bulk N the "
                        "chunked path wins ~1.5-1.8x over unchunked "
                        "(cache-resident per-chunk solve) and reaches "
                        "parity-to-1.4x vs the sequential per-query loop; "
                        "bigger bulk wins need a real mesh (see module "
                        "docstring). Low-latency N (~128) shows >= 2.5x "
                        "vs sequential. precompute_s/solve_s phase-split "
                        "the stripes engine (measured via use_cache=True; "
                        "cache-off defaults run the fused single-program "
                        "engine, which has no separable phases); hit_rate "
                        "is 0 unless --cache-capacity > 0 -- see the "
                        "--zipf artifact for the cache's steady state.")}
    for q in qs:
        queries = data.queries[:q]
        if q == 1 and impl == "fused":
            # the service admission policy routes fused singletons to the
            # sequential path (0.96x batched in the PR-1 artifact) -- every
            # "batched" variant IS the sequential program, so a chunk sweep
            # would chart pure timing noise. Record the policy instead.
            # (A non-fused impl bypasses the shortcut, so Q=1 falls through
            # to the real batched measurement below.)
            med = bench_interleaved(
                {"seq": lambda: svc.query_batch_sequential(queries)},
                rounds=rounds)
            t_seq = med["seq"]
            # only genuinely measured fields: no t_batched_s / speedup /
            # max_abs_err, so trajectory consumers can't mistake the policy
            # route for a batched measurement
            point = {"Q": 1, "t_seq_s": t_seq, "qps_seq": 1 / t_seq,
                     "admission": "sequential"}
            results["points"].append(point)
            print(f"qbatch/Q1,{t_seq * 1e6:.1f},"
                  f"qps={1 / t_seq:.1f}:admission=sequential")
            continue
        # correctness gate before timing: batched must match the oracle
        # (for every swept chunk size)
        seq_ref = svc.query_batch_sequential(queries)
        err = max(float(np.abs(svc.query_batch(queries, docs_chunk=dc)
                               - seq_ref).max())
                  for dc in docs_chunks)
        calls = {"seq": lambda: svc.query_batch_sequential(queries)}
        for dc in docs_chunks:
            calls[f"dc{dc}"] = (lambda d: (
                lambda: svc.query_batch(queries, docs_chunk=d)))(dc)
        med = bench_interleaved(calls, rounds=rounds)
        t_seq = med["seq"]
        chunk_times = {str(dc): med[f"dc{dc}"] for dc in docs_chunks}
        chosen = min(docs_chunks, key=lambda dc: med[f"dc{dc}"])
        t_bat = med[f"dc{chosen}"]
        t_un = med["dc0"]
        qps_seq, qps_bat = q / t_seq, q / t_bat
        # phase split at the chosen chunk: precompute = dedup + cache +
        # row compute + stripe assembly, solve = the Sinkhorn program (see
        # WMDService.last_batch_stats). use_cache=True routes through the
        # stripes engine even when the service's cache is disabled -- the
        # split is only measurable there (the cache-off default runs the
        # fused single-program engine). First call warms the stripes jits
        # (they are cold when the timed calls ran legacy); the second is
        # the steady-state measurement the artifact records.
        svc.query_batch(queries, docs_chunk=chosen, use_cache=True)
        svc.query_batch(queries, docs_chunk=chosen, use_cache=True)
        phases = svc.last_batch_stats
        point = {
            "Q": q, "t_seq_s": t_seq, "t_batched_s": t_bat,
            "t_unchunked_s": t_un, "chunk_times_s": chunk_times,
            "chosen_chunk": chosen,
            "qps_seq": qps_seq, "qps_batched": qps_bat,
            "speedup": t_seq / t_bat,
            "speedup_chunked_vs_unchunked": t_un / t_bat,
            "max_abs_err": err,
            "admission": "batched",
            "precompute_s": phases["precompute_s"],
            "solve_s": phases["solve_s"],
            "hit_rate": phases["hit_rate"],
        }
        results["points"].append(point)
        print(f"qbatch/Q{q},{t_bat / q * 1e6:.1f},"
              f"qps_batched={qps_bat:.1f}:qps_seq={qps_seq:.1f}:"
              f"speedup={point['speedup']:.2f}x:"
              f"chunk={chosen}:chunk_vs_unchunked="
              f"{point['speedup_chunked_vs_unchunked']:.2f}x")
    if out:
        with open(out, "w") as f:
            json.dump(results, f, indent=2)
        print(f"# wrote {out}")
    return results


def run_zipf(*, vocab: int = 8192, docs: int = 128, q: int = 16,
             batches: int = 24, warm: int = 8, query_words: int = 13,
             v_r: int = 16, s: float = 1.3, cache_capacity: int = 2048,
             embed_dim: int = 256, rows_bucket: int = 16,
             impl: str = "fused", out: str | None = None) -> dict:
    """Zipf query-stream mode: steady-state cache hit rate + phase split.

    Replays ``batches`` batches of ``q`` queries drawn from one seeded
    Zipf(s) stream through a single cached service. Per batch both paths
    run on the same queries -- the cache-ON call (serving AND warming the
    store) and the transient cache-OFF baseline -- in alternating order
    (slow-box drift hits both sides equally); ON and OFF results must be
    bitwise equal (asserted -- the exactness contract of core.kcache).
    Batches after ``warm`` form the steady state; the headline speedup is
    the ratio of the lower-quartile per-batch precompute_s of the two sides
    (the same estimator on both; on a shared noisy box low quantiles
    estimate the true phase cost, while means/medians of single shots
    absorb multi-x scheduler spikes -- the artifact records the medians
    too). Defaults model the
    serving regime the cache targets: a head-heavy stream (s = 1.3) against
    a wide-ish vocab/embedding (V = 8192, w = 256 -- directionally the
    paper's 100k x 300) where the row compute, not the stripe assembly,
    dominates the phase.
    """
    import numpy as np
    from repro.configs.sinkhorn_wmd import WMDConfig
    from repro.data import make_corpus, zipf_query_stream
    from repro.launch.mesh import make_mesh
    from repro.serving import WMDService

    cfg = WMDConfig(name="bench-zipf", vocab_size=vocab, embed_dim=embed_dim,
                    num_docs=docs, nnz_max=64, v_r=v_r, lamb=1.0, max_iter=15)
    data = make_corpus(vocab_size=vocab, embed_dim=cfg.embed_dim,
                       num_docs=docs, num_queries=1,
                       query_words=query_words, seed=0)
    mesh = make_mesh((1, 1), ("data", "model"))
    svc = WMDService(mesh=mesh, cfg=cfg, vecs=data.vecs, ell=data.ell,
                     impl=impl, cache_capacity=cache_capacity,
                     cache_rows_bucket=rows_bucket)
    stream = zipf_query_stream(vocab_size=vocab, query_words=query_words,
                               s=s, seed=1)
    results = {"mode": "zipf", "vocab": vocab, "docs": docs, "Q": q,
               "v_r": v_r, "query_words": query_words, "zipf_s": s,
               "cache_capacity": cache_capacity, "impl": impl,
               "warm_batches": warm, "points": [],
               "note": ("per batch: cache-ON call then transient cache-OFF "
                        "baseline on the same queries, asserted bitwise "
                        "equal. Steady state = batches after warm; "
                        "precompute speedup ~ 1/(1 - hit_rate) minus "
                        "assembly overhead.")}
    for i in range(batches):
        batch = [next(stream) for _ in range(q)]
        if i % 2 == 0:
            on = svc.query_batch(batch)
            st_on = dict(svc.last_batch_stats)
            off = svc.query_batch(batch, use_cache=False)
            st_off = dict(svc.last_batch_stats)
        else:
            off = svc.query_batch(batch, use_cache=False)
            st_off = dict(svc.last_batch_stats)
            on = svc.query_batch(batch)
            st_on = dict(svc.last_batch_stats)
        assert np.array_equal(on, off), "cache on/off must be bitwise equal"
        point = {"batch": i, "unique": st_on["unique"],
                 "hit_rate": st_on["hit_rate"],
                 "precompute_s": st_on["precompute_s"],
                 "precompute_s_nocache": st_off["precompute_s"],
                 "solve_s": st_on["solve_s"],
                 "precompute_speedup":
                     st_off["precompute_s"] / st_on["precompute_s"]}
        results["points"].append(point)
        print(f"zipf/b{i},{st_on['precompute_s'] * 1e6:.1f},"
              f"hit_rate={point['hit_rate']:.2f}:"
              f"pre_speedup={point['precompute_speedup']:.2f}x:"
              f"solve={st_on['solve_s'] * 1e3:.1f}ms")
    steady = results["points"][warm:] or results["points"]  # warm >= batches
    med = lambda xs: sorted(xs)[len(xs) // 2]   # noqa: E731
    q25 = lambda xs: sorted(xs)[len(xs) // 4]   # noqa: E731
    results["hit_rate_steady"] = med([p["hit_rate"] for p in steady])
    pre_on = q25([p["precompute_s"] for p in steady])
    pre_off = q25([p["precompute_s_nocache"] for p in steady])
    results["precompute_s_steady"] = pre_on
    results["precompute_s_nocache_steady"] = pre_off
    results["precompute_s_steady_median"] = med(
        [p["precompute_s"] for p in steady])
    results["precompute_s_nocache_steady_median"] = med(
        [p["precompute_s_nocache"] for p in steady])
    results["precompute_speedup_steady"] = pre_off / pre_on
    results["cache_stats"] = {
        "hit_rate": svc.cache_stats.hit_rate,
        "evictions": svc.cache_stats.evictions,
        "resident": svc.cache_resident}
    print(f"zipf/steady,{pre_on * 1e6:.1f},"
          f"hit_rate={results['hit_rate_steady']:.2f}:"
          f"pre_speedup={results['precompute_speedup_steady']:.2f}x")
    if out:
        with open(out, "w") as f:
            json.dump(results, f, indent=2)
        print(f"# wrote {out}")
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--vocab", type=int, default=None,
                    help="corpus vocab (default 1024; 8192 in --zipf mode)")
    ap.add_argument("--docs", type=int, default=128)
    ap.add_argument("--mean-words", type=float, default=8.0)
    ap.add_argument("--query-words", type=int, default=13)
    ap.add_argument("--v-r", type=int, default=16)
    ap.add_argument("--qs", type=int, nargs="+", default=[1, 4, 16, 64])
    ap.add_argument("--docs-chunk", type=int, nargs="+", default=[0],
                    help="docs_chunk sweep; 0 = unchunked (always included)")
    ap.add_argument("--impl", default="fused", choices=("fused", "kernel"),
                    help="batched contraction path (kernel = Pallas, "
                         "interpret mode on CPU: slow, correctness timing)")
    ap.add_argument("--rounds", type=int, default=5)
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke shape (small corpus, Q <= 8)")
    ap.add_argument("--cache-capacity", type=int, default=None,
                    help="cross-query K-cache rows (default: 0 = off; "
                         "2048 in --zipf mode)")
    ap.add_argument("--zipf", action="store_true",
                    help="Zipf query-stream mode: steady-state cache hit "
                         "rate + precompute-phase speedup (cache on vs off)")
    ap.add_argument("--zipf-s", type=float, default=1.3,
                    help="Zipf exponent of the query stream (1.3 = the "
                         "head-heavy serving regime; the corpus generator "
                         "itself defaults to the paper-ish 1.07)")
    ap.add_argument("--zipf-batches", type=int, default=24)
    ap.add_argument("--zipf-warm", type=int, default=8,
                    help="batches excluded from the steady-state aggregate")
    ap.add_argument("--zipf-q", type=int, default=16,
                    help="queries per batch in --zipf mode")
    ap.add_argument("--out", default=None,
                    help="artifact path (default BENCH_query_batch.json, "
                         "BENCH_zipf_cache.json in --zipf mode)")
    args = ap.parse_args()
    out = args.out or ("BENCH_zipf_cache.json" if args.zipf
                       else "BENCH_query_batch.json")
    if args.zipf:
        run_zipf(vocab=args.vocab or 8192,
                 docs=args.docs, q=args.zipf_q, batches=args.zipf_batches,
                 warm=args.zipf_warm, query_words=args.query_words,
                 v_r=args.v_r, s=args.zipf_s,
                 cache_capacity=(2048 if args.cache_capacity is None
                                 else args.cache_capacity),
                 impl=args.impl, out=out)
    elif args.tiny:
        run(vocab=512, docs=64, qs=(1, 4, 8), docs_chunks=(0, 16, 32),
            rounds=3, cache_capacity=args.cache_capacity or 0, out=out)
    else:
        run(vocab=args.vocab or 1024, docs=args.docs, qs=tuple(args.qs),
            mean_words=args.mean_words, query_words=args.query_words,
            v_r=args.v_r, docs_chunks=tuple(args.docs_chunk),
            impl=args.impl, rounds=args.rounds,
            cache_capacity=args.cache_capacity or 0, out=out)


if __name__ == "__main__":
    main()
