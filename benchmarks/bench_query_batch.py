"""Multi-query batching throughput: batched (Q, v_r, N) engine vs the
sequential per-query dispatch loop, with a ``--docs-chunk`` cache-blocking
sweep and an ``--impl`` (fused | kernel) mode.

    PYTHONPATH=src python benchmarks/bench_query_batch.py [--tiny] \
        [--docs-chunk 0 64 128 256] [--impl fused] [--out BENCH_query_batch.json]

For each Q the sequential baseline replays `WMDService.query` Q times
(re-gathering K, re-running precompute, and paying one program dispatch per
query); the batched path runs ONE device program. ``--docs-chunk`` sweeps
`WMDService(docs_chunk=...)` (0 = unchunked): the chunk loop sits OUTSIDE
the Sinkhorn loop (docs are independent OT problems), so each chunk's
(Q, v_r, docs_chunk) iterate stays cache-resident across all iterations --
see core.sparse_sinkhorn "Batched engine & cache blocking". All variants are
timed INTERLEAVED (round-robin, median of rounds) so slow-box drift hits
every variant equally. Emits ``name,us_per_call,derived`` CSV rows (the
harness idiom) and writes a JSON artifact for the perf trajectory
(`BENCH_*.json`, uploaded by the nightly CI smoke job) recording the full
chunk sweep and the chosen chunk per Q.

Measured regimes on the 2-core CPU CI box (vocab 2k, nnz ~96, v_r 16):
  * low-latency (N = 128): batched >= 2.5x sequential qps -- per-query
    dispatch + precompute rival solve compute and batching amortizes both.
  * bulk (N >= 1024, Q = 16): the unchunked batched path loses to sequential
    (~0.6x, the (Q, v_r, N)-working-set cache blow); doc-chunking wins it
    back (1.5-1.9x over unchunked), recovering parity-to-1.4x vs sequential.
    The remaining gap to bigger wins is structural: at bulk the per-query
    program overhead batching amortizes is only ~10-15% of a solve, and the
    iteration math itself runs at the same roofline either way -- the bulk
    win of the batched engine is collective amortization on real meshes
    (one psum per iteration regardless of Q), which a single-host bench
    cannot show.
  * Q = 1 is routed to the sequential path by the service admission policy
    (speedup 0.96x batched in the PR-1 artifact; the `admission` field
    records the route).

Self-contained on purpose (no benchmarks.common import): CI invokes it as a
script with only the installed `repro` package on the path.
"""
from __future__ import annotations

import argparse
import json
import time


def bench_interleaved(calls: dict, *, warmup: int = 1, rounds: int = 5):
    """Median wall seconds per call, measured round-robin across variants."""
    for fn in calls.values():
        for _ in range(warmup):
            fn()
    times = {name: [] for name in calls}
    for _ in range(rounds):
        for name, fn in calls.items():
            t0 = time.perf_counter()
            fn()
            times[name].append(time.perf_counter() - t0)
    return {name: sorted(ts)[len(ts) // 2] for name, ts in times.items()}


def run(*, vocab: int = 1024, docs: int = 128, qs=(1, 4, 16, 64),
        mean_words: float = 8.0, query_words: int = 13, v_r: int = 16,
        docs_chunks=(0,), impl: str = "fused", rounds: int = 5,
        out: str | None = None) -> dict:
    import numpy as np
    from repro.configs.sinkhorn_wmd import WMDConfig
    from repro.data import make_corpus
    from repro.launch.mesh import make_mesh
    from repro.serving import WMDService

    cfg = WMDConfig(name="bench-qbatch", vocab_size=vocab, embed_dim=64,
                    num_docs=docs, nnz_max=64, v_r=v_r, lamb=1.0, max_iter=15)
    data = make_corpus(vocab_size=vocab, embed_dim=cfg.embed_dim,
                       num_docs=docs, num_queries=max(qs),
                       query_words=query_words, mean_words=mean_words,
                       seed=0)
    mesh = make_mesh((1, 1), ("data", "model"))
    docs_chunks = tuple(dict.fromkeys(docs_chunks))  # dedup, keep order
    if 0 not in docs_chunks:
        docs_chunks = (0,) + docs_chunks
    # ONE service (one device-sharded corpus); the chunk sweep rides the
    # per-(impl, docs_chunk) batch-fn cache via query_batch(docs_chunk=...)
    svc = WMDService(mesh=mesh, cfg=cfg, vecs=data.vecs, ell=data.ell,
                     impl=impl)

    results = {"vocab": vocab, "docs": docs, "v_r": cfg.v_r,
               "nnz_max": data.ell.nnz_max, "max_iter": cfg.max_iter,
               "impl": impl, "docs_chunks": list(docs_chunks), "points": [],
               "note": ("chunk_times_s sweeps WMDService(docs_chunk=...); "
                        "chosen_chunk minimizes batched time. At bulk N the "
                        "chunked path wins ~1.5-1.8x over unchunked "
                        "(cache-resident per-chunk solve) and reaches "
                        "parity-to-1.4x vs the sequential per-query loop; "
                        "bigger bulk wins need a real mesh (see module "
                        "docstring). Low-latency N (~128) shows >= 2.5x "
                        "vs sequential.")}
    for q in qs:
        queries = data.queries[:q]
        if q == 1 and impl == "fused":
            # the service admission policy routes fused singletons to the
            # sequential path (0.96x batched in the PR-1 artifact) -- every
            # "batched" variant IS the sequential program, so a chunk sweep
            # would chart pure timing noise. Record the policy instead.
            # (A non-fused impl bypasses the shortcut, so Q=1 falls through
            # to the real batched measurement below.)
            med = bench_interleaved(
                {"seq": lambda: svc.query_batch_sequential(queries)},
                rounds=rounds)
            t_seq = med["seq"]
            # only genuinely measured fields: no t_batched_s / speedup /
            # max_abs_err, so trajectory consumers can't mistake the policy
            # route for a batched measurement
            point = {"Q": 1, "t_seq_s": t_seq, "qps_seq": 1 / t_seq,
                     "admission": "sequential"}
            results["points"].append(point)
            print(f"qbatch/Q1,{t_seq * 1e6:.1f},"
                  f"qps={1 / t_seq:.1f}:admission=sequential")
            continue
        # correctness gate before timing: batched must match the oracle
        # (for every swept chunk size)
        seq_ref = svc.query_batch_sequential(queries)
        err = max(float(np.abs(svc.query_batch(queries, docs_chunk=dc)
                               - seq_ref).max())
                  for dc in docs_chunks)
        calls = {"seq": lambda: svc.query_batch_sequential(queries)}
        for dc in docs_chunks:
            calls[f"dc{dc}"] = (lambda d: (
                lambda: svc.query_batch(queries, docs_chunk=d)))(dc)
        med = bench_interleaved(calls, rounds=rounds)
        t_seq = med["seq"]
        chunk_times = {str(dc): med[f"dc{dc}"] for dc in docs_chunks}
        chosen = min(docs_chunks, key=lambda dc: med[f"dc{dc}"])
        t_bat = med[f"dc{chosen}"]
        t_un = med["dc0"]
        qps_seq, qps_bat = q / t_seq, q / t_bat
        point = {
            "Q": q, "t_seq_s": t_seq, "t_batched_s": t_bat,
            "t_unchunked_s": t_un, "chunk_times_s": chunk_times,
            "chosen_chunk": chosen,
            "qps_seq": qps_seq, "qps_batched": qps_bat,
            "speedup": t_seq / t_bat,
            "speedup_chunked_vs_unchunked": t_un / t_bat,
            "max_abs_err": err,
            "admission": "batched",
        }
        results["points"].append(point)
        print(f"qbatch/Q{q},{t_bat / q * 1e6:.1f},"
              f"qps_batched={qps_bat:.1f}:qps_seq={qps_seq:.1f}:"
              f"speedup={point['speedup']:.2f}x:"
              f"chunk={chosen}:chunk_vs_unchunked="
              f"{point['speedup_chunked_vs_unchunked']:.2f}x")
    if out:
        with open(out, "w") as f:
            json.dump(results, f, indent=2)
        print(f"# wrote {out}")
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--vocab", type=int, default=1024)
    ap.add_argument("--docs", type=int, default=128)
    ap.add_argument("--mean-words", type=float, default=8.0)
    ap.add_argument("--query-words", type=int, default=13)
    ap.add_argument("--v-r", type=int, default=16)
    ap.add_argument("--qs", type=int, nargs="+", default=[1, 4, 16, 64])
    ap.add_argument("--docs-chunk", type=int, nargs="+", default=[0],
                    help="docs_chunk sweep; 0 = unchunked (always included)")
    ap.add_argument("--impl", default="fused", choices=("fused", "kernel"),
                    help="batched contraction path (kernel = Pallas, "
                         "interpret mode on CPU: slow, correctness timing)")
    ap.add_argument("--rounds", type=int, default=5)
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke shape (small corpus, Q <= 8)")
    ap.add_argument("--out", default="BENCH_query_batch.json")
    args = ap.parse_args()
    if args.tiny:
        run(vocab=512, docs=64, qs=(1, 4, 8), docs_chunks=(0, 16, 32),
            rounds=3, out=args.out)
    else:
        run(vocab=args.vocab, docs=args.docs, qs=tuple(args.qs),
            mean_words=args.mean_words, query_words=args.query_words,
            v_r=args.v_r, docs_chunks=tuple(args.docs_chunk),
            impl=args.impl, rounds=args.rounds, out=args.out)


if __name__ == "__main__":
    main()
