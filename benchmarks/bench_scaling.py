"""Fig. 10 analogue: per-query cost across source documents of different
sizes (paper sweeps v_r = 14..43 and reports strong scaling per doc).

No multi-core scaling exists on this container; the v_r sweep (the paper's
x-axis families) is reported as time per query and time per (nnz * v_r)
unit -- the Table II cost driver. Near-constant derived unit cost across
v_r = the scaling the paper's partitioning achieves via equal-nnz splits,
achieved here by construction (equal-shape ELL tiles)."""
from __future__ import annotations

import functools

from benchmarks.common import emit, timeit, wmd_problem
from repro.core import sinkhorn_wmd_sparse

ITERS = 10


def run() -> dict:
    out = {}
    for v_r in (14, 19, 27, 43):
        p = wmd_problem(query_words=v_r)
        f = functools.partial(sinkhorn_wmd_sparse, lamb=1.0, max_iter=ITERS,
                              impl="fused")
        t = timeit(f, p["sel"], p["r_sel"], p["cols"], p["vals"], p["vecs"])
        unit = t / (p["nnz"] * p["v_r"] * ITERS)
        emit(f"fig10/query_vr{p['v_r']}", t * 1e6,
             f"ns_per_nnz_vr_iter={unit * 1e9:.3f}")
        out[p["v_r"]] = t
    return out
