"""Perf-regression gate over the BENCH_*.json artifacts (bench.yml).

Compares a fresh bench run against the previous successful nightly run's
artifacts and fails (exit 1) when a declared headline field regresses by
more than ``--threshold`` (default 25%):

    python benchmarks/compare_bench.py --prev prev_dir --cur . \
        [--threshold 0.25] [--out BENCH_trajectory.json] [--summary table.md]

What is gated -- and what deliberately is not
---------------------------------------------
Only *ratio and rate* headline fields are declared in ``FIELDS``: qps,
speedup-vs-baseline ratios, solves-avoided and cache hit-rate fractions.
Ratios of quantities measured in the same run on the same box largely
cancel shared-runner drift (the benches measure them interleaved for
exactly that reason), so a >25% drop is signal, not noise. Absolute wall
times and tail latencies (p95/p99) on shared CI runners ARE >25% noisy,
so they ride along in the artifacts and the trajectory but never gate.

Each comparison lands in a markdown delta table (``--summary``, appended
to ``$GITHUB_STEP_SUMMARY`` in CI) and in ``BENCH_trajectory.json`` -- the
machine-readable run-over-run record (prev value, current value, delta,
verdict per field) that accumulates as a per-run artifact.

Missing data never gates spuriously -- but it is never conflated either.
A field measured now with no previous value (first run, or first run
after a rename) reports ``seeded``: it passes, and its current value
lands in the trajectory so the NEXT run has a baseline. A field absent
from the *current* run reports ``n/a``. And the baseline directory's own
state is classified (``baseline_status``): "missing-dir" (true first
run: nothing was ever downloaded), "no-artifacts" (a download landed but
held no readable BENCH_*.json -- an upstream failure worth eyeballing,
still not a regression), or "present". Only a *measured* regression
fails the job.

``--self-test`` proves the gate can actually fail: it synthesizes a
baseline, checks that an identical run passes and that a 30% slowdown on
every gated field fails, and exits non-zero if either half misbehaves
(bench.yml runs this before trusting the real comparison).
"""
from __future__ import annotations

import argparse
import json
import os
import sys

# (artifact file, dotted path into its JSON, direction). direction
# "higher" = regression when the value drops; "lower" = when it rises.
FIELDS: list[tuple[str, str, str]] = [
    ("BENCH_serving.json", "speedup_vs_sequential", "higher"),
    ("BENCH_serving.json", "saturating.throughput_qps", "higher"),
    ("BENCH_serving.json", "headlines.throughput_mode.value", "higher"),
    ("BENCH_serving.json", "offline.throughput_qps", "higher"),
    ("BENCH_query_batch.json", "points.-1.speedup", "higher"),
    ("BENCH_zipf_cache.json", "hit_rate_steady", "higher"),
    ("BENCH_zipf_cache.json", "precompute_speedup_steady", "higher"),
    ("BENCH_prune.json", "solves_avoided", "higher"),
    ("BENCH_prune.json", "speedup_vs_scan", "higher"),
    ("BENCH_prune.json", "speedup_vs_full", "higher"),
]


def get_path(obj, dotted: str):
    """Resolve a dotted path; integer segments index lists (-1 = last).
    Returns None when any segment is missing."""
    for seg in dotted.split("."):
        try:
            if isinstance(obj, list):
                obj = obj[int(seg)]
            elif isinstance(obj, dict):
                obj = obj[seg]
            else:
                return None
        except (KeyError, IndexError, ValueError, TypeError):
            return None
    return obj if isinstance(obj, (int, float)) else None


def load_artifacts(root: str) -> dict[str, dict]:
    """Read every declared artifact under ``root`` (searching one level of
    subdirectories too -- artifact downloads often unpack into a folder
    per artifact name). Missing files simply aren't in the result."""
    out: dict[str, dict] = {}
    names = {f for f, _, _ in FIELDS}
    for name in sorted(names):
        for cand in [os.path.join(root, name)] + sorted(
                os.path.join(root, d, name)
                for d in (os.listdir(root) if os.path.isdir(root) else [])
                if os.path.isdir(os.path.join(root, d))):
            if os.path.isfile(cand):
                try:
                    with open(cand) as fh:
                        out[name] = json.load(fh)
                except (OSError, json.JSONDecodeError) as e:
                    print(f"warning: unreadable {cand}: {e}",
                          file=sys.stderr)
                break
    return out


def baseline_status(root: str | None,
                    artifacts: dict | None = None) -> str:
    """Classify the baseline side: "missing-dir" (nothing was ever
    downloaded -- the true first run), "no-artifacts" (a directory exists
    but holds no readable BENCH_*.json), or "present"."""
    if not root or not os.path.isdir(root):
        return "missing-dir"
    if artifacts is None:
        artifacts = load_artifacts(root)
    return "present" if artifacts else "no-artifacts"


def compare(prev: dict[str, dict], cur: dict[str, dict],
            threshold: float, *, baseline: str = "present") -> dict:
    """Evaluate every declared field; returns the trajectory record.

    Statuses: ``ok`` / ``regression`` (both sides measured), ``seeded``
    (measured now, no previous value -- the current value becomes the
    next run's baseline via the trajectory/artifacts), ``n/a`` (not
    measured in the current run). Only ``regression`` fails."""
    rows = []
    for fname, path, direction in FIELDS:
        p = get_path(prev.get(fname), path)
        c = get_path(cur.get(fname), path)
        row = {"file": fname, "field": path, "direction": direction,
               "prev": p, "cur": c, "delta_frac": None, "status": "n/a"}
        if p is not None and c is not None and p > 0:
            delta = (c - p) / p
            row["delta_frac"] = delta
            worse = -delta if direction == "higher" else delta
            row["status"] = "regression" if worse > threshold else "ok"
        elif c is not None and p is None:
            row["status"] = "seeded"
        rows.append(row)
    regressions = [r for r in rows if r["status"] == "regression"]
    return {"threshold": threshold, "fields": rows,
            "baseline_status": baseline,
            "seeded": sum(r["status"] == "seeded" for r in rows),
            "regressions": len(regressions),
            "pass": not regressions}


def markdown_table(record: dict) -> str:
    """The job-summary delta table."""
    lines = ["### Bench regression gate "
             f"({'PASS' if record['pass'] else 'FAIL'}, "
             f"threshold {record['threshold']:.0%})", "",
             "| metric | prev | current | delta | status |",
             "|---|---|---|---|---|"]
    for r in record["fields"]:
        fmt = lambda v: "n/a" if v is None else f"{v:.3f}"  # noqa: E731
        delta = ("n/a" if r["delta_frac"] is None
                 else f"{r['delta_frac']:+.1%}")
        mark = {"ok": "ok", "n/a": "n/a", "seeded": "seeded (first run)",
                "regression": "**REGRESSION**"}[r["status"]]
        lines.append(f"| {r['file']}:{r['field']} | {fmt(r['prev'])} | "
                     f"{fmt(r['cur'])} | {delta} | {mark} |")
    return "\n".join(lines) + "\n"


def self_test(threshold: float) -> int:
    """Prove the gate trips on a synthetic 30% slowdown, stays quiet on an
    identical run, and seeds (rather than silently blanks) a first run
    with no baseline. Exit 0 iff all hold."""
    if not FIELDS:
        print("self-test: FIELDS is empty -- nothing is gated",
              file=sys.stderr)
        return 1
    base: dict[str, dict] = {}
    for fname, path, _ in FIELDS:
        obj = base.setdefault(fname, {})
        segs = path.split(".")
        for i, seg in enumerate(segs[:-1]):
            if segs[i + 1].lstrip("-").isdigit():
                obj = obj.setdefault(seg, [{}])
            elif seg.lstrip("-").isdigit():
                obj = obj[int(seg)]
            else:
                obj = obj.setdefault(seg, {})
        obj[segs[-1]] = 2.0  # every declared path ends in a dict key
    slow = json.loads(json.dumps(base))
    for fname, path, direction in FIELDS:
        segs = path.split(".")
        obj = slow[fname]
        for seg in segs[:-1]:
            obj = obj[int(seg)] if isinstance(obj, list) else obj[seg]
        factor = 0.7 if direction == "higher" else 1.3  # 30% worse
        obj[segs[-1]] = obj[segs[-1]] * factor

    # every declared path must resolve in its own synthesized artifact --
    # a path typo would otherwise read as an eternally-passing "n/a"
    bad = [(f, p) for f, p, _ in FIELDS
           if get_path(base.get(f), p) != 2.0]
    if bad:
        print(f"self-test: unresolvable field paths: {bad}",
              file=sys.stderr)
        return 1

    ident = compare(base, base, threshold)
    regress = compare(base, slow, threshold)
    seeded = compare({}, base, threshold,
                     baseline=baseline_status(None))
    ok_ident = ident["pass"] and all(r["status"] == "ok"
                                     for r in ident["fields"])
    ok_regress = (not regress["pass"]
                  and all(r["status"] == "regression"
                          for r in regress["fields"]))
    # a first run must pass AND record every current value (seeded), not
    # produce an empty all-n/a trajectory
    ok_seeded = (seeded["pass"] and seeded["seeded"] == len(FIELDS)
                 and seeded["baseline_status"] == "missing-dir"
                 and all(r["status"] == "seeded" and r["cur"] is not None
                         for r in seeded["fields"]))
    print(f"self-test: identical-run pass={ok_ident}, "
          f"30%-slowdown fails={ok_regress}, "
          f"no-baseline seeds={ok_seeded}")
    if not (ok_ident and ok_regress and ok_seeded):
        print(markdown_table(regress), file=sys.stderr)
        print(markdown_table(seeded), file=sys.stderr)
        return 1
    return 0


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--prev", help="directory with the previous run's "
                                   "BENCH_*.json (may nest one level)")
    ap.add_argument("--cur", default=".",
                    help="directory with the fresh run's BENCH_*.json")
    ap.add_argument("--threshold", type=float, default=0.25,
                    help="regression threshold as a fraction (0.25 = fail "
                         "on >25%% worse)")
    ap.add_argument("--out", default="",
                    help="write the machine-readable trajectory record "
                         "(BENCH_trajectory.json) here")
    ap.add_argument("--summary", default="",
                    help="append the markdown delta table to this file "
                         "(e.g. $GITHUB_STEP_SUMMARY)")
    ap.add_argument("--self-test", action="store_true",
                    help="verify the gate logic (synthetic slowdown must "
                         "fail, identity must pass) and exit")
    args = ap.parse_args()
    if args.self_test:
        return self_test(args.threshold)
    if not args.prev:
        ap.error("--prev is required (or use --self-test)")

    prev = load_artifacts(args.prev) if os.path.isdir(args.prev) else {}
    cur = load_artifacts(args.cur)
    status = baseline_status(args.prev, prev)
    record = compare(prev, cur, args.threshold, baseline=status)
    record["prev_files"] = sorted(prev)
    record["cur_files"] = sorted(cur)
    if status != "present":
        print(f"# no usable baseline ({status}): seeding the trajectory "
              f"with {record['seeded']} current value(s)")
    table = markdown_table(record)
    print(table)
    if args.summary:
        with open(args.summary, "a") as fh:
            fh.write(table + "\n")
    if args.out:
        with open(args.out, "w") as fh:
            json.dump(record, fh, indent=2)
        print(f"# wrote {args.out}")
    if not record["pass"]:
        print(f"::error::{record['regressions']} bench headline(s) "
              f"regressed by more than {args.threshold:.0%}")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
