"""Kernel microbenches: jnp fused path vs Pallas(interpret) correctness-path
cost, cdist matmul-vs-direct, fused precompute (kexp) saving.

interpret-mode Pallas timing on CPU is NOT a TPU performance statement (the
kernel body runs through the interpreter); it is reported for completeness.
The TPU-side statement is the roofline analysis (EXPERIMENTS.md)."""
from __future__ import annotations

import functools

import jax

from benchmarks.common import emit, timeit, wmd_problem
from repro.core import precompute
from repro.core.cost_matrix import cdist_direct, cdist_matmul
from repro.core.sparse_sinkhorn import pad_k, sddmm_spmm_type1
from repro.kernels import ops


def run() -> dict:
    p = wmd_problem(vocab=4096, docs=128)
    pre = precompute(p["sel"], p["r_sel"], p["vecs"], 1.0)
    k_pad = pad_k(pre.K)
    u = 1.0 / jax.numpy.full((p["v_r"], p["docs"]), 1.0 / p["v_r"])

    jnp_t1 = jax.jit(sddmm_spmm_type1)
    t_jnp = timeit(jnp_t1, k_pad, pre.r, u, p["cols"], p["vals"])
    emit("kernels/type1_jnp_fused", t_jnp * 1e6, "production jnp path")
    t_pal = timeit(functools.partial(ops.sddmm_spmm_type1, docs_blk=8),
                   k_pad, pre.r, u, p["cols"], p["vals"])
    emit("kernels/type1_pallas_interpret", t_pal * 1e6,
         "CPU interpreter (correctness path, not TPU perf)")

    a = p["vecs"][p["sel"]]
    t_direct = timeit(jax.jit(cdist_direct), a, p["vecs"])
    t_matmul = timeit(jax.jit(cdist_matmul), a, p["vecs"])
    emit("kernels/cdist_direct", t_direct * 1e6, "VPU form")
    emit("kernels/cdist_matmul", t_matmul * 1e6,
         f"MXU form;speedup={t_direct / t_matmul:.2f}x")

    # fused precompute: one pass producing (K, KM) vs cdist+exp+mul chain
    lamb = 1.0
    t_unfused_pre = timeit(
        jax.jit(functools.partial(precompute, lamb=lamb)),
        p["sel"], p["r_sel"], p["vecs"])
    t_fused_pre = timeit(
        functools.partial(ops.cdist_kexp, lamb=lamb, v_tile=512),
        a, p["vecs"])
    emit("kernels/precompute_unfused", t_unfused_pre * 1e6, "cdist+exp+mul")
    emit("kernels/precompute_kexp_interpret", t_fused_pre * 1e6,
         "fused single pass (interpret)")
    return {}
