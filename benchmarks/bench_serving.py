"""Async serving bench: request coalescer vs per-query sequential dispatch,
with an arrival-rate x coalescing-window sweep.

    PYTHONPATH=src python benchmarks/bench_serving.py [--tiny] [--chaos] \
        [--windows-ms 2 5 10] [--rate-factors 0.8 2.0] \
        [--out BENCH_serving.json]

What it measures (all through `serving.loadgen` clients, so latencies are
client-side submit -> result):

* **sequential baseline** -- a closed loop of one worker calling
  `WMDService.query` per request: the per-query dispatch ceiling the
  coalescer must beat (qps_seq).
* **saturating coalesced throughput** -- a closed loop with
  2 x max_batch workers over the coalescer: the queue never starves, every
  batch cuts on *fill*, throughput is the batched-engine ceiling. The
  headline `speedup_vs_sequential = qps_coalesced_saturating / qps_seq`
  (>= 1.5x on the 2-core CI box at the low-latency shape, where batching
  amortizes per-query dispatch + precompute -- see bench_query_batch).
  Both sides are measured INTERLEAVED over ``rounds`` paired rounds in
  alternating order (seq-first on even rounds, coalesced-first on odd) and
  the headline is the MEDIAN OF PER-ROUND RATIOS: shared-box drift on the
  CI box is multi-x but slowly varying, so it largely cancels inside a
  pair while medians of independent single shots do not (same reasoning
  as bench_query_batch's interleaved protocol and run_zipf's alternating
  order).
* **rate x window sweep** -- open-loop Poisson arrivals at
  ``rate_factor * qps_seq`` for each coalescing window: below capacity the
  window trigger dominates and p50 rides the window; past the sequential
  ceiling the coalescer keeps serving by cutting bigger batches (mean batch
  size climbs with rate -- the whole point of coalescing). Each point
  records throughput, p50/p95/p99, mean batch size, dispatch-trigger
  counts, and the cache hit rate when the service has one.
* **bitwise gate** -- before timing, every batch a closed-loop run actually
  dispatches is recorded at the engine boundary (payloads + result rows)
  and replayed as a direct `query_batch` call: every row must be bitwise
  identical (the dispatcher-owns-the-device contract). The offline bulk
  run is gated the same way against per-query dispatches of the same
  queries (batch composition must not change a bit).
* **chaos mode** (``--chaos``) -- the saturating closed loop re-run through
  a seeded `serving.faultinject.FaultSchedule` (dispatch errors, latency
  spikes, corrupted outputs) with the resilience layer engaged: the
  artifact gains an ungated ``chaos`` block with availability
  (completed/submitted), goodput (exact non-degraded successes per
  second), degraded fraction, retry and injected-fault counts.
* **cold vs warm start** -- the first thing the bench does is a registry
  warmup (`serving.warmup`) through a fresh persisted compilation cache:
  the *cold* pass pays every XLA backend compile, then a second identical
  service re-warms *warm* -- every program deserializes from the cache.
  The artifact records both sides (compiles, compile seconds, wall), i.e.
  the startup time `--cache-dir` buys a restarted server.
* **two MLPerf-style headlines** -- ``headlines.throughput_mode`` is the
  offline bulk-scoring qps (`serving.offline.run_offline`: full-occupancy
  batches, no windows/deadlines -- the offline scenario) and
  ``headlines.latency_mode`` is p50/p99 at the below-capacity open-loop
  point (smallest window, lowest rate factor -- the server scenario).
  Throughput mode answers "how fast can the engine drain a corpus",
  latency mode "what does a lightly-loaded interactive client see"; a
  change that trades one for the other moves the two headlines in
  opposite directions instead of vanishing into an average.

Artifact: ``BENCH_serving.json`` (uploaded by bench.yml) with the baseline,
saturating point, sweep grid, warmup deltas, offline block and headline
speedup. Self-contained on purpose (no benchmarks.common import): CI
invokes it as a script with only the installed `repro` package on the path.
"""
from __future__ import annotations

import argparse
import itertools
import json


def run(*, vocab: int = 1024, docs: int = 128, v_r: int = 16,
        query_words: int = 13, mean_words: float = 8.0,
        max_batch: int = 16, n_requests: int = 96,
        n_baseline: int = 24, rounds: int = 5,
        windows_ms=(2.0, 5.0, 10.0),
        rate_factors=(0.8, 2.0), cache_capacity: int = 0,
        zipf_s: float = 1.3, seed: int = 0, chaos: bool = False,
        ingest: bool = False, out: str | None = None) -> dict:
    import tempfile

    import numpy as np
    from repro.configs.sinkhorn_wmd import WMDConfig
    from repro.data import make_corpus, zipf_query_stream
    from repro.launch.mesh import make_mesh
    from repro.serving import (ShapeRegistry, WMDService, closed_loop,
                               enable_compilation_cache,
                               flush_compilation_cache, open_loop,
                               run_offline)
    from repro.serving import warm as registry_warm

    cfg = WMDConfig(name="bench-serving", vocab_size=vocab, embed_dim=64,
                    num_docs=docs, nnz_max=64, v_r=v_r, lamb=1.0,
                    max_iter=15)
    data = make_corpus(vocab_size=vocab, embed_dim=cfg.embed_dim,
                       num_docs=docs, num_queries=1,
                       query_words=query_words, mean_words=mean_words,
                       seed=seed)
    mesh = make_mesh((1, 1), ("data", "model"))
    # the persisted cache must be configured before the FIRST compile for
    # the cold pass below to be genuinely cold
    enable_compilation_cache(tempfile.mkdtemp(prefix="bench-jaxcache-"))
    svc = WMDService(mesh=mesh, cfg=cfg, vecs=data.vecs, ell=data.ell,
                     cache_capacity=cache_capacity)
    stream = zipf_query_stream(vocab_size=vocab, query_words=query_words,
                               s=zipf_s, seed=seed + 1)
    qs = list(itertools.islice(stream, n_requests))

    results = {}

    # -- cold vs warm start: registry warmup pays every compile into a
    # fresh persisted cache; a second identical service (new jit objects,
    # same programs) re-warms from it -- the delta is the startup time the
    # cache buys a restarted server.
    registry = ShapeRegistry.from_service(svc, max_batch=max_batch)
    rep_cold = registry_warm(svc, registry, queries=qs)
    svc_restart = WMDService(mesh=mesh, cfg=cfg, vecs=data.vecs,
                             ell=data.ell, cache_capacity=cache_capacity)
    rep_warm = registry_warm(svc_restart, registry, queries=qs)
    del svc_restart
    cache_info = flush_compilation_cache() or {}
    results["warmup"] = {
        "shapes": registry.labels,
        "cold": rep_cold.summary(), "warm": rep_warm.summary(),
        "compile_s_saved": rep_cold.compile_s - rep_warm.compile_s,
        "wall_s_saved": rep_cold.wall_s - rep_warm.wall_s,
        "cache_entries": cache_info.get("entries"),
        "cache_bytes": cache_info.get("bytes")}
    print(f"# warmup cold: {rep_cold.compiles} compiles "
          f"({rep_cold.compile_s:.2f}s, wall {rep_cold.wall_s:.2f}s) | "
          f"warm restart: {rep_warm.compiles} compiles, "
          f"{rep_warm.persistent_hits} cache hits "
          f"(wall {rep_warm.wall_s:.2f}s)")

    # warm the per-query program the sequential baseline runs (the pow2
    # buckets are already warm from the registry pass)
    svc.query(qs[0])

    results.update(
        {"vocab": vocab, "docs": docs, "v_r": v_r,
         "query_words": query_words, "max_batch": max_batch,
         "n_requests": n_requests, "cache_capacity": cache_capacity,
         "zipf_s": zipf_s, "max_iter": cfg.max_iter,
         "note": ("speedup_vs_sequential = saturating closed-loop "
                  "coalesced throughput / single-worker per-query "
                  "dispatch throughput. Sweep rates are multiples of "
                  "the measured sequential ceiling so the grid "
                  "adapts to the box. bitwise_checked: every "
                  "dispatched batch recorded at the engine boundary "
                  "and replayed as a direct query_batch, "
                  "array_equal. headlines: throughput_mode = offline "
                  "bulk qps, latency_mode = p50/p99 at the "
                  "below-capacity open-loop point.")})

    # -- bitwise gate: coalesced == direct query_batch of the same batches.
    # Record each dispatched (payloads, rows) pair at the engine boundary
    # and replay it directly afterwards: concurrent closed-loop submitters
    # can reach the coalescer in a different order than they popped queries,
    # so the batch compositions must be captured, not reconstructed from
    # request seq numbers.
    dispatched = []
    orig_query_batch = svc.query_batch

    def recording(rs, **kw):
        rows = orig_query_batch(rs, **kw)
        dispatched.append(([np.array(r) for r in rs], np.asarray(rows)))
        return rows

    with svc.async_service(window_ms=2.0, max_batch=max_batch) as co:
        co.warm(qs)       # compile every pow2 Q bucket (outside recording)
        svc.query_batch = recording
        try:
            closed_loop(co.submit, qs[:4 * max_batch],
                        concurrency=max_batch)
        finally:
            svc.query_batch = orig_query_batch
    for k, (rs, rows) in enumerate(dispatched):
        np.testing.assert_array_equal(
            np.asarray(svc.query_batch(rs)), rows,
            err_msg=f"coalesced dispatch {k} != direct query_batch")
    results["bitwise_checked"] = True
    results["bitwise_dispatches"] = len(dispatched)
    print(f"# bitwise gate: {len(dispatched)} coalesced dispatches == "
          f"direct query_batch (array_equal)")

    # -- sequential baseline vs saturating coalesced: paired rounds in
    # alternating order, headline = median of per-round ratios (see module
    # docstring -- slowly-varying shared-box drift cancels inside a pair).
    med = lambda xs: sorted(xs)[len(xs) // 2]   # noqa: E731
    seq_qps, sat_qps, seq_runs, sat_runs = [], [], [], []
    run_seq = lambda: closed_loop(lambda r: svc.query(r),   # noqa: E731
                                  qs[:n_baseline], concurrency=1)
    # at saturation the window is a throughput knob, not a latency one: a
    # wide window lets every batch reach fill (mean_batch -> max_batch)
    # while the queue hides the wait -- measured on the CI box, 10 ms vs
    # 2 ms is mean_batch 7.8-8.0 vs ~6.5 and ~1.4x the throughput
    sat_kw = dict(window_ms=max(*windows_ms, 10.0), max_batch=max_batch,
                  max_queue=4 * max_batch)
    with svc.async_service(**sat_kw) as co_warm:
        closed_loop(co_warm.submit, qs,   # warm the odd Q buckets on a
                    concurrency=2 * max_batch)   # throwaway coalescer so
    with svc.async_service(**sat_kw) as co:      # measured stats are clean
        run_sat = lambda: closed_loop(co.submit, qs,        # noqa: E731
                                      concurrency=2 * max_batch)
        for i in range(rounds):
            if i % 2 == 0:
                seq, sat = run_seq(), run_sat()
            else:
                sat, seq = run_sat(), run_seq()
            seq_qps.append(seq.throughput_qps)
            sat_qps.append(sat.throughput_qps)
            seq_runs.append(seq)
            sat_runs.append(sat)
        sat_stats = co.stats()
    ratios = [s / q for s, q in zip(sat_qps, seq_qps)]
    qps_seq, qps_sat = med(seq_qps), med(sat_qps)
    seq = seq_runs[seq_qps.index(qps_seq)]        # both summaries from the
    sat = sat_runs[sat_qps.index(qps_sat)]        # median-throughput round
    results["sequential"] = {**seq.summary(), "qps_rounds": seq_qps,
                             "throughput_qps": qps_seq}
    results["saturating"] = {**sat.summary(), "qps_rounds": sat_qps,
                             "throughput_qps": qps_sat,
                             "mean_batch_size": sat_stats.mean_batch_size,
                             "batch_size_hist": sat_stats.batch_size_hist,
                             "dispatch_fill": sat_stats.dispatch_fill,
                             "dispatch_window": sat_stats.dispatch_window,
                             "hit_rate": sat_stats.hit_rate}
    results["speedup_rounds"] = ratios
    results["speedup_vs_sequential"] = med(ratios)
    print(f"serving/seq,{1e6 / qps_seq:.1f},qps={qps_seq:.1f}")
    print(f"serving/saturating,{1e6 / qps_sat:.1f},"
          f"qps={qps_sat:.1f}:"
          f"mean_batch={sat_stats.mean_batch_size:.1f}:"
          f"speedup={results['speedup_vs_sequential']:.2f}x:"
          f"rounds={[round(r, 2) for r in ratios]}")

    # -- observability overhead: the same saturating closed loop with a
    # span tracer + metrics registry attached vs the shared no-op
    # recorder, paired rounds in alternating order (shared-box drift
    # cancels inside a pair, same protocol as the headline above). All
    # fields are UNGATED (never a compare_bench gated path): the <= 5%
    # contract is recorded as overhead_fraction for review, and the
    # traced rounds' span trees are exported as a sample Perfetto trace.
    from repro.obs import MetricsRegistry, Tracer
    obs_tracer = Tracer(ring=8 * n_requests)
    obs_reg = MetricsRegistry()

    def run_sat_obs(tr, reg):
        kw = dict(sat_kw)
        if tr is not None:
            kw.update(tracer=tr, metrics=reg)
        with svc.async_service(**kw) as co_o:
            return closed_loop(co_o.submit, qs,
                               concurrency=2 * max_batch).throughput_qps

    on_qps, off_qps = [], []
    for i in range(rounds):
        if i % 2 == 0:
            on = run_sat_obs(obs_tracer, obs_reg)
            off_q = run_sat_obs(None, None)
        else:
            off_q = run_sat_obs(None, None)
            on = run_sat_obs(obs_tracer, obs_reg)
        on_qps.append(on)
        off_qps.append(off_q)
    qps_on, qps_off = med(on_qps), med(off_qps)
    overhead = 1.0 - qps_on / qps_off
    results["observability"] = {
        "qps_obs_on": qps_on, "qps_obs_off": qps_off,
        "qps_obs_on_rounds": on_qps, "qps_obs_off_rounds": off_qps,
        "overhead_fraction": overhead,
        "span_trees": len(obs_tracer.snapshot()[0]),
        "trees_dropped": obs_tracer.dropped,
        "note": ("UNGATED: paired saturating rounds with a Tracer + "
                 "MetricsRegistry attached vs the no-op recorder; "
                 "overhead_fraction = 1 - qps_on/qps_off (median of "
                 "rounds). Contract: <= 0.05.")}
    print(f"serving/obs,{1e6 / max(qps_on, 1e-9):.1f},"
          f"qps_on={qps_on:.1f}:qps_off={qps_off:.1f}:"
          f"overhead={overhead:+.1%}")
    if out:
        trace_path = "BENCH_trace_sample.json"
        n_ev = obs_tracer.export_chrome(trace_path)
        print(f"# wrote {trace_path} ({n_ev} Perfetto trace events)")

    # -- arrival rate x window sweep (open-loop Poisson)
    results["sweep"] = []
    for window_ms in windows_ms:
        for factor in rate_factors:
            rate = factor * qps_seq
            with svc.async_service(window_ms=window_ms,
                                   max_batch=max_batch,
                                   max_queue=8 * max_batch) as co:
                res = open_loop(co.submit, iter(qs), rate_qps=rate,
                                seed=seed)
                st = co.stats()
            point = {"window_ms": window_ms, "rate_factor": factor,
                     **res.summary(),
                     "mean_batch_size": st.mean_batch_size,
                     "batch_size_hist": st.batch_size_hist,
                     "dispatch_fill": st.dispatch_fill,
                     "dispatch_window": st.dispatch_window,
                     "dispatch_deadline": st.dispatch_deadline,
                     "dispatch_drain": st.dispatch_drain,
                     "hit_rate": st.hit_rate}
            results["sweep"].append(point)
            print(f"serving/w{window_ms:g}r{factor:g},"
                  f"{1e6 / max(res.throughput_qps, 1e-9):.1f},"
                  f"qps={res.throughput_qps:.1f}:"
                  f"p50={res.percentile_ms(50):.1f}ms:"
                  f"p99={res.percentile_ms(99):.1f}ms:"
                  f"mean_batch={st.mean_batch_size:.1f}")

    # -- offline bulk scoring (throughput mode): full-occupancy batches,
    # no admission layer at all -- the drain-a-corpus ceiling. Gated
    # bitwise against direct query_batch calls of the same buckets (the
    # coalescer's composition-preserving contract; the full-solve
    # program's bits are per-bucket-shape, see serving.offline)
    off = run_offline(svc, qs, max_batch=max_batch)    # warm from registry
    off = run_offline(svc, qs, max_batch=max_batch)    # timed run
    for lo in range(0, min(len(qs), 2 * max_batch), max_batch):
        np.testing.assert_array_equal(
            off.dists[lo:lo + max_batch],
            np.asarray(svc.query_batch(qs[lo:lo + max_batch])),
            err_msg=f"offline bucket @{lo} != direct query_batch")
    results["offline"] = {**off.summary(), "bitwise_checked": True}
    print(f"serving/offline,{1e6 / max(off.throughput_qps, 1e-9):.1f},"
          f"qps={off.throughput_qps:.1f}:batches={off.batches}")

    # -- chaos mode (--chaos): the same closed loop through a seeded
    # fault injector + the resilience layer. Availability = completed /
    # submitted; goodput = EXACT (non-degraded) successes per second --
    # degraded bound-only answers keep availability up but don't count as
    # goodput. Fields are reported, not gated (tests/test_resilience.py
    # owns the >= 0.99 availability assertion).
    if chaos:
        from repro.serving import QueryCoalescer
        from repro.serving.faultinject import FaultSchedule, FaultyEngine
        from repro.serving.resilience import ResiliencePolicy
        sched = FaultSchedule(seed=seed + 7, p_error=0.15, p_latency=0.1,
                              p_corrupt=0.05, latency_s=0.002)
        eng = FaultyEngine(svc, sched)
        policy = ResiliencePolicy(max_retries=3, breaker_failures=4,
                                  breaker_cooldown_s=0.05,
                                  backoff_base_s=0.001, backoff_max_s=0.01,
                                  seed=seed)
        co = QueryCoalescer(eng, window_ms=2.0, max_batch=max_batch,
                            resilience=policy)
        try:
            res = closed_loop(co.submit, qs, concurrency=max_batch)
            st = co.stats()
        finally:
            co.shutdown(drain=True, timeout=120.0)
        availability = res.completed / max(res.submitted, 1)
        goodput = (res.completed - st.degraded) / max(res.duration_s, 1e-9)
        results["chaos"] = {
            "schedule": {"seed": seed + 7, "p_error": 0.15,
                         "p_latency": 0.1, "p_corrupt": 0.05,
                         "latency_s": 0.002},
            "injected": dict(eng.injected),
            "availability": availability,
            "goodput_qps": goodput,
            "throughput_qps": res.throughput_qps,
            "completed": res.completed, "failed": res.failed,
            "degraded": st.degraded,
            "degraded_fraction": st.degraded_fraction,
            "retries": st.retries,
            "breaker_transitions": st.breaker_transitions}
        print(f"serving/chaos,{1e6 / max(goodput, 1e-9):.1f},"
              f"avail={availability:.4f}:goodput={goodput:.1f}qps:"
              f"degraded_frac={st.degraded_fraction:.3f}:"
              f"retries={st.retries}:"
              f"injected={dict(eng.injected)}")

    # -- mixed read/write serving over a live (WAL-backed) corpus: zipf
    # reads with interleaved add/remove upserts through the coalescer's
    # writer lane, one mid-stream compaction. All fields are UNGATED
    # (recorded for the trajectory, never a headline): ingest throughput
    # on a tiny corpus is dominated by fsync latency, which is exactly the
    # box property worth tracking but not gating on.
    if ingest:
        import time

        from repro.core import formats as _formats
        from repro.data import LiveCorpus

        live = LiveCorpus(tempfile.mkdtemp(prefix="bench-live-"), vocab,
                          normalize=False)
        seed_docs = _formats.doc_lists_from_ell(data.ell)
        live.add_docs(list(range(len(seed_docs))), seed_docs)
        live_svc = WMDService(mesh=mesh, cfg=cfg, vecs=data.vecs, live=live,
                              cache_capacity=cache_capacity)
        wrng = np.random.default_rng(seed + 9)
        n_writes = max(8, n_requests // 4)
        every = max(1, len(qs) // n_writes)
        added: list[int] = []
        next_id = live.num_live
        t0 = time.perf_counter()
        with live_svc.async_service(window_ms=2.0,
                                    max_batch=max_batch) as co:
            co.warm(qs[: 2 * max_batch])
            futs, writes = [], []
            for i, q in enumerate(qs):
                futs.append(co.submit(q))
                if i % every == 0:
                    if added and wrng.random() < 0.25:
                        writes.append(co.submit_remove_docs([added.pop(0)]))
                    else:
                        wids = wrng.choice(vocab, 6, replace=False)
                        w = wrng.random(6).astype(np.float32)
                        doc = [(int(a), float(b)) for a, b in
                               zip(wids, w / w.sum())]
                        writes.append(co.submit_add_docs([next_id], [doc]))
                        added.append(next_id)
                        next_id += 1
                if i == len(qs) // 2:
                    live_svc.compact()           # mid-stream generation roll
            acked = sum(f.result(timeout=120.0) for f in writes)
            for f in futs:
                f.result(timeout=120.0)
            st_live = co.stats()
        mixed_wall = time.perf_counter() - t0
        results["ingest"] = {
            "reads": len(qs), "write_ops": len(writes),
            "write_acked": int(acked),
            "write_dispatches": st_live.write_dispatches,
            "docs_added": st_live.docs_added,
            "docs_removed": st_live.docs_removed,
            "mixed_qps": (len(qs) + len(writes)) / max(mixed_wall, 1e-9),
            "latency_ms_p50": st_live.latency_ms_p50,
            "latency_ms_p99": st_live.latency_ms_p99,
            "corpus": live.stats()}
        print(f"serving/ingest,{1e6 * mixed_wall / (len(qs) + len(writes)):.1f},"
              f"reads={len(qs)}:writes={len(writes)}:acked={acked}:"
              f"write_dispatches={st_live.write_dispatches}:"
              f"gen={live.stats()['gen']}:live={live.num_live}")
        live.close()

    # -- the two MLPerf-style headlines (see module docstring)
    lat_pt = min(results["sweep"],
                 key=lambda p: (p["rate_factor"], p["window_ms"]))
    results["headlines"] = {
        "throughput_mode": {"metric": "offline_bulk_qps",
                            "value": off.throughput_qps,
                            "saturating_online_qps": qps_sat},
        "latency_mode": {"metric": "p99_ms_open_loop",
                         "value": lat_pt["latency_ms_p99"],
                         "p50_ms": lat_pt["latency_ms_p50"],
                         "window_ms": lat_pt["window_ms"],
                         "rate_factor": lat_pt["rate_factor"]}}
    print(f"# headline throughput-mode: {off.throughput_qps:.1f} qps "
          f"(offline bulk) | latency-mode: "
          f"p50={lat_pt['latency_ms_p50']:.1f}ms "
          f"p99={lat_pt['latency_ms_p99']:.1f}ms "
          f"(w={lat_pt['window_ms']:g}ms, "
          f"{lat_pt['rate_factor']:g}x seq rate)")
    if out:
        with open(out, "w") as f:
            json.dump(results, f, indent=2)
        print(f"# wrote {out}")
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--vocab", type=int, default=1024)
    ap.add_argument("--docs", type=int, default=128)
    ap.add_argument("--v-r", type=int, default=16)
    ap.add_argument("--query-words", type=int, default=13)
    ap.add_argument("--mean-words", type=float, default=8.0)
    ap.add_argument("--max-batch", type=int, default=16)
    ap.add_argument("--requests", type=int, default=96)
    ap.add_argument("--rounds", type=int, default=5)
    ap.add_argument("--windows-ms", type=float, nargs="+",
                    default=[2.0, 5.0, 10.0])
    ap.add_argument("--rate-factors", type=float, nargs="+",
                    default=[0.8, 2.0],
                    help="open-loop arrival rates as multiples of the "
                         "measured sequential qps ceiling")
    ap.add_argument("--cache-capacity", type=int, default=0,
                    help="cross-query K-cache rows (adds hit_rate "
                         "passthrough to every point)")
    ap.add_argument("--zipf-s", type=float, default=1.3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke shape (small corpus, max_batch 8, "
                         "short sweep)")
    ap.add_argument("--chaos", action="store_true",
                    help="also run the closed loop through a seeded fault "
                         "injector + the resilience layer; reports "
                         "availability / goodput / degraded fraction")
    ap.add_argument("--ingest", action="store_true",
                    help="also run a mixed read/write block over a "
                         "WAL-backed live corpus (coalescer writer lane, "
                         "mid-stream compaction); fields are recorded "
                         "ungated -- never a regression-gate headline")
    ap.add_argument("--out", default="BENCH_serving.json")
    args = ap.parse_args()
    if args.tiny:
        run(vocab=512, docs=64, max_batch=8, n_requests=64, n_baseline=16,
            rounds=5, windows_ms=(2.0, 5.0), rate_factors=(0.8, 2.0),
            cache_capacity=args.cache_capacity, seed=args.seed,
            chaos=args.chaos, ingest=args.ingest, out=args.out)
    else:
        run(vocab=args.vocab, docs=args.docs, v_r=args.v_r,
            query_words=args.query_words, mean_words=args.mean_words,
            max_batch=args.max_batch,
            n_requests=args.requests, rounds=args.rounds,
            windows_ms=tuple(args.windows_ms),
            rate_factors=tuple(args.rate_factors),
            cache_capacity=args.cache_capacity, zipf_s=args.zipf_s,
            seed=args.seed, chaos=args.chaos, ingest=args.ingest,
            out=args.out)


if __name__ == "__main__":
    main()
