"""Shared benchmark utilities: deterministic problem builder + timer."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ell_from_dense, select_query
from repro.data.corpus import make_corpus


def timeit(fn, *args, warmup: int = 1, repeat: int = 3) -> float:
    """Median wall seconds of ``fn(*args)`` after warmup (blocks on result)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(repeat):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def wmd_problem(*, vocab=20_000, embed=300, docs=512, query_words=19,
                seed=0):
    """Paper-statistics problem at CPU-benchable scale.

    The paper's full dataset (V=100k, N=5000) is a ~50x larger instance of
    exactly this generator; benchmarks report derived per-unit costs that
    extrapolate linearly (Table II asymptotics -- verified by
    bench_asymptotic).
    """
    data = make_corpus(vocab_size=vocab, embed_dim=embed, num_docs=docs,
                       num_queries=1, query_words=query_words, seed=seed)
    sel, r_sel = select_query(data.queries[0])
    return {
        "vecs": jnp.asarray(data.vecs),
        "sel": jnp.asarray(sel),
        "r_sel": jnp.asarray(r_sel),
        "cols": jnp.asarray(data.ell.cols),
        "vals": jnp.asarray(data.ell.vals),
        "c_dense": jnp.asarray(data.ell.to_dense()),
        "ell": data.ell,
        "nnz": data.nnz,
        "vocab": vocab, "docs": docs, "embed": embed,
        "v_r": int(sel.shape[0]),
    }


def emit(name: str, us_per_call: float, derived: str) -> None:
    print(f"{name},{us_per_call:.1f},{derived}")
