"""Fig. 8 analogue: runtime of all implementations + speedups.

Paper: MKL-python 47s -> baseline C++ 1.46s (32x) -> fused C++ 0.035s
(42x more, ~700-1331x total). Here the ladder is: dense jnp (the naive
formulation the paper starts from) -> sparse unfused -> sparse fused ->
fused with precompute kernel path. Speedups are the 'derived' column.
"""
from __future__ import annotations

import functools

from benchmarks.common import emit, timeit, wmd_problem
from repro.core import sinkhorn_wmd_dense, sinkhorn_wmd_sparse

ITERS = 10


def run() -> dict:
    p = wmd_problem()
    dense = functools.partial(sinkhorn_wmd_dense, lamb=1.0, max_iter=ITERS)
    unfused = functools.partial(sinkhorn_wmd_sparse, lamb=1.0,
                                max_iter=ITERS, impl="unfused")
    fused = functools.partial(sinkhorn_wmd_sparse, lamb=1.0, max_iter=ITERS,
                              impl="fused")
    t_dense = timeit(dense, p["sel"], p["r_sel"], p["c_dense"], p["vecs"])
    t_unfused = timeit(unfused, p["sel"], p["r_sel"], p["cols"], p["vals"],
                       p["vecs"])
    t_fused = timeit(fused, p["sel"], p["r_sel"], p["cols"], p["vals"],
                     p["vecs"])
    emit("fig8/dense_naive", t_dense * 1e6, "speedup=1.0x")
    emit("fig8/sparse_unfused", t_unfused * 1e6,
         f"speedup={t_dense / t_unfused:.1f}x")
    emit("fig8/sparse_fused", t_fused * 1e6,
         f"speedup={t_dense / t_fused:.1f}x;fusion={t_unfused / t_fused:.2f}x")
    return {"dense": t_dense, "unfused": t_unfused, "fused": t_fused}
