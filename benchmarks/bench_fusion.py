"""Fig. 9 analogue: speedup from SDDMM-SpMM fusion.

Paper: 1.15-2.22x, growing with cores (fusion saves memory traffic, and
more cores = more bandwidth-bound). Cores cannot be swept on this
container; the bandwidth-pressure axis here is the doc count (bigger N =
more gather traffic per iteration), plus the vocab-chunked driver as a
shard-count proxy."""
from __future__ import annotations

import functools

from benchmarks.common import emit, timeit, wmd_problem
from repro.core import sinkhorn_wmd_sparse

ITERS = 10


def run() -> dict:
    out = {}
    for docs in (128, 512, 2048):
        p = wmd_problem(docs=docs)
        args = (p["sel"], p["r_sel"], p["cols"], p["vals"], p["vecs"])
        f = functools.partial(sinkhorn_wmd_sparse, lamb=1.0, max_iter=ITERS,
                              impl="fused")
        u = functools.partial(sinkhorn_wmd_sparse, lamb=1.0, max_iter=ITERS,
                              impl="unfused")
        tf, tu = timeit(f, *args), timeit(u, *args)
        emit(f"fig9/fusion_speedup_docs{docs}", tf * 1e6,
             f"fused_vs_unfused={tu / tf:.2f}x")
        out[docs] = tu / tf
    return out
