"""Table II verification: the iteration cost scales with t * nnz * v_r and
is independent of V; only the precompute carries the V * v_r * w term.

Times the LOOP in isolation (the paper's bound is about the loop; the
V-dependent precompute is a separate Table II term)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timeit, wmd_problem
from repro.core import precompute
from repro.core.sparse_sinkhorn import pad_k, safe_recip, sddmm_spmm_type1

ITERS = 10


def _loop_only(p):
    pre = jax.jit(precompute, static_argnames=("lamb",))(
        p["sel"], p["r_sel"], p["vecs"], lamb=1.0)
    k_pad = pad_k(pre.K)
    x0 = jnp.full((p["v_r"], p["docs"]), 1.0 / p["v_r"], jnp.float32)

    @jax.jit
    def loop(k_pad, r, x, cols, vals):
        def body(_, x):
            return sddmm_spmm_type1(k_pad, r, safe_recip(x), cols, vals)
        return jax.lax.fori_loop(0, ITERS, body, x)

    return timeit(loop, k_pad, pre.r, x0, p["cols"], p["vals"])


def run() -> dict:
    # scaling in nnz (via docs): expected exponent ~1.0
    docs_list = (256, 1024, 4096)
    times, nnzs = [], []
    for docs in docs_list:
        p = wmd_problem(docs=docs)
        times.append(_loop_only(p))
        nnzs.append(p["nnz"])
    exp = float(np.polyfit(np.log(nnzs), np.log(times), 1)[0])
    emit("table2/loop_nnz_scaling_exponent", times[-1] * 1e6,
         f"exponent={exp:.2f};expected~1.0")

    # V-independence of the loop at fixed nnz (dense algorithm would be ~4x)
    t_v1 = _loop_only(wmd_problem(vocab=10_000, docs=1024))
    t_v2 = _loop_only(wmd_problem(vocab=40_000, docs=1024))
    emit("table2/loop_vocab_4x_ratio", t_v2 * 1e6,
         f"ratio={t_v2 / t_v1:.2f};sparse_expected~1.0;dense_would_be~4.0")

    # precompute DOES scale with V (the V*v_r*w term)
    p1 = wmd_problem(vocab=10_000, docs=256)
    p2 = wmd_problem(vocab=40_000, docs=256)
    pre_t1 = timeit(jax.jit(functools.partial(precompute, lamb=1.0)),
                    p1["sel"], p1["r_sel"], p1["vecs"])
    pre_t2 = timeit(jax.jit(functools.partial(precompute, lamb=1.0)),
                    p2["sel"], p2["r_sel"], p2["vecs"])
    emit("table2/precompute_vocab_4x_ratio", pre_t2 * 1e6,
         f"ratio={pre_t2 / pre_t1:.2f};expected~4.0")
    return {"nnz_exponent": exp, "loop_vocab_ratio": t_v2 / t_v1}
