"""Unit tests for the roofline cost model -- the §Roofline methodology
depends on these being exactly right."""
import jax
import jax.numpy as jnp

from repro.launch.costmodel import Cost, jaxpr_cost, _shape_bytes


def test_dot_flops_exact():
    f = lambda a, b: a @ b
    t = jax.jit(f).trace(jax.ShapeDtypeStruct((32, 64), jnp.float32),
                         jax.ShapeDtypeStruct((64, 16), jnp.float32))
    assert jaxpr_cost(t.jaxpr).flops == 2 * 32 * 64 * 16


def test_batched_dot_flops():
    f = lambda a, b: jnp.einsum("bij,bjk->bik", a, b)
    t = jax.jit(f).trace(jax.ShapeDtypeStruct((4, 8, 16), jnp.float32),
                         jax.ShapeDtypeStruct((4, 16, 8), jnp.float32))
    assert jaxpr_cost(t.jaxpr).flops == 4 * 2 * 8 * 16 * 8


def test_scan_trip_count_multiplies():
    def f(x, ws):
        y, _ = jax.lax.scan(lambda c, w: (c @ w, None), x, ws)
        return y
    t = jax.jit(f).trace(jax.ShapeDtypeStruct((8, 16), jnp.float32),
                         jax.ShapeDtypeStruct((7, 16, 16), jnp.float32))
    got = jaxpr_cost(t.jaxpr).flops
    assert got == 7 * 2 * 8 * 16 * 16


def test_grad_counts_backward():
    def loss(w, x):
        y, _ = jax.lax.scan(lambda c, wi: (jnp.tanh(c @ wi), None), x, w)
        return jnp.sum(y * y)
    w = jax.ShapeDtypeStruct((4, 32, 32), jnp.float32)
    x = jax.ShapeDtypeStruct((8, 32), jnp.float32)
    fwd = jaxpr_cost(jax.jit(loss).trace(w, x).jaxpr).flops
    grad = jaxpr_cost(jax.jit(jax.grad(loss)).trace(w, x).jaxpr).flops
    assert 2.8 < grad / fwd < 3.3          # fwd + 2x in backward


def test_remat_counts_recompute():
    def loss(w, x):
        body = jax.checkpoint(lambda c, wi: jnp.tanh(c @ wi))
        y, _ = jax.lax.scan(lambda c, wi: (body(c, wi), None), x, w)
        return jnp.sum(y * y)
    w = jax.ShapeDtypeStruct((4, 32, 32), jnp.float32)
    x = jax.ShapeDtypeStruct((8, 32), jnp.float32)
    grad = jaxpr_cost(jax.jit(jax.grad(loss)).trace(w, x).jaxpr).flops
    one = 2 * 8 * 32 * 32
    assert 3.8 * 4 * one < grad < 4.4 * 4 * one   # ~4x per layer w/ remat


def test_while_flagged_unknown():
    def f(x):
        return jax.lax.while_loop(lambda c: jnp.sum(c) < 100.0,
                                  lambda c: c * 2.0, x)
    t = jax.jit(f).trace(jax.ShapeDtypeStruct((8,), jnp.float32))
    assert jaxpr_cost(t.jaxpr).unknown_loops >= 1


def test_shape_bytes_parser():
    assert _shape_bytes("f32[8,256]{1,0} all-gather(...)") == 8 * 256 * 4
    assert _shape_bytes("bf16[2,4]{1,0}") == 2 * 4 * 2
    assert _shape_bytes("(f32[4], s32[2])") == 4 * 4 + 2 * 4
    assert _shape_bytes("pred[]") == 1


def test_collective_parser_end_to_end():
    """Hand-checkable program: AG inside a 5-trip scan on a (2,4) mesh."""
    import json
    import os
    import subprocess
    import sys
    import textwrap
    code = textwrap.dedent("""
        import jax, jax.numpy as jnp, json
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.compat import make_mesh
        from repro.launch.costmodel import collective_bytes
        mesh = make_mesh((2, 4), ("data", "model"))
        def step(x, ws):
            y, _ = jax.lax.scan(lambda c, w: (c @ w, None), x, ws)
            return jnp.sum(y)
        x = jax.ShapeDtypeStruct((16, 256), jnp.float32,
            sharding=NamedSharding(mesh, P("data", None)))
        ws = jax.ShapeDtypeStruct((5, 256, 256), jnp.float32,
            sharding=NamedSharding(mesh, P(None, None, "model")))
        cb = collective_bytes(jax.jit(step).lower(x, ws).compile().as_text())
        print(json.dumps(cb["by_kind"]))
    """)
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=os.path.join(os.path.dirname(os.path.dirname(
                   os.path.abspath(__file__))), "src"))
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=300)
    assert out.returncode == 0, out.stderr[-2000:]
    by_kind = json.loads(out.stdout.strip().splitlines()[-1])
    # AG of f32[8,256] per device, ring (4-1)/4, x5 trips. The exact gathered
    # shape is XLA-version dependent (older releases pad the operand, only
    # ever ADDING bytes -- observed 1.25x on 0.4.x, exact on current), so
    # bound from below by the analytic value and above by the padding slack:
    # dropping a scan trip (0.8x) or the ring factor (1.33x) still fails.
    analytic = 8 * 256 * 4 * 0.75 * 5
    assert analytic * 0.999 <= by_kind["all-gather"] <= analytic * 1.3, \
        by_kind["all-gather"]


def test_cost_add_mul():
    c = Cost(flops=2, bytes=4, collective_bytes=6) * 3
    assert (c.flops, c.bytes, c.collective_bytes) == (6, 12, 18)
    s = c + Cost(flops=1, bytes=1, collective_bytes=1, unknown_loops=2)
    assert (s.flops, s.unknown_loops) == (7, 2)
