"""Per-architecture smoke tests (assignment deliverable f): REDUCED config of
the same family, one forward/train step on CPU, output shapes + no NaNs."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import arch_ids, get_config, get_smoke_config
from repro.models import build_model
from repro.optim import adamw, constant
from repro.train.step import init_state


def _batch(cfg, rng, b=2, s=32):
    out = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)),
                                 jnp.int32),
           "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)),
                                 jnp.int32)}
    if cfg.family == "vlm":
        p = cfg.encoder.num_positions
        out["patches"] = jnp.asarray(
            rng.normal(size=(b, p, cfg.d_model)), jnp.float32)
        out["tokens"] = out["tokens"][:, : s - p]
        out["labels"] = out["labels"][:, : s - p]
    if cfg.family == "audio":
        f = cfg.encoder.num_positions
        out["frames"] = jnp.asarray(
            rng.normal(size=(b, f, cfg.d_model)), jnp.float32)
    return out


@pytest.mark.parametrize("arch", arch_ids())
def test_forward_loss(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg, q_block=16, kv_block=16)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg, np.random.default_rng(0))
    loss, metrics = jax.jit(model.loss)(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch}: loss not finite"
    assert float(loss) > 0


@pytest.mark.parametrize("arch", arch_ids())
def test_train_step(arch):
    """One full optimizer step: params move, loss finite, no NaN params."""
    cfg = get_smoke_config(arch)
    model = build_model(cfg, q_block=16, kv_block=16)
    opt = adamw(constant(1e-3))
    state = init_state(model, opt, jax.random.PRNGKey(0))
    batch = _batch(cfg, np.random.default_rng(1))

    @jax.jit
    def step(state, batch):
        (loss, _), grads = jax.value_and_grad(
            model.loss, has_aux=True)(state.params, batch)
        params, opt_state = opt.update(grads, state.opt, state.params)
        return params, opt_state, loss

    params2, _, loss = step(state, batch)
    assert bool(jnp.isfinite(loss))
    leaves_before = jax.tree.leaves(state.params)
    leaves_after = jax.tree.leaves(params2)
    moved = any(float(jnp.abs(a - b).max()) > 0
                for a, b in zip(leaves_after, leaves_before))
    assert moved, f"{arch}: params did not update"
    assert all(bool(jnp.isfinite(x).all()) for x in leaves_after), \
        f"{arch}: NaN/inf in updated params"


@pytest.mark.parametrize("arch", arch_ids())
def test_prefill_decode_consistency(arch):
    """Decode after prefill == teacher-forced prefill at the same position.

    MoE archs get a generous tolerance: near-tied router logits legitimately
    flip expert choices between the two numerics paths (argmax must agree);
    capacity factor is raised so drops don't dominate the comparison.
    """
    cfg = get_smoke_config(arch)
    if cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    model = build_model(cfg, q_block=8, kv_block=8, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(2)
    b, s1, s2, maxlen = 2, 16, 24, 32
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s2)), jnp.int32)
    batch = {"tokens": toks[:, :s1], "labels": toks[:, :s1]}
    if cfg.family == "vlm":
        p = cfg.encoder.num_positions
        batch["patches"] = jnp.asarray(
            rng.normal(size=(b, p, cfg.d_model)), jnp.float32)
    if cfg.family == "audio":
        f = cfg.encoder.num_positions
        batch["frames"] = jnp.asarray(
            rng.normal(size=(b, f, cfg.d_model)), jnp.float32)

    _, cache = model.prefill(params, batch, max_len=maxlen)
    dec = jax.jit(model.decode)
    for t in range(s1, s2):
        logits_d, cache = dec(params, cache, toks[:, t:t + 1])
    logits_ref, _ = model.prefill(params, dict(batch, tokens=toks),
                                  max_len=maxlen)
    a = np.asarray(logits_d, np.float32)
    r = np.asarray(logits_ref, np.float32)
    assert np.array_equal(np.argmax(a, -1), np.argmax(r, -1)), \
        f"{arch}: decode/prefill argmax disagree"
    tol = 5e-2 if cfg.moe is not None else 2e-2
    rel = np.abs(a - r).max() / max(np.abs(r).max(), 1e-6)
    assert rel < tol, f"{arch}: rel err {rel}"


@pytest.mark.parametrize("arch", arch_ids())
def test_full_config_exactness(arch):
    """The FULL configs carry the exact published dims (exercised via
    dry-run only; here we pin the numbers so edits can't drift)."""
    cfg = get_config(arch)
    expected = {
        "mixtral-8x22b": (56, 6144, 48, 8, 32768),
        "deepseek-moe-16b": (28, 2048, 16, 16, 102400),
        "xlstm-125m": (12, 768, 4, 4, 50304),
        "paligemma-3b": (18, 2048, 8, 1, 257216),
        "recurrentgemma-9b": (38, 4096, 16, 1, 256000),
        "minicpm3-4b": (62, 2560, 40, 40, 73448),
        "olmo-1b": (16, 2048, 16, 16, 50304),
        "gemma-2b": (18, 2048, 8, 1, 256000),
        "starcoder2-3b": (30, 3072, 24, 2, 49152),
        "whisper-small": (12, 768, 12, 12, 51865),
    }[arch]
    got = (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
           cfg.vocab_size)
    assert got == expected, f"{arch}: {got} != {expected}"


def test_moe_details_pinned():
    mix = get_config("mixtral-8x22b")
    assert (mix.moe.num_experts, mix.moe.top_k) == (8, 2)
    assert mix.attn_kind == "swa" and mix.window == 4096
    ds = get_config("deepseek-moe-16b")
    assert (ds.moe.num_experts, ds.moe.top_k, ds.moe.num_shared) == (64, 6, 2)
    assert ds.moe.d_ff_expert == 1408
    mc = get_config("minicpm3-4b")
    assert (mc.mla.q_lora_rank, mc.mla.kv_lora_rank) == (768, 256)
    rg = get_config("recurrentgemma-9b")
    assert rg.block_pattern == ("rglru", "rglru", "attn")
    assert rg.window == 2048
