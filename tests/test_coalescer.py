"""Async serving subsystem: coalesced dispatch == direct query_batch
(bitwise, cache on and off), dispatch triggers (fill / window / deadline /
drain), backpressure policies, drain-on-shutdown completeness, priority
lane ordering, and a multi-threaded zipf-client stress test.

Scheduling behavior is tested against a fake in-process service (no jax,
deterministic, fast); the bitwise contract and the stress test run against
the real `WMDService` engine. Timing-triggered assertions use windows that
are orders of magnitude apart (10 s vs tens of ms) so a slow shared CI box
cannot flip which trigger fires.
"""
import itertools
import threading
import time

import numpy as np
import pytest

from repro.serving import (CoalescerClosedError, QueryCoalescer,
                           QueueFullError, closed_loop, open_loop)

NEVER_MS = 10_000.0      # "window never fires" on any sane CI box


class FakeService:
    """query_batch stand-in: records every dispatched batch, optional
    per-dispatch delay, result row i = (i, sum(r_i)) so order is visible."""

    def __init__(self, delay_s: float = 0.0, hit_rate: float | None = None):
        self.calls: list[list[np.ndarray]] = []
        self.delay_s = delay_s
        self.last_batch_stats: dict = {}
        self._hit_rate = hit_rate

    def query_batch(self, rs):
        self.calls.append(list(rs))
        if self.delay_s:
            time.sleep(self.delay_s)
        if self._hit_rate is not None:
            self.last_batch_stats = {"hit_rate": self._hit_rate}
        return np.stack([np.array([i, float(r.sum())], np.float32)
                         for i, r in enumerate(rs)])


def _queries(n, start=0):
    return [np.full(4, float(start + i), np.float32) for i in range(n)]


# ---------------------------------------------------------------- triggers

def test_fill_trigger_cuts_full_pow2_bucket():
    svc = FakeService()
    with QueryCoalescer(svc, window_ms=NEVER_MS, max_batch=4) as co:
        futs = co.submit_many(_queries(4))
        for f in futs:
            f.result(timeout=30)
        st = co.stats()
    assert st.dispatch_fill == 1 and st.dispatches == 1
    assert st.batch_size_hist == {4: 1}
    assert len(svc.calls[0]) == 4


def test_window_trigger_flushes_partial_batch():
    svc = FakeService()
    with QueryCoalescer(svc, window_ms=40.0, max_batch=64) as co:
        t0 = time.monotonic()
        futs = co.submit_many(_queries(2))
        for f in futs:
            f.result(timeout=30)
        waited = time.monotonic() - t0
        st = co.stats()
    assert st.dispatch_window == 1 and st.dispatches == 1
    assert st.batch_size_hist == {2: 1}
    assert waited >= 0.040          # the window was honored, not skipped


def test_deadline_trigger_preempts_window():
    svc = FakeService()
    with QueryCoalescer(svc, window_ms=NEVER_MS, max_batch=64) as co:
        fut = co.submit(_queries(1)[0], deadline_ms=60.0)
        fut.result(timeout=30)
        st = co.stats()
    assert st.dispatch_deadline == 1 and st.dispatches == 1
    # fired well before the 10 s window (miss count is timing-sensitive on
    # a loaded box, so only the trigger itself is asserted)
    assert st.latency_ms_p50 < 1_000.0


def test_deadline_miss_is_served_and_counted():
    svc = FakeService(delay_s=0.05)   # solve alone blows a 1 ms deadline
    with QueryCoalescer(svc, window_ms=NEVER_MS, max_batch=64) as co:
        fut = co.submit(_queries(1)[0], deadline_ms=1.0)
        assert fut.result(timeout=30) is not None     # served, not dropped
        st = co.stats()
    assert st.deadline_misses == 1 and st.completed == 1


def test_max_batch_rounds_up_to_pow2():
    co = QueryCoalescer(FakeService(), max_batch=5)
    try:
        assert co.max_batch == 8
    finally:
        co.shutdown()


# ------------------------------------------------------------ backpressure

def test_backpressure_reject_raises_and_counts():
    svc = FakeService()
    co = QueryCoalescer(svc, window_ms=NEVER_MS, max_batch=64, max_queue=2,
                        backpressure="reject")
    try:
        f1, f2 = co.submit_many(_queries(2))
        with pytest.raises(QueueFullError):
            co.submit(_queries(1)[0])
        co.shutdown(drain=True)       # queued pair still gets served
        assert f1.result(timeout=30) is not None
        assert f2.result(timeout=30) is not None
        st = co.stats()
        assert st.rejected == 1 and st.completed == 2
        assert st.dispatch_drain >= 1
    finally:
        co.shutdown()


def test_backpressure_block_waits_for_space():
    svc = FakeService(delay_s=0.02)
    with QueryCoalescer(svc, window_ms=1.0, max_batch=2, max_queue=2,
                        backpressure="block") as co:
        futs = co.submit_many(_queries(8))    # > max_queue: submits block
        for f in futs:                        # until dispatches free space
            f.result(timeout=30)
        st = co.stats()
    assert st.completed == 8 and st.rejected == 0


def test_backpressure_block_timeout_gives_up():
    svc = FakeService()
    co = QueryCoalescer(svc, window_ms=NEVER_MS, max_batch=64, max_queue=1,
                        backpressure="block")
    try:
        co.submit(_queries(1)[0])
        with pytest.raises(QueueFullError):
            co.submit(_queries(1)[0], timeout=0.05)
        assert co.stats().rejected == 1
    finally:
        co.shutdown()


# --------------------------------------------------------------- lifecycle

def test_drain_on_shutdown_completes_everything():
    svc = FakeService()
    co = QueryCoalescer(svc, window_ms=NEVER_MS, max_batch=4)
    futs = co.submit_many(_queries(10))       # 10 queued: 4+4+2 drain pops
    co.shutdown(drain=True)
    assert all(f.done() and f.exception() is None for f in futs)
    st = co.stats()
    assert st.completed == 10 and st.queue_depth == 0
    # the fill trigger may race drain for full buckets; every dispatch is
    # one of the two and together they cover all 10 requests
    assert st.dispatch_fill + st.dispatch_drain == st.dispatches
    assert sum(q * c for q, c in st.batch_size_hist.items()) == 10


def test_shutdown_without_drain_fails_pending():
    svc = FakeService()
    co = QueryCoalescer(svc, window_ms=NEVER_MS, max_batch=64)
    futs = co.submit_many(_queries(3))
    co.shutdown(drain=False)
    for f in futs:
        with pytest.raises(CoalescerClosedError):
            f.result(timeout=30)
    with pytest.raises(CoalescerClosedError):
        co.submit(_queries(1)[0])


def test_dispatch_exception_fans_out_and_keeps_serving():
    class Exploding(FakeService):
        def query_batch(self, rs):
            if not self.calls:
                self.calls.append(list(rs))
                raise RuntimeError("boom")
            return super().query_batch(rs)

    svc = Exploding()
    with QueryCoalescer(svc, window_ms=5.0, max_batch=64) as co:
        bad = co.submit(_queries(1)[0])
        with pytest.raises(RuntimeError, match="boom"):
            bad.result(timeout=30)
        good = co.submit(_queries(1)[0])      # coalescer survived the error
        assert good.result(timeout=30) is not None
        st = co.stats()
    assert st.failed == 1 and st.completed == 1


def test_cancelled_future_discarded_dispatcher_survives():
    """A client cancelling a queued request must not kill the dispatcher:
    the request is dropped at batch formation, the rest of the bucket is
    served, and later submits still complete."""
    svc = FakeService()
    with QueryCoalescer(svc, window_ms=NEVER_MS, max_batch=4) as co:
        futs = co.submit_many(_queries(3))
        assert futs[1].cancel()              # still queued: cancel wins
        last = co.submit(np.full(4, 9.0, np.float32))   # fills the bucket
        rows = [futs[0].result(timeout=30), futs[2].result(timeout=30),
                last.result(timeout=30)]
        st = co.stats()
    assert st.cancelled == 1 and st.dispatch_fill == 1
    assert len(svc.calls[0]) == 3            # cancelled req never dispatched
    assert [float(r[1]) for r in rows] == [0.0, 8.0, 36.0]


def test_drain_flushes_without_waiting_out_window():
    """drain() must dispatch whatever is queued immediately (drain trigger),
    not sit out a long coalescing window."""
    svc = FakeService()
    with QueryCoalescer(svc, window_ms=NEVER_MS, max_batch=8) as co:
        fut = co.submit(_queries(1)[0])
        co.drain(timeout=30)         # NEVER_MS window: only drain can fire
        assert fut.done()
        st = co.stats()
        assert st.dispatch_drain == 1 and st.queue_depth == 0
        after = co.submit(_queries(1)[0])    # coalescer stays open
        co.drain(timeout=30)
        assert after.done()


def test_all_cancelled_batch_never_dispatches():
    """A cut whose every request was cancelled must not reach the engine,
    and shutdown-with-drain must still complete."""
    svc = FakeService()
    with QueryCoalescer(svc, window_ms=NEVER_MS, max_batch=8) as co:
        futs = co.submit_many(_queries(2))
        assert all(f.cancel() for f in futs)
    st = co.stats()
    assert st.cancelled == 2 and st.dispatches == 0 and svc.calls == []


def test_priority_lane_dispatched_first():
    svc = FakeService()
    with QueryCoalescer(svc, window_ms=NEVER_MS, max_batch=8) as co:
        fa = co.submit(np.full(4, 1.0, np.float32))
        fb = co.submit(np.full(4, 2.0, np.float32))
        fc = co.submit(np.full(4, 3.0, np.float32), priority=1)
        co.shutdown(drain=True)       # idempotent with the context exit
    batch = svc.calls[0]
    assert [float(r[0]) for r in batch] == [3.0, 1.0, 2.0]  # hi lane first
    # result rows follow batch position, so fc got row 0
    assert float(fc.result()[0]) == 0.0
    assert float(fa.result()[0]) == 1.0
    assert float(fb.result()[0]) == 2.0


def test_stats_hit_rate_passthrough_and_estimate():
    svc = FakeService(hit_rate=0.5)
    with QueryCoalescer(svc, window_ms=1.0, max_batch=4) as co:
        for f in co.submit_many(_queries(4)):
            f.result(timeout=30)
        st = co.stats()
    assert st.hit_rate == pytest.approx(0.5)
    assert st.service_estimate_ms > 0.0
    assert st.latency_ms_p50 > 0.0 and st.latency_ms_p99 >= st.latency_ms_p50


# ------------------------------------------------------- loadgen (clients)

def test_open_loop_poisson_submits_everything():
    svc = FakeService()
    with QueryCoalescer(svc, window_ms=2.0, max_batch=8) as co:
        res = open_loop(co.submit, iter(_queries(20)), rate_qps=2000.0,
                        seed=0, keep_results=True)
    assert res.submitted == 20 and res.completed == 20 and res.failed == 0
    assert res.throughput_qps > 0
    assert len(res.results) == 20
    assert res.latencies_ms.shape == (20,)


def test_closed_loop_accepts_synchronous_baseline():
    calls = []

    def sync_submit(r):
        calls.append(r)
        return np.array([len(calls)], np.float32)   # not a Future

    res = closed_loop(sync_submit, _queries(6), concurrency=2,
                      keep_results=True)
    assert res.completed == 6 and len(calls) == 6
    assert len(res.results) == 6


# ------------------------------------------- real engine: bitwise contract

@pytest.fixture(scope="module")
def wmd_services():
    """One tiny corpus, a cache-off and a cached WMDService."""
    from repro.configs.sinkhorn_wmd import WMDConfig
    from repro.data import make_corpus
    from repro.launch.mesh import make_mesh
    from repro.serving import WMDService

    cfg = WMDConfig(name="t-coalescer", vocab_size=192, embed_dim=16,
                    num_docs=32, nnz_max=32, v_r=8, lamb=1.0, max_iter=8)
    data = make_corpus(vocab_size=192, embed_dim=16, num_docs=32,
                       num_queries=1, query_words=6, mean_words=6.0, seed=0)
    mesh = make_mesh((1, 1), ("data", "model"))
    svc = WMDService(mesh=mesh, cfg=cfg, vecs=data.vecs, ell=data.ell)
    svc_cached = WMDService(mesh=mesh, cfg=cfg, vecs=data.vecs,
                            ell=data.ell, cache_capacity=48,
                            cache_rows_bucket=8)
    return svc, svc_cached


def _zipf_queries(n, seed):
    from repro.data import zipf_query_stream
    stream = zipf_query_stream(vocab_size=192, query_words=6, s=1.3,
                               seed=seed)
    return list(itertools.islice(stream, n))


def _replay_oracle(svc, co, qs, results):
    """Assert every coalesced row == direct query_batch of the logged batch
    composition, bitwise (the dispatcher-owns-the-device contract)."""
    log = list(co.batch_log)
    covered = set()
    for group in log:
        direct = svc.query_batch([qs[i] for i in group])
        for j, seq in enumerate(group):
            np.testing.assert_array_equal(
                results[seq], direct[j],
                err_msg=f"request {seq} in dispatch {group}")
            covered.add(seq)
    assert covered == set(range(len(qs)))
    return log


@pytest.mark.parametrize("cached", [False, True])
def test_coalesced_bitwise_equals_direct_query_batch(wmd_services, cached):
    """10 requests through max_batch=4 cross at least one bucket boundary;
    every dispatched group's rows must be bitwise identical to a direct
    query_batch of the same queries in the same order -- with the cache on,
    the replay runs at *different* residency, so this also exercises the
    kcache exactness contract end to end."""
    svc = wmd_services[1] if cached else wmd_services[0]
    qs = _zipf_queries(10, seed=3 + cached)
    with svc.async_service(window_ms=30.0, max_batch=4) as co:
        futs = co.submit_many(qs)
        results = [f.result(timeout=60) for f in futs]
    log = _replay_oracle(svc, co, qs, results)
    assert len(log) >= 3              # bucket boundary genuinely crossed


def test_multithreaded_zipf_stress_bitwise(wmd_services):
    """4 client threads x 8 seeded zipf queries against the cached service:
    all complete, nothing is lost or duplicated, and every dispatched batch
    replays bitwise against the direct engine."""
    svc = wmd_services[1]
    per_thread = 8
    threads_n = 4
    qs_by_thread = [_zipf_queries(per_thread, seed=100 + t)
                    for t in range(threads_n)]
    dispatched = []
    orig = svc.query_batch

    def recording(rs, **kw):
        out = orig(rs, **kw)
        dispatched.append(([np.array(r) for r in rs], np.array(out)))
        return out

    svc.query_batch = recording
    try:
        results = {}
        errs = []
        with svc.async_service(window_ms=3.0, max_batch=8,
                               max_queue=64) as co:
            def client(t):
                try:
                    for i, r in enumerate(qs_by_thread[t]):
                        results[(t, i)] = co.submit(r).result(timeout=120)
                except Exception as e:      # noqa: BLE001 -- surfaced below
                    errs.append(e)
            ts = [threading.Thread(target=client, args=(t,))
                  for t in range(threads_n)]
            for t in ts:
                t.start()
            for t in ts:
                t.join()
            st = co.stats()
    finally:
        svc.query_batch = orig
    assert not errs
    assert st.completed == threads_n * per_thread
    assert len(results) == threads_n * per_thread
    assert sum(q * c for q, c in st.batch_size_hist.items()) == st.completed
    # bitwise: each recorded dispatch replayed directly on the engine
    for rs, out in dispatched:
        np.testing.assert_array_equal(np.asarray(svc.query_batch(rs)), out)


def test_async_service_and_drain_hook(wmd_services):
    """WMDService.async_service wires a working coalescer; drain_async
    flushes it; a single coalesced request == direct query_batch of one."""
    svc = wmd_services[0]
    q = _zipf_queries(1, seed=9)[0]
    co = svc.async_service(window_ms=20.0, max_batch=4)
    try:
        fut = co.submit(q)
        svc.drain_async(timeout=60)
        assert fut.done()
        np.testing.assert_array_equal(fut.result(), svc.query_batch([q])[0])
    finally:
        co.shutdown()
