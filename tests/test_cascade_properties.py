"""Property suite of the three-tier retrieval cascade (core.cascade + the
M-row cache + the cascaded `WMDService.top_k_batch(prune=True)`).

The invariants, in decreasing order of load-bearing-ness:
  1. the bound chain -- tier-0 centroid <= LC-RWMD <= doc-side RWMD <=
     engine distance, for every impl and every iteration budget. Each
     link is what makes the tier in front of it safe to prune with; the
     LC link is *bitwise* (the same min over the same floats, hoisted
     out of the doc loop -- core.cascade docstring).
  2. tier-disable invariance -- switching any tier (or all of them) off
     changes which docs get solved, never a single result bit: bounds
     only reorder and skip, every solved doc's bits come from the same
     stripes programs.
  3. M-cache transparency -- cache on == cache off bitwise, through
     evictions, at the store level and through the full pruned service.
  4. tier-0 only bites on clustered geometry -- exactly 0 on isotropic
     random embeddings (documented, not a bug) and strictly positive
     when query and corpus words occupy different clusters.

Each invariant has a seeded always-on test and (where shapes vary) a
hypothesis generalization, executed seeded in CI via ``--hypothesis-seed=0``
-- see ci.yml's property step.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.sinkhorn_wmd import WMDConfig
from repro.core import (MCache, assemble_m_stripes, centroid_bound_batch,
                        doc_centroids, ell_from_dense, lc_rwmd_bound_batch,
                        min_cost_vectors, rwmd_bound_batch, select_query,
                        sinkhorn_wmd_sparse_batch)
from repro.core.distributed import pad_query_batch
from repro.data import make_corpus, zipf_query_stream
from repro.launch.mesh import make_mesh
from repro.serving import WMDService

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                     # container without the dev extra:
    given = None                        # seeded subset still runs


# fp slack for cross-tier comparisons that accumulate in different orders;
# the service's prune_margin (1e-3) dominates this by ~100x. The LC link
# itself is exact (assert_array_equal below).
RTOL, ATOL = 1e-5, 1e-6


# ---------------------------------------------------------------------------
# shared problem builders (mirrors tests/test_rwmd_properties.py)
# ---------------------------------------------------------------------------

def _problem(seed, *, v=96, w=8, n=20, vr_bucket=8, q=3):
    """Random batched WMD problem: (sel_b, r_b, mask_b, ell, vecs)."""
    rng = np.random.default_rng(seed)
    vecs = rng.normal(size=(v, w)).astype(np.float32)
    c = np.zeros((v, n), np.float32)
    for j in range(n):
        widx = rng.choice(v, rng.integers(2, 9), replace=False)
        c[widx, j] = rng.random(widx.size).astype(np.float32)
        c[:, j] /= c[:, j].sum()
    ell = ell_from_dense(c)
    rs = []
    for i in range(q):
        r = np.zeros(v, np.float32)
        idx = rng.choice(v, int(rng.integers(3, vr_bucket + 1)),
                         replace=False)
        r[idx] = rng.random(idx.size).astype(np.float32) + 0.1
        r /= r.sum()
        rs.append(r)
    sels, rsels = zip(*[select_query(r) for r in rs])
    sel_b, r_b, mask_b = pad_query_batch(sels, rsels, vr_bucket)
    return sel_b, r_b, mask_b, ell, vecs


def _tier_bounds(sel_b, r_b, mask_b, ell, vecs):
    """(tier0, lc, doc_side) bound matrices, all (Q, N) numpy."""
    cols, vals = jnp.asarray(ell.cols), jnp.asarray(ell.vals)
    vecs_d = jnp.asarray(vecs)
    g, m = doc_centroids(cols, vals, vecs_d)
    lb0 = np.asarray(centroid_bound_batch(
        jnp.asarray(sel_b), jnp.asarray(r_b), jnp.asarray(mask_b),
        vecs_d, g, m))
    m_pad = assemble_m_stripes(sel_b, mask_b, vecs_d, rows_bucket=8)
    lb_lc = np.asarray(lc_rwmd_bound_batch(min_cost_vectors(m_pad),
                                           cols, vals))
    lb_doc = np.asarray(rwmd_bound_batch(m_pad, cols, vals))
    return lb0, lb_lc, lb_doc


def _service(seed, *, docs, vocab=512, capacity=0, mcache=0, prune_chunk=16,
             **kw):
    data = make_corpus(vocab_size=vocab, embed_dim=32, num_docs=docs,
                       num_queries=1, query_words=11, mean_words=12.0,
                       seed=seed)
    cfg = WMDConfig(name="cascade-prop", vocab_size=vocab, embed_dim=32,
                    num_docs=docs, nnz_max=64, v_r=16, lamb=1.0,
                    max_iter=8)
    mesh = make_mesh((1, 1), ("data", "model"))
    return WMDService(mesh=mesh, cfg=cfg, vecs=data.vecs, ell=data.ell,
                      cache_capacity=capacity, mcache_capacity=mcache,
                      prune_chunk=prune_chunk, bound_docs_chunk=None, **kw)


def _queries(vocab, q, seed):
    stream = zipf_query_stream(vocab_size=vocab, query_words=11, s=1.2,
                               seed=seed)
    return [next(stream) for _ in range(q)]


# ---------------------------------------------------------------------------
# 1. the bound chain: tier0 <= LC <= doc-side <= engine, every budget
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("impl", ["fused", "unfused", "kernel"])
@pytest.mark.parametrize("max_iter", [1, 3, 15])
def test_bound_chain_all_impls_all_budgets(impl, max_iter):
    """tier0(q,d) <= lc(q,d) <= rwmd(q,d) <= sinkhorn(q,d) at ANY fixed
    iteration budget -- the fact each cascade tier's pruning rests on.
    The LC <= doc-side link is equality down to the bit (hoisted min)."""
    sel_b, r_b, mask_b, ell, vecs = _problem(seed=max_iter * 13 + 5)
    lb0, lb_lc, lb_doc = _tier_bounds(sel_b, r_b, mask_b, ell, vecs)
    np.testing.assert_array_equal(lb_lc, lb_doc)
    assert np.all(lb0 <= lb_lc * (1 + RTOL) + ATOL), \
        f"tier0 exceeds LC by {np.max(lb0 - lb_lc)}"
    d = np.asarray(sinkhorn_wmd_sparse_batch(
        jnp.asarray(sel_b), jnp.asarray(r_b), jnp.asarray(ell.cols),
        jnp.asarray(ell.vals), jnp.asarray(vecs), 1.0, max_iter,
        row_mask=jnp.asarray(mask_b), impl=impl))
    assert np.all(lb_doc <= d * (1 + RTOL) + ATOL), \
        f"doc-side bound exceeds engine output by {np.max(lb_doc - d)}"


def test_lc_impls_agree():
    """LC fused == kernel == chunked == the dense ref oracle."""
    from repro.kernels import ops, ref
    sel_b, _, mask_b, ell, vecs = _problem(seed=17)
    cols, vals = jnp.asarray(ell.cols), jnp.asarray(ell.vals)
    m_pad = assemble_m_stripes(sel_b, mask_b, jnp.asarray(vecs),
                               rows_bucket=8)
    minm = min_cost_vectors(m_pad)
    lb = np.asarray(lc_rwmd_bound_batch(minm, cols, vals))
    lb_c = np.asarray(lc_rwmd_bound_batch(minm, cols, vals, docs_chunk=7))
    lb_k = np.asarray(ops.lc_rwmd_bound_batch(minm, cols, vals))
    lb_r = np.asarray(ref.lc_rwmd_bound_batch(minm, cols, vals))
    np.testing.assert_array_equal(lb, lb_c)
    np.testing.assert_allclose(lb_k, lb_r, rtol=1e-6, atol=1e-7)
    np.testing.assert_allclose(lb, lb_r, rtol=1e-6, atol=1e-7)


def test_cascade_pads_and_empties_inert():
    """Filler queries and empty docs score exactly 0 in EVERY tier -- the
    engine's distance for both, so a 0 bound can never prune them."""
    sel_b, r_b, mask_b, ell, vecs = _problem(seed=23, n=12)
    sel_f = np.concatenate([sel_b, np.zeros((1,) + sel_b.shape[1:],
                                            sel_b.dtype)])
    r_f = np.concatenate([r_b, np.zeros((1,) + r_b.shape[1:], r_b.dtype)])
    mask_f = np.concatenate([mask_b, np.zeros((1,) + mask_b.shape[1:],
                                              mask_b.dtype)])
    n, nnz = ell.cols.shape
    cols_e = np.concatenate(
        [ell.cols, np.full((1, nnz), ell.num_vocab, ell.cols.dtype)])
    vals_e = np.concatenate([ell.vals, np.zeros((1, nnz), ell.vals.dtype)])
    ell_e = type(ell)(cols=cols_e, vals=vals_e, num_vocab=ell.num_vocab)
    lb0, lb_lc, lb_doc = _tier_bounds(sel_f, r_f, mask_f, ell_e, vecs)
    for lb in (lb0, lb_lc, lb_doc):
        assert np.all(lb[-1] == 0.0)        # filler query row
        assert np.all(lb[:, -1] == 0.0)     # empty doc column


def test_tier0_zero_on_isotropic_positive_on_clustered():
    """Tier-0 is geometry: on isotropic random embeddings the centroid
    bound collapses to ~0 (m*R swamps ||g - m z||; why the random-corpus
    benches report centroid=0.00), while separated query/corpus clusters
    make it strictly positive on every real (query, doc) pair."""
    # clustered: query words hug the origin, doc words sit 10 sigma away
    rng = np.random.default_rng(29)
    v, w, nq = 64, 8, 12
    vecs = np.empty((v, w), np.float32)
    vecs[:nq] = 0.05 * rng.normal(size=(nq, w))
    far = rng.normal(size=(v - nq, w))
    far /= np.linalg.norm(far, axis=1, keepdims=True)
    vecs[nq:] = 10.0 * far + 0.05 * rng.normal(size=(v - nq, w))
    c = np.zeros((v, 6), np.float32)
    for j in range(6):
        widx = nq + rng.choice(v - nq, 5, replace=False)
        c[widx, j] = rng.random(5).astype(np.float32)
        c[:, j] /= c[:, j].sum()
    ell = ell_from_dense(c)
    rs = []
    for i in range(2):
        r = np.zeros(v, np.float32)
        idx = rng.choice(nq, 4, replace=False)
        r[idx] = rng.random(4).astype(np.float32) + 0.1
        r /= r.sum()
        rs.append(r)
    sels, rsels = zip(*[select_query(r) for r in rs])
    sel_b, r_b, mask_b = pad_query_batch(sels, rsels, 8)
    lb0, lb_lc, _ = _tier_bounds(sel_b, r_b, mask_b, ell, vecs)
    assert np.all(lb0[:2] > 1.0)                   # bites hard
    assert np.all(lb0 <= lb_lc * (1 + RTOL) + ATOL)  # still sound
    # isotropic, bench-like corpus (many words per doc): the query radius
    # R swamps the centroid gap and the relu clamps the whole screen to 0
    data = make_corpus(vocab_size=256, embed_dim=32, num_docs=16,
                       num_queries=0, query_words=11, mean_words=30.0,
                       seed=31)
    qs = _queries(256, 2, seed=31)
    sels, rsels = zip(*[select_query(r) for r in qs])
    sel_i, r_i, mask_i = pad_query_batch(sels, rsels, 16)
    lb0_iso, _, _ = _tier_bounds(sel_i, r_i, mask_i, data.ell, data.vecs)
    assert float(lb0_iso.max()) == 0.0


# ---------------------------------------------------------------------------
# 2. tier-disable invariance: any tier subset off, identical result bits
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("cfg_kw", [
    {"tier0": False},
    {"lc_impl": None},
    {"tier2_cap": 0},
    {"tier0": False, "lc_impl": None, "tier2_cap": 0},   # no pruning at all
    {"lc_impl": "kernel"},
    {"tier2_cap": 8},
])
def test_tier_toggle_bitwise_invariant(cfg_kw):
    """Disabling or swapping tiers changes how much is pruned, never the
    returned bits: every config equals the default cascade AND the
    exhaustive scan."""
    base = _service(seed=37, docs=64, prune_chunk=16)
    qs = _queries(512, 3, seed=37)
    idx_b, d_b = base.top_k_batch(qs, 5, prune=True)
    idx_s, d_s = base.top_k_scan_batch(qs, 5)
    np.testing.assert_array_equal(idx_b, idx_s)
    np.testing.assert_array_equal(d_b, d_s)
    svc = _service(seed=37, docs=64, prune_chunk=16, **cfg_kw)
    idx_t, d_t = svc.top_k_batch(qs, 5, prune=True)
    np.testing.assert_array_equal(idx_t, idx_b)
    np.testing.assert_array_equal(d_t, d_b)
    if cfg_kw.get("tier0") is False and cfg_kw.get("lc_impl", "x") is None \
            and cfg_kw.get("tier2_cap") == 0:
        # all tiers off: zero bounds prune nothing, the scan in disguise
        assert svc.last_prune_stats["solves_avoided"] == 0.0


def test_tier_funnel_stats_shape():
    """last_prune_stats["tiers"] reports the per-tier funnel: one entry per
    enabled tier, cumulative avoidance monotone, final cumulative equal to
    the headline solves_avoided."""
    svc = _service(seed=41, docs=64, prune_chunk=16)
    qs = _queries(512, 3, seed=41)
    svc.top_k_batch(qs, 5, prune=True)
    ps = svc.last_prune_stats
    tiers = ps["tiers"]
    assert [t["tier"] for t in tiers] == ["centroid", "lc_rwmd", "rwmd"]
    cum = [t["cascade_solves_avoided"] for t in tiers]
    assert all(b >= a for a, b in zip(cum, cum[1:]))    # monotone funnel
    assert all(t["seconds"] >= 0.0 for t in tiers)
    svc2 = _service(seed=41, docs=64, prune_chunk=16, lc_impl=None,
                    tier2_cap=0)
    svc2.top_k_batch(qs, 5, prune=True)
    assert [t["tier"] for t in svc2.last_prune_stats["tiers"]] \
        == ["centroid"]


# ---------------------------------------------------------------------------
# 3. M-cache transparency: on == off bitwise, through evictions
# ---------------------------------------------------------------------------

def _batch(rng, q, v_r, vocab):
    sel = np.zeros((q, v_r), np.int32)
    mask = np.zeros((q, v_r), np.float32)
    for i in range(q):
        n = int(rng.integers(1, v_r + 1))
        sel[i, :n] = rng.choice(vocab, n, replace=False)
        mask[i, :n] = 1.0
    return sel, mask


def test_mcache_stripes_bitwise_equal_recompute_oracle():
    """Random stream with evictions: every M-stripe assembly from the store
    is bitwise equal to the transient recompute (capacity-0) oracle."""
    rng = np.random.default_rng(43)
    vecs = jnp.asarray(rng.normal(size=(96, 8)).astype(np.float32))
    mc = MCache(12, vecs, rows_bucket=4)        # small: forces evictions
    oracle = MCache(0, vecs, rows_bucket=4)
    seen = set()
    for step in range(15):
        sel, mask = _batch(rng, q=int(rng.integers(1, 4)), v_r=5, vocab=96)
        seen.update(np.unique(sel).tolist())
        got, _ = mc.m_stripes_for_batch(sel, mask)
        want, _ = oracle.m_stripes_for_batch(sel, mask)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want),
                                      err_msg=f"step {step}")
    assert len(seen) > mc.capacity              # pressure engaged
    assert mc.stats.evictions > 0
    assert mc.stats.hit_rows > 0
    assert mc.resident <= mc.capacity


def test_service_mcache_on_off_bitwise_with_evictions():
    """Pruned top-k with a tiny M cache (evicting constantly) is bitwise
    identical to use_cache=False and to an mcache-free service, across
    repeat batches (hits) and fresh batches (misses)."""
    svc = _service(seed=47, docs=64, mcache=24, prune_chunk=16)
    svc_off = _service(seed=47, docs=64, mcache=0, prune_chunk=16)
    for s in (47, 48, 47):
        qs = _queries(512, 3, seed=s)
        idx_on, d_on = svc.top_k_batch(qs, 5, prune=True)
        idx_nc, d_nc = svc.top_k_batch(qs, 5, prune=True, use_cache=False)
        idx_off, d_off = svc_off.top_k_batch(qs, 5, prune=True)
        np.testing.assert_array_equal(idx_on, idx_nc)
        np.testing.assert_array_equal(d_on, d_nc)
        np.testing.assert_array_equal(idx_on, idx_off)
        np.testing.assert_array_equal(d_on, d_off)
    assert svc.mcache_stats.hit_rows > 0
    assert svc.mcache_resident <= 24


# ---------------------------------------------------------------------------
# hypothesis generalizations (skipped without the dev extra; CI runs them
# seeded via --hypothesis-seed=0)
# ---------------------------------------------------------------------------

if given is not None:
    _settings = settings(max_examples=15, deadline=None)

    @_settings
    @given(st.integers(0, 10_000), st.integers(1, 12))
    def test_hyp_bound_chain(seed, max_iter):
        sel_b, r_b, mask_b, ell, vecs = _problem(seed=seed)
        lb0, lb_lc, lb_doc = _tier_bounds(sel_b, r_b, mask_b, ell, vecs)
        np.testing.assert_array_equal(lb_lc, lb_doc)
        assert np.all(lb0 <= lb_lc * (1 + RTOL) + ATOL)
        d = np.asarray(sinkhorn_wmd_sparse_batch(
            jnp.asarray(sel_b), jnp.asarray(r_b), jnp.asarray(ell.cols),
            jnp.asarray(ell.vals), jnp.asarray(vecs), 1.0, max_iter,
            row_mask=jnp.asarray(mask_b)))
        assert np.all(lb_doc <= d * (1 + RTOL) + ATOL)

    @_settings
    @given(st.integers(0, 10_000), st.integers(1, 12),
           st.sampled_from([{"tier0": False}, {"lc_impl": None},
                            {"tier2_cap": 0}, {"tier2_cap": 4},
                            {"tier0": False, "lc_impl": None,
                             "tier2_cap": 0}]),
           st.sampled_from([0, 16, 512]))
    def test_hyp_tier_toggle_and_mcache_invariant(seed, k, cfg_kw, mcap):
        svc = _service(seed=seed % 97, docs=48, mcache=mcap,
                       prune_chunk=16, **cfg_kw)
        qs = _queries(512, 2, seed=seed)
        idx_p, d_p = svc.top_k_batch(qs, k, prune=True)
        idx_s, d_s = svc.top_k_scan_batch(qs, k)
        np.testing.assert_array_equal(idx_p, idx_s)
        np.testing.assert_array_equal(d_p, d_s)
