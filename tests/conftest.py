"""Shared fixtures. NOTE: no XLA_FLAGS here by design -- smoke tests and
benches must see 1 device; multi-device tests spawn subprocesses."""
import numpy as np
import pytest


@pytest.fixture(scope="session")
def wmd_problem():
    """Small synthetic WMD problem shared across core tests."""
    rng = np.random.default_rng(0)
    v, w, n, vr = 320, 24, 48, 11
    vecs = rng.normal(size=(v, w)).astype(np.float32)
    r = np.zeros(v, np.float32)
    idx = rng.choice(v, vr, replace=False)
    r[idx] = rng.random(vr).astype(np.float32)
    r /= r.sum()
    c = np.zeros((v, n), np.float32)
    for j in range(n):
        widx = rng.choice(v, rng.integers(4, 20), replace=False)
        c[widx, j] = rng.random(widx.size).astype(np.float32)
        c[:, j] /= c[:, j].sum()
    return {"vecs": vecs, "r": r, "c": c, "lamb": 1.0, "iters": 12}
