"""The CI perf-regression gate (benchmarks/compare_bench.py): a synthetic
slowdown past the threshold must FAIL, identity and missing baselines must
PASS, and the trajectory record / delta table must say which is which.

The gate guards the nightly bench headlines, so its failure semantics are
themselves pinned here -- a gate that can't fail (or fails on a missing
first-run baseline) is worse than no gate.
"""
import importlib.util
import json
import os
import subprocess
import sys

import pytest

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
_SCRIPT = os.path.join(_ROOT, "benchmarks", "compare_bench.py")


@pytest.fixture(scope="module")
def gate():
    spec = importlib.util.spec_from_file_location("compare_bench", _SCRIPT)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def _baseline(gate, value=2.0):
    """Artifacts holding ``value`` at every declared field path."""
    arts: dict = {}
    for fname, path, _ in gate.FIELDS:
        obj = arts.setdefault(fname, {})
        segs = path.split(".")
        for i, seg in enumerate(segs[:-1]):
            if segs[i + 1].lstrip("-").isdigit():
                obj = obj.setdefault(seg, [{}])
            elif seg.lstrip("-").isdigit():
                obj = obj[int(seg)]
            else:
                obj = obj.setdefault(seg, {})
        obj[segs[-1]] = value
    return arts


def test_get_path_dotted_and_list_indexing(gate):
    obj = {"a": {"b": [{"c": 1.5}, {"c": 2.5}]}}
    assert gate.get_path(obj, "a.b.0.c") == 1.5
    assert gate.get_path(obj, "a.b.-1.c") == 2.5
    assert gate.get_path(obj, "a.missing.c") is None
    assert gate.get_path(obj, "a.b.9.c") is None
    assert gate.get_path({"s": "text"}, "s") is None   # non-numeric leaf
    assert gate.get_path(None, "a") is None


def test_identity_run_passes(gate):
    base = _baseline(gate)
    rec = gate.compare(base, base, 0.25)
    assert rec["pass"] and rec["regressions"] == 0
    assert all(r["status"] == "ok" for r in rec["fields"])
    assert all(r["delta_frac"] == 0.0 for r in rec["fields"])


def test_synthetic_30pct_slowdown_fails(gate):
    base = _baseline(gate)
    slow = json.loads(json.dumps(base))
    for fname, path, direction in gate.FIELDS:
        segs = path.split(".")
        obj = slow[fname]
        for seg in segs[:-1]:
            obj = obj[int(seg)] if isinstance(obj, list) else obj[seg]
        obj[segs[-1]] *= 0.7 if direction == "higher" else 1.3
    rec = gate.compare(base, slow, 0.25)
    assert not rec["pass"]
    assert rec["regressions"] == len(gate.FIELDS)
    # ... and a 20% dip stays inside the 25% envelope
    mild = json.loads(json.dumps(base))
    for fname, path, direction in gate.FIELDS:
        segs = path.split(".")
        obj = mild[fname]
        for seg in segs[:-1]:
            obj = obj[int(seg)] if isinstance(obj, list) else obj[seg]
        obj[segs[-1]] *= 0.8 if direction == "higher" else 1.2
    assert gate.compare(base, mild, 0.25)["pass"]


def test_missing_baseline_seeds_not_blanks(gate):
    base = _baseline(gate)
    rec = gate.compare({}, base, 0.25)      # no previous artifacts at all
    assert rec["pass"]
    # measured-now fields seed the trajectory -- current values recorded,
    # never an all-n/a (empty) first record
    assert all(r["status"] == "seeded" and r["cur"] is not None
               for r in rec["fields"])
    assert rec["seeded"] == len(gate.FIELDS)
    # a file missing from the PREVIOUS side seeds just that file's fields;
    # a field missing from the CURRENT side is the true n/a
    partial = json.loads(json.dumps(base))
    first = gate.FIELDS[0][0]
    del partial[first]
    rec = gate.compare(partial, base, 0.25)
    assert rec["pass"]
    statuses = {r["file"]: r["status"] for r in rec["fields"]}
    assert statuses[first] == "seeded"
    cur_partial = json.loads(json.dumps(base))
    del cur_partial[first]
    rec = gate.compare(base, cur_partial, 0.25)
    assert rec["pass"]
    statuses = {r["file"]: r["status"] for r in rec["fields"]}
    assert statuses[first] == "n/a"


def test_baseline_status_classification(gate, tmp_path):
    """"no baseline was downloaded" vs "a download landed empty" are
    different failure modes; the record must say which happened."""
    assert gate.baseline_status(None) == "missing-dir"
    assert gate.baseline_status(str(tmp_path / "nope")) == "missing-dir"
    empty = tmp_path / "empty"
    empty.mkdir()
    assert gate.baseline_status(str(empty)) == "no-artifacts"
    fname = gate.FIELDS[0][0]
    (empty / fname).write_text(json.dumps(_baseline(gate)[fname]))
    assert gate.baseline_status(str(empty)) == "present"


def test_improvement_never_gates(gate):
    base = _baseline(gate)
    fast = json.loads(json.dumps(base))
    for fname, path, direction in gate.FIELDS:
        segs = path.split(".")
        obj = fast[fname]
        for seg in segs[:-1]:
            obj = obj[int(seg)] if isinstance(obj, list) else obj[seg]
        obj[segs[-1]] *= 3.0 if direction == "higher" else 0.3
    assert gate.compare(base, fast, 0.25)["pass"]


def test_markdown_table_marks_regressions(gate):
    base = _baseline(gate)
    slow = json.loads(json.dumps(base))
    fname0, path0, _ = gate.FIELDS[0]
    segs = path0.split(".")
    obj = slow[fname0]
    for seg in segs[:-1]:
        obj = obj[int(seg)] if isinstance(obj, list) else obj[seg]
    obj[segs[-1]] *= 0.5
    table = gate.markdown_table(gate.compare(base, slow, 0.25))
    assert "FAIL" in table and "**REGRESSION**" in table
    assert f"{fname0}:{path0}" in table
    ok_table = gate.markdown_table(gate.compare(base, base, 0.25))
    assert "PASS" in ok_table and "REGRESSION" not in ok_table


def test_self_test_passes(gate):
    assert gate.self_test(0.25) == 0


def test_declared_fields_are_ratios_not_latencies(gate):
    """The gate's own noise policy: only ratio/rate headlines, never raw
    latency percentiles or wall times (too noisy on shared runners)."""
    for _, path, direction in gate.FIELDS:
        leaf = path.rsplit(".", 1)[-1]
        assert "latency" not in leaf and "p99" not in leaf \
            and "p50" not in leaf and not leaf.endswith("_s"), path
        assert direction in ("higher", "lower")


def test_cli_end_to_end(gate, tmp_path):
    """The exact invocation bench.yml makes: dirs in, exit code + summary
    + BENCH_trajectory.json out. Regression -> exit 1; first run -> 0."""
    prev_d, cur_d = tmp_path / "prev", tmp_path / "cur"
    prev_d.mkdir(), cur_d.mkdir()
    base = _baseline(gate, 2.0)
    slow = _baseline(gate, 1.0)              # -50% on everything
    for name, obj in base.items():
        (prev_d / name).write_text(json.dumps(obj))
    for name, obj in slow.items():
        (cur_d / name).write_text(json.dumps(obj))
    traj = tmp_path / "BENCH_trajectory.json"
    summary = tmp_path / "summary.md"
    p = subprocess.run(
        [sys.executable, _SCRIPT, "--prev", str(prev_d), "--cur",
         str(cur_d), "--threshold", "0.25", "--out", str(traj),
         "--summary", str(summary)],
        capture_output=True, text=True, timeout=60)
    assert p.returncode == 1, p.stdout + p.stderr
    rec = json.loads(traj.read_text())
    assert not rec["pass"] and rec["regressions"] == len(gate.FIELDS)
    assert "**REGRESSION**" in summary.read_text()
    # first run: no --prev contents at all -> passes AND seeds
    traj2 = tmp_path / "BENCH_trajectory_first.json"
    p = subprocess.run(
        [sys.executable, _SCRIPT, "--prev", str(tmp_path / "nope"),
         "--cur", str(cur_d), "--out", str(traj2)],
        capture_output=True, text=True, timeout=60)
    assert p.returncode == 0, p.stdout + p.stderr
    rec = json.loads(traj2.read_text())
    assert rec["baseline_status"] == "missing-dir"
    assert rec["seeded"] == len(gate.FIELDS)
    assert all(r["cur"] is not None for r in rec["fields"])
    # and the self-test flag itself
    p = subprocess.run([sys.executable, _SCRIPT, "--self-test"],
                       capture_output=True, text=True, timeout=60)
    assert p.returncode == 0, p.stdout + p.stderr


def test_artifact_loader_finds_nested_dirs(gate, tmp_path):
    """dawidd6 downloads may unpack into a subdirectory per artifact
    name; the loader must find BENCH_*.json one level down."""
    nested = tmp_path / "bench-json"
    nested.mkdir()
    fname = gate.FIELDS[0][0]
    (nested / fname).write_text(json.dumps(_baseline(gate)[fname]))
    arts = gate.load_artifacts(str(tmp_path))
    assert fname in arts
    assert gate.load_artifacts(str(tmp_path / "missing")) == {}