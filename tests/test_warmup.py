"""AOT warmup registry + offline bulk mode: the registry must enumerate
exactly the shapes the coalescer can dispatch (cross-checked against a
randomized session's shape_log), a warmed service must serve every shape
with ZERO first-hit compiles (asserted via the jax compile-event counter,
not timing), and the offline driver must reproduce the online path's bits
on a golden query file -- top-k regardless of batch composition (union ==
per_query == scan), plain rows for the same bucket compositions.

Everything runs on one tiny corpus; compile counting uses jax's monitoring
events, so the zero-compile assertions are exact, not statistical.
"""
import os
import random

import numpy as np
import pytest

from repro.serving import (ProgramShape, QueryCoalescer, ShapeRegistry,
                           WMDService, load_query_file, measure_compiles,
                           run_offline, save_query_file, warm)
from repro.serving.warmup import synth_queries

NEVER_MS = 10_000.0


@pytest.fixture(scope="module")
def stack():
    """Tiny corpus + a cached, prunable service (top-k capable)."""
    from repro.configs.sinkhorn_wmd import WMDConfig
    from repro.data import make_corpus
    from repro.launch.mesh import make_mesh

    cfg = WMDConfig(name="t-warmup", vocab_size=192, embed_dim=16,
                    num_docs=32, nnz_max=32, v_r=8, lamb=1.0, max_iter=8)
    data = make_corpus(vocab_size=192, embed_dim=16, num_docs=32,
                       num_queries=12, query_words=6, mean_words=6.0,
                       seed=0)
    mesh = make_mesh((1, 1), ("data", "model"))
    svc = WMDService(mesh=mesh, cfg=cfg, vecs=data.vecs, ell=data.ell,
                     cache_capacity=48, cache_rows_bucket=8,
                     prune_chunk=8)
    return cfg, data, mesh, svc


def _fresh_service(stack):
    """A new service over the same corpus: fresh jit objects, cold caches."""
    cfg, data, mesh, _ = stack
    return WMDService(mesh=mesh, cfg=cfg, vecs=data.vecs, ell=data.ell,
                      cache_capacity=48, cache_rows_bucket=8,
                      prune_chunk=8)


# ------------------------------------------------------------ the registry

def test_program_shape_validation_and_labels():
    assert ProgramShape("plain", 4).label == "plain/q4"
    assert ProgramShape("top_k", 8, k=5).label == "top_k/q8/k5"
    assert ProgramShape("top_k_union", 2, k=3).label == "top_k_union/q2/k3"
    with pytest.raises(ValueError):
        ProgramShape("weird", 4)
    with pytest.raises(ValueError):
        ProgramShape("plain", 3)            # not a pow2 bucket
    with pytest.raises(ValueError):
        ProgramShape("plain", 4, k=5)       # k on a plain shape
    with pytest.raises(ValueError):
        ProgramShape("top_k", 4)            # top_k without k


def test_registry_enumerates_envelope_from_config(stack):
    _, _, _, svc = stack
    reg = ShapeRegistry.from_service(svc, max_batch=8)
    assert reg.labels == ["plain/q1", "plain/q2", "plain/q4", "plain/q8"]

    reg = ShapeRegistry.from_service(svc, max_batch=4, ks=(3, 5))
    # plain buckets first, then every (bucket, k) of the top_k kind
    assert set(reg.labels) == {
        "plain/q1", "plain/q2", "plain/q4",
        "top_k/q1/k3", "top_k/q1/k5", "top_k/q2/k3", "top_k/q2/k5",
        "top_k/q4/k3", "top_k/q4/k5"}
    # union rerank shapes only appear when requested explicitly
    assert not any(s.kind == "top_k_union" for s in reg)
    reg_u = ShapeRegistry.from_service(
        svc, max_batch=2, ks=(3,), kinds=("top_k_union",))
    assert reg_u.labels == ["top_k_union/q1/k3", "top_k_union/q2/k3"]

    # max_batch rounds up to its pow2 bucket, like the coalescer's
    assert ShapeRegistry.from_service(svc, max_batch=5).labels[-1] \
        == "plain/q8"
    with pytest.raises(ValueError):
        ShapeRegistry.from_service(svc, kinds=("top_k",))   # needs ks
    with pytest.raises(ValueError):
        ShapeRegistry.from_service(svc, kinds=("bogus",))


def test_registry_covers_is_bucket_rounded(stack):
    _, _, _, svc = stack
    reg = ShapeRegistry.from_service(svc, max_batch=4, ks=(3,))
    for q in (1, 2, 3, 4):                  # 3 pads into the q4 bucket
        assert reg.covers("plain", q)
        assert reg.covers("top_k", q, k=3)
    assert not reg.covers("plain", 5)       # beyond the envelope
    assert not reg.covers("top_k", 2, k=9)  # k never enumerated
    assert not reg.covers("top_k_union", 2, k=3)


def test_registry_covers_randomized_session_shape_log(stack):
    """THE envelope contract, both halves: over a randomized serving
    session (any arrival pattern, any mix of plain and top-k), every
    batch the coalescer dispatches lands on a shape the registry
    enumerates -- AND, because the registry was warmed first, the whole
    session fires zero compile-or-retrieve events (no request ever pays
    a first-hit compile)."""
    _, data, _, _ = stack
    svc = _fresh_service(stack)
    rng = random.Random(7)
    with QueryCoalescer(svc, window_ms=5.0, max_batch=4) as co:
        reg = ShapeRegistry.from_service(co.svc, max_batch=co.max_batch,
                                         ks=(3,))
        co.warm_registry(ks=(3,))
        with measure_compiles() as cc:
            futs = []
            for _ in range(40):
                q = data.queries[rng.randrange(len(data.queries))]
                if rng.random() < 0.5:
                    futs.append(co.submit(q))
                else:
                    futs.append(co.submit_top_k(q, k=3))
            for f in futs:
                f.result(timeout=60)
        log = list(co.shape_log)
    assert log, "session dispatched nothing"
    sizes = {q for _, q, _ in log}
    assert len(sizes) > 1, "session never varied batch size"
    for kind, q, k in log:
        assert reg.covers(kind, q, k), \
            f"dispatched shape ({kind}, q={q}, k={k}) outside the registry"
    assert cc.events == 0, \
        f"{cc.events} first-hit compiles during a warmed session (want 0)"


# ------------------------------------------------- warmup: zero first-hits

def test_warm_then_zero_compiles_on_every_shape(stack):
    """After one registry pass, re-dispatching EVERY enumerated shape must
    fire zero compile-or-retrieve events -- the programs are live in the
    jit caches, so steady state never meets a cold (or even persisted)
    program. This is the ISSUE's zero-first-hit acceptance gate."""
    cfg, data, _, _ = stack
    svc = _fresh_service(stack)
    reg = ShapeRegistry.from_service(svc, max_batch=4, ks=(3,),
                                     kinds=("plain", "top_k",
                                            "top_k_union"))
    report = warm(svc, reg)
    assert set(report.shapes) == set(reg.labels)
    # a fresh service's programs are cold IN-PROCESS either way: backend
    # compiles, or persisted-cache retrievals when CI restored a cache dir
    assert report.compiles + report.persistent_hits > 0

    qs = synth_queries(cfg, 4, seed=123)    # different payloads, same shapes
    with measure_compiles() as cc:
        for shape in reg:
            batch = qs[:shape.q_bucket]
            if shape.kind == "plain":
                svc.query_batch(batch)
            elif shape.kind == "top_k":
                svc.top_k_batch(batch, shape.k, prune=True)
            else:
                svc.top_k_batch(batch, shape.k, prune=True, rerank="union")
    assert cc.events == 0, \
        f"{cc.events} compile-or-retrieve events after warmup (want 0)"
    assert cc.compiles == 0


def test_warmup_report_accounting(stack):
    svc = _fresh_service(stack)
    reg = ShapeRegistry.from_service(svc, max_batch=2, ks=(3,))
    report = warm(svc, reg)
    assert report.wall_s > 0
    assert report.compiles == sum(s.compiles for s in
                                  report.shapes.values())
    assert set(report.compile_s_by_label()) == set(reg.labels)
    s = report.summary()
    assert s["shapes"] == reg.labels
    assert set(s["per_shape"]) == set(reg.labels)
    # every program was either backend-compiled or cache-retrieved --
    # a fresh service meets each shape cold in-process (CI may restore a
    # persisted cache dir, which flips compiles into retrievals)
    assert report.compiles + report.persistent_hits > 0
    assert report.retrieval_s >= 0


def test_synth_queries_are_admissible_histograms(stack):
    cfg, _, _, _ = stack
    qs = synth_queries(cfg, 5, seed=3)
    assert len(qs) == 5
    for q in qs:
        assert q.shape == (cfg.vocab_size,) and q.dtype == np.float32
        np.testing.assert_allclose(q.sum(), 1.0, rtol=1e-5)
        assert (q > 0).sum() <= cfg.v_r - 1     # fits the v_r bucket
    np.testing.assert_array_equal(qs[0], synth_queries(cfg, 1, seed=3)[0])


# ------------------------------------------- coalescer wiring + shims

def test_coalescer_warm_registry_populates_stats(stack):
    svc = _fresh_service(stack)
    with QueryCoalescer(svc, window_ms=NEVER_MS, max_batch=4) as co:
        rep = co.warm_registry(ks=(3,))
        st = co.stats()
    assert st.warmed_shapes == len(rep.shapes) == 3 + 3   # plain + top_k
    assert set(st.warmup_compile_s) == set(rep.shapes)
    assert all(v >= 0 for v in st.warmup_compile_s.values())


def test_coalescer_record_warmup_merges_passes(stack):
    svc = _fresh_service(stack)
    with QueryCoalescer(svc, window_ms=NEVER_MS, max_batch=2) as co:
        co.warm_registry()                   # plain only
        co.warm_registry(ks=(3,), kinds=("top_k",))
        st = co.stats()
    assert set(st.warmup_compile_s) == {
        "plain/q1", "plain/q2", "top_k/q1/k3", "top_k/q2/k3"}
    assert st.warmed_shapes == 4


def test_deprecated_warm_shims_forward_to_registry(stack):
    """`warm` / `warm_top_k` keep their signatures but now run the
    registry pass -- and a short query list no longer truncates the
    bucket ladder (the old ad-hoc walkers stopped at len(qs))."""
    _, data, _, _ = stack
    svc = _fresh_service(stack)
    with QueryCoalescer(svc, window_ms=NEVER_MS, max_batch=4) as co:
        co.warm(list(data.queries[:2]))      # 2 queries, 3 buckets
        st = co.stats()
        assert set(st.warmup_compile_s) == {"plain/q1", "plain/q2",
                                            "plain/q4"}
        co.warm_top_k(list(data.queries[:1]), 3)
        st = co.stats()
    assert {"top_k/q1/k3", "top_k/q2/k3", "top_k/q4/k3"} <= \
        set(st.warmup_compile_s)
    # empty payload stays a no-op (the historical contract)
    svc2 = _fresh_service(stack)
    with QueryCoalescer(svc2, window_ms=NEVER_MS, max_batch=4) as co2:
        co2.warm([])
        assert co2.stats().warmed_shapes == 0


# ------------------------------------------------------- offline bulk mode

def test_query_file_roundtrip(tmp_path, stack):
    _, data, _, _ = stack
    qs = list(data.queries[:5])
    for name in ("golden.npz", "golden.npy"):
        path = save_query_file(tmp_path / name, qs)
        back = load_query_file(path)
        assert len(back) == 5
        for a, b in zip(qs, back):
            np.testing.assert_array_equal(np.asarray(a, np.float32), b)
    with pytest.raises(ValueError):
        np.savez(tmp_path / "bad.npz", a=np.zeros(3), b=np.zeros(3))
        load_query_file(tmp_path / "bad.npz")
    with pytest.raises(ValueError):
        np.save(tmp_path / "bad1d.npy", np.zeros(4, np.float32))
        load_query_file(tmp_path / "bad1d.npy")


def test_offline_plain_bitwise_same_compositions(stack):
    """Plain offline rows == a direct query_batch of the same full-bucket
    compositions, bitwise (the coalescer's composition-preserving
    contract applied to the offline scheduler's in-order cuts)."""
    _, data, _, svc = stack
    qs = list(data.queries[:10])             # 4 + 4 + 2 under max_batch=4
    off = run_offline(svc, qs, max_batch=4)
    assert off.mode == "plain" and off.n == 10 and off.batches == 3
    assert off.dists.shape == (10, svc.ell.num_docs)
    for lo in range(0, len(qs), 4):
        direct = np.asarray(svc.query_batch(qs[lo:lo + 4]))
        np.testing.assert_array_equal(off.dists[lo:lo + len(direct)],
                                      direct)


def test_offline_topk_union_equals_per_query_equals_scan(stack):
    """The rerank tier's bit-stability across Q: union rerank (one
    (Q, chunk) program per block), the online per-query rerank, and the
    exhaustive scan all agree bitwise on the same queries -- so offline
    top-k == online top-k REGARDLESS of batch composition."""
    _, data, _, svc = stack
    qs = list(data.queries[:6])
    off_u = run_offline(svc, qs, k=3, max_batch=4, rerank="union")
    off_p = run_offline(svc, qs, k=3, max_batch=4, rerank="per_query")
    np.testing.assert_array_equal(off_u.topk_idx, off_p.topk_idx)
    np.testing.assert_array_equal(off_u.topk_dist, off_p.topk_dist)
    # vs the online path at a DIFFERENT composition (singletons)
    for i, q in enumerate(qs):
        idx_1, d_1 = svc.top_k_batch([q], 3, prune=True)
        np.testing.assert_array_equal(off_u.topk_idx[i], idx_1[0])
        np.testing.assert_array_equal(off_u.topk_dist[i], d_1[0])
    # vs the exhaustive scan oracle
    idx_s, d_s = svc.top_k_scan_batch(qs, 3)
    np.testing.assert_array_equal(off_u.topk_idx, idx_s)
    np.testing.assert_array_equal(off_u.topk_dist, d_s)
    assert off_u.rerank_programs is not None
    assert off_u.rerank_programs <= off_p.rerank_programs


def test_offline_golden_query_file_end_to_end(tmp_path, stack):
    """The serve.py --offline path in miniature: golden query file on
    disk -> load -> bulk-score -> persisted outputs match the online
    engine bitwise."""
    _, data, _, svc = stack
    path = save_query_file(tmp_path / "workload.npz",
                           list(data.queries[:7]))
    qs = load_query_file(path)
    off = run_offline(svc, qs, k=3, max_batch=4)
    out = off.save(tmp_path / "scored.npz")
    with np.load(out) as z:
        np.testing.assert_array_equal(z["topk_idx"], off.topk_idx)
        np.testing.assert_array_equal(z["topk_dist"], off.topk_dist)
    idx_s, d_s = svc.top_k_scan_batch(qs, 3)
    np.testing.assert_array_equal(off.topk_idx, idx_s)
    np.testing.assert_array_equal(off.topk_dist, d_s)
    s = off.summary()
    assert s["mode"] == "top_k" and s["n"] == 7 and s["rerank"] == "union"
    assert s["throughput_qps"] > 0
    assert 0 <= s["solves_avoided"] <= 1


def test_run_offline_rejects_unknown_rerank(stack):
    _, data, _, svc = stack
    with pytest.raises(ValueError):
        run_offline(svc, list(data.queries[:2]), k=3, rerank="sideways")


# -------------------------------------------- persisted compilation cache

def test_persistent_cache_roundtrip_subprocess(tmp_path):
    """Cold process compiles and persists; a second identical process
    re-lowers but retrieves every program (0 backend compiles). Run in
    subprocesses because jax's cache config is process-global state."""
    import subprocess
    import sys
    script = r"""
import sys
import numpy as np
from repro.configs.sinkhorn_wmd import WMDConfig
from repro.data import make_corpus
from repro.launch.mesh import make_mesh
from repro.serving import (ShapeRegistry, WMDService,
                           enable_compilation_cache, warm)
from repro.serving.warmup import flush_compilation_cache

enable_compilation_cache(sys.argv[1])
cfg = WMDConfig(name="t-cache", vocab_size=96, embed_dim=8, num_docs=16,
                nnz_max=24, v_r=8, lamb=1.0, max_iter=4)
data = make_corpus(vocab_size=96, embed_dim=8, num_docs=16,
                   num_queries=2, query_words=5, mean_words=5.0, seed=0)
svc = WMDService(mesh=make_mesh((1, 1), ("data", "model")), cfg=cfg,
                 vecs=data.vecs, ell=data.ell)
rep = warm(svc, ShapeRegistry.from_service(svc, max_batch=2))
info = flush_compilation_cache()
print(f"RESULT compiles={rep.compiles} hits={rep.persistent_hits} "
      f"entries={info['entries']}")
"""
    env = dict(os.environ, PYTHONPATH="src")
    outs = []
    for _ in range(2):
        p = subprocess.run([sys.executable, "-c", script,
                            str(tmp_path / "jaxcache")],
                           capture_output=True, text=True, timeout=300,
                           env=env, cwd=os.path.dirname(
                               os.path.dirname(os.path.abspath(__file__))))
        assert p.returncode == 0, p.stderr
        line = [ln for ln in p.stdout.splitlines()
                if ln.startswith("RESULT")][0]
        outs.append(dict(kv.split("=") for kv in line.split()[1:]))
    cold, warm_run = outs
    assert int(cold["compiles"]) > 0
    assert int(cold["entries"]) > 0          # entries persisted on disk
    assert int(warm_run["compiles"]) == 0, \
        f"second process recompiled: {warm_run}"
    assert int(warm_run["hits"]) == int(cold["compiles"])
