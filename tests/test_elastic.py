"""Elastic mesh factoring: `mesh_shape` is pure (no devices needed), so
every shrink scenario from DESIGN.md section 7 is pinned here, including
the degenerate counts that used to divide by zero."""
import numpy as np
import pytest

from repro.distributed.elastic import mesh_shape, remesh


@pytest.mark.parametrize("n,mp,expect", [
    (16, 16, ((1, 16), ("data", "model"))),
    (32, 16, ((2, 16), ("data", "model"))),
    # 16 does not divide 12: model halves 16 -> 8 -> 4
    (12, 16, ((3, 4), ("data", "model"))),
    # odd survivor count: model collapses all the way to 1
    (7, 16, ((7, 1), ("data", "model"))),
    (1, 16, ((1, 1), ("data", "model"))),
    (1, 1, ((1, 1), ("data", "model"))),
    # no tensor parallelism requested
    (8, 1, ((8, 1), ("data", "model"))),
])
def test_mesh_shape_factorings(n, mp, expect):
    assert mesh_shape(n, model_parallelism=mp) == expect


def test_mesh_shape_multi_pod():
    shape, names = mesh_shape(1024, model_parallelism=16, pod_size=256)
    assert names == ("pod", "data", "model")
    assert shape == (4, 16, 16)
    assert int(np.prod(shape)) == 1024


def test_mesh_shape_pod_shrink_keeps_divisibility():
    # 768 = 3 pods of 256; every pod slice must still factor data x model
    shape, names = mesh_shape(768, model_parallelism=16, pod_size=256)
    pods, data, model = shape
    assert names == ("pod", "data", "model")
    assert pods * data * model == 768 and model == 16


def test_mesh_shape_degenerate_inputs():
    with pytest.raises(ValueError):
        mesh_shape(0)
    with pytest.raises(ValueError):
        mesh_shape(-4)
    # non-positive model parallelism clamps to 1 instead of ZeroDivisionError
    assert mesh_shape(6, model_parallelism=0) == ((6, 1), ("data", "model"))
    assert mesh_shape(6, model_parallelism=-2) == ((6, 1), ("data", "model"))


def test_remesh_materializes_on_cpu():
    mesh = remesh(1, model_parallelism=16)
    assert mesh.axis_names == ("data", "model")
    assert mesh.devices.shape == (1, 1)
