"""Property-based tests (hypothesis) on the system's invariants."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (cdist_matmul, ell_from_dense, pad_k, precompute,
                        sinkhorn_plan)
from repro.core import sparse_sinkhorn as ss
from repro.core.formats import rebucket_for_vocab_shards

pytest.importorskip("hypothesis")  # optional dev dep
from hypothesis import given, settings, strategies as st  # noqa: E402

_settings = settings(max_examples=25, deadline=None)


def _rand_hist(rng, n):
    h = rng.random(n) + 1e-3
    return (h / h.sum()).astype(np.float32)


@_settings
@given(st.integers(2, 24), st.integers(2, 24), st.integers(0, 1000))
def test_sinkhorn_plan_marginals(n, m, seed):
    """Transport plan marginals must match the inputs (Sinkhorn's defining
    property -- this is what the fixed-point iteration enforces)."""
    rng = np.random.default_rng(seed)
    cost = rng.random((n, m)).astype(np.float32) * 3
    a, b = _rand_hist(rng, n), _rand_hist(rng, m)
    res = sinkhorn_plan(jnp.asarray(cost), jnp.asarray(a), jnp.asarray(b),
                        lamb=5.0, max_iter=300)
    plan = np.asarray(res.plan)
    np.testing.assert_allclose(plan.sum(1), a, atol=2e-3)
    np.testing.assert_allclose(plan.sum(0), b, atol=2e-3)
    assert np.all(plan >= 0)


@_settings
@given(st.integers(2, 16), st.integers(0, 1000))
def test_sinkhorn_distance_symmetry(n, seed):
    """d(a,b) == d(b,a) for symmetric cost (Cuturi: Sinkhorn dist is a
    metric)."""
    rng = np.random.default_rng(seed)
    pts = rng.random((n, 3)).astype(np.float32)
    cost = np.asarray(cdist_matmul(jnp.asarray(pts), jnp.asarray(pts)))
    a, b = _rand_hist(rng, n), _rand_hist(rng, n)
    d_ab = sinkhorn_plan(jnp.asarray(cost), jnp.asarray(a), jnp.asarray(b),
                         lamb=8.0, max_iter=200).cost
    d_ba = sinkhorn_plan(jnp.asarray(cost.T), jnp.asarray(b),
                         jnp.asarray(a), lamb=8.0, max_iter=200).cost
    np.testing.assert_allclose(float(d_ab), float(d_ba), rtol=1e-3)


@_settings
@given(st.integers(3, 12), st.integers(0, 500))
def test_sinkhorn_self_distance_minimal(n, seed):
    """d(a, a) <= d(a, b) for any b (approximate identity property)."""
    rng = np.random.default_rng(seed)
    pts = rng.random((n, 3)).astype(np.float32) * 2
    cost = np.asarray(cdist_matmul(jnp.asarray(pts), jnp.asarray(pts)))
    a, b = _rand_hist(rng, n), _rand_hist(rng, n)
    d_aa = float(sinkhorn_plan(jnp.asarray(cost), jnp.asarray(a),
                               jnp.asarray(a), lamb=20.0,
                               max_iter=300).cost)
    d_ab = float(sinkhorn_plan(jnp.asarray(cost), jnp.asarray(a),
                               jnp.asarray(b), lamb=20.0,
                               max_iter=300).cost)
    assert d_aa <= d_ab + 1e-4


@_settings
@given(st.integers(8, 64), st.integers(2, 12), st.integers(0, 99))
def test_ell_dense_roundtrip(v, n, seed):
    rng = np.random.default_rng(seed)
    c = np.zeros((v, n), np.float32)
    for j in range(n):
        k = rng.integers(1, max(v // 4, 2))
        idx = rng.choice(v, k, replace=False)
        c[idx, j] = rng.random(k).astype(np.float32)
    ell = ell_from_dense(c)
    np.testing.assert_allclose(ell.to_dense(), c)
    assert ell.nnz == (c != 0).sum()


@_settings
@given(st.sampled_from([2, 4, 8]), st.integers(0, 99))
def test_rebucket_preserves_nonzeros(shards, seed):
    """Vocab re-bucketing is a partition: every nonzero lands in exactly one
    shard with a correctly localized id."""
    rng = np.random.default_rng(seed)
    v, n = 64, 10
    c = np.zeros((v, n), np.float32)
    for j in range(n):
        idx = rng.choice(v, rng.integers(1, 12), replace=False)
        c[idx, j] = rng.random(idx.size).astype(np.float32)
    ell = ell_from_dense(c)
    rb = rebucket_for_vocab_shards(ell, shards)
    vloc = v // shards
    rebuilt = np.zeros_like(c)
    for s in range(shards):
        for j in range(n):
            live = rb.vals[s, j] != 0
            np.add.at(rebuilt[:, j],
                      rb.cols[s, j][live] + s * vloc, rb.vals[s, j][live])
    np.testing.assert_allclose(rebuilt, c)


@_settings
@given(st.integers(0, 200))
def test_fused_equals_unfused(seed):
    """The paper's central claim: fusion changes performance, not results."""
    rng = np.random.default_rng(seed)
    v, w, n, vr = 96, 8, 12, 5
    vecs = rng.normal(size=(v, w)).astype(np.float32)
    sel = rng.choice(v, vr, replace=False).astype(np.int32)
    r_sel = _rand_hist(rng, vr)
    c = np.zeros((v, n), np.float32)
    for j in range(n):
        idx = rng.choice(v, rng.integers(2, 9), replace=False)
        c[idx, j] = rng.random(idx.size).astype(np.float32)
        c[:, j] /= c[:, j].sum()
    ell = ell_from_dense(c)
    pre = precompute(jnp.asarray(sel), jnp.asarray(r_sel),
                     jnp.asarray(vecs), 1.0)
    k_pad = pad_k(pre.K)
    u = jnp.asarray(rng.random((vr, n)).astype(np.float32) + 0.5)
    cols, vals = jnp.asarray(ell.cols), jnp.asarray(ell.vals)
    fused = ss.sddmm_spmm_type1(k_pad, pre.r, u, cols, vals)
    v_ = ss.sddmm(k_pad, u, cols, vals)
    unfused = ss.spmm(k_pad / pre.r[:, None], v_, cols)
    np.testing.assert_allclose(np.asarray(fused), np.asarray(unfused),
                               rtol=1e-5, atol=1e-7)


@_settings
@given(st.integers(1, 6), st.integers(0, 50))
def test_query_padding_exact(pad_extra, seed):
    """Mask-based query padding must not change the distances at all."""
    from repro.core.distributed import pad_query
    from repro.core import sinkhorn_wmd_sparse, select_query
    rng = np.random.default_rng(seed)
    v, w, n, vr = 80, 8, 10, 6
    vecs = rng.normal(size=(v, w)).astype(np.float32)
    r = np.zeros(v, np.float32)
    idx = rng.choice(v, vr, replace=False)
    r[idx] = _rand_hist(rng, vr)
    c = np.zeros((v, n), np.float32)
    for j in range(n):
        widx = rng.choice(v, rng.integers(2, 9), replace=False)
        c[widx, j] = rng.random(widx.size).astype(np.float32)
        c[:, j] /= c[:, j].sum()
    ell = ell_from_dense(c)
    sel, r_sel = select_query(r)
    cols, vals = jnp.asarray(ell.cols), jnp.asarray(ell.vals)
    base = np.asarray(sinkhorn_wmd_sparse(sel, r_sel, cols, vals, vecs,
                                          1.0, 8))
    # padded query: extra rows with r=1, zeroed K rows via mask -> identical
    sel_p, r_p, mask = pad_query(sel, r_sel, vr + pad_extra)
    from repro.core.distributed import masked_k
    from repro.core.sparse_sinkhorn import sinkhorn_wmd_sparse_pre
    from repro.core.sinkhorn import SinkhornPrecompute
    k, km = masked_k(jnp.asarray(vecs[sel_p]), jnp.asarray(vecs), 1.0,
                     jnp.asarray(mask))
    pre = SinkhornPrecompute(K=k, K_over_r=k / jnp.asarray(r_p)[:, None],
                             KM=km, r=jnp.asarray(r_p))
    padded = np.asarray(sinkhorn_wmd_sparse_pre(pre, cols, vals, 8))
    # padding changes x0 from 1/v_r to 1/(v_r+pad); the Sinkhorn map is
    # 1-homogeneous so the WMD is scale-invariant analytically -- the
    # residual is f32 rounding drift over the iterations, not leakage from
    # the pad rows (those are exactly zeroed by the K-row mask).
    np.testing.assert_allclose(padded, base, rtol=2e-3, atol=1e-5)
