"""Tests pinning the §Perf optimizations: length bucketing, doc-sharded WMD
engine, absorbed MLA (covered in test_layers), grouped MoE dispatch."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (bucket_by_length, ell_from_dense, precompute,
                        select_query, sinkhorn_wmd_sparse)
from repro.core.sparse_sinkhorn import sinkhorn_wmd_sparse_pre


def _problem(seed=0, v=256, w=16, n=48):
    rng = np.random.default_rng(seed)
    vecs = rng.normal(size=(v, w)).astype(np.float32)
    r = np.zeros(v, np.float32)
    idx = rng.choice(v, 9, replace=False)
    h = rng.random(9) + 1e-2
    r[idx] = (h / h.sum()).astype(np.float32)
    c = np.zeros((v, n), np.float32)
    for j in range(n):
        k = rng.integers(2, 30)            # wide length spread -> buckets
        widx = rng.choice(v, k, replace=False)
        c[widx, j] = rng.random(k).astype(np.float32)
        c[:, j] /= c[:, j].sum()
    return vecs, r, c


def test_bucketed_solve_matches_global():
    """Per-bucket solve (shared precompute) == global-ELL solve, reassembled
    into corpus order."""
    vecs, r, c = _problem()
    sel, r_sel = select_query(r)
    ell = ell_from_dense(c)
    ref = np.asarray(sinkhorn_wmd_sparse(sel, r_sel, jnp.asarray(ell.cols),
                                         jnp.asarray(ell.vals), vecs,
                                         1.0, 10))
    bk = bucket_by_length(ell)
    assert len(bk.buckets) >= 2             # spread actually bucketed
    assert bk.total_slots < ell.cols.size   # padding actually reduced
    pre = precompute(jnp.asarray(sel), jnp.asarray(r_sel),
                     jnp.asarray(vecs), 1.0)
    per_bucket = [np.asarray(sinkhorn_wmd_sparse_pre(
        pre, jnp.asarray(b.cols), jnp.asarray(b.vals), 10))
        for b in bk.buckets]
    got = bk.scatter(per_bucket, ell.num_docs)
    np.testing.assert_allclose(got, ref, rtol=2e-3, atol=1e-5)


def test_bucket_doc_ids_partition():
    """Every doc appears in exactly one bucket."""
    _, _, c = _problem(seed=3)
    ell = ell_from_dense(c)
    bk = bucket_by_length(ell)
    all_ids = np.concatenate(bk.doc_ids)
    assert sorted(all_ids.tolist()) == list(range(ell.num_docs))


def test_bucket_nnz_preserved():
    _, _, c = _problem(seed=4)
    ell = ell_from_dense(c)
    bk = bucket_by_length(ell)
    assert bk.nnz == ell.nnz


def test_moe_grouped_dispatch_matches_ungrouped_semantics():
    """Grouped (per-batch-row) dispatch with ample capacity must equal a
    token-by-token reference computation of the same routing."""
    from repro.configs.base import ModelConfig, MoEConfig
    from repro.models.layers import moe as moe_mod
    cfg = ModelConfig(
        name="t", family="moe", num_layers=1, d_model=16, num_heads=2,
        num_kv_heads=2, head_dim=8, d_ff=0, vocab_size=64,
        moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=12,
                      capacity_factor=8.0))
    params = moe_mod.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(3, 8, 16)), jnp.float32)
    out, _ = moe_mod.apply(cfg, params, x)

    # token-by-token reference
    xf = np.asarray(x).reshape(-1, 16)
    logits = xf @ np.asarray(params["router"])
    ids, weights, _ = moe_mod._gates(cfg.moe, jnp.asarray(logits))
    ids, weights = np.asarray(ids), np.asarray(weights)
    ref = np.zeros_like(xf)
    wg = np.asarray(params["wi_gate"]); wu = np.asarray(params["wi_up"])
    wo = np.asarray(params["wo"])
    silu = lambda z: z / (1 + np.exp(-z))
    for t in range(xf.shape[0]):
        for j in range(cfg.moe.top_k):
            e = ids[t, j]
            h = silu(xf[t] @ wg[e]) * (xf[t] @ wu[e])
            ref[t] += weights[t, j] * (h @ wo[e])
    np.testing.assert_allclose(np.asarray(out).reshape(-1, 16), ref,
                               rtol=2e-3, atol=1e-4)


def test_docsharded_engine_available():
    """Doc-sharded engine builds and matches on a 1x1 mesh."""
    from repro.core.distributed import build_wmd_fn_docsharded, pad_query
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((1, 1), ("data", "model"))
    vecs, r, c = _problem(seed=6)
    sel, r_sel = select_query(r)
    ell = ell_from_dense(c)
    ref = np.asarray(sinkhorn_wmd_sparse(sel, r_sel, jnp.asarray(ell.cols),
                                         jnp.asarray(ell.vals), vecs,
                                         1.0, 8))
    sel_p, r_p, mask = pad_query(sel, r_sel, 16)
    fn = build_wmd_fn_docsharded(mesh, lamb=1.0, max_iter=8)
    got = np.asarray(fn(jnp.asarray(vecs[sel_p]), jnp.asarray(r_p),
                        jnp.asarray(mask), jnp.asarray(vecs),
                        jnp.asarray(ell.cols), jnp.asarray(ell.vals)))
    np.testing.assert_allclose(got, ref, rtol=2e-3, atol=1e-5)
