"""Multi-query batched PASWD engine: batched == sequential oracle across
mixed-v_r query sets, per-query convergence masking is exact, and pad
rows/slots contribute exactly zero."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (ell_from_dense, precompute_batch, select_query,
                        sddmm_spmm_type2_batch, pad_k,
                        sinkhorn_wmd_converged, sinkhorn_wmd_converged_batch,
                        sinkhorn_wmd_sparse, sinkhorn_wmd_sparse_batch)
from repro.core.distributed import pad_query_batch
from repro.core.sparse_sinkhorn import safe_recip

LAMB, ITERS = 1.0, 12


@pytest.fixture(scope="module")
def batch_problem():
    """Corpus + Q=4 queries with mixed v_r (5, 9, 13, 16 nonzero words)."""
    rng = np.random.default_rng(7)
    v, w, n = 256, 24, 48
    vecs = rng.normal(size=(v, w)).astype(np.float32)
    c = np.zeros((v, n), np.float32)
    for j in range(n):
        widx = rng.choice(v, rng.integers(4, 20), replace=False)
        c[widx, j] = rng.random(widx.size).astype(np.float32)
        c[:, j] /= c[:, j].sum()
    ell = ell_from_dense(c)
    queries = []
    for vr in (5, 9, 13, 16):
        r = np.zeros(v, np.float32)
        idx = rng.choice(v, vr, replace=False)
        r[idx] = rng.random(vr).astype(np.float32)
        r /= r.sum()
        queries.append(r)
    sels, rsels = zip(*[select_query(r) for r in queries])
    return {"vecs": vecs, "ell": ell, "queries": queries,
            "sels": sels, "rsels": rsels,
            "cols": jnp.asarray(ell.cols), "vals": jnp.asarray(ell.vals)}


def _batched(p, v_r_target, max_iter=ITERS):
    sel_b, r_b, mask_b = pad_query_batch(p["sels"], p["rsels"], v_r_target)
    return np.asarray(sinkhorn_wmd_sparse_batch(
        jnp.asarray(sel_b), jnp.asarray(r_b), p["cols"], p["vals"],
        p["vecs"], LAMB, max_iter, row_mask=jnp.asarray(mask_b)))


def test_batched_matches_sequential_oracle(batch_problem):
    """(a) batched (Q, v_r, N) engine == per-query solves, mixed v_r."""
    p = batch_problem
    batch = _batched(p, v_r_target=16)
    seq = np.stack([
        np.asarray(sinkhorn_wmd_sparse(s, r, p["cols"], p["vals"], p["vecs"],
                                       LAMB, ITERS))
        for s, r in zip(p["sels"], p["rsels"])])
    assert batch.shape == seq.shape
    err = np.abs(batch - seq).max() / np.abs(seq).max()
    assert err < 1e-4, err


def test_convergence_masking_exact(batch_problem):
    """(b) freezing converged queries never changes their results: each
    query's (wmd, n_iter) from the masked batch equals its solo solve."""
    p = batch_problem
    sel_b, r_b, mask_b = pad_query_batch(p["sels"], p["rsels"], 16)
    out = sinkhorn_wmd_converged_batch(
        jnp.asarray(sel_b), jnp.asarray(r_b), p["cols"], p["vals"],
        p["vecs"], LAMB, 500, tol=1e-5, row_mask=jnp.asarray(mask_b))
    n_iter = np.asarray(out.n_iter)
    # queries genuinely converge at different iterations -> masking engaged
    assert n_iter.min() < n_iter.max()
    assert n_iter.max() < 500
    for i, (s, r) in enumerate(zip(p["sels"], p["rsels"])):
        solo = sinkhorn_wmd_converged(s, r, p["cols"], p["vals"], p["vecs"],
                                      LAMB, 500, tol=1e-5)
        assert int(n_iter[i]) == int(solo.n_iter), i
        rel = (np.abs(np.asarray(out.wmd[i]) - np.asarray(solo.wmd)).max()
               / np.abs(np.asarray(solo.wmd)).max())
        assert rel < 1e-4, (i, rel)


def test_pad_rows_contribute_exactly_zero(batch_problem):
    """(c1) the masked K stripes of pad rows are exactly zero, and an
    all-pad (filler) query solves to exactly zero WMD."""
    p = batch_problem
    sel_b, r_b, mask_b = pad_query_batch(p["sels"], p["rsels"], 16)
    pre = precompute_batch(jnp.asarray(sel_b), jnp.asarray(r_b),
                           jnp.asarray(p["vecs"]), LAMB,
                           row_mask=jnp.asarray(mask_b))
    k = np.asarray(pre.K)
    km = np.asarray(pre.KM)
    for i in range(len(p["sels"])):
        vr = p["sels"][i].shape[0]
        np.testing.assert_array_equal(k[i, vr:], 0.0)
        np.testing.assert_array_equal(km[i, vr:], 0.0)
    # all-pad query (the service's Q-bucket filler): WMD exactly 0
    q1 = jnp.zeros((1, 16), jnp.int32)
    wmd = sinkhorn_wmd_sparse_batch(
        q1, jnp.ones((1, 16), jnp.float32), p["cols"], p["vals"], p["vecs"],
        LAMB, ITERS, row_mask=jnp.zeros((1, 16), jnp.float32))
    np.testing.assert_array_equal(np.asarray(wmd), 0.0)


def test_pad_slots_and_rows_inert_in_contractions(batch_problem):
    """(c2) ELL pad slots (col == V) read the appended zero K column, so
    flipping a pad slot's column id changes nothing; and distances are
    invariant (to fp tolerance) to the amount of row padding."""
    p = batch_problem
    sel_b, r_b, mask_b = pad_query_batch(p["sels"], p["rsels"], 16)
    pre = precompute_batch(jnp.asarray(sel_b), jnp.asarray(r_b),
                          jnp.asarray(p["vecs"]), LAMB,
                          row_mask=jnp.asarray(mask_b))
    k_pad, km_pad = pad_k(pre.K), pad_k(pre.KM)
    q, v_r = r_b.shape
    n = p["cols"].shape[0]
    u = safe_recip(jnp.full((q, v_r, n), 1.0 / v_r, jnp.float32))
    wmd_a = np.asarray(sddmm_spmm_type2_batch(k_pad, km_pad, u,
                                              p["cols"], p["vals"]))
    # retarget every pad slot (val == 0) from pad id V to word 0: must be
    # bit-identical because the `vals != 0` mask gates those slots.
    cols_mut = jnp.where(p["vals"] == 0.0, 0, p["cols"])
    wmd_b = np.asarray(sddmm_spmm_type2_batch(k_pad, km_pad, u,
                                              cols_mut, p["vals"]))
    np.testing.assert_array_equal(wmd_a, wmd_b)
    # row-padding invariance: v_r bucket 16 vs 32 (pad rows only add zeros)
    d16 = _batched(p, v_r_target=16)
    d32 = _batched(p, v_r_target=32)
    np.testing.assert_allclose(d16, d32, rtol=2e-5)


def test_distributed_batch_fn_matches_single_chip():
    """build_wmd_batch_fn on a (2, 2) mesh == per-query single-chip solves
    (subprocess: needs a forced device count)."""
    import os
    import subprocess
    import sys
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    code = """
import numpy as np, jax, jax.numpy as jnp
from repro.core import (select_query, sinkhorn_wmd_sparse, ell_from_dense,
                        rebucket_for_vocab_shards)
from repro.core.distributed import (build_wmd_batch_fn, pad_query_batch,
                                    shard_wmd_inputs)
from repro.launch.mesh import make_mesh

mesh = make_mesh((2, 2), ("data", "model"))
rng = np.random.default_rng(3)
V, w, N = 256, 32, 64
vecs = rng.normal(size=(V, w)).astype(np.float32)
c = np.zeros((V, N), np.float32)
for j in range(N):
    widx = rng.choice(V, rng.integers(3, 17), replace=False)
    c[widx, j] = rng.random(widx.size).astype(np.float32)
    c[:, j] /= c[:, j].sum()
ell = ell_from_dense(c)
queries = []
for vrn in (5, 9, 14):
    r = np.zeros(V, np.float32)
    idx = rng.choice(V, vrn, replace=False)
    r[idx] = rng.random(vrn).astype(np.float32); r /= r.sum()
    queries.append(r)
sels, rsels = zip(*[select_query(r) for r in queries])
ref = np.stack([np.asarray(sinkhorn_wmd_sparse(
    s, r, jnp.asarray(ell.cols), jnp.asarray(ell.vals), vecs, 1.0, 12))
    for s, r in zip(sels, rsels)])
sel_b, r_b, mask_b = pad_query_batch(sels, rsels, 16)
rb = rebucket_for_vocab_shards(ell, 2)
fn = build_wmd_batch_fn(mesh, lamb=1.0, max_iter=12)
vd, cd, vld = shard_wmd_inputs(mesh, vecs, rb.cols, rb.vals)
got = np.asarray(fn(jnp.asarray(vecs[sel_b]), jnp.asarray(r_b),
                    jnp.asarray(mask_b), vd, cd, vld))
err = np.abs(got - ref).max() / np.abs(ref).max()
assert got.shape == ref.shape, (got.shape, ref.shape)
assert err < 1e-4, err
print("DIST_BATCH_OK", err)
"""
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=4",
               PYTHONPATH=os.path.join(repo, "src"))
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
    assert "DIST_BATCH_OK" in out.stdout


def test_service_query_batch_matches_sequential():
    """WMDService.query_batch == the sequential per-query loop (single
    device), including non-power-of-two Q admission."""
    from repro.configs import sinkhorn_wmd as wmd_cfg
    from repro.data import make_corpus
    from repro.launch.mesh import make_mesh
    from repro.serving import WMDService
    mesh = make_mesh((1, 1), ("data", "model"))
    cfg = wmd_cfg.smoke_config()
    data = make_corpus(vocab_size=cfg.vocab_size, embed_dim=cfg.embed_dim,
                       num_docs=cfg.num_docs, num_queries=3,
                       query_words=cfg.v_r - 2, seed=1)
    svc = WMDService(mesh=mesh, cfg=cfg, vecs=data.vecs, ell=data.ell)
    batch = svc.query_batch(data.queries)        # Q=3 -> padded to 4
    seq = svc.query_batch_sequential(data.queries)
    assert batch.shape == (3, cfg.num_docs)
    err = np.abs(batch - seq).max() / np.abs(seq).max()
    assert err < 1e-4, err
    assert svc.query_batch([]).shape == (0, cfg.num_docs)
