"""Kernel-oracle fuzz for the RWMD min-SDDMM Pallas kernel (kernels.rwmd),
mirroring test_kernels.py: three-way agreement pallas == core-jnp == naive
dense oracle over random shapes, including non-tile-multiple v_r / N / V
and the +inf pad-row convention. CPU runs interpret mode; the accel.yml
runner exercises the compiled Mosaic path through the same selectors."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import assemble_m_stripes, ell_from_dense, rwmd_bound_batch
from repro.kernels import ops, ref

pytestmark = pytest.mark.kernel


def _problem(v, n, vr_bucket, q, nnz_hi, seed, *, n_pad_rows=2):
    """Random M stripes (+inf pad rows) + ELL; returns (m_pad, cols, vals)."""
    rng = np.random.default_rng(seed)
    vecs = rng.normal(size=(v, 12)).astype(np.float32)
    c = np.zeros((v, n), np.float32)
    for j in range(n):
        widx = rng.choice(v, rng.integers(2, nnz_hi), replace=False)
        c[widx, j] = rng.random(widx.size).astype(np.float32)
        c[:, j] /= c[:, j].sum()
    ell = ell_from_dense(c)
    sel_b = np.zeros((q, vr_bucket), np.int32)
    mask_b = np.zeros((q, vr_bucket), np.float32)
    for i in range(q):
        real = vr_bucket - (n_pad_rows if i % 2 else 0)
        sel_b[i, :real] = rng.choice(v, real, replace=False)
        mask_b[i, :real] = 1.0
    m_pad = assemble_m_stripes(sel_b, mask_b, vecs, rows_bucket=8)
    return m_pad, jnp.asarray(ell.cols), jnp.asarray(ell.vals)


# (V, N, v_r bucket, Q, nnz_hi) -- deliberately awkward: odd doc counts,
# v_r not a sublane multiple, V not a power of two, Q not a q_blk multiple
SHAPES = [(64, 16, 5, 2, 9), (97, 21, 11, 3, 8), (130, 40, 13, 5, 14),
          (256, 33, 17, 9, 20)]


@pytest.mark.parametrize("v,n,vr,q,nnz_hi", SHAPES)
def test_rwmd_kernel_threeway(v, n, vr, q, nnz_hi):
    m_pad, cols, vals = _problem(v, n, vr, q, nnz_hi, seed=v + n)
    lb_ref = np.asarray(ref.rwmd_bound_batch(m_pad, cols, vals))
    lb_core = np.asarray(rwmd_bound_batch(m_pad, cols, vals))
    lb_pal = np.asarray(ops.rwmd_bound_batch(m_pad, cols, vals))
    np.testing.assert_allclose(lb_core, lb_ref, rtol=1e-6, atol=1e-7)
    np.testing.assert_allclose(lb_pal, lb_ref, rtol=1e-6, atol=1e-7)


@pytest.mark.parametrize("docs_blk,q_blk", [(4, 2), (8, 8), (16, 4)])
def test_rwmd_kernel_tiling_invariance(docs_blk, q_blk):
    """BlockSpec tiling must not change results."""
    m_pad, cols, vals = _problem(96, 32, 7, 4, 10, seed=7)
    base = ops.rwmd_bound_batch(m_pad, cols, vals, docs_blk=8)
    got = ops.rwmd_bound_batch(m_pad, cols, vals, docs_blk=docs_blk,
                               q_blk=q_blk)
    np.testing.assert_allclose(np.asarray(got), np.asarray(base), rtol=1e-6)


def test_rwmd_kernel_filler_query_rows_zero():
    """All-+inf filler stripes (pow2 admission filler) come back exactly 0
    from the kernel wrapper, matching the jnp and oracle paths."""
    m_pad, cols, vals = _problem(64, 16, 6, 3, 8, seed=3)
    filler = jnp.full((1,) + m_pad.shape[1:], jnp.inf, m_pad.dtype)
    m_f = jnp.concatenate([m_pad, filler])
    for fn in (ops.rwmd_bound_batch, ref.rwmd_bound_batch,
               rwmd_bound_batch):
        lb = np.asarray(fn(m_f, cols, vals))
        assert np.all(lb[-1] == 0.0), fn
        # and the real rows are untouched by the filler's presence
        np.testing.assert_array_equal(
            lb[:-1], np.asarray(fn(m_pad, cols, vals)))


def test_rwmd_kernel_docs_chunk_maps_to_grid():
    """core dispatch impl='kernel' routes docs_chunk onto the doc-tile grid
    (the kernel's native blocking) -- same results as the default tile."""
    m_pad, cols, vals = _problem(64, 24, 5, 2, 8, seed=11)
    base = rwmd_bound_batch(m_pad, cols, vals, impl="kernel")
    got = rwmd_bound_batch(m_pad, cols, vals, impl="kernel", docs_chunk=4)
    np.testing.assert_allclose(np.asarray(got), np.asarray(base), rtol=1e-6)


# ---------------------------------------------------------------------------
# LC-RWMD (kernels.lcrwmd): the tier-1 dense-gather + SpMV kernel
# ---------------------------------------------------------------------------

def _lc_problem(v, n, vr, q, nnz_hi, seed):
    """Same random stripes, reduced to (Q, V+1) min-cost vectors."""
    from repro.core import min_cost_vectors
    m_pad, cols, vals = _problem(v, n, vr, q, nnz_hi, seed=seed)
    return min_cost_vectors(m_pad), m_pad, cols, vals


@pytest.mark.parametrize("v,n,vr,q,nnz_hi", SHAPES)
def test_lc_rwmd_kernel_threeway(v, n, vr, q, nnz_hi):
    """pallas == core-jnp == naive dense oracle, and all bitwise equal to
    the doc-side bound they hoist the min out of (the cascade's LC link)."""
    from repro.core import lc_rwmd_bound_batch
    minm, m_pad, cols, vals = _lc_problem(v, n, vr, q, nnz_hi, seed=v + n)
    lb_ref = np.asarray(ref.lc_rwmd_bound_batch(minm, cols, vals))
    lb_core = np.asarray(lc_rwmd_bound_batch(minm, cols, vals))
    lb_pal = np.asarray(ops.lc_rwmd_bound_batch(minm, cols, vals))
    np.testing.assert_allclose(lb_core, lb_ref, rtol=1e-6, atol=1e-7)
    np.testing.assert_allclose(lb_pal, lb_ref, rtol=1e-6, atol=1e-7)
    np.testing.assert_array_equal(
        lb_core, np.asarray(rwmd_bound_batch(m_pad, cols, vals)))


@pytest.mark.parametrize("docs_blk,q_blk", [(4, 2), (8, 8), (16, 4)])
def test_lc_rwmd_kernel_tiling_invariance(docs_blk, q_blk):
    minm, _, cols, vals = _lc_problem(96, 32, 7, 4, 10, seed=7)
    base = ops.lc_rwmd_bound_batch(minm, cols, vals, docs_blk=8)
    got = ops.lc_rwmd_bound_batch(minm, cols, vals, docs_blk=docs_blk,
                                  q_blk=q_blk)
    np.testing.assert_allclose(np.asarray(got), np.asarray(base), rtol=1e-6)


def test_lc_rwmd_kernel_filler_query_rows_zero():
    """All-+inf min-cost vectors (filler queries) finite-ize to exactly 0
    in every spelling, and their presence leaves real rows untouched."""
    from repro.core import lc_rwmd_bound_batch
    minm, _, cols, vals = _lc_problem(64, 16, 6, 3, 8, seed=3)
    filler = jnp.full((1, minm.shape[1]), jnp.inf, minm.dtype)
    m_f = jnp.concatenate([minm, filler])
    for fn in (ops.lc_rwmd_bound_batch, ref.lc_rwmd_bound_batch,
               lc_rwmd_bound_batch):
        lb = np.asarray(fn(m_f, cols, vals))
        assert np.all(lb[-1] == 0.0), fn
        np.testing.assert_array_equal(
            lb[:-1], np.asarray(fn(minm, cols, vals)))


def test_lc_rwmd_kernel_docs_chunk_maps_to_grid():
    """core dispatch impl='kernel' routes docs_chunk onto the doc-tile
    grid -- same results as the default tile."""
    from repro.core import lc_rwmd_bound_batch
    minm, _, cols, vals = _lc_problem(64, 24, 5, 2, 8, seed=11)
    base = lc_rwmd_bound_batch(minm, cols, vals, impl="kernel")
    got = lc_rwmd_bound_batch(minm, cols, vals, impl="kernel", docs_chunk=4)
    np.testing.assert_allclose(np.asarray(got), np.asarray(base), rtol=1e-6)
