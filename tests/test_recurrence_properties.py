"""Property-based tests on the recurrent layers' algebraic invariants:
chunkwise mLSTM == step recurrence for random gates/chunks; RG-LRU
associative-scan composition; state-passing consistency (prefill in two
halves == one pass)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.layers.xlstm import mlstm_chunkwise, mlstm_recurrent

pytest.importorskip("hypothesis")  # optional dev dep
from hypothesis import given, settings, strategies as st  # noqa: E402

_settings = settings(max_examples=15, deadline=None)


def _mlstm_inputs(rng, b, h, t, hd):
    q = jnp.asarray(rng.normal(size=(b, h, t, hd)), jnp.float32) * hd ** -0.5
    k = jnp.asarray(rng.normal(size=(b, h, t, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, h, t, hd)), jnp.float32)
    li = jnp.asarray(rng.normal(size=(b, h, t)) * 2, jnp.float32)
    lf = jnp.asarray(
        np.log(1 / (1 + np.exp(-rng.normal(size=(b, h, t)) - 2))),
        jnp.float32)
    return q, k, v, li, lf


@_settings
@given(st.sampled_from([8, 16, 32, 64]), st.integers(0, 500))
def test_mlstm_chunkwise_matches_recurrent(chunk, seed):
    rng = np.random.default_rng(seed)
    q, k, v, li, lf = _mlstm_inputs(rng, b=1, h=2, t=64, hd=8)
    h_ref, (c_r, n_r, m_r) = mlstm_recurrent(q, k, v, li, lf)
    h_ck, (c_c, n_c, m_c) = mlstm_chunkwise(q, k, v, li, lf, chunk=chunk)
    np.testing.assert_allclose(np.asarray(h_ck), np.asarray(h_ref),
                               atol=2e-3)
    np.testing.assert_allclose(np.asarray(m_c), np.asarray(m_r), atol=1e-4)


@_settings
@given(st.integers(0, 500))
def test_mlstm_state_passing_split(seed):
    """Running two half-sequences with carried state == one full pass."""
    rng = np.random.default_rng(seed)
    q, k, v, li, lf = _mlstm_inputs(rng, b=1, h=2, t=64, hd=8)
    h_full, st_full = mlstm_chunkwise(q, k, v, li, lf, chunk=16)
    h1, st1 = mlstm_chunkwise(q[:, :, :32], k[:, :, :32], v[:, :, :32],
                              li[:, :, :32], lf[:, :, :32], chunk=16)
    h2, st2 = mlstm_chunkwise(q[:, :, 32:], k[:, :, 32:], v[:, :, 32:],
                              li[:, :, 32:], lf[:, :, 32:], chunk=16,
                              state=st1)
    np.testing.assert_allclose(
        np.asarray(jnp.concatenate([h1, h2], axis=2)),
        np.asarray(h_full), atol=2e-3)
    for a, b in zip(st2, st_full):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-3)


@_settings
@given(st.integers(0, 500))
def test_rglru_scan_operator_associative(seed):
    """The (a, b) combine operator used in the associative scan must be
    associative (required for lax.associative_scan correctness)."""
    rng = np.random.default_rng(seed)
    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, a2 * b1 + b2
    es = [(rng.random(4).astype(np.float64),
           rng.normal(size=4).astype(np.float64)) for _ in range(3)]
    left = combine(combine(es[0], es[1]), es[2])
    right = combine(es[0], combine(es[1], es[2]))
    np.testing.assert_allclose(left[0], right[0], rtol=1e-12)
    np.testing.assert_allclose(left[1], right[1], rtol=1e-10, atol=1e-12)


@_settings
@given(st.integers(0, 300))
def test_rglru_prefill_decode_state_consistency(seed):
    """Prefill state (return_state) == decoding the same tokens stepwise."""
    from repro.configs import get_smoke_config
    from repro.models.layers import rglru as rg
    cfg = get_smoke_config("recurrentgemma-9b")
    params = rg.init(jax.random.PRNGKey(seed % 7), cfg)
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(1, 6, cfg.d_model)) * 0.5, jnp.float32)
    _, st_full = rg.fwd_full(cfg, params, x, return_state=True)
    st = rg.init_state(cfg, 1)
    for t in range(6):
        _, st = rg.fwd_decode(cfg, params, x[:, t:t + 1], st)
    np.testing.assert_allclose(np.asarray(st.h), np.asarray(st_full.h),
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(st.conv),
                               np.asarray(st_full.conv), atol=1e-5)
