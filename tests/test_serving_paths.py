"""Serving-path correctness: ring-buffer windowed decode vs a full-cache
reference, cache sharding specs, elastic checkpoint reshard."""
import dataclasses
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.models.layers import attention


def test_ring_buffer_decode_matches_full_cache():
    """A windowed (SWA) layer decoded through its ring buffer must equal the
    same layer decoded with an unbounded cache + window mask."""
    cfg = get_smoke_config("mixtral-8x22b")           # swa window=16
    params = attention.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    b, steps = 2, 40                                   # > 2x window: wraps
    xs = jnp.asarray(rng.normal(size=(b, steps, cfg.d_model)) * 0.3,
                     jnp.float32)

    # ring buffer path (buf = window = 16)
    cache = attention.init_cache(cfg, b, max_len=steps, dtype=jnp.float32)
    assert cache.k.shape[1] == cfg.window             # ring sizing
    outs_ring = []
    for t in range(steps):
        y, cache = attention.fwd_decode(cfg, params, xs[:, t:t + 1], cache)
        outs_ring.append(y)

    # reference: full cache with the window enforced by masking
    full_cfg = dataclasses.replace(cfg, attn_kind="full", window=0)
    ref_cache = attention.init_cache(full_cfg, b, max_len=steps,
                                     dtype=jnp.float32)
    # emulate windowed attention on the full cache by re-deriving from
    # fwd_full at each prefix length (teacher-forced windowed attention)
    y_ref_all = attention.fwd_full(cfg, params, xs, q_block=8, kv_block=8)
    ring = jnp.concatenate(outs_ring, axis=1)
    np.testing.assert_allclose(np.asarray(ring), np.asarray(y_ref_all),
                               atol=5e-4)


def test_cache_len_sizing():
    swa = get_smoke_config("mixtral-8x22b")
    assert attention.cache_len(swa, 32768) == swa.window
    full = get_smoke_config("olmo-1b")
    assert attention.cache_len(full, 32768) == 32768


def test_cache_shardings_divisibility_safe():
    """Every cache spec produced must be loadable as explicit jit shardings
    (even divisibility), for every arch at every decode shape."""
    from repro.configs import arch_ids, get_config
    from repro.distributed import partitioning
    from repro.models import build_model
    # abstract mesh: spec-only validation without needing 8 real devices
    from repro.compat import abstract_mesh
    mesh = abstract_mesh((2, 4), ("data", "model"))
    for arch in arch_ids():
        cfg = get_config(arch)
        model = build_model(cfg)
        cstruct = jax.eval_shape(lambda m=model: m.init_cache(8, 64))
        shards = partitioning.cache_shardings(mesh, cstruct)
        for leaf, sh in zip(jax.tree.leaves(cstruct),
                            jax.tree.leaves(shards,
                                            is_leaf=lambda x: isinstance(
                                                x, jax.sharding.Sharding))):
            for dim, entry in enumerate(sh.spec):
                if entry is None:
                    continue
                axes = entry if isinstance(entry, tuple) else (entry,)
                factor = int(np.prod([mesh.shape[a] for a in axes]))
                assert leaf.shape[dim] % factor == 0, (arch, leaf.shape,
                                                       sh.spec)


def test_checkpoint_elastic_reshard():
    """Save on one mesh factoring, restore onto another."""
    from repro.checkpoint import checkpointer as ckpt
    from repro.launch.mesh import make_mesh
    from jax.sharding import NamedSharding, PartitionSpec as P
    state = {"w": jnp.arange(32, dtype=jnp.float32).reshape(8, 4)}
    with tempfile.TemporaryDirectory() as td:
        ckpt.save(td, 1, state, mesh_signature="data=1xmodel=1")
        mesh = make_mesh((1, 1), ("data", "model"))
        sh = {"w": NamedSharding(mesh, P("data", None))}
        restored = ckpt.restore(td, 1, jax.eval_shape(lambda: state),
                                shardings=sh)
        np.testing.assert_array_equal(np.asarray(restored["w"]),
                                      np.asarray(state["w"]))
        assert restored["w"].sharding.spec == P("data", None)


def test_decode_cache_donation_shape_stable():
    """Repeated decode steps keep cache shapes/dtypes identical (donation
    contract for the serving loop)."""
    from repro.models import build_model
    cfg = get_smoke_config("gemma-2b")
    model = build_model(cfg, q_block=8, kv_block=8)
    params = model.init(jax.random.PRNGKey(0))
    cache = model.init_cache(2, 16)
    struct0 = jax.tree.map(lambda x: (x.shape, x.dtype), cache)
    tok = jnp.zeros((2, 1), jnp.int32)
    for _ in range(3):
        _, cache = model.decode(params, cache, tok)
    struct1 = jax.tree.map(lambda x: (x.shape, x.dtype), cache)
    assert struct0 == struct1
