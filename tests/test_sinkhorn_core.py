"""Core Sinkhorn-WMD: paper Algorithm 1 semantics, dense == sparse == fused,
convergence behavior, and the paper's f32-transcendental error envelope."""
import jax.numpy as jnp
import numpy as np

from repro.core import (ell_from_dense, select_query, sinkhorn_wmd_converged,
                        sinkhorn_wmd_dense, sinkhorn_wmd_sparse)


def _solve_all(p):
    sel, r_sel = select_query(p["r"])
    ell = ell_from_dense(p["c"])
    dense = np.asarray(sinkhorn_wmd_dense(sel, r_sel, p["c"], p["vecs"],
                                          p["lamb"], p["iters"]))
    cols, vals = jnp.asarray(ell.cols), jnp.asarray(ell.vals)
    fused = np.asarray(sinkhorn_wmd_sparse(sel, r_sel, cols, vals,
                                           p["vecs"], p["lamb"], p["iters"],
                                           impl="fused"))
    unfused = np.asarray(sinkhorn_wmd_sparse(sel, r_sel, cols, vals,
                                             p["vecs"], p["lamb"],
                                             p["iters"], impl="unfused"))
    return dense, fused, unfused


def test_dense_sparse_agree(wmd_problem):
    dense, fused, unfused = _solve_all(wmd_problem)
    np.testing.assert_allclose(fused, dense, rtol=2e-5)
    np.testing.assert_allclose(unfused, dense, rtol=2e-5)
    # fusion must be numerically identical to unfused (same math)
    np.testing.assert_allclose(fused, unfused, rtol=2e-6)


def test_wmd_positive_finite(wmd_problem):
    dense, _, _ = _solve_all(wmd_problem)
    assert np.all(np.isfinite(dense))
    assert np.all(dense > 0)


def test_more_iterations_converge(wmd_problem):
    """Successive iteration counts approach a fixed point."""
    p = wmd_problem
    sel, r_sel = select_query(p["r"])
    w5 = np.asarray(sinkhorn_wmd_dense(sel, r_sel, p["c"], p["vecs"],
                                       p["lamb"], 5))
    w50 = np.asarray(sinkhorn_wmd_dense(sel, r_sel, p["c"], p["vecs"],
                                        p["lamb"], 50))
    w100 = np.asarray(sinkhorn_wmd_dense(sel, r_sel, p["c"], p["vecs"],
                                         p["lamb"], 100))
    d_early = np.abs(w50 - w5).max()
    d_late = np.abs(w100 - w50).max()
    assert d_late < d_early


def test_converged_early_exit(wmd_problem):
    p = wmd_problem
    sel, r_sel = select_query(p["r"])
    ell = ell_from_dense(p["c"])
    out = sinkhorn_wmd_converged(sel, r_sel, jnp.asarray(ell.cols),
                                 jnp.asarray(ell.vals), p["vecs"],
                                 p["lamb"], 500, tol=1e-5)
    assert int(out.n_iter) < 500          # actually exits early
    ref = np.asarray(sinkhorn_wmd_dense(sel, r_sel, p["c"], p["vecs"],
                                        p["lamb"], 500))
    np.testing.assert_allclose(np.asarray(out.wmd), ref, rtol=1e-3)


def test_self_distance_smallest(wmd_problem):
    """A doc with exactly the query's histogram must be the nearest doc."""
    p = wmd_problem
    c = p["c"].copy()
    c[:, 0] = p["r"]                      # doc 0 == query
    sel, r_sel = select_query(p["r"])
    d = np.asarray(sinkhorn_wmd_dense(sel, r_sel, c, p["vecs"],
                                      p["lamb"], 50))
    assert np.argmin(d) == 0


def test_f32_error_envelope(wmd_problem):
    """Paper section IV-A: f32 transcendentals vs f64 within ~1e-6 relative.

    (The paper reports <= 9.5e-7 absolute on its data; we assert the same
    order of magnitude relative to the distance scale.)"""
    p = wmd_problem
    sel, r_sel = select_query(p["r"])
    f32 = np.asarray(sinkhorn_wmd_dense(sel, r_sel, p["c"], p["vecs"],
                                        p["lamb"], p["iters"]))
    # f64 oracle in numpy
    f64 = _numpy_f64_reference(p, sel, r_sel)
    rel = np.abs(f32 - f64) / np.abs(f64)
    assert rel.max() < 5e-5, rel.max()


def _numpy_f64_reference(p, sel, r_sel):
    """Straight float64 port of the paper's Fig. 3 Python code."""
    vecs = p["vecs"].astype(np.float64)
    c = p["c"].astype(np.float64)
    r = r_sel.astype(np.float64)
    a = vecs[sel]
    m = np.sqrt(np.maximum(
        (a * a).sum(1)[:, None] + (vecs * vecs).sum(1)[None, :]
        - 2 * a @ vecs.T, 0))
    k = np.exp(-p["lamb"] * m)
    k_over_r = k / r[:, None]
    kt = k.T
    km = k * m
    x = np.ones((len(r), c.shape[1])) / len(r)
    for _ in range(p["iters"]):
        u = 1.0 / x
        w = kt @ u
        v = np.where(c != 0, c / np.maximum(w, 1e-300), 0.0)
        x = k_over_r @ v
    u = 1.0 / x
    w = kt @ u
    v = np.where(c != 0, c / np.maximum(w, 1e-300), 0.0)
    return (u * (km @ v)).sum(axis=0)


def test_against_f64_oracle(wmd_problem):
    """End-to-end check against an independent numpy f64 implementation."""
    p = wmd_problem
    sel, r_sel = select_query(p["r"])
    ell = ell_from_dense(p["c"])
    got = np.asarray(sinkhorn_wmd_sparse(sel, r_sel, jnp.asarray(ell.cols),
                                         jnp.asarray(ell.vals), p["vecs"],
                                         p["lamb"], p["iters"]))
    ref = _numpy_f64_reference(p, sel, r_sel)
    np.testing.assert_allclose(got, ref, rtol=5e-5)
