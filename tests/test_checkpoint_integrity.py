"""Checkpoint integrity satellites: async write failures surface instead
of dying silently, shard checksums catch truncation/bit-flips, and
`latest_step` falls back past corrupt or incomplete steps."""
import glob
import json
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import checkpointer as ckpt
from repro.checkpoint import CheckpointCorruptionError


def _state(seed=0):
    rng = np.random.default_rng(seed)
    return {"w": jnp.asarray(rng.normal(size=(4, 3)).astype(np.float32)),
            "b": jnp.asarray(rng.normal(size=(3,)).astype(np.float32))}


def _shard_path(ckpt_dir, step):
    (path,) = glob.glob(
        os.path.join(ckpt_dir, f"step_{step:08d}", "shard_0.msgpack*"))
    return path


def _unwritable_dir(tmp_path):
    """A checkpoint-dir path that cannot be written to: its parent is a
    regular file, so makedirs fails with NotADirectoryError even for root
    (plain chmod is ignored under CAP_DAC_OVERRIDE)."""
    blocker = os.path.join(str(tmp_path), "blocker")
    with open(blocker, "w") as f:
        f.write("not a directory")
    return os.path.join(blocker, "ckpts")


def test_async_write_failure_raised_on_wait(tmp_path):
    td = str(tmp_path / "good")
    c = ckpt.AsyncCheckpointer(td)
    c.save(1, _state())
    c.wait()                                     # good save: no error
    c.ckpt_dir = _unwritable_dir(tmp_path)       # now unwritable
    c.save(2, _state())
    with pytest.raises(OSError):
        c.wait()                                 # background failure lands
    c.wait()                                     # ... exactly once
    assert ckpt.latest_step(td) == 1             # step 2 never appeared


def test_async_write_failure_raised_on_next_save(tmp_path):
    c = ckpt.AsyncCheckpointer(_unwritable_dir(tmp_path))
    c.save(1, _state())
    with pytest.raises(OSError):
        c.save(2, _state())                      # save() waits first


def test_truncated_shard_detected(tmp_path):
    td = str(tmp_path)
    state = _state()
    ckpt.save(td, 1, state)
    ckpt.save(td, 2, state)
    shard = _shard_path(td, 2)
    size = os.path.getsize(shard)
    with open(shard, "r+b") as f:
        f.truncate(size // 2)
    with pytest.raises(CheckpointCorruptionError, match="shard"):
        ckpt.restore(td, 2, state)
    # latest_step skips the corrupt step and lands on the last good one
    assert ckpt.latest_step(td) == 1
    r = ckpt.restore(td, 1, state)
    np.testing.assert_array_equal(np.asarray(r["w"]), np.asarray(state["w"]))


def test_bitflip_shard_detected(tmp_path):
    td = str(tmp_path)
    state = _state()
    ckpt.save(td, 1, state)
    ckpt.save(td, 5, state)
    shard = _shard_path(td, 5)
    with open(shard, "r+b") as f:
        f.seek(os.path.getsize(shard) // 3)
        b = f.read(1)
        f.seek(-1, os.SEEK_CUR)
        f.write(bytes([b[0] ^ 0x10]))            # same length, wrong bits
    with pytest.raises(CheckpointCorruptionError):
        ckpt.restore(td, 5, state)
    assert ckpt.latest_step(td) == 1


def test_missing_meta_skipped_by_latest_step(tmp_path):
    td = str(tmp_path)
    state = _state()
    ckpt.save(td, 1, state)
    ckpt.save(td, 2, state)
    os.remove(os.path.join(td, "step_00000002", "meta.json"))
    assert ckpt.latest_step(td) == 1
    os.remove(_shard_path(td, 1))                # shard gone entirely
    assert ckpt.latest_step(td) is None


def test_meta_carries_shard_checksum(tmp_path):
    td = str(tmp_path)
    ckpt.save(td, 3, _state())
    with open(os.path.join(td, "step_00000003", "meta.json")) as f:
        meta = json.load(f)
    (name, rec), = meta["shards"].items()
    assert name.startswith("shard_0.msgpack")
    assert len(rec["sha256"]) == 64
    assert rec["bytes"] == os.path.getsize(_shard_path(td, 3))


def test_legacy_checkpoint_without_checksums_restores(tmp_path):
    # checkpoints written before the "shards" key existed stay readable
    td = str(tmp_path)
    state = _state()
    ckpt.save(td, 1, state)
    meta_path = os.path.join(td, "step_00000001", "meta.json")
    with open(meta_path) as f:
        meta = json.load(f)
    del meta["shards"]
    with open(meta_path, "w") as f:
        json.dump(meta, f)
    assert ckpt.latest_step(td) == 1             # trusted as-is
    r = ckpt.restore(td, 1, state)
    np.testing.assert_array_equal(np.asarray(r["b"]), np.asarray(state["b"]))
