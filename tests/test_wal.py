"""WAL framing and recovery semantics: CRC-checked roundtrip, torn-tail
truncation, crash-boundary durability (torn => dropped, synced => kept)."""
import os
import struct

import pytest

from repro.data import wal
from repro.serving.faultinject import CrashInjector, InjectedCrash


def _log(tmp_path):
    return os.path.join(str(tmp_path), "test.log")


def test_roundtrip_append_replay(tmp_path):
    path = _log(tmp_path)
    recs = [{"op": "add", "ids": [1, 2], "docs": [[[0, 1.0]], []]},
            {"op": "remove", "ids": [7]},
            {"op": "add", "ids": [3], "docs": [[[5, 0.25], [6, 0.75]]]}]
    with wal.WalWriter(path) as w:
        for r in recs:
            off = w.append(r)
    assert off == os.path.getsize(path)
    assert wal.replay(path) == recs


def test_missing_file_is_empty_log(tmp_path):
    assert wal.replay(os.path.join(str(tmp_path), "nope.log")) == []


def test_append_extends_existing_log(tmp_path):
    path = _log(tmp_path)
    with wal.WalWriter(path) as w:
        w.append({"n": 1})
    with wal.WalWriter(path) as w:
        w.append({"n": 2})
    assert wal.replay(path) == [{"n": 1}, {"n": 2}]


@pytest.mark.parametrize("damage", ["garbage", "short_header",
                                    "short_payload", "bitflip"])
def test_torn_tail_truncated(tmp_path, damage):
    path = _log(tmp_path)
    with wal.WalWriter(path) as w:
        w.append({"n": 1})
        good = w.append({"n": 2})
    with open(path, "r+b") as f:
        f.seek(0, os.SEEK_END)
        if damage == "garbage":
            f.write(b"\xde\xad\xbe\xef" * 4)
        elif damage == "short_header":
            f.write(b"\x08")                       # 1 of 8 header bytes
        elif damage == "short_payload":
            f.write(struct.pack("<II", 100, 0))    # header promises 100B
            f.write(b"xy")                         # ... delivers 2
        elif damage == "bitflip":
            f.seek(good + 4)                       # flip inside record 3's
            f.write(struct.pack("<II", 3, 42))     # header-to-be => bad CRC
            f.write(b"abc")
    assert wal.replay(path) == [{"n": 1}, {"n": 2}]
    assert os.path.getsize(path) == good           # truncated back
    with wal.WalWriter(path) as w:                 # and extendable again
        w.append({"n": 3})
    assert wal.replay(path) == [{"n": 1}, {"n": 2}, {"n": 3}]


def test_corruption_mid_file_drops_suffix(tmp_path):
    path = _log(tmp_path)
    offs = []
    with wal.WalWriter(path) as w:
        for n in range(4):
            offs.append(w.append({"n": n}))
    with open(path, "r+b") as f:          # flip one payload byte of rec 1
        f.seek(offs[0] + wal._HDR.size)
        b = f.read(1)
        f.seek(offs[0] + wal._HDR.size)
        f.write(bytes([b[0] ^ 0xFF]))
    # records 2,3 are intact on disk but unreachable past the bad record:
    # the truncation rule discards the whole suffix (standard WAL recovery)
    assert wal.replay(path) == [{"n": 0}]
    assert os.path.getsize(path) == offs[0]


def test_crash_at_torn_boundary_record_dropped(tmp_path):
    path = _log(tmp_path)
    with wal.WalWriter(path) as w:
        w.append({"n": 1})
    hook = CrashInjector(target=1, match="wal")     # 0=pre, 1=torn
    w = wal.WalWriter(path, hook=hook)
    with pytest.raises(InjectedCrash):
        w.append({"n": 2, "pad": "x" * 64})
    assert hook.crashed_at[1] == "wal.append.torn"
    assert os.path.getsize(path) > 0
    # the un-acked half-written record is truncated away on replay
    assert wal.replay(path) == [{"n": 1}]
    with wal.WalWriter(path) as w2:
        w2.append({"n": 3})
    assert wal.replay(path) == [{"n": 1}, {"n": 3}]


def test_crash_after_sync_record_survives(tmp_path):
    path = _log(tmp_path)
    hook = CrashInjector(target=2, match="wal")     # 2=synced
    w = wal.WalWriter(path, hook=hook)
    with pytest.raises(InjectedCrash):
        w.append({"n": 1})
    assert hook.crashed_at[1] == "wal.append.synced"
    # fsync happened before the crash: the record is durable (the caller
    # never acked it, and replay legally surfaces it -- acked is a one-way
    # contract: acked => recoverable, not recoverable => acked)
    assert wal.replay(path) == [{"n": 1}]


def test_boundary_order_per_append(tmp_path):
    hook = CrashInjector()                          # pure counter
    with wal.WalWriter(_log(tmp_path), hook=hook) as w:
        w.append({"n": 1})
        w.append({"n": 2})
    assert hook.log == ["wal.append.pre", "wal.append.torn",
                        "wal.append.synced"] * 2


def test_replay_no_truncate_leaves_file(tmp_path):
    path = _log(tmp_path)
    with wal.WalWriter(path) as w:
        w.append({"n": 1})
    with open(path, "ab") as f:
        f.write(b"torn")
    size = os.path.getsize(path)
    assert wal.replay(path, truncate=False) == [{"n": 1}]
    assert os.path.getsize(path) == size            # inspect-only mode
