"""Property suite of the two-tier pruned retriever (core.rwmd + the pruned
`WMDService.top_k`).

The invariants, in decreasing order of load-bearing-ness:
  1. soundness -- the doc-side RWMD bound never exceeds the engine's
     returned distance, for every impl and (crucially) every iteration
     budget. This is THE fact the pruning contract rests on, and the
     reason the doc side was chosen: the engine enforces the doc-side
     marginal exactly at every iterate, while the classic query-side
     bound only holds at convergence (demonstrated below).
  2. exactness -- pruned top-k == the exhaustive chunked scan, bitwise,
     under random k / N / capacity / chunk.
  3. inertness -- pad query rows and pad ELL slots contribute exactly
     zero to the bound reduction.

Each invariant has a seeded always-on test (runs everywhere, no optional
deps) and a hypothesis-driven generalization (random shapes/seeds searched
adversarially; skipped when hypothesis is absent, executed seeded in CI via
``--hypothesis-seed=0`` -- see ci.yml's property step).
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.sinkhorn_wmd import WMDConfig
from repro.core import (assemble_m_stripes, ell_from_dense, rwmd_bound_batch,
                        rwmd_query_side_bound, select_query,
                        sinkhorn_wmd_sparse_batch)
from repro.core.distributed import pad_query_batch
from repro.data import make_corpus, zipf_query_stream
from repro.launch.mesh import make_mesh
from repro.serving import WMDService

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                     # container without the dev extra:
    given = None                        # seeded subset still runs


# ---------------------------------------------------------------------------
# shared problem builders
# ---------------------------------------------------------------------------

def _problem(seed, *, v=96, w=8, n=20, vr_bucket=8, q=3):
    """Random batched WMD problem: (sel_b, r_b, mask_b, cols, vals, vecs)."""
    rng = np.random.default_rng(seed)
    vecs = rng.normal(size=(v, w)).astype(np.float32)
    c = np.zeros((v, n), np.float32)
    for j in range(n):
        widx = rng.choice(v, rng.integers(2, 9), replace=False)
        c[widx, j] = rng.random(widx.size).astype(np.float32)
        c[:, j] /= c[:, j].sum()
    ell = ell_from_dense(c)
    rs = []
    for i in range(q):
        r = np.zeros(v, np.float32)
        idx = rng.choice(v, int(rng.integers(3, vr_bucket + 1)),
                         replace=False)
        r[idx] = rng.random(idx.size).astype(np.float32) + 0.1
        r /= r.sum()
        rs.append(r)
    sels, rsels = zip(*[select_query(r) for r in rs])
    sel_b, r_b, mask_b = pad_query_batch(sels, rsels, vr_bucket)
    return sel_b, r_b, mask_b, ell, vecs


def _bound_and_dist(sel_b, r_b, mask_b, ell, vecs, *, max_iter, impl):
    cols, vals = jnp.asarray(ell.cols), jnp.asarray(ell.vals)
    m_pad = assemble_m_stripes(sel_b, mask_b, vecs, rows_bucket=8)
    lb = np.asarray(rwmd_bound_batch(m_pad, cols, vals))
    d = np.asarray(sinkhorn_wmd_sparse_batch(
        jnp.asarray(sel_b), jnp.asarray(r_b), cols, vals,
        jnp.asarray(vecs), 1.0, max_iter,
        row_mask=jnp.asarray(mask_b), impl=impl))
    return lb, d


def _service(seed, *, docs, vocab=512, capacity=0, prune_chunk=16, k_cfg=16):
    data = make_corpus(vocab_size=vocab, embed_dim=32, num_docs=docs,
                       num_queries=1, query_words=11, mean_words=12.0,
                       seed=seed)
    cfg = WMDConfig(name="prop", vocab_size=vocab, embed_dim=32,
                    num_docs=docs, nnz_max=64, v_r=k_cfg, lamb=1.0,
                    max_iter=8)
    mesh = make_mesh((1, 1), ("data", "model"))
    return WMDService(mesh=mesh, cfg=cfg, vecs=data.vecs, ell=data.ell,
                      cache_capacity=capacity, prune_chunk=prune_chunk,
                      bound_docs_chunk=None)


def _queries(vocab, q, seed):
    stream = zipf_query_stream(vocab_size=vocab, query_words=11, s=1.2,
                               seed=seed)
    return [next(stream) for _ in range(q)]


# ---------------------------------------------------------------------------
# 1. soundness: bound <= engine output, every impl, every iteration budget
# ---------------------------------------------------------------------------

# fp slack of the comparison: the bound and the distance accumulate their
# dot products in different orders, so they may disagree by rounding even
# when mathematically ordered. The service's prune_margin (1e-3) dominates
# this by ~100x.
RTOL, ATOL = 1e-5, 1e-6


@pytest.mark.parametrize("impl", ["fused", "unfused", "kernel"])
@pytest.mark.parametrize("max_iter", [1, 3, 15])
def test_bound_below_engine_all_impls_all_budgets(impl, max_iter):
    """rwmd(q, d) <= sinkhorn_wmd(q, d) at ANY fixed iteration budget --
    including budget 1, where the query-side marginal is maximally stale."""
    sel_b, r_b, mask_b, ell, vecs = _problem(seed=max_iter * 7 + 1)
    lb, d = _bound_and_dist(sel_b, r_b, mask_b, ell, vecs,
                            max_iter=max_iter, impl=impl)
    assert np.all(lb <= d * (1 + RTOL) + ATOL), \
        f"bound exceeds engine output by {np.max(lb - d)}"


def test_query_side_bound_only_sound_at_convergence():
    """The classic query-side RWMD bounds the *converged* distance (200
    iterations) but is allowed to exceed a budget-limited one -- the
    asymmetry that drove the doc-side choice (core.rwmd docstring)."""
    sel_b, r_b, mask_b, ell, vecs = _problem(seed=3, n=24, q=4)
    cols, vals = jnp.asarray(ell.cols), jnp.asarray(ell.vals)
    m_pad = assemble_m_stripes(sel_b, mask_b, vecs, rows_bucket=8)
    lb_q = np.asarray(rwmd_query_side_bound(m_pad, jnp.asarray(r_b),
                                            cols, vals))
    d_conv = np.asarray(sinkhorn_wmd_sparse_batch(
        jnp.asarray(sel_b), jnp.asarray(r_b), cols, vals,
        jnp.asarray(vecs), 1.0, 200, row_mask=jnp.asarray(mask_b)))
    assert np.all(lb_q <= d_conv * (1 + 1e-4) + ATOL)


def test_bound_impls_agree():
    """fused == kernel == chunked, and all equal the dense oracle."""
    from repro.kernels import ops, ref
    sel_b, _, mask_b, ell, vecs = _problem(seed=11)
    cols, vals = jnp.asarray(ell.cols), jnp.asarray(ell.vals)
    m_pad = assemble_m_stripes(sel_b, mask_b, vecs, rows_bucket=8)
    lb = np.asarray(rwmd_bound_batch(m_pad, cols, vals))
    lb_c = np.asarray(rwmd_bound_batch(m_pad, cols, vals, docs_chunk=7))
    lb_k = np.asarray(ops.rwmd_bound_batch(m_pad, cols, vals))
    lb_r = np.asarray(ref.rwmd_bound_batch(m_pad, cols, vals))
    np.testing.assert_array_equal(lb, lb_c)
    np.testing.assert_allclose(lb_k, lb_r, rtol=1e-6, atol=1e-7)
    np.testing.assert_allclose(lb, lb_r, rtol=1e-6, atol=1e-7)


# ---------------------------------------------------------------------------
# 2. exactness: pruned top-k == exhaustive scan, bitwise
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("k,docs,capacity,chunk",
                         [(1, 48, 0, 8), (5, 80, 256, 16),
                          (16, 64, 64, 32), (7, 100, 0, 100)])
def test_pruned_topk_equals_scan(k, docs, capacity, chunk):
    svc = _service(seed=k, docs=docs, capacity=capacity, prune_chunk=chunk)
    qs = _queries(512, 3, seed=k)
    idx_p, d_p = svc.top_k_batch(qs, k, prune=True)
    ps = dict(svc.last_prune_stats)
    idx_s, d_s = svc.top_k_scan_batch(qs, k)
    np.testing.assert_array_equal(idx_p, idx_s)
    np.testing.assert_array_equal(d_p, d_s)
    # the prefilter must actually do something -- unless one block already
    # covers the whole corpus (chunk >= docs), where nothing CAN be pruned
    if chunk < docs:
        assert ps["solves_avoided"] > 0.0
    # and agree with the production one-program full scan as a SET (only
    # fp32-close: different program shapes vectorize differently, the same
    # engine-vs-engine tolerance the batched/sequential tests use)
    idx_f, d_f = svc.top_k_batch(qs, k)
    np.testing.assert_array_equal(np.sort(idx_p, -1), np.sort(idx_f, -1))
    np.testing.assert_allclose(d_p, d_f, rtol=1e-3, atol=1e-5)


def test_pruned_topk_k_exceeds_docs():
    """k > N degrades to k = N and still matches the scan bitwise."""
    svc = _service(seed=5, docs=24, prune_chunk=8)
    qs = _queries(512, 2, seed=5)
    idx_p, d_p = svc.top_k_batch(qs, 99, prune=True)
    idx_s, d_s = svc.top_k_scan_batch(qs, 99)
    assert idx_p.shape == (2, 24)
    np.testing.assert_array_equal(idx_p, idx_s)
    np.testing.assert_array_equal(d_p, d_s)


def test_pruned_topk_duplicate_docs_tie_deterministic():
    """Duplicate docs produce exactly tied distances; the (distance, id)
    selection rule must return the identical set from every route."""
    data = make_corpus(vocab_size=256, embed_dim=16, num_docs=30,
                      num_queries=1, query_words=9, mean_words=10.0, seed=2)
    dense = data.ell.to_dense()
    dense[:, 15:30] = dense[:, 0:15]          # 15 exact duplicates
    ell = ell_from_dense(dense)
    cfg = WMDConfig(name="ties", vocab_size=256, embed_dim=16, num_docs=30,
                    nnz_max=64, v_r=16, lamb=1.0, max_iter=8)
    mesh = make_mesh((1, 1), ("data", "model"))
    svc = WMDService(mesh=mesh, cfg=cfg, vecs=data.vecs, ell=ell,
                     prune_chunk=8, bound_docs_chunk=None)
    qs = _queries(256, 2, seed=9)
    idx_p, d_p = svc.top_k_batch(qs, 6, prune=True)
    idx_s, d_s = svc.top_k_scan_batch(qs, 6)
    np.testing.assert_array_equal(idx_p, idx_s)
    np.testing.assert_array_equal(d_p, d_s)
    idx_f, _ = svc.top_k_batch(qs, 6)
    np.testing.assert_array_equal(np.sort(idx_p, -1), np.sort(idx_f, -1))


def test_pruned_single_query_route():
    svc = _service(seed=8, docs=40, prune_chunk=8)
    q = _queries(512, 1, seed=8)[0]
    idx1, d1 = svc.top_k(q, 4, prune=True)
    idx_b, d_b = svc.top_k_batch([q], 4, prune=True)
    np.testing.assert_array_equal(idx1, idx_b[0])
    np.testing.assert_array_equal(d1, d_b[0])


def test_coalesced_topk_bitwise_and_homogeneous():
    """submit_top_k coalesces like plain queries: homogeneous batches, each
    one literally a top_k_batch(prune=True) dispatch -- results bitwise
    equal to the direct call; mixed kinds split at the kind boundary."""
    svc = _service(seed=13, docs=48, capacity=256, prune_chunk=16)
    qs = _queries(512, 6, seed=13)
    svc.query_batch(qs[:4])                       # compile outside serving
    svc.top_k_batch(qs[:4], 3, prune=True)
    with svc.async_service(window_ms=50.0, max_batch=4) as co:
        # homogeneous run: 4 top-k requests must cut as ONE batch
        futs = [co.submit_top_k(r, 3) for r in qs[:4]]
        co.drain()
        idx_d, d_d = svc.top_k_batch(qs[:4], 3, prune=True)
        for i, f in enumerate(futs):
            idx, d = f.result()
            np.testing.assert_array_equal(idx, idx_d[i])
            np.testing.assert_array_equal(d, d_d[i])
        st = co.stats()
        assert st.batch_size_hist.get(4, 0) >= 1   # coalesced, not split
        # mixed kinds: a plain query between top-k runs forces a cut at
        # each kind change -- every request still answered correctly
        f1 = co.submit_top_k(qs[4], 2)
        f2 = co.submit(qs[4])
        f3 = co.submit_top_k(qs[5], 2)
        co.drain()
        np.testing.assert_array_equal(f2.result(),
                                      svc.query_batch([qs[4]])[0])
        i1, dd1 = svc.top_k_batch([qs[4]], 2, prune=True)
        np.testing.assert_array_equal(f1.result()[0], i1[0])
        i3, dd3 = svc.top_k_batch([qs[5]], 2, prune=True)
        np.testing.assert_array_equal(f3.result()[1], dd3[0])


# ---------------------------------------------------------------------------
# 3. inertness: pad rows / pad slots contribute exactly zero
# ---------------------------------------------------------------------------

def test_pad_rows_and_slots_inert():
    """Growing the v_r bucket (more +inf pad rows) and appending pad ELL
    slots must not change a single bit of the bound."""
    sel_b, r_b, mask_b, ell, vecs = _problem(seed=21, vr_bucket=6)
    cols, vals = jnp.asarray(ell.cols), jnp.asarray(ell.vals)
    m_pad = assemble_m_stripes(sel_b, mask_b, vecs, rows_bucket=8)
    lb = np.asarray(rwmd_bound_batch(m_pad, cols, vals))
    # wider bucket: re-pad the same queries to v_r + 5
    pad = ((0, 0), (0, 5))
    sel_w = np.pad(sel_b, pad)
    mask_w = np.pad(mask_b, pad)
    m_w = assemble_m_stripes(sel_w, mask_w, vecs, rows_bucket=8)
    lb_w = np.asarray(rwmd_bound_batch(m_w, cols, vals))
    np.testing.assert_array_equal(lb_w, lb)
    # extra pad slots on every doc (col = V, val = 0)
    n, nnz = ell.cols.shape
    cols_s = np.concatenate(
        [ell.cols, np.full((n, 3), ell.num_vocab, ell.cols.dtype)], axis=1)
    vals_s = np.concatenate([ell.vals, np.zeros((n, 3), ell.vals.dtype)],
                            axis=1)
    lb_s = np.asarray(rwmd_bound_batch(m_pad, jnp.asarray(cols_s),
                                       jnp.asarray(vals_s)))
    np.testing.assert_array_equal(lb_s, lb)


def test_filler_queries_and_empty_docs_bound_zero():
    """All-pad filler queries and empty docs bound to exactly 0.0 -- the
    engine's distance for both -- so a 0 bound can never prune them."""
    sel_b, r_b, mask_b, ell, vecs = _problem(seed=31, n=12)
    # append a filler query and an empty doc
    sel_f = np.concatenate([sel_b, np.zeros((1,) + sel_b.shape[1:],
                                            sel_b.dtype)])
    mask_f = np.concatenate([mask_b, np.zeros((1,) + mask_b.shape[1:],
                                              mask_b.dtype)])
    n, nnz = ell.cols.shape
    cols_e = np.concatenate(
        [ell.cols, np.full((1, nnz), ell.num_vocab, ell.cols.dtype)])
    vals_e = np.concatenate([ell.vals, np.zeros((1, nnz), ell.vals.dtype)])
    m_pad = assemble_m_stripes(sel_f, mask_f, vecs, rows_bucket=8)
    lb = np.asarray(rwmd_bound_batch(m_pad, jnp.asarray(cols_e),
                                     jnp.asarray(vals_e)))
    assert np.all(lb[-1] == 0.0)        # filler query row
    assert np.all(lb[:, -1] == 0.0)     # empty doc column


# ---------------------------------------------------------------------------
# hypothesis generalizations (skipped without the dev extra; CI runs them
# seeded via --hypothesis-seed=0)
# ---------------------------------------------------------------------------

if given is not None:
    _settings = settings(max_examples=15, deadline=None)

    @_settings
    @given(st.integers(0, 10_000), st.integers(1, 12),
           st.sampled_from(["fused", "unfused"]))
    def test_hyp_bound_below_engine(seed, max_iter, impl):
        sel_b, r_b, mask_b, ell, vecs = _problem(seed=seed)
        lb, d = _bound_and_dist(sel_b, r_b, mask_b, ell, vecs,
                                max_iter=max_iter, impl=impl)
        assert np.all(lb <= d * (1 + RTOL) + ATOL)

    @_settings
    @given(st.integers(0, 10_000), st.integers(1, 20),
           st.integers(30, 90), st.sampled_from([0, 64, 1024]),
           st.sampled_from([4, 16, 64]))
    def test_hyp_pruned_equals_scan(seed, k, docs, capacity, chunk):
        svc = _service(seed=seed % 97, docs=docs, capacity=capacity,
                       prune_chunk=chunk)
        qs = _queries(512, 2, seed=seed)
        idx_p, d_p = svc.top_k_batch(qs, k, prune=True)
        idx_s, d_s = svc.top_k_scan_batch(qs, k)
        np.testing.assert_array_equal(idx_p, idx_s)
        np.testing.assert_array_equal(d_p, d_s)

    @_settings
    @given(st.integers(0, 10_000), st.integers(1, 8))
    def test_hyp_pad_rows_inert(seed, extra):
        sel_b, _, mask_b, ell, vecs = _problem(seed=seed, vr_bucket=6)
        cols, vals = jnp.asarray(ell.cols), jnp.asarray(ell.vals)
        m_pad = assemble_m_stripes(sel_b, mask_b, vecs, rows_bucket=8)
        lb = np.asarray(rwmd_bound_batch(m_pad, cols, vals))
        pad = ((0, 0), (0, extra))
        m_w = assemble_m_stripes(np.pad(sel_b, pad), np.pad(mask_b, pad),
                                 vecs, rows_bucket=8)
        lb_w = np.asarray(rwmd_bound_batch(m_w, cols, vals))
        np.testing.assert_array_equal(lb_w, lb)
